//! Bench: PJRT execute path — per-chunk dispatch cost, executable-cache
//! effect, and PJRT-vs-native throughput on the artifact grid. Skips
//! cleanly when `artifacts/` has not been built.

use hclfft::coordinator::engine::{NativeEngine, RowFftEngine};
use hclfft::dft::fft::Direction;
use hclfft::dft::SignalMatrix;
use hclfft::runtime::PjrtRowFftEngine;
use hclfft::stats::harness::{fft_flops, BenchSuite};

fn main() {
    let dir = std::path::Path::new("artifacts");
    if !dir.join("manifest.tsv").exists() {
        println!("bench_runtime skipped: run `make artifacts` first");
        return;
    }
    let engine = PjrtRowFftEngine::load(dir).expect("pjrt engine");
    let mut suite = BenchSuite::from_env("runtime");
    for &n in &[128usize, 512, 2048] {
        for rows in [8usize, 128] {
            let mut m = SignalMatrix::random(rows, n, 3);
            suite.bench_flops(&format!("pjrt_row_fft_{rows}x{n}"), fft_flops(rows, n), || {
                engine
                    .fft_rows(&mut m.re, &mut m.im, rows, n, Direction::Forward, 1)
                    .unwrap();
            });
            let mut m2 = SignalMatrix::random(rows, n, 3);
            suite.bench_flops(&format!("native_row_fft_{rows}x{n}"), fft_flops(rows, n), || {
                NativeEngine
                    .fft_rows(&mut m2.re, &mut m2.im, rows, n, Direction::Forward, 1)
                    .unwrap();
            });
        }
    }
    // ragged batch exercises the greedy chunk tiling (128+32+8+1...)
    let mut m = SignalMatrix::random(173, 256, 9);
    suite.bench_flops("pjrt_ragged_173x256", fft_flops(173, 256), || {
        engine.fft_rows(&mut m.re, &mut m.im, 173, 256, Direction::Forward, 1).unwrap();
    });
    suite.write_json(std::path::Path::new("results/bench_runtime.json")).ok();
    println!("{}", suite.report());
}
