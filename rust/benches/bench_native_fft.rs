//! Bench: native row-FFT throughput across lengths — the real-machine
//! analogue of the paper's speed functions (Figures 13-14). Reports
//! MFLOPs via the paper's speed formula so numbers are comparable with
//! the published plots.

use hclfft::coordinator::engine::{NativeEngine, RowFftEngine};
use hclfft::dft::fft::Direction;
use hclfft::dft::SignalMatrix;
use hclfft::stats::harness::{fft_flops, BenchSuite};

fn main() {
    let mut suite = BenchSuite::from_env("native_fft");
    for &n in &[128usize, 256, 512, 1024, 2048] {
        let rows = 64;
        let mut m = SignalMatrix::random(rows, n, n as u64);
        suite.bench_flops(&format!("row_fft_{rows}x{n}"), fft_flops(rows, n), || {
            NativeEngine
                .fft_rows(&mut m.re, &mut m.im, rows, n, Direction::Forward, 1)
                .unwrap();
        });
    }
    // non-pow2 5-smooth paper sizes — mixed-radix since the executor
    // refactor (see benches/bench_fft_sizes.rs for the vs-Bluestein A/B)
    for &n in &[192usize, 384, 1920] {
        let rows = 32;
        let mut m = SignalMatrix::random(rows, n, 1);
        suite.bench_flops(&format!("smooth_{rows}x{n}"), fft_flops(rows, n), || {
            NativeEngine
                .fft_rows(&mut m.re, &mut m.im, rows, n, Direction::Forward, 1)
                .unwrap();
        });
    }
    // non-smooth length (128·7): the Bluestein fallback path
    {
        let (rows, n) = (32usize, 896usize);
        let mut m = SignalMatrix::random(rows, n, 2);
        suite.bench_flops(&format!("bluestein_{rows}x{n}"), fft_flops(rows, n), || {
            NativeEngine
                .fft_rows(&mut m.re, &mut m.im, rows, n, Direction::Forward, 1)
                .unwrap();
        });
    }
    suite.write_json(std::path::Path::new("results/bench_native_fft.json")).ok();
    println!("{}", suite.report());
}
