//! Bench: the whole PFFT pipeline on the native engine — basic (one
//! group) vs PFFT-LB vs PFFT-FPM vs PFFT-FPM-PAD. The real-machine
//! analogue of Figures 15-24 (small N; the paper-scale campaign lives in
//! the virtual testbed, `hclfft figures`).

use hclfft::coordinator::engine::NativeEngine;
use hclfft::coordinator::group::GroupConfig;
use hclfft::coordinator::pad::PadCost;
use hclfft::coordinator::pfft::{pfft_fpm, pfft_fpm_pad, pfft_lb};
use hclfft::coordinator::PlannedTransform;
use hclfft::dft::SignalMatrix;
use hclfft::profiler::build_plane;
use hclfft::stats::harness::{fft2d_flops, BenchSuite};

fn main() {
    let mut suite = BenchSuite::from_env("pfft_end_to_end");
    for &n in &[256usize, 512, 1024] {
        let cfg = GroupConfig::new(2, 1);
        let xs: Vec<usize> = (1..=4).map(|k| k * n / 4).collect();
        let fpms = build_plane(&NativeEngine, cfg, xs, n, 10_000);
        // plan once through the shared seam (what the service memoizes)
        let plan = PlannedTransform::from_fpms(&fpms, n, 0.05, Some(PadCost::PaperRatio)).unwrap();
        let flops = fft2d_flops(n);

        let mut m = SignalMatrix::random(n, n, 1);
        suite.bench_flops(&format!("basic_1x2_n{n}"), flops, || {
            pfft_lb(&NativeEngine, &mut m.clone(), GroupConfig::new(1, 2), 64).unwrap();
        });
        suite.bench_flops(&format!("pfft_lb_n{n}"), flops, || {
            pfft_lb(&NativeEngine, &mut m.clone(), cfg, 64).unwrap();
        });
        suite.bench_flops(&format!("pfft_fpm_n{n}"), flops, || {
            pfft_fpm(&NativeEngine, &mut m.clone(), &plan.d, cfg.t, 64).unwrap();
        });
        suite.bench_flops(&format!("pfft_fpm_pad_n{n}"), flops, || {
            pfft_fpm_pad(&NativeEngine, &mut m.clone(), &plan.d, &plan.pads, cfg.t, 64).unwrap();
        });
        let _ = &mut m;
    }
    suite.write_json(std::path::Path::new("results/bench_pfft_end_to_end.json")).ok();
    println!("{}", suite.report());
}
