//! Bench: the serving layer vs the one-shot driver loop — what
//! batching + wisdom reuse buy on repeated same-size traffic, plus the
//! cold-vs-warm planning gap the wisdom store closes.

use hclfft::coordinator::engine::{EngineId, NativeEngine};
use hclfft::dft::SignalMatrix;
use hclfft::service::wisdom::PlanningConfig;
use hclfft::service::{Dft2dRequest, Dft2dService, ServiceBuilder, ServiceConfig};
use hclfft::stats::harness::{fft2d_flops, BenchSuite};

fn service(max_batch: usize) -> Dft2dService {
    let cfg = ServiceConfig {
        workers: 2,
        max_batch,
        planning: PlanningConfig {
            groups: 2,
            threads_per_group: 1,
            rep_scale: 10_000,
            profile_budget_s: 0.5,
            ..PlanningConfig::default()
        },
        ..ServiceConfig::default()
    };
    ServiceBuilder::new(cfg).native().build()
}

fn drive(svc: &Dft2dService, mats: &[SignalMatrix]) {
    let handles: Vec<_> = mats
        .iter()
        .map(|m| svc.submit(Dft2dRequest::forward("native", m.clone())).unwrap())
        .collect();
    for h in handles {
        h.wait().unwrap();
    }
}

fn main() {
    let mut suite = BenchSuite::from_env("service");
    let n = 256usize;
    let burst = 8usize;
    let mats: Vec<SignalMatrix> =
        (0..burst as u64).map(|s| SignalMatrix::random(n, n, s)).collect();
    let flops = burst as f64 * fft2d_flops(n);

    // reference: one-shot planned driver, sequential requests
    {
        let rec = hclfft::service::wisdom::WisdomRecord::from_measurement(
            EngineId::Native,
            &NativeEngine,
            n,
            &PlanningConfig {
                groups: 2,
                threads_per_group: 1,
                rep_scale: 10_000,
                profile_budget_s: 0.5,
                ..PlanningConfig::default()
            },
        );
        suite.bench_flops(&format!("single_shot_{burst}x{n}"), flops, || {
            for m in &mats {
                let mut work = m.clone();
                rec.plan.execute(&NativeEngine, &mut work, rec.t, 64).unwrap();
                std::hint::black_box(&work);
            }
        });
    }

    // warm service, batching enabled: the burst coalesces per dispatch
    {
        let svc = service(burst);
        drive(&svc, &mats[..1]); // warm the wisdom + plan cache
        suite.bench_flops(&format!("service_batched_{burst}x{n}"), flops, || {
            drive(&svc, &mats);
        });
        svc.shutdown();
    }

    // warm service, batching disabled: per-request dispatch overhead
    {
        let svc = service(1);
        drive(&svc, &mats[..1]);
        suite.bench_flops(&format!("service_unbatched_{burst}x{n}"), flops, || {
            drive(&svc, &mats);
        });
        svc.shutdown();
    }

    // cold planning cost: what the wisdom store amortizes away. One plan
    // per iteration (fresh service), measured at a small N to keep the
    // suite quick.
    {
        let n_cold = 64usize;
        suite.bench(&format!("cold_plan_n{n_cold}"), || {
            let svc = service(8);
            let m = SignalMatrix::random(n_cold, n_cold, 1);
            drive(&svc, std::slice::from_ref(&m));
            svc.shutdown();
        });
    }

    suite
        .write_json(std::path::Path::new("results/bench_service.json"))
        .ok();
    println!("{}", suite.report());
}
