//! Bench: blocked in-place transpose, block-size sweep — the paper's
//! Appendix A (block_size = 64) ablation.

use hclfft::dft::transpose::transpose_in_place;
use hclfft::dft::SignalMatrix;
use hclfft::stats::harness::BenchSuite;

fn main() {
    let mut suite = BenchSuite::from_env("transpose");
    for &n in &[256usize, 1024, 2048] {
        for &block in &[8usize, 16, 32, 64, 128, 256] {
            let mut m = SignalMatrix::random(n, n, 7);
            suite.bench(&format!("n{n}_block{block}"), || {
                transpose_in_place(&mut m, block);
            });
        }
    }
    suite.write_json(std::path::Path::new("results/bench_transpose.json")).ok();
    println!("{}", suite.report());
}
