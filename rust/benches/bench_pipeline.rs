//! Bench: fused tile pipeline vs barrier four-step at the paper sizes.
//!
//! The fused pipeline runs the column FFTs directly on row-major
//! storage (per-tile transpose into per-thread scratch) — both
//! whole-matrix transpose passes disappear, so the matrix is touched
//! twice per 2D transform instead of four times. This harness pins the
//! two modes against each other at N ∈ {384, 640, 1152}, *asserts
//! bit-exactness first* (the CI smoke relies on that gate), prints a
//! per-size speedup line, and writes the `BENCH_pipeline.json`
//! trajectory at the repo root (next to `BENCH_serve.json`).

use std::path::Path;

use hclfft::coordinator::engine::NativeEngine;
use hclfft::coordinator::partition::balanced;
use hclfft::coordinator::pfft::pfft_fpm_with_mode;
use hclfft::dft::pipeline::PipelineMode;
use hclfft::dft::SignalMatrix;
use hclfft::stats::harness::{fft2d_flops, BenchSuite};

fn main() {
    let mut suite = BenchSuite::from_env("pipeline");
    let groups = 4usize;
    let threads_per_group = 2usize;
    println!(
        "pipeline A/B: fused (tile stage-DAG, strided column FFTs) vs \
         barrier (four-step with transpose passes); p={groups}, t={threads_per_group}"
    );

    for &n in &[384usize, 640, 1152] {
        let d = balanced(groups, n).d;
        let orig = SignalMatrix::random(n, n, n as u64);

        // bit-exactness gate before any timing
        {
            let mut fused = orig.clone();
            let mut barrier = orig.clone();
            pfft_fpm_with_mode(
                &NativeEngine,
                &mut fused,
                &d,
                threads_per_group,
                64,
                PipelineMode::Fused,
            )
            .unwrap();
            pfft_fpm_with_mode(
                &NativeEngine,
                &mut barrier,
                &d,
                threads_per_group,
                64,
                PipelineMode::Barrier,
            )
            .unwrap();
            assert_eq!(
                fused.max_abs_diff(&barrier),
                0.0,
                "N={n}: fused output differs from barrier"
            );
            println!("N={n}: fused output bit-exact vs barrier (max diff 0)");
        }

        // transform a fresh clone per rep (like bench_pfft_end_to_end):
        // repeated unscaled forward passes on one matrix would overflow
        // to inf within the rep budget; the clone cost is identical on
        // both sides of the A/B
        suite.bench_flops(&format!("fused_{n}"), fft2d_flops(n), || {
            let mut m = orig.clone();
            pfft_fpm_with_mode(
                &NativeEngine,
                &mut m,
                &d,
                threads_per_group,
                64,
                PipelineMode::Fused,
            )
            .unwrap();
        });
        suite.bench_flops(&format!("barrier_{n}"), fft2d_flops(n), || {
            let mut m = orig.clone();
            pfft_fpm_with_mode(
                &NativeEngine,
                &mut m,
                &d,
                threads_per_group,
                64,
                PipelineMode::Barrier,
            )
            .unwrap();
        });
    }

    println!("\n== fused vs barrier ==");
    for pair in suite.results.chunks(2) {
        if let [fused, barrier] = pair {
            println!(
                "{:>16} vs {:<16} speedup {:.2}x",
                fused.name,
                barrier.name,
                barrier.mean_s / fused.mean_s
            );
        }
    }
    suite.write_json(Path::new("BENCH_pipeline.json")).ok();
    println!("{}", suite.report());
}
