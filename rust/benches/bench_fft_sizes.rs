//! Bench: the row-kernel story at the paper's N = 128·k sizes.
//!
//! The paper benchmarks grid sizes that are mostly *not* powers of two
//! (384 = 2^7·3, 640 = 2^7·5, 1152 = 2^7·3^2, 3200 = 2^7·5^2). Five
//! arms per size:
//!
//! * `radix_…` — the vectorized mixed-radix kernel (reordered schedule,
//!   fused FFT2/4/8 tail codelet + AVX2 bodies, AVX2 radix-2/3/5 stages
//!   with `--features simd`, the FMA generation with `--features fma`):
//!   the executor's live per-row path,
//! * `radix_fma_…` — the same Vectorized plan, reported separately so
//!   the FMA-generation speedup has its own trajectory: on an FMA-off
//!   build/host it coincides with `radix_…` (the `scalar_vs_vector_fma_*`
//!   gate metrics then degenerate to the plain vector ratio and still
//!   pass), on the `--features fma` leg it is the contracted kernel,
//! * `multirow_…` — the stage-major multi-row tile driver
//!   (`fft_rows_radix_tiled`, 4 rows per register-resident stage pass),
//! * `scalar_…` — [`KernelVariant::Scalar`], the pre-codelet kernel
//!   shape kept as the reference arm, so the scalar-vs-vectorized
//!   speedup is measured honestly in one process,
//! * `bluestein_…` — chirp-z forced at the same length (the pre-PR-2
//!   path for these sizes).
//!
//! Plus, at the paper sizes only, the memory-bound column-phase A/B:
//! `colphase_scalar_…` (forced-scalar gather/scatter via
//! `set_col_tile_force_scalar`) vs `colphase_simd_…` (the in-register
//! 4×4/8×8 tile transpose), full n×n column pass on one thread, with
//! its own `colphase simd-vs-scalar geomean` PASS/FAIL line.
//!
//! Every mean carries a t-test confidence interval (≥ 5 reps even under
//! `HCLFFT_BENCH_FAST`), and the scalar-vs-vectorized speedups are
//! reported with the CIs propagated into the ratio — plus a geometric
//! mean over the paper sizes {384, 640, 1152} with a PASS/FAIL verdict
//! that CI greps (PASS ⇔ geomean ≥ 1.0; the perf gate separately locks
//! the committed baseline). JSON lands in
//! `results/bench_fft_sizes.json` for `perf-gate --fft`.

use hclfft::dft::bluestein::{fft_row_bluestein, BluesteinPlan};
use hclfft::dft::fft::Direction;
use hclfft::dft::radix::{
    fft_row_radix, fft_rows_radix_tiled, fma_active, kernel_generation, KernelVariant, RadixPlan,
};
use hclfft::dft::SignalMatrix;
use hclfft::stats::harness::{fft_flops, BenchResult, BenchSuite};

fn find<'a>(results: &'a [BenchResult], name: &str) -> &'a BenchResult {
    results.iter().find(|r| r.name == name).unwrap_or_else(|| panic!("missing bench {name}"))
}

/// Relative half-width of a ratio of two measured means (independent
/// errors added in quadrature).
fn ratio_rel_hw(num: &BenchResult, den: &BenchResult) -> f64 {
    let a = num.ci_half_width_s / num.mean_s;
    let b = den.ci_half_width_s / den.mean_s;
    (a * a + b * b).sqrt()
}

fn main() {
    let mut suite = BenchSuite::from_env("fft_sizes");
    let rows = 16usize;
    let sizes = [384usize, 640, 768, 1152, 3200];
    println!("row kernel generation: {}", kernel_generation());
    for &n in &sizes {
        let orig = SignalMatrix::random(rows, n, n as u64);
        let mut sr = vec![0.0; n];
        let mut si = vec![0.0; n];

        // vectorized mixed-radix: the executor's native path
        let vec_plan = RadixPlan::new(n);
        let mut m = orig.clone();
        suite.bench_flops(&format!("radix_{rows}x{n}"), fft_flops(rows, n), || {
            for r in 0..rows {
                let span = r * n..(r + 1) * n;
                fft_row_radix(
                    &mut m.re[span.clone()],
                    &mut m.im[span],
                    &mut sr,
                    &mut si,
                    &vec_plan,
                    Direction::Forward,
                );
            }
        });

        // the FMA-generation trajectory: the same Vectorized plan under
        // its own name, so the fma CI leg's contracted kernels get a
        // dedicated perf-gate metric (coincides with radix_… when the
        // FMA generation is inactive)
        let mut mf = orig.clone();
        suite.bench_flops(&format!("radix_fma_{rows}x{n}"), fft_flops(rows, n), || {
            for r in 0..rows {
                let span = r * n..(r + 1) * n;
                fft_row_radix(
                    &mut mf.re[span.clone()],
                    &mut mf.im[span],
                    &mut sr,
                    &mut si,
                    &vec_plan,
                    Direction::Forward,
                );
            }
        });

        // stage-major multi-row tiling: 4 rows per register-resident
        // stage pass (the executor's in-chunk driver, forced to width 4)
        let tile = 4usize;
        let mut tr = vec![0.0; tile * n];
        let mut ti = vec![0.0; tile * n];
        let mut mt = orig.clone();
        suite.bench_flops(&format!("multirow_{rows}x{n}"), fft_flops(rows, n), || {
            let mut r = 0;
            while r < rows {
                let w = tile.min(rows - r);
                let span = r * n..(r + w) * n;
                fft_rows_radix_tiled(
                    &mut mt.re[span.clone()],
                    &mut mt.im[span],
                    w,
                    &mut tr,
                    &mut ti,
                    &vec_plan,
                    Direction::Forward,
                );
                r += w;
            }
        });

        // the pre-PR scalar kernel shape: the honest reference arm
        let scalar_plan = RadixPlan::with_variant(n, KernelVariant::Scalar);
        let mut m1 = orig.clone();
        suite.bench_flops(&format!("scalar_{rows}x{n}"), fft_flops(rows, n), || {
            for r in 0..rows {
                let span = r * n..(r + 1) * n;
                fft_row_radix(
                    &mut m1.re[span.clone()],
                    &mut m1.im[span],
                    &mut sr,
                    &mut si,
                    &scalar_plan,
                    Direction::Forward,
                );
            }
        });

        // Bluestein forced at the same length (the old path for these N)
        let b_plan = BluesteinPlan::new(n);
        let ml = b_plan.scratch_len();
        let mut m2 = orig.clone();
        let mut br = vec![0.0; ml];
        let mut bi = vec![0.0; ml];
        let mut cr = vec![0.0; ml];
        let mut ci = vec![0.0; ml];
        suite.bench_flops(&format!("bluestein_{rows}x{n}"), fft_flops(rows, n), || {
            for r in 0..rows {
                let span = r * n..(r + 1) * n;
                fft_row_bluestein(
                    &mut m2.re[span.clone()],
                    &mut m2.im[span],
                    &b_plan,
                    Direction::Forward,
                    &mut br,
                    &mut bi,
                    &mut cr,
                    &mut ci,
                );
            }
        });
    }

    // the memory-bound column phase at the paper sizes: forced-scalar
    // gather/scatter vs the in-register SIMD tile transpose, full n×n
    // column pass on one thread (the A/B `perf-gate` locks as
    // `colphase_scalar_vs_simd_*`). On builds/hosts without the AVX2
    // transpose the two arms run identical code and the ratio sits at
    // ~1.0 — the gate's 0.9 baseline still passes.
    let paper = [384usize, 640, 1152];
    {
        use hclfft::dft::exec::ExecCtx;
        use hclfft::dft::pipeline::{fft_cols_fused, set_col_tile_force_scalar};
        let ctx = ExecCtx::new(1);
        for &n in &paper {
            let orig = SignalMatrix::random(n, n, n as u64 + 1);
            let mut mc = orig.clone();
            set_col_tile_force_scalar(true);
            suite.bench_flops(&format!("colphase_scalar_{n}"), fft_flops(n, n), || {
                fft_cols_fused(&ctx, &mut mc, Direction::Forward, 1);
            });
            let mut ms = orig.clone();
            set_col_tile_force_scalar(false);
            suite.bench_flops(&format!("colphase_simd_{n}"), fft_flops(n, n), || {
                fft_cols_fused(&ctx, &mut ms, Direction::Forward, 1);
            });
        }
    }

    // scalar vs vectorized at the paper sizes, CIs propagated into the
    // ratio; the geomean line is the CI smoke's grep target and the
    // perf gate's `scalar_vs_vector_geomean` metric mirrors it
    println!("\n== scalar vs vectorized row kernel ==");
    let mut log_sum = 0.0;
    let mut rel2_sum = 0.0;
    for &n in &paper {
        let s = find(&suite.results, &format!("scalar_{rows}x{n}"));
        let v = find(&suite.results, &format!("radix_{rows}x{n}"));
        let speedup = s.mean_s / v.mean_s;
        let rel = ratio_rel_hw(s, v);
        println!(
            "{:>16} vs {:<16} speedup {:.2}x ± {:.2}",
            s.name,
            v.name,
            speedup,
            speedup * rel
        );
        log_sum += speedup.ln();
        rel2_sum += rel * rel;
    }
    let geo = (log_sum / paper.len() as f64).exp();
    let geo_hw = geo * rel2_sum.sqrt() / paper.len() as f64;
    let verdict = if geo >= 1.0 { "PASS" } else { "FAIL" };
    println!("vector-vs-scalar geomean {geo:.2}x ± {geo_hw:.2} {verdict} (target >= 1.30x)");

    // the FMA-generation arm vs the scalar reference (Student-t CIs
    // propagated into the ratio, like every speedup line here)
    println!(
        "\n== scalar vs fma-generation row kernel (fma_active: {}) ==",
        fma_active()
    );
    for &n in &paper {
        let s = find(&suite.results, &format!("scalar_{rows}x{n}"));
        let f = find(&suite.results, &format!("radix_fma_{rows}x{n}"));
        let speedup = s.mean_s / f.mean_s;
        println!(
            "{:>16} vs {:<20} speedup {:.2}x ± {:.2}",
            s.name,
            f.name,
            speedup,
            speedup * ratio_rel_hw(s, f)
        );
    }

    // multi-row tiling vs the per-row driver (same kernels, stage-major
    // loop order): the twiddle-stream amortization the tile model prices
    println!("\n== per-row vs multi-row (4-row tile) driver ==");
    for &n in &paper {
        let p = find(&suite.results, &format!("radix_{rows}x{n}"));
        let t = find(&suite.results, &format!("multirow_{rows}x{n}"));
        let speedup = p.mean_s / t.mean_s;
        println!(
            "{:>16} vs {:<20} speedup {:.2}x ± {:.2}",
            p.name,
            t.name,
            speedup,
            speedup * ratio_rel_hw(p, t)
        );
    }

    // the PR-2 story, still pinned: mixed-radix vs the chirp-z fallback
    println!("\n== bluestein/radix speedup ==");
    for &n in &sizes {
        let v = find(&suite.results, &format!("radix_{rows}x{n}"));
        let b = find(&suite.results, &format!("bluestein_{rows}x{n}"));
        let speedup = b.mean_s / v.mean_s;
        println!(
            "{:>20} vs {:<24} speedup {:.2}x ± {:.2}",
            v.name,
            b.name,
            speedup,
            speedup * ratio_rel_hw(b, v)
        );
    }
    // the column-phase A/B: pure data movement, so the speedup is the
    // memory-access win of the tile transpose alone. The geomean line
    // is the SIMD CI legs' grep target; `colphase_geomean` in the perf
    // gate mirrors it against the committed baseline.
    println!("\n== column phase: scalar gather vs SIMD tile transpose ==");
    let mut c_log_sum = 0.0;
    let mut c_rel2_sum = 0.0;
    for &n in &paper {
        let s = find(&suite.results, &format!("colphase_scalar_{n}"));
        let v = find(&suite.results, &format!("colphase_simd_{n}"));
        let speedup = s.mean_s / v.mean_s;
        let rel = ratio_rel_hw(s, v);
        println!(
            "{:>20} vs {:<20} speedup {:.2}x ± {:.2}",
            s.name,
            v.name,
            speedup,
            speedup * rel
        );
        c_log_sum += speedup.ln();
        c_rel2_sum += rel * rel;
    }
    let c_geo = (c_log_sum / paper.len() as f64).exp();
    let c_geo_hw = c_geo * c_rel2_sum.sqrt() / paper.len() as f64;
    let c_verdict = if c_geo >= 1.0 { "PASS" } else { "FAIL" };
    println!(
        "colphase simd-vs-scalar geomean {c_geo:.2}x ± {c_geo_hw:.2} {c_verdict} (target >= 1.00x)"
    );

    suite.write_json(std::path::Path::new("results/bench_fft_sizes.json")).ok();
    println!("{}", suite.report());
}
