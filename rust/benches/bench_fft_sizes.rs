//! Bench: Bluestein vs mixed-radix at the paper's N = 128·k sizes.
//!
//! The paper benchmarks grid sizes that are mostly *not* powers of two
//! (384 = 2^7·3, 640 = 2^7·5, 1152 = 2^7·3^2, 3200 = 2^7·5^2). Before
//! the mixed-radix executor, those lengths all paid Bluestein's chirp-z
//! (pad to >= 2N pow2, three pow2 FFTs per row). This bench pins both
//! kernels at each size so the speedup lands in the bench JSON
//! trajectory (`results/bench_fft_sizes.json`).

use hclfft::dft::bluestein::{fft_row_bluestein, BluesteinPlan};
use hclfft::dft::fft::Direction;
use hclfft::dft::radix::{fft_row_radix, RadixPlan};
use hclfft::dft::SignalMatrix;
use hclfft::stats::harness::{fft_flops, BenchSuite};

fn main() {
    let mut suite = BenchSuite::from_env("fft_sizes");
    let rows = 16usize;
    for &n in &[384usize, 640, 768, 1152, 3200] {
        let orig = SignalMatrix::random(rows, n, n as u64);

        // mixed-radix: the executor's native path for 5-smooth lengths
        let radix_plan = RadixPlan::new(n);
        let mut m = orig.clone();
        let mut sr = vec![0.0; n];
        let mut si = vec![0.0; n];
        suite.bench_flops(&format!("radix_{rows}x{n}"), fft_flops(rows, n), || {
            for r in 0..rows {
                let span = r * n..(r + 1) * n;
                fft_row_radix(
                    &mut m.re[span.clone()],
                    &mut m.im[span],
                    &mut sr,
                    &mut si,
                    &radix_plan,
                    Direction::Forward,
                );
            }
        });

        // Bluestein forced at the same length (the old path for these N)
        let b_plan = BluesteinPlan::new(n);
        let ml = b_plan.scratch_len();
        let mut m2 = orig.clone();
        let mut br = vec![0.0; ml];
        let mut bi = vec![0.0; ml];
        let mut cr = vec![0.0; ml];
        let mut ci = vec![0.0; ml];
        suite.bench_flops(&format!("bluestein_{rows}x{n}"), fft_flops(rows, n), || {
            for r in 0..rows {
                let span = r * n..(r + 1) * n;
                fft_row_bluestein(
                    &mut m2.re[span.clone()],
                    &mut m2.im[span],
                    &b_plan,
                    Direction::Forward,
                    &mut br,
                    &mut bi,
                    &mut cr,
                    &mut ci,
                );
            }
        });
    }

    // report the per-size speedup explicitly
    println!("\n== bluestein/radix speedup ==");
    let res = &suite.results;
    for pair in res.chunks(2) {
        if let [radix, blue] = pair {
            println!(
                "{:>20} vs {:<24} speedup {:.2}x",
                radix.name,
                blue.name,
                blue.mean_s / radix.mean_s
            );
        }
    }
    suite.write_json(std::path::Path::new("results/bench_fft_sizes.json")).ok();
    println!("{}", suite.report());
}
