//! Bench: POPTA/HPOPTA planning cost on paper-scale grids — shows the
//! coordinator's Step 1 is negligible against the FFT it optimizes
//! (the paper's 96-hour cost is FPM *construction*, not partitioning).

use hclfft::coordinator::partition::{hpopta, popta};
use hclfft::simulator::fpm::SimTestbed;
use hclfft::simulator::Package;
use hclfft::stats::harness::BenchSuite;

fn main() {
    let mut suite = BenchSuite::from_env("partition");
    for &n in &[2_048usize, 12_800, 24_704, 44_800] {
        let tb = SimTestbed::paper_best(Package::Mkl);
        let curves = tb.plane_sections(n);
        suite.bench(&format!("hpopta_p2_n{n}"), || {
            hpopta(&curves, n - n % 128).unwrap();
        });
    }
    for &n in &[12_800usize, 24_704] {
        let tb = SimTestbed::paper_best(Package::Fftw3); // p = 4
        let curves = tb.plane_sections(n);
        suite.bench(&format!("hpopta_p4_n{n}"), || {
            hpopta(&curves, n - n % 128).unwrap();
        });
        let avg = hclfft::coordinator::partition::average_curve(&curves);
        suite.bench(&format!("popta_p4_n{n}"), || {
            popta(&avg, 4, n - n % 128).unwrap();
        });
    }
    suite.write_json(std::path::Path::new("results/bench_partition.json")).ok();
    println!("{}", suite.report());
}
