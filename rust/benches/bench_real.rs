//! Bench: real-input (r2c) vs complex (c2c) transforms at the paper
//! sizes.
//!
//! The r2c pair kernel runs one complex FFT per *pair* of real rows —
//! roughly half the row-phase flops and memory traffic of the c2c path
//! — and the packed column phase touches only the `N/2+1` stored
//! columns. This harness:
//!
//! 1. **gates correctness first**: the fused and barrier real pipelines
//!    must be bit-identical, and both must match the c2c oracle (2D-DFT
//!    of the real embedding, cropped to the stored columns) to tight
//!    tolerance — the CI smoke greps these lines;
//! 2. A/Bs the **row phase** (a forward+inverse pair per rep keeps
//!    magnitudes bounded without per-rep clones — both sides pay the
//!    same structure): `c2c_rows_N` vs `r2c_rows_N`;
//! 3. A/Bs the **whole 2D transform** the same way: `c2c2d_N` vs
//!    `rfft2d_N`;
//! 4. prints per-size speedup lines and writes the `BENCH_real.json`
//!    trajectory at the repo root — the input of the `perf-gate` CI job
//!    (see `rust/src/bin/perf_gate.rs` and `BENCH_baseline.json`).

use std::path::Path;

use hclfft::coordinator::engine::{NativeEngine, RowFftEngine};
use hclfft::dft::dft2d::dft2d_with_mode;
use hclfft::dft::exec::ExecCtx;
use hclfft::dft::fft::Direction;
use hclfft::dft::pipeline::PipelineMode;
use hclfft::dft::real::{
    c2r_rows, crop_to_packed, embed_real, half_cols, irfft2d_with_mode, r2c_rows, rfft2d_with_mode,
    rfft_cols_fused, RealMatrix,
};
use hclfft::dft::SignalMatrix;
use hclfft::stats::harness::{fft2d_flops, BenchSuite};

fn main() {
    let mut suite = BenchSuite::from_env("real");
    let threads = 8usize;
    let ctx = ExecCtx::global();
    println!(
        "real A/B: r2c pair kernel + packed column phase vs the c2c path; \
         {threads} thread budget, exec pool {} thread(s)",
        ctx.workers()
    );

    for &n in &[384usize, 640, 1152] {
        let nc = half_cols(n);
        let rm = RealMatrix::random(n, n, n as u64);

        // correctness gates before any timing (the CI smoke relies on
        // these lines)
        {
            let fused = rfft2d_with_mode(&rm, threads, PipelineMode::Fused);
            let barrier = rfft2d_with_mode(&rm, threads, PipelineMode::Barrier);
            assert_eq!(
                fused.max_abs_diff(&barrier),
                0.0,
                "N={n}: fused real output differs from barrier"
            );
            println!("N={n}: fused real output bit-exact vs barrier (max diff 0)");
            let mut emb = embed_real(&rm);
            dft2d_with_mode(&mut emb, Direction::Forward, threads, PipelineMode::Barrier);
            let want = crop_to_packed(&emb);
            let err = fused.max_abs_diff(&want) / want.norm().max(1.0);
            assert!(err < 1e-9, "N={n}: r2c vs c2c oracle rel err {err}");
            println!("N={n}: r2c output matches the c2c oracle (rel err {err:.3e})");
            let back = irfft2d_with_mode(&fused, threads, PipelineMode::Fused);
            let rerr = back.max_abs_diff(&rm) / rm.norm().max(1.0);
            assert!(rerr < 1e-9, "N={n}: c2r∘r2c roundtrip rel err {rerr}");
            println!("N={n}: c2r . r2c roundtrip exact (rel err {rerr:.3e})");
        }

        // one row *phase* of the 2D transform is half its flops; a rep
        // here is a forward+inverse pair, i.e. two phases' worth
        let row_pair_flops = fft2d_flops(n);

        // c2c row phase: n complex rows of length n, fwd + inv
        let mut c = SignalMatrix::random(n, n, n as u64 + 1);
        suite.bench_flops(&format!("c2c_rows_{n}"), row_pair_flops, || {
            NativeEngine
                .fft_rows(&mut c.re, &mut c.im, n, n, Direction::Forward, threads)
                .unwrap();
            NativeEngine
                .fft_rows(&mut c.re, &mut c.im, n, n, Direction::Inverse, threads)
                .unwrap();
        });

        // r2c row phase: n real rows through the pair kernel, + c2r back
        let mut dre = vec![0.0; n * nc];
        let mut dim = vec![0.0; n * nc];
        let mut back = vec![0.0; n * n];
        suite.bench_flops(&format!("r2c_rows_{n}"), row_pair_flops / 2.0, || {
            r2c_rows(ctx, &rm.data, &mut dre, &mut dim, n, n, n, threads);
            c2r_rows(ctx, &dre, &dim, &mut back, n, n, threads);
        });

        // whole 2D transform, fwd + inv per rep — both sides reuse
        // preallocated buffers so neither pays per-rep allocation the
        // other does not
        let mut m2 = SignalMatrix::random(n, n, n as u64 + 2);
        suite.bench_flops(&format!("c2c2d_{n}"), 2.0 * fft2d_flops(n), || {
            dft2d_with_mode(&mut m2, Direction::Forward, threads, PipelineMode::Fused);
            dft2d_with_mode(&mut m2, Direction::Inverse, threads, PipelineMode::Fused);
        });
        let mut packed = SignalMatrix::zeros(n, nc);
        let mut real_out = vec![0.0; n * n];
        suite.bench_flops(&format!("rfft2d_{n}"), fft2d_flops(n), || {
            r2c_rows(ctx, &rm.data, &mut packed.re, &mut packed.im, n, n, n, threads);
            rfft_cols_fused(ctx, &mut packed, Direction::Forward, threads);
            rfft_cols_fused(ctx, &mut packed, Direction::Inverse, threads);
            c2r_rows(ctx, &packed.re, &packed.im, &mut real_out, n, n, threads);
        });
    }

    println!("\n== r2c vs c2c ==");
    for pair in suite.results.chunks(2) {
        if let [c2c, r2c] = pair {
            println!(
                "{:>16} vs {:<16} speedup {:.2}x",
                r2c.name,
                c2c.name,
                c2c.mean_s / r2c.mean_s
            );
        }
    }
    suite.write_json(Path::new("BENCH_real.json")).ok();
    println!("{}", suite.report());
}
