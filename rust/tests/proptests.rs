//! Property tests over the coordinator invariants and the numeric
//! substrates, using the in-repo mini property-test harness
//! (`util::proptest` — the vendored crate set has no proptest; see
//! DESIGN.md §3).

use hclfft::coordinator::engine::{NativeEngine, RowFftEngine};
use hclfft::coordinator::fpm::Curve;
use hclfft::coordinator::group::GroupConfig;
use hclfft::coordinator::partition::{balanced, hpopta, predict_makespan};
use hclfft::coordinator::pfft::pfft_lb;
use hclfft::dft::fft::Direction;
use hclfft::dft::transpose::transpose_in_place;
use hclfft::dft::SignalMatrix;
use hclfft::util::proptest::{run, Config};
use hclfft::util::prng::Xoshiro256;

/// Random partition instance: p curves on a common step grid + target n.
#[derive(Clone, Debug)]
struct PartitionCase {
    curves: Vec<Curve>,
    n: usize,
}

fn gen_partition_case(rng: &mut Xoshiro256) -> PartitionCase {
    let p = rng.range_usize(1, 4);
    let m = rng.range_usize(2, 12);
    let step = [1usize, 2, 64, 128][rng.range_usize(0, 3)];
    let curves: Vec<Curve> = (0..p)
        .map(|_| {
            let xs: Vec<usize> = (1..=m).map(|k| k * step).collect();
            let speeds: Vec<f64> = (0..m).map(|_| 1.0 + rng.next_f64() * 999.0).collect();
            Curve::new(xs, speeds)
        })
        .collect();
    let max_total: usize = curves.iter().map(|c| *c.xs.last().unwrap()).sum();
    let n = step * rng.range_usize(0, max_total / step);
    PartitionCase { curves, n }
}

#[test]
fn prop_hpopta_distribution_sums_to_n() {
    run(
        "hpopta-sums-to-n",
        &Config::default(),
        gen_partition_case,
        |_| vec![],
        |case| match hpopta(&case.curves, case.n) {
            Ok(part) => {
                let sum: usize = part.d.iter().sum();
                if sum != case.n {
                    return Err(format!("sum {sum} != n {}", case.n));
                }
                if part.d.len() != case.curves.len() {
                    return Err("arity mismatch".to_string());
                }
                Ok(())
            }
            Err(_) => Ok(()), // infeasible is a legal outcome; optimality
                               // vs brute force is covered separately
        },
    );
}

#[test]
fn prop_hpopta_makespan_is_exactly_attained_max() {
    run(
        "hpopta-makespan-consistent",
        &Config::default(),
        gen_partition_case,
        |_| vec![],
        |case| {
            let Ok(part) = hpopta(&case.curves, case.n) else { return Ok(()) };
            let recomputed = predict_makespan(&case.curves, &part.d);
            if (recomputed - part.makespan).abs() > 1e-9 * (1.0 + part.makespan) {
                return Err(format!("makespan {} != recomputed {recomputed}", part.makespan));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_hpopta_beats_or_ties_balanced_on_its_grid() {
    run(
        "hpopta-beats-balanced",
        &Config::default(),
        gen_partition_case,
        |_| vec![],
        |case| {
            let Ok(part) = hpopta(&case.curves, case.n) else { return Ok(()) };
            // compare only when the balanced split lies on the grid
            let bal = balanced(case.curves.len(), case.n);
            let on_grid = bal
                .d
                .iter()
                .zip(&case.curves)
                .all(|(&di, c)| di == 0 || c.speed_at(di).is_some());
            if !on_grid {
                return Ok(());
            }
            let bal_makespan = predict_makespan(&case.curves, &bal.d);
            if part.makespan > bal_makespan + 1e-9 {
                return Err(format!("opt {} > balanced {bal_makespan}", part.makespan));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_fft_roundtrip_random_shapes() {
    run(
        "fft-roundtrip",
        &Config { cases: 24, ..Config::default() },
        |rng| {
            let rows = rng.range_usize(1, 6);
            let n = [2usize, 4, 8, 12, 24, 64, 100, 128][rng.range_usize(0, 7)];
            (rows, n, rng.next_u64())
        },
        |_| vec![],
        |&(rows, n, seed)| {
            let orig = SignalMatrix::random(rows, n, seed);
            let mut m = orig.clone();
            NativeEngine
                .fft_rows(&mut m.re, &mut m.im, rows, n, Direction::Forward, 1)
                .map_err(|e| e.to_string())?;
            NativeEngine
                .fft_rows(&mut m.re, &mut m.im, rows, n, Direction::Inverse, 1)
                .map_err(|e| e.to_string())?;
            let err = m.max_abs_diff(&orig);
            if err > 1e-8 {
                return Err(format!("roundtrip err {err} (rows {rows}, n {n})"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_transpose_involution_random_blocks() {
    run(
        "transpose-involution",
        &Config { cases: 32, ..Config::default() },
        |rng| {
            let n = rng.range_usize(1, 100);
            let block = rng.range_usize(1, 128);
            (n, block, rng.next_u64())
        },
        |_| vec![],
        |&(n, block, seed)| {
            let orig = SignalMatrix::random(n, n, seed);
            let mut m = orig.clone();
            transpose_in_place(&mut m, block);
            transpose_in_place(&mut m, block);
            if m != orig {
                return Err(format!("involution broken (n {n}, block {block})"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_pfft_lb_parseval_energy() {
    // whole-pipeline invariant: the 2D transform preserves energy up to
    // the N^2 normalization (Parseval), for any group configuration
    run(
        "pfft-parseval",
        &Config { cases: 12, ..Config::default() },
        |rng| {
            let n = [8usize, 16, 24, 32][rng.range_usize(0, 3)];
            let p = rng.range_usize(1, 4);
            (n, p, rng.next_u64())
        },
        |_| vec![],
        |&(n, p, seed)| {
            let orig = SignalMatrix::random(n, n, seed);
            let mut m = orig.clone();
            pfft_lb(&NativeEngine, &mut m, GroupConfig::new(p, 1), 16)
                .map_err(|e| e.to_string())?;
            let e_time: f64 = orig.re.iter().zip(&orig.im).map(|(r, i)| r * r + i * i).sum();
            let e_freq: f64 =
                m.re.iter().zip(&m.im).map(|(r, i)| r * r + i * i).sum::<f64>()
                    / (n * n) as f64;
            if (e_time - e_freq).abs() / e_time > 1e-9 {
                return Err(format!("Parseval violated: {e_time} vs {e_freq}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_simulator_speed_positive_and_deterministic() {
    use hclfft::simulator::packages::PackageModel;
    use hclfft::simulator::Package;
    let models = [
        PackageModel::new(Package::Fftw2),
        PackageModel::new(Package::Fftw3),
        PackageModel::new(Package::Mkl),
    ];
    run(
        "simulator-speed-sane",
        &Config { cases: 200, ..Config::default() },
        |rng| {
            let n = 128 + 64 * rng.range_usize(0, 990);
            let which = rng.range_usize(0, 2);
            (which, n)
        },
        |_| vec![],
        |&(which, n)| {
            let m = &models[which];
            let a = m.speed(n);
            let b = m.speed(n);
            if a <= 0.0 || !a.is_finite() {
                return Err(format!("bad speed {a} at n {n}"));
            }
            if a != b {
                return Err("nondeterministic".to_string());
            }
            let g = m.group_speed(n / 2 + 1, n, 1, 2, 18);
            if g <= 0.0 || !g.is_finite() {
                return Err(format!("bad group speed {g}"));
            }
            Ok(())
        },
    );
}

/// Random JSON tree generator for the round-trip properties.
fn gen_json(rng: &mut Xoshiro256, depth: usize) -> hclfft::util::json::Json {
    use hclfft::util::json::Json;
    let leaf_only = depth == 0;
    // range_usize is inclusive: leaves are arms 0-3, containers 4-5
    match rng.range_usize(0, if leaf_only { 3 } else { 5 }) {
        0 => Json::Null,
        1 => Json::Bool(rng.next_f64() < 0.5),
        2 => Json::Int(rng.next_u64() as i64 / 1024),
        3 => {
            let s: String = (0..rng.range_usize(0, 8))
                .map(|_| {
                    // mix of plain chars, escapes and non-ascii
                    ['a', '"', '\\', '\n', '\t', 'é', '\u{1}', 'z'][rng.range_usize(0, 7)]
                })
                .collect();
            Json::Str(s)
        }
        4 => Json::Arr((0..rng.range_usize(0, 4)).map(|_| gen_json(rng, depth - 1)).collect()),
        _ => {
            let mut o = hclfft::util::json::Json::obj();
            for k in 0..rng.range_usize(0, 4) {
                o = o.set(&format!("k{k}"), gen_json(rng, depth - 1));
            }
            o
        }
    }
}

#[test]
fn prop_json_emit_parse_roundtrip() {
    use hclfft::util::json::Json;
    run(
        "json-emit-parse-roundtrip",
        &Config { cases: 200, ..Config::default() },
        |rng| gen_json(rng, 3),
        |_| vec![],
        |j| {
            for text in [j.to_string(), j.to_pretty()] {
                let back = Json::parse(&text).map_err(|e| format!("parse failed: {e} on {text}"))?;
                if &back != j {
                    return Err(format!("roundtrip mismatch: {text}"));
                }
            }
            Ok(())
        },
    );
}

/// Random `SpeedFunction` with gaps; non-integral speeds so the
/// Int/Num distinction cannot alias.
fn gen_speed_function(rng: &mut Xoshiro256) -> hclfft::coordinator::fpm::SpeedFunction {
    let nx = rng.range_usize(1, 6);
    let ny = rng.range_usize(1, 6);
    let xs: Vec<usize> = (1..=nx).map(|k| k * (1 + rng.range_usize(0, 3))).collect();
    let xs: Vec<usize> = {
        // force strictly ascending
        let mut acc = 0;
        xs.iter()
            .map(|&v| {
                acc += v;
                acc
            })
            .collect()
    };
    let ys: Vec<usize> = (1..=ny).map(|k| k * 128).collect();
    let mut f = hclfft::coordinator::fpm::SpeedFunction::new("prop", xs.clone(), ys.clone());
    for &x in &xs {
        for &y in &ys {
            if rng.next_f64() < 0.7 {
                f.set(x, y, 1.0 + rng.next_f64() * 9999.0);
            }
        }
    }
    f
}

#[test]
fn prop_speed_function_json_roundtrip() {
    use hclfft::util::json::Json;
    run(
        "speed-function-json-roundtrip",
        &Config { cases: 100, ..Config::default() },
        gen_speed_function,
        |_| vec![],
        |f| {
            let text = f.to_json().to_string();
            let j = Json::parse(&text).map_err(|e| format!("parse: {e}"))?;
            let g = hclfft::coordinator::fpm::SpeedFunction::from_json(&j)
                .map_err(|e| format!("from_json: {e}"))?;
            if g.xs != f.xs || g.ys != f.ys {
                return Err("grid mismatch".to_string());
            }
            for &x in &f.xs {
                for &y in &f.ys {
                    if g.get(x, y) != f.get(x, y) {
                        return Err(format!("speed mismatch at ({x},{y})"));
                    }
                }
            }
            Ok(())
        },
    );
}

/// A stationary observation stream: base mean with bounded (±8%)
/// multiplicative noise, plus a shuffled copy of the same samples.
fn gen_stationary_stream(rng: &mut Xoshiro256) -> (Vec<f64>, Vec<f64>) {
    let mean = 1e-4 + rng.next_f64() * 0.1;
    let count = 12 + rng.range_usize(0, 52);
    let samples: Vec<f64> =
        (0..count).map(|_| mean * (1.0 + 0.16 * (rng.next_f64() - 0.5))).collect();
    // Fisher-Yates shuffle for the permuted order
    let mut shuffled = samples.clone();
    for i in (1..shuffled.len()).rev() {
        let j = rng.range_usize(0, i);
        shuffled.swap(i, j);
    }
    (samples, shuffled)
}

#[test]
fn prop_online_observe_is_order_invariant() {
    use hclfft::model::{DriftPolicy, OnlineModel, PerfModel};
    run(
        "online-observe-order-invariant",
        &Config { cases: 60, ..Config::default() },
        gen_stationary_stream,
        |_| vec![],
        |(samples, shuffled)| {
            let mut a = OnlineModel::new("a", DriftPolicy::default());
            let mut b = OnlineModel::new("b", DriftPolicy::default());
            for &t in samples {
                a.observe(64, 128, t);
            }
            for &t in shuffled {
                b.observe(64, 128, t);
            }
            let (ta, tb) = (
                a.refined_time(64, 128).ok_or("no estimate a")?,
                b.refined_time(64, 128).ok_or("no estimate b")?,
            );
            if (ta - tb).abs() > 1e-9 * ta.abs().max(1e-12) {
                return Err(format!("estimate order-dependent: {ta} vs {tb}"));
            }
            // the set-based CI is order-invariant too
            let (ca, cb) = (
                a.point(64, 128).unwrap().ci_rel(0.95),
                b.point(64, 128).unwrap().ci_rel(0.95),
            );
            if (ca - cb).abs() > 1e-6 * ca.abs().max(1e-12) {
                return Err(format!("ci order-dependent: {ca} vs {cb}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_online_reported_ci_never_widens() {
    use hclfft::model::{DriftPolicy, OnlineModel, PerfModel};
    run(
        "online-ci-monotone",
        &Config { cases: 60, ..Config::default() },
        gen_stationary_stream,
        |_| vec![],
        |(samples, _)| {
            let mut m = OnlineModel::new("m", DriftPolicy::default());
            let mut last = f64::INFINITY;
            for &t in samples {
                m.observe(96, 256, t);
                let ci = m.point(96, 256).unwrap().reported_ci_rel();
                if ci > last * (1.0 + 1e-12) {
                    return Err(format!("reported CI widened: {ci} > {last}"));
                }
                last = ci;
            }
            Ok(())
        },
    );
}

#[test]
fn prop_online_drift_no_false_positives_on_stationary_stream() {
    use hclfft::model::{DriftPolicy, OnlineModel, PerfModel};
    run(
        "online-drift-no-false-positives",
        &Config { cases: 80, ..Config::default() },
        gen_stationary_stream,
        |_| vec![],
        |(samples, shuffled)| {
            let mut m = OnlineModel::new("m", DriftPolicy::default());
            for &t in samples.iter().chain(shuffled) {
                if let Some(e) = m.observe(32, 512, t) {
                    return Err(format!(
                        "false drift on stationary stream: variation {:.1}%",
                        e.variation_pct
                    ));
                }
            }
            if !m.drift_events().is_empty() {
                return Err("drift log non-empty on stationary stream".to_string());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_colphase_simd_matches_scalar_gather_bitwise() {
    use hclfft::dft::exec::ExecCtx;
    use hclfft::dft::pipeline::{fft_cols_fused_rect, set_col_tile_force_scalar};
    run(
        "colphase-simd-vs-scalar-bitwise",
        &Config { cases: 24, ..Config::default() },
        |rng| {
            // 5-smooth column lengths, including non-multiple-of-4 ones
            // (vector-rim remainders in the 4×4 tile transpose)
            let rows = [8usize, 12, 20, 30, 40, 45, 64, 90, 100][rng.range_usize(0, 8)];
            // width: square, packed-real (n/2+1 — always odd here), or
            // arbitrary rectangular
            let cols = match rng.range_usize(0, 2) {
                0 => rows,
                1 => rows / 2 + 1,
                _ => rng.range_usize(1, 70),
            };
            let threads = 1 + rng.range_usize(0, 3);
            let dir =
                if rng.next_f64() < 0.5 { Direction::Forward } else { Direction::Inverse };
            (rows, cols, threads, dir, rng.next_u64())
        },
        |_| vec![],
        |&(rows, cols, threads, dir, seed)| {
            let ctx = ExecCtx::new(threads);
            let base = SignalMatrix::random(rows, cols, seed);
            let mut vector = base.clone();
            let mut scalar = base.clone();
            // The toggle is process-global, so both passes run inside
            // this one case and the forcing is always restored. Other
            // tests observing a transient flip only vary in speed: the
            // SIMD gather/scatter is bit-identical by contract — the
            // very property under test.
            set_col_tile_force_scalar(false);
            fft_cols_fused_rect(
                &ctx,
                &mut vector.re,
                &mut vector.im,
                rows,
                cols,
                rows,
                dir,
                threads,
            );
            set_col_tile_force_scalar(true);
            fft_cols_fused_rect(
                &ctx,
                &mut scalar.re,
                &mut scalar.im,
                rows,
                cols,
                rows,
                dir,
                threads,
            );
            set_col_tile_force_scalar(false);
            if vector != scalar {
                return Err(format!(
                    "simd/scalar column phase mismatch {} (rows {rows}, cols {cols}, threads {threads})",
                    vector.max_abs_diff(&scalar)
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_wisdom_record_json_roundtrip() {
    use hclfft::coordinator::engine::EngineId;
    use hclfft::coordinator::pad::PadDecision;
    use hclfft::coordinator::partition::Algorithm;
    use hclfft::coordinator::plan::PlannedTransform;
    use hclfft::dft::real::TransformKind;
    use hclfft::service::wisdom::WisdomRecord;
    use hclfft::util::json::Json;
    run(
        "wisdom-record-json-roundtrip",
        &Config { cases: 100, ..Config::default() },
        |rng| {
            let p = rng.range_usize(1, 4);
            let n_units: usize = (0..p).map(|_| rng.range_usize(0, 50)).sum::<usize>() + 1;
            let n = n_units * 8;
            // random distribution summing to n
            let mut d = vec![0usize; p];
            let mut left = n;
            for item in d.iter_mut().take(p - 1) {
                let take = rng.range_usize(0, left);
                *item = take;
                left -= take;
            }
            d[p - 1] = left;
            let pads: Vec<PadDecision> = d
                .iter()
                .map(|_| PadDecision {
                    n_padded: n + 8 * rng.range_usize(0, 4),
                    t_unpadded: rng.next_f64() * 10.0,
                    t_padded: rng.next_f64() * 10.0,
                })
                .collect();
            WisdomRecord {
                engine: EngineId::Native,
                n,
                p,
                t: 1 + rng.range_usize(0, 8),
                eps: rng.next_f64() * 0.2,
                plan: PlannedTransform {
                    n,
                    d,
                    pads,
                    algorithm: [Algorithm::Popta, Algorithm::Hpopta, Algorithm::Balanced]
                        [rng.range_usize(0, 2)],
                    makespan: if rng.next_f64() < 0.2 { f64::NAN } else { rng.next_f64() * 100.0 },
                    kind: [TransformKind::C2c, TransformKind::R2c][rng.range_usize(0, 1)],
                },
                predicted_cost_s: rng.next_f64() * 10.0,
                factors: hclfft::dft::radix::factorize_235(n).unwrap_or_default(),
                fpms: if rng.next_f64() < 0.5 { vec![gen_speed_function(rng)] } else { vec![] },
                kernel_gen: hclfft::dft::radix::kernel_generation().to_string(),
            }
        },
        |_| vec![],
        |rec| {
            let text = rec.to_json().to_pretty();
            let j = Json::parse(&text).map_err(|e| format!("parse: {e}"))?;
            let back = WisdomRecord::from_json(&j).map_err(|e| format!("from_json: {e}"))?;
            // NaN makespan breaks PartialEq; compare piecewise
            if back.engine != rec.engine
                || back.n != rec.n
                || back.p != rec.p
                || back.t != rec.t
                || back.eps != rec.eps
                || back.plan.d != rec.plan.d
                || back.plan.pads != rec.plan.pads
                || back.plan.algorithm != rec.plan.algorithm
                || back.plan.kind != rec.plan.kind
                || back.predicted_cost_s != rec.predicted_cost_s
                || back.factors != rec.factors
                || back.fpms != rec.fpms
                || back.kernel_gen != rec.kernel_gen
            {
                return Err("field mismatch after roundtrip".to_string());
            }
            let ms_ok = (back.plan.makespan.is_nan() && rec.plan.makespan.is_nan())
                || back.plan.makespan == rec.plan.makespan;
            if !ms_ok {
                return Err("makespan mismatch".to_string());
            }
            Ok(())
        },
    );
}

/// The typed engine identity (PR 10): canonical string and numeric wire
/// encodings are lossless inverses over every id, `Display` agrees with
/// `as_str`, and unknown spellings are rejected (never silently mapped).
#[test]
fn prop_engine_id_parse_display_wire_roundtrip() {
    use hclfft::coordinator::engine::EngineId;
    run(
        "engine-id-roundtrip",
        &Config { cases: 100, ..Config::default() },
        |rng| EngineId::ALL[rng.range_usize(0, EngineId::ALL.len() - 1)],
        |_| vec![],
        |&id| {
            let s = id.to_string();
            if s != id.as_str() {
                return Err(format!("Display `{s}` != as_str `{}`", id.as_str()));
            }
            if EngineId::parse(&s) != Some(id) {
                return Err(format!("parse({s}) lost identity"));
            }
            if s.parse::<EngineId>() != Ok(id) {
                return Err(format!("FromStr({s}) lost identity"));
            }
            if EngineId::from_wire_code(id.wire_code()) != Some(id) {
                return Err(format!("wire code {} not invertible", id.wire_code()));
            }
            Ok(())
        },
    );
}

#[test]
fn engine_id_unknown_strings_rejected_and_wire_codes_unique() {
    use hclfft::coordinator::engine::EngineId;
    for bad in ["", "cufft", "sim-", "sim-cufft", "NATIVE", "native "] {
        assert!(EngineId::parse(bad).is_none(), "`{bad}` must not parse");
        assert!(bad.parse::<EngineId>().is_err(), "`{bad}` must not FromStr");
    }
    let mut codes: Vec<u8> = EngineId::ALL.iter().map(|e| e.wire_code()).collect();
    codes.sort_unstable();
    codes.dedup();
    assert_eq!(codes.len(), EngineId::ALL.len(), "wire codes must be unique");
    assert!(EngineId::from_wire_code(EngineId::ALL.len() as u8).is_none());
}
