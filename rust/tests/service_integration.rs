//! Integration: the 2D-DFT serving subsystem end to end — bit-exactness
//! against the single-shot coordinator drivers and the `dft2d` oracle,
//! wisdom persistence across restarts, concurrent hammering, and the
//! deterministic virtual-time scheduling path at paper-scale sizes.

use std::sync::Mutex;

use hclfft::coordinator::engine::{EngineId, NativeEngine};
use hclfft::dft::fft::Direction;
use hclfft::dft::real::TransformKind;
use hclfft::dft::SignalMatrix;
use hclfft::service::wisdom::{PlanningConfig, WisdomRecord, WisdomStore};
use hclfft::service::{Dft2dRequest, ResponseHandle, ServiceBuilder, ServiceConfig, ServiceError};
use hclfft::simulator::Package;

fn quick_cfg() -> ServiceConfig {
    ServiceConfig {
        workers: 2,
        max_batch: 8,
        planning: PlanningConfig {
            groups: 2,
            threads_per_group: 1,
            rep_scale: 10_000,
            ..PlanningConfig::default()
        },
        ..ServiceConfig::default()
    }
}

fn tmp_path(tag: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("hclfft_svc_{tag}_{}/w.json", std::process::id()))
}

/// Acceptance: service responses are bit-exact against the single-shot
/// `coordinator::pfft` path executing the very same memoized plan.
#[test]
fn responses_bit_exact_vs_single_shot_pfft() {
    let svc = ServiceBuilder::new(quick_cfg()).native().build();
    for n in [16usize, 32, 64] {
        let orig = SignalMatrix::random(n, n, n as u64);
        let resp = svc
            .submit(Dft2dRequest::forward("native", orig.clone()))
            .unwrap()
            .wait()
            .unwrap();
        let plan = svc.planned("native", n).expect("plan memoized after first request");
        assert_eq!(plan.d, resp.report.d);
        let mut single = orig.clone();
        plan.execute(&NativeEngine, &mut single, 1, 64).unwrap();
        assert_eq!(
            resp.matrix.max_abs_diff(&single),
            0.0,
            "n={n}: service output must be bit-exact vs single-shot pfft"
        );
    }
    svc.shutdown();
}

/// Real-input path through the service: r2c responses are bit-exact
/// against the single-shot planned real executor running the same
/// memoized kind-keyed plan, and the kind-keyed wisdom survives a
/// restart (warm service re-plans nothing).
#[test]
fn real_responses_bit_exact_and_wisdom_kind_keyed() {
    use hclfft::coordinator::real::rfft_planned_with_mode;
    use hclfft::dft::pipeline::PipelineMode;
    use hclfft::dft::real::RealMatrix;

    let path = tmp_path("realkind");
    let n = 32usize;
    let (resp_matrix, plan) = {
        let svc = ServiceBuilder::new(quick_cfg()).native().build();
        let orig = SignalMatrix::random_real(n, n, 77);
        let resp = svc
            .submit(Dft2dRequest::real_forward("native", orig.clone()))
            .unwrap()
            .wait()
            .unwrap();
        let plan = svc
            .planned_kind("native", n, TransformKind::R2c)
            .expect("kind-keyed plan memoized");
        assert_eq!(plan.kind, TransformKind::R2c);
        // single-shot oracle: same plan, same executor seam
        let rm = RealMatrix { rows: n, cols: n, data: orig.re.clone() };
        let single =
            rfft_planned_with_mode(&NativeEngine, &plan, &rm, 1, PipelineMode::Fused).unwrap();
        assert_eq!(
            resp.matrix.max_abs_diff(&single),
            0.0,
            "service r2c output must be bit-exact vs the single-shot planned real executor"
        );
        svc.save_wisdom(&path).unwrap();
        svc.shutdown();
        (resp.matrix, plan)
    };
    // restart: the kind-keyed record is warm — an identical request
    // pays zero planning events and produces identical bits
    let svc = ServiceBuilder::new(quick_cfg())
        .native()
        .load_wisdom(&path)
        .unwrap()
        .build();
    let orig = SignalMatrix::random_real(n, n, 77);
    let resp = svc.submit(Dft2dRequest::real_forward("native", orig)).unwrap().wait().unwrap();
    assert_eq!(resp.matrix.max_abs_diff(&resp_matrix), 0.0, "restart changed the bits");
    assert!(!resp.report.planned_cold, "kind-keyed wisdom must be warm after restart");
    assert_eq!(svc.stats().planning_events, 0);
    assert_eq!(
        svc.planned_kind("native", n, TransformKind::R2c).unwrap().d,
        plan.d,
        "restored kind-keyed partition must match"
    );
    svc.shutdown();
}

/// A committed version-2 wisdom file (no `kind` fields) upgrades
/// cleanly: every record loads as c2c, and re-saving writes the
/// current version-5 artifact. The CI `wisdom` smoke drives the same
/// upgrade through the CLI.
#[test]
fn v2_wisdom_file_upgrades_to_current_version() {
    let store =
        WisdomStore::load(std::path::Path::new("rust/tests/fixtures/wisdom_v2.json")).unwrap();
    assert_eq!(store.len(), 1);
    let rec = store.get(EngineId::Native, 16, 2).expect("v2 record loads under the c2c key");
    assert_eq!(rec.kind(), TransformKind::C2c);
    assert_eq!(rec.plan.d, vec![10, 6]);
    let j = store.to_json();
    assert_eq!(j.get("version").and_then(hclfft::util::json::Json::as_usize), Some(5));
}

/// A committed version-3 wisdom file (kind-keyed records, no `tiles`
/// array) upgrades cleanly: records keep their kinds, the store starts
/// with no measured tile widths (the executor falls back to the
/// modeled widths), and the save → load roundtrip of the upgraded
/// store preserves both the records and any tiles recorded after the
/// upgrade.
#[test]
fn v3_wisdom_file_upgrades_to_current_and_roundtrips() {
    let mut store =
        WisdomStore::load(std::path::Path::new("rust/tests/fixtures/wisdom_v3.json")).unwrap();
    assert_eq!(store.len(), 1);
    let rec = store
        .get_kind(EngineId::Native, 16, 2, TransformKind::R2c)
        .expect("v3 kind-keyed record loads under its own plane");
    assert_eq!(rec.kind(), TransformKind::R2c);
    assert_eq!(rec.plan.d, vec![12, 4]);
    assert!(store.tiles().next().is_none(), "v3 files carry no measured tile widths");
    assert_eq!(store.tile_width(16, TransformKind::R2c), None);
    // re-saving stamps the current version; a width recorded
    // post-upgrade survives the save → load roundtrip with the record
    // intact
    store.set_tile(16, TransformKind::R2c, 4);
    let path = tmp_path("v3upgrade");
    store.save(&path).unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    assert!(text.contains("\"version\": 5"), "upgraded artifact must be stamped v5");
    let back = WisdomStore::load(&path).unwrap();
    assert_eq!(back.tile_width(16, TransformKind::R2c), Some(4));
    // c2r shares the r2c plane for tiles exactly like plan records
    assert_eq!(back.tile_width(16, TransformKind::C2r), Some(4));
    assert_eq!(
        back.get_kind(EngineId::Native, 16, 2, TransformKind::R2c).unwrap().plan.d,
        vec![12, 4]
    );
}

/// Satellite: 8 client threads hammer the service; every response must
/// round-trip bit-exactly against the serial `dft::dft2d` oracle.
#[test]
fn eight_thread_hammer_bit_exact_vs_dft2d_oracle() {
    let svc = ServiceBuilder::new(quick_cfg()).native().build();
    let mismatches: Mutex<Vec<String>> = Mutex::new(Vec::new());
    std::thread::scope(|scope| {
        for c in 0..8u64 {
            let svc = &svc;
            let mismatches = &mismatches;
            scope.spawn(move || {
                for i in 0..4u64 {
                    let n = if (c + i) % 2 == 0 { 32 } else { 64 };
                    let orig = SignalMatrix::random(n, n, c * 100 + i);
                    let resp = svc
                        .submit(Dft2dRequest::forward("native", orig.clone()))
                        .unwrap()
                        .wait()
                        .unwrap();
                    let mut want = orig;
                    hclfft::dft::dft2d::dft2d(&mut want, Direction::Forward, 1);
                    let diff = resp.matrix.max_abs_diff(&want);
                    if diff != 0.0 {
                        mismatches
                            .lock()
                            .unwrap()
                            .push(format!("client {c} req {i} n={n}: diff {diff:e}"));
                    }
                }
            });
        }
    });
    let bad = mismatches.into_inner().unwrap();
    assert!(bad.is_empty(), "non-bit-exact responses: {bad:?}");
    let stats = svc.stats();
    assert_eq!(stats.completed, 32);
    assert_eq!(stats.failed, 0);
    // two sizes => exactly two cold plans no matter how the 8 threads race
    assert_eq!(stats.planning_events, 2);
    svc.shutdown();
}

/// Acceptance: a second service instance fed the persisted wisdom file
/// replans nothing (planning_events == 0 < cold run's count).
#[test]
fn persisted_wisdom_eliminates_planning() {
    let path = tmp_path("persist");
    let n = 48;

    let cold = ServiceBuilder::new(quick_cfg()).native().build();
    let orig = SignalMatrix::random(n, n, 7);
    let cold_resp = cold
        .submit(Dft2dRequest::forward("native", orig.clone()))
        .unwrap()
        .wait()
        .unwrap();
    let cold_stats = cold.stats();
    assert_eq!(cold_stats.planning_events, 1, "cold run must pay one plan");
    assert!(cold_resp.report.planned_cold);
    cold.save_wisdom(&path).unwrap();
    cold.shutdown();

    let warm = ServiceBuilder::new(quick_cfg())
        .native()
        .load_wisdom(&path)
        .unwrap()
        .build();
    let warm_resp = warm
        .submit(Dft2dRequest::forward("native", orig.clone()))
        .unwrap()
        .wait()
        .unwrap();
    let warm_stats = warm.stats();
    assert_eq!(warm_stats.planning_events, 0, "warm run must not replan");
    assert!(warm_stats.wisdom_hits >= 1);
    assert!(warm_stats.planning_events < cold_stats.planning_events);
    assert!(!warm_resp.report.planned_cold);
    // same wisdom => byte-identical response
    assert_eq!(warm_resp.matrix.max_abs_diff(&cold_resp.matrix), 0.0);
    warm.shutdown();
}

/// Batched dispatch must produce the same bytes as unbatched dispatch.
#[test]
fn batched_and_unbatched_agree() {
    let n = 32;
    let origs: Vec<SignalMatrix> = (0..6).map(|s| SignalMatrix::random(n, n, s)).collect();

    // unbatched reference: max_batch = 1
    let solo_cfg = ServiceConfig { max_batch: 1, ..quick_cfg() };
    let solo = ServiceBuilder::new(solo_cfg).native().build();
    let solo_out: Vec<SignalMatrix> = origs
        .iter()
        .map(|m| {
            solo.submit(Dft2dRequest::forward("native", m.clone()))
                .unwrap()
                .wait()
                .unwrap()
                .matrix
        })
        .collect();
    let wisdom = solo.wisdom_snapshot();
    solo.shutdown();

    // batched run reuses the identical wisdom (same plan, zero replans)
    let svc = ServiceBuilder::new(quick_cfg()).native().wisdom(wisdom).paused().build();
    let handles: Vec<ResponseHandle> = origs
        .iter()
        .map(|m| svc.submit(Dft2dRequest::forward("native", m.clone())).unwrap())
        .collect();
    svc.start();
    for (h, want) in handles.into_iter().zip(&solo_out) {
        let resp = h.wait().unwrap();
        assert!(resp.report.batched_with >= 1);
        assert_eq!(resp.matrix.max_abs_diff(want), 0.0);
    }
    let stats = svc.stats();
    assert_eq!(stats.planning_events, 0);
    assert!(stats.max_batch > 1, "paused submissions must coalesce");
    svc.shutdown();
}

/// Virtual-time path: paper-scale requests are priced by the calibrated
/// simulator and scheduled shortest-predicted-job-first, fully
/// deterministically (single worker, paused submission).
#[test]
fn virtual_time_spjf_schedules_cheap_sizes_first() {
    let sizes = [24_704usize, 8_064, 16_064];
    let mut store = WisdomStore::new();
    for &n in &sizes {
        store.insert(WisdomRecord::from_simulator(Package::Mkl, n, false));
    }
    let costs: Vec<f64> = sizes
        .iter()
        .map(|&n| {
            store
                .get(EngineId::Sim(Package::Mkl), n, Package::Mkl.best_groups().p)
                .unwrap()
                .predicted_cost_s
        })
        .collect();
    assert!(costs[1] < costs[2] && costs[2] < costs[0], "model must order sizes: {costs:?}");

    let cfg = ServiceConfig {
        workers: 1,
        starvation_bound_s: f64::INFINITY, // pure SPJF
        ..quick_cfg()
    };
    let svc = ServiceBuilder::new(cfg)
        .virtual_package("sim-mkl", Package::Mkl)
        .wisdom(store)
        .paused()
        .build();
    // submit most-expensive first; SPJF must still finish cheapest first
    let handles: Vec<ResponseHandle> = sizes
        .iter()
        .map(|&n| svc.submit(Dft2dRequest::probe("sim-mkl", n)).unwrap())
        .collect();
    svc.start();
    let done: Vec<(usize, f64)> = handles
        .into_iter()
        .zip(&sizes)
        .map(|(h, &n)| (n, h.wait().unwrap().report.virtual_done_s.unwrap()))
        .collect();
    let mut by_completion = done.clone();
    by_completion.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
    assert_eq!(
        by_completion.iter().map(|p| p.0).collect::<Vec<_>>(),
        vec![8_064, 16_064, 24_704],
        "virtual completion order must be shortest-predicted-job-first: {done:?}"
    );
    let stats = svc.stats();
    assert_eq!(stats.planning_events, 0, "prewarmed wisdom");
    assert_eq!(stats.wisdom_hits, 3);
    svc.shutdown();
}

/// A zero starvation bound degrades SPJF to strict FIFO — the other end
/// of the anti-starvation dial, again fully deterministic.
#[test]
fn zero_starvation_bound_means_fifo() {
    let sizes = [24_704usize, 8_064];
    let mut store = WisdomStore::new();
    for &n in &sizes {
        store.insert(WisdomRecord::from_simulator(Package::Mkl, n, false));
    }
    let cfg = ServiceConfig { workers: 1, starvation_bound_s: 0.0, ..quick_cfg() };
    let svc = ServiceBuilder::new(cfg)
        .virtual_package("sim-mkl", Package::Mkl)
        .wisdom(store)
        .paused()
        .build();
    let handles: Vec<ResponseHandle> = sizes
        .iter()
        .map(|&n| svc.submit(Dft2dRequest::probe("sim-mkl", n)).unwrap())
        .collect();
    svc.start();
    let done: Vec<f64> = handles
        .into_iter()
        .map(|h| h.wait().unwrap().report.virtual_done_s.unwrap())
        .collect();
    assert!(
        done[0] < done[1],
        "bound 0 must preserve submission order (big first): {done:?}"
    );
    svc.shutdown();
}

/// FPM-informed admission: wisdom-predicted cost vs deadline hint.
#[test]
fn admission_rejects_infeasible_deadlines() {
    let mut store = WisdomStore::new();
    store.insert(WisdomRecord::from_simulator(Package::Fftw3, 24_704, false));
    let svc = ServiceBuilder::new(quick_cfg())
        .virtual_package("sim-fftw3", Package::Fftw3)
        .wisdom(store)
        .build();
    let err = svc
        .submit(Dft2dRequest::probe("sim-fftw3", 24_704).with_deadline(1e-12))
        .unwrap_err();
    match err {
        ServiceError::DeadlineInfeasible { predicted_s, hint_s, .. } => {
            assert!(predicted_s > hint_s);
        }
        other => panic!("expected DeadlineInfeasible, got {other}"),
    }
    assert_eq!(svc.stats().rejected, 1);
    // generous deadline sails through
    let ok = svc
        .submit(Dft2dRequest::probe("sim-fftw3", 24_704).with_deadline(1e9))
        .unwrap()
        .wait()
        .unwrap();
    assert!(ok.report.virtual_done_s.is_some());
    svc.shutdown();
}

/// Acceptance (PR 3 tentpole): the online model learns from served
/// batches — predicted-vs-actual error shrinks — and an injected speed
/// shift triggers wisdom invalidation + a re-plan within a bounded
/// number of batches, all in deterministic virtual time.
#[test]
fn online_model_learns_and_replans_on_drift_in_virtual_time() {
    let n = 8_064usize;
    let pkg = Package::Mkl;
    let cfg = ServiceConfig { workers: 1, ..quick_cfg() };
    let svc = ServiceBuilder::new(cfg).virtual_package("sim-mkl", pkg).build();
    // the machine runs 2x slower than the calibrated simulator believes,
    // from the very first request — the model has to learn this
    svc.set_virtual_slowdown("sim-mkl", 2.0);

    let probe = |svc: &hclfft::service::Dft2dService| {
        let r = svc.submit(Dft2dRequest::probe("sim-mkl", n)).unwrap().wait().unwrap().report;
        assert!(r.executed_s > 0.0 && r.predicted_s > 0.0);
        (r.predicted_s - r.executed_s).abs() / r.executed_s
    };

    // phase 1: served batches shrink the calibration error
    let errs: Vec<f64> = (0..8).map(|_| probe(&svc)).collect();
    assert!(
        errs[0] > 0.4,
        "first prediction must be off by the hidden 2x slowdown: {errs:?}"
    );
    assert!(
        *errs.last().unwrap() < errs[0] / 4.0,
        "served batches must shrink predicted-vs-actual error: {errs:?}"
    );
    let phase1 = svc.stats();
    assert_eq!(phase1.drift_events, 0, "stationary stream must not drift");
    assert_eq!(phase1.planning_events, 1);

    // phase 2: a 3x speed shift (2x -> 6x) must fire drift within one
    // detection window and trigger wisdom invalidation + a re-plan
    let window = hclfft::model::DriftPolicy::default().window;
    svc.set_virtual_slowdown("sim-mkl", 6.0);
    let mut errs2 = Vec::new();
    for _ in 0..window + 4 {
        errs2.push(probe(&svc));
    }
    let stats = svc.stats();
    assert_eq!(stats.drift_events, 1, "exactly one drift for one shift: {errs2:?}");
    assert_eq!(
        stats.planning_events, 2,
        "drift must invalidate wisdom and re-plan (bounded: within {window} batches)"
    );
    let plan = svc.planned("sim-mkl", n).expect("re-planned partition exists");
    assert_eq!(plan.d.iter().sum::<usize>(), n);
    // the re-planned record prices the *shifted* machine
    let unscaled = WisdomRecord::from_simulator(pkg, n, false).predicted_cost_s;
    let p = pkg.best_groups().p;
    let replanned =
        svc.wisdom_snapshot().get(EngineId::Sim(pkg), n, p).unwrap().predicted_cost_s;
    assert!(
        replanned > 2.5 * unscaled,
        "re-planned cost {replanned} must track the 6x machine (base {unscaled})"
    );
    // and post-drift predictions converge again
    assert!(*errs2.last().unwrap() < 0.05, "post-replan calibration: {errs2:?}");

    // the model deltas + drift log survive persistence
    let path = tmp_path("drift");
    svc.save_wisdom(&path).unwrap();
    let store = WisdomStore::load(&path).unwrap();
    let persisted = store.model("sim-mkl").expect("model state persisted");
    assert_eq!(persisted.drift_events().len(), 1);
    assert!(persisted.observations() >= 8);
    svc.shutdown();

    // a restarted service resumes from the persisted model
    let cfg2 = ServiceConfig { workers: 1, ..quick_cfg() };
    let warm = ServiceBuilder::new(cfg2)
        .virtual_package("sim-mkl", pkg)
        .load_wisdom(&path)
        .unwrap()
        .build();
    let resumed = warm.model_snapshot("sim-mkl").expect("model reattached");
    assert_eq!(resumed.drift_events().len(), 1);
    assert_eq!(resumed.observations(), persisted.observations());
    warm.shutdown();
}

/// Acceptance (PR 3): re-partitioning never changes transform values on
/// unpadded plans — every row is transformed by the same kernel no
/// matter which group owns it. Two independently planned services
/// (independent measurements, possibly different d) must produce
/// byte-identical spectra for the same input.
#[test]
fn replans_keep_outputs_bit_exact() {
    let n = 32;
    let orig = SignalMatrix::random(n, n, 77);
    let mut outputs = Vec::new();
    let mut plans = Vec::new();
    for _ in 0..2 {
        let svc = ServiceBuilder::new(quick_cfg()).native().build();
        let resp = svc
            .submit(Dft2dRequest::forward("native", orig.clone()))
            .unwrap()
            .wait()
            .unwrap();
        plans.push(resp.report.d.clone());
        outputs.push(resp.matrix);
        svc.shutdown();
    }
    assert_eq!(
        outputs[0].max_abs_diff(&outputs[1]),
        0.0,
        "independently planned services (d = {:?} vs {:?}) must be bit-exact",
        plans[0],
        plans[1]
    );
}

/// A committed version-4 wisdom file (kind-keyed records + measured
/// tiles, no `portfolio` object) upgrades cleanly: records and tiles
/// both survive, the store starts with no portfolio state, and
/// portfolio surfaces attached post-upgrade roundtrip through the
/// re-saved version-5 artifact.
#[test]
fn v4_wisdom_file_upgrades_to_v5_and_roundtrips() {
    use hclfft::model::PortfolioModel;
    let mut store =
        WisdomStore::load(std::path::Path::new("rust/tests/fixtures/wisdom_v4.json")).unwrap();
    assert_eq!(store.len(), 1);
    let rec = store
        .get_kind(EngineId::Native, 16, 2, TransformKind::R2c)
        .expect("v4 engine string must parse forward to the typed id");
    assert_eq!(rec.engine, EngineId::Native);
    assert_eq!(rec.plan.d, vec![12, 4]);
    assert_eq!(store.tile_width(16, TransformKind::R2c), Some(4), "v4 tiles must survive");
    assert!(store.portfolio().is_none(), "v4 files carry no portfolio state");
    // surfaces attached after the upgrade persist in the v5 artifact
    let mut pf = PortfolioModel::new(vec![
        EngineId::Sim(Package::Mkl),
        EngineId::Sim(Package::Fftw3),
    ]);
    pf.set_surface(EngineId::Sim(Package::Mkl), 8_064, TransformKind::C2c, 0.25);
    store.set_portfolio(pf);
    let path = tmp_path("v4upgrade");
    store.save(&path).unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    assert!(text.contains("\"version\": 5"), "upgraded artifact must be stamped v5");
    let back = WisdomStore::load(&path).unwrap();
    assert_eq!(back.tile_width(16, TransformKind::R2c), Some(4));
    assert_eq!(
        back.get_kind(EngineId::Native, 16, 2, TransformKind::R2c).unwrap().plan.d,
        vec![12, 4]
    );
    let bp = back.portfolio().expect("portfolio surfaces persisted");
    assert_eq!(bp.members().len(), 2);
    assert_eq!(
        bp.surface(EngineId::Sim(Package::Mkl), 8_064, TransformKind::C2c),
        Some(0.25)
    );
}

/// Acceptance (portfolio tentpole): with heterogeneous calibrated
/// members the portfolio picks different engines at different sizes,
/// an injected machine slowdown on the incumbent fires drift and
/// triggers a re-pick onto the other member, and the learned surfaces
/// persist — all in deterministic virtual time.
#[test]
fn portfolio_picks_per_size_and_repicks_after_drift_in_virtual_time() {
    use hclfft::simulator::vexec::predict_point;
    let mkl = EngineId::Sim(Package::Mkl);
    let fftw3 = EngineId::Sim(Package::Fftw3);
    // self-calibrating: find one campaign size per winner from the same
    // cold surfaces admission seeds the portfolio with (pad_cost: None
    // in quick_cfg, so the fpm point prices the member)
    let cold = |e: EngineId, n: usize| predict_point(e.package().unwrap(), n).t_fpm;
    let sampled: Vec<usize> = hclfft::simulator::campaign_sizes().into_iter().step_by(97).collect();
    let mkl_n = sampled
        .iter()
        .copied()
        .find(|&n| cold(mkl, n) < cold(fftw3, n))
        .expect("calibration must give sim-mkl a winning size");
    let fftw3_n = sampled
        .iter()
        .copied()
        .find(|&n| cold(fftw3, n) < cold(mkl, n))
        .expect("calibration must give sim-fftw3 a winning size");

    let cfg = ServiceConfig { workers: 1, ..quick_cfg() };
    let svc = ServiceBuilder::new(cfg)
        .virtual_id(Package::Mkl)
        .virtual_id(Package::Fftw3)
        .portfolio(vec![mkl, fftw3])
        .build();
    let probe = |n: usize| {
        let r = svc.submit(Dft2dRequest::probe("portfolio", n)).unwrap().wait().unwrap().report;
        assert!(r.virtual_done_s.is_some(), "portfolio members run in virtual time");
        r
    };

    // per-size resolution: each size routes to its calibrated winner
    assert_eq!(probe(mkl_n).engine, mkl);
    assert_eq!(probe(fftw3_n).engine, fftw3);
    let picks = svc.portfolio_picks();
    assert_eq!(picks.len(), 2);
    assert!(
        picks.iter().any(|&(n, _, e)| n == mkl_n && e == mkl)
            && picks.iter().any(|&(n, _, e)| n == fftw3_n && e == fftw3),
        "portfolio must pick different engines at different sizes: {picks:?}"
    );

    // converge the incumbent's model at the true machine speed, then
    // shift the machine hard enough that the other member must win
    for _ in 0..4 {
        assert_eq!(probe(mkl_n).engine, mkl, "picks are sticky while calibrated");
    }
    let factor = 4.0 * (cold(fftw3, mkl_n) / cold(mkl, mkl_n)).max(1.0);
    svc.set_virtual_slowdown(mkl.as_str(), factor);
    let window = hclfft::model::DriftPolicy::default().window;
    let mut last = probe(mkl_n);
    for _ in 0..window + 4 {
        last = probe(mkl_n);
    }
    assert!(svc.stats().drift_events >= 1, "slowdown x{factor} must fire drift");
    let repicks = svc.portfolio_repicks();
    assert!(
        repicks.iter().any(|ev| ev.n == mkl_n && ev.from == mkl && ev.to == fftw3),
        "drift on the incumbent must re-pick the other member: {repicks:?}"
    );
    assert_eq!(last.engine, fftw3, "post-drift requests run on the re-picked member");
    assert_eq!(
        probe(fftw3_n).engine,
        fftw3,
        "drift on one member must not disturb the other size's pick"
    );

    // the portfolio state (members + surfaces) persists in wisdom v5
    let path = tmp_path("portfolio");
    svc.save_wisdom(&path).unwrap();
    let store = WisdomStore::load(&path).unwrap();
    let pf = store.portfolio().expect("portfolio surfaces persisted");
    assert_eq!(pf.members(), [mkl, fftw3]);
    assert!(pf.surface(fftw3, mkl_n, TransformKind::C2c).is_some());
    svc.shutdown();
}

/// Acceptance (portfolio tentpole): routing a request through the
/// portfolio must not change a single bit versus forcing the resolved
/// engine directly — c2c and r2c, across 5-smooth sizes. Both services
/// share one wisdom snapshot so they execute the identical plan.
#[test]
fn portfolio_execution_bit_identical_to_direct_engine() {
    let sizes = [16usize, 18, 20, 24, 27, 45, 50, 60];
    let direct = ServiceBuilder::new(quick_cfg()).native().build();
    let mut complex_out = Vec::new();
    let mut real_out = Vec::new();
    for &n in &sizes {
        let orig = SignalMatrix::random(n, n, n as u64 + 1);
        let resp =
            direct.submit(Dft2dRequest::forward("native", orig.clone())).unwrap().wait().unwrap();
        complex_out.push((orig, resp.matrix));
        let real = SignalMatrix::random_real(n, n, n as u64 + 2);
        let resp = direct
            .submit(Dft2dRequest::real_forward("native", real.clone()))
            .unwrap()
            .wait()
            .unwrap();
        real_out.push((real, resp.matrix));
    }
    let wisdom = direct.wisdom_snapshot();
    direct.shutdown();

    let viapf = ServiceBuilder::new(quick_cfg())
        .native()
        .portfolio(vec![EngineId::Native])
        .wisdom(wisdom)
        .build();
    for (&n, (orig, want)) in sizes.iter().zip(&complex_out) {
        let resp = viapf
            .submit(Dft2dRequest::forward("portfolio", orig.clone()))
            .unwrap()
            .wait()
            .unwrap();
        assert_eq!(resp.report.engine, EngineId::Native, "n={n}: resolved member is reported");
        assert_eq!(
            resp.matrix.max_abs_diff(want),
            0.0,
            "n={n} c2c: portfolio routing must be bit-identical to the direct engine"
        );
    }
    for (&n, (orig, want)) in sizes.iter().zip(&real_out) {
        let resp = viapf
            .submit(Dft2dRequest::real_forward("portfolio", orig.clone()))
            .unwrap()
            .wait()
            .unwrap();
        assert_eq!(resp.report.engine, EngineId::Native, "n={n}: resolved member is reported");
        assert_eq!(
            resp.matrix.max_abs_diff(want),
            0.0,
            "n={n} r2c: portfolio routing must be bit-identical to the direct engine"
        );
    }
    assert_eq!(viapf.stats().planning_events, 0, "shared wisdom must keep the warm path warm");
    viapf.shutdown();
}

/// Inverse requests take the exact dft2d path and undo forward service
/// responses exactly enough for f64.
#[test]
fn service_inverse_roundtrip() {
    let svc = ServiceBuilder::new(quick_cfg()).native().build();
    let orig = SignalMatrix::random(24, 24, 11); // non-pow2 (Bluestein)
    let fwd = svc
        .submit(Dft2dRequest::forward("native", orig.clone()))
        .unwrap()
        .wait()
        .unwrap();
    let back = svc
        .submit(Dft2dRequest::inverse("native", fwd.matrix))
        .unwrap()
        .wait()
        .unwrap();
    let err = back.matrix.max_abs_diff(&orig) / orig.norm().max(1.0);
    assert!(err < 1e-9, "roundtrip rel err {err}");
    svc.shutdown();
}
