//! Integration: the sharded async serving front end end to end —
//! routed output bit-exactness vs a single-service oracle, graceful
//! drain (in-process and over a real socket on an ephemeral port),
//! bounded admission with typed shedding, the deterministic open-loop
//! routing harness (model vs round-robin), stable wire error codes and
//! admission-side payload validation.

use hclfft::dft::fft::Direction;
use hclfft::dft::real::{half_cols, rfft2d, RealMatrix, TransformKind};
use hclfft::dft::SignalMatrix;
use hclfft::serve::wire::WireRequest;
use hclfft::serve::{
    run_virtual_open_loop, Arrivals, FrontBuilder, FrontConfig, NetClient, NetConfig, NetServer,
    RoutePolicy, VirtualShard, VirtualSpec,
};
use hclfft::service::wisdom::PlanningConfig;
use hclfft::service::{Dft2dRequest, ServiceBuilder, ServiceConfig, ServiceError};
use hclfft::util::prng::Xoshiro256;

/// Fast planning, like the service integration suite, with a per-shard
/// processor-group count (each shard plans for its own p).
fn cfg_with_groups(groups: usize) -> ServiceConfig {
    ServiceConfig {
        workers: 2,
        max_batch: 8,
        planning: PlanningConfig {
            groups,
            threads_per_group: 1,
            rep_scale: 10_000,
            ..PlanningConfig::default()
        },
        ..ServiceConfig::default()
    }
}

fn max_abs_diff(a_re: &[f64], a_im: &[f64], b_re: &[f64], b_im: &[f64]) -> f64 {
    assert_eq!(a_re.len(), b_re.len());
    assert_eq!(a_im.len(), b_im.len());
    let d_re = a_re.iter().zip(b_re).map(|(x, y)| (x - y).abs());
    let d_im = a_im.iter().zip(b_im).map(|(x, y)| (x - y).abs());
    d_re.chain(d_im).fold(0.0, f64::max)
}

/// Tentpole property: routing must be invisible in the bits. Shards
/// planned for *different* p (different POPTA partitions) produce the
/// same spectra as an independently planned single-service oracle, for
/// random 5-smooth sizes and both c2c and r2c kinds — so wherever the
/// router places a request, the answer is byte-identical.
#[test]
fn routed_outputs_bit_exact_vs_single_service_oracle() {
    // round-robin placement: both shards are guaranteed traffic
    let front = FrontBuilder::new(FrontConfig { capacity: 32, policy: RoutePolicy::RoundRobin })
        .shard("p1", ServiceBuilder::new(cfg_with_groups(1)).native())
        .shard("p2", ServiceBuilder::new(cfg_with_groups(2)).native())
        .build();
    let oracle = ServiceBuilder::new(cfg_with_groups(2)).native().build();

    let pool = [16usize, 18, 20, 24, 27, 30, 32, 36, 40, 45, 48, 50, 54, 60];
    let mut rng = Xoshiro256::seeded(0x5EED_CAFE);
    let mut shards_hit = [false, false];
    for pick in 0..4 {
        let n = pool[(rng.next_f64() * pool.len() as f64) as usize % pool.len()];

        // c2c, against the serial dft2d oracle (known bit-exact for the
        // single service; the claim here is that sharding changes nothing)
        let orig = SignalMatrix::random(n, n, 1000 + pick);
        let mut want = orig.clone();
        hclfft::dft::dft2d::dft2d(&mut want, Direction::Forward, 1);
        for _ in 0..2 {
            let ticket = front.submit(Dft2dRequest::forward("native", orig.clone())).unwrap();
            shards_hit[ticket.shard()] = true;
            let resp = ticket.wait().unwrap();
            assert_eq!(
                resp.matrix.max_abs_diff(&want),
                0.0,
                "n={n}: routed c2c output must be bit-exact vs the dft2d oracle"
            );
        }

        // r2c, against the independently planned single-service oracle
        let real = SignalMatrix::random_real(n, n, 2000 + pick);
        let want = oracle
            .submit(Dft2dRequest::real_forward("native", real.clone()))
            .unwrap()
            .wait()
            .unwrap();
        for _ in 0..2 {
            let ticket = front.submit(Dft2dRequest::real_forward("native", real.clone())).unwrap();
            shards_hit[ticket.shard()] = true;
            let resp = ticket.wait().unwrap();
            assert_eq!(
                resp.matrix.max_abs_diff(&want.matrix),
                0.0,
                "n={n}: routed r2c output must be bit-exact vs the single-service oracle"
            );
        }
    }
    assert_eq!(shards_hit, [true, true], "round-robin must exercise both shards");
    let stats = front.stats();
    assert_eq!(stats.total.completed, 16);
    assert_eq!(stats.total.failed + stats.total.shed, 0);
    front.shutdown();
    oracle.shutdown();
}

/// Graceful drain: work admitted to paused shards still executes and
/// resolves its tickets during shutdown; submits after the drain began
/// are rejected with the typed `ShuttingDown` (stable code 6).
#[test]
fn shutdown_drains_admitted_work_then_rejects_new_submits() {
    let front = FrontBuilder::new(FrontConfig { capacity: 8, policy: RoutePolicy::ModelFinishTime })
        .shard("a", ServiceBuilder::new(cfg_with_groups(1)).native().paused())
        .shard("b", ServiceBuilder::new(cfg_with_groups(2)).native().paused())
        .build();
    let orig = SignalMatrix::random(16, 16, 5);
    let tickets: Vec<_> = (0..3)
        .map(|_| front.submit(Dft2dRequest::forward("native", orig.clone())).unwrap())
        .collect();
    for t in &tickets {
        assert!(!t.is_done(), "paused shards must not have executed anything yet");
    }
    assert_eq!(front.inflight(), 3);

    front.shutdown();
    assert!(front.is_draining());
    for t in tickets {
        let resp = t.wait().expect("admitted work must complete during the drain");
        assert_eq!(resp.matrix.rows, 16);
    }
    assert_eq!(front.inflight(), 0);
    let err = front.submit(Dft2dRequest::forward("native", orig)).unwrap_err();
    assert_eq!(err, ServiceError::ShuttingDown);
    assert_eq!(err.code(), 6);
    assert_eq!(front.stats().total.completed, 3);
}

/// The TCP front end on an ephemeral port: request/response round-trips
/// are correct (c2c bit-exact vs the dft2d oracle, r2c vs the rfft2d
/// oracle), typed rejections travel as error frames with stable codes,
/// and a client shutdown frame drains the server cleanly — while a
/// server without `--allow-shutdown` refuses it.
#[test]
fn tcp_roundtrip_error_frames_and_remote_shutdown() {
    let front = FrontBuilder::new(FrontConfig::default())
        .shard("s0", ServiceBuilder::new(cfg_with_groups(1)).native())
        .shard("s1", ServiceBuilder::new(cfg_with_groups(2)).native())
        .build();
    let cfg = NetConfig { allow_remote_shutdown: true, ..NetConfig::default() };
    let mut server = NetServer::bind(front, "127.0.0.1:0", cfg).unwrap();
    let addr = server.local_addr();
    let mut client = NetClient::connect(addr).unwrap();

    // c2c round-trip: the wire carries exact f64 little-endian bits
    let n = 20usize;
    let orig = SignalMatrix::random(n, n, 9);
    let resp = client
        .roundtrip(WireRequest {
            req_id: 0,
            deadline_us: 0,
            n: n as u64,
            kind: TransformKind::C2c,
            direction: Direction::Forward,
            engine: "native".into(),
            re: orig.re.clone(),
            im: orig.im.clone(),
        })
        .unwrap()
        .expect("c2c request must succeed");
    assert_eq!((resp.rows, resp.cols), (n as u64, n as u64));
    assert!((resp.shard as usize) < 2);
    assert!(resp.server_latency_s >= 0.0);
    let mut want = orig.clone();
    hclfft::dft::dft2d::dft2d(&mut want, Direction::Forward, 1);
    assert_eq!(
        max_abs_diff(&resp.re, &resp.im, &want.re, &want.im),
        0.0,
        "spectrum over TCP must be bit-exact vs the dft2d oracle"
    );

    // r2c round-trip: empty im plane on the wire, packed half spectrum back
    let n = 24usize;
    let real = SignalMatrix::random_real(n, n, 10);
    let resp = client
        .roundtrip(WireRequest {
            req_id: 0,
            deadline_us: 0,
            n: n as u64,
            kind: TransformKind::R2c,
            direction: Direction::Forward,
            engine: "native".into(),
            re: real.re.clone(),
            im: Vec::new(),
        })
        .unwrap()
        .expect("r2c request must succeed");
    assert_eq!((resp.rows as usize, resp.cols as usize), (n, half_cols(n)));
    let rm = RealMatrix { rows: n, cols: n, data: real.re.clone() };
    let want = rfft2d(&rm, 1);
    let err = max_abs_diff(&resp.re, &resp.im, &want.re, &want.im);
    assert!(err < 1e-6, "r2c spectrum over TCP vs rfft2d oracle: max err {err:e}");

    // typed rejection: unknown engine ships its stable code in an error frame
    let rejected = client
        .roundtrip(WireRequest {
            req_id: 0,
            deadline_us: 0,
            n: 8,
            kind: TransformKind::C2c,
            direction: Direction::Forward,
            engine: "cufft".into(),
            re: vec![0.0; 64],
            im: vec![0.0; 64],
        })
        .unwrap()
        .expect_err("unknown engine must be rejected");
    assert_eq!(rejected.0, ServiceError::UnknownEngine("cufft".into()).code());
    assert!(rejected.1.contains("cufft"), "message must name the engine: {}", rejected.1);

    // clean remote shutdown: acknowledged, then the server drains
    assert!(client.shutdown_server().unwrap(), "shutdown must be acknowledged");
    server.wait_until_stopped();
    assert!(server.is_stopped());
    assert_eq!(server.front().stats().total.completed, 2);
    server.shutdown();

    // a second server with remote shutdown disabled refuses the frame
    let front2 = FrontBuilder::new(FrontConfig::default())
        .shard("solo", ServiceBuilder::new(cfg_with_groups(1)).native())
        .build();
    let mut server2 = NetServer::bind(front2, "127.0.0.1:0", NetConfig::default()).unwrap();
    let mut client2 = NetClient::connect(server2.local_addr()).unwrap();
    assert!(!client2.shutdown_server().unwrap(), "disabled shutdown must be refused");
    assert!(!server2.is_stopped());
    server2.shutdown();
}

/// Bounded admission: beyond `capacity` requests in flight, submits are
/// shed immediately with `Overloaded` (stable code 8) carrying a
/// non-negative model-predicted wait, and the shed is counted.
#[test]
fn overload_sheds_with_typed_predicted_wait() {
    let front = FrontBuilder::new(FrontConfig { capacity: 1, policy: RoutePolicy::ModelFinishTime })
        .shard("only", ServiceBuilder::new(cfg_with_groups(1)).native().paused())
        .build();
    let orig = SignalMatrix::random(16, 16, 3);
    let admitted = front.submit(Dft2dRequest::forward("native", orig.clone())).unwrap();
    let err = front.submit(Dft2dRequest::forward("native", orig)).unwrap_err();
    match err {
        ServiceError::Overloaded { queued, capacity, predicted_wait_s } => {
            assert_eq!((queued, capacity), (1, 1));
            assert!(
                predicted_wait_s >= 0.0 && predicted_wait_s.is_finite(),
                "shed clients get a finite predicted wait, got {predicted_wait_s}"
            );
        }
        other => panic!("expected Overloaded, got {other}"),
    }
    assert_eq!(front.stats().total.shed, 1);
    front.shutdown();
    assert!(admitted.wait().is_ok(), "the admitted request still completes");
}

/// Acceptance, in fully deterministic virtual time through the real
/// router: (1) under overload the bounded window sheds and keeps the
/// accepted tail finite; (2) on heterogeneous shards, model-predicted
/// finish-time placement beats round-robin on p95 latency.
#[test]
fn virtual_open_loop_sheds_under_overload_and_model_beats_round_robin() {
    // overload: two 100 ms shards offered ~4x their joint capacity
    let uniform = vec![
        VirtualShard { name: "u0".into(), true_s: vec![0.1], believed_s: vec![0.102] },
        VirtualShard { name: "u1".into(), true_s: vec![0.1], believed_s: vec![0.098] },
    ];
    let spec = VirtualSpec {
        requests: 300,
        arrivals: Arrivals::Poisson { rate_rps: 80.0, seed: 17 },
        capacity: 5,
        policy: RoutePolicy::ModelFinishTime,
        classes: vec![0],
    };
    let rep = run_virtual_open_loop(&uniform, &spec);
    assert_eq!(rep.offered, 300);
    assert!(rep.shed > 0, "4x overload must shed");
    assert_eq!(rep.accepted + rep.shed, 300);
    assert!(
        rep.latency_p99_s <= 0.1 * (spec.capacity as f64 + 1.0),
        "p99 {} must stay bounded by the admission window",
        rep.latency_p99_s
    );

    // heterogeneous shards (one 4x slower): same schedule, both policies
    let skewed = vec![
        VirtualShard { name: "fast".into(), true_s: vec![0.02], believed_s: vec![0.0204] },
        VirtualShard { name: "slow".into(), true_s: vec![0.08], believed_s: vec![0.0784] },
    ];
    let mk = |policy| VirtualSpec {
        requests: 400,
        arrivals: Arrivals::Poisson { rate_rps: 30.0, seed: 23 },
        capacity: 8,
        policy,
        classes: vec![0],
    };
    let model = run_virtual_open_loop(&skewed, &mk(RoutePolicy::ModelFinishTime));
    let rr = run_virtual_open_loop(&skewed, &mk(RoutePolicy::RoundRobin));
    assert!(
        model.latency_p95_s < rr.latency_p95_s,
        "model p95 {} must beat round-robin p95 {}",
        model.latency_p95_s,
        rr.latency_p95_s
    );
    assert!(model.shed <= rr.shed, "model sheds ({}) <= round-robin ({})", model.shed, rr.shed);
}

/// Satellite: the wire protocol's numeric error codes are a contract —
/// every variant keeps its number forever, and the rendered messages
/// carry the n/kind context a remote client needs to diagnose.
#[test]
fn service_error_codes_are_stable_and_contextual() {
    let shape = ServiceError::BadShape { n: 8, rows: 8, cols: 7, kind: "c2c" };
    let deadline =
        ServiceError::DeadlineInfeasible { n: 8, kind: "c2c", predicted_s: 1.0, hint_s: 0.5 };
    let overloaded = ServiceError::Overloaded { queued: 4, capacity: 4, predicted_wait_s: 0.25 };
    let too_large = ServiceError::PayloadTooLarge { n: 8, kind: "c2c", bytes: 99, max_bytes: 64 };
    let torn = ServiceError::BadPayload { n: 8, kind: "c2c", expected: 4, re_len: 4, im_len: 3 };

    assert_eq!(ServiceError::UnknownEngine("cufft".into()).code(), 1);
    assert_eq!(shape.code(), 2);
    assert_eq!(ServiceError::UnsupportedKind { engine: "sim-mkl".into(), kind: "r2c" }.code(), 3);
    assert_eq!(deadline.code(), 4);
    assert_eq!(ServiceError::Engine("boom".into()).code(), 5);
    assert_eq!(ServiceError::ShuttingDown.code(), 6);
    assert_eq!(ServiceError::Disconnected.code(), 7);
    assert_eq!(overloaded.code(), 8);
    assert_eq!(too_large.code(), 9);
    assert_eq!(torn.code(), 10);

    // context spot-checks on the rendered messages
    assert!(shape.to_string().contains("n=8"), "{shape}");
    assert!(deadline.to_string().contains("c2c"), "{deadline}");
    assert!(overloaded.to_string().contains("capacity 4"), "{overloaded}");
    assert!(too_large.to_string().contains("99"), "{too_large}");
    assert!(torn.to_string().contains("im=3"), "{torn}");
}

/// Satellite: admission-side validation turns malformed payloads into
/// typed rejections *before* any worker touches them — plane/geometry
/// disagreement, a configured byte budget, and a declared n that does
/// not match the matrix.
#[test]
fn admission_validates_geometry_and_payload() {
    let shard_cfg = ServiceConfig { max_payload_bytes: Some(256), ..cfg_with_groups(1) };
    let front = FrontBuilder::new(FrontConfig::default())
        .shard("strict", ServiceBuilder::new(shard_cfg).native())
        .build();

    // plane length disagrees with the declared geometry
    let mut torn = Dft2dRequest::forward("native", SignalMatrix::random(8, 8, 1));
    torn.matrix.im.pop();
    let err = front.submit(torn).unwrap_err();
    match &err {
        ServiceError::BadPayload { n, expected, im_len, .. } => {
            assert_eq!((*n, *expected, *im_len), (8, 64, 63));
        }
        other => panic!("expected BadPayload, got {other}"),
    }
    assert_eq!(err.code(), 10);

    // well-formed planes, but over the configured byte budget
    let err = front
        .submit(Dft2dRequest::forward("native", SignalMatrix::random(8, 8, 2)))
        .unwrap_err();
    match &err {
        ServiceError::PayloadTooLarge { bytes, max_bytes, .. } => {
            assert_eq!((*bytes, *max_bytes), (1024, 256));
        }
        other => panic!("expected PayloadTooLarge, got {other}"),
    }
    assert_eq!(err.code(), 9);

    // declared n disagrees with the matrix
    let err = front
        .submit(Dft2dRequest {
            n: 9,
            matrix: SignalMatrix::random(8, 8, 3),
            direction: Direction::Forward,
            kind: TransformKind::C2c,
            engine: "native".into(),
            deadline_hint: None,
        })
        .unwrap_err();
    assert!(matches!(err, ServiceError::BadShape { n: 9, rows: 8, cols: 8, .. }), "got {err}");
    assert_eq!(err.code(), 2);

    // every rejection rolled its admission slot back
    assert_eq!(front.inflight(), 0);
    assert_eq!(front.stats().total.rejected, 3);
    front.shutdown();
}
