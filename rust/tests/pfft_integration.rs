//! Integration: PFFT drivers end to end on the native engine — planning
//! from *measured* FPMs, execution, and numeric verification against the
//! naive oracle.

use hclfft::coordinator::engine::NativeEngine;
use hclfft::coordinator::group::{best_config, candidates_for_budget, GroupConfig};
use hclfft::coordinator::pad::{pads_for_distribution, PadCost};
use hclfft::coordinator::pfft::{pfft_fpm, pfft_fpm_pad, pfft_lb, plan_partition};
use hclfft::model::StaticModel;
use hclfft::dft::{naive_dft2d, SignalMatrix};
use hclfft::profiler::build_plane;

fn rel_err(a: &SignalMatrix, b: &SignalMatrix) -> f64 {
    a.max_abs_diff(b) / b.norm().max(1.0)
}

#[test]
fn measured_plan_then_execute_matches_oracle() {
    let n = 32;
    let cfg = GroupConfig::new(2, 1);
    let fpms = build_plane(&NativeEngine, cfg, vec![8, 16, 24, 32], n, 10_000);
    let part = plan_partition(&StaticModel::new(fpms), n, 0.05).unwrap();
    assert_eq!(part.d.iter().sum::<usize>(), n);

    let orig = SignalMatrix::random(n, n, 3);
    let mut m = orig.clone();
    pfft_fpm(&NativeEngine, &mut m, &part.d, 1, 16).unwrap();
    let want = naive_dft2d(&orig);
    assert!(rel_err(&m, &want) < 1e-9, "rel err {}", rel_err(&m, &want));
}

#[test]
fn all_three_drivers_agree_when_unpadded() {
    let n = 24; // non-power-of-two: exercises Bluestein
    let orig = SignalMatrix::random(n, n, 9);

    let mut lb = orig.clone();
    pfft_lb(&NativeEngine, &mut lb, GroupConfig::new(3, 1), 8).unwrap();

    let mut fpm = orig.clone();
    pfft_fpm(&NativeEngine, &mut fpm, &[10, 6, 8], 1, 8).unwrap();

    let fpms = build_plane(&NativeEngine, GroupConfig::new(3, 1), vec![6, 12, 18, 24], n, 10_000);
    let model = StaticModel::new(fpms);
    let pads: Vec<_> =
        pads_for_distribution(&model, &[10, 6, 8], n, usize::MAX, PadCost::PaperRatio)
            .into_iter()
            .map(|mut p| {
                p.n_padded = n; // force unpadded so all three must agree exactly
                p
            })
            .collect();
    let mut pad = orig.clone();
    pfft_fpm_pad(&NativeEngine, &mut pad, &[10, 6, 8], &pads, 1, 8).unwrap();

    assert!(lb.max_abs_diff(&fpm) < 1e-12);
    assert!(fpm.max_abs_diff(&pad) < 1e-12);
    let want = naive_dft2d(&orig);
    assert!(rel_err(&lb, &want) < 1e-9);
}

#[test]
fn padded_run_is_row_phase_spectral_interpolation() {
    // PFFT-FPM-PAD with a forced pad must equal the composition of padded
    // row phases + transposes done manually (the paper's semantics).
    let n = 16;
    let pad_to = 20;
    let d = vec![16usize];
    let orig = SignalMatrix::random(n, n, 4);

    let pads = vec![hclfft::coordinator::pad::PadDecision {
        n_padded: pad_to,
        t_unpadded: 1.0,
        t_padded: 0.5,
    }];
    let mut got = orig.clone();
    pfft_fpm_pad(&NativeEngine, &mut got, &d, &pads, 1, 8).unwrap();

    // manual composition
    use hclfft::coordinator::engine::RowFftEngine;
    use hclfft::dft::fft::Direction;
    use hclfft::dft::transpose::transpose_in_place_parallel;
    let mut want = orig.clone();
    for _phase in 0..2 {
        let padded = want.pad_cols(pad_to);
        let mut w = padded.clone();
        NativeEngine
            .fft_rows(&mut w.re, &mut w.im, n, pad_to, Direction::Forward, 1)
            .unwrap();
        want = w.crop_cols(n);
        transpose_in_place_parallel(&mut want, 8, 1);
    }
    assert!(got.max_abs_diff(&want) < 1e-12);
}

#[test]
fn best_config_selection_runs_real_measurements() {
    // the paper's (p, t) selection procedure with real timings on a tiny
    // size — just assert it picks *something* from the candidate set and
    // the measurement is positive
    let candidates = candidates_for_budget(4);
    let n = 32;
    let (best, secs) = best_config(&candidates, |cfg| {
        let mut m = SignalMatrix::random(n, n, 1);
        let t0 = std::time::Instant::now();
        pfft_lb(&NativeEngine, &mut m, cfg, 16).unwrap();
        t0.elapsed().as_secs_f64()
    })
    .unwrap();
    assert!(candidates.contains(&best));
    assert!(secs > 0.0);
}

#[test]
fn large_pow2_matches_between_thread_counts() {
    let n = 128;
    let orig = SignalMatrix::random(n, n, 17);
    let mut a = orig.clone();
    let mut b = orig.clone();
    pfft_lb(&NativeEngine, &mut a, GroupConfig::new(1, 1), 64).unwrap();
    pfft_lb(&NativeEngine, &mut b, GroupConfig::new(4, 2), 64).unwrap();
    assert!(a.max_abs_diff(&b) < 1e-12);
}
