//! Integration: the PJRT runtime against the AOT JAX/Pallas artifacts —
//! the rust side of the three-layer AOT bridge. Requires both the `pjrt`
//! cargo feature (the `xla` crate is not in the offline vendor set) and
//! `artifacts/manifest.tsv` (built by `make artifacts`); each test skips
//! gracefully with a printed notice when either is missing, so plain
//! `cargo test` stays green pre-AOT.

use std::path::Path;

use hclfft::coordinator::engine::{NativeEngine, RowFftEngine};
use hclfft::coordinator::group::GroupConfig;
use hclfft::coordinator::pfft::{pfft_fpm, pfft_lb};
use hclfft::dft::fft::Direction;
use hclfft::dft::SignalMatrix;
use hclfft::runtime::{PjrtRowFftEngine, PjrtRuntime};

fn artifacts() -> Option<&'static Path> {
    if !hclfft::runtime::pjrt_available() {
        eprintln!(
            "skipping: hclfft built without the `pjrt` feature \
             (enable with `--features pjrt` after adding the xla crate)"
        );
        return None;
    }
    let p = Path::new("artifacts");
    if p.join("manifest.tsv").exists() {
        Some(p)
    } else {
        eprintln!("skipping: artifacts/ not built (run `make artifacts`)");
        None
    }
}

#[test]
fn pjrt_row_ffts_match_native_across_grid() {
    let Some(dir) = artifacts() else { return };
    let engine = PjrtRowFftEngine::load(dir).unwrap();
    let lengths = engine.supported_lengths().unwrap();
    assert!(!lengths.is_empty());
    for &n in lengths.iter().take(3) {
        for rows in [1usize, 5, 9] {
            let orig = SignalMatrix::random(rows, n, n as u64);
            let mut got = orig.clone();
            engine
                .fft_rows(&mut got.re, &mut got.im, rows, n, Direction::Forward, 1)
                .unwrap();
            let mut want = orig.clone();
            NativeEngine
                .fft_rows(&mut want.re, &mut want.im, rows, n, Direction::Forward, 1)
                .unwrap();
            let err = got.max_abs_diff(&want) / want.norm().max(1.0);
            assert!(err < 1e-4, "rows={rows} n={n}: rel err {err}"); // f32 artifacts
        }
    }
}

#[test]
fn pjrt_inverse_roundtrip() {
    let Some(dir) = artifacts() else { return };
    let engine = PjrtRowFftEngine::load(dir).unwrap();
    let n = engine.supported_lengths().unwrap()[0];
    let orig = SignalMatrix::random(4, n, 2);
    let mut m = orig.clone();
    engine.fft_rows(&mut m.re, &mut m.im, 4, n, Direction::Forward, 1).unwrap();
    engine.fft_rows(&mut m.re, &mut m.im, 4, n, Direction::Inverse, 1).unwrap();
    let err = m.max_abs_diff(&orig) / orig.norm().max(1.0);
    assert!(err < 1e-4, "roundtrip rel err {err}");
}

#[test]
fn pjrt_unsupported_length_errors() {
    let Some(dir) = artifacts() else { return };
    let engine = PjrtRowFftEngine::load(dir).unwrap();
    let mut m = SignalMatrix::random(2, 96, 1); // 96 not in the grid
    let err = engine
        .fft_rows(&mut m.re, &mut m.im, 2, 96, Direction::Forward, 1)
        .unwrap_err();
    assert!(err.to_string().contains("not supported"), "{err}");
}

#[test]
fn pjrt_full2d_matches_native_dft2d() {
    let Some(dir) = artifacts() else { return };
    let rt = PjrtRuntime::load(dir).unwrap();
    let n = 128;
    let orig = SignalMatrix::random(n, n, 5);
    let mut re32: Vec<f32> = orig.re.iter().map(|&v| v as f32).collect();
    let mut im32: Vec<f32> = orig.im.iter().map(|&v| v as f32).collect();
    rt.full2d_f32(&mut re32, &mut im32, n).unwrap();

    let mut want = orig.clone();
    hclfft::dft::dft2d::dft2d(&mut want, Direction::Forward, 1);
    let scale = want.norm().max(1.0);
    let mut max_err = 0.0f64;
    for i in 0..n * n {
        max_err = max_err.max((re32[i] as f64 - want.re[i]).abs());
        max_err = max_err.max((im32[i] as f64 - want.im[i]).abs());
    }
    assert!(max_err / scale < 1e-4, "full2d rel err {}", max_err / scale);
}

#[test]
fn pjrt_under_pfft_drivers_matches_native() {
    let Some(dir) = artifacts() else { return };
    let engine = PjrtRowFftEngine::load(dir).unwrap();
    let n = 256;
    let orig = SignalMatrix::random(n, n, 11);

    let mut pjrt_out = orig.clone();
    pfft_fpm(&engine, &mut pjrt_out, &[100, 156], 1, 64).unwrap();

    let mut native_out = orig.clone();
    pfft_lb(&NativeEngine, &mut native_out, GroupConfig::new(2, 1), 64).unwrap();

    let err = pjrt_out.max_abs_diff(&native_out) / native_out.norm().max(1.0);
    assert!(err < 1e-4, "pjrt-vs-native under drivers: rel err {err}");
}

#[test]
fn executable_cache_reuses_compilations() {
    let Some(dir) = artifacts() else { return };
    let rt = PjrtRuntime::load(dir).unwrap();
    let n = rt.supported_lengths()[0];
    let mut re = vec![0.0f32; 8 * n];
    let mut im = vec![0.0f32; 8 * n];
    rt.row_ffts_f32(&mut re, &mut im, 8, n, Direction::Forward).unwrap();
    let after_first = rt.cached_executables();
    rt.row_ffts_f32(&mut re, &mut im, 8, n, Direction::Forward).unwrap();
    assert_eq!(rt.cached_executables(), after_first, "second run must not recompile");
}
