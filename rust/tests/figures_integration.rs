//! Integration: the figures harness regenerates every paper item in
//! quick mode and produces well-formed CSVs.

use std::path::PathBuf;

use hclfft::figures::{all_ids, generate, Ctx};

fn ctx() -> Ctx {
    let dir = std::env::temp_dir().join("hclfft_figs_integration");
    std::fs::create_dir_all(&dir).unwrap();
    let mut c = Ctx::new(&dir, true);
    c.decimate = 32; // extra-quick for debug-mode CI
    c
}

#[test]
fn every_simulated_figure_generates() {
    let ctx = ctx();
    for id in all_ids() {
        if id == "real" {
            continue; // needs artifacts; covered by runtime_integration
        }
        let out = generate(id, &ctx).unwrap_or_else(|e| panic!("fig {id}: {e}"));
        assert!(!out.is_empty(), "fig {id} produced empty output");
    }
}

#[test]
fn figure_csvs_are_written_and_parse() {
    let ctx = ctx();
    for id in ["1", "15", "20", "25"] {
        generate(id, &ctx).unwrap();
        let csv = std::fs::read_to_string(ctx.out_dir.join(format!("fig{id}.csv"))).unwrap();
        let mut lines = csv.lines();
        let header = lines.next().unwrap();
        assert!(header.starts_with("N,"), "fig{id} header: {header}");
        let mut count = 0;
        for line in lines {
            let cols: Vec<&str> = line.split(',').collect();
            assert!(cols.len() >= 2, "fig{id}: short row {line}");
            let _: usize = cols[0].parse().expect("N column");
            for v in &cols[1..] {
                let x: f64 = v.parse().expect("numeric column");
                assert!(x.is_finite() && x > 0.0, "fig{id}: bad value {v}");
            }
            count += 1;
        }
        assert!(count > 5, "fig{id}: only {count} rows");
    }
}

#[test]
fn summary_figure_contains_published_comparisons() {
    let ctx = ctx();
    let s = generate("summary", &ctx).unwrap();
    assert!(s.contains("published"));
    assert!(s.contains("reproduced"));
    assert!(s.contains("PFFT-FPM max speedup"));
}

#[test]
fn fig10_reports_partition_gain() {
    let ctx = ctx();
    let s = generate("10", &ctx).unwrap();
    assert!(s.contains("gain"), "{s}");
}

#[test]
fn table1_and_illustrations() {
    let ctx = ctx();
    assert!(generate("t1", &ctx).unwrap().contains("Haswell"));
    assert!(generate("7", &ctx).unwrap().contains("PFFT-LB"));
    assert!(generate("8", &ctx).unwrap().contains("{5,3,2,6}"));
}

#[test]
fn out_dir_is_respected() {
    let dir = std::env::temp_dir().join(format!("hclfft_figs_alt_{}", std::process::id()));
    let mut ctx = Ctx::new(&dir, true);
    ctx.decimate = 64;
    let _ = std::fs::create_dir_all(&dir);
    generate("1", &ctx).unwrap();
    assert!(PathBuf::from(&dir).join("fig1.csv").exists());
    let _ = std::fs::remove_dir_all(&dir);
}
