//! Integration: the reproduction criteria — the virtual campaign must
//! land in the paper's published bands (DESIGN.md §5/§6). These tests ARE
//! the claim "the shape of the paper's evaluation holds".
//!
//! Grids are decimated (every 8th campaign size) to keep non-release test
//! time reasonable; the bands account for that.

use hclfft::simulator::packages::PackageModel;
use hclfft::simulator::vexec::{Campaign, CampaignSummary};
use hclfft::simulator::{campaign_sizes, paper_sizes, Package};
use hclfft::stats::summary;

fn decimated() -> Vec<usize> {
    campaign_sizes().into_iter().step_by(8).collect()
}

#[test]
fn package_study_statistics() {
    // Figures 1-6 headline stats (published values in comments)
    let sizes = paper_sizes();
    for (pkg, avg, peak) in [
        (Package::Fftw2, 7033.0, 17841.0),
        (Package::Fftw3, 5065.0, 16989.0),
        (Package::Mkl, 9572.0, 39424.0),
    ] {
        let m = PackageModel::new(pkg);
        let speeds: Vec<f64> = sizes.iter().map(|&n| m.speed(n)).collect();
        let s = summary(&speeds);
        assert!((s.mean - avg).abs() / avg < 0.02, "{}: avg {}", pkg.name(), s.mean);
        assert!((s.max - peak).abs() / peak < 0.02, "{}: peak {}", pkg.name(), s.max);
    }
}

#[test]
fn fftw3_speedups_in_paper_band() {
    // paper: FPM avg 1.9x max 6.8x; PAD avg 2.0x max 9.4x
    let c = Campaign::run(Package::Fftw3, &decimated());
    let s = c.summary();
    assert!((1.4..=2.4).contains(&s.avg_speedup_fpm), "FPM avg {}", s.avg_speedup_fpm);
    assert!((4.0..=10.0).contains(&s.max_speedup_fpm), "FPM max {}", s.max_speedup_fpm);
    assert!((1.6..=2.6).contains(&s.avg_speedup_pad), "PAD avg {}", s.avg_speedup_pad);
    assert!((4.0..=12.0).contains(&s.max_speedup_pad), "PAD max {}", s.max_speedup_pad);
    // PAD dominates FPM on average (it strictly extends it)
    assert!(s.avg_speedup_pad >= s.avg_speedup_fpm);
}

#[test]
fn mkl_speedups_in_paper_band() {
    // paper: FPM avg 1.3x max 2.0x; PAD avg 1.4x max 5.9x
    let c = Campaign::run(Package::Mkl, &decimated());
    let s = c.summary();
    assert!((1.05..=1.5).contains(&s.avg_speedup_fpm), "FPM avg {}", s.avg_speedup_fpm);
    assert!(s.max_speedup_fpm <= 3.0, "FPM max {}", s.max_speedup_fpm);
    assert!((1.2..=1.9).contains(&s.avg_speedup_pad), "PAD avg {}", s.avg_speedup_pad);
    assert!((2.5..=7.0).contains(&s.max_speedup_pad), "PAD max {}", s.max_speedup_pad);
    // the MKL signature: padding matters far more than repartitioning
    assert!(s.max_speedup_pad > 1.5 * s.max_speedup_fpm);
}

#[test]
fn range_structure_matches_section_v_f() {
    for pkg in [Package::Fftw3, Package::Mkl] {
        let c = Campaign::run(pkg, &decimated());
        let low = CampaignSummary::for_range(&c.points, 0, 10_000);
        let mid = CampaignSummary::for_range(&c.points, 10_000, 33_000);
        let high = CampaignSummary::for_range(&c.points, 33_000, usize::MAX);
        // low range: "not significant"
        assert!(
            (0.8..=1.3).contains(&low.avg_speedup_fpm),
            "{}: low FPM {}",
            pkg.name(),
            low.avg_speedup_fpm
        );
        // mid range: "tremendous"
        assert!(
            mid.avg_speedup_fpm > low.avg_speedup_fpm,
            "{}: mid {} vs low {}",
            pkg.name(),
            mid.avg_speedup_fpm,
            low.avg_speedup_fpm
        );
        // high range: good but variations remain
        assert!(
            high.avg_speedup_fpm > 1.0,
            "{}: high {}",
            pkg.name(),
            high.avg_speedup_fpm
        );
    }
}

#[test]
fn optimized_beats_unoptimized_fftw2_on_average() {
    // Figures 25/26: optimized 3.3.7 and MKL overtake unoptimized 2.1.5
    use hclfft::simulator::vexec::{app_flops, transpose_time};
    let f2 = PackageModel::new(Package::Fftw2);
    for pkg in [Package::Fftw3, Package::Mkl] {
        let c = Campaign::run(pkg, &decimated());
        let mut sp_sum = 0.0;
        for p in &c.points {
            let t_f2 = app_flops(p.n) / (f2.speed(p.n) * 1e6) + 2.0 * transpose_time(p.n);
            sp_sum += t_f2 / p.t_pad;
        }
        let avg = sp_sum / c.points.len() as f64;
        // paper: 1.2x (fftw3), 1.7x (mkl)
        assert!(avg > 1.0, "{}: avg speedup vs fftw2 {avg}", pkg.name());
        if pkg == Package::Mkl {
            assert!(avg > 1.3, "mkl should clearly beat fftw2: {avg}");
        }
    }
}

#[test]
fn high_range_variations_remain_in_optimized_curve() {
    // paper §V-F: "major variations still remain" for N > 33000
    let c = Campaign::run(Package::Mkl, &decimated());
    let high: Vec<f64> = c
        .points
        .iter()
        .filter(|p| p.n > 33_000)
        .map(|p| p.mflops(p.t_pad))
        .collect();
    assert!(high.len() > 10);
    let s = summary(&high);
    // coefficient of variation must stay substantial (not smoothed flat)
    assert!(s.sd / s.mean > 0.10, "optimized high-range too smooth: cv {}", s.sd / s.mean);
}

#[test]
fn campaign_is_deterministic() {
    let a = Campaign::run(Package::Fftw3, &[12_800, 24_704]);
    let b = Campaign::run(Package::Fftw3, &[12_800, 24_704]);
    for (x, y) in a.points.iter().zip(&b.points) {
        assert_eq!(x.d, y.d);
        assert_eq!(x.pads, y.pads);
        assert_eq!(x.t_pad.to_bits(), y.t_pad.to_bits());
    }
}
