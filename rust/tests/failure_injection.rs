//! Failure injection: the system must fail loudly and precisely on
//! corrupt inputs — broken manifests, unparsable HLO, bad configs,
//! degenerate planning inputs.

use std::path::Path;

use hclfft::config::Config;
use hclfft::coordinator::fpm::SpeedFunction;
use hclfft::runtime::{Manifest, PjrtRuntime};

fn tmp_dir(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("hclfft_fail_{tag}_{}", std::process::id()));
    std::fs::create_dir_all(&d).unwrap();
    d
}

#[test]
fn missing_manifest_reports_path() {
    let d = tmp_dir("nomanifest");
    let Err(err) = PjrtRuntime::load(&d) else {
        panic!("load must fail without a manifest");
    };
    assert!(err.to_string().contains("manifest"), "{err}");
}

#[test]
fn truncated_manifest_line_reports_lineno() {
    let err = Manifest::parse("row_fft\t8\t128\n", Path::new("/x")).unwrap_err();
    assert!(err.contains("line 1"), "{err}");
}

#[test]
fn corrupt_hlo_file_fails_at_compile_not_later() {
    let d = tmp_dir("badhlo");
    std::fs::write(d.join("manifest.tsv"), "row_fft\t8\t128\tbroken.hlo.txt\n").unwrap();
    std::fs::write(d.join("broken.hlo.txt"), "HloModule not-actually-hlo ENTRY {").unwrap();
    let rt = PjrtRuntime::load(&d).unwrap(); // manifest ok
    let mut re = vec![0.0f32; 8 * 128];
    let mut im = vec![0.0f32; 8 * 128];
    let err = rt
        .row_ffts_f32(&mut re, &mut im, 8, 128, hclfft::dft::fft::Direction::Forward)
        .unwrap_err();
    let msg = err.to_string();
    assert!(msg.contains("broken.hlo.txt") || msg.contains("runtime failure"), "{msg}");
}

#[test]
fn manifest_pointing_at_missing_file_errors() {
    let d = tmp_dir("missingfile");
    std::fs::write(d.join("manifest.tsv"), "row_fft\t8\t128\tnot_there.hlo.txt\n").unwrap();
    let rt = PjrtRuntime::load(&d).unwrap();
    let mut re = vec![0.0f32; 8 * 128];
    let mut im = vec![0.0f32; 8 * 128];
    assert!(rt
        .row_ffts_f32(&mut re, &mut im, 8, 128, hclfft::dft::fft::Direction::Forward)
        .is_err());
}

#[test]
fn config_rejects_malformed_values_with_key_name() {
    let d = tmp_dir("badconfig");
    let p = d.join("bad.conf");
    std::fs::write(&p, "groups = not_a_number\n").unwrap();
    let err = Config::load(Some(&p)).unwrap_err();
    assert!(err.contains("groups"), "{err}");
}

#[test]
fn config_rejects_unknown_keys() {
    let d = tmp_dir("unknownkey");
    let p = d.join("u.conf");
    std::fs::write(&p, "grops = 2\n").unwrap();
    let err = Config::load(Some(&p)).unwrap_err();
    assert!(err.contains("unknown key"), "{err}");
}

#[test]
fn fpm_tsv_with_garbage_reports_line() {
    let d = tmp_dir("badfpm");
    let p = d.join("f.tsv");
    std::fs::write(&p, "128\t128\t100.0\n128\tbroken\n").unwrap();
    let err = SpeedFunction::read_tsv(&p).unwrap_err();
    assert!(err.contains("line 2"), "{err}");
}

#[test]
fn fpm_tsv_empty_errors() {
    let d = tmp_dir("emptyfpm");
    let p = d.join("e.tsv");
    std::fs::write(&p, "# nothing\n").unwrap();
    assert!(SpeedFunction::read_tsv(&p).unwrap_err().contains("no data"));
}

#[test]
fn partitioning_degenerate_inputs() {
    use hclfft::coordinator::fpm::Curve;
    use hclfft::coordinator::partition::{hpopta, PartitionError};
    // single point far below N
    let c = Curve::new(vec![64], vec![100.0]);
    assert!(matches!(
        hpopta(&[c], 6400).unwrap_err(),
        PartitionError::Unreachable { n: 6400, .. }
    ));
}

#[test]
fn cli_errors_are_actionable() {
    use hclfft::cli;
    let args = cli::parse(&["run".to_string(), "--n".to_string(), "abc".to_string()]).unwrap();
    let err = args.opt_usize("n").unwrap_err();
    assert!(err.contains("--n") && err.contains("abc"), "{err}");
}
