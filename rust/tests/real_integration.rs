//! Integration tests of the real-input (r2c/c2r) path: correctness is
//! anchored to the existing complex path — the packed forward output
//! must match the c2c oracle on the real-embedded input to tight
//! tolerance, c2r ∘ r2c must round-trip, and the two properties are
//! exercised over random 5-smooth N, random FPM partitions, and both
//! pipeline modes. Plus the real-kind tile-DAG scheduler-determinism
//! regression: any worker count, same bits.

use hclfft::coordinator::engine::NativeEngine;
use hclfft::coordinator::pad::PadDecision;
use hclfft::coordinator::partition::Algorithm;
use hclfft::coordinator::real::{
    execute_real_batch_with_mode, pfft_fpm_pad_real_with_mode, pfft_fpm_real_with_mode,
};
use hclfft::coordinator::PlannedTransform;
use hclfft::dft::dft2d::dft2d_with_mode;
use hclfft::dft::fft::Direction;
use hclfft::dft::pipeline::PipelineMode;
use hclfft::dft::radix::is_five_smooth;
use hclfft::dft::real::{
    crop_to_packed, embed_real, expand_packed, half_cols, irfft2d_with_mode, rfft2d_with_mode,
    RealMatrix, TransformKind,
};
use hclfft::dft::SignalMatrix;
use hclfft::util::prng::Xoshiro256;
use hclfft::util::proptest::{run, Config};

/// c2c oracle for the packed forward transform: 2D-DFT the real
/// embedding with the barrier driver, keep the stored columns.
fn oracle_packed(m: &RealMatrix) -> SignalMatrix {
    let mut full = embed_real(m);
    dft2d_with_mode(&mut full, Direction::Forward, 2, PipelineMode::Barrier);
    crop_to_packed(&full)
}

fn rel_err(a: &SignalMatrix, b: &SignalMatrix) -> f64 {
    a.max_abs_diff(b) / b.norm().max(1.0)
}

/// Random FPM-style partition of n rows over p groups (any shape,
/// including zero-row groups).
fn random_partition(rng: &mut Xoshiro256, n: usize, p: usize) -> Vec<usize> {
    let mut d = vec![0usize; p];
    let mut left = n;
    for item in d.iter_mut().take(p - 1) {
        let take = rng.range_usize(0, left);
        *item = take;
        left -= take;
    }
    d[p - 1] = left;
    d
}

#[test]
fn rfft2d_matches_oracle_at_paper_sizes() {
    for &n in &[384usize, 640] {
        let m = RealMatrix::random(n, n, n as u64);
        let want = oracle_packed(&m);
        for mode in [PipelineMode::Fused, PipelineMode::Barrier] {
            let got = rfft2d_with_mode(&m, 4, mode);
            let err = rel_err(&got, &want);
            assert!(err < 1e-9, "n={n} {mode:?}: rel err {err}");
        }
    }
}

#[test]
fn expand_recovers_full_spectrum_non_pow2() {
    let n = 96;
    let m = RealMatrix::random(n, n, 5);
    let packed = rfft2d_with_mode(&m, 3, PipelineMode::Fused);
    let full = expand_packed(&packed);
    let mut want = embed_real(&m);
    dft2d_with_mode(&mut want, Direction::Forward, 2, PipelineMode::Barrier);
    let err = rel_err(&full, &want);
    assert!(err < 1e-9, "rel err {err}");
}

#[test]
fn prop_r2c_matches_oracle_over_smooth_sizes_partitions_and_modes() {
    // property: for random 5-smooth N, random FPM partitions d and both
    // pipeline modes, the planned real transform matches the c2c oracle
    // on the real embedding, fused == barrier bit-for-bit, and
    // c2r ∘ r2c round-trips. N capped so the O(n² log n) oracle stays
    // fast over many cases.
    let smooth: Vec<usize> = (8..=200usize).filter(|&n| is_five_smooth(n)).collect();
    let cfg = Config { cases: 24, ..Config::default() };
    run(
        "r2c-oracle-roundtrip",
        &cfg,
        |rng| {
            let n = smooth[rng.range_usize(0, smooth.len() - 1)];
            let p = rng.range_usize(1, 4);
            let d = random_partition(rng, n, p);
            let seed = rng.range_usize(0, 1 << 30) as u64;
            (n, d, seed)
        },
        |_| vec![],
        |(n, d, seed)| {
            let (n, d) = (*n, d.clone());
            let m = RealMatrix::random(n, n, *seed);
            let fused = pfft_fpm_real_with_mode(&NativeEngine, &m, &d, 2, PipelineMode::Fused)
                .map_err(|e| e.to_string())?;
            let barrier =
                pfft_fpm_real_with_mode(&NativeEngine, &m, &d, 2, PipelineMode::Barrier)
                    .map_err(|e| e.to_string())?;
            if fused.max_abs_diff(&barrier) != 0.0 {
                return Err(format!("fused != barrier bitwise (n={n}, d={d:?})"));
            }
            let want = oracle_packed(&m);
            let err = rel_err(&fused, &want);
            if err > 1e-9 {
                return Err(format!("oracle mismatch {err} (n={n}, d={d:?})"));
            }
            // round-trip: c2r of the packed spectrum recovers the signal
            for mode in [PipelineMode::Fused, PipelineMode::Barrier] {
                let back = irfft2d_with_mode(&fused, 2, mode);
                let rerr = back.max_abs_diff(&m) / m.norm().max(1.0);
                if rerr > 1e-9 {
                    return Err(format!("roundtrip err {rerr} (n={n}, {mode:?})"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_padded_r2c_matches_padded_c2c() {
    // property: with random smooth pads, the padded real row phase is
    // the same forward-only spectral interpolation as the c2c driver's
    // (compared on the stored columns), in both modes.
    let smooth: Vec<usize> = (16..=160usize).filter(|&n| is_five_smooth(n)).collect();
    let cfg = Config { cases: 12, ..Config::default() };
    run(
        "r2c-padded-oracle",
        &cfg,
        |rng| {
            let n = smooth[rng.range_usize(0, smooth.len() - 1)];
            let p = rng.range_usize(1, 3);
            let d = random_partition(rng, n, p);
            // random smooth pads >= n per group
            let pads: Vec<usize> = (0..p)
                .map(|_| {
                    let above: Vec<usize> =
                        (n..=n + 64).filter(|&v| is_five_smooth(v)).collect();
                    above[rng.range_usize(0, above.len() - 1)]
                })
                .collect();
            let seed = rng.range_usize(0, 1 << 30) as u64;
            (n, d, pads, seed)
        },
        |_| vec![],
        |(n, d, pads, seed)| {
            let (n, d) = (*n, d.clone());
            let pads: Vec<PadDecision> = pads
                .iter()
                .map(|&v| PadDecision { n_padded: v, t_unpadded: 1.0, t_padded: 0.5 })
                .collect();
            let m = RealMatrix::random(n, n, *seed);
            let fused =
                pfft_fpm_pad_real_with_mode(&NativeEngine, &m, &d, &pads, 1, PipelineMode::Fused)
                    .map_err(|e| e.to_string())?;
            let barrier = pfft_fpm_pad_real_with_mode(
                &NativeEngine,
                &m,
                &d,
                &pads,
                1,
                PipelineMode::Barrier,
            )
            .map_err(|e| e.to_string())?;
            if fused.max_abs_diff(&barrier) != 0.0 {
                return Err(format!("padded fused != barrier bitwise (n={n}, d={d:?})"));
            }
            // c2c padded oracle, cropped to the stored columns
            let mut full = embed_real(&m);
            hclfft::coordinator::pfft::pfft_fpm_pad_with_mode(
                &NativeEngine,
                &mut full,
                &d,
                &pads,
                1,
                64,
                PipelineMode::Barrier,
            )
            .map_err(|e| e.to_string())?;
            let want = crop_to_packed(&full);
            let err = rel_err(&fused, &want);
            if err > 1e-9 {
                return Err(format!("padded oracle mismatch {err} (n={n}, d={d:?})"));
            }
            Ok(())
        },
    );
}

#[test]
fn real_tile_dag_scheduler_determinism() {
    // regression: real-kind tile DAGs must produce identical bits for
    // every worker count and schedule (tiles own disjoint index sets;
    // execution order must never affect values)
    let n = 80;
    let plan = PlannedTransform {
        n,
        d: vec![50, 30],
        pads: vec![
            PadDecision { n_padded: 96, t_unpadded: 1.0, t_padded: 0.5 },
            PadDecision { n_padded: n, t_unpadded: 1.0, t_padded: 1.0 },
        ],
        algorithm: Algorithm::Hpopta,
        makespan: f64::NAN,
        kind: TransformKind::R2c,
    };
    let ms: Vec<RealMatrix> = (0..2).map(|s| RealMatrix::random(n, n, 700 + s)).collect();
    let mut reference: Option<Vec<SignalMatrix>> = None;
    for workers in [1usize, 2, 8] {
        let mut outs: Vec<SignalMatrix> =
            (0..2).map(|_| SignalMatrix::zeros(n, half_cols(n))).collect();
        {
            let srcs: Vec<&[f64]> = ms.iter().map(|m| &m.data[..]).collect();
            let mut dst_refs: Vec<&mut SignalMatrix> = outs.iter_mut().collect();
            execute_real_batch_with_mode(
                &NativeEngine,
                &plan,
                &srcs,
                &mut dst_refs,
                workers,
                PipelineMode::Fused,
            )
            .unwrap();
        }
        match &reference {
            None => reference = Some(outs),
            Some(want) => {
                for (got, want) in outs.iter().zip(want) {
                    assert_eq!(
                        got.max_abs_diff(want),
                        0.0,
                        "workers={workers} changed the bits of a real-kind tile DAG"
                    );
                }
            }
        }
    }
}

#[test]
fn odd_size_real_transform_round_trips() {
    // odd N: half_cols = (n+1)/2, a leftover unpaired row per tile
    let n = 45; // 3^2 · 5, odd and 5-smooth
    assert_eq!(half_cols(n), 23);
    let m = RealMatrix::random(n, n, 9);
    let want = oracle_packed(&m);
    for mode in [PipelineMode::Fused, PipelineMode::Barrier] {
        let got = rfft2d_with_mode(&m, 3, mode);
        let err = rel_err(&got, &want);
        assert!(err < 1e-9, "{mode:?}: rel err {err}");
        let back = irfft2d_with_mode(&got, 3, mode);
        let rerr = back.max_abs_diff(&m) / m.norm().max(1.0);
        assert!(rerr < 1e-9, "{mode:?}: roundtrip err {rerr}");
    }
}
