//! Integration: the mixed-radix executor end to end — correctness vs the
//! naive DFT oracle and Bluestein at the paper's N = 128·k sizes,
//! inverse round-trips, thread-count invariance through the shared pool,
//! and the small-rows/large-n utilization regression.

use hclfft::coordinator::engine::{NativeEngine, RowFftEngine};
use hclfft::dft::bluestein::{fft_row_bluestein, BluesteinPlan};
use hclfft::dft::exec::{fft_rows_pooled, work_units, ExecCtx, STAGE_PARALLEL_MIN_N};
use hclfft::dft::fft::Direction;
use hclfft::dft::radix::{
    factorize_235, fft_row_radix, fft_rows_radix, fft_rows_radix_tiled, fma_active,
    is_five_smooth, KernelVariant, RadixPlan,
};
use hclfft::dft::{naive_dft_rows, SignalMatrix};
use hclfft::util::proptest::{run, Config};

/// The paper's benchmark lengths exercised throughout this file:
/// 384 = 2^7·3, 640 = 2^7·5, 768 = 2^8·3, 1152 = 2^7·3^2, 3200 = 25·128.
const PAPER_SIZES: [usize; 5] = [384, 640, 768, 1152, 3200];

#[test]
fn paper_sizes_are_five_smooth() {
    for &n in &PAPER_SIZES {
        let f = factorize_235(n).expect("paper size must be 5-smooth");
        assert_eq!(f.iter().product::<usize>(), n);
    }
    assert!(!is_five_smooth(24_704), "24704 = 128·193 stays on Bluestein");
}

#[test]
fn mixed_radix_matches_naive_at_paper_sizes() {
    for &n in &PAPER_SIZES {
        let rows = if n >= 3200 { 1 } else { 2 };
        let orig = SignalMatrix::random(rows, n, n as u64);
        let mut m = orig.clone();
        fft_rows_radix(&mut m.re, &mut m.im, rows, n, Direction::Forward);
        let want = naive_dft_rows(&orig, false);
        let scale = want.norm().max(1.0);
        let err = m.max_abs_diff(&want) / scale;
        assert!(err < 1e-9, "n={n}: rel err {err}");
    }
}

#[test]
fn mixed_radix_cross_checks_bluestein_at_paper_sizes() {
    // two independent algorithms agreeing at every paper size
    for &n in &PAPER_SIZES {
        let orig = SignalMatrix::random(1, n, 7 * n as u64 + 1);
        let mut radix = orig.clone();
        fft_rows_radix(&mut radix.re, &mut radix.im, 1, n, Direction::Forward);
        let plan = BluesteinPlan::new(n);
        let ml = plan.scratch_len();
        let (mut br, mut bi) = (vec![0.0; ml], vec![0.0; ml]);
        let (mut sr, mut si) = (vec![0.0; ml], vec![0.0; ml]);
        let mut blue = orig.clone();
        fft_row_bluestein(
            &mut blue.re,
            &mut blue.im,
            &plan,
            Direction::Forward,
            &mut br,
            &mut bi,
            &mut sr,
            &mut si,
        );
        let scale = blue.norm().max(1.0);
        let err = radix.max_abs_diff(&blue) / scale;
        assert!(err < 1e-9, "n={n}: radix vs bluestein rel err {err}");
    }
}

#[test]
fn inverse_round_trips_at_paper_sizes() {
    for &n in &PAPER_SIZES {
        let orig = SignalMatrix::random(1, n, 3);
        let mut m = orig.clone();
        fft_rows_radix(&mut m.re, &mut m.im, 1, n, Direction::Forward);
        fft_rows_radix(&mut m.re, &mut m.im, 1, n, Direction::Inverse);
        let err = m.max_abs_diff(&orig);
        assert!(err < 1e-9, "n={n}: roundtrip err {err}");
    }
}

#[test]
fn prop_mixed_radix_matches_naive_on_random_smooth_lengths() {
    // property: for random 5-smooth lengths the kernel agrees with the
    // O(n^2) oracle (the pool of all smooth lengths <= 1280 keeps the
    // oracle affordable)
    let smooth: Vec<usize> = (1..=1280usize).filter(|&n| is_five_smooth(n)).collect();
    run(
        "radix-vs-naive",
        &Config { cases: 25, ..Config::default() },
        |rng| smooth[rng.range_usize(0, smooth.len() - 1)],
        |_| vec![],
        |&n| {
            let m = SignalMatrix::random(1, n, n as u64 + 13);
            let mut got = m.clone();
            fft_rows_radix(&mut got.re, &mut got.im, 1, n, Direction::Forward);
            let want = naive_dft_rows(&m, false);
            let scale = want.norm().max(1.0);
            let err = got.max_abs_diff(&want) / scale;
            if err < 1e-9 {
                Ok(())
            } else {
                Err(format!("n={n}: rel err {err}"))
            }
        },
    );
}

#[test]
fn pool_thread_count_invariance_is_bitwise() {
    // the executor must produce identical bits for every thread budget
    let ctx = ExecCtx::new(6);
    for &n in &[384usize, 640, 1152] {
        let rows = 12;
        let orig = SignalMatrix::random(rows, n, 99);
        let mut reference = orig.clone();
        fft_rows_pooled(&ctx, &mut reference.re, &mut reference.im, rows, n, Direction::Forward, 1);
        for threads in [2usize, 3, 5, 8, 16] {
            let mut m = orig.clone();
            fft_rows_pooled(&ctx, &mut m.re, &mut m.im, rows, n, Direction::Forward, threads);
            assert_eq!(
                m.max_abs_diff(&reference),
                0.0,
                "n={n} threads={threads}: must be bit-exact vs serial"
            );
        }
    }
}

#[test]
fn native_engine_bit_exact_across_thread_budgets() {
    let engine = NativeEngine;
    let orig = SignalMatrix::random(33, 384, 5);
    let mut a = orig.clone();
    engine.fft_rows(&mut a.re, &mut a.im, 33, 384, Direction::Forward, 1).unwrap();
    for t in [2usize, 7] {
        let mut b = orig.clone();
        engine.fft_rows(&mut b.re, &mut b.im, 33, 384, Direction::Forward, t).unwrap();
        assert_eq!(a.max_abs_diff(&b), 0.0, "threads={t}");
    }
}

#[test]
fn small_rows_large_n_regression() {
    // rows < threads with long smooth rows: the old code clamped the
    // thread budget to the row count; the executor now splits stages
    // within each row. Values must be bit-identical either way.
    let n = STAGE_PARALLEL_MIN_N * 2; // 8192
    assert_eq!(work_units(3, n, 8), 8, "must fan out past the row count");
    let ctx = ExecCtx::new(8);
    let orig = SignalMatrix::random(3, n, 17);
    let mut serial = orig.clone();
    fft_rows_pooled(&ctx, &mut serial.re, &mut serial.im, 3, n, Direction::Forward, 1);
    let mut wide = orig.clone();
    fft_rows_pooled(&ctx, &mut wide.re, &mut wide.im, 3, n, Direction::Forward, 8);
    assert_eq!(serial.max_abs_diff(&wide), 0.0);
    // and the stage-split path is actually correct, not just stable
    let mut back = wide.clone();
    fft_rows_pooled(&ctx, &mut back.re, &mut back.im, 3, n, Direction::Inverse, 8);
    assert!(back.max_abs_diff(&orig) < 1e-10);
}

/// Transform one row with an explicit kernel variant (fresh plan and
/// scratch — this is the reference harness, not the hot path).
fn run_variant(m: &SignalMatrix, variant: KernelVariant, dir: Direction) -> SignalMatrix {
    let n = m.cols;
    let plan = RadixPlan::with_variant(n, variant);
    let mut out = m.clone();
    let (mut sr, mut si) = (vec![0.0; n], vec![0.0; n]);
    fft_row_radix(&mut out.re, &mut out.im, &mut sr, &mut si, &plan, dir);
    out
}

#[test]
fn prop_scalar_and_vectorized_kernels_agree() {
    // property: on random 5-smooth lengths the Scalar (pre-codelet)
    // and Vectorized (codelet + optional AVX2/FMA) kernels agree within
    // 1e-12 relative error, both stay inside the naive-DFT oracle
    // band, and the vectorized inverse round-trips. The 1e-12 band is
    // what the FMA generation is held to (its contracted roundings
    // preclude bit-equality with the scalar reference); the plain AVX2
    // generation is additionally pinned bit-identical to the scalar
    // loops by the unit tests in `dft::radix`.
    let smooth: Vec<usize> = (2..=1280usize).filter(|&n| is_five_smooth(n)).collect();
    run(
        "scalar-vs-vectorized-kernels",
        &Config { cases: 40, ..Config::default() },
        |rng| smooth[rng.range_usize(0, smooth.len() - 1)],
        |_| vec![],
        |&n| {
            let m = SignalMatrix::random(1, n, 31 * n as u64 + 7);
            let scalar = run_variant(&m, KernelVariant::Scalar, Direction::Forward);
            let vectorized = run_variant(&m, KernelVariant::Vectorized, Direction::Forward);
            let want = naive_dft_rows(&m, false);
            let scale = want.norm().max(1.0);
            let cross = scalar.max_abs_diff(&vectorized) / scale;
            if cross >= 1e-12 {
                return Err(format!("n={n}: scalar vs vectorized rel err {cross}"));
            }
            for (label, got) in [("scalar", &scalar), ("vectorized", &vectorized)] {
                let err = got.max_abs_diff(&want) / scale;
                if err >= 1e-9 {
                    return Err(format!("n={n}: {label} vs naive rel err {err}"));
                }
            }
            let back = run_variant(&vectorized, KernelVariant::Vectorized, Direction::Inverse);
            let rt = back.max_abs_diff(&m);
            if rt >= 1e-9 {
                return Err(format!("n={n}: vectorized roundtrip err {rt}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_multirow_tiling_is_bitwise_identical_to_per_row() {
    // property: the stage-major multi-row tile driver and the pooled
    // executor (which tiles with the model-preferred width inside each
    // worker chunk) produce bit-identical results to the per-row serial
    // kernel, over random 5-smooth n, row counts, and thread budgets —
    // in every kernel generation, FMA included (tiling reorders loops,
    // never arithmetic)
    let smooth: Vec<usize> = (2..=960usize).filter(|&n| is_five_smooth(n)).collect();
    let ctx = ExecCtx::new(4);
    run(
        "multirow-tiling-bitwise",
        &Config { cases: 25, ..Config::default() },
        |rng| {
            let n = smooth[rng.range_usize(0, smooth.len() - 1)];
            (n, rng.range_usize(1, 6), rng.range_usize(1, 8))
        },
        |_| vec![],
        |&(n, rows, threads)| {
            let m = SignalMatrix::random(rows, n, (n * rows) as u64 + 29);
            let plan = RadixPlan::new(n);
            // reference: one row at a time through the serial driver
            let mut per_row = m.clone();
            let (mut sr, mut si) = (vec![0.0; n], vec![0.0; n]);
            for r in 0..rows {
                let span = r * n..(r + 1) * n;
                fft_row_radix(
                    &mut per_row.re[span.clone()],
                    &mut per_row.im[span],
                    &mut sr,
                    &mut si,
                    &plan,
                    Direction::Forward,
                );
            }
            // one stage-major tile over the whole batch
            let mut tiled = m.clone();
            let (mut tr, mut ti) = (vec![0.0; rows * n], vec![0.0; rows * n]);
            fft_rows_radix_tiled(
                &mut tiled.re,
                &mut tiled.im,
                rows,
                &mut tr,
                &mut ti,
                &plan,
                Direction::Forward,
            );
            if tiled.max_abs_diff(&per_row) != 0.0 {
                return Err(format!("n={n} rows={rows}: tiled differs from per-row"));
            }
            // the pooled executor's model-chosen tiling
            let mut pooled = m.clone();
            fft_rows_pooled(&ctx, &mut pooled.re, &mut pooled.im, rows, n, Direction::Forward, threads);
            if pooled.max_abs_diff(&per_row) != 0.0 {
                return Err(format!("n={n} rows={rows} threads={threads}: pooled differs"));
            }
            Ok(())
        },
    );
}

#[test]
fn fma_generation_matches_scalar_reference_at_paper_sizes() {
    // dedicated FMA-generation accuracy pin at the paper's bench sizes
    // (the random-length proptest above covers the long tail): the
    // Vectorized kernel — the FMA generation when active — stays within
    // 1e-12 relative of the Scalar reference in both directions. With
    // FMA inactive the bound is trivially met (plain kernels are
    // bit-identical to their scalar loops).
    for &n in &[384usize, 640, 1152] {
        let m = SignalMatrix::random(1, n, 71 * n as u64 + 3);
        for dir in [Direction::Forward, Direction::Inverse] {
            let scalar = run_variant(&m, KernelVariant::Scalar, dir);
            let vectorized = run_variant(&m, KernelVariant::Vectorized, dir);
            let scale = scalar.norm().max(1.0);
            let rel = scalar.max_abs_diff(&vectorized) / scale;
            assert!(
                rel < 1e-12,
                "n={n} {dir:?} (fma_active={}): rel err {rel}",
                fma_active()
            );
        }
    }
}

#[test]
fn pooled_split_row_is_bit_exact_with_codelet_tail() {
    // a single long 5-smooth row (>= STAGE_PARALLEL_MIN_N) takes the
    // split-stage path, which now finishes through the fused tail
    // codelet: every thread budget must produce identical bits, and
    // the result must still invert
    let n = 4320; // 2^5·3^3·5 — all three radixes plus an fft8 tail
    assert!(n >= STAGE_PARALLEL_MIN_N && is_five_smooth(n));
    let ctx = ExecCtx::new(8);
    let orig = SignalMatrix::random(2, n, 23);
    let mut serial = orig.clone();
    fft_rows_pooled(&ctx, &mut serial.re, &mut serial.im, 2, n, Direction::Forward, 1);
    for threads in [3usize, 8] {
        let mut m = orig.clone();
        fft_rows_pooled(&ctx, &mut m.re, &mut m.im, 2, n, Direction::Forward, threads);
        assert_eq!(serial.max_abs_diff(&m), 0.0, "threads={threads}: must be bit-exact");
    }
    let mut back = serial.clone();
    fft_rows_pooled(&ctx, &mut back.re, &mut back.im, 2, n, Direction::Inverse, 8);
    assert!(back.max_abs_diff(&orig) < 1e-9);
}

#[test]
fn dft2d_non_pow2_matches_naive() {
    // full 2D driver over the executor at a 5-smooth non-pow2 size
    let n = 48; // 2^4·3
    let orig = SignalMatrix::random(n, n, 8);
    let mut m = orig.clone();
    hclfft::dft::dft2d::dft2d(&mut m, Direction::Forward, 4);
    let want = hclfft::dft::naive_dft2d(&orig);
    let scale = want.norm().max(1.0);
    assert!(m.max_abs_diff(&want) / scale < 1e-10);
}
