//! Fused-pipeline integration tests: the tile-granular stage-DAG
//! execution path must be **bit-exact** against the barrier four-step
//! path for every (N, d, pad) — both run the same per-row kernel over
//! the same logical vectors — and numerically correct against the naive
//! O(N³) oracle. Plus the tile-scheduler determinism regression: the
//! bits must not depend on worker count or scheduling order.

use hclfft::coordinator::engine::NativeEngine;
use hclfft::coordinator::pad::PadDecision;
use hclfft::coordinator::pfft::{pfft_fpm_pad_with_mode, pfft_fpm_with_mode};
use hclfft::coordinator::ExecPipeline;
use hclfft::dft::dft2d::dft2d_with_mode;
use hclfft::dft::fft::Direction;
use hclfft::dft::pipeline::PipelineMode;
use hclfft::dft::radix::is_five_smooth;
use hclfft::dft::{naive_dft2d, SignalMatrix};
use hclfft::util::proptest::{run, Config};
use hclfft::util::prng::Xoshiro256;

/// Smallest 5-smooth length ≥ x (pad candidates for random cases).
fn next_smooth(mut x: usize) -> usize {
    x = x.max(1);
    while !is_five_smooth(x) {
        x += 1;
    }
    x
}

/// One random pipeline case: a 5-smooth N, an FPM row partition d
/// (imbalanced, zero groups allowed), and per-group pad lengths.
#[derive(Clone, Debug)]
struct PipelineCase {
    n: usize,
    d: Vec<usize>,
    pads: Vec<usize>,
    seed: u64,
}

fn gen_case(rng: &mut Xoshiro256) -> PipelineCase {
    // random 5-smooth N in [8, 120] (the naive oracle is O(N³))
    let n = next_smooth(rng.range_usize(8, 120));
    let p = rng.range_usize(1, 4);
    // random composition of n into p parts (zeros allowed)
    let mut d = vec![0usize; p];
    let mut left = n;
    for part in d.iter_mut().take(p - 1) {
        *part = rng.range_usize(0, left);
        left -= *part;
    }
    d[p - 1] = left;
    // each group pads with probability ~1/2 (to a nearby smooth length)
    let pads: Vec<usize> = (0..p)
        .map(|_| {
            if rng.range_usize(0, 1) == 0 {
                n
            } else {
                next_smooth(n + rng.range_usize(1, n / 2 + 1))
            }
        })
        .collect();
    PipelineCase { n, d, pads, seed: rng.next_u64() }
}

#[test]
fn prop_fused_bit_exact_vs_barrier_and_correct() {
    run(
        "fused == barrier == naive over random (N, d, pad)",
        &Config::default(),
        gen_case,
        |_| Vec::new(),
        |case| {
            let orig = SignalMatrix::random(case.n, case.n, case.seed);
            let pads: Vec<PadDecision> = case
                .pads
                .iter()
                .map(|&v| PadDecision { n_padded: v, t_unpadded: 1.0, t_padded: 1.0 })
                .collect();
            let mut fused = orig.clone();
            let mut barrier = orig.clone();
            pfft_fpm_pad_with_mode(
                &NativeEngine,
                &mut fused,
                &case.d,
                &pads,
                2,
                64,
                PipelineMode::Fused,
            )
            .map_err(|e| e.to_string())?;
            pfft_fpm_pad_with_mode(
                &NativeEngine,
                &mut barrier,
                &case.d,
                &pads,
                2,
                64,
                PipelineMode::Barrier,
            )
            .map_err(|e| e.to_string())?;
            if fused.max_abs_diff(&barrier) != 0.0 {
                return Err(format!(
                    "fused differs from barrier by {}",
                    fused.max_abs_diff(&barrier)
                ));
            }
            // padding is spectral interpolation at the pad length, so
            // the padded result is NOT the exact N-point DFT; only the
            // all-unpadded case compares against the oracle
            if case.pads.iter().all(|&v| v == case.n) {
                let want = naive_dft2d(&orig);
                let err = fused.max_abs_diff(&want) / want.norm().max(1.0);
                if err > 1e-9 {
                    return Err(format!("rel err vs naive {err}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_unpadded_fused_matches_naive() {
    // dedicated unpadded property: every partition shape must hit the
    // oracle (the mixed case above only checks it opportunistically)
    run(
        "unpadded fused == naive over random (N, d)",
        &Config { cases: 32, ..Config::default() },
        gen_case,
        |_| Vec::new(),
        |case| {
            let orig = SignalMatrix::random(case.n, case.n, case.seed ^ 1);
            let mut fused = orig.clone();
            pfft_fpm_with_mode(&NativeEngine, &mut fused, &case.d, 1, 64, PipelineMode::Fused)
                .map_err(|e| e.to_string())?;
            let want = naive_dft2d(&orig);
            let err = fused.max_abs_diff(&want) / want.norm().max(1.0);
            if err > 1e-9 {
                return Err(format!("rel err vs naive {err}"));
            }
            Ok(())
        },
    );
}

#[test]
fn tile_scheduler_determinism_regression() {
    // same pipeline, same input, any worker count, repeated runs: the
    // output bits must be identical — tile tasks own disjoint index
    // sets, so scheduling order must never leak into the values
    let n = 160; // 2^5·5, three groups, group 1 padded
    let pipe = ExecPipeline::compile(n, &[96, 40, 24], Some(&[n, 192, n][..]));
    let orig = SignalMatrix::random(n, n, 4242);
    let mut reference: Option<SignalMatrix> = None;
    for workers in [1usize, 2, 3, 8] {
        for rep in 0..3 {
            let mut m = orig.clone();
            pipe.execute_batch(&NativeEngine, &mut [&mut m], workers).unwrap();
            match &reference {
                None => reference = Some(m),
                Some(want) => assert_eq!(
                    m.max_abs_diff(want),
                    0.0,
                    "workers={workers} rep={rep} changed the output bits"
                ),
            }
        }
    }
}

#[test]
fn fused_dft2d_inverse_roundtrip_and_barrier_parity() {
    // the service's inverse path runs dft2d under the same mode; both
    // directions must agree with the barrier path bit-for-bit
    for &n in &[60usize, 77] {
        // 77 = 7·11: Bluestein columns through the fused gather
        let orig = SignalMatrix::random(n, n, n as u64);
        let mut fused = orig.clone();
        dft2d_with_mode(&mut fused, Direction::Forward, 3, PipelineMode::Fused);
        let mut barrier = orig.clone();
        dft2d_with_mode(&mut barrier, Direction::Forward, 3, PipelineMode::Barrier);
        assert_eq!(fused.max_abs_diff(&barrier), 0.0, "n={n} forward");
        dft2d_with_mode(&mut fused, Direction::Inverse, 3, PipelineMode::Fused);
        let err = fused.max_abs_diff(&orig) / orig.norm().max(1.0);
        assert!(err < 1e-9, "n={n} roundtrip rel err {err}");
    }
}
