//! Integration: the partitioning pipeline (Step 1 of PFFT-FPM) over the
//! simulated testbed — ε-identity test, POPTA/HPOPTA selection, and the
//! paper's running example.

use hclfft::coordinator::fpm::Curve;
use hclfft::coordinator::partition::{
    average_curve, balanced, brute_force, curves_identical, hpopta, predict_makespan,
};
use hclfft::simulator::fpm::SimTestbed;
use hclfft::simulator::Package;

#[test]
fn paper_example_n24704_is_imbalanced_and_better_than_balanced() {
    let tb = SimTestbed::paper_best(Package::Mkl);
    let curves = tb.plane_sections(24_704);
    assert!(!curves_identical(&curves, 0.05), "paper example is heterogeneous");
    let part = hpopta(&curves, 24_704).unwrap();
    assert_eq!(part.d.iter().sum::<usize>(), 24_704);
    // deliberately imbalanced (like the paper's (11648, 13056))
    assert_ne!(part.d[0], part.d[1], "expected load imbalance: {:?}", part.d);
    let bal = predict_makespan(&curves, &balanced(2, 24_704).d);
    assert!(part.makespan <= bal + 1e-12, "opt {} > balanced {bal}", part.makespan);
}

#[test]
fn hpopta_never_worse_than_balanced_across_sizes() {
    let tb = SimTestbed::paper_best(Package::Fftw3);
    // sizes divisible by p*128 so the balanced split lies on the FPM grid
    // (off-grid balanced splits would be priced by nearest-point speeds,
    // making the comparison meaningless)
    for n in [1_536usize, 5_120, 12_800, 25_600, 33_280] {
        let curves = tb.plane_sections(n);
        let part = hpopta(&curves, n - n % 128).unwrap();
        let bal = predict_makespan(&curves, &balanced(curves.len(), n - n % 128).d);
        assert!(
            part.makespan <= bal + 1e-12,
            "n={n}: hpopta {} vs balanced {bal}",
            part.makespan
        );
    }
}

#[test]
fn hpopta_optimal_vs_brute_force_on_simulated_sections() {
    // decimate the real sections to a brute-forceable grid and cross-check
    let tb = SimTestbed::paper_best(Package::Mkl);
    let full = tb.plane_sections(2_048);
    let small: Vec<Curve> = full
        .iter()
        .map(|c| {
            let xs: Vec<usize> = c.xs.iter().copied().take(4).collect();
            let speeds: Vec<f64> = c.speeds.iter().copied().take(4).collect();
            Curve::new(xs, speeds)
        })
        .collect();
    let n = 768; // reachable: e.g. 256 + 512 on the {128..512} grid
    let (bf_d, bf_m) = brute_force(&small, n).expect("feasible");
    let part = hpopta(&small, n).unwrap();
    assert!(
        (part.makespan - bf_m).abs() < 1e-9,
        "hpopta {} (d {:?}) vs brute {} (d {:?})",
        part.makespan,
        part.d,
        bf_m,
        bf_d
    );
}

#[test]
fn averaging_collapses_homogeneous_groups() {
    // force-identical curves: average equals each curve
    let c = Curve::new(vec![128, 256, 384], vec![100.0, 200.0, 150.0]);
    let avg = average_curve(&[c.clone(), c.clone(), c.clone()]);
    for (k, &x) in c.xs.iter().enumerate() {
        assert!((avg.speed_at(x).unwrap() - c.speeds[k]).abs() < 1e-9);
    }
    assert!(curves_identical(&[c.clone(), c], 0.0));
}

#[test]
fn plane_sections_memory_cap_respected_at_large_n() {
    let tb = SimTestbed::paper_best(Package::Fftw3);
    let curves = tb.plane_sections(44_864);
    for c in &curves {
        let max_x = *c.xs.last().unwrap();
        assert!(
            (max_x as u128) * 44_864 <= hclfft::simulator::fpm::MEM_CAP_XY,
            "memory cap violated: x={max_x}"
        );
    }
    // partitioning still succeeds with the capped grid (sum reachable
    // because p * max_x >= n)
    let part = hpopta(&curves, 44_800).unwrap();
    assert_eq!(part.d.iter().sum::<usize>(), 44_800);
}
