//! Steady-state allocation audit for the executor hot path.
//!
//! This file intentionally holds a SINGLE test so the process-global
//! counting allocator and the scratch-grow counter see no concurrent
//! noise from sibling tests (each integration-test file is its own
//! binary; tests *within* a binary run in parallel threads).
//!
//! The assertion backing the "no per-call scratch allocations" claim:
//! after a warmup pass, repeated row-FFT batches at a fixed size must
//! (a) never grow a scratch arena and (b) allocate only O(1) bytes per
//! call (job boxes and queue nodes — not the O(n) `vec![0.0; n]`
//! buffers the pre-executor code allocated per call). The bench note
//! lives in `benches/bench_fft_sizes.rs` / README §Architecture.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};

struct CountingAlloc;

static ALLOCATED_BYTES: AtomicUsize = AtomicUsize::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCATED_BYTES.fetch_add(layout.size(), Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCATED_BYTES.fetch_add(new_size.saturating_sub(layout.size()), Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

use hclfft::dft::exec::{fft_rows_pooled, scratch_grow_events, ExecCtx};
use hclfft::dft::fft::Direction;
use hclfft::dft::SignalMatrix;

#[test]
fn warm_fft_loop_does_not_allocate_scratch() {
    // pin the pool size before first ExecCtx::global() use so the set of
    // threads that can own arenas is small and the budget deterministic
    std::env::set_var("HCLFFT_POOL_THREADS", "4");
    let (rows, n) = (32usize, 768usize); // 768 = 2^8·3 — mixed-radix path
    let ctx = ExecCtx::global();
    let threads = 4usize;
    let mut m = SignalMatrix::random(rows, n, 1);

    // warmup: builds the plan, spawns the pool, and keeps iterating
    // until a full pass grows no arena (chunk→worker assignment varies,
    // so a fixed warmup count could leave a worker's arena cold)
    let mut warm_iters = 0;
    loop {
        let before = scratch_grow_events();
        fft_rows_pooled(ctx, &mut m.re, &mut m.im, rows, n, Direction::Forward, threads);
        warm_iters += 1;
        if scratch_grow_events() == before && warm_iters >= 5 {
            break;
        }
        assert!(warm_iters < 500, "arenas never reached steady state");
    }

    let grow_before = scratch_grow_events();
    let bytes_before = ALLOCATED_BYTES.load(Ordering::Relaxed);
    let iters = 50usize;
    for _ in 0..iters {
        fft_rows_pooled(ctx, &mut m.re, &mut m.im, rows, n, Direction::Forward, threads);
    }
    let grow_delta = scratch_grow_events() - grow_before;
    let bytes_delta = ALLOCATED_BYTES.load(Ordering::Relaxed) - bytes_before;

    // a not-yet-exercised thread may still warm its arena once (2 planes)
    // — but steady-state growth is bounded by the thread population, not
    // by the iteration count (per-call growth would be >= 2·iters)
    assert!(
        grow_delta <= 2 * (4 + 1),
        "scratch arenas grew {grow_delta} times over {iters} warm iterations"
    );

    // per-iteration allocation budget: job boxes + queue bookkeeping are
    // fine (a few hundred bytes); per-call O(n) scratch planes are not.
    // The old code allocated 2 Vec<f64> of n=768 per chunk per call
    // (~49 KiB/iter at 4 chunks); the bound sits far below that.
    let per_iter = bytes_delta / iters;
    assert!(
        per_iter < 8 * 1024,
        "steady-state allocates {per_iter} B/iter (total {bytes_delta} B over {iters})"
    );

    // sanity: the warm executor still computes correct transforms
    let orig = SignalMatrix::random(rows, n, 2);
    let mut rt = orig.clone();
    fft_rows_pooled(ctx, &mut rt.re, &mut rt.im, rows, n, Direction::Forward, threads);
    fft_rows_pooled(ctx, &mut rt.re, &mut rt.im, rows, n, Direction::Inverse, threads);
    let err = rt.max_abs_diff(&orig);
    assert!(err < 1e-9, "warm roundtrip err {err}");
}
