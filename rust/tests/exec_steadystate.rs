//! Steady-state allocation audit for the executor hot path.
//!
//! This file intentionally holds a SINGLE test so the process-global
//! counting allocator and the scratch-grow counter see no concurrent
//! noise from sibling tests (each integration-test file is its own
//! binary; tests *within* a binary run in parallel threads).
//!
//! The assertion backing the "no per-call scratch allocations" claim:
//! after a warmup pass, repeated row-FFT batches at a fixed size must
//! (a) never grow a scratch arena and (b) allocate only O(1) bytes per
//! call (job boxes and queue nodes — not the O(n) `vec![0.0; n]`
//! buffers the pre-executor code allocated per call). The bench note
//! lives in `benches/bench_fft_sizes.rs` / README §Architecture.
//!
//! The same audit covers the fused tile pipeline over a *padded batch*
//! (the serving hot path): steady-state pipeline runs may allocate the
//! small per-run DAG bookkeeping (task boxes, edge lists), but never a
//! tile scratch plane — pads are stride choices inside reused arenas.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};

struct CountingAlloc;

static ALLOCATED_BYTES: AtomicUsize = AtomicUsize::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCATED_BYTES.fetch_add(layout.size(), Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCATED_BYTES.fetch_add(new_size.saturating_sub(layout.size()), Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

use hclfft::dft::exec::{fft_rows_pooled, scratch_grow_events, ExecCtx};
use hclfft::dft::fft::Direction;
use hclfft::dft::SignalMatrix;

#[test]
fn warm_fft_loop_does_not_allocate_scratch() {
    // pin the pool size before first ExecCtx::global() use so the set of
    // threads that can own arenas is small and the budget deterministic
    std::env::set_var("HCLFFT_POOL_THREADS", "4");
    let (rows, n) = (32usize, 768usize); // 768 = 2^8·3 — mixed-radix path
    let ctx = ExecCtx::global();
    let threads = 4usize;
    let mut m = SignalMatrix::random(rows, n, 1);

    // warmup: builds the plan, spawns the pool, and keeps iterating
    // until a full pass grows no arena (chunk→worker assignment varies,
    // so a fixed warmup count could leave a worker's arena cold)
    let mut warm_iters = 0;
    loop {
        let before = scratch_grow_events();
        fft_rows_pooled(ctx, &mut m.re, &mut m.im, rows, n, Direction::Forward, threads);
        warm_iters += 1;
        if scratch_grow_events() == before && warm_iters >= 5 {
            break;
        }
        assert!(warm_iters < 500, "arenas never reached steady state");
    }

    let grow_before = scratch_grow_events();
    let bytes_before = ALLOCATED_BYTES.load(Ordering::Relaxed);
    let iters = 50usize;
    for _ in 0..iters {
        fft_rows_pooled(ctx, &mut m.re, &mut m.im, rows, n, Direction::Forward, threads);
    }
    let grow_delta = scratch_grow_events() - grow_before;
    let bytes_delta = ALLOCATED_BYTES.load(Ordering::Relaxed) - bytes_before;

    // a not-yet-exercised thread may still warm its arena once (2 planes)
    // — but steady-state growth is bounded by the thread population, not
    // by the iteration count (per-call growth would be >= 2·iters)
    assert!(
        grow_delta <= 2 * (4 + 1),
        "scratch arenas grew {grow_delta} times over {iters} warm iterations"
    );

    // per-iteration allocation budget: job boxes + queue bookkeeping are
    // fine (a few hundred bytes); per-call O(n) scratch planes are not.
    // The old code allocated 2 Vec<f64> of n=768 per chunk per call
    // (~49 KiB/iter at 4 chunks); the bound sits far below that.
    let per_iter = bytes_delta / iters;
    assert!(
        per_iter < 8 * 1024,
        "steady-state allocates {per_iter} B/iter (total {bytes_delta} B over {iters})"
    );

    // sanity: the warm executor still computes correct transforms
    let orig = SignalMatrix::random(rows, n, 2);
    let mut rt = orig.clone();
    fft_rows_pooled(ctx, &mut rt.re, &mut rt.im, rows, n, Direction::Forward, threads);
    fft_rows_pooled(ctx, &mut rt.re, &mut rt.im, rows, n, Direction::Inverse, threads);
    let err = rt.max_abs_diff(&orig);
    assert!(err < 1e-9, "warm roundtrip err {err}");

    // ----- fused pipeline + padded batch (the serving hot path) -----
    use hclfft::coordinator::engine::NativeEngine;
    use hclfft::coordinator::pad::PadDecision;
    use hclfft::coordinator::partition::Algorithm;
    use hclfft::coordinator::PlannedTransform;
    use hclfft::dft::pipeline::PipelineMode;
    use hclfft::service::batch::execute_planned_batch_with_mode;

    let pn = 384usize; // 2^7·3 — mixed-radix rows and columns
    let plan = PlannedTransform {
        n: pn,
        d: vec![256, 128],
        pads: vec![
            PadDecision { n_padded: pn, t_unpadded: 0.0, t_padded: 0.0 },
            // group 1 pads: the stride path must stay allocation-free
            PadDecision { n_padded: 480, t_unpadded: 1.0, t_padded: 0.5 },
        ],
        algorithm: Algorithm::Hpopta,
        makespan: f64::NAN,
        kind: hclfft::dft::real::TransformKind::C2c,
    };
    assert!(plan.is_padded(), "audit must exercise the padded tile path");
    let mut batch: Vec<SignalMatrix> =
        (0..2).map(|s| SignalMatrix::random(pn, pn, 100 + s)).collect();
    let run_pipeline = |batch: &mut Vec<SignalMatrix>| {
        let mut refs: Vec<&mut SignalMatrix> = batch.iter_mut().collect();
        execute_planned_batch_with_mode(
            &NativeEngine,
            &plan,
            &mut refs,
            2,
            64,
            PipelineMode::Fused,
        )
        .unwrap();
    };

    // warmup until a full pipeline pass grows no arena (tile→worker
    // assignment varies run to run, so iterate rather than count)
    let mut warm_iters = 0;
    loop {
        let before = scratch_grow_events();
        run_pipeline(&mut batch);
        warm_iters += 1;
        if scratch_grow_events() == before && warm_iters >= 5 {
            break;
        }
        assert!(warm_iters < 500, "pipeline arenas never reached steady state");
    }

    let grow_before = scratch_grow_events();
    let bytes_before = ALLOCATED_BYTES.load(Ordering::Relaxed);
    let iters = 20usize;
    for _ in 0..iters {
        run_pipeline(&mut batch);
    }
    let grow_delta = scratch_grow_events() - grow_before;
    let bytes_delta = ALLOCATED_BYTES.load(Ordering::Relaxed) - bytes_before;

    // a late-touched thread may still warm its arenas once — tile tasks
    // lease a gather arena plus a nested kernel arena (2 planes each),
    // so the bound is 4 planes per thread in the population, never a
    // function of the iteration count (per-call growth would be ≥ iters)
    assert!(
        grow_delta <= 4 * (4 + 1),
        "pipeline scratch arenas grew {grow_delta} times over {iters} warm iterations"
    );

    // per-iteration budget: DAG bookkeeping (task boxes, edge lists,
    // ready queue) is O(tiles) small allocations — fine. A single
    // leaked tile scratch plane would cost ≥ 32·480·8 ≈ 120 KiB per
    // plane pair, and the old gather path copied whole (B·rows × pad)
    // work matrices: the bound sits far below either.
    let per_iter = bytes_delta / iters;
    assert!(
        per_iter < 96 * 1024,
        "pipeline steady state allocates {per_iter} B/iter (total {bytes_delta} B over {iters})"
    );

    // sanity: the warm pipeline still computes the right transform
    let orig = SignalMatrix::random(pn, pn, 7);
    let mut fused = orig.clone();
    let mut barrier = orig.clone();
    {
        let mut refs: Vec<&mut SignalMatrix> = vec![&mut fused];
        execute_planned_batch_with_mode(&NativeEngine, &plan, &mut refs, 2, 64, PipelineMode::Fused)
            .unwrap();
    }
    {
        let mut refs: Vec<&mut SignalMatrix> = vec![&mut barrier];
        execute_planned_batch_with_mode(
            &NativeEngine,
            &plan,
            &mut refs,
            2,
            64,
            PipelineMode::Barrier,
        )
        .unwrap();
    }
    assert_eq!(fused.max_abs_diff(&barrier), 0.0, "warm fused pipeline must stay bit-exact");

    // ----- mixed c2c/r2c padded batch (the kind-diverse serving mix) -----
    // A warm serve loop alternating c2c and r2c padded batches must
    // allocate no packed plane and grow no scratch arena: pair-packed
    // row tiles, strided column tiles and the c2c tile paths all lease
    // from the same per-thread arenas, and the r2c outputs are written
    // into caller-owned (preallocated) packed matrices.
    use hclfft::coordinator::real::execute_real_batch_with_mode;
    use hclfft::dft::real::{half_cols, RealMatrix, TransformKind};

    let real_plan = PlannedTransform {
        n: pn,
        d: vec![256, 128],
        pads: vec![
            PadDecision { n_padded: pn, t_unpadded: 0.0, t_padded: 0.0 },
            PadDecision { n_padded: 480, t_unpadded: 1.0, t_padded: 0.5 },
        ],
        algorithm: Algorithm::Hpopta,
        makespan: f64::NAN,
        kind: TransformKind::R2c,
    };
    let real_srcs: Vec<RealMatrix> =
        (0..2).map(|s| RealMatrix::random(pn, pn, 200 + s)).collect();
    let mut packed_outs: Vec<SignalMatrix> =
        (0..2).map(|_| SignalMatrix::zeros(pn, half_cols(pn))).collect();
    let run_mixed = |batch: &mut Vec<SignalMatrix>, packed: &mut Vec<SignalMatrix>| {
        {
            let mut refs: Vec<&mut SignalMatrix> = batch.iter_mut().collect();
            execute_planned_batch_with_mode(
                &NativeEngine,
                &plan,
                &mut refs,
                2,
                64,
                PipelineMode::Fused,
            )
            .unwrap();
        }
        {
            let srcs: Vec<&[f64]> = real_srcs.iter().map(|m| &m.data[..]).collect();
            let mut dst_refs: Vec<&mut SignalMatrix> = packed.iter_mut().collect();
            execute_real_batch_with_mode(
                &NativeEngine,
                &real_plan,
                &srcs,
                &mut dst_refs,
                2,
                PipelineMode::Fused,
            )
            .unwrap();
        }
    };

    // warmup until a full mixed pass grows no arena
    let mut warm_iters = 0;
    loop {
        let before = scratch_grow_events();
        run_mixed(&mut batch, &mut packed_outs);
        warm_iters += 1;
        if scratch_grow_events() == before && warm_iters >= 5 {
            break;
        }
        assert!(warm_iters < 500, "mixed-kind arenas never reached steady state");
    }

    let grow_before = scratch_grow_events();
    let bytes_before = ALLOCATED_BYTES.load(Ordering::Relaxed);
    let iters = 10usize;
    for _ in 0..iters {
        run_mixed(&mut batch, &mut packed_outs);
    }
    let grow_delta = scratch_grow_events() - grow_before;
    let bytes_delta = ALLOCATED_BYTES.load(Ordering::Relaxed) - bytes_before;

    // arena growth stays bounded by the thread population (a late
    // thread may warm pair + gather arenas once) — never the iteration
    // count
    assert!(
        grow_delta <= 4 * (4 + 1),
        "mixed-kind scratch arenas grew {grow_delta} times over {iters} warm iterations"
    );
    // per-iteration budget: two DAGs' bookkeeping. A single warm-path
    // packed-plane allocation would cost 2 · 384 · 193 · 8 ≈ 1.2 MiB —
    // the bound sits far below one.
    let per_iter = bytes_delta / iters;
    assert!(
        per_iter < 192 * 1024,
        "mixed c2c/r2c steady state allocates {per_iter} B/iter (total {bytes_delta} B)"
    );

    // sanity: the warm real path still matches its barrier oracle
    let mut barrier_out: Vec<SignalMatrix> =
        (0..2).map(|_| SignalMatrix::zeros(pn, half_cols(pn))).collect();
    {
        let srcs: Vec<&[f64]> = real_srcs.iter().map(|m| &m.data[..]).collect();
        let mut dst_refs: Vec<&mut SignalMatrix> = barrier_out.iter_mut().collect();
        execute_real_batch_with_mode(
            &NativeEngine,
            &real_plan,
            &srcs,
            &mut dst_refs,
            2,
            PipelineMode::Barrier,
        )
        .unwrap();
    }
    for (f, b) in packed_outs.iter().zip(&barrier_out) {
        assert_eq!(f.max_abs_diff(b), 0.0, "warm real pipeline must stay bit-exact");
    }

    // ----- plan-cache footprint: shared per-stage twiddle tables -----
    // Stage twiddle tables depend only on (radix, n_cur), so plans for
    // different lengths must hold the *same* Arc allocation for a
    // common stage geometry — 384 = 2^7·3 and 768 = 2^8·3 (both used
    // above) share every geometry after 768's extra leading radix-2.
    use hclfft::dft::plan::PlanCache;
    let p384 = PlanCache::global().radix(384);
    let p768 = PlanCache::global().radix(768);
    let mut shared = 0usize;
    for sa in &p384.stages {
        for sb in &p768.stages {
            if sa.radix == sb.radix && sa.n_cur == sb.n_cur {
                assert!(
                    std::sync::Arc::ptr_eq(sa.twiddles(), sb.twiddles()),
                    "stage ({}, {}) duplicated across plans",
                    sa.radix,
                    sa.n_cur
                );
                shared += 1;
            }
        }
    }
    assert!(shared >= 4, "384/768 share only {shared} stage geometries");

    // and the counting allocator proves it: re-planning a length whose
    // stage tables are all cached allocates only plan skeleton (factor
    // + stage vecs), never the ~12 KiB of twiddle planes an un-deduped
    // 768 build would copy
    let bytes_before = ALLOCATED_BYTES.load(Ordering::Relaxed);
    let rebuilt = hclfft::dft::radix::RadixPlan::new(768);
    let plan_bytes = ALLOCATED_BYTES.load(Ordering::Relaxed) - bytes_before;
    assert_eq!(rebuilt.n, 768);
    assert!(
        plan_bytes < 4 * 1024,
        "re-planning 768 allocated {plan_bytes} B — twiddle tables are not shared"
    );
}
