//! `perf-gate` — the CI performance-regression gate.
//!
//! The repo's load-bearing speedups — the fused pipeline over the
//! barrier four-step (PR 4), the r2c real path over c2c (PR 5), and
//! the vectorized row kernel over the scalar reference arm (this PR) —
//! are *ratios of means measured in the same process on the same
//! machine*, so they are comparable across runners in a way raw
//! wall-clock numbers are not. This binary reads the bench
//! trajectories (`BENCH_pipeline.json`, `BENCH_real.json`,
//! `results/bench_fft_sizes.json`), recomputes each speedup, and fails
//! (exit 1) if any drops below its committed baseline
//! (`BENCH_baseline.json`) minus the noise tolerance — the speedup
//! trajectory cannot silently erode.
//!
//! Baseline format (committed at the repo root):
//!
//! ```json
//! {
//!   "version": 2,
//!   "tolerance": 0.15,
//!   "metrics": [
//!     {"name": "fused_vs_barrier_384", "suite": "pipeline",
//!      "slow": "barrier_384", "fast": "fused_384", "baseline": 1.0},
//!     {"name": "scalar_vs_vector_geomean", "suite": "fft",
//!      "pairs": [{"slow": "scalar_16x384", "fast": "radix_16x384"},
//!                {"slow": "scalar_16x640", "fast": "radix_16x640"}],
//!      "baseline": 1.25}
//!   ]
//! }
//! ```
//!
//! `speedup = mean(slow) / mean(fast)` — or, when a metric carries a
//! `pairs` array instead of a single `slow`/`fast`, the *geometric
//! mean* of the pair ratios (the shape of the bench's
//! vector-vs-scalar geomean line). The gate requires
//! `speedup >= baseline * (1 - tolerance)`.
//!
//! Flags: `--baseline <file>` `--pipeline <file>` `--real <file>`
//! `--fft <file>` `--tolerance <f>` (override) `--scale <f>` (multiply
//! every measured speedup — `--scale 0.5` is the CI self-test proving
//! the gate demonstrably fails on an injected regression).

use std::collections::BTreeMap;
use std::path::Path;

use hclfft::cli;
use hclfft::util::json::Json;

fn main() {
    // reuse the crate's CLI grammar by prepending a subcommand token
    let mut argv: Vec<String> = vec!["perf-gate".to_string()];
    argv.extend(std::env::args().skip(1));
    let code = match run(&argv) {
        Ok(true) => 0,
        Ok(false) => 1,
        Err(e) => {
            eprintln!("perf-gate error: {e}");
            2
        }
    };
    std::process::exit(code);
}

/// name → mean seconds of one bench suite JSON.
fn load_means(path: &Path) -> Result<BTreeMap<String, f64>, String> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| format!("cannot read {}: {e} (run the benches first)", path.display()))?;
    let j = Json::parse(&text).map_err(|e| format!("{}: {e}", path.display()))?;
    let mut means = BTreeMap::new();
    for r in j.get("results").and_then(Json::as_arr).ok_or("bench json: missing results")? {
        let name = r.get("name").and_then(Json::as_str).ok_or("bench json: missing name")?;
        let mean = r.get("mean_s").and_then(Json::as_f64).ok_or("bench json: missing mean_s")?;
        means.insert(name.to_string(), mean);
    }
    Ok(means)
}

fn run(argv: &[String]) -> Result<bool, String> {
    let args = cli::parse(argv)?;
    args.validate(&["baseline", "pipeline", "real", "fft", "tolerance", "scale"])?;
    let baseline_path = args.opt_or("baseline", "BENCH_baseline.json");
    let pipeline_path = args.opt_or("pipeline", "BENCH_pipeline.json");
    let real_path = args.opt_or("real", "BENCH_real.json");
    let fft_path = args.opt_or("fft", "results/bench_fft_sizes.json");
    let scale = args.opt_f64("scale")?.unwrap_or(1.0);

    let text = std::fs::read_to_string(&baseline_path)
        .map_err(|e| format!("cannot read {baseline_path}: {e}"))?;
    let base = Json::parse(&text).map_err(|e| format!("{baseline_path}: {e}"))?;
    let tolerance = args
        .opt_f64("tolerance")?
        .or_else(|| base.get("tolerance").and_then(Json::as_f64))
        .unwrap_or(0.15);
    if !(0.0..1.0).contains(&tolerance) {
        return Err(format!("tolerance {tolerance} out of range [0, 1)"));
    }

    let mut suites: BTreeMap<&str, BTreeMap<String, f64>> = BTreeMap::new();
    suites.insert("pipeline", load_means(Path::new(&pipeline_path))?);
    suites.insert("real", load_means(Path::new(&real_path))?);
    suites.insert("fft", load_means(Path::new(&fft_path))?);

    let metrics = base
        .get("metrics")
        .and_then(Json::as_arr)
        .ok_or("baseline: missing metrics array")?;
    if metrics.is_empty() {
        return Err("baseline: empty metrics array".into());
    }

    println!(
        "perf-gate: {} metric(s), tolerance {:.0}%{}",
        metrics.len(),
        tolerance * 100.0,
        if scale != 1.0 { format!(", injected scale {scale}") } else { String::new() }
    );
    let mut ok = true;
    for m in metrics {
        let name = m.get("name").and_then(Json::as_str).ok_or("baseline: metric missing name")?;
        let suite = m.get("suite").and_then(Json::as_str).ok_or("baseline: metric missing suite")?;
        let baseline = m
            .get("baseline")
            .and_then(Json::as_f64)
            .ok_or("baseline: metric missing baseline")?;
        // a metric is one slow/fast ratio, or — with a `pairs` array —
        // the geometric mean of several (the gate-side mirror of the
        // bench's vector-vs-scalar geomean line)
        let mut pairs: Vec<(String, String)> = Vec::new();
        if let Some(arr) = m.get("pairs").and_then(Json::as_arr) {
            for p in arr {
                let slow =
                    p.get("slow").and_then(Json::as_str).ok_or("baseline: pair missing slow")?;
                let fast =
                    p.get("fast").and_then(Json::as_str).ok_or("baseline: pair missing fast")?;
                pairs.push((slow.to_string(), fast.to_string()));
            }
            if pairs.is_empty() {
                return Err(format!("baseline: metric `{name}` has an empty pairs array"));
            }
        } else {
            let slow =
                m.get("slow").and_then(Json::as_str).ok_or("baseline: metric missing slow")?;
            let fast =
                m.get("fast").and_then(Json::as_str).ok_or("baseline: metric missing fast")?;
            pairs.push((slow.to_string(), fast.to_string()));
        }
        let means = suites
            .get(suite)
            .ok_or_else(|| format!("baseline: unknown suite `{suite}` for `{name}`"))?;
        let mut log_sum = 0.0;
        let mut valid = true;
        for (slow, fast) in &pairs {
            let (Some(&slow_s), Some(&fast_s)) =
                (means.get(slow.as_str()), means.get(fast.as_str()))
            else {
                println!(
                    "  FAIL {name}: bench result `{slow}` or `{fast}` missing from {suite} suite"
                );
                valid = false;
                break;
            };
            if !(slow_s.is_finite() && fast_s.is_finite()) || fast_s <= 0.0 {
                println!("  FAIL {name}: degenerate means (slow {slow_s}, fast {fast_s})");
                valid = false;
                break;
            }
            log_sum += (slow_s / fast_s * scale).ln();
        }
        if !valid {
            ok = false;
            continue;
        }
        let speedup = (log_sum / pairs.len() as f64).exp();
        let floor = baseline * (1.0 - tolerance);
        let pass = speedup >= floor;
        println!(
            "  {} {name}: speedup {speedup:.3}x (baseline {baseline:.3}x, floor {floor:.3}x)",
            if pass { "PASS" } else { "FAIL" }
        );
        ok &= pass;
    }
    if ok {
        println!("perf-gate: OK — no speedup fell below baseline - {:.0}%", tolerance * 100.0);
    } else {
        println!("perf-gate: REGRESSION — at least one speedup fell below its floor");
    }
    Ok(ok)
}
