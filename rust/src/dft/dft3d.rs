//! 3D-DFT extension — the paper's stated future work (§VII: "we plan to
//! extend our algorithms for fast computation of 3D-DFT").
//!
//! The row-column decomposition generalizes to *slab* decomposition: a
//! P×N×N volume is transformed as
//!
//!   1. batched 1D-FFTs along axis 2 (contiguous rows of every slab),
//!   2. per-slab transpose (axes 1↔2), batched 1D-FFTs, transpose back,
//!   3. slab rotation (axes 0↔1), batched 1D-FFTs along the former
//!      depth axis, rotation back.
//!
//! Every compute step is again "x row 1D-FFTs of length y", so the same
//! FPMs, POPTA/HPOPTA partitioning and padding apply unchanged — the
//! distribution now splits *slabs* instead of rows (see
//! [`crate::coordinator::pfft3d`]).

use crate::dft::fft::Direction;
use crate::dft::transpose::transpose_in_place_parallel;
use crate::dft::SignalMatrix;

/// A complex cube in SoA split-plane layout, `[d][r][c]` row-major.
#[derive(Clone, Debug, PartialEq)]
pub struct SignalCube {
    pub n: usize,
    pub re: Vec<f64>,
    pub im: Vec<f64>,
}

impl SignalCube {
    pub fn zeros(n: usize) -> Self {
        SignalCube { n, re: vec![0.0; n * n * n], im: vec![0.0; n * n * n] }
    }

    pub fn random(n: usize, seed: u64) -> Self {
        let mut rng = crate::util::prng::Xoshiro256::seeded(seed);
        let mut c = SignalCube::zeros(n);
        for v in c.re.iter_mut().chain(c.im.iter_mut()) {
            *v = rng.next_f64() * 2.0 - 1.0;
        }
        c
    }

    #[inline]
    pub fn idx(&self, d: usize, r: usize, c: usize) -> usize {
        (d * self.n + r) * self.n + c
    }

    pub fn get(&self, d: usize, r: usize, c: usize) -> (f64, f64) {
        let i = self.idx(d, r, c);
        (self.re[i], self.im[i])
    }

    pub fn set(&mut self, d: usize, r: usize, c: usize, re: f64, im: f64) {
        let i = self.idx(d, r, c);
        self.re[i] = re;
        self.im[i] = im;
    }

    pub fn max_abs_diff(&self, other: &SignalCube) -> f64 {
        self.re
            .iter()
            .zip(&other.re)
            .chain(self.im.iter().zip(&other.im))
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }

    pub fn norm(&self) -> f64 {
        self.re.iter().zip(&self.im).map(|(r, i)| r * r + i * i).sum::<f64>().sqrt()
    }

    /// View slab `d` as a borrowed SignalMatrix-shaped pair of slices.
    pub fn slab_mut(&mut self, d: usize) -> (&mut [f64], &mut [f64]) {
        let n2 = self.n * self.n;
        (&mut self.re[d * n2..(d + 1) * n2], &mut self.im[d * n2..(d + 1) * n2])
    }
}

/// Rotate axes 0↔2 in place: cube[d][r][c] ↔ cube[c][r][d]. After this,
/// the contiguous row axis (axis 2) holds what was the depth axis, so a
/// batched row FFT transforms the original axis 0.
pub fn rotate_d_c(cube: &mut SignalCube) {
    let n = cube.n;
    for r in 0..n {
        for d in 0..n {
            for c in (d + 1)..n {
                let a = (d * n + r) * n + c;
                let b = (c * n + r) * n + d;
                cube.re.swap(a, b);
                cube.im.swap(a, b);
            }
        }
    }
}

/// Per-slab transpose (axes 1↔2) over a contiguous range of slabs.
pub fn transpose_slabs(cube: &mut SignalCube, d0: usize, d1: usize, block: usize, threads: usize) {
    let n = cube.n;
    let n2 = n * n;
    for d in d0..d1 {
        // wrap the slab in a temporary SignalMatrix facade
        let mut m = SignalMatrix {
            rows: n,
            cols: n,
            re: cube.re[d * n2..(d + 1) * n2].to_vec(),
            im: cube.im[d * n2..(d + 1) * n2].to_vec(),
        };
        transpose_in_place_parallel(&mut m, block, threads);
        cube.re[d * n2..(d + 1) * n2].copy_from_slice(&m.re);
        cube.im[d * n2..(d + 1) * n2].copy_from_slice(&m.im);
    }
}

/// Full 3D-DFT of an n×n×n cube using one thread group (the baseline the
/// PFFT-FPM-3D coordinator beats). Dir applies to all three axes.
pub fn dft3d(cube: &mut SignalCube, dir: Direction, threads: usize) {
    let n = cube.n;
    // axis 2: all n^2 rows are contiguous
    crate::dft::bluestein::fft_rows(&mut cube.re, &mut cube.im, n * n, n, dir);
    // axis 1: per-slab transpose, rows, transpose back
    transpose_slabs(cube, 0, n, 64, threads);
    crate::dft::bluestein::fft_rows(&mut cube.re, &mut cube.im, n * n, n, dir);
    transpose_slabs(cube, 0, n, 64, threads);
    // axis 0: rotate depth<->column, rows, rotate back
    rotate_d_c(cube);
    crate::dft::bluestein::fft_rows(&mut cube.re, &mut cube.im, n * n, n, dir);
    rotate_d_c(cube);
}

/// Naive O(N^2)-per-axis 3D-DFT oracle (tests only; keep n tiny).
pub fn naive_dft3d(cube: &SignalCube) -> SignalCube {
    let n = cube.n;
    let mut out = SignalCube::zeros(n);
    let w = |k: usize, j: usize| {
        let ang = -2.0 * std::f64::consts::PI * (k * j % n) as f64 / n as f64;
        (ang.cos(), ang.sin())
    };
    for kd in 0..n {
        for kr in 0..n {
            for kc in 0..n {
                let (mut sr, mut si) = (0.0, 0.0);
                for d in 0..n {
                    for r in 0..n {
                        for c in 0..n {
                            let (xr, xi) = cube.get(d, r, c);
                            let (w1r, w1i) = w(kd, d);
                            let (w2r, w2i) = w(kr, r);
                            let (w3r, w3i) = w(kc, c);
                            // w = w1*w2*w3
                            let (t1r, t1i) = (w1r * w2r - w1i * w2i, w1r * w2i + w1i * w2r);
                            let (wr, wi) = (t1r * w3r - t1i * w3i, t1r * w3i + t1i * w3r);
                            sr += xr * wr - xi * wi;
                            si += xr * wi + xi * wr;
                        }
                    }
                }
                out.set(kd, kr, kc, sr, si);
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dft3d_matches_naive() {
        for &n in &[2usize, 4, 6] {
            let orig = SignalCube::random(n, n as u64);
            let mut c = orig.clone();
            dft3d(&mut c, Direction::Forward, 1);
            let want = naive_dft3d(&orig);
            let scale = want.norm().max(1.0);
            assert!(
                c.max_abs_diff(&want) / scale < 1e-10,
                "n={n}: {}",
                c.max_abs_diff(&want) / scale
            );
        }
    }

    #[test]
    fn dft3d_roundtrip() {
        let orig = SignalCube::random(8, 3);
        let mut c = orig.clone();
        dft3d(&mut c, Direction::Forward, 2);
        dft3d(&mut c, Direction::Inverse, 2);
        assert!(c.max_abs_diff(&orig) < 1e-10);
    }

    #[test]
    fn rotate_is_involution() {
        let orig = SignalCube::random(5, 7);
        let mut c = orig.clone();
        rotate_d_c(&mut c);
        assert_ne!(c, orig);
        rotate_d_c(&mut c);
        assert_eq!(c, orig);
    }

    #[test]
    fn rotate_moves_elements_correctly() {
        let mut c = SignalCube::zeros(3);
        c.set(0, 2, 1, 5.0, -5.0);
        rotate_d_c(&mut c);
        // [d][r][c] -> [c][r][d]: (0,2,1) lands at (1,2,0)
        assert_eq!(c.get(1, 2, 0), (5.0, -5.0));
        assert_eq!(c.get(0, 2, 1), (0.0, 0.0));
    }

    #[test]
    fn transpose_slabs_per_slab() {
        let mut c = SignalCube::zeros(2);
        c.set(1, 0, 1, 3.0, 4.0);
        transpose_slabs(&mut c, 0, 2, 16, 1);
        assert_eq!(c.get(1, 1, 0), (3.0, 4.0));
    }

    #[test]
    fn parseval_3d() {
        let n = 4;
        let orig = SignalCube::random(n, 9);
        let mut c = orig.clone();
        dft3d(&mut c, Direction::Forward, 1);
        let et: f64 = orig.re.iter().zip(&orig.im).map(|(r, i)| r * r + i * i).sum();
        let ef: f64 =
            c.re.iter().zip(&c.im).map(|(r, i)| r * r + i * i).sum::<f64>() / (n * n * n) as f64;
        assert!((et - ef).abs() / et < 1e-10);
    }
}
