//! Iterative Stockham radix-2 FFT over split-plane buffers.
//!
//! Same decimation-in-frequency Stockham formulation as the L1 Pallas
//! kernel (`python/compile/kernels/fft.py`) so the two implementations are
//! line-for-line comparable: state is viewed as `(n_cur, s)` with original
//! index `q + s·p`; each stage halves `n_cur`, doubles `s`, and the result
//! lands in natural order (no bit reversal).
//!
//! Since the mixed-radix executor landed ([`crate::dft::radix`] +
//! [`crate::dft::exec`]), general row FFTs dispatch through
//! [`crate::dft::exec::fft_rows_pooled`]; this kernel remains the engine
//! behind Bluestein's internal convolution FFTs ([`fft_rows_pow2_with`]
//! transforms a batch of rows reusing a cached [`plan::Pow2Plan`] twiddle
//! table and one scratch buffer — the plan-once/execute-many shape of
//! Algorithm 6) and an independent cross-check for the all-2s radix
//! schedule.
//!
//! The kernel shares the vectorized machinery of [`crate::dft::radix`]:
//! the last `log2(min(n, 8))` stages run as one fused FFT2/4/8 tail
//! codelet (hardcoded twiddles, in-place, no final un-ping-pong copy),
//! and the stride-1 first stage — where the lane loop degenerates to
//! scalar — dispatches to the AVX2 kernel in [`crate::dft::simd`] when
//! the `simd` feature is compiled in and the CPU supports it (identical
//! IEEE-754 operation order, so the output is bit-identical either way).
//! Because the tail codelets and the stage dispatchers are shared, this
//! kernel inherits phase-2 vectorization for free: the AVX2 codelet
//! bodies sweep the tail 4 lanes at a time, and under `--features fma`
//! the stride-1 stage runs the FMA kernel generation (see
//! [`crate::dft::simd`]'s module docs for the bit-exactness contract).

use crate::dft::plan::Pow2Plan;
use crate::dft::{radix, simd};

/// Forward/inverse direction marker.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Direction {
    Forward,
    Inverse,
}

impl Direction {
    #[inline]
    pub fn sign(self) -> f64 {
        match self {
            Direction::Forward => -1.0,
            Direction::Inverse => 1.0,
        }
    }
}

/// Transform a single length-`n` row (power of two) in `re`/`im`,
/// using `plan` twiddles and `scratch` (same length) as the ping-pong
/// buffer. O(n log n), result in natural order.
pub fn fft_row_pow2(
    re: &mut [f64],
    im: &mut [f64],
    scratch_re: &mut [f64],
    scratch_im: &mut [f64],
    plan: &Pow2Plan,
    dir: Direction,
) {
    let n = plan.n;
    debug_assert!(n.is_power_of_two());
    debug_assert_eq!(re.len(), n);
    debug_assert_eq!(scratch_re.len(), n);

    if n == 1 {
        return;
    }

    // ping-pong between (re,im) and scratch; stage s: view src as
    // (n_cur, stride) row-major [p, q] at index q + stride*p. The last
    // log2(tail) stages are held back and fused into one codelet pass.
    let tail = n.min(8);
    let sign = if dir == Direction::Inverse { -1.0 } else { 1.0 };
    let mut n_cur = n;
    let mut stride = 1usize;
    let mut in_src = true; // data currently in re/im?
    while n_cur > tail {
        let m = n_cur / 2;
        let (sr, si, dr, di): (&[f64], &[f64], &mut [f64], &mut [f64]) = if in_src {
            (&*re, &*im, &mut *scratch_re, &mut *scratch_im)
        } else {
            (&*scratch_re, &*scratch_im, &mut *re, &mut *im)
        };
        // twiddles for this stage: w_p = exp(sign*2πi * p / n_cur)
        // plan stores forward twiddles at stride n/n_cur: w_p = tw[p * (n/n_cur)]
        let tw_step = plan.n / n_cur;
        if stride == 1 {
            // first stage only: tw_step == 1, so the plan's twiddle
            // planes are exactly the per-p table the AVX2 kernel packs
            let (twr, twi) = plan.twiddles();
            if simd::try_stage2(sign, twr, twi, sr, si, dr, di, 0, m, m, 1) {
                n_cur = m;
                stride *= 2;
                in_src = !in_src;
                continue;
            }
        }
        for p in 0..m {
            let (wr, wi0) = plan.twiddle(p * tw_step);
            let wi = sign * wi0;
            let a_base = stride * p;
            let b_base = stride * (p + m);
            let o0_base = stride * 2 * p;
            let o1_base = stride * (2 * p + 1);
            // slice the butterfly lanes once: the explicit subslices let
            // LLVM drop per-element bounds checks and vectorize the q
            // loop (see EXPERIMENTS.md §Perf)
            let sar = &sr[a_base..a_base + stride];
            let sai = &si[a_base..a_base + stride];
            let sbr = &sr[b_base..b_base + stride];
            let sbi = &si[b_base..b_base + stride];
            let (d0r, d1r) = dr[o0_base..o1_base + stride].split_at_mut(stride);
            let (d0i, d1i) = di[o0_base..o1_base + stride].split_at_mut(stride);
            for q in 0..stride {
                let ar = sar[q];
                let ai = sai[q];
                let br = sbr[q];
                let bi = sbi[q];
                d0r[q] = ar + br;
                d0i[q] = ai + bi;
                let xr = ar - br;
                let xi = ai - bi;
                d1r[q] = xr * wr - xi * wi;
                d1i[q] = xr * wi + xi * wr;
            }
        }
        n_cur = m;
        stride *= 2;
        in_src = !in_src;
    }

    // fused FFT2/4/8 finish (shared with the mixed-radix kernel): one
    // hardcoded-twiddle pass lands the result in re/im with no copy
    if in_src {
        radix::tail_codelet_inplace(tail, sign, re, im);
    } else {
        radix::tail_codelet(tail, sign, scratch_re, scratch_im, re, im);
    }
    if dir == Direction::Inverse {
        let inv_n = 1.0 / n as f64;
        for v in re.iter_mut() {
            *v *= inv_n;
        }
        for v in im.iter_mut() {
            *v *= inv_n;
        }
    }
}

/// Transform `rows` rows of length `plan.n` stored contiguously in
/// `re`/`im` (row-major), reusing one scratch buffer.
pub fn fft_rows_pow2_with(
    re: &mut [f64],
    im: &mut [f64],
    rows: usize,
    plan: &Pow2Plan,
    dir: Direction,
    scratch_re: &mut Vec<f64>,
    scratch_im: &mut Vec<f64>,
) {
    let n = plan.n;
    debug_assert_eq!(re.len(), rows * n);
    scratch_re.resize(n, 0.0);
    scratch_im.resize(n, 0.0);
    for r in 0..rows {
        let span = r * n..(r + 1) * n;
        fft_row_pow2(
            &mut re[span.clone()],
            &mut im[span],
            &mut scratch_re[..],
            &mut scratch_im[..],
            plan,
            dir,
        );
    }
}

/// Convenience allocation-per-call wrapper (tests / cold paths).
pub fn fft_rows_pow2(re: &mut [f64], im: &mut [f64], rows: usize, n: usize, dir: Direction) {
    let plan = Pow2Plan::new(n);
    let mut sr = Vec::new();
    let mut si = Vec::new();
    fft_rows_pow2_with(re, im, rows, &plan, dir, &mut sr, &mut si);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dft::{naive_dft_rows, SignalMatrix};

    fn fft_matrix(m: &SignalMatrix, dir: Direction) -> SignalMatrix {
        let mut out = m.clone();
        fft_rows_pow2(&mut out.re, &mut out.im, m.rows, m.cols, dir);
        out
    }

    #[test]
    fn matches_naive_dft_across_sizes() {
        for &n in &[1usize, 2, 4, 8, 16, 64, 256, 1024] {
            let m = SignalMatrix::random(2, n, n as u64);
            let got = fft_matrix(&m, Direction::Forward);
            let want = naive_dft_rows(&m, false);
            let scale = want.norm().max(1.0);
            assert!(
                got.max_abs_diff(&want) / scale < 1e-10,
                "n={n}: diff {}",
                got.max_abs_diff(&want)
            );
        }
    }

    #[test]
    fn inverse_roundtrip() {
        for &n in &[2usize, 8, 128, 512] {
            let m = SignalMatrix::random(3, n, 7);
            let f = fft_matrix(&m, Direction::Forward);
            let b = fft_matrix(&f, Direction::Inverse);
            assert!(m.max_abs_diff(&b) < 1e-10, "n={n}");
        }
    }

    #[test]
    fn impulse_flat_spectrum() {
        let mut m = SignalMatrix::zeros(1, 32);
        m.set(0, 0, 1.0, 0.0);
        let f = fft_matrix(&m, Direction::Forward);
        for c in 0..32 {
            let (re, im) = f.get(0, c);
            assert!((re - 1.0).abs() < 1e-12 && im.abs() < 1e-12);
        }
    }

    #[test]
    fn constant_maps_to_delta() {
        let n = 64;
        let mut m = SignalMatrix::zeros(1, n);
        for c in 0..n {
            m.set(0, c, 1.0, 0.0);
        }
        let f = fft_matrix(&m, Direction::Forward);
        let (re0, _) = f.get(0, 0);
        assert!((re0 - n as f64).abs() < 1e-9);
        for c in 1..n {
            let (re, im) = f.get(0, c);
            assert!(re.abs() < 1e-9 && im.abs() < 1e-9);
        }
    }

    #[test]
    fn parseval_energy_preserved() {
        let n = 256;
        let m = SignalMatrix::random(1, n, 5);
        let f = fft_matrix(&m, Direction::Forward);
        let te: f64 = m.re.iter().zip(&m.im).map(|(r, i)| r * r + i * i).sum();
        let fe: f64 = f.re.iter().zip(&f.im).map(|(r, i)| r * r + i * i).sum::<f64>() / n as f64;
        assert!((te - fe).abs() / te < 1e-12);
    }

    #[test]
    fn linearity() {
        let n = 64;
        let a = SignalMatrix::random(1, n, 1);
        let b = SignalMatrix::random(1, n, 2);
        let mut sum = SignalMatrix::zeros(1, n);
        for i in 0..n {
            sum.re[i] = 2.0 * a.re[i] - 0.5 * b.re[i];
            sum.im[i] = 2.0 * a.im[i] - 0.5 * b.im[i];
        }
        let fa = fft_matrix(&a, Direction::Forward);
        let fb = fft_matrix(&b, Direction::Forward);
        let fs = fft_matrix(&sum, Direction::Forward);
        for i in 0..n {
            assert!((fs.re[i] - (2.0 * fa.re[i] - 0.5 * fb.re[i])).abs() < 1e-9);
            assert!((fs.im[i] - (2.0 * fa.im[i] - 0.5 * fb.im[i])).abs() < 1e-9);
        }
    }

    #[test]
    fn shift_theorem() {
        // circular shift by k multiplies spectrum by exp(-2πi k l / n)
        let n = 32;
        let m = SignalMatrix::random(1, n, 9);
        let mut shifted = SignalMatrix::zeros(1, n);
        let k = 5;
        for c in 0..n {
            let (re, im) = m.get(0, c);
            shifted.set(0, (c + k) % n, re, im);
        }
        let fm = fft_matrix(&m, Direction::Forward);
        let fs = fft_matrix(&shifted, Direction::Forward);
        for l in 0..n {
            let ang = -2.0 * std::f64::consts::PI * (k as f64) * (l as f64) / n as f64;
            let (wr, wi) = (ang.cos(), ang.sin());
            let (ar, ai) = fm.get(0, l);
            let want = (ar * wr - ai * wi, ar * wi + ai * wr);
            let got = fs.get(0, l);
            assert!((got.0 - want.0).abs() < 1e-9 && (got.1 - want.1).abs() < 1e-9);
        }
    }
}
