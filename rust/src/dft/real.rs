//! Real-input (r2c / c2r) transforms over the native substrate.
//!
//! The dominant production workloads — images, sensor grids, spectral
//! solvers — are real-valued, and a real signal's spectrum is Hermitian
//! (`F[-k] = conj(F[k])`): half of a complex transform's work and
//! storage is redundant. This module makes the real case first-class:
//!
//! * **r2c row kernel** ([`r2c_rows`]): two real rows pack into *one*
//!   complex FFT (row a → re plane, row b → im plane of a single
//!   length-v vector); the Hermitian unpack
//!   `A[k] = (Z[k] + conj(Z[v-k]))/2`, `B[k] = (Z[k] - conj(Z[v-k]))/2i`
//!   separates the two spectra afterwards. One complex FFT per *pair*
//!   of rows — roughly half the row-phase flops of the c2c path.
//! * **Hermitian-packed storage**: an `N×N` real transform stores only
//!   the non-redundant half-spectrum columns `0..N/2+1` — a plain
//!   [`SignalMatrix`] of shape `N × (N/2+1)` ([`half_cols`]); the full
//!   `N×N` spectrum is recoverable via [`expand_packed`].
//! * **packed column phase** ([`rfft_cols_fused`]): plain complex FFTs
//!   down the `N/2+1` stored columns, executed as the fused pipeline's
//!   strided tiles (per-tile transpose-gather into pooled scratch — the
//!   same access pattern as [`crate::dft::pipeline::fft_col_range`],
//!   at the packed stride). With `--features simd` both the tile
//!   gather/scatter here and the barrier fallback's out-of-place
//!   rectangle transpose run on the 4×4 in-register transpose kernels
//!   of [`crate::dft::simd`] — the packed `N/2+1` width is always odd,
//!   so the non-multiple-of-4 rim columns take the scalar edge path.
//!   Both modes feed every logical column vector to the same kernel,
//!   and the transpose kernels are pure data movement, so fused,
//!   barrier, scalar and SIMD routes are all bit-identical.
//! * **c2r inverse** ([`c2r_rows`], [`irfft2d`]): inverse column FFTs,
//!   then the inverse pair trick — two Hermitian half-spectra rows
//!   re-combine into one complex inverse FFT whose re/im planes are the
//!   two real rows. `irfft2d(rfft2d(x)) == x` up to rounding.
//!
//! Pairing is **per tile** ([`crate::dft::pipeline::DEFAULT_ROW_TILE`]
//! rows, an even count): every execution strategy — serial, pooled,
//! stage-DAG, any worker count — packs identical row pairs, which is
//! what makes fused and barrier real pipelines bit-identical. Padded
//! plans run the pair FFT at the group's pad length `v > n` and keep
//! the first `n/2+1` bins — the same forward-only spectral
//! interpolation semantics as the c2c PFFT-FPM-PAD row phase.
//!
//! The pair FFT is an ordinary complex row transform, so the real path
//! inherits the vectorized mixed-radix kernel for free: the fused
//! FFT2/4/8 tail codelets and (with `--features simd`) the AVX2
//! radix-2 stages of [`crate::dft::radix`] apply to every packed pair,
//! compounding with the ~2x pairing win above.

use crate::dft::exec::{fft_rows_pooled, with_scratch, ExecCtx, Job};
use crate::dft::fft::Direction;
use crate::dft::pipeline::{default_mode, fft_cols_fused_rect, PipelineMode, DEFAULT_ROW_TILE};
use crate::dft::transpose::transposed;
use crate::dft::SignalMatrix;

// ---------------------------------------------------------------------------
// Transform kinds
// ---------------------------------------------------------------------------

/// What a planned/served 2D transform consumes and produces. Every
/// layer above the kernels — plans, wisdom records, model streams,
/// batch keys, requests — is keyed by this.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum TransformKind {
    /// Complex-to-complex: the classic path, `N×N` in, `N×N` out.
    #[default]
    C2c,
    /// Real-to-complex forward: `N×N` real in, Hermitian-packed
    /// `N×(N/2+1)` half-spectrum out.
    R2c,
    /// Complex-to-real inverse: packed `N×(N/2+1)` in, `N×N` real out.
    C2r,
}

impl TransformKind {
    pub fn name(&self) -> &'static str {
        match self {
            TransformKind::C2c => "c2c",
            TransformKind::R2c => "r2c",
            TransformKind::C2r => "c2r",
        }
    }

    /// Parse a CLI/JSON value. `real` is accepted as an alias for the
    /// forward real kind (the `--kind=real` flag).
    pub fn parse(s: &str) -> Option<TransformKind> {
        match s.trim().to_ascii_lowercase().as_str() {
            "c2c" | "complex" => Some(TransformKind::C2c),
            "r2c" | "real" => Some(TransformKind::R2c),
            "c2r" => Some(TransformKind::C2r),
            _ => None,
        }
    }

    /// The *plane* kind FPM surfaces, wisdom records and model streams
    /// are keyed by: c2r shares the real plane's partitions and
    /// observation streams with r2c (same row kernels, same tile
    /// geometry), exactly as c2c inverse shares the c2c plan.
    pub fn plan_kind(&self) -> TransformKind {
        match self {
            TransformKind::C2r => TransformKind::R2c,
            k => *k,
        }
    }

    /// Does this kind transform real-plane data (either direction)?
    pub fn is_real(&self) -> bool {
        *self != TransformKind::C2c
    }

    /// Complex-flop factor vs the c2c transform of the same N (the real
    /// row phase does half the kernel work; the packed column phase
    /// touches half the columns).
    pub fn flops_factor(&self) -> f64 {
        if self.is_real() {
            0.5
        } else {
            1.0
        }
    }
}

/// Stored columns of the Hermitian-packed half spectrum for row length
/// `n`: bins `0..=n/2`.
pub fn half_cols(n: usize) -> usize {
    n / 2 + 1
}

// ---------------------------------------------------------------------------
// The real signal matrix
// ---------------------------------------------------------------------------

/// A real matrix in row-major layout — half the memory traffic of a
/// [`SignalMatrix`] carrying a zero imaginary plane.
#[derive(Clone, Debug, PartialEq)]
pub struct RealMatrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl RealMatrix {
    pub fn zeros(rows: usize, cols: usize) -> RealMatrix {
        RealMatrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Deterministic random matrix for tests/benches.
    pub fn random(rows: usize, cols: usize, seed: u64) -> RealMatrix {
        let mut rng = crate::util::prng::Xoshiro256::seeded(seed);
        let mut m = RealMatrix::zeros(rows, cols);
        for v in m.data.iter_mut() {
            *v = rng.next_f64() * 2.0 - 1.0;
        }
        m
    }

    pub fn get(&self, r: usize, c: usize) -> f64 {
        self.data[r * self.cols + c]
    }

    pub fn set(&mut self, r: usize, c: usize, v: f64) {
        self.data[r * self.cols + c] = v;
    }

    /// Max |elementwise difference| against another real matrix.
    pub fn max_abs_diff(&self, other: &RealMatrix) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }

    /// Frobenius norm (for relative-error checks).
    pub fn norm(&self) -> f64 {
        self.data.iter().map(|v| v * v).sum::<f64>().sqrt()
    }
}

/// Embed a real matrix as a complex [`SignalMatrix`] with a zero
/// imaginary plane — the c2c oracle's input for real-path tests.
pub fn embed_real(m: &RealMatrix) -> SignalMatrix {
    SignalMatrix { rows: m.rows, cols: m.cols, re: m.data.clone(), im: vec![0.0; m.data.len()] }
}

/// Reconstruct the full `n×n` spectrum from Hermitian-packed
/// `n×(n/2+1)` storage: `F[r, c] = conj(F[(n-r)%n, n-c])` for the
/// dropped columns. Only exact for *unpadded* transforms (padded row
/// phases interpolate the spectrum, whose symmetry is about the pad
/// length, not n).
pub fn expand_packed(packed: &SignalMatrix) -> SignalMatrix {
    let n = packed.rows;
    let nc = packed.cols;
    assert_eq!(nc, half_cols(n), "not a packed half spectrum");
    let mut full = SignalMatrix::zeros(n, n);
    for r in 0..n {
        for c in 0..n {
            let (re, im) = if c < nc {
                packed.get(r, c)
            } else {
                let (re, im) = packed.get((n - r) % n, n - c);
                (re, -im)
            };
            full.set(r, c, re, im);
        }
    }
    full
}

// ---------------------------------------------------------------------------
// Pack / unpack primitives (shared with the engine-generic coordinator path)
// ---------------------------------------------------------------------------

/// Pack `rows` real rows (contiguous length-`n` rows in `src`) into
/// `rows.div_ceil(2)` complex length-`v` rows: pair j carries row `2j`
/// in the re plane and row `2j+1` in the im plane. `wre`/`wim` must be
/// zeroed (a scratch lease is) — the `v - n` tail is the stride-choice
/// pad, and an odd leftover row leaves its im plane zero. Returns the
/// pair count.
pub fn pack_pairs_tile(
    src: &[f64],
    rows: usize,
    n: usize,
    v: usize,
    wre: &mut [f64],
    wim: &mut [f64],
) -> usize {
    let pairs = rows.div_ceil(2);
    debug_assert!(src.len() >= rows * n);
    debug_assert!(wre.len() >= pairs * v && wim.len() >= pairs * v);
    for j in 0..pairs {
        let a = 2 * j;
        wre[j * v..j * v + n].copy_from_slice(&src[a * n..(a + 1) * n]);
        let b = a + 1;
        if b < rows {
            wim[j * v..j * v + n].copy_from_slice(&src[b * n..(b + 1) * n]);
        }
    }
    pairs
}

/// Hermitian-unpack the transformed pairs: from each length-`v`
/// spectrum `Z` recover the two packed rows' half spectra
/// `A[k] = (Z[k] + conj(Z[(v-k)%v]))/2` and
/// `B[k] = (Z[k] - conj(Z[(v-k)%v]))/2i`, keeping bins `0..nc`, written
/// to contiguous length-`nc` rows of `dst_re`/`dst_im`.
pub fn unpack_pairs_tile(
    wre: &[f64],
    wim: &[f64],
    rows: usize,
    nc: usize,
    v: usize,
    dst_re: &mut [f64],
    dst_im: &mut [f64],
) {
    let pairs = rows.div_ceil(2);
    debug_assert!(nc <= v);
    debug_assert!(dst_re.len() >= rows * nc && dst_im.len() >= rows * nc);
    for j in 0..pairs {
        let z = j * v;
        let a = 2 * j;
        let b = a + 1;
        let has_b = b < rows;
        for k in 0..nc {
            let km = if k == 0 { 0 } else { v - k };
            let (zkr, zki) = (wre[z + k], wim[z + k]);
            let (zmr, zmi) = (wre[z + km], wim[z + km]);
            dst_re[a * nc + k] = 0.5 * (zkr + zmr);
            dst_im[a * nc + k] = 0.5 * (zki - zmi);
            if has_b {
                dst_re[b * nc + k] = 0.5 * (zki + zmi);
                dst_im[b * nc + k] = 0.5 * (zmr - zkr);
            }
        }
    }
}

/// Inverse of the pair trick's unpack: re-combine two Hermitian
/// half-spectra rows (bins `0..nc` of length-`n` spectra, contiguous
/// `nc`-rows in `src_re`/`src_im`) into `rows.div_ceil(2)` full
/// length-`n` complex rows `Z[k] = A[k] + i·B[k]` (Hermitian extension
/// supplies bins `nc..n`). Exact length only — c2r does not interpolate.
pub fn pack_spectra_tile(
    src_re: &[f64],
    src_im: &[f64],
    rows: usize,
    n: usize,
    nc: usize,
    wre: &mut [f64],
    wim: &mut [f64],
) -> usize {
    let pairs = rows.div_ceil(2);
    debug_assert_eq!(nc, half_cols(n));
    debug_assert!(src_re.len() >= rows * nc && wre.len() >= pairs * n);
    for j in 0..pairs {
        let z = j * n;
        let a = 2 * j;
        let b = a + 1;
        let has_b = b < rows;
        for k in 0..n {
            let (ar, ai) = if k < nc {
                (src_re[a * nc + k], src_im[a * nc + k])
            } else {
                (src_re[a * nc + (n - k)], -src_im[a * nc + (n - k)])
            };
            let (br, bi) = if !has_b {
                (0.0, 0.0)
            } else if k < nc {
                (src_re[b * nc + k], src_im[b * nc + k])
            } else {
                (src_re[b * nc + (n - k)], -src_im[b * nc + (n - k)])
            };
            wre[z + k] = ar - bi;
            wim[z + k] = ai + br;
        }
    }
    pairs
}

/// After the inverse FFT of [`pack_spectra_tile`]'s rows, the re plane
/// is row `2j` and the im plane row `2j+1`: copy them out as real rows.
pub fn unpack_real_tile(wre: &[f64], wim: &[f64], rows: usize, n: usize, dst: &mut [f64]) {
    let pairs = rows.div_ceil(2);
    debug_assert!(dst.len() >= rows * n);
    for j in 0..pairs {
        let z = j * n;
        let a = 2 * j;
        dst[a * n..(a + 1) * n].copy_from_slice(&wre[z..z + n]);
        let b = a + 1;
        if b < rows {
            dst[b * n..(b + 1) * n].copy_from_slice(&wim[z..z + n]);
        }
    }
}

// ---------------------------------------------------------------------------
// Row kernels over the native substrate
// ---------------------------------------------------------------------------

/// One r2c row tile over the native substrate: pack → one pooled FFT
/// call over the pairs → Hermitian unpack.
#[allow(clippy::too_many_arguments)]
fn r2c_tile(
    ctx: &ExecCtx,
    src_tile: &[f64],
    dst_re: &mut [f64],
    dst_im: &mut [f64],
    rows: usize,
    n: usize,
    nc: usize,
    v: usize,
) {
    with_scratch(|s| {
        let pairs = rows.div_ceil(2);
        let (wre, wim) = s.pair(pairs * v);
        pack_pairs_tile(src_tile, rows, n, v, wre, wim);
        fft_rows_pooled(ctx, wre, wim, pairs, v, Direction::Forward, 1);
        unpack_pairs_tile(wre, wim, rows, nc, v, dst_re, dst_im);
    });
}

/// The r2c row kernel: transform `rows` contiguous real rows of length
/// `n` in `src` into Hermitian-packed rows of length `n/2+1` in the
/// `dst` planes, running each pair of rows as one complex FFT of length
/// `v >= n` (`v > n` = the padded row phase's spectral interpolation —
/// the first `n/2+1` bins of the interpolated spectrum are kept). Work
/// is tiled in [`DEFAULT_ROW_TILE`]-row steps and fans out over up to
/// `threads` pool jobs; the per-tile pairing makes results identical
/// for every thread count.
#[allow(clippy::too_many_arguments)]
pub fn r2c_rows(
    ctx: &ExecCtx,
    src: &[f64],
    dst_re: &mut [f64],
    dst_im: &mut [f64],
    rows: usize,
    n: usize,
    v: usize,
    threads: usize,
) {
    assert!(v >= n, "pad length below n");
    let nc = half_cols(n);
    debug_assert_eq!(src.len(), rows * n);
    debug_assert_eq!(dst_re.len(), rows * nc);
    debug_assert_eq!(dst_im.len(), rows * nc);
    if rows == 0 || n == 0 {
        return;
    }
    // carve per-tile dst slices (disjoint row ranges)
    let mut tiles: Vec<(usize, usize, &mut [f64], &mut [f64])> = Vec::new();
    let mut re_rest: &mut [f64] = dst_re;
    let mut im_rest: &mut [f64] = dst_im;
    let mut r = 0usize;
    while r < rows {
        let len = DEFAULT_ROW_TILE.min(rows - r);
        let (re_t, re_n) = re_rest.split_at_mut(len * nc);
        let (im_t, im_n) = im_rest.split_at_mut(len * nc);
        re_rest = re_n;
        im_rest = im_n;
        tiles.push((r, len, re_t, im_t));
        r += len;
    }
    let threads = threads.max(1);
    if threads == 1 || tiles.len() == 1 {
        for (start, len, re_t, im_t) in tiles {
            r2c_tile(ctx, &src[start * n..(start + len) * n], re_t, im_t, len, n, nc, v);
        }
        return;
    }
    let per_job = tiles.len().div_ceil(threads.min(tiles.len()));
    let mut jobs: Vec<Job> = Vec::new();
    let mut it = tiles.into_iter();
    loop {
        let chunk: Vec<(usize, usize, &mut [f64], &mut [f64])> =
            it.by_ref().take(per_job).collect();
        if chunk.is_empty() {
            break;
        }
        jobs.push(Box::new(move || {
            for (start, len, re_t, im_t) in chunk {
                r2c_tile(ctx, &src[start * n..(start + len) * n], re_t, im_t, len, n, nc, v);
            }
        }));
    }
    ctx.run_jobs(jobs);
}

/// One c2r row tile: Hermitian re-combine → one pooled inverse FFT over
/// the pairs → real rows out.
fn c2r_tile(
    ctx: &ExecCtx,
    src_re: &[f64],
    src_im: &[f64],
    dst: &mut [f64],
    rows: usize,
    n: usize,
    nc: usize,
) {
    with_scratch(|s| {
        let pairs = rows.div_ceil(2);
        let (wre, wim) = s.pair(pairs * n);
        pack_spectra_tile(src_re, src_im, rows, n, nc, wre, wim);
        fft_rows_pooled(ctx, wre, wim, pairs, n, Direction::Inverse, 1);
        unpack_real_tile(wre, wim, rows, n, dst);
    });
}

/// The c2r row kernel — inverse of [`r2c_rows`] at exact length: turn
/// `rows` Hermitian-packed spectra rows (length `n/2+1`) back into real
/// rows of length `n`, two rows per complex inverse FFT.
pub fn c2r_rows(
    ctx: &ExecCtx,
    src_re: &[f64],
    src_im: &[f64],
    dst: &mut [f64],
    rows: usize,
    n: usize,
    threads: usize,
) {
    let nc = half_cols(n);
    debug_assert_eq!(src_re.len(), rows * nc);
    debug_assert_eq!(dst.len(), rows * n);
    if rows == 0 || n == 0 {
        return;
    }
    let mut tiles: Vec<(usize, usize, &mut [f64])> = Vec::new();
    let mut rest: &mut [f64] = dst;
    let mut r = 0usize;
    while r < rows {
        let len = DEFAULT_ROW_TILE.min(rows - r);
        let (d_t, d_n) = rest.split_at_mut(len * n);
        rest = d_n;
        tiles.push((r, len, d_t));
        r += len;
    }
    let threads = threads.max(1);
    if threads == 1 || tiles.len() == 1 {
        for (start, len, d_t) in tiles {
            c2r_tile(
                ctx,
                &src_re[start * nc..(start + len) * nc],
                &src_im[start * nc..(start + len) * nc],
                d_t,
                len,
                n,
                nc,
            );
        }
        return;
    }
    let per_job = tiles.len().div_ceil(threads.min(tiles.len()));
    let mut jobs: Vec<Job> = Vec::new();
    let mut it = tiles.into_iter();
    loop {
        let chunk: Vec<(usize, usize, &mut [f64])> = it.by_ref().take(per_job).collect();
        if chunk.is_empty() {
            break;
        }
        jobs.push(Box::new(move || {
            for (start, len, d_t) in chunk {
                c2r_tile(
                    ctx,
                    &src_re[start * nc..(start + len) * nc],
                    &src_im[start * nc..(start + len) * nc],
                    d_t,
                    len,
                    n,
                    nc,
                );
            }
        }));
    }
    ctx.run_jobs(jobs);
}

// ---------------------------------------------------------------------------
// The packed column phase
// ---------------------------------------------------------------------------

/// Complex FFTs down every stored column of the packed `n×(n/2+1)`
/// matrix — the fused pipeline's strided column tiles at the packed
/// stride (the shared [`fft_cols_fused_rect`] scheduler). Bit-identical
/// to the barrier (transpose) path: both feed the same logical column
/// vectors to the same row kernel.
pub fn rfft_cols_fused(ctx: &ExecCtx, packed: &mut SignalMatrix, dir: Direction, threads: usize) {
    let n = packed.rows;
    let nc = packed.cols;
    assert_eq!(nc, half_cols(n), "not a packed half spectrum");
    fft_cols_fused_rect(ctx, &mut packed.re, &mut packed.im, n, nc, n, dir, threads);
}

/// The barrier column phase: out-of-place transpose of the packed
/// rectangle, row FFTs over the `n/2+1` transposed rows, transpose
/// back. Kept as the fallback and the bit-exactness oracle.
pub fn rfft_cols_barrier(ctx: &ExecCtx, packed: &mut SignalMatrix, dir: Direction, threads: usize) {
    assert_eq!(packed.cols, half_cols(packed.rows), "not a packed half spectrum");
    let mut t = transposed(packed);
    fft_rows_pooled(ctx, &mut t.re, &mut t.im, t.rows, t.cols, dir, threads);
    *packed = transposed(&t);
}

// ---------------------------------------------------------------------------
// 2D drivers
// ---------------------------------------------------------------------------

/// Forward real 2D transform of an `n×n` real matrix into Hermitian-
/// packed `n×(n/2+1)` storage, under an explicit pipeline mode.
pub fn rfft2d_with_mode(m: &RealMatrix, threads: usize, mode: PipelineMode) -> SignalMatrix {
    assert_eq!(m.rows, m.cols, "square real matrix required");
    let n = m.rows;
    let nc = half_cols(n);
    let ctx = ExecCtx::global();
    let mut packed = SignalMatrix::zeros(n, nc);
    r2c_rows(ctx, &m.data, &mut packed.re, &mut packed.im, n, n, n, threads);
    match mode {
        PipelineMode::Fused => rfft_cols_fused(ctx, &mut packed, Direction::Forward, threads),
        PipelineMode::Barrier => rfft_cols_barrier(ctx, &mut packed, Direction::Forward, threads),
    }
    packed
}

/// [`rfft2d_with_mode`] under the process-wide default mode.
pub fn rfft2d(m: &RealMatrix, threads: usize) -> SignalMatrix {
    rfft2d_with_mode(m, threads, default_mode())
}

/// Inverse real 2D transform: packed `n×(n/2+1)` half spectrum back to
/// the `n×n` real signal. Exact inverse of the *unpadded* forward path.
/// Consumes the spectrum (the column phase runs in place) — the
/// borrowing convenience wrapper is [`irfft2d_with_mode`].
pub fn irfft2d_owned_with_mode(
    mut packed: SignalMatrix,
    threads: usize,
    mode: PipelineMode,
) -> RealMatrix {
    let n = packed.rows;
    assert_eq!(packed.cols, half_cols(n), "not a packed half spectrum");
    let ctx = ExecCtx::global();
    match mode {
        PipelineMode::Fused => rfft_cols_fused(ctx, &mut packed, Direction::Inverse, threads),
        PipelineMode::Barrier => rfft_cols_barrier(ctx, &mut packed, Direction::Inverse, threads),
    }
    let mut out = RealMatrix::zeros(n, n);
    c2r_rows(ctx, &packed.re, &packed.im, &mut out.data, n, n, threads);
    out
}

/// [`irfft2d_owned_with_mode`] over a borrowed spectrum (pays one
/// clone; the serving path uses the owned variant).
pub fn irfft2d_with_mode(packed: &SignalMatrix, threads: usize, mode: PipelineMode) -> RealMatrix {
    irfft2d_owned_with_mode(packed.clone(), threads, mode)
}

/// [`irfft2d_with_mode`] under the process-wide default mode.
pub fn irfft2d(packed: &SignalMatrix, threads: usize) -> RealMatrix {
    irfft2d_with_mode(packed, threads, default_mode())
}

/// Crop a full `n×n` spectrum to its packed `n×(n/2+1)` half — the c2c
/// oracle's view of what the real path must produce.
pub fn crop_to_packed(full: &SignalMatrix) -> SignalMatrix {
    assert_eq!(full.rows, full.cols, "square spectrum required");
    full.crop_cols(half_cols(full.rows))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dft::dft2d::dft2d_with_mode;

    fn rel_err(a: &SignalMatrix, b: &SignalMatrix) -> f64 {
        a.max_abs_diff(b) / b.norm().max(1.0)
    }

    /// c2c oracle for the packed forward transform: 2D-DFT the real
    /// embedding, keep the stored columns.
    fn oracle_packed(m: &RealMatrix) -> SignalMatrix {
        let mut full = embed_real(m);
        dft2d_with_mode(&mut full, Direction::Forward, 1, PipelineMode::Barrier);
        crop_to_packed(&full)
    }

    #[test]
    fn kind_names_and_parse() {
        assert_eq!(TransformKind::parse("c2c"), Some(TransformKind::C2c));
        assert_eq!(TransformKind::parse("real"), Some(TransformKind::R2c));
        assert_eq!(TransformKind::parse(" R2C "), Some(TransformKind::R2c));
        assert_eq!(TransformKind::parse("c2r"), Some(TransformKind::C2r));
        assert_eq!(TransformKind::parse("nope"), None);
        assert_eq!(TransformKind::C2r.plan_kind(), TransformKind::R2c);
        assert_eq!(TransformKind::C2c.plan_kind(), TransformKind::C2c);
        assert!(TransformKind::R2c.is_real() && !TransformKind::C2c.is_real());
        assert_eq!(TransformKind::R2c.flops_factor(), 0.5);
    }

    #[test]
    fn half_cols_even_and_odd() {
        assert_eq!(half_cols(8), 5);
        assert_eq!(half_cols(9), 5);
        assert_eq!(half_cols(1), 1);
    }

    #[test]
    fn pack_unpack_pair_recovers_row_spectra() {
        // the pair trick must reproduce each row's own FFT half spectrum
        let n = 16;
        let nc = half_cols(n);
        let m = RealMatrix::random(2, n, 3);
        let ctx = ExecCtx::new(1);
        let mut dre = vec![0.0; 2 * nc];
        let mut dim = vec![0.0; 2 * nc];
        r2c_rows(&ctx, &m.data, &mut dre, &mut dim, 2, n, n, 1);
        for r in 0..2 {
            let mut row = SignalMatrix::zeros(1, n);
            row.re.copy_from_slice(&m.data[r * n..(r + 1) * n]);
            let want = crate::dft::naive_dft_rows(&row, false);
            for k in 0..nc {
                let (wr, wi) = want.get(0, k);
                assert!(
                    (dre[r * nc + k] - wr).abs() < 1e-9 && (dim[r * nc + k] - wi).abs() < 1e-9,
                    "row {r} bin {k}"
                );
            }
        }
    }

    #[test]
    fn odd_row_count_leftover_row_correct() {
        let n = 8;
        let nc = half_cols(n);
        let m = RealMatrix::random(3, n, 5);
        let ctx = ExecCtx::new(1);
        let mut dre = vec![0.0; 3 * nc];
        let mut dim = vec![0.0; 3 * nc];
        r2c_rows(&ctx, &m.data, &mut dre, &mut dim, 3, n, n, 1);
        let mut row = SignalMatrix::zeros(1, n);
        row.re.copy_from_slice(&m.data[2 * n..3 * n]);
        let want = crate::dft::naive_dft_rows(&row, false);
        for k in 0..nc {
            let (wr, wi) = want.get(0, k);
            assert!((dre[2 * nc + k] - wr).abs() < 1e-9 && (dim[2 * nc + k] - wi).abs() < 1e-9);
        }
    }

    #[test]
    fn r2c_then_c2r_roundtrips_rows() {
        let n = 24;
        let nc = half_cols(n);
        let ctx = ExecCtx::new(2);
        for rows in [1usize, 2, 5, 8] {
            let m = RealMatrix::random(rows, n, rows as u64);
            let mut dre = vec![0.0; rows * nc];
            let mut dim = vec![0.0; rows * nc];
            r2c_rows(&ctx, &m.data, &mut dre, &mut dim, rows, n, n, 2);
            let mut back = vec![0.0; rows * n];
            c2r_rows(&ctx, &dre, &dim, &mut back, rows, n, 2);
            let err = m
                .data
                .iter()
                .zip(&back)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0, f64::max);
            assert!(err < 1e-10, "rows={rows}: {err}");
        }
    }

    #[test]
    fn rfft2d_matches_c2c_oracle_both_modes() {
        // even, odd, mixed-radix and Bluestein sizes; > one column tile
        for &n in &[8usize, 15, 24, 22, 96] {
            let m = RealMatrix::random(n, n, n as u64 + 2);
            let want = oracle_packed(&m);
            for mode in [PipelineMode::Fused, PipelineMode::Barrier] {
                let got = rfft2d_with_mode(&m, 3, mode);
                assert_eq!((got.rows, got.cols), (n, half_cols(n)));
                let err = rel_err(&got, &want);
                assert!(err < 1e-9, "n={n} {mode:?}: rel err {err}");
            }
        }
    }

    #[test]
    fn fused_matches_barrier_bitwise() {
        for &n in &[22usize, 24, 96] {
            let m = RealMatrix::random(n, n, n as u64 + 31);
            let fused = rfft2d_with_mode(&m, 4, PipelineMode::Fused);
            let barrier = rfft2d_with_mode(&m, 4, PipelineMode::Barrier);
            assert_eq!(fused.max_abs_diff(&barrier), 0.0, "n={n}");
        }
    }

    #[test]
    fn expand_packed_recovers_full_spectrum() {
        let n = 16;
        let m = RealMatrix::random(n, n, 9);
        let packed = rfft2d_with_mode(&m, 2, PipelineMode::Fused);
        let full = expand_packed(&packed);
        let mut want = embed_real(&m);
        dft2d_with_mode(&mut want, Direction::Forward, 1, PipelineMode::Barrier);
        let err = rel_err(&full, &want);
        assert!(err < 1e-9, "rel err {err}");
    }

    #[test]
    fn irfft2d_roundtrips_both_modes() {
        for &n in &[8usize, 15, 24, 96] {
            let m = RealMatrix::random(n, n, n as u64 + 77);
            for mode in [PipelineMode::Fused, PipelineMode::Barrier] {
                let packed = rfft2d_with_mode(&m, 2, mode);
                let back = irfft2d_with_mode(&packed, 2, mode);
                let err = back.max_abs_diff(&m) / m.norm().max(1.0);
                assert!(err < 1e-10, "n={n} {mode:?}: {err}");
            }
        }
    }

    #[test]
    fn thread_count_invariant_bitwise() {
        let n = 96;
        let m = RealMatrix::random(n, n, 13);
        let a = rfft2d_with_mode(&m, 1, PipelineMode::Fused);
        let b = rfft2d_with_mode(&m, 7, PipelineMode::Fused);
        assert_eq!(a.max_abs_diff(&b), 0.0);
    }

    #[test]
    fn padded_r2c_is_spectral_interpolation() {
        // r2c at pad v == c2c rows zero-padded to v, FFT, first nc bins
        let (rows, n, v) = (4usize, 16usize, 24usize);
        let nc = half_cols(n);
        let m = RealMatrix::random(rows, n, 11);
        let ctx = ExecCtx::new(1);
        let mut dre = vec![0.0; rows * nc];
        let mut dim = vec![0.0; rows * nc];
        r2c_rows(&ctx, &m.data, &mut dre, &mut dim, rows, n, v, 1);
        let mut emb = SignalMatrix::zeros(rows, n);
        emb.re.copy_from_slice(&m.data);
        let padded = emb.pad_cols(v);
        let want = crate::dft::naive_dft_rows(&padded, false);
        for r in 0..rows {
            for k in 0..nc {
                let (wr, wi) = want.get(r, k);
                assert!(
                    (dre[r * nc + k] - wr).abs() < 1e-9 && (dim[r * nc + k] - wi).abs() < 1e-9,
                    "row {r} bin {k}"
                );
            }
        }
    }
}
