//! Blocked matrix transpose — port of the paper's Appendix A
//! (`hcl_transpose_block`, block size 64) to the SoA split-plane layout,
//! plus a multithreaded variant (the paper's `PARALLEL_TRANSPOSE`).
//!
//! The in-place square transpose walks the upper triangle in b×b tiles and
//! swaps mirrored tiles; the diagonal tiles transpose in place. This is
//! the paper's cache-blocking scheme exactly (their `block_size=64` default
//! is kept; the sweep lives in `rust/benches/bench_transpose.rs`).
//!
//! On AVX2 machines the element moves inside each cache tile run
//! through the in-register 4×4 transpose kernels of
//! [`crate::dft::simd`] ([`crate::dft::simd::transpose_swap`] /
//! [`crate::dft::simd::transpose_diag`] for the in-place barrier path,
//! [`crate::dft::simd::transpose_block`] for the rectangular
//! out-of-place transpose the real c2r route uses); the scalar loops
//! below are the runtime-detected fallback. Transposition is pure data
//! movement, so the two paths are bit-identical always.

use crate::dft::simd;
use crate::dft::SignalMatrix;

/// Paper's default block size (Appendix A: "We use a block size of 64").
pub const DEFAULT_BLOCK: usize = 64;

/// In-place transpose of a square n×n split-plane matrix with blocking.
pub fn transpose_in_place(m: &mut SignalMatrix, block: usize) {
    assert_eq!(m.rows, m.cols, "in-place transpose requires square matrix");
    let n = m.rows;
    let b = block.max(1);
    let mut i = 0;
    while i < n {
        let ih = (i + b).min(n);
        // diagonal tile
        transpose_diag_tile(&mut m.re, n, i, ih);
        transpose_diag_tile(&mut m.im, n, i, ih);
        // off-diagonal tiles (swap mirrored pairs)
        let mut j = ih;
        while j < n {
            let jh = (j + b).min(n);
            swap_tiles(&mut m.re, n, i, ih, j, jh);
            swap_tiles(&mut m.im, n, i, ih, j, jh);
            j = jh;
        }
        i = ih;
    }
}

/// Transpose the diagonal tile rows [lo, hi) in place.
fn transpose_diag_tile(x: &mut [f64], n: usize, lo: usize, hi: usize) {
    debug_assert!(hi <= n && x.len() >= n * n);
    // SAFETY: `x` is the full n×n plane and the tile bounds are checked
    // above; the kernel swaps exactly the (r, c)/(c, r) pairs of the
    // scalar loop below.
    if unsafe { simd::transpose_diag(x.as_mut_ptr(), n, lo, hi) } {
        return;
    }
    for r in lo..hi {
        for c in (r + 1)..hi {
            x.swap(r * n + c, c * n + r);
        }
    }
}

/// Swap tile (ri.., cj..) with its mirror (cj.., ri..), transposing both.
fn swap_tiles(x: &mut [f64], n: usize, r0: usize, r1: usize, c0: usize, c1: usize) {
    debug_assert!(r1 <= n && c1 <= n && c0 >= r1 && x.len() >= n * n);
    // SAFETY: bounds checked above and the tile sits strictly above the
    // diagonal (`c0 >= r1`), so tile and mirror are disjoint as the
    // kernel requires.
    if unsafe { simd::transpose_swap(x.as_mut_ptr(), n, r0, r1, c0, c1) } {
        return;
    }
    for r in r0..r1 {
        for c in c0..c1 {
            x.swap(r * n + c, c * n + r);
        }
    }
}

/// Multithreaded in-place transpose: tile pairs are partitioned across
/// up to `threads` jobs on the shared [`crate::dft::exec::ExecCtx`]
/// pool — no per-call thread spawns (each tile pair touches a disjoint
/// index set, so the split-plane buffers can be shared mutably via raw
/// parts safely).
pub fn transpose_in_place_parallel(m: &mut SignalMatrix, block: usize, threads: usize) {
    assert_eq!(m.rows, m.cols);
    let n = m.rows;
    let b = block.max(1);
    if threads <= 1 || n < 2 * b {
        return transpose_in_place(m, block);
    }

    // enumerate tile jobs: (i, j) with j >= i, block-aligned
    let mut jobs: Vec<(usize, usize)> = Vec::new();
    let mut i = 0;
    while i < n {
        let mut j = i;
        while j < n {
            jobs.push((i, j));
            j += b;
        }
        i += b;
    }

    let re_ptr = SendPtr(m.re.as_mut_ptr());
    let im_ptr = SendPtr(m.im.as_mut_ptr());
    let jobs_per = jobs.len().div_ceil(threads);
    let mut tasks: Vec<crate::dft::exec::Job> = Vec::new();
    for chunk in jobs.chunks(jobs_per.max(1)) {
        let re_ptr = re_ptr;
        let im_ptr = im_ptr;
        tasks.push(Box::new(move || {
            // rebind the wrappers whole: 2021 precise capture would
            // otherwise capture only the (non-Send) pointer fields
            let (re_ptr, im_ptr) = (re_ptr, im_ptr);
            for &(ti, tj) in chunk {
                let ih = (ti + b).min(n);
                let jh = (tj + b).min(n);
                // SAFETY: each (ti, tj) tile pair touches indices
                // {(r,c), (c,r) : r in [ti,ih), c in [tj,jh)} which are
                // disjoint across jobs for ti <= tj block-aligned grid,
                // and ExecCtx::run_jobs does not return before every job
                // has finished.
                let re = unsafe { std::slice::from_raw_parts_mut(re_ptr.0, n * n) };
                let im = unsafe { std::slice::from_raw_parts_mut(im_ptr.0, n * n) };
                if ti == tj {
                    transpose_diag_tile(re, n, ti, ih);
                    transpose_diag_tile(im, n, ti, ih);
                } else {
                    swap_tiles(re, n, ti, ih, tj, jh);
                    swap_tiles(im, n, ti, ih, tj, jh);
                }
            }
        }));
    }
    crate::dft::exec::ExecCtx::global().run_jobs(tasks);
}

#[derive(Clone, Copy)]
struct SendPtr(*mut f64);
// SAFETY: jobs touch disjoint index sets (see above).
unsafe impl Send for SendPtr {}

/// Out-of-place transpose (works for rectangular matrices).
pub fn transposed(m: &SignalMatrix) -> SignalMatrix {
    let mut out = SignalMatrix::zeros(m.cols, m.rows);
    let b = DEFAULT_BLOCK;
    let mut i = 0;
    while i < m.rows {
        let ih = (i + b).min(m.rows);
        let mut j = 0;
        while j < m.cols {
            let jh = (j + b).min(m.cols);
            // SAFETY: the (ih-i) × (jh-j) source block and its
            // transposed destination block lie inside the two
            // allocations (`out` is cols × rows); pure data movement,
            // bit-identical to the scalar fallback.
            let did = unsafe {
                simd::transpose_block(
                    m.re.as_ptr().add(i * m.cols + j),
                    m.cols,
                    out.re.as_mut_ptr().add(j * m.rows + i),
                    m.rows,
                    ih - i,
                    jh - j,
                ) && simd::transpose_block(
                    m.im.as_ptr().add(i * m.cols + j),
                    m.cols,
                    out.im.as_mut_ptr().add(j * m.rows + i),
                    m.rows,
                    ih - i,
                    jh - j,
                )
            };
            if !did {
                for r in i..ih {
                    for c in j..jh {
                        let src = r * m.cols + c;
                        let dst = c * m.rows + r;
                        out.re[dst] = m.re[src];
                        out.im[dst] = m.im[src];
                    }
                }
            }
            j = jh;
        }
        i = ih;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn reference_transpose(m: &SignalMatrix) -> SignalMatrix {
        let mut out = SignalMatrix::zeros(m.cols, m.rows);
        for r in 0..m.rows {
            for c in 0..m.cols {
                let (re, im) = m.get(r, c);
                out.set(c, r, re, im);
            }
        }
        out
    }

    #[test]
    fn in_place_matches_reference() {
        for &n in &[1usize, 2, 63, 64, 65, 128, 130] {
            let orig = SignalMatrix::random(n, n, n as u64);
            let mut m = orig.clone();
            transpose_in_place(&mut m, 64);
            assert_eq!(m, reference_transpose(&orig), "n={n}");
        }
    }

    #[test]
    fn in_place_involution() {
        let orig = SignalMatrix::random(100, 100, 9);
        let mut m = orig.clone();
        transpose_in_place(&mut m, 32);
        transpose_in_place(&mut m, 32);
        assert_eq!(m, orig);
    }

    #[test]
    fn block_size_does_not_change_result() {
        let orig = SignalMatrix::random(96, 96, 2);
        for &b in &[1usize, 7, 16, 64, 200] {
            let mut m = orig.clone();
            transpose_in_place(&mut m, b);
            assert_eq!(m, reference_transpose(&orig), "block={b}");
        }
    }

    #[test]
    fn parallel_matches_serial() {
        for &(n, t) in &[(128usize, 2usize), (130, 3), (256, 4), (64, 8)] {
            let orig = SignalMatrix::random(n, n, 77);
            let mut a = orig.clone();
            let mut b = orig.clone();
            transpose_in_place(&mut a, 64);
            transpose_in_place_parallel(&mut b, 64, t);
            assert_eq!(a, b, "n={n} t={t}");
        }
    }

    #[test]
    fn out_of_place_rectangular() {
        let m = SignalMatrix::random(3, 7, 4);
        let t = transposed(&m);
        assert_eq!((t.rows, t.cols), (7, 3));
        for r in 0..3 {
            for c in 0..7 {
                assert_eq!(m.get(r, c), t.get(c, r));
            }
        }
    }

    #[test]
    fn out_of_place_rectangular_vector_blocks_and_rims() {
        // shapes straddling the 8/4/scalar block boundaries of the AVX2
        // kernel in both dimensions (and the packed-real 70×33 shape);
        // on non-AVX2 machines this still passes through the scalar path
        for &(rows, cols) in &[(13usize, 70usize), (70, 33), (8, 8), (9, 65)] {
            let m = SignalMatrix::random(rows, cols, (rows * cols) as u64);
            let t = transposed(&m);
            assert_eq!((t.rows, t.cols), (cols, rows));
            for r in 0..rows {
                for c in 0..cols {
                    assert_eq!(m.get(r, c), t.get(c, r), "{rows}x{cols} at ({r},{c})");
                }
            }
        }
    }
}
