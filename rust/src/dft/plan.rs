//! Cached FFT plans — the plan-once/execute-many analogue of
//! `fftw_plan_many_dft` (paper Algorithm 6).
//!
//! A [`crate::dft::radix::RadixPlan`] holds the factor schedule and
//! per-stage twiddles for any 5-smooth length (the generalized plan
//! behind [`RowPlan`]); a [`Pow2Plan`] holds the forward twiddle table
//! for a power-of-two length (used by Bluestein's internal convolution
//! FFTs); a [`BluesteinPlan`](crate::dft::bluestein::BluesteinPlan)
//! holds the chirp sequences and padded pow2 sub-plan for the remaining
//! (non-smooth) lengths. [`PlanCache`] memoizes all three behind
//! mutexes so abstract-processor threads share tables (twiddle
//! construction is O(n) but shows up hard in profiles when executed per
//! call — see EXPERIMENTS.md §Perf), and [`PlanCache::row_plan`] is the
//! single dispatch point deciding which kernel a row length gets.
//!
//! Radix plans additionally dedupe *per-stage twiddle tables* across
//! cache entries: a stage table depends only on `(radix, n_cur)`, so
//! plans whose schedules pass through the same geometry (384 and 768
//! share five of six stage tables) hold `Arc`s into one process-wide
//! table cache — see `radix::StageTwiddles`. The counting-allocator
//! audit in `rust/tests/exec_steadystate.rs` asserts the sharing.

use std::collections::HashMap;
use std::sync::{Arc, Mutex, OnceLock};

use crate::dft::radix::{is_five_smooth, RadixPlan};

/// Twiddle table for a power-of-two FFT: `tw[k] = exp(-2πi k / n)` for
/// k in [0, n/2).
#[derive(Clone, Debug)]
pub struct Pow2Plan {
    pub n: usize,
    tw_re: Vec<f64>,
    tw_im: Vec<f64>,
}

impl Pow2Plan {
    pub fn new(n: usize) -> Self {
        assert!(n.is_power_of_two(), "Pow2Plan requires power-of-two n, got {n}");
        let half = (n / 2).max(1);
        let mut tw_re = Vec::with_capacity(half);
        let mut tw_im = Vec::with_capacity(half);
        for k in 0..half {
            let ang = -2.0 * std::f64::consts::PI * k as f64 / n as f64;
            tw_re.push(ang.cos());
            tw_im.push(ang.sin());
        }
        Pow2Plan { n, tw_re, tw_im }
    }

    /// Forward twiddle `exp(-2πi k / n)`, k < n/2.
    #[inline]
    pub fn twiddle(&self, k: usize) -> (f64, f64) {
        (self.tw_re[k], self.tw_im[k])
    }

    /// The full forward twiddle planes (k < n/2), contiguous. The first
    /// DIF stage reads `tw[p]` directly (twiddle stride 1), which is
    /// what the AVX2 stage-2 kernel consumes as packed lanes.
    #[inline]
    pub(crate) fn twiddles(&self) -> (&[f64], &[f64]) {
        (&self.tw_re, &self.tw_im)
    }
}

/// The memoized kernel choice for one row length: mixed-radix for
/// 5-smooth lengths, Bluestein for everything else.
#[derive(Clone)]
pub enum RowPlan {
    Radix(Arc<RadixPlan>),
    Bluestein(Arc<crate::dft::bluestein::BluesteinPlan>),
}

impl RowPlan {
    /// The row length this plan transforms.
    pub fn n(&self) -> usize {
        match self {
            RowPlan::Radix(p) => p.n,
            RowPlan::Bluestein(p) => p.n,
        }
    }

    /// Kernel label for reports ("mixed-radix" / "bluestein").
    pub fn kernel(&self) -> &'static str {
        match self {
            RowPlan::Radix(_) => "mixed-radix",
            RowPlan::Bluestein(_) => "bluestein",
        }
    }

    /// The factor schedule (empty for Bluestein lengths).
    pub fn factors(&self) -> Vec<usize> {
        match self {
            RowPlan::Radix(p) => p.factors.clone(),
            RowPlan::Bluestein(_) => Vec::new(),
        }
    }
}

/// Process-wide plan cache (radix/pow2/Bluestein plans keyed by n).
#[derive(Default)]
pub struct PlanCache {
    radix: Mutex<HashMap<usize, Arc<RadixPlan>>>,
    pow2: Mutex<HashMap<usize, Arc<Pow2Plan>>>,
    bluestein: Mutex<HashMap<usize, Arc<crate::dft::bluestein::BluesteinPlan>>>,
}

impl PlanCache {
    pub fn global() -> &'static PlanCache {
        static CACHE: OnceLock<PlanCache> = OnceLock::new();
        CACHE.get_or_init(PlanCache::default)
    }

    pub fn pow2(&self, n: usize) -> Arc<Pow2Plan> {
        let mut map = self.pow2.lock().unwrap();
        map.entry(n).or_insert_with(|| Arc::new(Pow2Plan::new(n))).clone()
    }

    /// Mixed-radix plan for a 5-smooth length (panics otherwise).
    pub fn radix(&self, n: usize) -> Arc<RadixPlan> {
        let mut map = self.radix.lock().unwrap();
        map.entry(n).or_insert_with(|| Arc::new(RadixPlan::new(n))).clone()
    }

    /// The executor's dispatch: the right kernel plan for a row length.
    pub fn row_plan(&self, n: usize) -> RowPlan {
        if is_five_smooth(n) {
            RowPlan::Radix(self.radix(n))
        } else {
            RowPlan::Bluestein(self.bluestein(n))
        }
    }

    pub fn bluestein(&self, n: usize) -> Arc<crate::dft::bluestein::BluesteinPlan> {
        let mut map = self.bluestein.lock().unwrap();
        map.entry(n)
            .or_insert_with(|| Arc::new(crate::dft::bluestein::BluesteinPlan::new(n)))
            .clone()
    }

    /// Number of cached pow2 plans (test hook).
    pub fn pow2_len(&self) -> usize {
        self.pow2.lock().unwrap().len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn twiddle_values() {
        let p = Pow2Plan::new(8);
        let (re, im) = p.twiddle(0);
        assert!((re - 1.0).abs() < 1e-15 && im.abs() < 1e-15);
        let (re, im) = p.twiddle(2); // exp(-i π/2) = -i
        assert!(re.abs() < 1e-15 && (im + 1.0).abs() < 1e-15);
    }

    #[test]
    #[should_panic(expected = "power-of-two")]
    fn rejects_non_pow2() {
        Pow2Plan::new(24);
    }

    #[test]
    fn cache_shares_plans() {
        let cache = PlanCache::default();
        let a = cache.pow2(64);
        let b = cache.pow2(64);
        assert!(Arc::ptr_eq(&a, &b));
        let _c = cache.pow2(128);
        assert_eq!(cache.pow2_len(), 2);
    }

    #[test]
    fn global_cache_is_singleton() {
        let a = PlanCache::global().pow2(32);
        let b = PlanCache::global().pow2(32);
        assert!(Arc::ptr_eq(&a, &b));
    }

    #[test]
    fn row_plan_dispatches_by_smoothness() {
        let cache = PlanCache::default();
        // 5-smooth (including non-pow2 paper sizes) → mixed-radix
        for &n in &[64usize, 384, 640, 1152] {
            let p = cache.row_plan(n);
            assert!(matches!(p, RowPlan::Radix(_)), "n={n}");
            assert_eq!(p.kernel(), "mixed-radix");
            assert_eq!(p.n(), n);
            assert!(!p.factors().is_empty());
        }
        // non-smooth (prime factor > 5) → Bluestein fallback
        for &n in &[7usize, 896, 1000 * 7 + 3] {
            let p = cache.row_plan(n);
            assert!(matches!(p, RowPlan::Bluestein(_)), "n={n}");
            assert_eq!(p.kernel(), "bluestein");
            assert!(p.factors().is_empty());
        }
        // cached: same Arc comes back
        let a = cache.row_plan(384);
        let b = cache.row_plan(384);
        if let (RowPlan::Radix(pa), RowPlan::Radix(pb)) = (&a, &b) {
            assert!(Arc::ptr_eq(pa, pb));
        } else {
            panic!("expected radix plans");
        }
    }
}
