//! Native FFT substrate — the from-scratch compute engine.
//!
//! The paper's experiments run FFTW / Intel MKL under the coordinator;
//! neither exists here, so this module provides the multithreaded 2D-DFT
//! compute engine the coordinator drives on the real machine:
//!
//! * [`plan`] — cached FFT plans (twiddle tables, factor schedules,
//!   Bluestein state): the analogue of `fftw_plan_many_dft` (Algorithm
//!   6's plan/execute/destroy becomes plan-once/execute-many, see
//!   DESIGN.md §Perf); [`plan::PlanCache::row_plan`] is the single
//!   kernel-dispatch point,
//! * [`radix`] — the mixed-radix (2/3/5) Stockham DIF kernel: every
//!   5-smooth length — which includes most of the paper's N = 128·k
//!   grid (384 = 2⁷·3, 640 = 2⁷·5, 1152 = 2⁷·3², …) — runs natively in
//!   O(n log n); its vectorized schedule fuses the last pow2 stages
//!   into hardcoded-twiddle FFT2/4/8 tail codelets,
//! * [`simd`] — opt-in (`--features simd`) AVX2 kernels, runtime-
//!   detected with a safe scalar fallback and bit-identical output:
//!   the narrow-stride radix-2 stages, the 4×4/8×8 in-register tile
//!   transposes behind the column-phase gather/scatter and the blocked
//!   transpose, and the cross-row vectorization of the stride-1
//!   odd-radix stages (4 rows per vector),
//! * [`fft`] — iterative Stockham radix-2 (same algorithm as the L1
//!   Pallas kernel, so the two implementations cross-check each other;
//!   still the engine behind Bluestein's internal convolution FFTs),
//! * [`bluestein`] — chirp-z fallback for the remaining *non-smooth*
//!   lengths (primes etc.): pads to a ≥ 2N power of two, three pow2
//!   FFTs per row — correct for any N, ~5-6x the flops of mixed-radix,
//! * [`exec`] — the shared execution context (`ExecCtx`): one
//!   persistent worker pool + per-thread scratch arenas; its
//!   [`exec::fft_rows_pooled`] is the single row-FFT entry point every
//!   layer (engine, drivers, service) dispatches through,
//! * [`transpose`] — the paper's Appendix A blocked in-place transpose
//!   (parallel variant runs on the shared pool),
//! * [`pipeline`] — the fused tiled 2D pipeline: a stage-DAG tile
//!   scheduler on the shared pool plus strided column FFTs (per-tile
//!   SIMD transpose-gather into scratch) that replace the global
//!   transpose barriers; the barrier path survives as
//!   [`pipeline::PipelineMode::Barrier`],
//! * [`real`] — the real-input (r2c / c2r) path: two real rows packed
//!   into one complex FFT (Hermitian unpack), `N×(N/2+1)` packed
//!   half-spectrum storage, fused tile schedules for the packed column
//!   phase — roughly half the flops and memory traffic of c2c for the
//!   dominant real-valued workloads,
//! * [`dft2d`] — the row-column 2D-DFT driver with thread groups.
//!
//! Layout is SoA split planes (`re`, `im` as separate slices), matching
//! the L1/L2 representation, with `f64` precision so the native engine
//! doubles as a numeric oracle for the f32 PJRT artifacts.

pub mod bluestein;
pub mod dft2d;
pub mod dft3d;
pub mod exec;
pub mod fft;
pub mod pipeline;
pub mod plan;
pub mod radix;
pub mod real;
pub mod simd;
pub mod transpose;

/// A complex matrix in SoA split-plane layout, row-major.
#[derive(Clone, Debug, PartialEq)]
pub struct SignalMatrix {
    pub rows: usize,
    pub cols: usize,
    pub re: Vec<f64>,
    pub im: Vec<f64>,
}

impl SignalMatrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        SignalMatrix { rows, cols, re: vec![0.0; rows * cols], im: vec![0.0; rows * cols] }
    }

    /// Deterministic random matrix for tests/benches.
    pub fn random(rows: usize, cols: usize, seed: u64) -> Self {
        let mut rng = crate::util::prng::Xoshiro256::seeded(seed);
        let mut m = SignalMatrix::zeros(rows, cols);
        for v in m.re.iter_mut().chain(m.im.iter_mut()) {
            *v = rng.next_f64() * 2.0 - 1.0;
        }
        m
    }

    /// Deterministic random *real* matrix (zero imaginary plane) — the
    /// r2c request payload for tests/benches.
    pub fn random_real(rows: usize, cols: usize, seed: u64) -> Self {
        let mut rng = crate::util::prng::Xoshiro256::seeded(seed);
        let mut m = SignalMatrix::zeros(rows, cols);
        for v in m.re.iter_mut() {
            *v = rng.next_f64() * 2.0 - 1.0;
        }
        m
    }

    #[inline]
    pub fn idx(&self, r: usize, c: usize) -> usize {
        r * self.cols + c
    }

    pub fn get(&self, r: usize, c: usize) -> (f64, f64) {
        let i = self.idx(r, c);
        (self.re[i], self.im[i])
    }

    pub fn set(&mut self, r: usize, c: usize, re: f64, im: f64) {
        let i = self.idx(r, c);
        self.re[i] = re;
        self.im[i] = im;
    }

    /// Max |elementwise difference| against another matrix.
    pub fn max_abs_diff(&self, other: &SignalMatrix) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.re
            .iter()
            .zip(&other.re)
            .chain(self.im.iter().zip(&other.im))
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }

    /// Frobenius norm (for relative-error checks).
    pub fn norm(&self) -> f64 {
        self.re
            .iter()
            .zip(&self.im)
            .map(|(r, i)| r * r + i * i)
            .sum::<f64>()
            .sqrt()
    }

    /// Copy this matrix into the top-left corner of a (rows, new_cols)
    /// zero matrix — the PFFT-FPM-PAD row-padding primitive.
    pub fn pad_cols(&self, new_cols: usize) -> SignalMatrix {
        assert!(new_cols >= self.cols);
        let mut out = SignalMatrix::zeros(self.rows, new_cols);
        for r in 0..self.rows {
            let src = r * self.cols..(r + 1) * self.cols;
            let dst = r * new_cols..r * new_cols + self.cols;
            out.re[dst.clone()].copy_from_slice(&self.re[src.clone()]);
            out.im[dst].copy_from_slice(&self.im[src]);
        }
        out
    }

    /// Inverse of [`pad_cols`]: take the left `new_cols` columns.
    pub fn crop_cols(&self, new_cols: usize) -> SignalMatrix {
        assert!(new_cols <= self.cols);
        let mut out = SignalMatrix::zeros(self.rows, new_cols);
        for r in 0..self.rows {
            let src = r * self.cols..r * self.cols + new_cols;
            let dst = r * new_cols..(r + 1) * new_cols;
            out.re[dst.clone()].copy_from_slice(&self.re[src.clone()]);
            out.im[dst].copy_from_slice(&self.im[src]);
        }
        out
    }
}

/// Naive O(N^2)-per-row DFT oracle (paper Section III-A definition).
/// Slow by design; used only in tests.
pub fn naive_dft_rows(m: &SignalMatrix, inverse: bool) -> SignalMatrix {
    let n = m.cols;
    let sign = if inverse { 2.0 } else { -2.0 };
    let mut out = SignalMatrix::zeros(m.rows, n);
    for r in 0..m.rows {
        for k in 0..n {
            let (mut sr, mut si) = (0.0f64, 0.0f64);
            for j in 0..n {
                let ang = sign * std::f64::consts::PI * (k as f64) * (j as f64) / n as f64;
                let (wr, wi) = (ang.cos(), ang.sin());
                let (xr, xi) = m.get(r, j);
                sr += xr * wr - xi * wi;
                si += xr * wi + xi * wr;
            }
            if inverse {
                sr /= n as f64;
                si /= n as f64;
            }
            out.set(r, k, sr, si);
        }
    }
    out
}

/// Naive full 2D-DFT oracle: row DFTs then column DFTs.
pub fn naive_dft2d(m: &SignalMatrix) -> SignalMatrix {
    assert_eq!(m.rows, m.cols, "square signal matrix required");
    let rowed = naive_dft_rows(m, false);
    let mut t = transpose::transposed(&rowed);
    t = naive_dft_rows(&t, false);
    transpose::transposed(&t)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pad_and_crop_roundtrip() {
        let m = SignalMatrix::random(3, 5, 1);
        let padded = m.pad_cols(8);
        assert_eq!((padded.rows, padded.cols), (3, 8));
        // padded region is zero
        for r in 0..3 {
            for c in 5..8 {
                assert_eq!(padded.get(r, c), (0.0, 0.0));
            }
        }
        assert_eq!(padded.crop_cols(5), m);
    }

    #[test]
    fn naive_dft_impulse() {
        let mut m = SignalMatrix::zeros(1, 4);
        m.set(0, 0, 1.0, 0.0);
        let f = naive_dft_rows(&m, false);
        for c in 0..4 {
            let (re, im) = f.get(0, c);
            assert!((re - 1.0).abs() < 1e-12 && im.abs() < 1e-12);
        }
    }

    #[test]
    fn naive_dft_roundtrip() {
        let m = SignalMatrix::random(2, 6, 3);
        let f = naive_dft_rows(&m, false);
        let b = naive_dft_rows(&f, true);
        assert!(m.max_abs_diff(&b) < 1e-10);
    }

    #[test]
    fn norm_and_diff() {
        let mut a = SignalMatrix::zeros(1, 2);
        a.set(0, 0, 3.0, 4.0);
        assert!((a.norm() - 5.0).abs() < 1e-12);
        let b = SignalMatrix::zeros(1, 2);
        assert_eq!(a.max_abs_diff(&b), 4.0);
    }
}
