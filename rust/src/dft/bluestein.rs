//! Arbitrary-length FFT via Bluestein's chirp-z transform — the
//! executor's fallback for *non-smooth* lengths.
//!
//! Lengths whose prime factors are all in {2, 3, 5} run the native
//! mixed-radix kernel ([`crate::dft::radix`]) instead; this module
//! handles everything else (primes, 128·7 = 896, 128·193 = 24704, …),
//! where no small-radix schedule exists:
//!
//!   X_k = b*_k · Σ_j (a_j · b*_j) · b_{k-j},   b_j = exp(iπ j²/n)
//!
//! i.e. a length-n DFT becomes one circular convolution of length
//! m ≥ 2n−1 (m a power of two), computed with three pow2 FFTs. The
//! chirp sequences and the pre-transformed kernel are cached per n in
//! [`BluesteinPlan`].

use crate::dft::fft::{fft_row_pow2, Direction};
use crate::dft::plan::Pow2Plan;

/// Precomputed chirp state for a length-`n` Bluestein transform.
#[derive(Clone, Debug)]
pub struct BluesteinPlan {
    pub n: usize,
    /// Padded convolution length (power of two ≥ 2n-1).
    pub m: usize,
    /// chirp b_j = exp(-iπ j²/n) for forward transforms, j in [0, n).
    chirp_re: Vec<f64>,
    chirp_im: Vec<f64>,
    /// FFT of the convolution kernel (conj chirp, wrapped), length m.
    kernel_re: Vec<f64>,
    kernel_im: Vec<f64>,
    sub: Pow2Plan,
}

impl BluesteinPlan {
    pub fn new(n: usize) -> Self {
        assert!(n >= 1);
        let m = (2 * n - 1).next_power_of_two();
        let sub = Pow2Plan::new(m);

        // forward chirp: b_j = exp(-iπ j² / n)
        let mut chirp_re = vec![0.0; n];
        let mut chirp_im = vec![0.0; n];
        for j in 0..n {
            // j² mod 2n to keep the angle argument small (exactness)
            let jsq = (j * j) % (2 * n);
            let ang = -std::f64::consts::PI * jsq as f64 / n as f64;
            chirp_re[j] = ang.cos();
            chirp_im[j] = ang.sin();
        }

        // kernel c_j = conj(b_j) wrapped circularly: c[0]=b*_0,
        // c[j] = c[m-j] = b*_j for j in [1, n)
        let mut kernel_re = vec![0.0; m];
        let mut kernel_im = vec![0.0; m];
        for j in 0..n {
            kernel_re[j] = chirp_re[j];
            kernel_im[j] = -chirp_im[j];
            if j > 0 {
                kernel_re[m - j] = chirp_re[j];
                kernel_im[m - j] = -chirp_im[j];
            }
        }
        // pre-transform the kernel
        let mut sr = vec![0.0; m];
        let mut si = vec![0.0; m];
        fft_row_pow2(&mut kernel_re, &mut kernel_im, &mut sr, &mut si, &sub, Direction::Forward);

        BluesteinPlan { n, m, chirp_re, chirp_im, kernel_re, kernel_im, sub }
    }

    /// Scratch buffer length needed by [`fft_row_bluestein`] (4 buffers
    /// of this length).
    pub fn scratch_len(&self) -> usize {
        self.m
    }
}

/// Transform one length-`n` row (arbitrary n) in place using `plan` and
/// four caller-provided scratch buffers of length `plan.m`.
pub fn fft_row_bluestein(
    re: &mut [f64],
    im: &mut [f64],
    plan: &BluesteinPlan,
    dir: Direction,
    buf_re: &mut [f64],
    buf_im: &mut [f64],
    scr_re: &mut [f64],
    scr_im: &mut [f64],
) {
    let n = plan.n;
    let m = plan.m;
    debug_assert_eq!(re.len(), n);
    debug_assert_eq!(buf_re.len(), m);

    // inverse transform via conj-forward-conj: ifft(x) = conj(fft(conj(x)))/n
    if dir == Direction::Inverse {
        for v in im.iter_mut() {
            *v = -*v;
        }
    }

    // a_j * b_j  (chirp-premultiply), zero-pad to m
    for j in 0..n {
        let (ar, ai) = (re[j], im[j]);
        let (br, bi) = (plan.chirp_re[j], plan.chirp_im[j]);
        buf_re[j] = ar * br - ai * bi;
        buf_im[j] = ar * bi + ai * br;
    }
    for j in n..m {
        buf_re[j] = 0.0;
        buf_im[j] = 0.0;
    }

    // convolution via pow2 FFT: fft(buf) * kernel_fft, then ifft
    fft_row_pow2(buf_re, buf_im, scr_re, scr_im, &plan.sub, Direction::Forward);
    for j in 0..m {
        let (xr, xi) = (buf_re[j], buf_im[j]);
        let (kr, ki) = (plan.kernel_re[j], plan.kernel_im[j]);
        buf_re[j] = xr * kr - xi * ki;
        buf_im[j] = xr * ki + xi * kr;
    }
    fft_row_pow2(buf_re, buf_im, scr_re, scr_im, &plan.sub, Direction::Inverse);

    // chirp-postmultiply and write back
    for k in 0..n {
        let (br, bi) = (plan.chirp_re[k], plan.chirp_im[k]);
        let (xr, xi) = (buf_re[k], buf_im[k]);
        re[k] = xr * br - xi * bi;
        im[k] = xr * bi + xi * br;
    }

    if dir == Direction::Inverse {
        let inv_n = 1.0 / n as f64;
        for k in 0..n {
            re[k] *= inv_n;
            im[k] = -im[k] * inv_n;
        }
    }
}

/// Batched arbitrary-length row FFT (allocates scratch once).
pub fn fft_rows(re: &mut [f64], im: &mut [f64], rows: usize, n: usize, dir: Direction) {
    if n.is_power_of_two() {
        crate::dft::fft::fft_rows_pow2(re, im, rows, n, dir);
        return;
    }
    let plan = crate::dft::plan::PlanCache::global().bluestein(n);
    let m = plan.scratch_len();
    let mut buf_re = vec![0.0; m];
    let mut buf_im = vec![0.0; m];
    let mut scr_re = vec![0.0; m];
    let mut scr_im = vec![0.0; m];
    for r in 0..rows {
        let span = r * n..(r + 1) * n;
        fft_row_bluestein(
            &mut re[span.clone()],
            &mut im[span],
            &plan,
            dir,
            &mut buf_re,
            &mut buf_im,
            &mut scr_re,
            &mut scr_im,
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dft::{naive_dft_rows, SignalMatrix};

    fn bluestein_matrix(m: &SignalMatrix, dir: Direction) -> SignalMatrix {
        let mut out = m.clone();
        fft_rows(&mut out.re, &mut out.im, m.rows, m.cols, dir);
        out
    }

    #[test]
    fn matches_naive_on_paper_sizes() {
        // paper grid sizes are multiples of 128 — not powers of two
        for &n in &[3usize, 5, 12, 24, 100, 128, 192, 320, 448] {
            let m = SignalMatrix::random(2, n, n as u64 + 1);
            let got = bluestein_matrix(&m, Direction::Forward);
            let want = naive_dft_rows(&m, false);
            let scale = want.norm().max(1.0);
            assert!(
                got.max_abs_diff(&want) / scale < 1e-9,
                "n={n}: rel diff {}",
                got.max_abs_diff(&want) / scale
            );
        }
    }

    #[test]
    fn pow2_fast_path_taken() {
        // power-of-two goes through radix-2; result must still match naive
        let m = SignalMatrix::random(1, 64, 11);
        let got = bluestein_matrix(&m, Direction::Forward);
        let want = naive_dft_rows(&m, false);
        assert!(got.max_abs_diff(&want) < 1e-9);
    }

    #[test]
    fn inverse_roundtrip_arbitrary_n() {
        for &n in &[7usize, 48, 192, 1000] {
            let m = SignalMatrix::random(2, n, 3);
            let f = bluestein_matrix(&m, Direction::Forward);
            let b = bluestein_matrix(&f, Direction::Inverse);
            assert!(m.max_abs_diff(&b) < 1e-9, "n={n}: {}", m.max_abs_diff(&b));
        }
    }

    #[test]
    fn n_equals_one_is_identity() {
        let m = SignalMatrix::random(3, 1, 5);
        let got = bluestein_matrix(&m, Direction::Forward);
        assert!(m.max_abs_diff(&got) < 1e-15);
    }

    #[test]
    fn plan_pads_to_pow2() {
        let p = BluesteinPlan::new(192);
        assert!(p.m.is_power_of_two());
        assert!(p.m >= 2 * 192 - 1);
    }
}
