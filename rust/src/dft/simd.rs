//! Opt-in AVX2/FMA fast paths for the mixed-radix butterfly stages and
//! the FFT4/FFT8 tail codelets (`simd` / `fma` cargo features, x86_64
//! only).
//!
//! The scalar stage loops in [`crate::dft::radix`] / [`crate::dft::fft`]
//! autovectorize when the lane width `stride` is large, but the *first*
//! stages of the reordered schedule run at `stride` 1 and 2 — there the
//! per-`q` lane loop degenerates to scalar code — and even the wide
//! stages never contract to FMA on their own (rustc does not fuse
//! `a*b + c` without explicit intrinsics). This module provides explicit
//! `core::arch` kernels for every stage shape of all three radixes:
//!
//! * **stride 1** — radix-2 and radix-3: four butterflies per
//!   iteration; contiguous loads, twiddles deinterleaved with
//!   `unpack*` + `permute4x64`, and the element-interleaved outputs
//!   rebuilt with lane permutes (+ blends for the 3-way scatter).
//! * **stride 2** — radix-2/3/5: two butterflies (four lanes) per
//!   iteration; outputs interleave at 128-bit granularity so
//!   `permute2f128` pairs suffice, and each butterfly's twiddle is
//!   duplicated across its two lanes with `permute4x64`.
//! * **stride ≥ 4 (wide)** — radix-2/3/5: the lane loop itself is
//!   vectorized four `q` at a time with broadcast per-butterfly
//!   twiddles; no shuffles at all. This is what runs on the large-
//!   stride radix-3/5 stages of the paper sizes (384 = 2⁷·3 runs its
//!   radix-3 stage at stride 16).
//! * **tail codelets** — the fused FFT4/FFT8 tail sweep
//!   ([`crate::dft::radix::tail_codelet`]) processes four lanes `q` per
//!   iteration. Pure elementwise arithmetic across the `s`-strided
//!   chunks, so the same kernel serves the in-place and out-of-place
//!   forms (all loads precede all stores per lane group).
//!
//! # Bit-exactness and the FMA generation
//!
//! The **plain** (non-FMA) kernels perform the *same* IEEE-754
//! operations in the same order as the scalar loops — mul, mul, sub/add
//! per complex multiply, never FMA — so their output is bit-identical
//! to scalar output. That keeps the repo's thread-count invariance and
//! fused==barrier bit-exactness properties intact per kernel variant,
//! and lets tests assert exact equality between the scalar and SIMD
//! paths.
//!
//! With `--features fma` (and runtime FMA support) the stage kernels
//! are instead generated with `fmadd/fmsub/fnmadd`, which contract each
//! multiply-accumulate to a single rounding. That output **cannot** be
//! bit-identical to the plain kernels, so the FMA build is a distinct
//! [`crate::dft::radix::kernel_generation`] (wisdom records re-measure
//! across the switch) and is accuracy-tested against the scalar kernel
//! within 1e-12 relative error instead of asserted equal. Thread-count
//! invariance still holds bitwise *within* the FMA generation: the
//! executor may split a stage at any butterfly boundary, which moves
//! butterflies between the vector body and the scalar remainder — so
//! the FMA remainders use `f64::mul_add` with exactly the association
//! of the vector fmadd/fmsub, making every element's arithmetic
//! independent of where the split lands. The tail codelets contain no
//! multiply-accumulate chains worth fusing and are generated once,
//! bit-identical to scalar in both generations.
//!
//! Selection is at runtime: [`avx2_enabled`] / [`fma_enabled`] cache
//! one `is_x86_feature_detected!` probe each; non-AVX2 machines (and
//! non-x86_64 builds, and builds without the features) fall back to the
//! safe scalar loops with zero overhead beyond one branch per stage.

/// Is the AVX2 fast path compiled in *and* available on this CPU?
/// Always `false` without the `simd` feature or off x86_64.
pub fn avx2_enabled() -> bool {
    imp::avx2_enabled()
}

/// Is the FMA kernel generation compiled in (`fma` feature) *and*
/// available on this CPU? Implies [`avx2_enabled`].
pub fn fma_enabled() -> bool {
    imp::fma_enabled()
}

/// Try to run one radix-2 DIF stage over butterflies `p ∈ [p_lo, p_hi)`
/// with the AVX2 kernels. Returns `false` (having done nothing) when
/// the fast path is unavailable or the stage shape is not one it
/// handles; the caller then runs the scalar loop. Slice conventions
/// match [`crate::dft::radix::apply_stage_range`]: `src` planes are the
/// full row, `dst` planes start at the range's first output block, and
/// `tw[p]` is the stage twiddle for butterfly `p` (conjugated via
/// `sign` for the inverse transform).
#[allow(clippy::too_many_arguments)]
pub(crate) fn try_stage2(
    sign: f64,
    tw_re: &[f64],
    tw_im: &[f64],
    src_re: &[f64],
    src_im: &[f64],
    dst_re: &mut [f64],
    dst_im: &mut [f64],
    p_lo: usize,
    p_hi: usize,
    m: usize,
    stride: usize,
) -> bool {
    imp::try_stage2(sign, tw_re, tw_im, src_re, src_im, dst_re, dst_im, p_lo, p_hi, m, stride)
}

/// Radix-3 counterpart of [`try_stage2`]; `tw[2p]`/`tw[2p+1]` are the
/// k = 1, 2 twiddles of butterfly `p`. Handles stride 1, 2 and ≥ 4.
#[allow(clippy::too_many_arguments)]
pub(crate) fn try_stage3(
    sign: f64,
    tw_re: &[f64],
    tw_im: &[f64],
    src_re: &[f64],
    src_im: &[f64],
    dst_re: &mut [f64],
    dst_im: &mut [f64],
    p_lo: usize,
    p_hi: usize,
    m: usize,
    stride: usize,
) -> bool {
    imp::try_stage3(sign, tw_re, tw_im, src_re, src_im, dst_re, dst_im, p_lo, p_hi, m, stride)
}

/// Radix-5 counterpart of [`try_stage2`]; `tw[4p..4p+4]` are the
/// k = 1..4 twiddles of butterfly `p`. Handles stride 2 and ≥ 4 (the
/// stride-1/3 shapes occur only on pure 3^a·5^b lengths and stay
/// scalar).
#[allow(clippy::too_many_arguments)]
pub(crate) fn try_stage5(
    sign: f64,
    tw_re: &[f64],
    tw_im: &[f64],
    src_re: &[f64],
    src_im: &[f64],
    dst_re: &mut [f64],
    dst_im: &mut [f64],
    p_lo: usize,
    p_hi: usize,
    m: usize,
    stride: usize,
) -> bool {
    imp::try_stage5(sign, tw_re, tw_im, src_re, src_im, dst_re, dst_im, p_lo, p_hi, m, stride)
}

/// Cross-row radix-3 stage kernel for a **4-row tile** at stride 1
/// (the shape [`try_stage3`] handles per-row, vectorized here *across*
/// rows instead): each group of four butterflies loads unit-stride
/// quads from all four rows, 4×4-transposes them into row-lane
/// vectors, runs the scalar-order butterfly with broadcast twiddles,
/// and transposes back to unit-stride stores. Plane layout is four
/// contiguous length-`n` rows (`n = 3m`, the stage spans the whole
/// row since stride-1 stages come first). Returns how many butterflies
/// (a multiple of 4) were processed for *all* rows — the caller
/// finishes `[done, m)` per row; 0 means declined. Declines under the
/// FMA generation: there the per-row stride-1 radix-3 path runs the
/// contracted kernel, and mixing it with this plain-op body would make
/// a row's bits depend on its tile width.
#[allow(clippy::too_many_arguments)]
pub(crate) fn try_stage3_xrow4(
    sign: f64,
    tw_re: &[f64],
    tw_im: &[f64],
    src_re: &[f64],
    src_im: &[f64],
    dst_re: &mut [f64],
    dst_im: &mut [f64],
    n: usize,
    m: usize,
) -> usize {
    imp::try_stage3_xrow4(sign, tw_re, tw_im, src_re, src_im, dst_re, dst_im, n, m)
}

/// Radix-5 counterpart of [`try_stage3_xrow4`] (`n = 5m`). Dispatches
/// in every generation: the per-row radix-5 stride-1 shape is scalar
/// plain-op arithmetic under *all* feature combinations, and this body
/// replicates that exact IEEE-754 op order — so a row computes the
/// same bits whether it runs per-row or inside a 4-row tile.
#[allow(clippy::too_many_arguments)]
pub(crate) fn try_stage5_xrow4(
    sign: f64,
    tw_re: &[f64],
    tw_im: &[f64],
    src_re: &[f64],
    src_im: &[f64],
    dst_re: &mut [f64],
    dst_im: &mut [f64],
    n: usize,
    m: usize,
) -> usize {
    imp::try_stage5_xrow4(sign, tw_re, tw_im, src_re, src_im, dst_re, dst_im, n, m)
}

/// Blocked out-of-place transpose of one f64 plane with in-register
/// 4×4 (and 8×8 two-register) AVX2 kernels:
/// `dst[c·dst_stride + r] = src[r·src_stride + c]` for the `nr × nc`
/// rectangle, scalar rim for non-multiple-of-4 edges. Returns `false`
/// having done nothing when AVX2 is unavailable — the caller keeps its
/// scalar loops. Pure data movement, so the result is bit-identical to
/// the scalar path in every kernel generation.
///
/// # Safety
/// `src` must be valid for reads of `(nr-1)·src_stride + nc` elements
/// and `dst` for writes of `(nc-1)·dst_stride + nr` elements, with
/// `src_stride >= nc`, `dst_stride >= nr`, and no overlap.
pub(crate) unsafe fn transpose_block(
    src: *const f64,
    src_stride: usize,
    dst: *mut f64,
    dst_stride: usize,
    nr: usize,
    nc: usize,
) -> bool {
    imp::transpose_block(src, src_stride, dst, dst_stride, nr, nc)
}

/// In-place swap-transpose of the tile `rows [r0, r1) × cols [c0, c1)`
/// of an `n×n` plane with its mirror tile (the barrier transpose's
/// `swap_tiles` body): element `(r, c)` trades places with `(c, r)`,
/// 4×4 register blocks plus a scalar rim. Returns `false` (nothing
/// done) without AVX2.
///
/// # Safety
/// `x` must be valid for reads/writes of `n·n` elements, with
/// `r1 <= n`, `c1 <= n` and the tile strictly off-diagonal
/// (`c0 >= r1`), so the tile and its mirror are disjoint.
pub(crate) unsafe fn transpose_swap(
    x: *mut f64,
    n: usize,
    r0: usize,
    r1: usize,
    c0: usize,
    c1: usize,
) -> bool {
    imp::transpose_swap(x, n, r0, r1, c0, c1)
}

/// In-place transpose of the diagonal tile `[lo, hi) × [lo, hi)` of an
/// `n×n` plane (the barrier transpose's `transpose_diag_tile` body):
/// 4×4 in-register blocks on the diagonal, swap-transposed pairs off
/// it, scalar rim. Returns `false` (nothing done) without AVX2.
///
/// # Safety
/// `x` must be valid for reads/writes of `n·n` elements and `hi <= n`.
pub(crate) unsafe fn transpose_diag(x: *mut f64, n: usize, lo: usize, hi: usize) -> bool {
    imp::transpose_diag(x, n, lo, hi)
}

/// AVX2 body of the FFT4 tail codelet, out-of-place form: planes are
/// `(4, s)` chunked, `s = len/4`. Processes a multiple-of-4 prefix of
/// the lane range `q ∈ [0, s)` and returns how many lanes were done
/// (0 when the fast path is unavailable); the caller finishes the
/// remainder with the scalar body.
pub(crate) fn tail4_oop(
    sign: f64,
    src_re: &[f64],
    src_im: &[f64],
    dst_re: &mut [f64],
    dst_im: &mut [f64],
) -> usize {
    imp::tail4_oop(sign, src_re, src_im, dst_re, dst_im)
}

/// In-place form of [`tail4_oop`] (same kernel: all loads precede all
/// stores within each lane group, so aliasing src/dst is fine).
pub(crate) fn tail4_inplace(sign: f64, re: &mut [f64], im: &mut [f64]) -> usize {
    imp::tail4_inplace(sign, re, im)
}

/// AVX2 body of the FFT8 tail codelet, out-of-place form; see
/// [`tail4_oop`] for the lane-prefix contract (`s = len/8`).
pub(crate) fn tail8_oop(
    sign: f64,
    src_re: &[f64],
    src_im: &[f64],
    dst_re: &mut [f64],
    dst_im: &mut [f64],
) -> usize {
    imp::tail8_oop(sign, src_re, src_im, dst_re, dst_im)
}

/// In-place form of [`tail8_oop`].
pub(crate) fn tail8_inplace(sign: f64, re: &mut [f64], im: &mut [f64]) -> usize {
    imp::tail8_inplace(sign, re, im)
}

#[cfg(all(feature = "simd", target_arch = "x86_64"))]
mod imp {
    use crate::dft::radix::{C5_1, C5_2, C8, S3, S5_1, S5_2};
    use std::arch::x86_64::*;
    use std::sync::OnceLock;

    /// cos(2π/3), the radix-3 butterfly constant (shared with the
    /// scalar loop in `radix.rs`).
    const C3: f64 = -0.5;

    pub fn avx2_enabled() -> bool {
        static AVX2: OnceLock<bool> = OnceLock::new();
        *AVX2.get_or_init(|| std::arch::is_x86_feature_detected!("avx2"))
    }

    pub fn fma_enabled() -> bool {
        static FMA: OnceLock<bool> = OnceLock::new();
        *FMA.get_or_init(|| {
            cfg!(feature = "fma")
                && avx2_enabled()
                && std::arch::is_x86_feature_detected!("fma")
        })
    }

    // -----------------------------------------------------------------
    // Multiply-accumulate families
    // -----------------------------------------------------------------
    // Each stage kernel is generated twice from one body: the *plain*
    // family mirrors the scalar loops' op order exactly (separate mul
    // then add/sub — bit-identical to scalar), the *fma* family
    // contracts to one rounding. The s-prefixed macros are the scalar
    // remainder counterparts: the fma scalar forms use `f64::mul_add`
    // with the same association as the vector fmadd/fmsub, so an
    // element computes identical bits whether a stage-range split lands
    // it in the vector body or the remainder.

    /// a·b + c
    macro_rules! vmla_plain {
        ($a:expr, $b:expr, $c:expr) => {
            _mm256_add_pd(_mm256_mul_pd($a, $b), $c)
        };
    }
    /// a·b − c
    macro_rules! vmls_plain {
        ($a:expr, $b:expr, $c:expr) => {
            _mm256_sub_pd(_mm256_mul_pd($a, $b), $c)
        };
    }
    /// c − a·b
    macro_rules! vmnla_plain {
        ($a:expr, $b:expr, $c:expr) => {
            _mm256_sub_pd($c, _mm256_mul_pd($a, $b))
        };
    }
    macro_rules! smla_plain {
        ($a:expr, $b:expr, $c:expr) => {
            ($a) * ($b) + ($c)
        };
    }
    macro_rules! smls_plain {
        ($a:expr, $b:expr, $c:expr) => {
            ($a) * ($b) - ($c)
        };
    }
    macro_rules! smnla_plain {
        ($a:expr, $b:expr, $c:expr) => {
            ($c) - ($a) * ($b)
        };
    }

    #[cfg(feature = "fma")]
    macro_rules! vmla_fma {
        ($a:expr, $b:expr, $c:expr) => {
            _mm256_fmadd_pd($a, $b, $c)
        };
    }
    #[cfg(feature = "fma")]
    macro_rules! vmls_fma {
        ($a:expr, $b:expr, $c:expr) => {
            _mm256_fmsub_pd($a, $b, $c)
        };
    }
    #[cfg(feature = "fma")]
    macro_rules! vmnla_fma {
        ($a:expr, $b:expr, $c:expr) => {
            _mm256_fnmadd_pd($a, $b, $c)
        };
    }
    #[cfg(feature = "fma")]
    macro_rules! smla_fma {
        ($a:expr, $b:expr, $c:expr) => {
            f64::mul_add($a, $b, $c)
        };
    }
    #[cfg(feature = "fma")]
    macro_rules! smls_fma {
        ($a:expr, $b:expr, $c:expr) => {
            f64::mul_add($a, $b, -($c))
        };
    }
    #[cfg(feature = "fma")]
    macro_rules! smnla_fma {
        ($a:expr, $b:expr, $c:expr) => {
            f64::mul_add(-($a), $b, $c)
        };
    }

    // -----------------------------------------------------------------
    // Shared shuffle helpers (generation-independent data movement)
    // -----------------------------------------------------------------

    /// Scatter the radix-3 stride-1 outputs of four butterflies:
    /// `dk = [dk(p) dk(p+1) dk(p+2) dk(p+3)]` interleaves to the 12
    /// contiguous doubles `out[3j + k] = dk(p+j)`.
    #[target_feature(enable = "avx2")]
    unsafe fn interleave3_store(d0: __m256d, d1: __m256d, d2: __m256d, out: *mut f64) {
        // o0 = [d0_0 d1_0 d2_0 d0_1], o1 = [d1_1 d2_1 d0_2 d1_2],
        // o2 = [d2_2 d0_3 d1_3 d2_3]; each is one lane permute per
        // source + two blends
        let o0 = _mm256_blend_pd(
            _mm256_blend_pd(
                _mm256_permute4x64_pd(d0, 0x40),
                _mm256_permute4x64_pd(d1, 0x00),
                0b0010,
            ),
            _mm256_permute4x64_pd(d2, 0x00),
            0b0100,
        );
        let o1 = _mm256_blend_pd(
            _mm256_blend_pd(
                _mm256_permute4x64_pd(d1, 0x81),
                _mm256_permute4x64_pd(d2, 0x55),
                0b0010,
            ),
            _mm256_permute4x64_pd(d0, 0xAA),
            0b0100,
        );
        let o2 = _mm256_blend_pd(
            _mm256_blend_pd(
                _mm256_permute4x64_pd(d2, 0xC2),
                _mm256_permute4x64_pd(d0, 0xFF),
                0b0010,
            ),
            _mm256_permute4x64_pd(d1, 0xFF),
            0b0100,
        );
        _mm256_storeu_pd(out, o0);
        _mm256_storeu_pd(out.add(4), o1);
        _mm256_storeu_pd(out.add(8), o2);
    }

    /// Deinterleave four butterflies' (w1, w2) twiddle pairs from the
    /// radix-3 layout `tw[2p + {0,1}]`: returns
    /// `([w1_0..w1_3], [w2_0..w2_3])` from the 8 doubles at `tw + 2p`.
    #[target_feature(enable = "avx2")]
    unsafe fn deinterleave2(tw: *const f64) -> (__m256d, __m256d) {
        let v0 = _mm256_loadu_pd(tw);
        let v1 = _mm256_loadu_pd(tw.add(4));
        // unpacklo = [w1_0 w1_2 w1_1 w1_3] → 0xD8 reorders to ascending
        let w1 = _mm256_permute4x64_pd(_mm256_unpacklo_pd(v0, v1), 0xD8);
        let w2 = _mm256_permute4x64_pd(_mm256_unpackhi_pd(v0, v1), 0xD8);
        (w1, w2)
    }

    /// `[w_p, w_p, w_{p+1}, w_{p+1}]` from a 128-bit pair load (the
    /// stride-2 per-butterfly twiddle duplication).
    #[target_feature(enable = "avx2")]
    unsafe fn dup2(tw: *const f64) -> __m256d {
        let v = _mm256_castpd128_pd256(_mm_loadu_pd(tw));
        _mm256_permute4x64_pd(v, 0x50)
    }

    // -----------------------------------------------------------------
    // Stage kernels, generated once per multiply-accumulate family
    // -----------------------------------------------------------------

    macro_rules! define_stage_kernels {
        ($feat:literal, $vmla:ident, $vmls:ident, $vmnla:ident,
         $smla:ident, $smls:ident, $smnla:ident,
         $s2s1:ident, $s2s2:ident, $s2w:ident,
         $s3s1:ident, $s3s2:ident, $s3w:ident,
         $s5s2:ident, $s5w:ident) => {

        /// Radix-2 stage at `stride == 1`: butterfly `p` reads `src[p]`,
        /// `src[p+m]` and writes `dst[2(p−p_lo)]`, `dst[2(p−p_lo)+1]`.
        /// Four butterflies per iteration; the 4-lane `d0`/`d1` results
        /// are element-interleaved into 8 contiguous outputs.
        #[allow(clippy::too_many_arguments)]
        #[target_feature(enable = $feat)]
        unsafe fn $s2s1(
            sign: f64,
            tw_re: &[f64],
            tw_im: &[f64],
            src_re: &[f64],
            src_im: &[f64],
            dst_re: &mut [f64],
            dst_im: &mut [f64],
            p_lo: usize,
            p_hi: usize,
            m: usize,
        ) {
            let sgn = _mm256_set1_pd(sign);
            let mut p = p_lo;
            while p + 4 <= p_hi {
                let ar = _mm256_loadu_pd(src_re.as_ptr().add(p));
                let ai = _mm256_loadu_pd(src_im.as_ptr().add(p));
                let br = _mm256_loadu_pd(src_re.as_ptr().add(p + m));
                let bi = _mm256_loadu_pd(src_im.as_ptr().add(p + m));
                let wr = _mm256_loadu_pd(tw_re.as_ptr().add(p));
                let wi = _mm256_mul_pd(sgn, _mm256_loadu_pd(tw_im.as_ptr().add(p)));
                let d0r = _mm256_add_pd(ar, br);
                let d0i = _mm256_add_pd(ai, bi);
                let xr = _mm256_sub_pd(ar, br);
                let xi = _mm256_sub_pd(ai, bi);
                let d1r = $vmls!(xr, wr, _mm256_mul_pd(xi, wi));
                let d1i = $vmla!(xr, wi, _mm256_mul_pd(xi, wr));
                // interleave lanes k of d0/d1 into out[2k], out[2k+1]:
                // unpacklo = [d0_0 d1_0 d0_2 d1_2], unpackhi = odd lanes
                let o = 2 * (p - p_lo);
                let lo = _mm256_unpacklo_pd(d0r, d1r);
                let hi = _mm256_unpackhi_pd(d0r, d1r);
                _mm256_storeu_pd(dst_re.as_mut_ptr().add(o), _mm256_permute2f128_pd(lo, hi, 0x20));
                _mm256_storeu_pd(
                    dst_re.as_mut_ptr().add(o + 4),
                    _mm256_permute2f128_pd(lo, hi, 0x31),
                );
                let lo = _mm256_unpacklo_pd(d0i, d1i);
                let hi = _mm256_unpackhi_pd(d0i, d1i);
                _mm256_storeu_pd(dst_im.as_mut_ptr().add(o), _mm256_permute2f128_pd(lo, hi, 0x20));
                _mm256_storeu_pd(
                    dst_im.as_mut_ptr().add(o + 4),
                    _mm256_permute2f128_pd(lo, hi, 0x31),
                );
                p += 4;
            }
            // remainder butterflies: the scalar expressions with the
            // family's multiply-accumulate forms
            while p < p_hi {
                let wr = tw_re[p];
                let wi = sign * tw_im[p];
                let (ar, ai) = (src_re[p], src_im[p]);
                let (br, bi) = (src_re[p + m], src_im[p + m]);
                let o = 2 * (p - p_lo);
                dst_re[o] = ar + br;
                dst_im[o] = ai + bi;
                let xr = ar - br;
                let xi = ai - bi;
                dst_re[o + 1] = $smls!(xr, wr, xi * wi);
                dst_im[o + 1] = $smla!(xr, wi, xi * wr);
                p += 1;
            }
        }

        /// Radix-2 stage at `stride == 2`: butterfly `p` reads lanes
        /// `src[2p..2p+2]`, `src[2(p+m)..+2]` and writes
        /// `dst[4(p−p_lo)..+2]` / `dst[4(p−p_lo)+2..+4]`. Two
        /// butterflies per iteration; outputs interleave at 128-bit
        /// granularity.
        #[allow(clippy::too_many_arguments)]
        #[target_feature(enable = $feat)]
        unsafe fn $s2s2(
            sign: f64,
            tw_re: &[f64],
            tw_im: &[f64],
            src_re: &[f64],
            src_im: &[f64],
            dst_re: &mut [f64],
            dst_im: &mut [f64],
            p_lo: usize,
            p_hi: usize,
            m: usize,
        ) {
            let sgn = _mm256_set1_pd(sign);
            let mut p = p_lo;
            while p + 2 <= p_hi {
                let ar = _mm256_loadu_pd(src_re.as_ptr().add(2 * p));
                let ai = _mm256_loadu_pd(src_im.as_ptr().add(2 * p));
                let br = _mm256_loadu_pd(src_re.as_ptr().add(2 * (p + m)));
                let bi = _mm256_loadu_pd(src_im.as_ptr().add(2 * (p + m)));
                let wr = dup2(tw_re.as_ptr().add(p));
                let wi = _mm256_mul_pd(sgn, dup2(tw_im.as_ptr().add(p)));
                let d0r = _mm256_add_pd(ar, br);
                let d0i = _mm256_add_pd(ai, bi);
                let xr = _mm256_sub_pd(ar, br);
                let xi = _mm256_sub_pd(ai, bi);
                let d1r = $vmls!(xr, wr, _mm256_mul_pd(xi, wi));
                let d1i = $vmla!(xr, wi, _mm256_mul_pd(xi, wr));
                // out[0..4] = [d0 lanes 0,1 | d1 lanes 0,1], out[4..8] = lanes 2,3
                let o = 4 * (p - p_lo);
                _mm256_storeu_pd(dst_re.as_mut_ptr().add(o), _mm256_permute2f128_pd(d0r, d1r, 0x20));
                _mm256_storeu_pd(
                    dst_re.as_mut_ptr().add(o + 4),
                    _mm256_permute2f128_pd(d0r, d1r, 0x31),
                );
                _mm256_storeu_pd(dst_im.as_mut_ptr().add(o), _mm256_permute2f128_pd(d0i, d1i, 0x20));
                _mm256_storeu_pd(
                    dst_im.as_mut_ptr().add(o + 4),
                    _mm256_permute2f128_pd(d0i, d1i, 0x31),
                );
                p += 2;
            }
            while p < p_hi {
                let wr = tw_re[p];
                let wi = sign * tw_im[p];
                for q in 0..2 {
                    let (ar, ai) = (src_re[2 * p + q], src_im[2 * p + q]);
                    let (br, bi) = (src_re[2 * (p + m) + q], src_im[2 * (p + m) + q]);
                    let o = 4 * (p - p_lo) + q;
                    dst_re[o] = ar + br;
                    dst_im[o] = ai + bi;
                    let xr = ar - br;
                    let xi = ai - bi;
                    dst_re[o + 2] = $smls!(xr, wr, xi * wi);
                    dst_im[o + 2] = $smla!(xr, wi, xi * wr);
                }
                p += 1;
            }
        }

        /// Radix-2 stage at `stride >= 4` (wide): the `q` lane loop runs
        /// four lanes per iteration with the butterfly's twiddle
        /// broadcast — contiguous loads/stores, no shuffles.
        #[allow(clippy::too_many_arguments)]
        #[target_feature(enable = $feat)]
        unsafe fn $s2w(
            sign: f64,
            tw_re: &[f64],
            tw_im: &[f64],
            src_re: &[f64],
            src_im: &[f64],
            dst_re: &mut [f64],
            dst_im: &mut [f64],
            p_lo: usize,
            p_hi: usize,
            m: usize,
            stride: usize,
        ) {
            for p in p_lo..p_hi {
                let wr_s = tw_re[p];
                let wi_s = sign * tw_im[p];
                let wr = _mm256_set1_pd(wr_s);
                let wi = _mm256_set1_pd(wi_s);
                let a_base = stride * p;
                let b_base = stride * (p + m);
                let o = 2 * stride * (p - p_lo);
                let mut q = 0usize;
                while q + 4 <= stride {
                    let ar = _mm256_loadu_pd(src_re.as_ptr().add(a_base + q));
                    let ai = _mm256_loadu_pd(src_im.as_ptr().add(a_base + q));
                    let br = _mm256_loadu_pd(src_re.as_ptr().add(b_base + q));
                    let bi = _mm256_loadu_pd(src_im.as_ptr().add(b_base + q));
                    _mm256_storeu_pd(dst_re.as_mut_ptr().add(o + q), _mm256_add_pd(ar, br));
                    _mm256_storeu_pd(dst_im.as_mut_ptr().add(o + q), _mm256_add_pd(ai, bi));
                    let xr = _mm256_sub_pd(ar, br);
                    let xi = _mm256_sub_pd(ai, bi);
                    _mm256_storeu_pd(
                        dst_re.as_mut_ptr().add(o + stride + q),
                        $vmls!(xr, wr, _mm256_mul_pd(xi, wi)),
                    );
                    _mm256_storeu_pd(
                        dst_im.as_mut_ptr().add(o + stride + q),
                        $vmla!(xr, wi, _mm256_mul_pd(xi, wr)),
                    );
                    q += 4;
                }
                while q < stride {
                    let (ar, ai) = (src_re[a_base + q], src_im[a_base + q]);
                    let (br, bi) = (src_re[b_base + q], src_im[b_base + q]);
                    dst_re[o + q] = ar + br;
                    dst_im[o + q] = ai + bi;
                    let xr = ar - br;
                    let xi = ai - bi;
                    dst_re[o + stride + q] = $smls!(xr, wr_s, xi * wi_s);
                    dst_im[o + stride + q] = $smla!(xr, wi_s, xi * wr_s);
                    q += 1;
                }
            }
        }

        /// Radix-3 stage at `stride == 1`, four butterflies per
        /// iteration: contiguous x0/x1/x2 loads, twiddle pairs
        /// deinterleaved, and the 3-way output scatter rebuilt with
        /// lane permutes + blends ([`interleave3_store`]).
        #[allow(clippy::too_many_arguments)]
        #[target_feature(enable = $feat)]
        unsafe fn $s3s1(
            sign: f64,
            tw_re: &[f64],
            tw_im: &[f64],
            src_re: &[f64],
            src_im: &[f64],
            dst_re: &mut [f64],
            dst_im: &mut [f64],
            p_lo: usize,
            p_hi: usize,
            m: usize,
        ) {
            let sgn = _mm256_set1_pd(sign);
            let c3v = _mm256_set1_pd(C3);
            let s3 = sign * (-S3);
            let s3v = _mm256_set1_pd(s3);
            let mut p = p_lo;
            while p + 4 <= p_hi {
                let x0r = _mm256_loadu_pd(src_re.as_ptr().add(p));
                let x0i = _mm256_loadu_pd(src_im.as_ptr().add(p));
                let x1r = _mm256_loadu_pd(src_re.as_ptr().add(p + m));
                let x1i = _mm256_loadu_pd(src_im.as_ptr().add(p + m));
                let x2r = _mm256_loadu_pd(src_re.as_ptr().add(p + 2 * m));
                let x2i = _mm256_loadu_pd(src_im.as_ptr().add(p + 2 * m));
                let (w1r, w2r) = deinterleave2(tw_re.as_ptr().add(2 * p));
                let (w1i, w2i) = deinterleave2(tw_im.as_ptr().add(2 * p));
                let w1i = _mm256_mul_pd(sgn, w1i);
                let w2i = _mm256_mul_pd(sgn, w2i);
                let tr = _mm256_add_pd(x1r, x2r);
                let ti = _mm256_add_pd(x1i, x2i);
                let dr = _mm256_sub_pd(x1r, x2r);
                let di = _mm256_sub_pd(x1i, x2i);
                let d0r = _mm256_add_pd(x0r, tr);
                let d0i = _mm256_add_pd(x0i, ti);
                let br = $vmla!(c3v, tr, x0r);
                let bi = $vmla!(c3v, ti, x0i);
                // y1 = b + i·s3·d, y2 = b − i·s3·d
                let y1r = $vmnla!(s3v, di, br);
                let y1i = $vmla!(s3v, dr, bi);
                let y2r = $vmla!(s3v, di, br);
                let y2i = $vmnla!(s3v, dr, bi);
                let d1r = $vmls!(y1r, w1r, _mm256_mul_pd(y1i, w1i));
                let d1i = $vmla!(y1r, w1i, _mm256_mul_pd(y1i, w1r));
                let d2r = $vmls!(y2r, w2r, _mm256_mul_pd(y2i, w2i));
                let d2i = $vmla!(y2r, w2i, _mm256_mul_pd(y2i, w2r));
                let o = 3 * (p - p_lo);
                interleave3_store(d0r, d1r, d2r, dst_re.as_mut_ptr().add(o));
                interleave3_store(d0i, d1i, d2i, dst_im.as_mut_ptr().add(o));
                p += 4;
            }
            while p < p_hi {
                let t = 2 * p;
                let w1r = tw_re[t];
                let w1i = sign * tw_im[t];
                let w2r = tw_re[t + 1];
                let w2i = sign * tw_im[t + 1];
                let (x0r, x0i) = (src_re[p], src_im[p]);
                let (x1r, x1i) = (src_re[p + m], src_im[p + m]);
                let (x2r, x2i) = (src_re[p + 2 * m], src_im[p + 2 * m]);
                let tr = x1r + x2r;
                let ti = x1i + x2i;
                let dr = x1r - x2r;
                let di = x1i - x2i;
                let o = 3 * (p - p_lo);
                dst_re[o] = x0r + tr;
                dst_im[o] = x0i + ti;
                let br = $smla!(C3, tr, x0r);
                let bi = $smla!(C3, ti, x0i);
                let y1r = $smnla!(s3, di, br);
                let y1i = $smla!(s3, dr, bi);
                let y2r = $smla!(s3, di, br);
                let y2i = $smnla!(s3, dr, bi);
                dst_re[o + 1] = $smls!(y1r, w1r, y1i * w1i);
                dst_im[o + 1] = $smla!(y1r, w1i, y1i * w1r);
                dst_re[o + 2] = $smls!(y2r, w2r, y2i * w2i);
                dst_im[o + 2] = $smla!(y2r, w2i, y2i * w2r);
                p += 1;
            }
        }

        /// Radix-3 stage at `stride == 2`, two butterflies per
        /// iteration: outputs interleave at 128-bit granularity
        /// (`permute2f128` trio), twiddles duplicate across each
        /// butterfly's two lanes with `permute4x64`.
        #[allow(clippy::too_many_arguments)]
        #[target_feature(enable = $feat)]
        unsafe fn $s3s2(
            sign: f64,
            tw_re: &[f64],
            tw_im: &[f64],
            src_re: &[f64],
            src_im: &[f64],
            dst_re: &mut [f64],
            dst_im: &mut [f64],
            p_lo: usize,
            p_hi: usize,
            m: usize,
        ) {
            let sgn = _mm256_set1_pd(sign);
            let c3v = _mm256_set1_pd(C3);
            let s3 = sign * (-S3);
            let s3v = _mm256_set1_pd(s3);
            let mut p = p_lo;
            while p + 2 <= p_hi {
                let x0r = _mm256_loadu_pd(src_re.as_ptr().add(2 * p));
                let x0i = _mm256_loadu_pd(src_im.as_ptr().add(2 * p));
                let x1r = _mm256_loadu_pd(src_re.as_ptr().add(2 * (p + m)));
                let x1i = _mm256_loadu_pd(src_im.as_ptr().add(2 * (p + m)));
                let x2r = _mm256_loadu_pd(src_re.as_ptr().add(2 * (p + 2 * m)));
                let x2i = _mm256_loadu_pd(src_im.as_ptr().add(2 * (p + 2 * m)));
                // tw[2p..2p+4] = [w1_p w2_p w1_{p+1} w2_{p+1}]
                let v = _mm256_loadu_pd(tw_re.as_ptr().add(2 * p));
                let w1r = _mm256_permute4x64_pd(v, 0xA0);
                let w2r = _mm256_permute4x64_pd(v, 0xF5);
                let v = _mm256_loadu_pd(tw_im.as_ptr().add(2 * p));
                let w1i = _mm256_mul_pd(sgn, _mm256_permute4x64_pd(v, 0xA0));
                let w2i = _mm256_mul_pd(sgn, _mm256_permute4x64_pd(v, 0xF5));
                let tr = _mm256_add_pd(x1r, x2r);
                let ti = _mm256_add_pd(x1i, x2i);
                let dr = _mm256_sub_pd(x1r, x2r);
                let di = _mm256_sub_pd(x1i, x2i);
                let d0r = _mm256_add_pd(x0r, tr);
                let d0i = _mm256_add_pd(x0i, ti);
                let br = $vmla!(c3v, tr, x0r);
                let bi = $vmla!(c3v, ti, x0i);
                let y1r = $vmnla!(s3v, di, br);
                let y1i = $vmla!(s3v, dr, bi);
                let y2r = $vmla!(s3v, di, br);
                let y2i = $vmnla!(s3v, dr, bi);
                let d1r = $vmls!(y1r, w1r, _mm256_mul_pd(y1i, w1i));
                let d1i = $vmla!(y1r, w1i, _mm256_mul_pd(y1i, w1r));
                let d2r = $vmls!(y2r, w2r, _mm256_mul_pd(y2i, w2i));
                let d2i = $vmla!(y2r, w2i, _mm256_mul_pd(y2i, w2r));
                // dst[6p'..6p'+12] = [d0(p) d1(p) | d2(p) d0(p+1) | d1(p+1) d2(p+1)]
                let o = 6 * (p - p_lo);
                _mm256_storeu_pd(dst_re.as_mut_ptr().add(o), _mm256_permute2f128_pd(d0r, d1r, 0x20));
                _mm256_storeu_pd(
                    dst_re.as_mut_ptr().add(o + 4),
                    _mm256_permute2f128_pd(d2r, d0r, 0x30),
                );
                _mm256_storeu_pd(
                    dst_re.as_mut_ptr().add(o + 8),
                    _mm256_permute2f128_pd(d1r, d2r, 0x31),
                );
                _mm256_storeu_pd(dst_im.as_mut_ptr().add(o), _mm256_permute2f128_pd(d0i, d1i, 0x20));
                _mm256_storeu_pd(
                    dst_im.as_mut_ptr().add(o + 4),
                    _mm256_permute2f128_pd(d2i, d0i, 0x30),
                );
                _mm256_storeu_pd(
                    dst_im.as_mut_ptr().add(o + 8),
                    _mm256_permute2f128_pd(d1i, d2i, 0x31),
                );
                p += 2;
            }
            while p < p_hi {
                let t = 2 * p;
                let w1r = tw_re[t];
                let w1i = sign * tw_im[t];
                let w2r = tw_re[t + 1];
                let w2i = sign * tw_im[t + 1];
                for q in 0..2 {
                    let (x0r, x0i) = (src_re[2 * p + q], src_im[2 * p + q]);
                    let (x1r, x1i) = (src_re[2 * (p + m) + q], src_im[2 * (p + m) + q]);
                    let (x2r, x2i) = (src_re[2 * (p + 2 * m) + q], src_im[2 * (p + 2 * m) + q]);
                    let tr = x1r + x2r;
                    let ti = x1i + x2i;
                    let dr = x1r - x2r;
                    let di = x1i - x2i;
                    let o = 6 * (p - p_lo) + q;
                    dst_re[o] = x0r + tr;
                    dst_im[o] = x0i + ti;
                    let br = $smla!(C3, tr, x0r);
                    let bi = $smla!(C3, ti, x0i);
                    let y1r = $smnla!(s3, di, br);
                    let y1i = $smla!(s3, dr, bi);
                    let y2r = $smla!(s3, di, br);
                    let y2i = $smnla!(s3, dr, bi);
                    dst_re[o + 2] = $smls!(y1r, w1r, y1i * w1i);
                    dst_im[o + 2] = $smla!(y1r, w1i, y1i * w1r);
                    dst_re[o + 4] = $smls!(y2r, w2r, y2i * w2i);
                    dst_im[o + 4] = $smla!(y2r, w2i, y2i * w2r);
                }
                p += 1;
            }
        }

        /// Radix-3 stage at `stride >= 4` (wide): vectorized `q` lane
        /// loop with broadcast twiddles — the shape the paper sizes'
        /// radix-3 stages actually run at.
        #[allow(clippy::too_many_arguments)]
        #[target_feature(enable = $feat)]
        unsafe fn $s3w(
            sign: f64,
            tw_re: &[f64],
            tw_im: &[f64],
            src_re: &[f64],
            src_im: &[f64],
            dst_re: &mut [f64],
            dst_im: &mut [f64],
            p_lo: usize,
            p_hi: usize,
            m: usize,
            stride: usize,
        ) {
            let c3v = _mm256_set1_pd(C3);
            let s3 = sign * (-S3);
            let s3v = _mm256_set1_pd(s3);
            for p in p_lo..p_hi {
                let t = 2 * p;
                let w1r_s = tw_re[t];
                let w1i_s = sign * tw_im[t];
                let w2r_s = tw_re[t + 1];
                let w2i_s = sign * tw_im[t + 1];
                let w1r = _mm256_set1_pd(w1r_s);
                let w1i = _mm256_set1_pd(w1i_s);
                let w2r = _mm256_set1_pd(w2r_s);
                let w2i = _mm256_set1_pd(w2i_s);
                let a0 = stride * p;
                let a1 = stride * (p + m);
                let a2 = stride * (p + 2 * m);
                let o = 3 * stride * (p - p_lo);
                let mut q = 0usize;
                while q + 4 <= stride {
                    let x0r = _mm256_loadu_pd(src_re.as_ptr().add(a0 + q));
                    let x0i = _mm256_loadu_pd(src_im.as_ptr().add(a0 + q));
                    let x1r = _mm256_loadu_pd(src_re.as_ptr().add(a1 + q));
                    let x1i = _mm256_loadu_pd(src_im.as_ptr().add(a1 + q));
                    let x2r = _mm256_loadu_pd(src_re.as_ptr().add(a2 + q));
                    let x2i = _mm256_loadu_pd(src_im.as_ptr().add(a2 + q));
                    let tr = _mm256_add_pd(x1r, x2r);
                    let ti = _mm256_add_pd(x1i, x2i);
                    let dr = _mm256_sub_pd(x1r, x2r);
                    let di = _mm256_sub_pd(x1i, x2i);
                    _mm256_storeu_pd(dst_re.as_mut_ptr().add(o + q), _mm256_add_pd(x0r, tr));
                    _mm256_storeu_pd(dst_im.as_mut_ptr().add(o + q), _mm256_add_pd(x0i, ti));
                    let br = $vmla!(c3v, tr, x0r);
                    let bi = $vmla!(c3v, ti, x0i);
                    let y1r = $vmnla!(s3v, di, br);
                    let y1i = $vmla!(s3v, dr, bi);
                    let y2r = $vmla!(s3v, di, br);
                    let y2i = $vmnla!(s3v, dr, bi);
                    _mm256_storeu_pd(
                        dst_re.as_mut_ptr().add(o + stride + q),
                        $vmls!(y1r, w1r, _mm256_mul_pd(y1i, w1i)),
                    );
                    _mm256_storeu_pd(
                        dst_im.as_mut_ptr().add(o + stride + q),
                        $vmla!(y1r, w1i, _mm256_mul_pd(y1i, w1r)),
                    );
                    _mm256_storeu_pd(
                        dst_re.as_mut_ptr().add(o + 2 * stride + q),
                        $vmls!(y2r, w2r, _mm256_mul_pd(y2i, w2i)),
                    );
                    _mm256_storeu_pd(
                        dst_im.as_mut_ptr().add(o + 2 * stride + q),
                        $vmla!(y2r, w2i, _mm256_mul_pd(y2i, w2r)),
                    );
                    q += 4;
                }
                while q < stride {
                    let (x0r, x0i) = (src_re[a0 + q], src_im[a0 + q]);
                    let (x1r, x1i) = (src_re[a1 + q], src_im[a1 + q]);
                    let (x2r, x2i) = (src_re[a2 + q], src_im[a2 + q]);
                    let tr = x1r + x2r;
                    let ti = x1i + x2i;
                    let dr = x1r - x2r;
                    let di = x1i - x2i;
                    dst_re[o + q] = x0r + tr;
                    dst_im[o + q] = x0i + ti;
                    let br = $smla!(C3, tr, x0r);
                    let bi = $smla!(C3, ti, x0i);
                    let y1r = $smnla!(s3, di, br);
                    let y1i = $smla!(s3, dr, bi);
                    let y2r = $smla!(s3, di, br);
                    let y2i = $smnla!(s3, dr, bi);
                    dst_re[o + stride + q] = $smls!(y1r, w1r_s, y1i * w1i_s);
                    dst_im[o + stride + q] = $smla!(y1r, w1i_s, y1i * w1r_s);
                    dst_re[o + 2 * stride + q] = $smls!(y2r, w2r_s, y2i * w2i_s);
                    dst_im[o + 2 * stride + q] = $smla!(y2r, w2i_s, y2i * w2r_s);
                    q += 1;
                }
            }
        }

        /// Radix-5 stage at `stride == 2`, two butterflies per
        /// iteration: `permute2f128` gathers the k = 1..4 twiddle
        /// quads, the five outputs scatter through five `permute2f128`
        /// stores.
        #[allow(clippy::too_many_arguments)]
        #[target_feature(enable = $feat)]
        unsafe fn $s5s2(
            sign: f64,
            tw_re: &[f64],
            tw_im: &[f64],
            src_re: &[f64],
            src_im: &[f64],
            dst_re: &mut [f64],
            dst_im: &mut [f64],
            p_lo: usize,
            p_hi: usize,
            m: usize,
        ) {
            let sgn = _mm256_set1_pd(sign);
            let c1v = _mm256_set1_pd(C5_1);
            let c2v = _mm256_set1_pd(C5_2);
            let s1 = sign * (-S5_1);
            let s2 = sign * (-S5_2);
            let s1v = _mm256_set1_pd(s1);
            let s2v = _mm256_set1_pd(s2);
            let mut p = p_lo;
            while p + 2 <= p_hi {
                let x0r = _mm256_loadu_pd(src_re.as_ptr().add(2 * p));
                let x0i = _mm256_loadu_pd(src_im.as_ptr().add(2 * p));
                let x1r = _mm256_loadu_pd(src_re.as_ptr().add(2 * (p + m)));
                let x1i = _mm256_loadu_pd(src_im.as_ptr().add(2 * (p + m)));
                let x2r = _mm256_loadu_pd(src_re.as_ptr().add(2 * (p + 2 * m)));
                let x2i = _mm256_loadu_pd(src_im.as_ptr().add(2 * (p + 2 * m)));
                let x3r = _mm256_loadu_pd(src_re.as_ptr().add(2 * (p + 3 * m)));
                let x3i = _mm256_loadu_pd(src_im.as_ptr().add(2 * (p + 3 * m)));
                let x4r = _mm256_loadu_pd(src_re.as_ptr().add(2 * (p + 4 * m)));
                let x4i = _mm256_loadu_pd(src_im.as_ptr().add(2 * (p + 4 * m)));
                // tw[4p..4p+8] = [w1 w2 w3 w4](p) ++ [w1 w2 w3 w4](p+1)
                let a = _mm256_loadu_pd(tw_re.as_ptr().add(4 * p));
                let b = _mm256_loadu_pd(tw_re.as_ptr().add(4 * p + 4));
                let lo = _mm256_permute2f128_pd(a, b, 0x20);
                let hi = _mm256_permute2f128_pd(a, b, 0x31);
                let w1r = _mm256_permute4x64_pd(lo, 0xA0);
                let w2r = _mm256_permute4x64_pd(lo, 0xF5);
                let w3r = _mm256_permute4x64_pd(hi, 0xA0);
                let w4r = _mm256_permute4x64_pd(hi, 0xF5);
                let a = _mm256_loadu_pd(tw_im.as_ptr().add(4 * p));
                let b = _mm256_loadu_pd(tw_im.as_ptr().add(4 * p + 4));
                let lo = _mm256_permute2f128_pd(a, b, 0x20);
                let hi = _mm256_permute2f128_pd(a, b, 0x31);
                let w1i = _mm256_mul_pd(sgn, _mm256_permute4x64_pd(lo, 0xA0));
                let w2i = _mm256_mul_pd(sgn, _mm256_permute4x64_pd(lo, 0xF5));
                let w3i = _mm256_mul_pd(sgn, _mm256_permute4x64_pd(hi, 0xA0));
                let w4i = _mm256_mul_pd(sgn, _mm256_permute4x64_pd(hi, 0xF5));
                let t1r = _mm256_add_pd(x1r, x4r);
                let t1i = _mm256_add_pd(x1i, x4i);
                let t2r = _mm256_add_pd(x2r, x3r);
                let t2i = _mm256_add_pd(x2i, x3i);
                let e1r = _mm256_sub_pd(x1r, x4r);
                let e1i = _mm256_sub_pd(x1i, x4i);
                let e2r = _mm256_sub_pd(x2r, x3r);
                let e2i = _mm256_sub_pd(x2i, x3i);
                let d0r = _mm256_add_pd(_mm256_add_pd(x0r, t1r), t2r);
                let d0i = _mm256_add_pd(_mm256_add_pd(x0i, t1i), t2i);
                let m1r = $vmla!(c2v, t2r, $vmla!(c1v, t1r, x0r));
                let m1i = $vmla!(c2v, t2i, $vmla!(c1v, t1i, x0i));
                let m2r = $vmla!(c1v, t2r, $vmla!(c2v, t1r, x0r));
                let m2i = $vmla!(c1v, t2i, $vmla!(c2v, t1i, x0i));
                let u1r = $vmla!(s2v, e2r, _mm256_mul_pd(s1v, e1r));
                let u1i = $vmla!(s2v, e2i, _mm256_mul_pd(s1v, e1i));
                let u2r = $vmls!(s2v, e1r, _mm256_mul_pd(s1v, e2r));
                let u2i = $vmls!(s2v, e1i, _mm256_mul_pd(s1v, e2i));
                // y1 = m1 + i·u1, y4 = m1 − i·u1, y2 = m2 + i·u2, y3 = m2 − i·u2
                let y1r = _mm256_sub_pd(m1r, u1i);
                let y1i = _mm256_add_pd(m1i, u1r);
                let y4r = _mm256_add_pd(m1r, u1i);
                let y4i = _mm256_sub_pd(m1i, u1r);
                let y2r = _mm256_sub_pd(m2r, u2i);
                let y2i = _mm256_add_pd(m2i, u2r);
                let y3r = _mm256_add_pd(m2r, u2i);
                let y3i = _mm256_sub_pd(m2i, u2r);
                let d1r = $vmls!(y1r, w1r, _mm256_mul_pd(y1i, w1i));
                let d1i = $vmla!(y1r, w1i, _mm256_mul_pd(y1i, w1r));
                let d2r = $vmls!(y2r, w2r, _mm256_mul_pd(y2i, w2i));
                let d2i = $vmla!(y2r, w2i, _mm256_mul_pd(y2i, w2r));
                let d3r = $vmls!(y3r, w3r, _mm256_mul_pd(y3i, w3i));
                let d3i = $vmla!(y3r, w3i, _mm256_mul_pd(y3i, w3r));
                let d4r = $vmls!(y4r, w4r, _mm256_mul_pd(y4i, w4i));
                let d4i = $vmla!(y4r, w4i, _mm256_mul_pd(y4i, w4r));
                // dst[10p'..10p'+20] = [d0 d1 | d2 d3 | d4 d0' | d1' d2' | d3' d4']
                let o = 10 * (p - p_lo);
                _mm256_storeu_pd(dst_re.as_mut_ptr().add(o), _mm256_permute2f128_pd(d0r, d1r, 0x20));
                _mm256_storeu_pd(
                    dst_re.as_mut_ptr().add(o + 4),
                    _mm256_permute2f128_pd(d2r, d3r, 0x20),
                );
                _mm256_storeu_pd(
                    dst_re.as_mut_ptr().add(o + 8),
                    _mm256_permute2f128_pd(d4r, d0r, 0x30),
                );
                _mm256_storeu_pd(
                    dst_re.as_mut_ptr().add(o + 12),
                    _mm256_permute2f128_pd(d1r, d2r, 0x31),
                );
                _mm256_storeu_pd(
                    dst_re.as_mut_ptr().add(o + 16),
                    _mm256_permute2f128_pd(d3r, d4r, 0x31),
                );
                _mm256_storeu_pd(dst_im.as_mut_ptr().add(o), _mm256_permute2f128_pd(d0i, d1i, 0x20));
                _mm256_storeu_pd(
                    dst_im.as_mut_ptr().add(o + 4),
                    _mm256_permute2f128_pd(d2i, d3i, 0x20),
                );
                _mm256_storeu_pd(
                    dst_im.as_mut_ptr().add(o + 8),
                    _mm256_permute2f128_pd(d4i, d0i, 0x30),
                );
                _mm256_storeu_pd(
                    dst_im.as_mut_ptr().add(o + 12),
                    _mm256_permute2f128_pd(d1i, d2i, 0x31),
                );
                _mm256_storeu_pd(
                    dst_im.as_mut_ptr().add(o + 16),
                    _mm256_permute2f128_pd(d3i, d4i, 0x31),
                );
                p += 2;
            }
            while p < p_hi {
                let t = 4 * p;
                for q in 0..2 {
                    let (x0r, x0i) = (src_re[2 * p + q], src_im[2 * p + q]);
                    let (x1r, x1i) = (src_re[2 * (p + m) + q], src_im[2 * (p + m) + q]);
                    let (x2r, x2i) = (src_re[2 * (p + 2 * m) + q], src_im[2 * (p + 2 * m) + q]);
                    let (x3r, x3i) = (src_re[2 * (p + 3 * m) + q], src_im[2 * (p + 3 * m) + q]);
                    let (x4r, x4i) = (src_re[2 * (p + 4 * m) + q], src_im[2 * (p + 4 * m) + q]);
                    let t1r = x1r + x4r;
                    let t1i = x1i + x4i;
                    let t2r = x2r + x3r;
                    let t2i = x2i + x3i;
                    let e1r = x1r - x4r;
                    let e1i = x1i - x4i;
                    let e2r = x2r - x3r;
                    let e2i = x2i - x3i;
                    let o = 10 * (p - p_lo) + q;
                    dst_re[o] = x0r + t1r + t2r;
                    dst_im[o] = x0i + t1i + t2i;
                    let m1r = $smla!(C5_2, t2r, $smla!(C5_1, t1r, x0r));
                    let m1i = $smla!(C5_2, t2i, $smla!(C5_1, t1i, x0i));
                    let m2r = $smla!(C5_1, t2r, $smla!(C5_2, t1r, x0r));
                    let m2i = $smla!(C5_1, t2i, $smla!(C5_2, t1i, x0i));
                    let u1r = $smla!(s2, e2r, s1 * e1r);
                    let u1i = $smla!(s2, e2i, s1 * e1i);
                    let u2r = $smls!(s2, e1r, s1 * e2r);
                    let u2i = $smls!(s2, e1i, s1 * e2i);
                    let y1r = m1r - u1i;
                    let y1i = m1i + u1r;
                    let y4r = m1r + u1i;
                    let y4i = m1i - u1r;
                    let y2r = m2r - u2i;
                    let y2i = m2i + u2r;
                    let y3r = m2r + u2i;
                    let y3i = m2i - u2r;
                    let (w1r, w1i) = (tw_re[t], sign * tw_im[t]);
                    let (w2r, w2i) = (tw_re[t + 1], sign * tw_im[t + 1]);
                    let (w3r, w3i) = (tw_re[t + 2], sign * tw_im[t + 2]);
                    let (w4r, w4i) = (tw_re[t + 3], sign * tw_im[t + 3]);
                    dst_re[o + 2] = $smls!(y1r, w1r, y1i * w1i);
                    dst_im[o + 2] = $smla!(y1r, w1i, y1i * w1r);
                    dst_re[o + 4] = $smls!(y2r, w2r, y2i * w2i);
                    dst_im[o + 4] = $smla!(y2r, w2i, y2i * w2r);
                    dst_re[o + 6] = $smls!(y3r, w3r, y3i * w3i);
                    dst_im[o + 6] = $smla!(y3r, w3i, y3i * w3r);
                    dst_re[o + 8] = $smls!(y4r, w4r, y4i * w4i);
                    dst_im[o + 8] = $smla!(y4r, w4i, y4i * w4r);
                }
                p += 1;
            }
        }

        /// Radix-5 stage at `stride >= 4` (wide): vectorized `q` lane
        /// loop with broadcast twiddles (the 640 = 2⁷·5 shape).
        #[allow(clippy::too_many_arguments)]
        #[target_feature(enable = $feat)]
        unsafe fn $s5w(
            sign: f64,
            tw_re: &[f64],
            tw_im: &[f64],
            src_re: &[f64],
            src_im: &[f64],
            dst_re: &mut [f64],
            dst_im: &mut [f64],
            p_lo: usize,
            p_hi: usize,
            m: usize,
            stride: usize,
        ) {
            let c1v = _mm256_set1_pd(C5_1);
            let c2v = _mm256_set1_pd(C5_2);
            let s1 = sign * (-S5_1);
            let s2 = sign * (-S5_2);
            let s1v = _mm256_set1_pd(s1);
            let s2v = _mm256_set1_pd(s2);
            for p in p_lo..p_hi {
                let t = 4 * p;
                let (w1r_s, w1i_s) = (tw_re[t], sign * tw_im[t]);
                let (w2r_s, w2i_s) = (tw_re[t + 1], sign * tw_im[t + 1]);
                let (w3r_s, w3i_s) = (tw_re[t + 2], sign * tw_im[t + 2]);
                let (w4r_s, w4i_s) = (tw_re[t + 3], sign * tw_im[t + 3]);
                let w1r = _mm256_set1_pd(w1r_s);
                let w1i = _mm256_set1_pd(w1i_s);
                let w2r = _mm256_set1_pd(w2r_s);
                let w2i = _mm256_set1_pd(w2i_s);
                let w3r = _mm256_set1_pd(w3r_s);
                let w3i = _mm256_set1_pd(w3i_s);
                let w4r = _mm256_set1_pd(w4r_s);
                let w4i = _mm256_set1_pd(w4i_s);
                let a0 = stride * p;
                let a1 = stride * (p + m);
                let a2 = stride * (p + 2 * m);
                let a3 = stride * (p + 3 * m);
                let a4 = stride * (p + 4 * m);
                let o = 5 * stride * (p - p_lo);
                let mut q = 0usize;
                while q + 4 <= stride {
                    let x0r = _mm256_loadu_pd(src_re.as_ptr().add(a0 + q));
                    let x0i = _mm256_loadu_pd(src_im.as_ptr().add(a0 + q));
                    let x1r = _mm256_loadu_pd(src_re.as_ptr().add(a1 + q));
                    let x1i = _mm256_loadu_pd(src_im.as_ptr().add(a1 + q));
                    let x2r = _mm256_loadu_pd(src_re.as_ptr().add(a2 + q));
                    let x2i = _mm256_loadu_pd(src_im.as_ptr().add(a2 + q));
                    let x3r = _mm256_loadu_pd(src_re.as_ptr().add(a3 + q));
                    let x3i = _mm256_loadu_pd(src_im.as_ptr().add(a3 + q));
                    let x4r = _mm256_loadu_pd(src_re.as_ptr().add(a4 + q));
                    let x4i = _mm256_loadu_pd(src_im.as_ptr().add(a4 + q));
                    let t1r = _mm256_add_pd(x1r, x4r);
                    let t1i = _mm256_add_pd(x1i, x4i);
                    let t2r = _mm256_add_pd(x2r, x3r);
                    let t2i = _mm256_add_pd(x2i, x3i);
                    let e1r = _mm256_sub_pd(x1r, x4r);
                    let e1i = _mm256_sub_pd(x1i, x4i);
                    let e2r = _mm256_sub_pd(x2r, x3r);
                    let e2i = _mm256_sub_pd(x2i, x3i);
                    _mm256_storeu_pd(
                        dst_re.as_mut_ptr().add(o + q),
                        _mm256_add_pd(_mm256_add_pd(x0r, t1r), t2r),
                    );
                    _mm256_storeu_pd(
                        dst_im.as_mut_ptr().add(o + q),
                        _mm256_add_pd(_mm256_add_pd(x0i, t1i), t2i),
                    );
                    let m1r = $vmla!(c2v, t2r, $vmla!(c1v, t1r, x0r));
                    let m1i = $vmla!(c2v, t2i, $vmla!(c1v, t1i, x0i));
                    let m2r = $vmla!(c1v, t2r, $vmla!(c2v, t1r, x0r));
                    let m2i = $vmla!(c1v, t2i, $vmla!(c2v, t1i, x0i));
                    let u1r = $vmla!(s2v, e2r, _mm256_mul_pd(s1v, e1r));
                    let u1i = $vmla!(s2v, e2i, _mm256_mul_pd(s1v, e1i));
                    let u2r = $vmls!(s2v, e1r, _mm256_mul_pd(s1v, e2r));
                    let u2i = $vmls!(s2v, e1i, _mm256_mul_pd(s1v, e2i));
                    let y1r = _mm256_sub_pd(m1r, u1i);
                    let y1i = _mm256_add_pd(m1i, u1r);
                    let y4r = _mm256_add_pd(m1r, u1i);
                    let y4i = _mm256_sub_pd(m1i, u1r);
                    let y2r = _mm256_sub_pd(m2r, u2i);
                    let y2i = _mm256_add_pd(m2i, u2r);
                    let y3r = _mm256_add_pd(m2r, u2i);
                    let y3i = _mm256_sub_pd(m2i, u2r);
                    _mm256_storeu_pd(
                        dst_re.as_mut_ptr().add(o + stride + q),
                        $vmls!(y1r, w1r, _mm256_mul_pd(y1i, w1i)),
                    );
                    _mm256_storeu_pd(
                        dst_im.as_mut_ptr().add(o + stride + q),
                        $vmla!(y1r, w1i, _mm256_mul_pd(y1i, w1r)),
                    );
                    _mm256_storeu_pd(
                        dst_re.as_mut_ptr().add(o + 2 * stride + q),
                        $vmls!(y2r, w2r, _mm256_mul_pd(y2i, w2i)),
                    );
                    _mm256_storeu_pd(
                        dst_im.as_mut_ptr().add(o + 2 * stride + q),
                        $vmla!(y2r, w2i, _mm256_mul_pd(y2i, w2r)),
                    );
                    _mm256_storeu_pd(
                        dst_re.as_mut_ptr().add(o + 3 * stride + q),
                        $vmls!(y3r, w3r, _mm256_mul_pd(y3i, w3i)),
                    );
                    _mm256_storeu_pd(
                        dst_im.as_mut_ptr().add(o + 3 * stride + q),
                        $vmla!(y3r, w3i, _mm256_mul_pd(y3i, w3r)),
                    );
                    _mm256_storeu_pd(
                        dst_re.as_mut_ptr().add(o + 4 * stride + q),
                        $vmls!(y4r, w4r, _mm256_mul_pd(y4i, w4i)),
                    );
                    _mm256_storeu_pd(
                        dst_im.as_mut_ptr().add(o + 4 * stride + q),
                        $vmla!(y4r, w4i, _mm256_mul_pd(y4i, w4r)),
                    );
                    q += 4;
                }
                while q < stride {
                    let (x0r, x0i) = (src_re[a0 + q], src_im[a0 + q]);
                    let (x1r, x1i) = (src_re[a1 + q], src_im[a1 + q]);
                    let (x2r, x2i) = (src_re[a2 + q], src_im[a2 + q]);
                    let (x3r, x3i) = (src_re[a3 + q], src_im[a3 + q]);
                    let (x4r, x4i) = (src_re[a4 + q], src_im[a4 + q]);
                    let t1r = x1r + x4r;
                    let t1i = x1i + x4i;
                    let t2r = x2r + x3r;
                    let t2i = x2i + x3i;
                    let e1r = x1r - x4r;
                    let e1i = x1i - x4i;
                    let e2r = x2r - x3r;
                    let e2i = x2i - x3i;
                    dst_re[o + q] = x0r + t1r + t2r;
                    dst_im[o + q] = x0i + t1i + t2i;
                    let m1r = $smla!(C5_2, t2r, $smla!(C5_1, t1r, x0r));
                    let m1i = $smla!(C5_2, t2i, $smla!(C5_1, t1i, x0i));
                    let m2r = $smla!(C5_1, t2r, $smla!(C5_2, t1r, x0r));
                    let m2i = $smla!(C5_1, t2i, $smla!(C5_2, t1i, x0i));
                    let u1r = $smla!(s2, e2r, s1 * e1r);
                    let u1i = $smla!(s2, e2i, s1 * e1i);
                    let u2r = $smls!(s2, e1r, s1 * e2r);
                    let u2i = $smls!(s2, e1i, s1 * e2i);
                    let y1r = m1r - u1i;
                    let y1i = m1i + u1r;
                    let y4r = m1r + u1i;
                    let y4i = m1i - u1r;
                    let y2r = m2r - u2i;
                    let y2i = m2i + u2r;
                    let y3r = m2r + u2i;
                    let y3i = m2i - u2r;
                    dst_re[o + stride + q] = $smls!(y1r, w1r_s, y1i * w1i_s);
                    dst_im[o + stride + q] = $smla!(y1r, w1i_s, y1i * w1r_s);
                    dst_re[o + 2 * stride + q] = $smls!(y2r, w2r_s, y2i * w2i_s);
                    dst_im[o + 2 * stride + q] = $smla!(y2r, w2i_s, y2i * w2r_s);
                    dst_re[o + 3 * stride + q] = $smls!(y3r, w3r_s, y3i * w3i_s);
                    dst_im[o + 3 * stride + q] = $smla!(y3r, w3i_s, y3i * w3r_s);
                    dst_re[o + 4 * stride + q] = $smls!(y4r, w4r_s, y4i * w4i_s);
                    dst_im[o + 4 * stride + q] = $smla!(y4r, w4i_s, y4i * w4r_s);
                    q += 1;
                }
            }
        }

        };
    }

    // The plain generation: AVX2 only, every op in the same IEEE-754
    // order as the scalar stage loops → bit-identical results.
    define_stage_kernels!(
        "avx2",
        vmla_plain,
        vmls_plain,
        vmnla_plain,
        smla_plain,
        smls_plain,
        smnla_plain,
        stage2_s1,
        stage2_s2,
        stage2_w,
        stage3_s1,
        stage3_s2,
        stage3_w,
        stage5_s2,
        stage5_w
    );

    // The FMA generation: identical structure, but every mul+add /
    // mul+sub pair contracts to a fused op (vector *and* scalar
    // remainder, so arbitrary stage-range splits stay bitwise
    // consistent within the generation). Not bit-identical to scalar.
    #[cfg(feature = "fma")]
    define_stage_kernels!(
        "avx2,fma",
        vmla_fma,
        vmls_fma,
        vmnla_fma,
        smla_fma,
        smls_fma,
        smnla_fma,
        stage2_s1_fma,
        stage2_s2_fma,
        stage2_w_fma,
        stage3_s1_fma,
        stage3_s2_fma,
        stage3_w_fma,
        stage5_s2_fma,
        stage5_w_fma
    );

    /// Dispatch one stage shape to the FMA kernel when that generation
    /// is active, else to the plain AVX2 kernel.
    macro_rules! run_kernel {
        ($plain:ident, $fma:ident, ($($a:expr),* $(,)?)) => {{
            #[cfg(feature = "fma")]
            if fma_enabled() {
                unsafe { $fma($($a),*) };
                return true;
            }
            unsafe { $plain($($a),*) };
            true
        }};
    }

    #[allow(clippy::too_many_arguments)]
    pub(crate) fn try_stage2(
        sign: f64,
        tw_re: &[f64],
        tw_im: &[f64],
        src_re: &[f64],
        src_im: &[f64],
        dst_re: &mut [f64],
        dst_im: &mut [f64],
        p_lo: usize,
        p_hi: usize,
        m: usize,
        stride: usize,
    ) -> bool {
        if !avx2_enabled() {
            return false;
        }
        debug_assert!(p_hi <= m && tw_re.len() >= m && tw_im.len() >= m);
        match stride {
            1 => run_kernel!(
                stage2_s1,
                stage2_s1_fma,
                (sign, tw_re, tw_im, src_re, src_im, dst_re, dst_im, p_lo, p_hi, m)
            ),
            2 => run_kernel!(
                stage2_s2,
                stage2_s2_fma,
                (sign, tw_re, tw_im, src_re, src_im, dst_re, dst_im, p_lo, p_hi, m)
            ),
            s if s >= 4 => run_kernel!(
                stage2_w,
                stage2_w_fma,
                (sign, tw_re, tw_im, src_re, src_im, dst_re, dst_im, p_lo, p_hi, m, s)
            ),
            _ => false,
        }
    }

    #[allow(clippy::too_many_arguments)]
    pub(crate) fn try_stage3(
        sign: f64,
        tw_re: &[f64],
        tw_im: &[f64],
        src_re: &[f64],
        src_im: &[f64],
        dst_re: &mut [f64],
        dst_im: &mut [f64],
        p_lo: usize,
        p_hi: usize,
        m: usize,
        stride: usize,
    ) -> bool {
        if !avx2_enabled() {
            return false;
        }
        debug_assert!(p_hi <= m && tw_re.len() >= 2 * m && tw_im.len() >= 2 * m);
        match stride {
            1 => run_kernel!(
                stage3_s1,
                stage3_s1_fma,
                (sign, tw_re, tw_im, src_re, src_im, dst_re, dst_im, p_lo, p_hi, m)
            ),
            2 => run_kernel!(
                stage3_s2,
                stage3_s2_fma,
                (sign, tw_re, tw_im, src_re, src_im, dst_re, dst_im, p_lo, p_hi, m)
            ),
            s if s >= 4 => run_kernel!(
                stage3_w,
                stage3_w_fma,
                (sign, tw_re, tw_im, src_re, src_im, dst_re, dst_im, p_lo, p_hi, m, s)
            ),
            _ => false,
        }
    }

    #[allow(clippy::too_many_arguments)]
    pub(crate) fn try_stage5(
        sign: f64,
        tw_re: &[f64],
        tw_im: &[f64],
        src_re: &[f64],
        src_im: &[f64],
        dst_re: &mut [f64],
        dst_im: &mut [f64],
        p_lo: usize,
        p_hi: usize,
        m: usize,
        stride: usize,
    ) -> bool {
        if !avx2_enabled() {
            return false;
        }
        debug_assert!(p_hi <= m && tw_re.len() >= 4 * m && tw_im.len() >= 4 * m);
        match stride {
            2 => run_kernel!(
                stage5_s2,
                stage5_s2_fma,
                (sign, tw_re, tw_im, src_re, src_im, dst_re, dst_im, p_lo, p_hi, m)
            ),
            s if s >= 4 => run_kernel!(
                stage5_w,
                stage5_w_fma,
                (sign, tw_re, tw_im, src_re, src_im, dst_re, dst_im, p_lo, p_hi, m, s)
            ),
            _ => false,
        }
    }

    // ---- In-register transpose kernels --------------------------------
    //
    // Pure data movement — no arithmetic at all — so every consumer
    // (column-tile gather/scatter, the barrier transpose, the rect
    // transpose on the real route) is bit-identical to its scalar loop
    // in every kernel generation. The 4×4 f64 transpose is the
    // primitive: unpacklo/unpackhi pair rows within 128-bit lanes,
    // then permute2f128 crosses the lanes. 8×8 blocks are four 4×4
    // quadrant transposes (an 8-wide f64 row is two ymm registers).

    /// Transpose a 4×4 f64 block held in four ymm registers: output
    /// vector `j` holds lane `j` of each input (`out_j[i] = in_i[j]`).
    #[target_feature(enable = "avx2")]
    unsafe fn tr4(
        r0: __m256d,
        r1: __m256d,
        r2: __m256d,
        r3: __m256d,
    ) -> (__m256d, __m256d, __m256d, __m256d) {
        let t0 = _mm256_unpacklo_pd(r0, r1);
        let t1 = _mm256_unpackhi_pd(r0, r1);
        let t2 = _mm256_unpacklo_pd(r2, r3);
        let t3 = _mm256_unpackhi_pd(r2, r3);
        (
            _mm256_permute2f128_pd(t0, t2, 0x20),
            _mm256_permute2f128_pd(t1, t3, 0x20),
            _mm256_permute2f128_pd(t0, t2, 0x31),
            _mm256_permute2f128_pd(t1, t3, 0x31),
        )
    }

    /// Transpose one 4×4 block out-of-place: rows of `src` (stride
    /// `ss`) become rows of `dst` (stride `ds`).
    #[target_feature(enable = "avx2")]
    unsafe fn tr4x4_block(src: *const f64, ss: usize, dst: *mut f64, ds: usize) {
        let a = _mm256_loadu_pd(src);
        let b = _mm256_loadu_pd(src.add(ss));
        let c = _mm256_loadu_pd(src.add(2 * ss));
        let d = _mm256_loadu_pd(src.add(3 * ss));
        let (t0, t1, t2, t3) = tr4(a, b, c, d);
        _mm256_storeu_pd(dst, t0);
        _mm256_storeu_pd(dst.add(ds), t1);
        _mm256_storeu_pd(dst.add(2 * ds), t2);
        _mm256_storeu_pd(dst.add(3 * ds), t3);
    }

    /// Transpose one 8×8 block out-of-place as four 4×4 quadrants
    /// (each 8-wide f64 row spans two ymm registers): the off-diagonal
    /// quadrants swap places, the diagonal ones transpose in place.
    #[target_feature(enable = "avx2")]
    unsafe fn tr8x8_block(src: *const f64, ss: usize, dst: *mut f64, ds: usize) {
        tr4x4_block(src, ss, dst, ds);
        tr4x4_block(src.add(4), ss, dst.add(4 * ds), ds);
        tr4x4_block(src.add(4 * ss), ss, dst.add(4), ds);
        tr4x4_block(src.add(4 * ss + 4), ss, dst.add(4 * ds + 4), ds);
    }

    /// Swap-transpose two disjoint 4×4 blocks of an `n`-stride plane in
    /// place: `a` receives the transpose of `b` and vice versa. All
    /// eight loads complete before the first store.
    #[target_feature(enable = "avx2")]
    unsafe fn tr4x4_swap(a: *mut f64, b: *mut f64, n: usize) {
        let a0 = _mm256_loadu_pd(a);
        let a1 = _mm256_loadu_pd(a.add(n));
        let a2 = _mm256_loadu_pd(a.add(2 * n));
        let a3 = _mm256_loadu_pd(a.add(3 * n));
        let b0 = _mm256_loadu_pd(b);
        let b1 = _mm256_loadu_pd(b.add(n));
        let b2 = _mm256_loadu_pd(b.add(2 * n));
        let b3 = _mm256_loadu_pd(b.add(3 * n));
        let (ta0, ta1, ta2, ta3) = tr4(a0, a1, a2, a3);
        let (tb0, tb1, tb2, tb3) = tr4(b0, b1, b2, b3);
        _mm256_storeu_pd(a, tb0);
        _mm256_storeu_pd(a.add(n), tb1);
        _mm256_storeu_pd(a.add(2 * n), tb2);
        _mm256_storeu_pd(a.add(3 * n), tb3);
        _mm256_storeu_pd(b, ta0);
        _mm256_storeu_pd(b.add(n), ta1);
        _mm256_storeu_pd(b.add(2 * n), ta2);
        _mm256_storeu_pd(b.add(3 * n), ta3);
    }

    /// Transpose a 4×4 block of an `n`-stride plane in place (used for
    /// blocks sitting on the main diagonal). Loads before stores, so
    /// aliasing the block with itself is fine.
    #[target_feature(enable = "avx2")]
    unsafe fn tr4x4_inplace(p: *mut f64, n: usize) {
        let a = _mm256_loadu_pd(p);
        let b = _mm256_loadu_pd(p.add(n));
        let c = _mm256_loadu_pd(p.add(2 * n));
        let d = _mm256_loadu_pd(p.add(3 * n));
        let (t0, t1, t2, t3) = tr4(a, b, c, d);
        _mm256_storeu_pd(p, t0);
        _mm256_storeu_pd(p.add(n), t1);
        _mm256_storeu_pd(p.add(2 * n), t2);
        _mm256_storeu_pd(p.add(3 * n), t3);
    }

    #[target_feature(enable = "avx2")]
    unsafe fn transpose_block_core(
        src: *const f64,
        ss: usize,
        dst: *mut f64,
        ds: usize,
        nr: usize,
        nc: usize,
    ) {
        let mut i = 0usize;
        while i + 8 <= nr {
            let mut j = 0usize;
            while j + 8 <= nc {
                tr8x8_block(src.add(i * ss + j), ss, dst.add(j * ds + i), ds);
                j += 8;
            }
            while j + 4 <= nc {
                tr4x4_block(src.add(i * ss + j), ss, dst.add(j * ds + i), ds);
                tr4x4_block(src.add((i + 4) * ss + j), ss, dst.add(j * ds + i + 4), ds);
                j += 4;
            }
            for c in j..nc {
                for r in i..i + 8 {
                    *dst.add(c * ds + r) = *src.add(r * ss + c);
                }
            }
            i += 8;
        }
        while i + 4 <= nr {
            let mut j = 0usize;
            while j + 4 <= nc {
                tr4x4_block(src.add(i * ss + j), ss, dst.add(j * ds + i), ds);
                j += 4;
            }
            for c in j..nc {
                for r in i..i + 4 {
                    *dst.add(c * ds + r) = *src.add(r * ss + c);
                }
            }
            i += 4;
        }
        for r in i..nr {
            for c in 0..nc {
                *dst.add(c * ds + r) = *src.add(r * ss + c);
            }
        }
    }

    pub(crate) unsafe fn transpose_block(
        src: *const f64,
        ss: usize,
        dst: *mut f64,
        ds: usize,
        nr: usize,
        nc: usize,
    ) -> bool {
        if !avx2_enabled() {
            return false;
        }
        transpose_block_core(src, ss, dst, ds, nr, nc);
        true
    }

    #[target_feature(enable = "avx2")]
    unsafe fn transpose_swap_core(
        x: *mut f64,
        n: usize,
        r0: usize,
        r1: usize,
        c0: usize,
        c1: usize,
    ) {
        let rq = r0 + ((r1 - r0) & !3);
        let cq = c0 + ((c1 - c0) & !3);
        let mut r = r0;
        while r < rq {
            let mut c = c0;
            while c < cq {
                tr4x4_swap(x.add(r * n + c), x.add(c * n + r), n);
                c += 4;
            }
            r += 4;
        }
        // scalar rim: leftover columns of the aligned row band, then
        // the leftover rows in full
        for r in r0..r1 {
            let c_lo = if r < rq { cq } else { c0 };
            for c in c_lo..c1 {
                let i = r * n + c;
                let j = c * n + r;
                let t = *x.add(i);
                *x.add(i) = *x.add(j);
                *x.add(j) = t;
            }
        }
    }

    pub(crate) unsafe fn transpose_swap(
        x: *mut f64,
        n: usize,
        r0: usize,
        r1: usize,
        c0: usize,
        c1: usize,
    ) -> bool {
        if !avx2_enabled() {
            return false;
        }
        transpose_swap_core(x, n, r0, r1, c0, c1);
        true
    }

    #[target_feature(enable = "avx2")]
    unsafe fn transpose_diag_core(x: *mut f64, n: usize, lo: usize, hi: usize) {
        let q = lo + ((hi - lo) & !3);
        let mut bi = lo;
        while bi < q {
            tr4x4_inplace(x.add(bi * n + bi), n);
            let mut bj = bi + 4;
            while bj < q {
                tr4x4_swap(x.add(bi * n + bj), x.add(bj * n + bi), n);
                bj += 4;
            }
            bi += 4;
        }
        // scalar rim: every (r, c) pair with c >= q (covers r >= q too,
        // since only pairs above the diagonal are swapped)
        for r in lo..hi {
            for c in (r + 1).max(q)..hi {
                let i = r * n + c;
                let j = c * n + r;
                let t = *x.add(i);
                *x.add(i) = *x.add(j);
                *x.add(j) = t;
            }
        }
    }

    pub(crate) unsafe fn transpose_diag(x: *mut f64, n: usize, lo: usize, hi: usize) -> bool {
        if !avx2_enabled() {
            return false;
        }
        transpose_diag_core(x, n, lo, hi);
        true
    }

    // ---- Cross-row stage kernels (4-row tile, stride 1) ---------------
    //
    // Odd-radix stride-1 stages (pure 3^a·5^b row lengths) have no
    // within-row vector shape: lanes would sit `m` apart. Across a
    // 4-row tile they do — four rows' elements at the same position are
    // a strided 4×4 block, and `tr4` turns unit-stride quad loads into
    // row-lane vectors. The butterfly then runs 4 rows at a time with
    // broadcast twiddles in the exact scalar op order, and the outputs
    // transpose back into unit-stride quad stores. Single plain-op
    // generation, like the tail codelets.

    #[allow(clippy::too_many_arguments)]
    pub(crate) fn try_stage3_xrow4(
        sign: f64,
        tw_re: &[f64],
        tw_im: &[f64],
        src_re: &[f64],
        src_im: &[f64],
        dst_re: &mut [f64],
        dst_im: &mut [f64],
        n: usize,
        m: usize,
    ) -> usize {
        // Under the FMA generation the *per-row* stride-1 radix-3 path
        // runs the contracted kernel; this body is plain-op, so mixing
        // them would make a row's bits depend on its tile width.
        if !avx2_enabled() || fma_enabled() {
            return 0;
        }
        let qend = m & !3;
        if qend == 0 {
            return 0;
        }
        debug_assert!(n == 3 * m);
        debug_assert!(src_re.len() >= 4 * n && src_im.len() >= 4 * n);
        debug_assert!(dst_re.len() >= 4 * n && dst_im.len() >= 4 * n);
        debug_assert!(tw_re.len() >= 2 * m && tw_im.len() >= 2 * m);
        unsafe {
            xrow4_r3(
                sign,
                tw_re.as_ptr(),
                tw_im.as_ptr(),
                src_re.as_ptr(),
                src_im.as_ptr(),
                dst_re.as_mut_ptr(),
                dst_im.as_mut_ptr(),
                n,
                m,
                qend,
            );
        }
        qend
    }

    #[allow(clippy::too_many_arguments)]
    pub(crate) fn try_stage5_xrow4(
        sign: f64,
        tw_re: &[f64],
        tw_im: &[f64],
        src_re: &[f64],
        src_im: &[f64],
        dst_re: &mut [f64],
        dst_im: &mut [f64],
        n: usize,
        m: usize,
    ) -> usize {
        // Radix-5 stride-1 is scalar plain-op per row in every
        // generation (try_stage5 declines stride 1), so this body is
        // bit-compatible under fma too and always dispatches.
        if !avx2_enabled() {
            return 0;
        }
        let qend = m & !3;
        if qend == 0 {
            return 0;
        }
        debug_assert!(n == 5 * m);
        debug_assert!(src_re.len() >= 4 * n && src_im.len() >= 4 * n);
        debug_assert!(dst_re.len() >= 4 * n && dst_im.len() >= 4 * n);
        debug_assert!(tw_re.len() >= 4 * m && tw_im.len() >= 4 * m);
        unsafe {
            xrow4_r5(
                sign,
                tw_re.as_ptr(),
                tw_im.as_ptr(),
                src_re.as_ptr(),
                src_im.as_ptr(),
                dst_re.as_mut_ptr(),
                dst_im.as_mut_ptr(),
                n,
                m,
                qend,
            );
        }
        qend
    }

    /// Which output vectors feed each unit-stride store quad: flat
    /// output index `l = 3j + k` (position offset `j`, branch `k`)
    /// lands at row offset `3·p0 + l`, so quad `t` packs `(j, k)` pairs
    /// with `l ∈ [4t, 4t+4)`.
    const R3_QUADS: [[(usize, usize); 4]; 3] = [
        [(0, 0), (0, 1), (0, 2), (1, 0)],
        [(1, 1), (1, 2), (2, 0), (2, 1)],
        [(2, 2), (3, 0), (3, 1), (3, 2)],
    ];

    /// Radix-5 analogue of [`R3_QUADS`]: `l = 5j + k`.
    const R5_QUADS: [[(usize, usize); 4]; 5] = [
        [(0, 0), (0, 1), (0, 2), (0, 3)],
        [(0, 4), (1, 0), (1, 1), (1, 2)],
        [(1, 3), (1, 4), (2, 0), (2, 1)],
        [(2, 2), (2, 3), (2, 4), (3, 0)],
        [(3, 1), (3, 2), (3, 3), (3, 4)],
    ];

    #[allow(clippy::too_many_arguments)]
    #[target_feature(enable = "avx2")]
    unsafe fn xrow4_r3(
        sign: f64,
        twr: *const f64,
        twi: *const f64,
        sr: *const f64,
        si: *const f64,
        dr: *mut f64,
        di: *mut f64,
        n: usize,
        m: usize,
        qend: usize,
    ) {
        let c3 = _mm256_set1_pd(C3);
        let s3 = _mm256_set1_pd(sign * (-S3));
        let mut p0 = 0usize;
        while p0 < qend {
            // gather: branch k, rows 0..4, positions p0..p0+4 →
            // per-position row-lane vectors x[k][j]
            let mut xr = [[_mm256_setzero_pd(); 4]; 3];
            let mut xi = [[_mm256_setzero_pd(); 4]; 3];
            for (k, (xrk, xik)) in xr.iter_mut().zip(xi.iter_mut()).enumerate() {
                let base = k * m + p0;
                let (v0, v1, v2, v3) = tr4(
                    _mm256_loadu_pd(sr.add(base)),
                    _mm256_loadu_pd(sr.add(n + base)),
                    _mm256_loadu_pd(sr.add(2 * n + base)),
                    _mm256_loadu_pd(sr.add(3 * n + base)),
                );
                *xrk = [v0, v1, v2, v3];
                let (v0, v1, v2, v3) = tr4(
                    _mm256_loadu_pd(si.add(base)),
                    _mm256_loadu_pd(si.add(n + base)),
                    _mm256_loadu_pd(si.add(2 * n + base)),
                    _mm256_loadu_pd(si.add(3 * n + base)),
                );
                *xik = [v0, v1, v2, v3];
            }
            // butterfly: y[j][k], lanes = rows; scalar op order with
            // broadcast twiddles
            let mut yr = [[_mm256_setzero_pd(); 3]; 4];
            let mut yi = [[_mm256_setzero_pd(); 3]; 4];
            for j in 0..4 {
                let t = 2 * (p0 + j);
                let w1r = _mm256_set1_pd(*twr.add(t));
                let w1i = _mm256_set1_pd(sign * *twi.add(t));
                let w2r = _mm256_set1_pd(*twr.add(t + 1));
                let w2i = _mm256_set1_pd(sign * *twi.add(t + 1));
                let (x0r, x0i) = (xr[0][j], xi[0][j]);
                let (x1r, x1i) = (xr[1][j], xi[1][j]);
                let (x2r, x2i) = (xr[2][j], xi[2][j]);
                let tr = _mm256_add_pd(x1r, x2r);
                let ti = _mm256_add_pd(x1i, x2i);
                let dr_ = _mm256_sub_pd(x1r, x2r);
                let di_ = _mm256_sub_pd(x1i, x2i);
                yr[j][0] = _mm256_add_pd(x0r, tr);
                yi[j][0] = _mm256_add_pd(x0i, ti);
                let br = _mm256_add_pd(x0r, _mm256_mul_pd(c3, tr));
                let bi = _mm256_add_pd(x0i, _mm256_mul_pd(c3, ti));
                let y1r = _mm256_sub_pd(br, _mm256_mul_pd(s3, di_));
                let y1i = _mm256_add_pd(bi, _mm256_mul_pd(s3, dr_));
                let y2r = _mm256_add_pd(br, _mm256_mul_pd(s3, di_));
                let y2i = _mm256_sub_pd(bi, _mm256_mul_pd(s3, dr_));
                yr[j][1] = _mm256_sub_pd(_mm256_mul_pd(y1r, w1r), _mm256_mul_pd(y1i, w1i));
                yi[j][1] = _mm256_add_pd(_mm256_mul_pd(y1r, w1i), _mm256_mul_pd(y1i, w1r));
                yr[j][2] = _mm256_sub_pd(_mm256_mul_pd(y2r, w2r), _mm256_mul_pd(y2i, w2i));
                yi[j][2] = _mm256_add_pd(_mm256_mul_pd(y2r, w2i), _mm256_mul_pd(y2i, w2r));
            }
            // scatter: transpose each output quad back to row-major
            // unit-stride stores
            let ob = 3 * p0;
            for (t, ix) in R3_QUADS.iter().enumerate() {
                let o = ob + 4 * t;
                let (w0, w1, w2, w3) = tr4(
                    yr[ix[0].0][ix[0].1],
                    yr[ix[1].0][ix[1].1],
                    yr[ix[2].0][ix[2].1],
                    yr[ix[3].0][ix[3].1],
                );
                _mm256_storeu_pd(dr.add(o), w0);
                _mm256_storeu_pd(dr.add(n + o), w1);
                _mm256_storeu_pd(dr.add(2 * n + o), w2);
                _mm256_storeu_pd(dr.add(3 * n + o), w3);
                let (w0, w1, w2, w3) = tr4(
                    yi[ix[0].0][ix[0].1],
                    yi[ix[1].0][ix[1].1],
                    yi[ix[2].0][ix[2].1],
                    yi[ix[3].0][ix[3].1],
                );
                _mm256_storeu_pd(di.add(o), w0);
                _mm256_storeu_pd(di.add(n + o), w1);
                _mm256_storeu_pd(di.add(2 * n + o), w2);
                _mm256_storeu_pd(di.add(3 * n + o), w3);
            }
            p0 += 4;
        }
    }

    #[allow(clippy::too_many_arguments)]
    #[target_feature(enable = "avx2")]
    unsafe fn xrow4_r5(
        sign: f64,
        twr: *const f64,
        twi: *const f64,
        sr: *const f64,
        si: *const f64,
        dr: *mut f64,
        di: *mut f64,
        n: usize,
        m: usize,
        qend: usize,
    ) {
        let c1 = _mm256_set1_pd(C5_1);
        let c2 = _mm256_set1_pd(C5_2);
        let s1 = _mm256_set1_pd(sign * (-S5_1));
        let s2 = _mm256_set1_pd(sign * (-S5_2));
        let mut p0 = 0usize;
        while p0 < qend {
            let mut xr = [[_mm256_setzero_pd(); 4]; 5];
            let mut xi = [[_mm256_setzero_pd(); 4]; 5];
            for (k, (xrk, xik)) in xr.iter_mut().zip(xi.iter_mut()).enumerate() {
                let base = k * m + p0;
                let (v0, v1, v2, v3) = tr4(
                    _mm256_loadu_pd(sr.add(base)),
                    _mm256_loadu_pd(sr.add(n + base)),
                    _mm256_loadu_pd(sr.add(2 * n + base)),
                    _mm256_loadu_pd(sr.add(3 * n + base)),
                );
                *xrk = [v0, v1, v2, v3];
                let (v0, v1, v2, v3) = tr4(
                    _mm256_loadu_pd(si.add(base)),
                    _mm256_loadu_pd(si.add(n + base)),
                    _mm256_loadu_pd(si.add(2 * n + base)),
                    _mm256_loadu_pd(si.add(3 * n + base)),
                );
                *xik = [v0, v1, v2, v3];
            }
            let mut yr = [[_mm256_setzero_pd(); 5]; 4];
            let mut yi = [[_mm256_setzero_pd(); 5]; 4];
            for j in 0..4 {
                let t = 4 * (p0 + j);
                let wr = [
                    _mm256_set1_pd(*twr.add(t)),
                    _mm256_set1_pd(*twr.add(t + 1)),
                    _mm256_set1_pd(*twr.add(t + 2)),
                    _mm256_set1_pd(*twr.add(t + 3)),
                ];
                let wi = [
                    _mm256_set1_pd(sign * *twi.add(t)),
                    _mm256_set1_pd(sign * *twi.add(t + 1)),
                    _mm256_set1_pd(sign * *twi.add(t + 2)),
                    _mm256_set1_pd(sign * *twi.add(t + 3)),
                ];
                let (x0r, x0i) = (xr[0][j], xi[0][j]);
                let (x1r, x1i) = (xr[1][j], xi[1][j]);
                let (x2r, x2i) = (xr[2][j], xi[2][j]);
                let (x3r, x3i) = (xr[3][j], xi[3][j]);
                let (x4r, x4i) = (xr[4][j], xi[4][j]);
                let t1r = _mm256_add_pd(x1r, x4r);
                let t1i = _mm256_add_pd(x1i, x4i);
                let t2r = _mm256_add_pd(x2r, x3r);
                let t2i = _mm256_add_pd(x2i, x3i);
                let e1r = _mm256_sub_pd(x1r, x4r);
                let e1i = _mm256_sub_pd(x1i, x4i);
                let e2r = _mm256_sub_pd(x2r, x3r);
                let e2i = _mm256_sub_pd(x2i, x3i);
                yr[j][0] = _mm256_add_pd(_mm256_add_pd(x0r, t1r), t2r);
                yi[j][0] = _mm256_add_pd(_mm256_add_pd(x0i, t1i), t2i);
                let m1r = _mm256_add_pd(
                    _mm256_add_pd(x0r, _mm256_mul_pd(c1, t1r)),
                    _mm256_mul_pd(c2, t2r),
                );
                let m1i = _mm256_add_pd(
                    _mm256_add_pd(x0i, _mm256_mul_pd(c1, t1i)),
                    _mm256_mul_pd(c2, t2i),
                );
                let m2r = _mm256_add_pd(
                    _mm256_add_pd(x0r, _mm256_mul_pd(c2, t1r)),
                    _mm256_mul_pd(c1, t2r),
                );
                let m2i = _mm256_add_pd(
                    _mm256_add_pd(x0i, _mm256_mul_pd(c2, t1i)),
                    _mm256_mul_pd(c1, t2i),
                );
                let u1r = _mm256_add_pd(_mm256_mul_pd(s1, e1r), _mm256_mul_pd(s2, e2r));
                let u1i = _mm256_add_pd(_mm256_mul_pd(s1, e1i), _mm256_mul_pd(s2, e2i));
                let u2r = _mm256_sub_pd(_mm256_mul_pd(s2, e1r), _mm256_mul_pd(s1, e2r));
                let u2i = _mm256_sub_pd(_mm256_mul_pd(s2, e1i), _mm256_mul_pd(s1, e2i));
                let y1r = _mm256_sub_pd(m1r, u1i);
                let y1i = _mm256_add_pd(m1i, u1r);
                let y4r = _mm256_add_pd(m1r, u1i);
                let y4i = _mm256_sub_pd(m1i, u1r);
                let y2r = _mm256_sub_pd(m2r, u2i);
                let y2i = _mm256_add_pd(m2i, u2r);
                let y3r = _mm256_add_pd(m2r, u2i);
                let y3i = _mm256_sub_pd(m2i, u2r);
                yr[j][1] = _mm256_sub_pd(_mm256_mul_pd(y1r, wr[0]), _mm256_mul_pd(y1i, wi[0]));
                yi[j][1] = _mm256_add_pd(_mm256_mul_pd(y1r, wi[0]), _mm256_mul_pd(y1i, wr[0]));
                yr[j][2] = _mm256_sub_pd(_mm256_mul_pd(y2r, wr[1]), _mm256_mul_pd(y2i, wi[1]));
                yi[j][2] = _mm256_add_pd(_mm256_mul_pd(y2r, wi[1]), _mm256_mul_pd(y2i, wr[1]));
                yr[j][3] = _mm256_sub_pd(_mm256_mul_pd(y3r, wr[2]), _mm256_mul_pd(y3i, wi[2]));
                yi[j][3] = _mm256_add_pd(_mm256_mul_pd(y3r, wi[2]), _mm256_mul_pd(y3i, wr[2]));
                yr[j][4] = _mm256_sub_pd(_mm256_mul_pd(y4r, wr[3]), _mm256_mul_pd(y4i, wi[3]));
                yi[j][4] = _mm256_add_pd(_mm256_mul_pd(y4r, wi[3]), _mm256_mul_pd(y4i, wr[3]));
            }
            let ob = 5 * p0;
            for (t, ix) in R5_QUADS.iter().enumerate() {
                let o = ob + 4 * t;
                let (w0, w1, w2, w3) = tr4(
                    yr[ix[0].0][ix[0].1],
                    yr[ix[1].0][ix[1].1],
                    yr[ix[2].0][ix[2].1],
                    yr[ix[3].0][ix[3].1],
                );
                _mm256_storeu_pd(dr.add(o), w0);
                _mm256_storeu_pd(dr.add(n + o), w1);
                _mm256_storeu_pd(dr.add(2 * n + o), w2);
                _mm256_storeu_pd(dr.add(3 * n + o), w3);
                let (w0, w1, w2, w3) = tr4(
                    yi[ix[0].0][ix[0].1],
                    yi[ix[1].0][ix[1].1],
                    yi[ix[2].0][ix[2].1],
                    yi[ix[3].0][ix[3].1],
                );
                _mm256_storeu_pd(di.add(o), w0);
                _mm256_storeu_pd(di.add(n + o), w1);
                _mm256_storeu_pd(di.add(2 * n + o), w2);
                _mm256_storeu_pd(di.add(3 * n + o), w3);
            }
            p0 += 4;
        }
    }

    // ---- AVX2 tail-codelet bodies -------------------------------------
    //
    // One generation only (plain AVX2): the FFT4/FFT8 butterflies have
    // no worthwhile mul+add chains to fuse, so an FMA variant would buy
    // nothing and cost bit-identity. Keeping a single body means the
    // tail sweep is *always* bit-identical to the scalar codelet, under
    // every feature combination.

    /// Vectorized FFT4 columns: butterflies `q, q+1, q+2, q+3` of the
    /// final fused radix-4 tail, 4 per iteration. Processes
    /// `qend = s & !3` columns (caller finishes the remainder in
    /// scalar); all loads complete before the first store so the
    /// in-place wrapper can alias `src == dst`.
    #[target_feature(enable = "avx2")]
    unsafe fn tail4_core(
        sign: f64,
        sr: *const f64,
        si: *const f64,
        dr: *mut f64,
        di: *mut f64,
        s: usize,
        qend: usize,
    ) {
        let sgn = _mm256_set1_pd(sign);
        let mut q = 0usize;
        while q < qend {
            let x0r = _mm256_loadu_pd(sr.add(q));
            let x0i = _mm256_loadu_pd(si.add(q));
            let x1r = _mm256_loadu_pd(sr.add(s + q));
            let x1i = _mm256_loadu_pd(si.add(s + q));
            let x2r = _mm256_loadu_pd(sr.add(2 * s + q));
            let x2i = _mm256_loadu_pd(si.add(2 * s + q));
            let x3r = _mm256_loadu_pd(sr.add(3 * s + q));
            let x3i = _mm256_loadu_pd(si.add(3 * s + q));
            let t0r = _mm256_add_pd(x0r, x2r);
            let t0i = _mm256_add_pd(x0i, x2i);
            let t1r = _mm256_add_pd(x1r, x3r);
            let t1i = _mm256_add_pd(x1i, x3i);
            let u0r = _mm256_sub_pd(x0r, x2r);
            let u0i = _mm256_sub_pd(x0i, x2i);
            let u1r = _mm256_sub_pd(x1r, x3r);
            let u1i = _mm256_sub_pd(x1i, x3i);
            let su1i = _mm256_mul_pd(sgn, u1i);
            let su1r = _mm256_mul_pd(sgn, u1r);
            _mm256_storeu_pd(dr.add(q), _mm256_add_pd(t0r, t1r));
            _mm256_storeu_pd(di.add(q), _mm256_add_pd(t0i, t1i));
            _mm256_storeu_pd(dr.add(s + q), _mm256_add_pd(u0r, su1i));
            _mm256_storeu_pd(di.add(s + q), _mm256_sub_pd(u0i, su1r));
            _mm256_storeu_pd(dr.add(2 * s + q), _mm256_sub_pd(t0r, t1r));
            _mm256_storeu_pd(di.add(2 * s + q), _mm256_sub_pd(t0i, t1i));
            _mm256_storeu_pd(dr.add(3 * s + q), _mm256_sub_pd(u0r, su1i));
            _mm256_storeu_pd(di.add(3 * s + q), _mm256_add_pd(u0i, su1r));
            q += 4;
        }
    }

    /// Vectorized FFT8 columns, 4 per iteration, same aliasing contract
    /// as [`tail4_core`].
    #[target_feature(enable = "avx2")]
    unsafe fn tail8_core(
        sign: f64,
        sr: *const f64,
        si: *const f64,
        dr: *mut f64,
        di: *mut f64,
        s: usize,
        qend: usize,
    ) {
        let sgn = _mm256_set1_pd(sign);
        let c8v = _mm256_set1_pd(C8);
        let neg0 = _mm256_set1_pd(-0.0);
        let mut q = 0usize;
        while q < qend {
            let x0r = _mm256_loadu_pd(sr.add(q));
            let x0i = _mm256_loadu_pd(si.add(q));
            let x1r = _mm256_loadu_pd(sr.add(s + q));
            let x1i = _mm256_loadu_pd(si.add(s + q));
            let x2r = _mm256_loadu_pd(sr.add(2 * s + q));
            let x2i = _mm256_loadu_pd(si.add(2 * s + q));
            let x3r = _mm256_loadu_pd(sr.add(3 * s + q));
            let x3i = _mm256_loadu_pd(si.add(3 * s + q));
            let x4r = _mm256_loadu_pd(sr.add(4 * s + q));
            let x4i = _mm256_loadu_pd(si.add(4 * s + q));
            let x5r = _mm256_loadu_pd(sr.add(5 * s + q));
            let x5i = _mm256_loadu_pd(si.add(5 * s + q));
            let x6r = _mm256_loadu_pd(sr.add(6 * s + q));
            let x6i = _mm256_loadu_pd(si.add(6 * s + q));
            let x7r = _mm256_loadu_pd(sr.add(7 * s + q));
            let x7i = _mm256_loadu_pd(si.add(7 * s + q));
            // FFT4 over evens (x0 x2 x4 x6) → e0..e3
            let t0r = _mm256_add_pd(x0r, x4r);
            let t0i = _mm256_add_pd(x0i, x4i);
            let t1r = _mm256_add_pd(x2r, x6r);
            let t1i = _mm256_add_pd(x2i, x6i);
            let u0r = _mm256_sub_pd(x0r, x4r);
            let u0i = _mm256_sub_pd(x0i, x4i);
            let u1r = _mm256_sub_pd(x2r, x6r);
            let u1i = _mm256_sub_pd(x2i, x6i);
            let su1i = _mm256_mul_pd(sgn, u1i);
            let su1r = _mm256_mul_pd(sgn, u1r);
            let e0r = _mm256_add_pd(t0r, t1r);
            let e0i = _mm256_add_pd(t0i, t1i);
            let e1r = _mm256_add_pd(u0r, su1i);
            let e1i = _mm256_sub_pd(u0i, su1r);
            let e2r = _mm256_sub_pd(t0r, t1r);
            let e2i = _mm256_sub_pd(t0i, t1i);
            let e3r = _mm256_sub_pd(u0r, su1i);
            let e3i = _mm256_add_pd(u0i, su1r);
            // FFT4 over odds (x1 x3 x5 x7) → o0..o3
            let t0r = _mm256_add_pd(x1r, x5r);
            let t0i = _mm256_add_pd(x1i, x5i);
            let t1r = _mm256_add_pd(x3r, x7r);
            let t1i = _mm256_add_pd(x3i, x7i);
            let u0r = _mm256_sub_pd(x1r, x5r);
            let u0i = _mm256_sub_pd(x1i, x5i);
            let u1r = _mm256_sub_pd(x3r, x7r);
            let u1i = _mm256_sub_pd(x3i, x7i);
            let su1i = _mm256_mul_pd(sgn, u1i);
            let su1r = _mm256_mul_pd(sgn, u1r);
            let o0r = _mm256_add_pd(t0r, t1r);
            let o0i = _mm256_add_pd(t0i, t1i);
            let o1r = _mm256_add_pd(u0r, su1i);
            let o1i = _mm256_sub_pd(u0i, su1r);
            let o2r = _mm256_sub_pd(t0r, t1r);
            let o2i = _mm256_sub_pd(t0i, t1i);
            let o3r = _mm256_sub_pd(u0r, su1i);
            let o3i = _mm256_add_pd(u0i, su1r);
            // twiddled odd terms: t1 = w^1·o1, t2 = w^2·o2, t3 = w^3·o3
            let t1r = _mm256_mul_pd(c8v, _mm256_add_pd(o1r, _mm256_mul_pd(sgn, o1i)));
            let t1i = _mm256_mul_pd(c8v, _mm256_sub_pd(o1i, _mm256_mul_pd(sgn, o1r)));
            let t2r = _mm256_mul_pd(sgn, o2i);
            let t2i = _mm256_xor_pd(_mm256_mul_pd(sgn, o2r), neg0);
            let t3r = _mm256_xor_pd(
                _mm256_mul_pd(c8v, _mm256_sub_pd(o3r, _mm256_mul_pd(sgn, o3i))),
                neg0,
            );
            let t3i = _mm256_xor_pd(
                _mm256_mul_pd(c8v, _mm256_add_pd(o3i, _mm256_mul_pd(sgn, o3r))),
                neg0,
            );
            _mm256_storeu_pd(dr.add(q), _mm256_add_pd(e0r, o0r));
            _mm256_storeu_pd(di.add(q), _mm256_add_pd(e0i, o0i));
            _mm256_storeu_pd(dr.add(s + q), _mm256_add_pd(e1r, t1r));
            _mm256_storeu_pd(di.add(s + q), _mm256_add_pd(e1i, t1i));
            _mm256_storeu_pd(dr.add(2 * s + q), _mm256_add_pd(e2r, t2r));
            _mm256_storeu_pd(di.add(2 * s + q), _mm256_add_pd(e2i, t2i));
            _mm256_storeu_pd(dr.add(3 * s + q), _mm256_add_pd(e3r, t3r));
            _mm256_storeu_pd(di.add(3 * s + q), _mm256_add_pd(e3i, t3i));
            _mm256_storeu_pd(dr.add(4 * s + q), _mm256_sub_pd(e0r, o0r));
            _mm256_storeu_pd(di.add(4 * s + q), _mm256_sub_pd(e0i, o0i));
            _mm256_storeu_pd(dr.add(5 * s + q), _mm256_sub_pd(e1r, t1r));
            _mm256_storeu_pd(di.add(5 * s + q), _mm256_sub_pd(e1i, t1i));
            _mm256_storeu_pd(dr.add(6 * s + q), _mm256_sub_pd(e2r, t2r));
            _mm256_storeu_pd(di.add(6 * s + q), _mm256_sub_pd(e2i, t2i));
            _mm256_storeu_pd(dr.add(7 * s + q), _mm256_sub_pd(e3r, t3r));
            _mm256_storeu_pd(di.add(7 * s + q), _mm256_sub_pd(e3i, t3i));
            q += 4;
        }
    }

    pub(crate) fn tail4_oop(
        sign: f64,
        src_re: &[f64],
        src_im: &[f64],
        dst_re: &mut [f64],
        dst_im: &mut [f64],
    ) -> usize {
        if !avx2_enabled() {
            return 0;
        }
        let s = src_re.len() / 4;
        let qend = s & !3;
        if qend == 0 {
            return 0;
        }
        unsafe {
            tail4_core(
                sign,
                src_re.as_ptr(),
                src_im.as_ptr(),
                dst_re.as_mut_ptr(),
                dst_im.as_mut_ptr(),
                s,
                qend,
            )
        };
        qend
    }

    pub(crate) fn tail4_inplace(sign: f64, re: &mut [f64], im: &mut [f64]) -> usize {
        if !avx2_enabled() {
            return 0;
        }
        let s = re.len() / 4;
        let qend = s & !3;
        if qend == 0 {
            return 0;
        }
        let pr = re.as_mut_ptr();
        let pi = im.as_mut_ptr();
        unsafe { tail4_core(sign, pr as *const f64, pi as *const f64, pr, pi, s, qend) };
        qend
    }

    pub(crate) fn tail8_oop(
        sign: f64,
        src_re: &[f64],
        src_im: &[f64],
        dst_re: &mut [f64],
        dst_im: &mut [f64],
    ) -> usize {
        if !avx2_enabled() {
            return 0;
        }
        let s = src_re.len() / 8;
        let qend = s & !3;
        if qend == 0 {
            return 0;
        }
        unsafe {
            tail8_core(
                sign,
                src_re.as_ptr(),
                src_im.as_ptr(),
                dst_re.as_mut_ptr(),
                dst_im.as_mut_ptr(),
                s,
                qend,
            )
        };
        qend
    }

    pub(crate) fn tail8_inplace(sign: f64, re: &mut [f64], im: &mut [f64]) -> usize {
        if !avx2_enabled() {
            return 0;
        }
        let s = re.len() / 8;
        let qend = s & !3;
        if qend == 0 {
            return 0;
        }
        let pr = re.as_mut_ptr();
        let pi = im.as_mut_ptr();
        unsafe { tail8_core(sign, pr as *const f64, pi as *const f64, pr, pi, s, qend) };
        qend
    }
}

/// Portable stub: every probe reports `false`, every hook declines, so
/// callers always take the scalar loops. Compiled when the `simd`
/// feature is off or the target is not x86_64.
#[cfg(not(all(feature = "simd", target_arch = "x86_64")))]
mod imp {
    pub(crate) fn avx2_enabled() -> bool {
        false
    }

    pub(crate) fn fma_enabled() -> bool {
        false
    }

    #[allow(clippy::too_many_arguments)]
    pub(crate) fn try_stage2(
        _sign: f64,
        _tw_re: &[f64],
        _tw_im: &[f64],
        _src_re: &[f64],
        _src_im: &[f64],
        _dst_re: &mut [f64],
        _dst_im: &mut [f64],
        _p_lo: usize,
        _p_hi: usize,
        _m: usize,
        _stride: usize,
    ) -> bool {
        false
    }

    #[allow(clippy::too_many_arguments)]
    pub(crate) fn try_stage3(
        _sign: f64,
        _tw_re: &[f64],
        _tw_im: &[f64],
        _src_re: &[f64],
        _src_im: &[f64],
        _dst_re: &mut [f64],
        _dst_im: &mut [f64],
        _p_lo: usize,
        _p_hi: usize,
        _m: usize,
        _stride: usize,
    ) -> bool {
        false
    }

    #[allow(clippy::too_many_arguments)]
    pub(crate) fn try_stage5(
        _sign: f64,
        _tw_re: &[f64],
        _tw_im: &[f64],
        _src_re: &[f64],
        _src_im: &[f64],
        _dst_re: &mut [f64],
        _dst_im: &mut [f64],
        _p_lo: usize,
        _p_hi: usize,
        _m: usize,
        _stride: usize,
    ) -> bool {
        false
    }

    pub(crate) fn tail4_oop(
        _sign: f64,
        _src_re: &[f64],
        _src_im: &[f64],
        _dst_re: &mut [f64],
        _dst_im: &mut [f64],
    ) -> usize {
        0
    }

    pub(crate) fn tail4_inplace(_sign: f64, _re: &mut [f64], _im: &mut [f64]) -> usize {
        0
    }

    pub(crate) fn tail8_oop(
        _sign: f64,
        _src_re: &[f64],
        _src_im: &[f64],
        _dst_re: &mut [f64],
        _dst_im: &mut [f64],
    ) -> usize {
        0
    }

    pub(crate) fn tail8_inplace(_sign: f64, _re: &mut [f64], _im: &mut [f64]) -> usize {
        0
    }

    #[allow(clippy::too_many_arguments)]
    pub(crate) fn try_stage3_xrow4(
        _sign: f64,
        _tw_re: &[f64],
        _tw_im: &[f64],
        _src_re: &[f64],
        _src_im: &[f64],
        _dst_re: &mut [f64],
        _dst_im: &mut [f64],
        _n: usize,
        _m: usize,
    ) -> usize {
        0
    }

    #[allow(clippy::too_many_arguments)]
    pub(crate) fn try_stage5_xrow4(
        _sign: f64,
        _tw_re: &[f64],
        _tw_im: &[f64],
        _src_re: &[f64],
        _src_im: &[f64],
        _dst_re: &mut [f64],
        _dst_im: &mut [f64],
        _n: usize,
        _m: usize,
    ) -> usize {
        0
    }

    pub(crate) unsafe fn transpose_block(
        _src: *const f64,
        _ss: usize,
        _dst: *mut f64,
        _ds: usize,
        _nr: usize,
        _nc: usize,
    ) -> bool {
        false
    }

    pub(crate) unsafe fn transpose_swap(
        _x: *mut f64,
        _n: usize,
        _r0: usize,
        _r1: usize,
        _c0: usize,
        _c1: usize,
    ) -> bool {
        false
    }

    pub(crate) unsafe fn transpose_diag(_x: *mut f64, _n: usize, _lo: usize, _hi: usize) -> bool {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The feature lattice must be monotone: FMA implies AVX2, and both
    /// are constant across repeated probes (OnceLock-cached).
    #[test]
    fn detection_is_consistent() {
        let a1 = avx2_enabled();
        let a2 = avx2_enabled();
        assert_eq!(a1, a2, "avx2 probe must be stable");
        let f1 = fma_enabled();
        let f2 = fma_enabled();
        assert_eq!(f1, f2, "fma probe must be stable");
        assert!(
            !f1 || a1,
            "fma generation requires the avx2 kernels to exist"
        );
        #[cfg(not(all(feature = "simd", target_arch = "x86_64")))]
        {
            assert!(!a1 && !f1, "stub must report no SIMD support");
        }
        #[cfg(not(feature = "fma"))]
        {
            assert!(!f1, "fma generation requires --features fma");
        }
    }

    // Numeric coverage for every kernel shape (stride 1/2/wide for
    // radix-2/3/5, the AVX2 tails, and the FMA generation) lives in
    // rust/src/dft/radix.rs unit tests and rust/tests/radix_integration.rs,
    // where the kernels are exercised through real plans against the
    // scalar KernelVariant.
}
