//! Opt-in AVX2 fast path for the radix-2 butterfly stages (`simd`
//! cargo feature, x86_64 only).
//!
//! The scalar stage loops in [`crate::dft::radix`] / [`crate::dft::fft`]
//! autovectorize well when the lane width `stride` is ≥ 4, but the
//! *first* stages of the reordered schedule run at `stride` 1 and 2 —
//! there the per-`q` lane loop degenerates to scalar code and LLVM is
//! left vectorizing across butterflies on its own, which it does not do
//! reliably through the twiddle multiply. This module provides explicit
//! `core::arch` kernels for exactly those two shapes:
//!
//! * **stride 1** — four butterflies per iteration: contiguous loads of
//!   `a`, `b`, and the stage twiddles, with the element-interleaved
//!   outputs produced by `unpacklo/unpackhi` + a 128-bit lane permute.
//! * **stride 2** — two butterflies (four lanes) per iteration: outputs
//!   interleave at 128-bit granularity so a single `permute2f128` pair
//!   suffices; the per-butterfly twiddle is duplicated across its two
//!   lanes with `permute4x64`.
//!
//! **Bit-exactness contract:** the vector kernels perform the *same*
//! IEEE-754 operations in the same order as the scalar loop — mul, mul,
//! sub/add per complex multiply, never FMA. SIMD output is therefore
//! bit-identical to scalar output, which keeps the repo's thread-count
//! invariance and fused==barrier bit-exactness properties intact per
//! kernel variant, and lets tests assert exact equality between the
//! scalar and SIMD paths.
//!
//! Selection is at runtime: [`avx2_enabled`] caches one
//! `is_x86_feature_detected!("avx2")` probe; non-AVX2 machines (and
//! non-x86_64 builds, and builds without the feature) fall back to the
//! safe scalar loops with zero overhead beyond one branch per stage.

/// Is the AVX2 fast path compiled in *and* available on this CPU?
/// Always `false` without the `simd` feature or off x86_64.
pub fn avx2_enabled() -> bool {
    imp::avx2_enabled()
}

/// Try to run one radix-2 DIF stage over butterflies `p ∈ [p_lo, p_hi)`
/// with the AVX2 kernels. Returns `false` (having done nothing) when
/// the fast path is unavailable or the stage shape is not one it
/// handles; the caller then runs the scalar loop. Slice conventions
/// match [`crate::dft::radix::apply_stage_range`]: `src` planes are the
/// full row, `dst` planes start at the range's first output block, and
/// `tw[p]` is the stage twiddle for butterfly `p` (conjugated via
/// `sign` for the inverse transform).
#[allow(clippy::too_many_arguments)]
pub(crate) fn try_stage2(
    sign: f64,
    tw_re: &[f64],
    tw_im: &[f64],
    src_re: &[f64],
    src_im: &[f64],
    dst_re: &mut [f64],
    dst_im: &mut [f64],
    p_lo: usize,
    p_hi: usize,
    m: usize,
    stride: usize,
) -> bool {
    imp::try_stage2(sign, tw_re, tw_im, src_re, src_im, dst_re, dst_im, p_lo, p_hi, m, stride)
}

#[cfg(all(feature = "simd", target_arch = "x86_64"))]
mod imp {
    use std::sync::OnceLock;

    pub fn avx2_enabled() -> bool {
        static AVX2: OnceLock<bool> = OnceLock::new();
        *AVX2.get_or_init(|| std::arch::is_x86_feature_detected!("avx2"))
    }

    #[allow(clippy::too_many_arguments)]
    pub fn try_stage2(
        sign: f64,
        tw_re: &[f64],
        tw_im: &[f64],
        src_re: &[f64],
        src_im: &[f64],
        dst_re: &mut [f64],
        dst_im: &mut [f64],
        p_lo: usize,
        p_hi: usize,
        m: usize,
        stride: usize,
    ) -> bool {
        if !avx2_enabled() || stride > 2 {
            return false;
        }
        debug_assert!(p_hi <= m && tw_re.len() >= m && tw_im.len() >= m);
        // SAFETY: avx2_enabled() verified the CPU supports the target
        // features; all slice accesses inside stay within the bounds
        // asserted by apply_stage_range's dst-slice contract.
        unsafe {
            match stride {
                1 => stage2_stride1(sign, tw_re, tw_im, src_re, src_im, dst_re, dst_im, p_lo, p_hi, m),
                _ => stage2_stride2(sign, tw_re, tw_im, src_re, src_im, dst_re, dst_im, p_lo, p_hi, m),
            }
        }
        true
    }

    /// Radix-2 stage at `stride == 1`: butterfly `p` reads `src[p]`,
    /// `src[p+m]` and writes `dst[2(p−p_lo)]`, `dst[2(p−p_lo)+1]`.
    /// Four butterflies per iteration; the 4-lane `d0`/`d1` results are
    /// element-interleaved into 8 contiguous outputs.
    #[allow(clippy::too_many_arguments)]
    #[target_feature(enable = "avx2")]
    unsafe fn stage2_stride1(
        sign: f64,
        tw_re: &[f64],
        tw_im: &[f64],
        src_re: &[f64],
        src_im: &[f64],
        dst_re: &mut [f64],
        dst_im: &mut [f64],
        p_lo: usize,
        p_hi: usize,
        m: usize,
    ) {
        use std::arch::x86_64::*;
        let sgn = _mm256_set1_pd(sign);
        let mut p = p_lo;
        while p + 4 <= p_hi {
            let ar = _mm256_loadu_pd(src_re.as_ptr().add(p));
            let ai = _mm256_loadu_pd(src_im.as_ptr().add(p));
            let br = _mm256_loadu_pd(src_re.as_ptr().add(p + m));
            let bi = _mm256_loadu_pd(src_im.as_ptr().add(p + m));
            let wr = _mm256_loadu_pd(tw_re.as_ptr().add(p));
            let wi = _mm256_mul_pd(sgn, _mm256_loadu_pd(tw_im.as_ptr().add(p)));
            let d0r = _mm256_add_pd(ar, br);
            let d0i = _mm256_add_pd(ai, bi);
            let xr = _mm256_sub_pd(ar, br);
            let xi = _mm256_sub_pd(ai, bi);
            // same op order as the scalar loop: mul, mul, sub/add (no FMA)
            let d1r = _mm256_sub_pd(_mm256_mul_pd(xr, wr), _mm256_mul_pd(xi, wi));
            let d1i = _mm256_add_pd(_mm256_mul_pd(xr, wi), _mm256_mul_pd(xi, wr));
            // interleave lanes k of d0/d1 into out[2k], out[2k+1]:
            // unpacklo = [d0_0 d1_0 d0_2 d1_2], unpackhi = [d0_1 d1_1 d0_3 d1_3]
            let o = 2 * (p - p_lo);
            let lo = _mm256_unpacklo_pd(d0r, d1r);
            let hi = _mm256_unpackhi_pd(d0r, d1r);
            _mm256_storeu_pd(dst_re.as_mut_ptr().add(o), _mm256_permute2f128_pd(lo, hi, 0x20));
            _mm256_storeu_pd(dst_re.as_mut_ptr().add(o + 4), _mm256_permute2f128_pd(lo, hi, 0x31));
            let lo = _mm256_unpacklo_pd(d0i, d1i);
            let hi = _mm256_unpackhi_pd(d0i, d1i);
            _mm256_storeu_pd(dst_im.as_mut_ptr().add(o), _mm256_permute2f128_pd(lo, hi, 0x20));
            _mm256_storeu_pd(dst_im.as_mut_ptr().add(o + 4), _mm256_permute2f128_pd(lo, hi, 0x31));
            p += 4;
        }
        // remainder butterflies: the scalar expressions, verbatim
        while p < p_hi {
            let wr = tw_re[p];
            let wi = sign * tw_im[p];
            let (ar, ai) = (src_re[p], src_im[p]);
            let (br, bi) = (src_re[p + m], src_im[p + m]);
            let o = 2 * (p - p_lo);
            dst_re[o] = ar + br;
            dst_im[o] = ai + bi;
            let xr = ar - br;
            let xi = ai - bi;
            dst_re[o + 1] = xr * wr - xi * wi;
            dst_im[o + 1] = xr * wi + xi * wr;
            p += 1;
        }
    }

    /// Radix-2 stage at `stride == 2`: butterfly `p` reads lanes
    /// `src[2p..2p+2]`, `src[2(p+m)..2(p+m)+2]` and writes
    /// `dst[4(p−p_lo)..+2]` / `dst[4(p−p_lo)+2..+4]`. Two butterflies
    /// per iteration; outputs interleave at 128-bit granularity, so one
    /// `permute2f128` pair reshuffles them, and each butterfly's
    /// twiddle is duplicated across its two lanes.
    #[allow(clippy::too_many_arguments)]
    #[target_feature(enable = "avx2")]
    unsafe fn stage2_stride2(
        sign: f64,
        tw_re: &[f64],
        tw_im: &[f64],
        src_re: &[f64],
        src_im: &[f64],
        dst_re: &mut [f64],
        dst_im: &mut [f64],
        p_lo: usize,
        p_hi: usize,
        m: usize,
    ) {
        use std::arch::x86_64::*;
        let sgn = _mm256_set1_pd(sign);
        // [w_p, w_p, w_{p+1}, w_{p+1}] from a 128-bit pair load
        let dup = |tw: &[f64], p: usize| {
            let v = _mm256_castpd128_pd256(_mm_loadu_pd(tw.as_ptr().add(p)));
            _mm256_permute4x64_pd(v, 0x50)
        };
        let mut p = p_lo;
        while p + 2 <= p_hi {
            let ar = _mm256_loadu_pd(src_re.as_ptr().add(2 * p));
            let ai = _mm256_loadu_pd(src_im.as_ptr().add(2 * p));
            let br = _mm256_loadu_pd(src_re.as_ptr().add(2 * (p + m)));
            let bi = _mm256_loadu_pd(src_im.as_ptr().add(2 * (p + m)));
            let wr = dup(tw_re, p);
            let wi = _mm256_mul_pd(sgn, dup(tw_im, p));
            let d0r = _mm256_add_pd(ar, br);
            let d0i = _mm256_add_pd(ai, bi);
            let xr = _mm256_sub_pd(ar, br);
            let xi = _mm256_sub_pd(ai, bi);
            let d1r = _mm256_sub_pd(_mm256_mul_pd(xr, wr), _mm256_mul_pd(xi, wi));
            let d1i = _mm256_add_pd(_mm256_mul_pd(xr, wi), _mm256_mul_pd(xi, wr));
            // out[0..4] = [d0 lanes 0,1 | d1 lanes 0,1], out[4..8] = lanes 2,3
            let o = 4 * (p - p_lo);
            _mm256_storeu_pd(dst_re.as_mut_ptr().add(o), _mm256_permute2f128_pd(d0r, d1r, 0x20));
            _mm256_storeu_pd(dst_re.as_mut_ptr().add(o + 4), _mm256_permute2f128_pd(d0r, d1r, 0x31));
            _mm256_storeu_pd(dst_im.as_mut_ptr().add(o), _mm256_permute2f128_pd(d0i, d1i, 0x20));
            _mm256_storeu_pd(dst_im.as_mut_ptr().add(o + 4), _mm256_permute2f128_pd(d0i, d1i, 0x31));
            p += 2;
        }
        while p < p_hi {
            let wr = tw_re[p];
            let wi = sign * tw_im[p];
            for q in 0..2 {
                let (ar, ai) = (src_re[2 * p + q], src_im[2 * p + q]);
                let (br, bi) = (src_re[2 * (p + m) + q], src_im[2 * (p + m) + q]);
                let o = 4 * (p - p_lo) + q;
                dst_re[o] = ar + br;
                dst_im[o] = ai + bi;
                let xr = ar - br;
                let xi = ai - bi;
                dst_re[o + 2] = xr * wr - xi * wi;
                dst_im[o + 2] = xr * wi + xi * wr;
            }
            p += 1;
        }
    }
}

#[cfg(not(all(feature = "simd", target_arch = "x86_64")))]
mod imp {
    pub fn avx2_enabled() -> bool {
        false
    }

    #[allow(clippy::too_many_arguments)]
    pub fn try_stage2(
        _sign: f64,
        _tw_re: &[f64],
        _tw_im: &[f64],
        _src_re: &[f64],
        _src_im: &[f64],
        _dst_re: &mut [f64],
        _dst_im: &mut [f64],
        _p_lo: usize,
        _p_hi: usize,
        _m: usize,
        _stride: usize,
    ) -> bool {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn detection_is_consistent() {
        // cached probe must be stable across calls; without the feature
        // (or off x86_64) it is identically false
        assert_eq!(avx2_enabled(), avx2_enabled());
        #[cfg(not(all(feature = "simd", target_arch = "x86_64")))]
        assert!(!avx2_enabled());
    }

    // Scalar-vs-SIMD bit-exactness is asserted at the stage level from
    // `radix::tests` (stage_range_split_is_bit_exact runs both paths)
    // and end-to-end from `rust/tests/radix_integration.rs`, where the
    // Scalar-variant plan (never SIMD) is compared against the
    // Vectorized plan on every random 5-smooth size.
}
