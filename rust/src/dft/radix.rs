//! Mixed-radix (2/3/5) iterative Stockham DIF FFT.
//!
//! The paper's problem sizes are N = 128·k — mostly *not* powers of two
//! (384 = 2⁷·3, 640 = 2⁷·5, 1152 = 2⁷·3²). The radix-2 kernel
//! ([`crate::dft::fft`]) cannot run them natively, and routing them
//! through Bluestein's chirp-z ([`crate::dft::bluestein`]) pads to a
//! ≥ 2N power of two and pays three pow2 FFTs per row — a ~5-6x flop
//! overhead on exactly the sizes the paper benchmarks. This module
//! closes that gap with a native mixed-radix kernel: any 5-smooth length
//! (factors in {2, 3, 5}) runs in O(n log n) directly; Bluestein is
//! demoted to the non-smooth fallback (primes and the like).
//!
//! Same decimation-in-frequency Stockham formulation as the radix-2
//! kernel, generalized: state is viewed as an `(n_cur, stride)` matrix
//! with original index `stride·p + q`; a radix-r stage gathers the r
//! blocks `p, p+m, …, p+(r−1)m` (m = n_cur/r), applies the hard-coded
//! r-point butterfly, multiplies outputs k = 1..r by the stage twiddle
//! `exp(−2πi·p·k/n_cur)`, and scatters to blocks `r·p + k`. Each stage
//! divides `n_cur` by r and multiplies `stride` by r; the result lands
//! in natural order (no digit reversal).
//!
//! [`apply_stage_range`] applies one stage over a sub-range of `p`, so
//! the executor ([`crate::dft::exec`]) can split a *single long row*
//! across pool workers (disjoint output blocks per `p`) with bit-exact
//! results regardless of the split.

use crate::dft::fft::Direction;

/// Factor `n` into its {2, 3, 5} prime factors (ascending), or `None`
/// if `n` has any other prime factor (or is zero). `n == 1` factors as
/// the empty product.
pub fn factorize_235(n: usize) -> Option<Vec<usize>> {
    if n == 0 {
        return None;
    }
    let mut rem = n;
    let mut factors = Vec::new();
    for r in [2usize, 3, 5] {
        while rem % r == 0 {
            factors.push(r);
            rem /= r;
        }
    }
    if rem == 1 {
        Some(factors)
    } else {
        None
    }
}

/// Is `n` 5-smooth (product of 2s, 3s and 5s only)? Allocation-free —
/// this runs on every row-FFT dispatch.
pub fn is_five_smooth(n: usize) -> bool {
    if n == 0 {
        return false;
    }
    let mut rem = n;
    for r in [2usize, 3, 5] {
        while rem % r == 0 {
            rem /= r;
        }
    }
    rem == 1
}

/// Human-readable row-kernel description for a length (CLI reports).
pub fn kernel_summary(n: usize) -> String {
    if n == 0 {
        return "empty".to_string();
    }
    match factorize_235(n) {
        Some(f) if f.is_empty() => "identity".to_string(),
        Some(f) => {
            let (mut two, mut three, mut five) = (0usize, 0usize, 0usize);
            for r in f {
                match r {
                    2 => two += 1,
                    3 => three += 1,
                    _ => five += 1,
                }
            }
            let mut parts = Vec::new();
            for (b, e) in [(2usize, two), (3, three), (5, five)] {
                match e {
                    0 => {}
                    1 => parts.push(b.to_string()),
                    _ => parts.push(format!("{b}^{e}")),
                }
            }
            format!("mixed-radix {}", parts.join("*"))
        }
        None => {
            let m = (2 * n - 1).next_power_of_two();
            format!("bluestein (pow2 pad {m})")
        }
    }
}

/// One DIF stage: radix, sub-DFT geometry, and the twiddle table
/// `tw[p·(r−1) + (k−1)] = exp(−2πi·p·k/n_cur)` for p ∈ [0, m), k ∈ [1, r).
#[derive(Clone, Debug)]
pub struct RadixStage {
    pub radix: usize,
    /// DFT length still to be resolved when this stage runs.
    pub n_cur: usize,
    /// lane width (original-index stride factor) at this stage
    pub stride: usize,
    tw_re: Vec<f64>,
    tw_im: Vec<f64>,
}

impl RadixStage {
    /// Butterfly count of this stage (`n_cur / radix`).
    #[inline]
    pub fn butterflies(&self) -> usize {
        self.n_cur / self.radix
    }
}

/// Factor schedule + per-stage twiddles for a 5-smooth length — the
/// generalized plan that replaces pow2-only dispatch.
#[derive(Clone, Debug)]
pub struct RadixPlan {
    pub n: usize,
    /// radix schedule (ascending factors of n)
    pub factors: Vec<usize>,
    pub stages: Vec<RadixStage>,
}

impl RadixPlan {
    /// Plan a 5-smooth length; panics otherwise (see [`RadixPlan::try_new`]).
    pub fn new(n: usize) -> RadixPlan {
        RadixPlan::try_new(n)
            .unwrap_or_else(|| panic!("RadixPlan requires a 5-smooth length, got {n}"))
    }

    /// Plan a 5-smooth length, or `None` when `n` has other factors
    /// (those lengths belong to Bluestein).
    pub fn try_new(n: usize) -> Option<RadixPlan> {
        let factors = factorize_235(n)?;
        let mut stages = Vec::with_capacity(factors.len());
        let mut n_cur = n;
        let mut stride = 1usize;
        for &r in &factors {
            let m = n_cur / r;
            let mut tw_re = Vec::with_capacity(m * (r - 1));
            let mut tw_im = Vec::with_capacity(m * (r - 1));
            for p in 0..m {
                for k in 1..r {
                    // p·k mod n_cur keeps the angle argument small (exactness)
                    let pk = (p * k) % n_cur;
                    let ang = -2.0 * std::f64::consts::PI * pk as f64 / n_cur as f64;
                    tw_re.push(ang.cos());
                    tw_im.push(ang.sin());
                }
            }
            stages.push(RadixStage { radix: r, n_cur, stride, tw_re, tw_im });
            n_cur = m;
            stride *= r;
        }
        Some(RadixPlan { n, factors, stages })
    }
}

/// Transform a single length-`n` row in `re`/`im`, using `plan` and a
/// same-length ping-pong scratch. O(n log n), natural output order.
pub fn fft_row_radix(
    re: &mut [f64],
    im: &mut [f64],
    scratch_re: &mut [f64],
    scratch_im: &mut [f64],
    plan: &RadixPlan,
    dir: Direction,
) {
    let n = plan.n;
    debug_assert_eq!(re.len(), n);
    debug_assert_eq!(scratch_re.len(), n);

    let mut in_src = true; // data currently in re/im?
    for stage in &plan.stages {
        let m = stage.butterflies();
        if in_src {
            apply_stage_range(stage, dir, re, im, scratch_re, scratch_im, 0, m);
        } else {
            apply_stage_range(stage, dir, scratch_re, scratch_im, re, im, 0, m);
        }
        in_src = !in_src;
    }
    if !in_src {
        re.copy_from_slice(scratch_re);
        im.copy_from_slice(scratch_im);
    }
    if dir == Direction::Inverse {
        let inv_n = 1.0 / n as f64;
        for v in re.iter_mut() {
            *v *= inv_n;
        }
        for v in im.iter_mut() {
            *v *= inv_n;
        }
    }
}

/// Apply one DIF stage for butterflies `p ∈ [p_lo, p_hi)`, reading the
/// full `src` planes and writing `dst`, which must cover *exactly* the
/// output blocks of the range: `dst.len() == (p_hi − p_lo)·r·stride`
/// (the range's blocks are contiguous, starting at absolute offset
/// `r·stride·p_lo`). Because ranges own disjoint output slices, the
/// executor runs them concurrently with plain `split_at_mut`; the
/// arithmetic is identical regardless of how the range is split
/// (bit-exact thread-count invariance).
#[allow(clippy::too_many_arguments)]
pub fn apply_stage_range(
    stage: &RadixStage,
    dir: Direction,
    src_re: &[f64],
    src_im: &[f64],
    dst_re: &mut [f64],
    dst_im: &mut [f64],
    p_lo: usize,
    p_hi: usize,
) {
    let m = stage.butterflies();
    let stride = stage.stride;
    debug_assert!(p_hi <= m);
    debug_assert_eq!(dst_re.len(), (p_hi - p_lo) * stage.radix * stride);
    // plan stores forward twiddles; inverse conjugates via `sign`
    let sign = if dir == Direction::Inverse { -1.0 } else { 1.0 };
    match stage.radix {
        2 => stage2(stage, sign, src_re, src_im, dst_re, dst_im, p_lo, p_hi, m, stride),
        3 => stage3(stage, sign, src_re, src_im, dst_re, dst_im, p_lo, p_hi, m, stride),
        5 => stage5(stage, sign, src_re, src_im, dst_re, dst_im, p_lo, p_hi, m, stride),
        other => unreachable!("unsupported radix {other}"),
    }
}

#[allow(clippy::too_many_arguments)]
fn stage2(
    stage: &RadixStage,
    sign: f64,
    src_re: &[f64],
    src_im: &[f64],
    dst_re: &mut [f64],
    dst_im: &mut [f64],
    p_lo: usize,
    p_hi: usize,
    m: usize,
    stride: usize,
) {
    for p in p_lo..p_hi {
        let wr = stage.tw_re[p];
        let wi = sign * stage.tw_im[p];
        let a_base = stride * p;
        let b_base = stride * (p + m);
        let o_base = stride * 2 * (p - p_lo);
        // explicit lane subslices let LLVM drop bounds checks and
        // vectorize the q loop (same shape as the radix-2 kernel)
        let sar = &src_re[a_base..a_base + stride];
        let sai = &src_im[a_base..a_base + stride];
        let sbr = &src_re[b_base..b_base + stride];
        let sbi = &src_im[b_base..b_base + stride];
        let (d0r, d1r) = dst_re[o_base..o_base + 2 * stride].split_at_mut(stride);
        let (d0i, d1i) = dst_im[o_base..o_base + 2 * stride].split_at_mut(stride);
        for q in 0..stride {
            let ar = sar[q];
            let ai = sai[q];
            let br = sbr[q];
            let bi = sbi[q];
            d0r[q] = ar + br;
            d0i[q] = ai + bi;
            let xr = ar - br;
            let xi = ai - bi;
            d1r[q] = xr * wr - xi * wi;
            d1i[q] = xr * wi + xi * wr;
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn stage3(
    stage: &RadixStage,
    sign: f64,
    src_re: &[f64],
    src_im: &[f64],
    dst_re: &mut [f64],
    dst_im: &mut [f64],
    p_lo: usize,
    p_hi: usize,
    m: usize,
    stride: usize,
) {
    const C3: f64 = -0.5; // cos(2π/3)
    let s3 = sign * (-(3.0f64.sqrt()) / 2.0); // sin(−2π/3), sign-adjusted
    for p in p_lo..p_hi {
        let t = 2 * p;
        let w1r = stage.tw_re[t];
        let w1i = sign * stage.tw_im[t];
        let w2r = stage.tw_re[t + 1];
        let w2i = sign * stage.tw_im[t + 1];
        let a0 = stride * p;
        let a1 = stride * (p + m);
        let a2 = stride * (p + 2 * m);
        let o = stride * 3 * (p - p_lo);
        let s0r = &src_re[a0..a0 + stride];
        let s0i = &src_im[a0..a0 + stride];
        let s1r = &src_re[a1..a1 + stride];
        let s1i = &src_im[a1..a1 + stride];
        let s2r = &src_re[a2..a2 + stride];
        let s2i = &src_im[a2..a2 + stride];
        let (d0r, rest_r) = dst_re[o..o + 3 * stride].split_at_mut(stride);
        let (d1r, d2r) = rest_r.split_at_mut(stride);
        let (d0i, rest_i) = dst_im[o..o + 3 * stride].split_at_mut(stride);
        let (d1i, d2i) = rest_i.split_at_mut(stride);
        for q in 0..stride {
            let x0r = s0r[q];
            let x0i = s0i[q];
            let x1r = s1r[q];
            let x1i = s1i[q];
            let x2r = s2r[q];
            let x2i = s2i[q];
            let tr = x1r + x2r;
            let ti = x1i + x2i;
            let dr = x1r - x2r;
            let di = x1i - x2i;
            d0r[q] = x0r + tr;
            d0i[q] = x0i + ti;
            let br = x0r + C3 * tr;
            let bi = x0i + C3 * ti;
            // y1 = b + i·s3·d, y2 = b − i·s3·d
            let y1r = br - s3 * di;
            let y1i = bi + s3 * dr;
            let y2r = br + s3 * di;
            let y2i = bi - s3 * dr;
            d1r[q] = y1r * w1r - y1i * w1i;
            d1i[q] = y1r * w1i + y1i * w1r;
            d2r[q] = y2r * w2r - y2i * w2i;
            d2i[q] = y2r * w2i + y2i * w2r;
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn stage5(
    stage: &RadixStage,
    sign: f64,
    src_re: &[f64],
    src_im: &[f64],
    dst_re: &mut [f64],
    dst_im: &mut [f64],
    p_lo: usize,
    p_hi: usize,
    m: usize,
    stride: usize,
) {
    let fifth = 2.0 * std::f64::consts::PI / 5.0;
    let c1 = fifth.cos(); // cos(2π/5)
    let c2 = (2.0 * fifth).cos(); // cos(4π/5)
    let s1 = sign * (-fifth.sin()); // sin(−2π/5), sign-adjusted
    let s2 = sign * (-(2.0 * fifth).sin()); // sin(−4π/5), sign-adjusted
    for p in p_lo..p_hi {
        let t = 4 * p;
        let mut wr = [0.0f64; 4];
        let mut wi = [0.0f64; 4];
        for k in 0..4 {
            wr[k] = stage.tw_re[t + k];
            wi[k] = sign * stage.tw_im[t + k];
        }
        let o = stride * 5 * (p - p_lo);
        let bases = [
            stride * p,
            stride * (p + m),
            stride * (p + 2 * m),
            stride * (p + 3 * m),
            stride * (p + 4 * m),
        ];
        let s0r = &src_re[bases[0]..bases[0] + stride];
        let s0i = &src_im[bases[0]..bases[0] + stride];
        let s1r = &src_re[bases[1]..bases[1] + stride];
        let s1i = &src_im[bases[1]..bases[1] + stride];
        let s2r = &src_re[bases[2]..bases[2] + stride];
        let s2i = &src_im[bases[2]..bases[2] + stride];
        let s3r = &src_re[bases[3]..bases[3] + stride];
        let s3i = &src_im[bases[3]..bases[3] + stride];
        let s4r = &src_re[bases[4]..bases[4] + stride];
        let s4i = &src_im[bases[4]..bases[4] + stride];
        let (d0r, rest_r) = dst_re[o..o + 5 * stride].split_at_mut(stride);
        let (d1r, rest_r) = rest_r.split_at_mut(stride);
        let (d2r, rest_r) = rest_r.split_at_mut(stride);
        let (d3r, d4r) = rest_r.split_at_mut(stride);
        let (d0i, rest_i) = dst_im[o..o + 5 * stride].split_at_mut(stride);
        let (d1i, rest_i) = rest_i.split_at_mut(stride);
        let (d2i, rest_i) = rest_i.split_at_mut(stride);
        let (d3i, d4i) = rest_i.split_at_mut(stride);
        for q in 0..stride {
            let (x0r, x0i) = (s0r[q], s0i[q]);
            let (x1r, x1i) = (s1r[q], s1i[q]);
            let (x2r, x2i) = (s2r[q], s2i[q]);
            let (x3r, x3i) = (s3r[q], s3i[q]);
            let (x4r, x4i) = (s4r[q], s4i[q]);
            let t1r = x1r + x4r;
            let t1i = x1i + x4i;
            let t2r = x2r + x3r;
            let t2i = x2i + x3i;
            let e1r = x1r - x4r;
            let e1i = x1i - x4i;
            let e2r = x2r - x3r;
            let e2i = x2i - x3i;
            d0r[q] = x0r + t1r + t2r;
            d0i[q] = x0i + t1i + t2i;
            let m1r = x0r + c1 * t1r + c2 * t2r;
            let m1i = x0i + c1 * t1i + c2 * t2i;
            let m2r = x0r + c2 * t1r + c1 * t2r;
            let m2i = x0i + c2 * t1i + c1 * t2i;
            let u1r = s1 * e1r + s2 * e2r;
            let u1i = s1 * e1i + s2 * e2i;
            let u2r = s2 * e1r - s1 * e2r;
            let u2i = s2 * e1i - s1 * e2i;
            // y1 = m1 + i·u1, y4 = m1 − i·u1, y2 = m2 + i·u2, y3 = m2 − i·u2
            let y1r = m1r - u1i;
            let y1i = m1i + u1r;
            let y4r = m1r + u1i;
            let y4i = m1i - u1r;
            let y2r = m2r - u2i;
            let y2i = m2i + u2r;
            let y3r = m2r + u2i;
            let y3i = m2i - u2r;
            d1r[q] = y1r * wr[0] - y1i * wi[0];
            d1i[q] = y1r * wi[0] + y1i * wr[0];
            d2r[q] = y2r * wr[1] - y2i * wi[1];
            d2i[q] = y2r * wi[1] + y2i * wr[1];
            d3r[q] = y3r * wr[2] - y3i * wi[2];
            d3i[q] = y3r * wi[2] + y3i * wr[2];
            d4r[q] = y4r * wr[3] - y4i * wi[3];
            d4i[q] = y4r * wi[3] + y4i * wr[3];
        }
    }
}

/// Batched convenience wrapper (allocates a plan + scratch per call;
/// tests and cold paths only — hot paths go through
/// [`crate::dft::exec::fft_rows_pooled`]).
pub fn fft_rows_radix(re: &mut [f64], im: &mut [f64], rows: usize, n: usize, dir: Direction) {
    let plan = RadixPlan::new(n);
    let mut sr = vec![0.0; n];
    let mut si = vec![0.0; n];
    for r in 0..rows {
        let span = r * n..(r + 1) * n;
        fft_row_radix(&mut re[span.clone()], &mut im[span], &mut sr, &mut si, &plan, dir);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dft::{naive_dft_rows, SignalMatrix};

    fn radix_matrix(m: &SignalMatrix, dir: Direction) -> SignalMatrix {
        let mut out = m.clone();
        fft_rows_radix(&mut out.re, &mut out.im, m.rows, m.cols, dir);
        out
    }

    #[test]
    fn factorization() {
        assert_eq!(factorize_235(1), Some(vec![]));
        assert_eq!(factorize_235(2), Some(vec![2]));
        assert_eq!(factorize_235(384), Some(vec![2, 2, 2, 2, 2, 2, 2, 3]));
        assert_eq!(factorize_235(640), Some(vec![2, 2, 2, 2, 2, 2, 2, 5]));
        assert_eq!(factorize_235(1152), Some(vec![2, 2, 2, 2, 2, 2, 2, 3, 3]));
        assert_eq!(factorize_235(0), None);
        assert_eq!(factorize_235(7), None);
        assert_eq!(factorize_235(896), None); // 128·7
        assert!(is_five_smooth(3200));
        assert!(!is_five_smooth(1000 * 7));
    }

    #[test]
    fn kernel_summary_strings() {
        assert_eq!(kernel_summary(384), "mixed-radix 2^7*3");
        assert_eq!(kernel_summary(640), "mixed-radix 2^7*5");
        assert_eq!(kernel_summary(6), "mixed-radix 2*3");
        assert!(kernel_summary(7).starts_with("bluestein"));
        assert_eq!(kernel_summary(1), "identity");
    }

    #[test]
    fn matches_naive_across_smooth_sizes() {
        for &n in &[1usize, 2, 3, 4, 5, 6, 8, 10, 12, 15, 16, 20, 24, 30, 60, 128, 384, 640] {
            let m = SignalMatrix::random(2, n, n as u64 + 3);
            let got = radix_matrix(&m, Direction::Forward);
            let want = naive_dft_rows(&m, false);
            let scale = want.norm().max(1.0);
            assert!(
                got.max_abs_diff(&want) / scale < 1e-10,
                "n={n}: rel diff {}",
                got.max_abs_diff(&want) / scale
            );
        }
    }

    #[test]
    fn inverse_roundtrip() {
        for &n in &[3usize, 5, 15, 60, 384, 1152] {
            let m = SignalMatrix::random(2, n, 7);
            let f = radix_matrix(&m, Direction::Forward);
            let b = radix_matrix(&f, Direction::Inverse);
            assert!(m.max_abs_diff(&b) < 1e-9, "n={n}: {}", m.max_abs_diff(&b));
        }
    }

    #[test]
    fn pow2_schedule_matches_radix2_kernel() {
        // the all-2s schedule must agree with the dedicated pow2 kernel
        let n = 256;
        let m = SignalMatrix::random(3, n, 9);
        let got = radix_matrix(&m, Direction::Forward);
        let mut want = m.clone();
        crate::dft::fft::fft_rows_pow2(&mut want.re, &mut want.im, 3, n, Direction::Forward);
        assert!(got.max_abs_diff(&want) < 1e-9);
    }

    #[test]
    fn matches_bluestein_at_paper_sizes() {
        for &n in &[384usize, 640, 768] {
            let m = SignalMatrix::random(1, n, 11);
            let got = radix_matrix(&m, Direction::Forward);
            let mut want = m.clone();
            let plan = crate::dft::bluestein::BluesteinPlan::new(n);
            let ml = plan.scratch_len();
            let (mut br, mut bi) = (vec![0.0; ml], vec![0.0; ml]);
            let (mut sr, mut si) = (vec![0.0; ml], vec![0.0; ml]);
            crate::dft::bluestein::fft_row_bluestein(
                &mut want.re,
                &mut want.im,
                &plan,
                Direction::Forward,
                &mut br,
                &mut bi,
                &mut sr,
                &mut si,
            );
            let scale = want.norm().max(1.0);
            assert!(
                got.max_abs_diff(&want) / scale < 1e-9,
                "n={n}: {}",
                got.max_abs_diff(&want) / scale
            );
        }
    }

    #[test]
    fn impulse_flat_spectrum() {
        let mut m = SignalMatrix::zeros(1, 30);
        m.set(0, 0, 1.0, 0.0);
        let f = radix_matrix(&m, Direction::Forward);
        for c in 0..30 {
            let (re, im) = f.get(0, c);
            assert!((re - 1.0).abs() < 1e-12 && im.abs() < 1e-12, "bin {c}");
        }
    }

    #[test]
    fn stage_range_split_is_bit_exact() {
        // applying a stage in two halves must equal one full application
        let n = 240; // 2^4·3·5 — exercises all three radixes
        let plan = RadixPlan::new(n);
        let m = SignalMatrix::random(1, n, 5);
        for stage in &plan.stages {
            let bf = stage.butterflies();
            let (mut full_r, mut full_i) = (vec![0.0; n], vec![0.0; n]);
            apply_stage_range(stage, Direction::Forward, &m.re, &m.im, &mut full_r, &mut full_i, 0, bf);
            let (mut split_r, mut split_i) = (vec![0.0; n], vec![0.0; n]);
            let mid = bf / 2;
            let cut = stage.radix * stage.stride * mid;
            let (lo_r, hi_r) = split_r.split_at_mut(cut);
            let (lo_i, hi_i) = split_i.split_at_mut(cut);
            apply_stage_range(stage, Direction::Forward, &m.re, &m.im, lo_r, lo_i, 0, mid);
            apply_stage_range(stage, Direction::Forward, &m.re, &m.im, hi_r, hi_i, mid, bf);
            assert_eq!(full_r, split_r, "radix {} re", stage.radix);
            assert_eq!(full_i, split_i, "radix {} im", stage.radix);
        }
    }

    #[test]
    #[should_panic(expected = "5-smooth")]
    fn rejects_non_smooth() {
        RadixPlan::new(14);
    }
}
