//! Mixed-radix (2/3/5) iterative Stockham DIF FFT — the vectorized row
//! kernel behind every execution path.
//!
//! The paper's problem sizes are N = 128·k — mostly *not* powers of two
//! (384 = 2⁷·3, 640 = 2⁷·5, 1152 = 2⁷·3²). The radix-2 kernel
//! ([`crate::dft::fft`]) cannot run them natively, and routing them
//! through Bluestein's chirp-z ([`crate::dft::bluestein`]) pads to a
//! ≥ 2N power of two and pays three pow2 FFTs per row — a ~5-6x flop
//! overhead on exactly the sizes the paper benchmarks. This module
//! closes that gap with a native mixed-radix kernel: any 5-smooth length
//! (factors in {2, 3, 5}) runs in O(n log n) directly; Bluestein is
//! demoted to the non-smooth fallback (primes and the like).
//!
//! Same decimation-in-frequency Stockham formulation as the radix-2
//! kernel, generalized: state is viewed as an `(n_cur, stride)` matrix
//! with original index `stride·p + q`; a radix-r stage gathers the r
//! blocks `p, p+m, …, p+(r−1)m` (m = n_cur/r), applies the hard-coded
//! r-point butterfly, multiplies outputs k = 1..r by the stage twiddle
//! `exp(−2πi·p·k/n_cur)`, and scatters to blocks `r·p + k`. Each stage
//! divides `n_cur` by r and multiplies `stride` by r; the result lands
//! in natural order (no digit reversal).
//!
//! # Kernel variants
//!
//! [`KernelVariant::Vectorized`] (the default) restructures the
//! schedule for throughput; [`KernelVariant::Scalar`] preserves the
//! pre-codelet kernel shape (ascending factors, every stage twiddled
//! through the ping-pong, no SIMD) as the honest reference arm for the
//! scalar-vs-vectorized speedup in `bench_fft_sizes` and the perf gate.
//! The vectorized plan differs in three ways:
//!
//! * **Reordered schedule.** Radix-2 stages run first, then 3s, then
//!   5s, with the *last* `k = min(#2s, 3)` radix-2 stages held back and
//!   fused into a single tail codelet. Odd radices therefore run at
//!   lane widths that are multiples of the remaining pow2 factor —
//!   vector-friendly `q` loops — and every explicit stage keeps the
//!   bounds-check-free subslice shape that autovectorizes at default
//!   flags.
//! * **Tail codelets.** The final `k` radix-2 stages all carry unit
//!   twiddles in this schedule (their `n_cur` divides the held-back
//!   pow2 factor), so they collapse into one hardcoded-constant
//!   FFT2/FFT4/FFT8 applied per lane `q` at stride `s = n/tail` — one
//!   pass over the data instead of `k` twiddled ping-pong passes, and
//!   it runs *in place* (output block `s·j+q` reads exactly the input
//!   block set `s·p+q`), which also eliminates the final un-ping-pong
//!   copy. At 384 that turns 8 full-row passes into 6; at 1152, 9+copy
//!   into 7.
//! * **SIMD stages.** With the `simd` cargo feature on x86_64, every
//!   radix-2/3/5 stage shape (stride 1, stride 2, and the wide
//!   stride ≥ 4 lane loops) and the FFT4/FFT8 tail codelet bodies
//!   dispatch to explicit AVX2 kernels ([`crate::dft::simd`]), selected
//!   at runtime via `is_x86_feature_detected!` with a safe scalar
//!   fallback. The plain AVX2 kernels perform identical IEEE-754
//!   operations (no FMA), so their output is bit-identical to the
//!   scalar loops; with `--features fma` (and runtime FMA support) a
//!   second kernel generation contracts the complex multiplies to
//!   fused ops — faster, not bit-identical, and therefore tagged as a
//!   distinct [`kernel_generation`].
//!
//! [`apply_stage_range`] applies one stage over a sub-range of `p`, so
//! the executor ([`crate::dft::exec`]) can split a *single long row*
//! across pool workers (disjoint output blocks per `p`) with bit-exact
//! results regardless of the split; the tail codelet is a single serial
//! pass in that path. [`fft_rows_radix_tiled`] advances a small tile of
//! rows through each stage together (stage-major order), so per-stage
//! twiddle tables are streamed once per tile instead of once per row —
//! bit-identical to the row-major order because the per-row arithmetic
//! is untouched. [`kernel_generation`] names the kernel's measurable
//! speed surface — wisdom records tagged with a different generation
//! miss at lookup so the profiler re-measures FPM surfaces (and
//! POPTA/HPOPTA partitions shift) after a kernel change.

use crate::dft::fft::Direction;
use crate::dft::simd;

// ---------------------------------------------------------------------------
// Hoisted butterfly constants
// ---------------------------------------------------------------------------
// Correctly-rounded f64 literals of the algebraic values (libm's cos/sin
// are not correctly rounded: e.g. cos(4π/5) comes back 2 ulp off on
// x86_64 glibc), hoisted so no stage recomputes trig per call. The
// `hoisted_constants_match_trig` test pins them against runtime trig to
// ~1e-15 — not bitwise, exactly because libm varies by platform.

/// sin(2π/3) = √3/2
pub(crate) const S3: f64 = 0.866_025_403_784_438_6;
/// cos(2π/5) = (√5 − 1)/4
pub(crate) const C5_1: f64 = 0.309_016_994_374_947_45;
/// cos(4π/5) = −(√5 + 1)/4
pub(crate) const C5_2: f64 = -0.809_016_994_374_947_5;
/// sin(2π/5)
pub(crate) const S5_1: f64 = 0.951_056_516_295_153_5;
/// sin(4π/5)
pub(crate) const S5_2: f64 = 0.587_785_252_292_473_1;
/// cos(2π/8) = 1/√2 (FFT8 codelet twiddle)
pub(crate) const C8: f64 = std::f64::consts::FRAC_1_SQRT_2;

/// Factor `n` into its {2, 3, 5} prime factors (ascending), or `None`
/// if `n` has any other prime factor (or is zero). `n == 1` factors as
/// the empty product.
pub fn factorize_235(n: usize) -> Option<Vec<usize>> {
    if n == 0 {
        return None;
    }
    let mut rem = n;
    let mut factors = Vec::new();
    for r in [2usize, 3, 5] {
        while rem % r == 0 {
            factors.push(r);
            rem /= r;
        }
    }
    if rem == 1 {
        Some(factors)
    } else {
        None
    }
}

/// Is `n` 5-smooth (product of 2s, 3s and 5s only)? Allocation-free —
/// this runs on every row-FFT dispatch.
pub fn is_five_smooth(n: usize) -> bool {
    if n == 0 {
        return false;
    }
    let mut rem = n;
    for r in [2usize, 3, 5] {
        while rem % r == 0 {
            rem /= r;
        }
    }
    rem == 1
}

/// Which inner-loop implementation a [`RadixPlan`] uses.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelVariant {
    /// The pre-codelet kernel shape: ascending factor schedule, every
    /// stage twiddled through the ping-pong, no SIMD. Kept as the
    /// reference arm of the scalar-vs-vectorized bench/perf-gate story.
    Scalar,
    /// Reordered schedule + fused FFT2/4/8 tail codelet + (with the
    /// `simd` feature) AVX2 stride-1/2 first stages. The default.
    Vectorized,
}

/// Is the AVX2 fast path active in this process (feature compiled in
/// *and* detected at runtime)?
pub fn simd_active() -> bool {
    simd::avx2_enabled()
}

/// Is the FMA kernel generation active in this process (`fma` feature
/// compiled in *and* FMA detected at runtime)? Implies [`simd_active`].
pub fn fma_active() -> bool {
    simd::fma_enabled()
}

/// Name of the kernel generation whose speed surface the profiler would
/// measure right now — the *runtime-detected* feature set, not the
/// compile-time one, so a wisdom file written on a non-AVX2 host never
/// stale-loops on an AVX2 host and vice versa. Stored on wisdom
/// records: a native record tagged with a *different* generation
/// (pre-codelet artifact, an AVX2 on/off mismatch across machines, or
/// an FMA generation switch) misses at lookup, forcing a re-measure so
/// FPM surfaces — and the POPTA/HPOPTA partitions and pad choices
/// planned over them — track the installed kernel. The FMA generation
/// is split out because its contracted roundings change both the speed
/// surface *and* the bit-level output.
pub fn kernel_generation() -> &'static str {
    if fma_active() {
        "stockham-v2-codelet+avx2+fma"
    } else if simd_active() {
        "stockham-v2-codelet+avx2"
    } else {
        "stockham-v2-codelet"
    }
}

/// Human-readable row-kernel description for a length (CLI reports):
/// factorization plus, for the vectorized plan, the fused tail codelet
/// and whether the AVX2 first stages apply.
pub fn kernel_summary(n: usize) -> String {
    if n == 0 {
        return "empty".to_string();
    }
    match factorize_235(n) {
        Some(f) if f.is_empty() => "identity".to_string(),
        Some(f) => {
            let (mut two, mut three, mut five) = (0usize, 0usize, 0usize);
            for r in f {
                match r {
                    2 => two += 1,
                    3 => three += 1,
                    _ => five += 1,
                }
            }
            let mut parts = Vec::new();
            for (b, e) in [(2usize, two), (3, three), (5, five)] {
                match e {
                    0 => {}
                    1 => parts.push(b.to_string()),
                    _ => parts.push(format!("{b}^{e}")),
                }
            }
            let base = format!("mixed-radix {}", parts.join("*"));
            let k = two.min(3);
            // runtime-detected feature tags: AVX2 now covers every
            // radix-2/3/5 stage shape and the codelet bodies, so it
            // applies to any vectorized plan; FMA marks the contracted
            // kernel generation
            let mut tags: Vec<String> = Vec::new();
            if k > 0 {
                tags.push(format!("fft{} codelet", 1usize << k));
            }
            if fma_active() {
                tags.push("avx2+fma".to_string());
            } else if simd_active() {
                tags.push("avx2".to_string());
            }
            if tags.is_empty() {
                base
            } else {
                format!("{base} [{}]", tags.join("+"))
            }
        }
        None => {
            let m = (2 * n - 1).next_power_of_two();
            format!("bluestein (pow2 pad {m})")
        }
    }
}

/// One stage's twiddle table, split-complex:
/// `re[p·(r−1) + (k−1)] = cos(−2π·p·k/n_cur)` (and `im` the sine) for
/// p ∈ [0, m), k ∈ [1, r). The table depends only on `(radix, n_cur)`,
/// so it is built once in a process-wide cache and shared behind `Arc`
/// across every plan whose schedule passes through that geometry — 384
/// and 768 share five of six stage tables; see [`stage_twiddles`].
#[derive(Debug)]
pub struct StageTwiddles {
    pub re: Vec<f64>,
    pub im: Vec<f64>,
}

/// Process-wide twiddle-table cache keyed by `(radix, n_cur)`. Plans
/// for different lengths routinely share stage geometries (every 5-smooth
/// multiple of 384 runs the same (2, 384) stage, every length with a
/// trailing ·3 factor after the pow2 run hits (3, 24), …), and
/// [`crate::dft::plan::PlanCache`] keeps plans alive for the process
/// lifetime — deduping the tables bounds plan-cache memory by the set of
/// distinct geometries instead of the sum over lengths.
fn stage_twiddles(radix: usize, n_cur: usize) -> std::sync::Arc<StageTwiddles> {
    use std::collections::HashMap;
    use std::sync::{Arc, Mutex, OnceLock};
    static CACHE: OnceLock<Mutex<HashMap<(usize, usize), Arc<StageTwiddles>>>> = OnceLock::new();
    let cache = CACHE.get_or_init(|| Mutex::new(HashMap::new()));
    if let Some(t) = cache.lock().unwrap().get(&(radix, n_cur)) {
        return Arc::clone(t);
    }
    // build outside the lock — tables are O(n_cur) and the first plan
    // for a big length should not stall concurrent planners
    let m = n_cur / radix;
    let mut re = Vec::with_capacity(m * (radix - 1));
    let mut im = Vec::with_capacity(m * (radix - 1));
    for p in 0..m {
        for k in 1..radix {
            // p·k mod n_cur keeps the angle argument small (exactness)
            let pk = (p * k) % n_cur;
            let ang = -2.0 * std::f64::consts::PI * pk as f64 / n_cur as f64;
            re.push(ang.cos());
            im.push(ang.sin());
        }
    }
    let fresh = Arc::new(StageTwiddles { re, im });
    Arc::clone(cache.lock().unwrap().entry((radix, n_cur)).or_insert(fresh))
}

/// One DIF stage: radix, sub-DFT geometry, and the (shared) twiddle
/// table — see [`StageTwiddles`] for the layout.
#[derive(Clone, Debug)]
pub struct RadixStage {
    pub radix: usize,
    /// DFT length still to be resolved when this stage runs.
    pub n_cur: usize,
    /// lane width (original-index stride factor) at this stage
    pub stride: usize,
    /// eligible for the AVX2/FMA fast paths (any vectorized-plan stage;
    /// the dispatcher picks the kernel by radix and stride)
    simd_ok: bool,
    tw: std::sync::Arc<StageTwiddles>,
}

impl RadixStage {
    /// Butterfly count of this stage (`n_cur / radix`).
    #[inline]
    pub fn butterflies(&self) -> usize {
        self.n_cur / self.radix
    }

    /// The shared twiddle table. Exposed so the steady-state memory
    /// audit can assert that plans of different lengths hold the *same*
    /// allocation for a common stage geometry (`Arc::ptr_eq`).
    pub fn twiddles(&self) -> &std::sync::Arc<StageTwiddles> {
        &self.tw
    }
}

/// Factor schedule + per-stage twiddles for a 5-smooth length — the
/// generalized plan that replaces pow2-only dispatch.
#[derive(Clone, Debug)]
pub struct RadixPlan {
    pub n: usize,
    /// ascending {2,3,5} factorization of `n` (stable, informational —
    /// the *executed* schedule is `stages` plus the fused `tail`)
    pub factors: Vec<usize>,
    /// which inner-loop implementation this plan runs
    pub variant: KernelVariant,
    /// fused final-stages codelet size (1 = none, else 2/4/8): the last
    /// log2(tail) radix-2 stages run as one hardcoded-twiddle pass
    pub tail: usize,
    pub stages: Vec<RadixStage>,
}

impl RadixPlan {
    /// Plan a 5-smooth length with the default (vectorized) kernel;
    /// panics otherwise (see [`RadixPlan::try_new`]).
    pub fn new(n: usize) -> RadixPlan {
        Self::with_variant(n, KernelVariant::Vectorized)
    }

    /// Plan with an explicit kernel variant; panics on non-smooth `n`.
    pub fn with_variant(n: usize, variant: KernelVariant) -> RadixPlan {
        RadixPlan::try_with_variant(n, variant)
            .unwrap_or_else(|| panic!("RadixPlan requires a 5-smooth length, got {n}"))
    }

    /// Plan a 5-smooth length, or `None` when `n` has other factors
    /// (those lengths belong to Bluestein).
    pub fn try_new(n: usize) -> Option<RadixPlan> {
        Self::try_with_variant(n, KernelVariant::Vectorized)
    }

    /// [`RadixPlan::try_new`] with an explicit kernel variant.
    pub fn try_with_variant(n: usize, variant: KernelVariant) -> Option<RadixPlan> {
        let factors = factorize_235(n)?;
        // The executed schedule. Scalar: the ascending factors, no tail.
        // Vectorized: 2s first (fusing the last min(#2s, 3) of them into
        // the tail codelet), then 3s, then 5s.
        let (schedule, tail) = match variant {
            KernelVariant::Scalar => (factors.clone(), 1usize),
            KernelVariant::Vectorized => {
                let twos = factors.iter().filter(|&&r| r == 2).count();
                let k = twos.min(3);
                let mut schedule = Vec::with_capacity(factors.len() - k);
                schedule.resize(twos - k, 2usize);
                schedule.extend(factors.iter().copied().filter(|&r| r != 2));
                (schedule, 1usize << k)
            }
        };
        let mut stages = Vec::with_capacity(schedule.len());
        let mut n_cur = n;
        let mut stride = 1usize;
        for &r in &schedule {
            let m = n_cur / r;
            let tw = stage_twiddles(r, n_cur);
            // the AVX2/FMA dispatcher handles every vectorized-plan
            // stage shape (it declines the rare ones it has no kernel
            // for); the scalar reference variant never dispatches
            let simd_ok = variant == KernelVariant::Vectorized;
            stages.push(RadixStage { radix: r, n_cur, stride, simd_ok, tw });
            n_cur = m;
            stride *= r;
        }
        debug_assert_eq!(n_cur, tail);
        Some(RadixPlan { n, factors, variant, tail, stages })
    }
}

/// Transform a single length-`n` row in `re`/`im`, using `plan` and a
/// same-length ping-pong scratch. O(n log n), natural output order.
pub fn fft_row_radix(
    re: &mut [f64],
    im: &mut [f64],
    scratch_re: &mut [f64],
    scratch_im: &mut [f64],
    plan: &RadixPlan,
    dir: Direction,
) {
    let n = plan.n;
    debug_assert_eq!(re.len(), n);
    debug_assert_eq!(im.len(), n);
    debug_assert_eq!(scratch_re.len(), n);
    debug_assert_eq!(scratch_im.len(), n);

    let mut in_src = true; // data currently in re/im?
    for stage in &plan.stages {
        let m = stage.butterflies();
        if in_src {
            apply_stage_range(stage, dir, re, im, scratch_re, scratch_im, 0, m);
        } else {
            apply_stage_range(stage, dir, scratch_re, scratch_im, re, im, 0, m);
        }
        in_src = !in_src;
    }
    finish_tail(plan, dir, re, im, scratch_re, scratch_im, in_src);
    if dir == Direction::Inverse {
        let inv_n = 1.0 / n as f64;
        for v in re.iter_mut() {
            *v *= inv_n;
        }
        for v in im.iter_mut() {
            *v *= inv_n;
        }
    }
}

/// Finish a row after the explicit stages: run the fused tail codelet
/// (in place when the data sits in `re`/`im`, as a gathering pass from
/// the scratch planes otherwise — either way the result lands in
/// `re`/`im` with no extra copy), or, for tail-less plans, the legacy
/// un-ping-pong copy. Shared by the serial kernel and the executor's
/// split-row path.
pub(crate) fn finish_tail(
    plan: &RadixPlan,
    dir: Direction,
    re: &mut [f64],
    im: &mut [f64],
    scratch_re: &mut [f64],
    scratch_im: &mut [f64],
    in_src: bool,
) {
    if plan.tail == 1 {
        if !in_src {
            re.copy_from_slice(scratch_re);
            im.copy_from_slice(scratch_im);
        }
        return;
    }
    let sign = if dir == Direction::Inverse { -1.0 } else { 1.0 };
    if in_src {
        tail_codelet_inplace(plan.tail, sign, re, im);
    } else {
        tail_codelet(plan.tail, sign, scratch_re, scratch_im, re, im);
    }
}

/// Apply one DIF stage for butterflies `p ∈ [p_lo, p_hi)`, reading the
/// full `src` planes and writing `dst`, which must cover *exactly* the
/// output blocks of the range: `dst.len() == (p_hi − p_lo)·r·stride`
/// (the range's blocks are contiguous, starting at absolute offset
/// `r·stride·p_lo`). Because ranges own disjoint output slices, the
/// executor runs them concurrently with plain `split_at_mut`; the
/// arithmetic is identical regardless of how the range is split — and
/// identical between the scalar loops and the plain AVX2 kernels, which
/// use the same IEEE-754 operation order (bit-exact thread-count and
/// scalar-vs-SIMD invariance). The FMA generation is *not* bit-identical
/// to the scalar loops, but its vector bodies and scalar remainders use
/// the same fused association, so split-position/thread-count
/// invariance still holds bitwise within that generation.
#[allow(clippy::too_many_arguments)]
pub fn apply_stage_range(
    stage: &RadixStage,
    dir: Direction,
    src_re: &[f64],
    src_im: &[f64],
    dst_re: &mut [f64],
    dst_im: &mut [f64],
    p_lo: usize,
    p_hi: usize,
) {
    let m = stage.butterflies();
    let stride = stage.stride;
    debug_assert!(p_hi <= m);
    debug_assert_eq!(dst_re.len(), (p_hi - p_lo) * stage.radix * stride);
    // plan stores forward twiddles; inverse conjugates via `sign`
    let sign = if dir == Direction::Inverse { -1.0 } else { 1.0 };
    match stage.radix {
        2 => stage2(stage, sign, src_re, src_im, dst_re, dst_im, p_lo, p_hi, m, stride),
        3 => stage3(stage, sign, src_re, src_im, dst_re, dst_im, p_lo, p_hi, m, stride),
        5 => stage5(stage, sign, src_re, src_im, dst_re, dst_im, p_lo, p_hi, m, stride),
        other => unreachable!("unsupported radix {other}"),
    }
}

#[allow(clippy::too_many_arguments)]
fn stage2(
    stage: &RadixStage,
    sign: f64,
    src_re: &[f64],
    src_im: &[f64],
    dst_re: &mut [f64],
    dst_im: &mut [f64],
    p_lo: usize,
    p_hi: usize,
    m: usize,
    stride: usize,
) {
    // explicit AVX2 kernels when available (bit-identical arithmetic in
    // the plain generation, so the dispatch is unobservable in the
    // output; the FMA generation is its own kernel_generation());
    // scalar loop otherwise
    if stage.simd_ok
        && simd::try_stage2(
            sign,
            &stage.tw.re,
            &stage.tw.im,
            src_re,
            src_im,
            dst_re,
            dst_im,
            p_lo,
            p_hi,
            m,
            stride,
        )
    {
        return;
    }
    for p in p_lo..p_hi {
        let wr = stage.tw.re[p];
        let wi = sign * stage.tw.im[p];
        let a_base = stride * p;
        let b_base = stride * (p + m);
        let o_base = stride * 2 * (p - p_lo);
        // explicit lane subslices let LLVM drop bounds checks and
        // vectorize the q loop (same shape as the radix-2 kernel)
        let sar = &src_re[a_base..a_base + stride];
        let sai = &src_im[a_base..a_base + stride];
        let sbr = &src_re[b_base..b_base + stride];
        let sbi = &src_im[b_base..b_base + stride];
        let (d0r, d1r) = dst_re[o_base..o_base + 2 * stride].split_at_mut(stride);
        let (d0i, d1i) = dst_im[o_base..o_base + 2 * stride].split_at_mut(stride);
        for q in 0..stride {
            let ar = sar[q];
            let ai = sai[q];
            let br = sbr[q];
            let bi = sbi[q];
            d0r[q] = ar + br;
            d0i[q] = ai + bi;
            let xr = ar - br;
            let xi = ai - bi;
            d1r[q] = xr * wr - xi * wi;
            d1i[q] = xr * wi + xi * wr;
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn stage3(
    stage: &RadixStage,
    sign: f64,
    src_re: &[f64],
    src_im: &[f64],
    dst_re: &mut [f64],
    dst_im: &mut [f64],
    p_lo: usize,
    p_hi: usize,
    m: usize,
    stride: usize,
) {
    if stage.simd_ok
        && simd::try_stage3(
            sign,
            &stage.tw.re,
            &stage.tw.im,
            src_re,
            src_im,
            dst_re,
            dst_im,
            p_lo,
            p_hi,
            m,
            stride,
        )
    {
        return;
    }
    const C3: f64 = -0.5; // cos(2π/3)
    let s3 = sign * (-S3); // sin(−2π/3), sign-adjusted
    for p in p_lo..p_hi {
        let t = 2 * p;
        let w1r = stage.tw.re[t];
        let w1i = sign * stage.tw.im[t];
        let w2r = stage.tw.re[t + 1];
        let w2i = sign * stage.tw.im[t + 1];
        let a0 = stride * p;
        let a1 = stride * (p + m);
        let a2 = stride * (p + 2 * m);
        let o = stride * 3 * (p - p_lo);
        let s0r = &src_re[a0..a0 + stride];
        let s0i = &src_im[a0..a0 + stride];
        let s1r = &src_re[a1..a1 + stride];
        let s1i = &src_im[a1..a1 + stride];
        let s2r = &src_re[a2..a2 + stride];
        let s2i = &src_im[a2..a2 + stride];
        let (d0r, rest_r) = dst_re[o..o + 3 * stride].split_at_mut(stride);
        let (d1r, d2r) = rest_r.split_at_mut(stride);
        let (d0i, rest_i) = dst_im[o..o + 3 * stride].split_at_mut(stride);
        let (d1i, d2i) = rest_i.split_at_mut(stride);
        for q in 0..stride {
            let x0r = s0r[q];
            let x0i = s0i[q];
            let x1r = s1r[q];
            let x1i = s1i[q];
            let x2r = s2r[q];
            let x2i = s2i[q];
            let tr = x1r + x2r;
            let ti = x1i + x2i;
            let dr = x1r - x2r;
            let di = x1i - x2i;
            d0r[q] = x0r + tr;
            d0i[q] = x0i + ti;
            let br = x0r + C3 * tr;
            let bi = x0i + C3 * ti;
            // y1 = b + i·s3·d, y2 = b − i·s3·d
            let y1r = br - s3 * di;
            let y1i = bi + s3 * dr;
            let y2r = br + s3 * di;
            let y2i = bi - s3 * dr;
            d1r[q] = y1r * w1r - y1i * w1i;
            d1i[q] = y1r * w1i + y1i * w1r;
            d2r[q] = y2r * w2r - y2i * w2i;
            d2i[q] = y2r * w2i + y2i * w2r;
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn stage5(
    stage: &RadixStage,
    sign: f64,
    src_re: &[f64],
    src_im: &[f64],
    dst_re: &mut [f64],
    dst_im: &mut [f64],
    p_lo: usize,
    p_hi: usize,
    m: usize,
    stride: usize,
) {
    if stage.simd_ok
        && simd::try_stage5(
            sign,
            &stage.tw.re,
            &stage.tw.im,
            src_re,
            src_im,
            dst_re,
            dst_im,
            p_lo,
            p_hi,
            m,
            stride,
        )
    {
        return;
    }
    let c1 = C5_1; // cos(2π/5)
    let c2 = C5_2; // cos(4π/5)
    let s1 = sign * (-S5_1); // sin(−2π/5), sign-adjusted
    let s2 = sign * (-S5_2); // sin(−4π/5), sign-adjusted
    for p in p_lo..p_hi {
        let t = 4 * p;
        let wr = [stage.tw.re[t], stage.tw.re[t + 1], stage.tw.re[t + 2], stage.tw.re[t + 3]];
        let wi = [
            sign * stage.tw.im[t],
            sign * stage.tw.im[t + 1],
            sign * stage.tw.im[t + 2],
            sign * stage.tw.im[t + 3],
        ];
        let o = stride * 5 * (p - p_lo);
        let bases = [
            stride * p,
            stride * (p + m),
            stride * (p + 2 * m),
            stride * (p + 3 * m),
            stride * (p + 4 * m),
        ];
        let s0r = &src_re[bases[0]..bases[0] + stride];
        let s0i = &src_im[bases[0]..bases[0] + stride];
        let s1r = &src_re[bases[1]..bases[1] + stride];
        let s1i = &src_im[bases[1]..bases[1] + stride];
        let s2r = &src_re[bases[2]..bases[2] + stride];
        let s2i = &src_im[bases[2]..bases[2] + stride];
        let s3r = &src_re[bases[3]..bases[3] + stride];
        let s3i = &src_im[bases[3]..bases[3] + stride];
        let s4r = &src_re[bases[4]..bases[4] + stride];
        let s4i = &src_im[bases[4]..bases[4] + stride];
        let (d0r, rest_r) = dst_re[o..o + 5 * stride].split_at_mut(stride);
        let (d1r, rest_r) = rest_r.split_at_mut(stride);
        let (d2r, rest_r) = rest_r.split_at_mut(stride);
        let (d3r, d4r) = rest_r.split_at_mut(stride);
        let (d0i, rest_i) = dst_im[o..o + 5 * stride].split_at_mut(stride);
        let (d1i, rest_i) = rest_i.split_at_mut(stride);
        let (d2i, rest_i) = rest_i.split_at_mut(stride);
        let (d3i, d4i) = rest_i.split_at_mut(stride);
        for q in 0..stride {
            let (x0r, x0i) = (s0r[q], s0i[q]);
            let (x1r, x1i) = (s1r[q], s1i[q]);
            let (x2r, x2i) = (s2r[q], s2i[q]);
            let (x3r, x3i) = (s3r[q], s3i[q]);
            let (x4r, x4i) = (s4r[q], s4i[q]);
            let t1r = x1r + x4r;
            let t1i = x1i + x4i;
            let t2r = x2r + x3r;
            let t2i = x2i + x3i;
            let e1r = x1r - x4r;
            let e1i = x1i - x4i;
            let e2r = x2r - x3r;
            let e2i = x2i - x3i;
            d0r[q] = x0r + t1r + t2r;
            d0i[q] = x0i + t1i + t2i;
            let m1r = x0r + c1 * t1r + c2 * t2r;
            let m1i = x0i + c1 * t1i + c2 * t2i;
            let m2r = x0r + c2 * t1r + c1 * t2r;
            let m2i = x0i + c2 * t1i + c1 * t2i;
            let u1r = s1 * e1r + s2 * e2r;
            let u1i = s1 * e1i + s2 * e2i;
            let u2r = s2 * e1r - s1 * e2r;
            let u2i = s2 * e1i - s1 * e2i;
            // y1 = m1 + i·u1, y4 = m1 − i·u1, y2 = m2 + i·u2, y3 = m2 − i·u2
            let y1r = m1r - u1i;
            let y1i = m1i + u1r;
            let y4r = m1r + u1i;
            let y4i = m1i - u1r;
            let y2r = m2r - u2i;
            let y2i = m2i + u2r;
            let y3r = m2r + u2i;
            let y3i = m2i - u2r;
            d1r[q] = y1r * wr[0] - y1i * wi[0];
            d1i[q] = y1r * wi[0] + y1i * wr[0];
            d2r[q] = y2r * wr[1] - y2i * wi[1];
            d2i[q] = y2r * wi[1] + y2i * wr[1];
            d3r[q] = y3r * wr[2] - y3i * wi[2];
            d3i[q] = y3r * wi[2] + y3i * wr[2];
            d4r[q] = y4r * wr[3] - y4i * wi[3];
            d4i[q] = y4r * wi[3] + y4i * wr[3];
        }
    }
}

// ---------------------------------------------------------------------------
// Tail codelets — hardcoded-twiddle FFT2/FFT4/FFT8 over the lane set
// ---------------------------------------------------------------------------
// After the explicit stages, the state is an `(tail, s)` matrix with
// s = n/tail: lane q of the length-`tail` sub-DFT lives at indices
// `s·p + q`. The codelet computes the full natural-order DFT of each
// lane in one pass — output `s·k + q` covers exactly the input block
// set, so the in-place form needs no scratch and no final copy. `sign`
// is +1 forward / −1 inverse (the same convention as the stages; the
// 1/n inverse scale stays with the caller).

/// One complex FFT4 butterfly on lane `q` of the chunked planes (the
/// radix-4 DIT with hardcoded ±i twiddles). Reads every input before
/// the first write, so source and destination chunks may alias (the
/// in-place form passes the same identifiers for both).
macro_rules! fft4_lanes_body {
    ($q:expr, $sign:expr,
     $s0r:ident, $s0i:ident, $s1r:ident, $s1i:ident,
     $s2r:ident, $s2i:ident, $s3r:ident, $s3i:ident,
     $d0r:ident, $d0i:ident, $d1r:ident, $d1i:ident,
     $d2r:ident, $d2i:ident, $d3r:ident, $d3i:ident) => {{
        let (x0r, x0i) = ($s0r[$q], $s0i[$q]);
        let (x1r, x1i) = ($s1r[$q], $s1i[$q]);
        let (x2r, x2i) = ($s2r[$q], $s2i[$q]);
        let (x3r, x3i) = ($s3r[$q], $s3i[$q]);
        let t0r = x0r + x2r;
        let t0i = x0i + x2i;
        let t1r = x1r + x3r;
        let t1i = x1i + x3i;
        let u0r = x0r - x2r;
        let u0i = x0i - x2i;
        let u1r = x1r - x3r;
        let u1i = x1i - x3i;
        $d0r[$q] = t0r + t1r;
        $d0i[$q] = t0i + t1i;
        $d2r[$q] = t0r - t1r;
        $d2i[$q] = t0i - t1i;
        // y1 = u0 − i·sign·u1, y3 = u0 + i·sign·u1
        $d1r[$q] = u0r + $sign * u1i;
        $d1i[$q] = u0i - $sign * u1r;
        $d3r[$q] = u0r - $sign * u1i;
        $d3i[$q] = u0i + $sign * u1r;
    }};
}

/// One complex FFT8 butterfly on lane `q`: DIT over two FFT4s (evens
/// x0,x2,x4,x6 and odds x1,x3,x5,x7) with the 1/√2 twiddles hardcoded.
/// Same aliasing contract as [`fft4_lanes_body`].
macro_rules! fft8_lanes_body {
    ($q:expr, $sign:expr,
     $s0r:ident, $s0i:ident, $s1r:ident, $s1i:ident,
     $s2r:ident, $s2i:ident, $s3r:ident, $s3i:ident,
     $s4r:ident, $s4i:ident, $s5r:ident, $s5i:ident,
     $s6r:ident, $s6i:ident, $s7r:ident, $s7i:ident,
     $d0r:ident, $d0i:ident, $d1r:ident, $d1i:ident,
     $d2r:ident, $d2i:ident, $d3r:ident, $d3i:ident,
     $d4r:ident, $d4i:ident, $d5r:ident, $d5i:ident,
     $d6r:ident, $d6i:ident, $d7r:ident, $d7i:ident) => {{
        let (x0r, x0i) = ($s0r[$q], $s0i[$q]);
        let (x1r, x1i) = ($s1r[$q], $s1i[$q]);
        let (x2r, x2i) = ($s2r[$q], $s2i[$q]);
        let (x3r, x3i) = ($s3r[$q], $s3i[$q]);
        let (x4r, x4i) = ($s4r[$q], $s4i[$q]);
        let (x5r, x5i) = ($s5r[$q], $s5i[$q]);
        let (x6r, x6i) = ($s6r[$q], $s6i[$q]);
        let (x7r, x7i) = ($s7r[$q], $s7i[$q]);
        // FFT4 of the evens (x0, x2, x4, x6) → e0..e3
        let a0r = x0r + x4r;
        let a0i = x0i + x4i;
        let a1r = x2r + x6r;
        let a1i = x2i + x6i;
        let b0r = x0r - x4r;
        let b0i = x0i - x4i;
        let b1r = x2r - x6r;
        let b1i = x2i - x6i;
        let e0r = a0r + a1r;
        let e0i = a0i + a1i;
        let e2r = a0r - a1r;
        let e2i = a0i - a1i;
        let e1r = b0r + $sign * b1i;
        let e1i = b0i - $sign * b1r;
        let e3r = b0r - $sign * b1i;
        let e3i = b0i + $sign * b1r;
        // FFT4 of the odds (x1, x3, x5, x7) → o0..o3
        let a0r = x1r + x5r;
        let a0i = x1i + x5i;
        let a1r = x3r + x7r;
        let a1i = x3i + x7i;
        let b0r = x1r - x5r;
        let b0i = x1i - x5i;
        let b1r = x3r - x7r;
        let b1i = x3i - x7i;
        let o0r = a0r + a1r;
        let o0i = a0i + a1i;
        let o2r = a0r - a1r;
        let o2i = a0i - a1i;
        let o1r = b0r + $sign * b1i;
        let o1i = b0i - $sign * b1r;
        let o3r = b0r - $sign * b1i;
        let o3i = b0i + $sign * b1r;
        // odd branch twiddled by w8^k = e^(−sign·2πik/8), c = 1/√2
        let t1r = C8 * (o1r + $sign * o1i);
        let t1i = C8 * (o1i - $sign * o1r);
        let t2r = $sign * o2i;
        let t2i = -($sign * o2r);
        let t3r = -(C8 * (o3r - $sign * o3i));
        let t3i = -(C8 * (o3i + $sign * o3r));
        $d0r[$q] = e0r + o0r;
        $d0i[$q] = e0i + o0i;
        $d4r[$q] = e0r - o0r;
        $d4i[$q] = e0i - o0i;
        $d1r[$q] = e1r + t1r;
        $d1i[$q] = e1i + t1i;
        $d5r[$q] = e1r - t1r;
        $d5i[$q] = e1i - t1i;
        $d2r[$q] = e2r + t2r;
        $d2i[$q] = e2i + t2i;
        $d6r[$q] = e2r - t2r;
        $d6i[$q] = e2i - t2i;
        $d3r[$q] = e3r + t3r;
        $d3i[$q] = e3i + t3i;
        $d7r[$q] = e3r - t3r;
        $d7i[$q] = e3i - t3i;
    }};
}

/// Out-of-place tail codelet: gather lanes from `src`, write the
/// natural-order result to `dst` (used when the ping-pong left the data
/// in the scratch planes — replaces codelet stages *and* the copy).
pub(crate) fn tail_codelet(
    tail: usize,
    sign: f64,
    src_re: &[f64],
    src_im: &[f64],
    dst_re: &mut [f64],
    dst_im: &mut [f64],
) {
    let s = src_re.len() / tail;
    debug_assert_eq!(src_re.len(), tail * s);
    debug_assert_eq!(dst_re.len(), tail * s);
    match tail {
        2 => {
            let (s0r, s1r) = src_re.split_at(s);
            let (s0i, s1i) = src_im.split_at(s);
            let (d0r, d1r) = dst_re.split_at_mut(s);
            let (d0i, d1i) = dst_im.split_at_mut(s);
            for q in 0..s {
                let (ar, ai) = (s0r[q], s0i[q]);
                let (br, bi) = (s1r[q], s1i[q]);
                d0r[q] = ar + br;
                d0i[q] = ai + bi;
                d1r[q] = ar - br;
                d1i[q] = ai - bi;
            }
        }
        4 => {
            // AVX2 body covers a multiple-of-4 lane prefix (identical
            // IEEE-754 op order — bit-identical, in every generation);
            // the scalar body finishes the remainder
            let done = simd::tail4_oop(sign, src_re, src_im, dst_re, dst_im);
            let (s0r, rest) = src_re.split_at(s);
            let (s1r, rest) = rest.split_at(s);
            let (s2r, s3r) = rest.split_at(s);
            let (s0i, rest) = src_im.split_at(s);
            let (s1i, rest) = rest.split_at(s);
            let (s2i, s3i) = rest.split_at(s);
            let (d0r, rest) = dst_re.split_at_mut(s);
            let (d1r, rest) = rest.split_at_mut(s);
            let (d2r, d3r) = rest.split_at_mut(s);
            let (d0i, rest) = dst_im.split_at_mut(s);
            let (d1i, rest) = rest.split_at_mut(s);
            let (d2i, d3i) = rest.split_at_mut(s);
            for q in done..s {
                fft4_lanes_body!(
                    q, sign, s0r, s0i, s1r, s1i, s2r, s2i, s3r, s3i, d0r, d0i, d1r, d1i, d2r, d2i,
                    d3r, d3i
                );
            }
        }
        8 => {
            let done = simd::tail8_oop(sign, src_re, src_im, dst_re, dst_im);
            let (s0r, rest) = src_re.split_at(s);
            let (s1r, rest) = rest.split_at(s);
            let (s2r, rest) = rest.split_at(s);
            let (s3r, rest) = rest.split_at(s);
            let (s4r, rest) = rest.split_at(s);
            let (s5r, rest) = rest.split_at(s);
            let (s6r, s7r) = rest.split_at(s);
            let (s0i, rest) = src_im.split_at(s);
            let (s1i, rest) = rest.split_at(s);
            let (s2i, rest) = rest.split_at(s);
            let (s3i, rest) = rest.split_at(s);
            let (s4i, rest) = rest.split_at(s);
            let (s5i, rest) = rest.split_at(s);
            let (s6i, s7i) = rest.split_at(s);
            let (d0r, rest) = dst_re.split_at_mut(s);
            let (d1r, rest) = rest.split_at_mut(s);
            let (d2r, rest) = rest.split_at_mut(s);
            let (d3r, rest) = rest.split_at_mut(s);
            let (d4r, rest) = rest.split_at_mut(s);
            let (d5r, rest) = rest.split_at_mut(s);
            let (d6r, d7r) = rest.split_at_mut(s);
            let (d0i, rest) = dst_im.split_at_mut(s);
            let (d1i, rest) = rest.split_at_mut(s);
            let (d2i, rest) = rest.split_at_mut(s);
            let (d3i, rest) = rest.split_at_mut(s);
            let (d4i, rest) = rest.split_at_mut(s);
            let (d5i, rest) = rest.split_at_mut(s);
            let (d6i, d7i) = rest.split_at_mut(s);
            for q in done..s {
                fft8_lanes_body!(
                    q, sign, s0r, s0i, s1r, s1i, s2r, s2i, s3r, s3i, s4r, s4i, s5r, s5i, s6r, s6i,
                    s7r, s7i, d0r, d0i, d1r, d1i, d2r, d2i, d3r, d3i, d4r, d4i, d5r, d5i, d6r,
                    d6i, d7r, d7i
                );
            }
        }
        other => unreachable!("unsupported tail {other}"),
    }
}

/// In-place tail codelet (used when the ping-pong left the data in the
/// destination planes): identical arithmetic to [`tail_codelet`] — the
/// butterfly bodies read every input before writing.
pub(crate) fn tail_codelet_inplace(tail: usize, sign: f64, re: &mut [f64], im: &mut [f64]) {
    let s = re.len() / tail;
    debug_assert_eq!(re.len(), tail * s);
    match tail {
        2 => {
            let (c0r, c1r) = re.split_at_mut(s);
            let (c0i, c1i) = im.split_at_mut(s);
            for q in 0..s {
                let (ar, ai) = (c0r[q], c0i[q]);
                let (br, bi) = (c1r[q], c1i[q]);
                c0r[q] = ar + br;
                c0i[q] = ai + bi;
                c1r[q] = ar - br;
                c1i[q] = ai - bi;
            }
        }
        4 => {
            // AVX2 prefix as in [`tail_codelet`]: each 4-lane group loads
            // every input before storing, so in-place aliasing is safe
            let done = simd::tail4_inplace(sign, re, im);
            let (c0r, rest) = re.split_at_mut(s);
            let (c1r, rest) = rest.split_at_mut(s);
            let (c2r, c3r) = rest.split_at_mut(s);
            let (c0i, rest) = im.split_at_mut(s);
            let (c1i, rest) = rest.split_at_mut(s);
            let (c2i, c3i) = rest.split_at_mut(s);
            for q in done..s {
                fft4_lanes_body!(
                    q, sign, c0r, c0i, c1r, c1i, c2r, c2i, c3r, c3i, c0r, c0i, c1r, c1i, c2r, c2i,
                    c3r, c3i
                );
            }
        }
        8 => {
            let done = simd::tail8_inplace(sign, re, im);
            let (c0r, rest) = re.split_at_mut(s);
            let (c1r, rest) = rest.split_at_mut(s);
            let (c2r, rest) = rest.split_at_mut(s);
            let (c3r, rest) = rest.split_at_mut(s);
            let (c4r, rest) = rest.split_at_mut(s);
            let (c5r, rest) = rest.split_at_mut(s);
            let (c6r, c7r) = rest.split_at_mut(s);
            let (c0i, rest) = im.split_at_mut(s);
            let (c1i, rest) = rest.split_at_mut(s);
            let (c2i, rest) = rest.split_at_mut(s);
            let (c3i, rest) = rest.split_at_mut(s);
            let (c4i, rest) = rest.split_at_mut(s);
            let (c5i, rest) = rest.split_at_mut(s);
            let (c6i, c7i) = rest.split_at_mut(s);
            for q in done..s {
                fft8_lanes_body!(
                    q, sign, c0r, c0i, c1r, c1i, c2r, c2i, c3r, c3i, c4r, c4i, c5r, c5i, c6r, c6i,
                    c7r, c7i, c0r, c0i, c1r, c1i, c2r, c2i, c3r, c3i, c4r, c4i, c5r, c5i, c6r,
                    c6i, c7r, c7i
                );
            }
        }
        other => unreachable!("unsupported tail {other}"),
    }
}

/// Transform `rows` contiguous length-`n` rows through one *stage-major*
/// sweep: every row advances through stage `k` before any row starts
/// stage `k+1`, so each stage's twiddle table is streamed once per tile
/// instead of once per row and the stage kernel stays register-resident
/// across rows. The per-row arithmetic is exactly [`fft_row_radix`]'s —
/// the loop order changes, the operations do not — so the output is
/// bit-identical to the per-row driver in every kernel generation.
///
/// Exactly-4-row tiles additionally vectorize stride-1 radix-3/5
/// stages *across* the rows (`simd::try_stage{3,5}_xrow4`): those
/// shapes appear whenever a length carries at most three factors of 2
/// (the tail codelet absorbs them all, e.g. 360 = 2³·3²·5 opens on a
/// stride-1 radix-3 stage, 40 = 2³·5 on a stride-1 radix-5 one) and
/// have no within-row vector form at radix 5. The kernels replicate
/// the per-row op order bit-for-bit — and decline any generation where
/// they could not — so tile width stays unobservable in the output.
///
/// `scratch_re`/`scratch_im` must each hold at least `rows * plan.n`
/// elements (one ping-pong plane per row in the tile).
pub fn fft_rows_radix_tiled(
    re: &mut [f64],
    im: &mut [f64],
    rows: usize,
    scratch_re: &mut [f64],
    scratch_im: &mut [f64],
    plan: &RadixPlan,
    dir: Direction,
) {
    let n = plan.n;
    debug_assert_eq!(re.len(), rows * n);
    debug_assert_eq!(im.len(), re.len());
    debug_assert!(scratch_re.len() >= rows * n);
    debug_assert!(scratch_im.len() >= rows * n);

    let mut in_src = true; // data currently in re/im?
    for stage in &plan.stages {
        let m = stage.butterflies();
        // Cross-row fast path: in a 4-row tile, the stride-1 odd-radix
        // stages (pure 3^a·5^b row lengths, where no within-row vector
        // shape exists) vectorize *across* the rows — unit-stride quad
        // loads/stores plus in-register 4×4 transposes, exact scalar op
        // order (see `simd::try_stage{3,5}_xrow4` for the generation
        // gating that keeps tile width unobservable in the bits). The
        // kernel covers a multiple-of-4 prefix of the butterflies for
        // all four rows at once; the per-row loop below finishes the
        // remainder.
        let done = if rows == 4 && stage.stride == 1 && stage.simd_ok {
            let sign = if dir == Direction::Inverse { -1.0 } else { 1.0 };
            let (twr, twi) = (&stage.tw.re[..], &stage.tw.im[..]);
            match (stage.radix, in_src) {
                (3, true) => {
                    simd::try_stage3_xrow4(sign, twr, twi, re, im, scratch_re, scratch_im, n, m)
                }
                (3, false) => {
                    simd::try_stage3_xrow4(sign, twr, twi, scratch_re, scratch_im, re, im, n, m)
                }
                (5, true) => {
                    simd::try_stage5_xrow4(sign, twr, twi, re, im, scratch_re, scratch_im, n, m)
                }
                (5, false) => {
                    simd::try_stage5_xrow4(sign, twr, twi, scratch_re, scratch_im, re, im, n, m)
                }
                _ => 0,
            }
        } else {
            0
        };
        if done < m {
            for r in 0..rows {
                let span = r * n..(r + 1) * n;
                let dst_span = r * n + stage.radix * stage.stride * done..(r + 1) * n;
                if in_src {
                    apply_stage_range(
                        stage,
                        dir,
                        &re[span.clone()],
                        &im[span],
                        &mut scratch_re[dst_span.clone()],
                        &mut scratch_im[dst_span],
                        done,
                        m,
                    );
                } else {
                    apply_stage_range(
                        stage,
                        dir,
                        &scratch_re[span.clone()],
                        &scratch_im[span],
                        &mut re[dst_span.clone()],
                        &mut im[dst_span],
                        done,
                        m,
                    );
                }
            }
        }
        in_src = !in_src;
    }
    for r in 0..rows {
        let span = r * n..(r + 1) * n;
        finish_tail(
            plan,
            dir,
            &mut re[span.clone()],
            &mut im[span.clone()],
            &mut scratch_re[span.clone()],
            &mut scratch_im[span],
            in_src,
        );
    }
    if dir == Direction::Inverse {
        let inv_n = 1.0 / n as f64;
        for v in re.iter_mut() {
            *v *= inv_n;
        }
        for v in im.iter_mut() {
            *v *= inv_n;
        }
    }
}

/// Batched convenience wrapper for tests and cold paths: shares the
/// process-wide cached plan ([`crate::dft::plan::PlanCache`]) and this
/// thread's scratch arena ([`crate::dft::exec::with_scratch`]) instead
/// of allocating either per call — hot paths still go through
/// [`crate::dft::exec::fft_rows_pooled`]. Rows are processed in
/// multi-row tiles ([`fft_rows_radix_tiled`]) of the effective width
/// ([`crate::dft::exec::effective_row_tile`]: measured calibration when
/// one is cached, the model otherwise).
pub fn fft_rows_radix(re: &mut [f64], im: &mut [f64], rows: usize, n: usize, dir: Direction) {
    debug_assert_eq!(re.len(), rows * n);
    debug_assert_eq!(im.len(), re.len());
    let plan = crate::dft::plan::PlanCache::global().radix(n);
    let tile = crate::dft::exec::effective_row_tile(n).min(rows.max(1));
    crate::dft::exec::with_scratch(|scratch| {
        let (sr, si) = scratch.pair(tile * n);
        let mut r = 0;
        while r < rows {
            let w = tile.min(rows - r);
            let span = r * n..(r + w) * n;
            fft_rows_radix_tiled(&mut re[span.clone()], &mut im[span], w, sr, si, &plan, dir);
            r += w;
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dft::{naive_dft_rows, SignalMatrix};

    fn radix_matrix(m: &SignalMatrix, dir: Direction) -> SignalMatrix {
        let mut out = m.clone();
        fft_rows_radix(&mut out.re, &mut out.im, m.rows, m.cols, dir);
        out
    }

    fn variant_matrix(m: &SignalMatrix, variant: KernelVariant, dir: Direction) -> SignalMatrix {
        let plan = RadixPlan::with_variant(m.cols, variant);
        let mut out = m.clone();
        let mut sr = vec![0.0; m.cols];
        let mut si = vec![0.0; m.cols];
        for r in 0..m.rows {
            let span = r * m.cols..(r + 1) * m.cols;
            fft_row_radix(&mut out.re[span.clone()], &mut out.im[span], &mut sr, &mut si, &plan, dir);
        }
        out
    }

    #[test]
    fn factorization() {
        assert_eq!(factorize_235(1), Some(vec![]));
        assert_eq!(factorize_235(2), Some(vec![2]));
        assert_eq!(factorize_235(384), Some(vec![2, 2, 2, 2, 2, 2, 2, 3]));
        assert_eq!(factorize_235(640), Some(vec![2, 2, 2, 2, 2, 2, 2, 5]));
        assert_eq!(factorize_235(1152), Some(vec![2, 2, 2, 2, 2, 2, 2, 3, 3]));
        assert_eq!(factorize_235(0), None);
        assert_eq!(factorize_235(7), None);
        assert_eq!(factorize_235(896), None); // 128·7
        assert!(is_five_smooth(3200));
        assert!(!is_five_smooth(1000 * 7));
    }

    #[test]
    fn hoisted_constants_match_trig() {
        // ~1e-15, NOT bitwise: libm is not correctly rounded and varies
        // by platform; the consts are the correctly-rounded values
        let third = 2.0 * std::f64::consts::PI / 3.0;
        let fifth = 2.0 * std::f64::consts::PI / 5.0;
        assert!((S3 - third.sin()).abs() < 1e-15);
        assert!((C5_1 - fifth.cos()).abs() < 1e-15);
        assert!((C5_2 - (2.0 * fifth).cos()).abs() < 1e-15);
        assert!((S5_1 - fifth.sin()).abs() < 1e-15);
        assert!((S5_2 - (2.0 * fifth).sin()).abs() < 1e-15);
        assert!((C8 - (std::f64::consts::PI / 4.0).cos()).abs() < 1e-15);
    }

    #[test]
    fn kernel_summary_strings() {
        // runtime-detected feature tag: AVX2 covers every stage shape
        // plus the codelet bodies, so it applies to any vectorized plan
        let feat = if fma_active() {
            "+avx2+fma"
        } else if simd_active() {
            "+avx2"
        } else {
            ""
        };
        assert_eq!(kernel_summary(384), format!("mixed-radix 2^7*3 [fft8 codelet{feat}]"));
        assert_eq!(kernel_summary(640), format!("mixed-radix 2^7*5 [fft8 codelet{feat}]"));
        assert_eq!(kernel_summary(6), format!("mixed-radix 2*3 [fft2 codelet{feat}]"));
        assert_eq!(kernel_summary(24), format!("mixed-radix 2^3*3 [fft8 codelet{feat}]"));
        // no radix-2 factor → no codelet tail, but the vectorized
        // radix-3/5 stages still earn the feature tag
        let solo = if fma_active() {
            " [avx2+fma]"
        } else if simd_active() {
            " [avx2]"
        } else {
            ""
        };
        assert_eq!(kernel_summary(15), format!("mixed-radix 3*5{solo}"));
        assert!(kernel_summary(7).starts_with("bluestein"));
        assert_eq!(kernel_summary(1), "identity");
    }

    #[test]
    fn kernel_generation_tracks_detected_features() {
        let gen = kernel_generation();
        assert!(gen.starts_with("stockham-v2-codelet"));
        assert_eq!(gen.ends_with("+avx2+fma"), fma_active());
        assert_eq!(gen.contains("+avx2"), simd_active());
        if fma_active() {
            assert!(simd_active(), "fma generation implies avx2");
        }
    }

    #[test]
    fn plan_schedules() {
        // vectorized: 2s first, minus the 3 fused into the fft8 tail
        let p = RadixPlan::new(384); // 2^7·3
        assert_eq!(p.variant, KernelVariant::Vectorized);
        assert_eq!(p.tail, 8);
        assert_eq!(p.stages.iter().map(|s| s.radix).collect::<Vec<_>>(), vec![2, 2, 2, 2, 3]);
        assert_eq!(p.factors, vec![2, 2, 2, 2, 2, 2, 2, 3]); // still ascending
        assert_eq!(p.stages.last().unwrap().n_cur, 24);
        // scalar keeps the pre-codelet shape
        let s = RadixPlan::with_variant(384, KernelVariant::Scalar);
        assert_eq!(s.tail, 1);
        assert_eq!(s.stages.len(), 8);
        // fewer than 3 twos → smaller tail; no twos → no tail
        assert_eq!(RadixPlan::new(12).tail, 4); // 2^2·3
        assert_eq!(RadixPlan::new(15).tail, 1);
        assert_eq!(RadixPlan::new(8).tail, 8); // pure codelet, no stages
        assert!(RadixPlan::new(8).stages.is_empty());
    }

    #[test]
    fn matches_naive_across_smooth_sizes() {
        for &n in &[
            1usize, 2, 3, 4, 5, 6, 8, 10, 12, 15, 16, 20, 24, 30, 40, 48, 60, 80, 96, 120, 128,
            240, 384, 480, 640,
        ] {
            let m = SignalMatrix::random(2, n, n as u64 + 3);
            let got = radix_matrix(&m, Direction::Forward);
            let want = naive_dft_rows(&m, false);
            let scale = want.norm().max(1.0);
            assert!(
                got.max_abs_diff(&want) / scale < 1e-10,
                "n={n}: rel diff {}",
                got.max_abs_diff(&want) / scale
            );
        }
    }

    #[test]
    fn scalar_variant_matches_vectorized() {
        // both kernels are exact FFTs of the same row — they agree far
        // below the oracle tolerance, on every tail size and parity of
        // stage count
        for &n in &[2usize, 4, 6, 8, 12, 16, 24, 40, 48, 60, 120, 384, 640, 1152] {
            let m = SignalMatrix::random(2, n, 17 * n as u64 + 1);
            let a = variant_matrix(&m, KernelVariant::Scalar, Direction::Forward);
            let b = variant_matrix(&m, KernelVariant::Vectorized, Direction::Forward);
            let scale = a.norm().max(1.0);
            assert!(
                a.max_abs_diff(&b) / scale < 1e-12,
                "n={n}: scalar vs vectorized rel diff {}",
                a.max_abs_diff(&b) / scale
            );
        }
    }

    #[test]
    fn inverse_roundtrip() {
        for &n in &[3usize, 5, 15, 60, 384, 1152] {
            let m = SignalMatrix::random(2, n, 7);
            let f = radix_matrix(&m, Direction::Forward);
            let b = radix_matrix(&f, Direction::Inverse);
            assert!(m.max_abs_diff(&b) < 1e-9, "n={n}: {}", m.max_abs_diff(&b));
        }
    }

    #[test]
    fn pow2_schedule_matches_radix2_kernel() {
        // the all-2s schedule must agree with the dedicated pow2 kernel
        let n = 256;
        let m = SignalMatrix::random(3, n, 9);
        let got = radix_matrix(&m, Direction::Forward);
        let mut want = m.clone();
        crate::dft::fft::fft_rows_pow2(&mut want.re, &mut want.im, 3, n, Direction::Forward);
        assert!(got.max_abs_diff(&want) < 1e-9);
    }

    #[test]
    fn matches_bluestein_at_paper_sizes() {
        for &n in &[384usize, 640, 768] {
            let m = SignalMatrix::random(1, n, 11);
            let got = radix_matrix(&m, Direction::Forward);
            let mut want = m.clone();
            let plan = crate::dft::bluestein::BluesteinPlan::new(n);
            let ml = plan.scratch_len();
            let (mut br, mut bi) = (vec![0.0; ml], vec![0.0; ml]);
            let (mut sr, mut si) = (vec![0.0; ml], vec![0.0; ml]);
            crate::dft::bluestein::fft_row_bluestein(
                &mut want.re,
                &mut want.im,
                &plan,
                Direction::Forward,
                &mut br,
                &mut bi,
                &mut sr,
                &mut si,
            );
            let scale = want.norm().max(1.0);
            assert!(
                got.max_abs_diff(&want) / scale < 1e-9,
                "n={n}: {}",
                got.max_abs_diff(&want) / scale
            );
        }
    }

    #[test]
    fn impulse_flat_spectrum() {
        let mut m = SignalMatrix::zeros(1, 30);
        m.set(0, 0, 1.0, 0.0);
        let f = radix_matrix(&m, Direction::Forward);
        for c in 0..30 {
            let (re, im) = f.get(0, c);
            assert!((re - 1.0).abs() < 1e-12 && im.abs() < 1e-12, "bin {c}");
        }
    }

    #[test]
    fn stage_range_split_is_bit_exact() {
        // applying a stage in two halves must equal one full application
        // — for both kernel variants (the SIMD fast path, when active,
        // must be bit-identical to the scalar loop as well)
        let n = 240; // 2^4·3·5 — exercises all three radixes
        for variant in [KernelVariant::Scalar, KernelVariant::Vectorized] {
            let plan = RadixPlan::with_variant(n, variant);
            let m = SignalMatrix::random(1, n, 5);
            for stage in &plan.stages {
                let bf = stage.butterflies();
                let (mut full_r, mut full_i) = (vec![0.0; n], vec![0.0; n]);
                apply_stage_range(
                    stage,
                    Direction::Forward,
                    &m.re,
                    &m.im,
                    &mut full_r,
                    &mut full_i,
                    0,
                    bf,
                );
                let (mut split_r, mut split_i) = (vec![0.0; n], vec![0.0; n]);
                let mid = bf / 2;
                let cut = stage.radix * stage.stride * mid;
                let (lo_r, hi_r) = split_r.split_at_mut(cut);
                let (lo_i, hi_i) = split_i.split_at_mut(cut);
                apply_stage_range(stage, Direction::Forward, &m.re, &m.im, lo_r, lo_i, 0, mid);
                apply_stage_range(stage, Direction::Forward, &m.re, &m.im, hi_r, hi_i, mid, bf);
                assert_eq!(full_r, split_r, "{variant:?} radix {} re", stage.radix);
                assert_eq!(full_i, split_i, "{variant:?} radix {} im", stage.radix);
            }
        }
    }

    #[test]
    fn tail_codelet_inplace_matches_out_of_place() {
        // the two codelet forms share one butterfly body; pin it
        for tail in [2usize, 4, 8] {
            let s = 6;
            let n = tail * s;
            let m = SignalMatrix::random(1, n, 31 + tail as u64);
            for sign in [1.0, -1.0] {
                let (mut or, mut oi) = (vec![0.0; n], vec![0.0; n]);
                tail_codelet(tail, sign, &m.re, &m.im, &mut or, &mut oi);
                let (mut ir, mut ii) = (m.re.clone(), m.im.clone());
                tail_codelet_inplace(tail, sign, &mut ir, &mut ii);
                assert_eq!(or, ir, "tail {tail} sign {sign} re");
                assert_eq!(oi, ii, "tail {tail} sign {sign} im");
            }
        }
    }

    #[test]
    fn simd_stage_dispatch_matches_forced_scalar() {
        // every stage shape the dispatchers cover: radix-3 stride 1 (24),
        // radix-5 stride 2 (80), radix-3 stride 1+3 / radix-5 wide (90),
        // all three radixes incl. wide (240), wide radix-3 (384, 1152),
        // wide radix-5 (640)
        for &n in &[24usize, 80, 90, 240, 384, 640, 1152] {
            let plan = RadixPlan::new(n);
            let m = SignalMatrix::random(1, n, 41 * n as u64 + 5);
            for (si, stage) in plan.stages.iter().enumerate() {
                let mut forced = stage.clone();
                forced.simd_ok = false;
                let bf = stage.butterflies();
                for dir in [Direction::Forward, Direction::Inverse] {
                    let (mut vr, mut vi) = (vec![0.0; n], vec![0.0; n]);
                    apply_stage_range(stage, dir, &m.re, &m.im, &mut vr, &mut vi, 0, bf);
                    let (mut sr2, mut si2) = (vec![0.0; n], vec![0.0; n]);
                    apply_stage_range(&forced, dir, &m.re, &m.im, &mut sr2, &mut si2, 0, bf);
                    if fma_active() {
                        // contracted roundings: tolerance, not equality
                        for q in 0..n {
                            let scale = vr[q].abs().max(vi[q].abs()).max(1.0);
                            assert!(
                                (vr[q] - sr2[q]).abs() / scale < 1e-12
                                    && (vi[q] - si2[q]).abs() / scale < 1e-12,
                                "n={n} stage {si} (radix {}, stride {}) q={q}",
                                stage.radix,
                                stage.stride
                            );
                        }
                    } else {
                        // plain AVX2 keeps the scalar IEEE-754 op order
                        assert_eq!(vr, sr2, "n={n} stage {si} radix {} re", stage.radix);
                        assert_eq!(vi, si2, "n={n} stage {si} radix {} im", stage.radix);
                    }
                }
            }
        }
    }

    #[test]
    fn tiled_rows_bitwise_match_per_row() {
        // stage-major multi-row tiling reorders loops, not arithmetic —
        // bit-identical to the per-row driver in every generation
        for &n in &[240usize, 384] {
            let rows = 5;
            let plan = RadixPlan::new(n);
            let m = SignalMatrix::random(rows, n, 61 + n as u64);
            for dir in [Direction::Forward, Direction::Inverse] {
                let mut per_row = m.clone();
                let (mut sr, mut si) = (vec![0.0; n], vec![0.0; n]);
                for r in 0..rows {
                    let span = r * n..(r + 1) * n;
                    fft_row_radix(
                        &mut per_row.re[span.clone()],
                        &mut per_row.im[span],
                        &mut sr,
                        &mut si,
                        &plan,
                        dir,
                    );
                }
                let mut tiled = m.clone();
                let (mut tr, mut ti) = (vec![0.0; rows * n], vec![0.0; rows * n]);
                fft_rows_radix_tiled(
                    &mut tiled.re, &mut tiled.im, rows, &mut tr, &mut ti, &plan, dir,
                );
                assert_eq!(per_row.re, tiled.re, "n={n} {dir:?} re");
                assert_eq!(per_row.im, tiled.im, "n={n} {dir:?} im");
            }
        }
    }

    #[test]
    fn xrow4_tile_bitwise_matches_per_row() {
        // exactly-4-row tiles take the cross-row stride-1 radix-3/5
        // kernels; lengths chosen so those stages fire with
        // non-multiple-of-4 butterfly remainders: 45 = 3²·5 (radix-3
        // stride 1, m=15), 25 = 5² (radix-5 stride 1, m=5), 40 = 2³·5
        // (radix-5 stride 1 after the tail absorbs the 2s), 360 =
        // 2³·3²·5 (radix-3 stride 1 opener plus an FFT8 tail), 375 =
        // 3·5³ (m=125). Must stay bit-identical to the per-row driver.
        for &n in &[45usize, 25, 40, 360, 375] {
            let rows = 4;
            let plan = RadixPlan::new(n);
            let m = SignalMatrix::random(rows, n, 91 + n as u64);
            for dir in [Direction::Forward, Direction::Inverse] {
                let mut per_row = m.clone();
                let (mut sr, mut si) = (vec![0.0; n], vec![0.0; n]);
                for r in 0..rows {
                    let span = r * n..(r + 1) * n;
                    fft_row_radix(
                        &mut per_row.re[span.clone()],
                        &mut per_row.im[span],
                        &mut sr,
                        &mut si,
                        &plan,
                        dir,
                    );
                }
                let mut tiled = m.clone();
                let (mut tr, mut ti) = (vec![0.0; rows * n], vec![0.0; rows * n]);
                fft_rows_radix_tiled(
                    &mut tiled.re, &mut tiled.im, rows, &mut tr, &mut ti, &plan, dir,
                );
                assert_eq!(per_row.re, tiled.re, "n={n} {dir:?} re");
                assert_eq!(per_row.im, tiled.im, "n={n} {dir:?} im");
            }
        }
    }

    #[test]
    fn stage_twiddles_shared_across_plans() {
        // 384 = 2^7·3 and 768 = 2^8·3 share every stage geometry after
        // 768's extra leading radix-2 — the Arc allocations must be the
        // same, not equal copies
        let a = RadixPlan::new(384);
        let b = RadixPlan::new(768);
        let mut shared = 0usize;
        for sa in &a.stages {
            for sb in &b.stages {
                if sa.radix == sb.radix && sa.n_cur == sb.n_cur {
                    assert!(
                        std::sync::Arc::ptr_eq(sa.twiddles(), sb.twiddles()),
                        "radix {} n_cur {} not shared",
                        sa.radix,
                        sa.n_cur
                    );
                    shared += 1;
                }
            }
        }
        assert!(shared >= 4, "expected shared stage geometries, got {shared}");
        // and two plans for the *same* length share everything
        let c = RadixPlan::new(384);
        for (sa, sc) in a.stages.iter().zip(&c.stages) {
            assert!(std::sync::Arc::ptr_eq(sa.twiddles(), sc.twiddles()));
        }
    }

    #[test]
    #[should_panic(expected = "5-smooth")]
    fn rejects_non_smooth() {
        RadixPlan::new(14);
    }
}
