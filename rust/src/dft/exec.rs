//! `ExecCtx` — the shared execution context: one persistent worker pool
//! plus per-thread scratch arenas, replacing the per-call
//! `std::thread::scope` spawns and per-call `vec![0.0; n]` scratch
//! allocations that used to be scattered across `dft2d`, the native
//! engine, the transpose and the batch executor.
//!
//! Design:
//!
//! * **One pool.** [`ExecCtx::global`] owns N OS threads for the whole
//!   process; every layer (row FFTs, transposes, PFFT group phases,
//!   batched service dispatch) submits jobs to it. Waiting callers *help
//!   execute* queued jobs, so nested parallelism (a group job whose
//!   engine call fans out row chunks) cannot deadlock the fixed pool.
//! * **Per-thread scratch arenas.** [`with_scratch`] leases a reusable
//!   arena from a thread-local pool; `resize` on retained `Vec`s means
//!   the steady-state serve loop performs no scratch allocation
//!   (asserted by `rust/tests/exec_steadystate.rs`;
//!   [`scratch_grow_events`] counts arena growth for tests/benches).
//! * **One executor entry point.** [`fft_rows_pooled`] is the single
//!   row-FFT dispatch: 5-smooth lengths run the mixed-radix kernel
//!   ([`crate::dft::radix`]), everything else falls back to Bluestein.
//!   Batches split by rows; a *small* batch of *long* smooth rows splits
//!   within each row across stage sub-ranges instead of clamping the
//!   thread budget to the row count. Within a worker's chunk, smooth
//!   rows advance in stage-major multi-row tiles
//!   ([`radix::fft_rows_radix_tiled`]) whose width comes from
//!   [`effective_row_tile`]: a measured one-shot micro-calibration
//!   ([`calibrate_row_tile`], persisted via wisdom, invalidated by
//!   memory-class model drift) when one exists, else the model surface
//!   in [`row_tile_curve`] — twiddle streams amortize across the tile
//!   while the working set stays cache-resident.
//!
//! Determinism: all split strategies preserve per-element arithmetic
//! exactly, so results are bit-identical for every `parallelism` value —
//! the invariant the service's bit-exactness tests rely on.

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::time::Duration;

use crate::dft::bluestein::fft_row_bluestein;
use crate::dft::fft::Direction;
use crate::dft::plan::{PlanCache, RowPlan};
use crate::dft::radix::{self, RadixPlan};

// ---------------------------------------------------------------------------
// Scratch arenas
// ---------------------------------------------------------------------------

/// Process-wide count of scratch-arena growth events (test/bench hook:
/// after warmup, a steady-state serve loop must not grow any arena).
static SCRATCH_GROW_EVENTS: AtomicUsize = AtomicUsize::new(0);

/// How many times any scratch arena had to grow its capacity so far.
pub fn scratch_grow_events() -> usize {
    SCRATCH_GROW_EVENTS.load(Ordering::Relaxed)
}

/// A reusable per-thread buffer arena: up to four f64 planes, retained
/// across leases so repeated same-size work allocates nothing.
pub struct Scratch {
    bufs: [Vec<f64>; 4],
}

impl Scratch {
    fn new() -> Scratch {
        Scratch { bufs: [Vec::new(), Vec::new(), Vec::new(), Vec::new()] }
    }

    fn lease(buf: &mut Vec<f64>, len: usize) -> &mut [f64] {
        if len > buf.capacity() {
            SCRATCH_GROW_EVENTS.fetch_add(1, Ordering::Relaxed);
        }
        buf.clear();
        buf.resize(len, 0.0);
        &mut buf[..]
    }

    /// Two zeroed length-`len` planes (radix ping-pong scratch).
    pub fn pair(&mut self, len: usize) -> (&mut [f64], &mut [f64]) {
        let [a, b, _, _] = &mut self.bufs;
        (Self::lease(a, len), Self::lease(b, len))
    }

    /// Four zeroed length-`len` planes (Bluestein convolution scratch).
    pub fn quad(&mut self, len: usize) -> (&mut [f64], &mut [f64], &mut [f64], &mut [f64]) {
        let [a, b, c, d] = &mut self.bufs;
        (
            Self::lease(a, len),
            Self::lease(b, len),
            Self::lease(c, len),
            Self::lease(d, len),
        )
    }
}

thread_local! {
    static SCRATCH_POOL: std::cell::RefCell<Vec<Scratch>> = std::cell::RefCell::new(Vec::new());
}

/// Run `f` with a scratch arena leased from this thread's pool. Nested
/// calls receive distinct arenas; every arena is returned for reuse, so
/// each OS thread converges on a fixed working set and the steady state
/// allocates nothing.
pub fn with_scratch<R>(f: impl FnOnce(&mut Scratch) -> R) -> R {
    let mut s = SCRATCH_POOL
        .with(|p| p.borrow_mut().pop())
        .unwrap_or_else(Scratch::new);
    let r = f(&mut s);
    SCRATCH_POOL.with(|p| p.borrow_mut().push(s));
    r
}

// ---------------------------------------------------------------------------
// The worker pool
// ---------------------------------------------------------------------------

/// A unit of pool work. Borrowing closures are fine: [`ExecCtx::run_jobs`]
/// does not return until every submitted job has finished.
pub type Job<'env> = Box<dyn FnOnce() + Send + 'env>;

struct Latch {
    remaining: Mutex<usize>,
    cv: Condvar,
    panicked: AtomicBool,
}

struct Task {
    job: Job<'static>,
    latch: Arc<Latch>,
}

struct Shared {
    queue: Mutex<VecDeque<Task>>,
    cv: Condvar,
    shutdown: AtomicBool,
}

/// Shared execution context: a fixed worker pool every layer submits to.
pub struct ExecCtx {
    shared: Arc<Shared>,
    handles: Mutex<Vec<std::thread::JoinHandle<()>>>,
    workers: usize,
}

impl ExecCtx {
    /// Pool with `workers` OS threads (tests; production uses
    /// [`ExecCtx::global`]).
    pub fn new(workers: usize) -> ExecCtx {
        let workers = workers.max(1);
        let shared = Arc::new(Shared {
            queue: Mutex::new(VecDeque::new()),
            cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
        });
        let mut handles = Vec::with_capacity(workers);
        for _ in 0..workers {
            let shared = Arc::clone(&shared);
            handles.push(std::thread::spawn(move || worker_loop(shared)));
        }
        ExecCtx { shared, handles: Mutex::new(handles), workers }
    }

    /// The process-wide pool (sized by `HCLFFT_POOL_THREADS` or the
    /// machine's available parallelism), created on first use and kept
    /// for the process lifetime. An unparsable or zero
    /// `HCLFFT_POOL_THREADS` warns to stderr and falls back to the
    /// machine default — a silently ignored override would misreport
    /// every thread-budget experiment built on top of it.
    pub fn global() -> &'static ExecCtx {
        static CTX: OnceLock<ExecCtx> = OnceLock::new();
        CTX.get_or_init(|| {
            let machine_default =
                || std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
            let workers = match std::env::var("HCLFFT_POOL_THREADS") {
                Ok(v) => match v.trim().parse::<usize>() {
                    Ok(w) if w >= 1 => w,
                    Ok(_) => {
                        eprintln!(
                            "warning: HCLFFT_POOL_THREADS=0 is not a valid pool size; \
                             using the machine default"
                        );
                        machine_default()
                    }
                    Err(_) => {
                        eprintln!(
                            "warning: HCLFFT_POOL_THREADS=`{v}` is not a positive integer; \
                             using the machine default"
                        );
                        machine_default()
                    }
                },
                Err(_) => machine_default(),
            };
            ExecCtx::new(workers)
        })
    }

    /// Number of pool worker threads.
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Run the jobs to completion on the pool (the caller helps execute
    /// queued work while it waits, so jobs may themselves call
    /// `run_jobs` without deadlocking a fully busy pool). Panics if any
    /// job panicked.
    pub fn run_jobs<'env>(&self, jobs: Vec<Job<'env>>) {
        if jobs.is_empty() {
            return;
        }
        if jobs.len() == 1 {
            // nothing to overlap — run inline, skip the queue round-trip
            let mut jobs = jobs;
            (jobs.pop().unwrap())();
            return;
        }
        let latch = Arc::new(Latch {
            remaining: Mutex::new(jobs.len()),
            cv: Condvar::new(),
            panicked: AtomicBool::new(false),
        });
        {
            let mut q = self.shared.queue.lock().unwrap();
            for job in jobs {
                // SAFETY: the loop below does not let this call return
                // until the latch reaches zero, and the latch is only
                // decremented *after* a job has finished running (panics
                // included — jobs run under catch_unwind). Hence every
                // 'env borrow captured by the jobs strictly outlives
                // their execution, and erasing the lifetime for the
                // queue is sound.
                let job: Job<'static> =
                    unsafe { std::mem::transmute::<Job<'env>, Job<'static>>(job) };
                q.push_back(Task { job, latch: Arc::clone(&latch) });
            }
        }
        self.shared.cv.notify_all();
        loop {
            {
                let rem = latch.remaining.lock().unwrap();
                if *rem == 0 {
                    break;
                }
            }
            // help: drain queued tasks (ours or anyone's) instead of
            // sleeping — the fixed pool stays deadlock-free under nested
            // run_jobs because every waiter makes progress itself
            let task = self.shared.queue.lock().unwrap().pop_front();
            match task {
                Some(t) => run_task(t),
                None => {
                    // everything still pending is running on other
                    // threads; their completion notifies the latch. The
                    // timeout is defensive only.
                    let rem = latch.remaining.lock().unwrap();
                    if *rem > 0 {
                        let _ = latch.cv.wait_timeout(rem, Duration::from_millis(10)).unwrap();
                    }
                }
            }
        }
        if latch.panicked.load(Ordering::Acquire) {
            panic!("ExecCtx job panicked");
        }
    }
}

impl Drop for ExecCtx {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::Release);
        {
            // take the queue lock so no worker is between a failed pop
            // and its cv wait when we notify
            let _q = self.shared.queue.lock().unwrap();
            self.shared.cv.notify_all();
        }
        for h in self.handles.lock().unwrap().drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(shared: Arc<Shared>) {
    loop {
        let task = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                if let Some(t) = q.pop_front() {
                    break Some(t);
                }
                if shared.shutdown.load(Ordering::Acquire) {
                    break None;
                }
                q = shared.cv.wait(q).unwrap();
            }
        };
        match task {
            Some(t) => run_task(t),
            None => return,
        }
    }
}

fn run_task(task: Task) {
    let Task { job, latch } = task;
    if catch_unwind(AssertUnwindSafe(job)).is_err() {
        latch.panicked.store(true, Ordering::Release);
    }
    let mut rem = latch.remaining.lock().unwrap();
    *rem -= 1;
    if *rem == 0 {
        latch.cv.notify_all();
    }
}

// ---------------------------------------------------------------------------
// The row-FFT executor
// ---------------------------------------------------------------------------

/// Minimum row length for splitting a *single* row across stage
/// sub-ranges: below this the per-stage barrier costs more than the
/// parallelism pays.
pub const STAGE_PARALLEL_MIN_N: usize = 4096;

/// The single row-FFT entry point: transform `rows` rows of length `n`
/// stored contiguously in split planes, using up to `parallelism`
/// concurrent chunks on the shared pool. 5-smooth lengths run the
/// mixed-radix kernel; everything else falls back to Bluestein. When
/// the batch has fewer rows than the thread budget and the rows are
/// long, work is split *within* rows (per-stage sub-ranges) instead of
/// silently clamping to `rows` chunks.
pub fn fft_rows_pooled(
    ctx: &ExecCtx,
    re: &mut [f64],
    im: &mut [f64],
    rows: usize,
    n: usize,
    dir: Direction,
    parallelism: usize,
) {
    if rows == 0 || n == 0 {
        return;
    }
    debug_assert_eq!(re.len(), rows * n);
    let parallelism = parallelism.max(1);
    let plan = PlanCache::global().row_plan(n);

    if parallelism == 1 {
        with_scratch(|s| fft_rows_chunk(&plan, re, im, rows, n, dir, s));
        return;
    }

    if splits_within_rows(rows, n, parallelism) {
        if let RowPlan::Radix(rp) = &plan {
            for r in 0..rows {
                let span = r * n..(r + 1) * n;
                fft_row_radix_pooled(ctx, &mut re[span.clone()], &mut im[span], rp, dir, parallelism);
            }
            return;
        }
    }

    let chunks = parallelism.min(rows);
    let rows_per = rows.div_ceil(chunks);
    let mut jobs: Vec<Job> = Vec::with_capacity(chunks);
    for (rc, ic) in re.chunks_mut(rows_per * n).zip(im.chunks_mut(rows_per * n)) {
        let plan = plan.clone();
        jobs.push(Box::new(move || {
            let r = rc.len() / n;
            with_scratch(|s| fft_rows_chunk(&plan, rc, ic, r, n, dir, s));
        }));
    }
    ctx.run_jobs(jobs);
}

/// The dispatch predicate shared by [`fft_rows_pooled`] and
/// [`work_units`]: split *within* rows (per-stage sub-ranges) when the
/// batch has fewer long smooth rows than the thread budget.
fn splits_within_rows(rows: usize, n: usize, parallelism: usize) -> bool {
    rows < parallelism && n >= STAGE_PARALLEL_MIN_N && radix::is_five_smooth(n)
}

/// How many concurrent work units `fft_rows_pooled` produces — the
/// chunking policy, exposed for the under-utilization regression test.
pub fn work_units(rows: usize, n: usize, parallelism: usize) -> usize {
    let parallelism = parallelism.max(1);
    if rows == 0 || n == 0 || parallelism == 1 {
        return 1;
    }
    if splits_within_rows(rows, n, parallelism) {
        return parallelism; // per-stage sub-ranges inside each row
    }
    parallelism.min(rows)
}

// ---------------------------------------------------------------------------
// Multi-row tile model
// ---------------------------------------------------------------------------

/// Candidate multi-row tile widths for the stage-major radix driver
/// ([`radix::fft_rows_radix_tiled`]): 1 (per-row), 2, 4.
pub const ROW_TILE_CANDIDATES: [usize; 3] = [1, 2, 4];

/// Per-core cache budget (bytes) the tile model plans against: the
/// tile's working set (4 ping-pong planes per row) should stay resident
/// across a stage pass. 256 KiB is a conservative per-core L2 slice.
const ROW_TILE_CACHE_BUDGET: usize = 256 << 10;

/// Model surface for the multi-row tile width at row length `n`: a
/// [`Curve`](crate::model::surface::Curve) over the candidate widths,
/// scored by modeled per-row memory traffic. One stage pass moves
/// `32·n` bytes of row data per row (read + write, both planes) plus a
/// `~16·n`-byte twiddle stream that a W-row tile amortizes W ways; a
/// tile whose working set (`32·n·W` bytes) overflows the per-core cache
/// budget is penalized by the overflow ratio. The same `PerfModel`
/// surface shape (monotone xs, positive speeds) the planner uses
/// everywhere, so tile choice stays model-driven rather than a
/// hardcoded constant.
pub fn row_tile_curve(n: usize) -> crate::model::surface::Curve {
    let n = n.max(1);
    let mut speeds = Vec::with_capacity(ROW_TILE_CANDIDATES.len());
    for &w in &ROW_TILE_CANDIDATES {
        let data = 32.0 * n as f64; // per-row plane traffic per pass
        let twiddle = 16.0 * n as f64 / w as f64; // amortized over the tile
        let footprint = 32.0 * n as f64 * w as f64;
        let over = (footprint / ROW_TILE_CACHE_BUDGET as f64).max(1.0);
        speeds.push(1.0 / ((data + twiddle) * over));
    }
    crate::model::surface::Curve::new(ROW_TILE_CANDIDATES.to_vec(), speeds)
}

/// Resolve a raw `HCLFFT_ROW_TILE` value: parse it (clamped to 1..=8),
/// or warn to stderr and fall back to the model/measured choice — the
/// same parse-fallback contract as `HCLFFT_POOL_THREADS` and
/// `HCLFFT_PIPELINE`, with distinct zero vs non-integer messages.
/// Factored out of [`preferred_row_tile`]'s OnceLock init so the
/// fallback path is unit-testable without racing on the cached
/// override or the ambient environment.
fn row_tile_from_env_value(v: &str) -> Option<usize> {
    match v.trim().parse::<usize>() {
        Ok(w) if w >= 1 => Some(w.min(8)),
        Ok(_) => {
            eprintln!(
                "warning: HCLFFT_ROW_TILE=0 is not a valid tile width; \
                 using the model-preferred tile width"
            );
            None
        }
        Err(_) => {
            eprintln!(
                "warning: HCLFFT_ROW_TILE=`{v}` is not a positive integer; \
                 using the model-preferred tile width"
            );
            None
        }
    }
}

/// The cached `HCLFFT_ROW_TILE` experiment override, if any.
fn row_tile_env_override() -> Option<usize> {
    static OVERRIDE: OnceLock<Option<usize>> = OnceLock::new();
    *OVERRIDE.get_or_init(|| match std::env::var("HCLFFT_ROW_TILE") {
        Ok(v) => row_tile_from_env_value(&v),
        Err(_) => None,
    })
}

/// The tile width the model prefers at row length `n` (argmax of
/// [`row_tile_curve`]; `HCLFFT_ROW_TILE` overrides for experiments,
/// clamped to 1..=8 — an unparsable or zero value warns and falls back
/// to the model, matching the `HCLFFT_POOL_THREADS` policy). This is
/// the purely *modeled* answer; execution paths consult
/// [`effective_row_tile`], which lets a measured calibration win.
pub fn preferred_row_tile(n: usize) -> usize {
    if let Some(w) = row_tile_env_override() {
        return w;
    }
    let curve = row_tile_curve(n);
    let mut best = (1usize, f64::MIN);
    for (&w, &s) in curve.xs.iter().zip(&curve.speeds) {
        if s > best.1 {
            best = (w, s);
        }
    }
    best.0
}

// ---------------------------------------------------------------------------
// Measured tile-width calibration
// ---------------------------------------------------------------------------

/// Tile widths the measured calibration times: the model's candidates
/// plus 8, so a machine whose cache comfortably holds wider tiles can
/// beat the conservative modeled budget.
pub const ROW_TILE_MEASURE_CANDIDATES: [usize; 4] = [1, 2, 4, 8];

/// The process-wide measured tile-width cache, keyed by row length.
/// Widths never change output bits (the tiled driver is bit-identical
/// to per-row in every generation), so this cache affects speed only.
/// Seeded from wisdom at service build, filled by
/// [`calibrate_row_tile`] on cold plans, cleared per length when the
/// online model reports memory-class drift. Kernel-generation staleness
/// is handled at the wisdom layer: within one process the generation
/// cannot change.
fn measured_tiles() -> &'static Mutex<std::collections::BTreeMap<usize, usize>> {
    static TILES: OnceLock<Mutex<std::collections::BTreeMap<usize, usize>>> = OnceLock::new();
    TILES.get_or_init(|| Mutex::new(std::collections::BTreeMap::new()))
}

/// The measured tile width for row length `n`, if one is cached.
pub fn measured_row_tile(n: usize) -> Option<usize> {
    measured_tiles().lock().unwrap().get(&n).copied()
}

/// Record a measured tile width for row length `n` (wisdom seeding /
/// calibration). Zero is ignored; widths clamp to the 1..=8 range the
/// execution paths accept.
pub fn set_measured_row_tile(n: usize, width: usize) {
    if width >= 1 {
        measured_tiles().lock().unwrap().insert(n, width.min(8));
    }
}

/// Drop the measured tile width for row length `n` (memory-class drift
/// invalidation — the next cold plan re-calibrates).
pub fn clear_measured_row_tile(n: usize) {
    measured_tiles().lock().unwrap().remove(&n);
}

/// One-shot micro-calibration: time the stage-major tiled driver at
/// each [`ROW_TILE_MEASURE_CANDIDATES`] width over a small synthetic
/// batch, cache and return the fastest. Returns the cached winner
/// without re-measuring when one exists; Bluestein lengths return 1
/// (their kernel is per-row, so width cannot matter). Best-of-3 trials
/// per arm keeps scheduler noise out of the winner; the whole sweep is
/// a few hundred microseconds at paper sizes — cold-plan-path cost,
/// amortized by the wisdom store across processes.
pub fn calibrate_row_tile(n: usize) -> usize {
    if n == 0 {
        return 1;
    }
    if let Some(w) = measured_row_tile(n) {
        return w;
    }
    let row_plan = PlanCache::global().row_plan(n);
    let RowPlan::Radix(plan) = &row_plan else {
        set_measured_row_tile(n, 1);
        return 1;
    };
    let rows = 8usize; // one full pass per candidate width divides 8
    let iters = (32_768 / n).clamp(1, 32);
    let mut re = vec![0.0f64; rows * n];
    let mut im = vec![0.0f64; rows * n];
    for (i, v) in re.iter_mut().enumerate() {
        *v = (i % 17) as f64 * 0.125 - 1.0;
    }
    for (i, v) in im.iter_mut().enumerate() {
        *v = (i % 13) as f64 * 0.0625 - 0.5;
    }
    let mut best = (preferred_row_tile(n), f64::INFINITY);
    with_scratch(|scratch| {
        for &w in &ROW_TILE_MEASURE_CANDIDATES {
            let (sr, si) = scratch.pair(w * n);
            let mut arm = f64::INFINITY;
            for _ in 0..3 {
                let t0 = std::time::Instant::now();
                for _ in 0..iters {
                    let mut r = 0;
                    while r < rows {
                        let t = w.min(rows - r);
                        let span = r * n..(r + t) * n;
                        radix::fft_rows_radix_tiled(
                            &mut re[span.clone()],
                            &mut im[span],
                            t,
                            sr,
                            si,
                            plan,
                            Direction::Forward,
                        );
                        r += t;
                    }
                }
                arm = arm.min(t0.elapsed().as_secs_f64());
            }
            if arm < best.1 {
                best = (w, arm);
            }
        }
    });
    set_measured_row_tile(n, best.0);
    best.0
}

/// The tile width the execution paths actually use at row length `n`:
/// the `HCLFFT_ROW_TILE` experiment override when set, else the
/// measured calibration winner when one is cached, else the modeled
/// [`preferred_row_tile`] choice. Never changes output bits — only
/// which loop order computes them.
pub fn effective_row_tile(n: usize) -> usize {
    if let Some(w) = row_tile_env_override() {
        return w;
    }
    if let Some(w) = measured_row_tile(n) {
        return w;
    }
    preferred_row_tile(n)
}

/// One worker's serial chunk: `rows` rows with the per-thread arena.
/// Smooth rows advance through the stage-major multi-row driver in
/// tiles of the effective width — measured when a calibration exists,
/// modeled otherwise (identical bits to per-row either way).
fn fft_rows_chunk(
    plan: &RowPlan,
    re: &mut [f64],
    im: &mut [f64],
    rows: usize,
    n: usize,
    dir: Direction,
    scratch: &mut Scratch,
) {
    match plan {
        RowPlan::Radix(p) => {
            let tile = effective_row_tile(n).min(rows.max(1));
            let (sr, si) = scratch.pair(tile * n);
            let mut r = 0;
            while r < rows {
                let w = tile.min(rows - r);
                let span = r * n..(r + w) * n;
                radix::fft_rows_radix_tiled(&mut re[span.clone()], &mut im[span], w, sr, si, p, dir);
                r += w;
            }
        }
        RowPlan::Bluestein(p) => {
            let mlen = p.scratch_len();
            let (br, bi, sr, si) = scratch.quad(mlen);
            for r in 0..rows {
                let span = r * n..(r + 1) * n;
                fft_row_bluestein(&mut re[span.clone()], &mut im[span], p, dir, br, bi, sr, si);
            }
        }
    }
}

/// Transform one long row by splitting every DIF stage's butterfly
/// range across `tasks` pool jobs (a barrier per stage). Stage output
/// blocks are disjoint per range, so the split is plain `split_at_mut`
/// and the arithmetic — hence the bits — match the serial kernel.
///
/// Limitation: only the butterfly index `p` is split, so late stages
/// with fewer than `tasks` butterflies (the last has `m == 1`)
/// under-fill the pool — Amdahl caps the speedup below the full thread
/// budget. Splitting the `q` lane range inside a butterfly would lift
/// that (still disjoint dst) and is left for a later perf PR.
fn fft_row_radix_pooled(
    ctx: &ExecCtx,
    re: &mut [f64],
    im: &mut [f64],
    plan: &RadixPlan,
    dir: Direction,
    tasks: usize,
) {
    let n = plan.n;
    debug_assert_eq!(re.len(), n);
    with_scratch(|scratch| {
        let (sr, si) = scratch.pair(n);
        let mut in_src = true;
        for stage in &plan.stages {
            let m = stage.butterflies();
            let step = m.div_ceil(tasks).max(1);
            let unit = stage.radix * stage.stride; // dst elems per butterfly
            {
                let (src_re, src_im, dst_re, dst_im): (&[f64], &[f64], &mut [f64], &mut [f64]) =
                    if in_src {
                        (&*re, &*im, &mut *sr, &mut *si)
                    } else {
                        (&*sr, &*si, &mut *re, &mut *im)
                    };
                let mut jobs: Vec<Job> = Vec::with_capacity(m.div_ceil(step));
                let mut rest_re = dst_re;
                let mut rest_im = dst_im;
                let mut p0 = 0usize;
                while p0 < m {
                    let p1 = (p0 + step).min(m);
                    let (out_re, next_re) = rest_re.split_at_mut((p1 - p0) * unit);
                    let (out_im, next_im) = rest_im.split_at_mut((p1 - p0) * unit);
                    rest_re = next_re;
                    rest_im = next_im;
                    jobs.push(Box::new(move || {
                        radix::apply_stage_range(stage, dir, src_re, src_im, out_re, out_im, p0, p1);
                    }));
                    p0 = p1;
                }
                ctx.run_jobs(jobs);
            }
            in_src = !in_src;
        }
        // fused tail codelet (or legacy copy for tail-less plans): a
        // single serial pass — it is one cheap sweep over the row, so
        // splitting it is not worth a barrier (Amdahl note above)
        radix::finish_tail(plan, dir, re, im, sr, si, in_src);
    });
    if dir == Direction::Inverse {
        let inv_n = 1.0 / n as f64;
        for v in re.iter_mut() {
            *v *= inv_n;
        }
        for v in im.iter_mut() {
            *v *= inv_n;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dft::{naive_dft_rows, SignalMatrix};

    #[test]
    fn pool_runs_jobs_and_reports_size() {
        let ctx = ExecCtx::new(3);
        assert_eq!(ctx.workers(), 3);
        let hits: Vec<AtomicUsize> = (0..8).map(|_| AtomicUsize::new(0)).collect();
        let mut jobs: Vec<Job> = Vec::new();
        for h in &hits {
            jobs.push(Box::new(move || {
                h.fetch_add(1, Ordering::Relaxed);
            }));
        }
        ctx.run_jobs(jobs);
        for h in &hits {
            assert_eq!(h.load(Ordering::Relaxed), 1);
        }
    }

    #[test]
    fn nested_run_jobs_does_not_deadlock() {
        let ctx = ExecCtx::new(1); // single worker forces helping
        let total = AtomicUsize::new(0);
        let mut jobs: Vec<Job> = Vec::new();
        for _ in 0..4 {
            let ctx = &ctx;
            let total = &total;
            jobs.push(Box::new(move || {
                let mut inner: Vec<Job> = Vec::new();
                for _ in 0..3 {
                    inner.push(Box::new(move || {
                        total.fetch_add(1, Ordering::Relaxed);
                    }));
                }
                ctx.run_jobs(inner);
            }));
        }
        ctx.run_jobs(jobs);
        assert_eq!(total.load(Ordering::Relaxed), 12);
    }

    #[test]
    #[should_panic(expected = "ExecCtx job panicked")]
    fn job_panic_propagates() {
        let ctx = ExecCtx::new(2);
        let jobs: Vec<Job> = vec![
            Box::new(|| {}),
            Box::new(|| panic!("boom")),
        ];
        ctx.run_jobs(jobs);
    }

    #[test]
    fn pooled_rows_match_naive() {
        let ctx = ExecCtx::new(4);
        for &n in &[24usize, 64, 100, 384] {
            let orig = SignalMatrix::random(6, n, n as u64);
            let mut m = orig.clone();
            fft_rows_pooled(&ctx, &mut m.re, &mut m.im, 6, n, Direction::Forward, 4);
            let want = naive_dft_rows(&orig, false);
            let scale = want.norm().max(1.0);
            assert!(
                m.max_abs_diff(&want) / scale < 1e-9,
                "n={n}: {}",
                m.max_abs_diff(&want) / scale
            );
        }
    }

    #[test]
    fn pooled_rows_thread_count_invariant_bitwise() {
        let ctx = ExecCtx::new(4);
        let orig = SignalMatrix::random(10, 360, 5); // 360 = 2^3·3^2·5
        let mut a = orig.clone();
        let mut b = orig.clone();
        fft_rows_pooled(&ctx, &mut a.re, &mut a.im, 10, 360, Direction::Forward, 1);
        fft_rows_pooled(&ctx, &mut b.re, &mut b.im, 10, 360, Direction::Forward, 7);
        assert_eq!(a.max_abs_diff(&b), 0.0);
    }

    #[test]
    fn stage_parallel_single_row_bitwise_matches_serial() {
        let ctx = ExecCtx::new(4);
        let n = STAGE_PARALLEL_MIN_N; // pow2, eligible
        let orig = SignalMatrix::random(1, n, 9);
        let mut serial = orig.clone();
        fft_rows_pooled(&ctx, &mut serial.re, &mut serial.im, 1, n, Direction::Forward, 1);
        let mut par = orig.clone();
        fft_rows_pooled(&ctx, &mut par.re, &mut par.im, 1, n, Direction::Forward, 4);
        assert_eq!(serial.max_abs_diff(&par), 0.0, "stage-split must be bit-exact");
        // and it is actually correct, not just self-consistent
        let mut back = par.clone();
        fft_rows_pooled(&ctx, &mut back.re, &mut back.im, 1, n, Direction::Inverse, 4);
        assert!(back.max_abs_diff(&orig) < 1e-10);
    }

    #[test]
    fn work_units_split_within_rows() {
        // the old clamp would report min(rows, threads) = 2
        assert_eq!(work_units(2, STAGE_PARALLEL_MIN_N, 8), 8);
        assert_eq!(work_units(2, 64, 8), 2); // short rows: clamp is right
        assert_eq!(work_units(64, 1024, 8), 8);
        assert_eq!(work_units(64, 1024, 1), 1);
        // non-smooth long rows stay row-chunked (Bluestein is serial per row)
        assert_eq!(work_units(2, 4096 + 1, 8), 2);
    }

    #[test]
    fn row_tile_model_prefers_multirow_at_paper_sizes() {
        // twiddle amortization wins while the tile fits the cache budget
        for &n in &[384usize, 640, 1152] {
            assert_eq!(preferred_row_tile(n), 4, "n={n}");
        }
        // a huge row overflows the budget at width 4 → narrower tiles
        assert!(preferred_row_tile(1 << 20) <= 2);
        // the curve is a valid model surface over the candidate widths
        let c = row_tile_curve(384);
        assert_eq!(c.xs, ROW_TILE_CANDIDATES.to_vec());
        assert!(c.speeds.iter().all(|&s| s > 0.0));
        assert!(c.speed_nearest(4) >= c.speed_nearest(1));
    }

    #[test]
    fn row_tile_env_value_warns_and_falls_back() {
        // regression: a zero or unparsable HCLFFT_ROW_TILE must take the
        // same warn-to-stderr fallback route as HCLFFT_POOL_THREADS /
        // HCLFFT_PIPELINE — never a silent ignore. The helper is
        // exercised directly so this test cannot race the OnceLock
        // cache or the ambient environment.
        assert_eq!(row_tile_from_env_value("bogus"), None);
        assert_eq!(row_tile_from_env_value(""), None);
        assert_eq!(row_tile_from_env_value("0"), None);
        assert_eq!(row_tile_from_env_value("-3"), None);
        // parsable values pass through (trimmed, clamped to 1..=8)
        assert_eq!(row_tile_from_env_value("4"), Some(4));
        assert_eq!(row_tile_from_env_value(" 2 "), Some(2));
        assert_eq!(row_tile_from_env_value("64"), Some(8));
    }

    #[test]
    fn measured_tile_cache_overrides_model() {
        // distinct n so parallel tests sharing the process-global cache
        // never collide; tile width cannot change bits, so even a
        // collision would only change speed
        let n = 9999;
        assert_eq!(measured_row_tile(n), None);
        assert_eq!(effective_row_tile(n), preferred_row_tile(n));
        set_measured_row_tile(n, 2);
        assert_eq!(effective_row_tile(n), 2);
        set_measured_row_tile(n, 64); // clamped like the env override
        assert_eq!(effective_row_tile(n), 8);
        set_measured_row_tile(n, 0); // ignored
        assert_eq!(effective_row_tile(n), 8);
        clear_measured_row_tile(n);
        assert_eq!(effective_row_tile(n), preferred_row_tile(n));
    }

    #[test]
    fn calibration_measures_caches_and_clears() {
        let n = 30; // 5-smooth, unused by other tests
        clear_measured_row_tile(n);
        let w = calibrate_row_tile(n);
        assert!(
            ROW_TILE_MEASURE_CANDIDATES.contains(&w),
            "winner {w} not a candidate"
        );
        assert_eq!(measured_row_tile(n), Some(w), "winner must be cached");
        assert_eq!(effective_row_tile(n), w);
        // re-calibration is a cache hit, not a re-measure
        assert_eq!(calibrate_row_tile(n), w);
        clear_measured_row_tile(n);
        assert_eq!(measured_row_tile(n), None);
        // Bluestein lengths pin width 1: the kernel is per-row
        let nb = 4099; // prime
        clear_measured_row_tile(nb);
        assert_eq!(calibrate_row_tile(nb), 1);
        clear_measured_row_tile(nb);
    }

    #[test]
    fn scratch_arenas_reused() {
        // private-field access: verify leases reuse the retained buffers
        // (the global grow counter is asserted by the single-test binary
        // `rust/tests/exec_steadystate.rs`, which has no concurrent noise)
        let mut s = Scratch::new();
        let first = {
            let (a, b) = s.pair(128);
            a[0] = 1.0;
            b[127] = 2.0;
            (a.as_ptr() as usize, b.as_ptr() as usize)
        };
        for _ in 0..5 {
            let (a, b) = s.pair(128);
            assert_eq!(a[0], 0.0, "lease must re-zero");
            assert_eq!(
                (a.as_ptr() as usize, b.as_ptr() as usize),
                first,
                "same-size lease must not reallocate"
            );
        }
        let (a, _b, c, _d) = s.quad(512);
        assert_eq!((a.len(), c.len()), (512, 512));
    }
}
