//! Fused tiled 2D pipeline — tile-granular stage scheduling that
//! replaces the global transpose barriers.
//!
//! The four-step skeleton (row FFTs → transpose → row FFTs → transpose)
//! spends two of its four matrix passes in transposes: pure memory
//! traffic that exists only to make the column FFTs contiguous. The
//! fused pipeline removes both barriers by running the column FFTs
//! *directly on row-major storage*: each column tile is transposed into
//! a per-thread [`crate::dft::exec::Scratch`] arena (the per-tile
//! transpose doubles as
//! the padded-plan gather, so padding becomes a stride choice in the
//! tile, not a whole-matrix `pad_cols` copy), transformed with the same
//! row kernel, and scattered back — the tile stays cache-resident
//! through gather → FFT → scatter, and the matrix is touched twice per
//! 2D transform instead of four times.
//!
//! The per-tile gather/scatter itself is the memory-bound half of the
//! fused transform (the phase-resolved model classifies it as such), so
//! on AVX2 machines it runs through the in-register 4×4/8×8 transpose
//! kernels of [`crate::dft::simd`]: strided scalar element moves become
//! unit-stride vector loads along source rows and vector stores along
//! tile rows. The scalar loops remain as the runtime-detected fallback
//! and as the A/B reference arm ([`set_col_tile_force_scalar`]); both
//! paths are pure data movement, so they are bit-identical in every
//! kernel generation.
//!
//! Three pieces live here:
//!
//! * [`PipelineMode`] — fused vs barrier selection, with a process-wide
//!   default (CLI `--pipeline`, env `HCLFFT_PIPELINE`). The barrier
//!   path is kept as a first-class fallback and as the bit-exactness
//!   oracle: both modes run the same per-row kernel over the same
//!   logical vectors, so their outputs are bit-identical.
//! * [`StageDag`] — a small dependency-counting task scheduler on the
//!   shared [`ExecCtx`] pool: a tile task becomes ready the moment its
//!   predecessors finish, so in a batched execution one matrix's column
//!   tiles run while the next matrix's row tiles are still in flight —
//!   no per-phase join barrier across the batch. Execution order never
//!   affects values (tiles own disjoint index sets), so results are
//!   bit-identical for every worker count and schedule.
//! * [`fft_cols_fused`] — the fused column phase over the native
//!   substrate, used by [`crate::dft::dft2d::dft2d`]. The
//!   engine-dispatching drivers build their tiles in
//!   [`crate::coordinator::plan::ExecPipeline`] instead, on top of the
//!   same scheduler.

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU8, Ordering};
use std::sync::{Condvar, Mutex};

use crate::dft::exec::{fft_rows_pooled, with_scratch, ExecCtx, Job};
use crate::dft::fft::Direction;
use crate::dft::simd;
use crate::dft::SignalMatrix;

// ---------------------------------------------------------------------------
// Pipeline mode
// ---------------------------------------------------------------------------

/// How the two FFT phases of a 2D transform are executed.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PipelineMode {
    /// Tile-granular fused pipeline: strided column FFTs via per-tile
    /// transposes into scratch — no whole-matrix transpose passes.
    Fused,
    /// The paper's four-step skeleton with full-matrix transpose
    /// barriers between phases (the pre-pipeline behaviour; kept as a
    /// fallback and as the bit-exactness oracle).
    Barrier,
}

impl PipelineMode {
    /// Parse a CLI/env value ("fused" | "barrier").
    pub fn parse(s: &str) -> Option<PipelineMode> {
        match s.trim().to_ascii_lowercase().as_str() {
            "fused" => Some(PipelineMode::Fused),
            "barrier" => Some(PipelineMode::Barrier),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            PipelineMode::Fused => "fused",
            PipelineMode::Barrier => "barrier",
        }
    }
}

const MODE_UNSET: u8 = 0;
const MODE_FUSED: u8 = 1;
const MODE_BARRIER: u8 = 2;

/// Process-wide default mode consulted by the implicit entry points
/// (`dft2d`, the PFFT drivers, `PlannedTransform::execute`). Explicit
/// `*_with_mode` variants ignore it — tests use those so concurrent
/// test threads never race on this global.
static DEFAULT_MODE: AtomicU8 = AtomicU8::new(MODE_UNSET);

/// Override the process default (the CLI's `--pipeline` flag).
pub fn set_default_mode(mode: PipelineMode) {
    let v = match mode {
        PipelineMode::Fused => MODE_FUSED,
        PipelineMode::Barrier => MODE_BARRIER,
    };
    DEFAULT_MODE.store(v, Ordering::Relaxed);
}

/// Resolve a raw `HCLFFT_PIPELINE` value: parse it, or warn to stderr
/// (the same contract as `ExecCtx::global()`'s `HCLFFT_POOL_THREADS`
/// warning — a silently ignored override would misreport every
/// pipeline A/B built on top of it) and fall back to the fused default.
/// Factored out of [`default_mode`] so the fallback path is unit-
/// testable without racing on the process-global cache or the ambient
/// environment.
fn mode_from_env_value(v: &str) -> PipelineMode {
    PipelineMode::parse(v).unwrap_or_else(|| {
        eprintln!(
            "warning: HCLFFT_PIPELINE=`{v}` is not `fused` or `barrier`; \
             using the fused pipeline"
        );
        PipelineMode::Fused
    })
}

/// The current process default: an explicit [`set_default_mode`] value,
/// else `HCLFFT_PIPELINE` (fused|barrier) from the environment, else
/// fused. Unparsable env values warn once and fall back to fused.
pub fn default_mode() -> PipelineMode {
    match DEFAULT_MODE.load(Ordering::Relaxed) {
        MODE_FUSED => PipelineMode::Fused,
        MODE_BARRIER => PipelineMode::Barrier,
        _ => {
            let mode = match std::env::var("HCLFFT_PIPELINE") {
                Ok(v) => mode_from_env_value(&v),
                Err(_) => PipelineMode::Fused,
            };
            set_default_mode(mode);
            mode
        }
    }
}

// ---------------------------------------------------------------------------
// Tile geometry defaults
// ---------------------------------------------------------------------------

/// Rows per row-stage tile. Small enough that a partition's row range
/// fans out across the whole pool; large enough that per-tile dispatch
/// overhead stays negligible against an FFT over `tile × n` points.
/// Orthogonal to the *kernel-level* multi-row tiling
/// ([`crate::dft::exec::preferred_row_tile`], 2–4 rows per
/// register-resident stage pass): this constant parallelizes dispatch
/// across the pool, while the kernel tile amortizes twiddle streams
/// inside one worker's chunk — a 32-row dispatch tile executes as eight
/// 4-row kernel tiles.
pub const DEFAULT_ROW_TILE: usize = 32;

/// Columns per column-stage tile: each source row contributes one
/// contiguous 32-value read during the per-tile transpose while the
/// write side fans out over 32 streams (well inside the L1 line
/// budget — the same blocking argument as the paper's Appendix A
/// transpose), a tile of a paper-size matrix stays L2-resident through
/// gather → FFT → scatter, and N = 640 still yields 20 column tasks to
/// keep a wide pool busy.
pub const DEFAULT_COL_TILE: usize = 32;

/// When set, [`gather_col_tile`]/[`scatter_col_tile`] skip the AVX2
/// in-register transpose kernels and run their scalar strided loops —
/// the A/B switch the `colphase_scalar_vs_simd_*` bench arms and the
/// bit-identity property test flip. Scalar and SIMD tile moves are pure
/// data movement either way, so this toggle can never change values,
/// only speed.
static COL_TILE_FORCE_SCALAR: AtomicBool = AtomicBool::new(false);

/// Force (or stop forcing) the scalar column-tile gather/scatter path.
pub fn set_col_tile_force_scalar(on: bool) {
    COL_TILE_FORCE_SCALAR.store(on, Ordering::Relaxed);
}

/// Does the column-tile gather/scatter currently take the AVX2
/// in-register transpose path? `false` on non-AVX2 machines, builds
/// without `--features simd`, or under [`set_col_tile_force_scalar`].
pub fn col_tile_simd_active() -> bool {
    simd::avx2_enabled() && !COL_TILE_FORCE_SCALAR.load(Ordering::Relaxed)
}

/// A raw split-plane pointer that pipeline tasks share. SAFETY contract
/// (upheld by every constructor in this crate): tasks built over one
/// `SendPtr` touch pairwise-disjoint index sets, or are ordered by
/// [`StageDag`] edges (completion of a predecessor happens-before a
/// dependent starts — the scheduler hands dependents out under the same
/// mutex the predecessor's completion updates), and the DAG's `run`
/// does not return before every task finished.
#[derive(Clone, Copy)]
pub struct SendPtr(pub *mut f64);
// SAFETY: see the contract above — disjointness or DAG ordering makes
// the aliasing sound, and the borrow the pointer was created from
// outlives the scheduler run.
unsafe impl Send for SendPtr {}

// ---------------------------------------------------------------------------
// The stage-DAG scheduler
// ---------------------------------------------------------------------------

/// A dependency-counting task graph executed on the shared pool.
///
/// Tasks are closures; edges are "must finish before". `run` drains the
/// graph with `workers` cooperating pool jobs, each pulling whatever
/// task is ready — a tile enters its column phase the moment its
/// row-phase dependencies are done instead of waiting on the slowest
/// group behind a phase barrier.
pub struct StageDag<'env> {
    tasks: Vec<Option<Job<'env>>>,
    deps: Vec<usize>,
    dependents: Vec<Vec<usize>>,
}

impl<'env> Default for StageDag<'env> {
    fn default() -> Self {
        StageDag::new()
    }
}

impl<'env> StageDag<'env> {
    pub fn new() -> StageDag<'env> {
        StageDag { tasks: Vec::new(), deps: Vec::new(), dependents: Vec::new() }
    }

    /// Number of tasks added so far.
    pub fn len(&self) -> usize {
        self.tasks.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tasks.is_empty()
    }

    /// Add a task; returns its id for [`StageDag::add_edge`].
    pub fn add(&mut self, job: impl FnOnce() + Send + 'env) -> usize {
        self.tasks.push(Some(Box::new(job)));
        self.deps.push(0);
        self.dependents.push(Vec::new());
        self.tasks.len() - 1
    }

    /// Require task `from` to finish before task `to` may start.
    pub fn add_edge(&mut self, from: usize, to: usize) {
        assert!(from < self.tasks.len() && to < self.tasks.len(), "edge references unknown task");
        assert_ne!(from, to, "self-edge would deadlock the stage DAG");
        self.dependents[from].push(to);
        self.deps[to] += 1;
    }

    /// Run every task to completion with up to `workers` cooperating
    /// pool jobs. Panics if a task panicked or the graph has a cycle.
    pub fn run(self, ctx: &ExecCtx, workers: usize) {
        let total = self.tasks.len();
        if total == 0 {
            return;
        }
        let workers = workers.max(1).min(total);
        let dependents = self.dependents;

        struct DagState<'env> {
            slots: Vec<Option<Job<'env>>>,
            deps: Vec<usize>,
            ready: VecDeque<usize>,
            running: usize,
            done: usize,
            failed: Option<&'static str>,
        }
        let ready: VecDeque<usize> =
            self.deps.iter().enumerate().filter(|(_, &d)| d == 0).map(|(i, _)| i).collect();
        let state = Mutex::new(DagState {
            slots: self.tasks,
            deps: self.deps,
            ready,
            running: 0,
            done: 0,
            failed: None,
        });
        let cv = Condvar::new();

        let mut jobs: Vec<Job> = Vec::with_capacity(workers);
        for _ in 0..workers {
            let state = &state;
            let cv = &cv;
            let dependents = &dependents;
            jobs.push(Box::new(move || loop {
                let (id, job) = {
                    let mut s = state.lock().unwrap();
                    loop {
                        if s.failed.is_some() || s.done == total {
                            return;
                        }
                        if let Some(id) = s.ready.pop_front() {
                            s.running += 1;
                            let job = s.slots[id].take().expect("task scheduled twice");
                            break (id, job);
                        }
                        if s.running == 0 {
                            // nothing ready, nothing running, not done:
                            // the remaining tasks wait on each other
                            s.failed = Some("stage DAG contains a dependency cycle");
                            cv.notify_all();
                            return;
                        }
                        s = cv.wait(s).unwrap();
                    }
                };
                let ok = catch_unwind(AssertUnwindSafe(job)).is_ok();
                let mut s = state.lock().unwrap();
                s.running -= 1;
                s.done += 1;
                if ok {
                    for &dep in &dependents[id] {
                        s.deps[dep] -= 1;
                        if s.deps[dep] == 0 {
                            s.ready.push_back(dep);
                        }
                    }
                } else {
                    s.failed = Some("stage DAG task panicked");
                }
                cv.notify_all();
            }));
        }
        ctx.run_jobs(jobs);

        let s = state.into_inner().unwrap();
        if let Some(why) = s.failed {
            panic!("{why}");
        }
        assert_eq!(s.done, total, "stage DAG finished with unexecuted tasks");
    }
}

// ---------------------------------------------------------------------------
// The fused column phase over the native substrate
// ---------------------------------------------------------------------------

/// Transpose-gather columns `[c0, c1)` of a `rows × stride` row-major
/// region into tile rows of length `fft_len` in `dst` (the caller's
/// zeroed scratch lease supplies the `fft_len − rows` stride-padding
/// tail). Reads are row-major over the source, so each source row
/// contributes one contiguous `c1 − c0`-value read while the write side
/// fans out over that many streams — the blocked-transpose access
/// shape. Element access goes through raw pointers so concurrent tile
/// tasks never materialize overlapping `&mut` plane slices.
///
/// # Safety
///
/// The caller must have exclusive logical access to columns `[c0, c1)`
/// of both planes for the duration of the call (disjoint tile column
/// sets, or [`StageDag`] ordering against writers of other index
/// sets), and both planes must be live `rows × stride` allocations.
#[allow(clippy::too_many_arguments)]
pub unsafe fn gather_col_tile(
    re: SendPtr,
    im: SendPtr,
    rows: usize,
    stride: usize,
    c0: usize,
    c1: usize,
    fft_len: usize,
    dst_re: &mut [f64],
    dst_im: &mut [f64],
) {
    let w = c1 - c0;
    debug_assert!(c1 <= stride && fft_len >= rows);
    debug_assert!(dst_re.len() >= w * fft_len && dst_im.len() >= w * fft_len);
    if !COL_TILE_FORCE_SCALAR.load(Ordering::Relaxed) {
        // in-register 4×4/8×8 tile transpose: unit-stride vector loads
        // along the source rows, vector stores along the tile rows.
        // SAFETY: the rows × w source window starting at column c0 and
        // the w × fft_len destination tile satisfy the caller's
        // exclusivity contract; transpose_block is pure data movement,
        // bit-identical to the scalar loop below.
        let did = simd::transpose_block(re.0.add(c0), stride, dst_re.as_mut_ptr(), fft_len, rows, w)
            && simd::transpose_block(im.0.add(c0), stride, dst_im.as_mut_ptr(), fft_len, rows, w);
        if did {
            return;
        }
    }
    for r in 0..rows {
        let base = r * stride + c0;
        for j in 0..w {
            dst_re[j * fft_len + r] = *re.0.add(base + j);
            dst_im[j * fft_len + r] = *im.0.add(base + j);
        }
    }
}

/// Mirror of [`gather_col_tile`]: scatter the first `rows` spectrum
/// points of each tile row back into columns `[c0, c1)`.
///
/// # Safety
///
/// Same contract as [`gather_col_tile`].
#[allow(clippy::too_many_arguments)]
pub unsafe fn scatter_col_tile(
    re: SendPtr,
    im: SendPtr,
    rows: usize,
    stride: usize,
    c0: usize,
    c1: usize,
    fft_len: usize,
    src_re: &[f64],
    src_im: &[f64],
) {
    let w = c1 - c0;
    debug_assert!(c1 <= stride && fft_len >= rows);
    debug_assert!(src_re.len() >= w * fft_len && src_im.len() >= w * fft_len);
    if !COL_TILE_FORCE_SCALAR.load(Ordering::Relaxed) {
        // SAFETY: mirror of the gather — the w × rows tile transposes
        // back into the rows × w column window at c0.
        let did = simd::transpose_block(src_re.as_ptr(), fft_len, re.0.add(c0), stride, w, rows)
            && simd::transpose_block(src_im.as_ptr(), fft_len, im.0.add(c0), stride, w, rows);
        if did {
            return;
        }
    }
    for r in 0..rows {
        let base = r * stride + c0;
        for j in 0..w {
            *re.0.add(base + j) = src_re[j * fft_len + r];
            *im.0.add(base + j) = src_im[j * fft_len + r];
        }
    }
}

/// Transform columns `[c0, c1)` of a row-major split-plane region in
/// place: per-tile transpose into scratch rows of length `fft_len`
/// (zero tail when `fft_len > rows` — stride-choice padding), run the
/// row kernel over the gathered rows, scatter the first `rows` spectrum
/// points back. `stride` is the distance between consecutive rows of
/// the region (≥ the logical row length).
///
/// Values are bit-identical to "transpose, row-FFT the same vectors,
/// transpose back": the kernel sees exactly the same logical input
/// either way.
#[allow(clippy::too_many_arguments)]
pub fn fft_col_range(
    ctx: &ExecCtx,
    re: &mut [f64],
    im: &mut [f64],
    rows: usize,
    stride: usize,
    c0: usize,
    c1: usize,
    fft_len: usize,
    dir: Direction,
) {
    debug_assert!(c1 <= stride && fft_len >= rows);
    let w = c1 - c0;
    if w == 0 || rows == 0 {
        return;
    }
    let (rp, ip) = (SendPtr(re.as_mut_ptr()), SendPtr(im.as_mut_ptr()));
    with_scratch(|scratch| {
        let (wre, wim) = scratch.pair(w * fft_len);
        // SAFETY: this function holds `&mut` on both whole planes, so
        // access to every column is exclusive here.
        unsafe { gather_col_tile(rp, ip, rows, stride, c0, c1, fft_len, wre, wim) };
        fft_rows_pooled(ctx, wre, wim, w, fft_len, dir, 1);
        unsafe { scatter_col_tile(rp, ip, rows, stride, c0, c1, fft_len, wre, wim) };
    });
}

/// The fused column phase of a square 2D-DFT: column FFTs of every
/// column of `m`, executed as [`DEFAULT_COL_TILE`]-wide tiles chunked
/// over at most `threads` pool jobs (the caller's thread budget is
/// honored, exactly like the row phase) — the replacement for
/// `transpose → row FFTs → transpose`. Inverse direction works
/// symmetrically (the kernel's per-column 1/n scaling happens in the
/// gathered tile).
pub fn fft_cols_fused(ctx: &ExecCtx, m: &mut SignalMatrix, dir: Direction, threads: usize) {
    assert_eq!(m.rows, m.cols, "square signal matrix required");
    let n = m.rows;
    fft_cols_fused_rect(ctx, &mut m.re, &mut m.im, n, n, n, dir, threads);
}

/// Rectangle-general fused column phase: FFT every column of a
/// `rows × cols` row-major split-plane region at length
/// `fft_len >= rows` (zero-tail stride padding), as
/// [`DEFAULT_COL_TILE`]-wide tiles chunked over at most `threads` pool
/// jobs. [`fft_cols_fused`] is the square case; the packed real path
/// calls this with `cols = n/2+1`
/// ([`crate::dft::real::rfft_cols_fused`]).
#[allow(clippy::too_many_arguments)]
pub fn fft_cols_fused_rect(
    ctx: &ExecCtx,
    re: &mut [f64],
    im: &mut [f64],
    rows: usize,
    cols: usize,
    fft_len: usize,
    dir: Direction,
    threads: usize,
) {
    debug_assert!(fft_len >= rows);
    debug_assert!(re.len() >= rows * cols && im.len() >= rows * cols);
    if rows == 0 || cols == 0 {
        return;
    }
    let threads = threads.max(1);
    if threads == 1 || cols <= DEFAULT_COL_TILE {
        let mut c = 0;
        while c < cols {
            let hi = (c + DEFAULT_COL_TILE).min(cols);
            fft_col_range(ctx, re, im, rows, cols, c, hi, fft_len, dir);
            c = hi;
        }
        return;
    }
    let mut tiles: Vec<(usize, usize)> = Vec::with_capacity(cols.div_ceil(DEFAULT_COL_TILE));
    let mut c = 0;
    while c < cols {
        let hi = (c + DEFAULT_COL_TILE).min(cols);
        tiles.push((c, hi));
        c = hi;
    }
    let re_ptr = SendPtr(re.as_mut_ptr());
    let im_ptr = SendPtr(im.as_mut_ptr());
    let per_job = tiles.len().div_ceil(threads.min(tiles.len()));
    let mut jobs: Vec<Job> = Vec::with_capacity(tiles.len().div_ceil(per_job));
    for chunk in tiles.chunks(per_job) {
        jobs.push(Box::new(move || {
            // rebind the wrappers whole: 2021 precise capture would
            // otherwise capture only the (non-Send) pointer fields
            let (re_ptr, im_ptr) = (re_ptr, im_ptr);
            for &(c0, hi) in chunk {
                with_scratch(|scratch| {
                    let (wre, wim) = scratch.pair((hi - c0) * fft_len);
                    // SAFETY: jobs own disjoint column sets, access is
                    // raw-pointer per element (no overlapping `&mut`
                    // slices), and run_jobs does not return before
                    // every job finished.
                    unsafe {
                        gather_col_tile(re_ptr, im_ptr, rows, cols, c0, hi, fft_len, wre, wim)
                    };
                    fft_rows_pooled(ctx, wre, wim, hi - c0, fft_len, dir, 1);
                    unsafe {
                        scatter_col_tile(re_ptr, im_ptr, rows, cols, c0, hi, fft_len, wre, wim)
                    };
                });
            }
        }));
    }
    ctx.run_jobs(jobs);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dft::transpose::transpose_in_place_parallel;
    use crate::dft::{naive_dft_rows, SignalMatrix};
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn mode_parses_and_names() {
        assert_eq!(PipelineMode::parse("fused"), Some(PipelineMode::Fused));
        assert_eq!(PipelineMode::parse(" Barrier "), Some(PipelineMode::Barrier));
        assert_eq!(PipelineMode::parse("nope"), None);
        assert_eq!(PipelineMode::Fused.name(), "fused");
        assert_eq!(PipelineMode::Barrier.name(), "barrier");
    }

    #[test]
    fn unparsable_env_value_warns_and_falls_back_to_fused() {
        // regression: an unparsable HCLFFT_PIPELINE must take the same
        // warn-to-stderr fallback route as a bad HCLFFT_POOL_THREADS —
        // never a silent mode flip. The helper is exercised directly so
        // this test cannot race the process-global mode cache.
        assert_eq!(mode_from_env_value("bogus"), PipelineMode::Fused);
        assert_eq!(mode_from_env_value(""), PipelineMode::Fused);
        // parsable values pass through untouched (incl. whitespace/case)
        assert_eq!(mode_from_env_value("barrier"), PipelineMode::Barrier);
        assert_eq!(mode_from_env_value(" FUSED "), PipelineMode::Fused);
    }

    #[test]
    fn dag_respects_edges_and_runs_everything() {
        let ctx = ExecCtx::new(3);
        let order: Mutex<Vec<usize>> = Mutex::new(Vec::new());
        let mut dag = StageDag::new();
        // diamond: 0 -> {1, 2} -> 3
        let a = dag.add(|| order.lock().unwrap().push(0));
        let b = dag.add(|| order.lock().unwrap().push(1));
        let c = dag.add(|| order.lock().unwrap().push(2));
        let d = dag.add(|| order.lock().unwrap().push(3));
        dag.add_edge(a, b);
        dag.add_edge(a, c);
        dag.add_edge(b, d);
        dag.add_edge(c, d);
        dag.run(&ctx, 3);
        let got = order.into_inner().unwrap();
        assert_eq!(got.len(), 4);
        assert_eq!(got[0], 0, "root first");
        assert_eq!(got[3], 3, "sink last");
    }

    #[test]
    fn dag_single_worker_suffices() {
        let ctx = ExecCtx::new(1);
        let hits = AtomicUsize::new(0);
        let mut dag = StageDag::new();
        let mut prev = None;
        for _ in 0..16 {
            let id = dag.add(|| {
                hits.fetch_add(1, Ordering::Relaxed);
            });
            if let Some(p) = prev {
                dag.add_edge(p, id);
            }
            prev = Some(id);
        }
        dag.run(&ctx, 1);
        assert_eq!(hits.load(Ordering::Relaxed), 16);
    }

    #[test]
    #[should_panic(expected = "dependency cycle")]
    fn dag_cycle_detected() {
        let ctx = ExecCtx::new(2);
        let mut dag = StageDag::new();
        let a = dag.add(|| {});
        let b = dag.add(|| {});
        dag.add_edge(a, b);
        dag.add_edge(b, a);
        dag.run(&ctx, 2);
    }

    #[test]
    #[should_panic(expected = "task panicked")]
    fn dag_task_panic_propagates() {
        let ctx = ExecCtx::new(2);
        let mut dag = StageDag::new();
        dag.add(|| {});
        dag.add(|| panic!("boom"));
        dag.run(&ctx, 2);
    }

    /// Oracle: the barrier column phase (transpose → row FFTs →
    /// transpose) over the same matrix.
    fn cols_via_barrier(m: &SignalMatrix, dir: Direction) -> SignalMatrix {
        let mut t = m.clone();
        transpose_in_place_parallel(&mut t, 64, 2);
        let f = naive_dft_rows(&t, dir == Direction::Inverse);
        let mut out = f;
        transpose_in_place_parallel(&mut out, 64, 2);
        out
    }

    #[test]
    fn fused_cols_match_barrier_cols() {
        let ctx = ExecCtx::new(4);
        // 96 spans three tiles at width 32; 24 and 22 exercise the
        // mixed-radix and Bluestein column kernels
        for &n in &[22usize, 24, 96] {
            let orig = SignalMatrix::random(n, n, n as u64 + 1);
            let mut fused = orig.clone();
            fft_cols_fused(&ctx, &mut fused, Direction::Forward, 4);
            let want = cols_via_barrier(&orig, Direction::Forward);
            let scale = want.norm().max(1.0);
            assert!(
                fused.max_abs_diff(&want) / scale < 1e-9,
                "n={n}: {}",
                fused.max_abs_diff(&want) / scale
            );
        }
    }

    #[test]
    fn forced_scalar_col_tiles_match_simd_bitwise() {
        // The SIMD tile transpose is pure data movement: forcing the
        // scalar gather/scatter must reproduce the exact same bits,
        // remainder rims included (70 = 2·5·7 leaves a 6-wide tail tile
        // and non-multiple-of-4 row count). On non-AVX2 machines both
        // runs take the scalar path and the assert is trivially true.
        let ctx = ExecCtx::new(2);
        let orig = SignalMatrix::random(70, 70, 17);
        let mut simd_run = orig.clone();
        fft_cols_fused(&ctx, &mut simd_run, Direction::Forward, 2);
        set_col_tile_force_scalar(true);
        let mut scalar_run = orig.clone();
        fft_cols_fused(&ctx, &mut scalar_run, Direction::Forward, 2);
        set_col_tile_force_scalar(false);
        assert_eq!(simd_run.max_abs_diff(&scalar_run), 0.0);
    }

    #[test]
    fn fused_cols_thread_count_invariant_bitwise() {
        let ctx = ExecCtx::new(4);
        let orig = SignalMatrix::random(96, 96, 9);
        let mut a = orig.clone();
        let mut b = orig.clone();
        fft_cols_fused(&ctx, &mut a, Direction::Forward, 1);
        fft_cols_fused(&ctx, &mut b, Direction::Forward, 4);
        assert_eq!(a.max_abs_diff(&b), 0.0);
    }

    #[test]
    fn fused_col_range_pads_as_stride_choice() {
        // padded column FFT == zero-pad the column to fft_len, FFT,
        // keep the first n bins (the paper's spectral interpolation)
        let (n, v) = (16usize, 24usize);
        let orig = SignalMatrix::random(n, n, 5);
        let mut got = orig.clone();
        let ctx = ExecCtx::new(2);
        {
            let (re, im) = (&mut got.re[..], &mut got.im[..]);
            fft_col_range(&ctx, re, im, n, n, 0, n, v, Direction::Forward);
        }
        // oracle: transpose, pad rows to v, FFT, crop, transpose back
        let mut t = orig.clone();
        transpose_in_place_parallel(&mut t, 64, 1);
        let padded = t.pad_cols(v);
        let f = naive_dft_rows(&padded, false);
        let mut want = f.crop_cols(n);
        transpose_in_place_parallel(&mut want, 64, 1);
        let scale = want.norm().max(1.0);
        assert!(got.max_abs_diff(&want) / scale < 1e-9);
    }
}
