//! 2D-DFT row-column driver over the native substrate.
//!
//! Implements the paper's sequential algorithm (Section III-A) and the
//! multithreaded row-FFT stage the abstract processors execute. The
//! coordinator-level parallel algorithms (PFFT-LB / PFFT-FPM / PAD) live
//! in [`crate::coordinator::pfft`]; this module provides the engine
//! primitives they drive.

use crate::dft::exec::{fft_rows_pooled, ExecCtx};
use crate::dft::fft::Direction;
use crate::dft::pipeline::{default_mode, fft_cols_fused, PipelineMode};
use crate::dft::transpose::{transpose_in_place_parallel, DEFAULT_BLOCK};
use crate::dft::SignalMatrix;

/// Execute `rows` 1D-FFTs over the given contiguous row range of `m`
/// with a `threads`-wide slice of the shared pool (the paper's
/// `1D_ROW_FFTS_LOCAL` with a thread group). Mixed-radix for 5-smooth
/// row lengths, Bluestein fallback otherwise — this is a thin veneer
/// over the single executor entry point
/// [`crate::dft::exec::fft_rows_pooled`].
pub fn row_ffts_local(
    m: &mut SignalMatrix,
    row_start: usize,
    rows: usize,
    dir: Direction,
    threads: usize,
) {
    let n = m.cols;
    if rows == 0 || n == 0 {
        return;
    }
    assert!(row_start + rows <= m.rows, "row range out of bounds");
    let re = &mut m.re[row_start * n..(row_start + rows) * n];
    let im = &mut m.im[row_start * n..(row_start + rows) * n];
    fft_rows_pooled(ExecCtx::global(), re, im, rows, n, dir, threads);
}

/// Full 2D-DFT of a square signal matrix with one thread group — the
/// "basic FFT version" baseline of the paper's experiments (one group of
/// `threads` threads), steps 1-4 of PFFT-LB with p=1. Dispatches on the
/// process-wide [`PipelineMode`]; both modes are bit-identical (each
/// logical row/column vector meets the same per-row kernel either way).
pub fn dft2d(m: &mut SignalMatrix, dir: Direction, threads: usize) {
    dft2d_with_mode(m, dir, threads, default_mode());
}

/// [`dft2d`] with an explicit pipeline mode (tests and A/B benches —
/// explicit callers never race on the process default).
pub fn dft2d_with_mode(m: &mut SignalMatrix, dir: Direction, threads: usize, mode: PipelineMode) {
    match mode {
        PipelineMode::Fused => dft2d_fused(m, dir, threads),
        PipelineMode::Barrier => dft2d_barrier(m, dir, threads),
    }
}

/// The fused path: row FFTs in place, then strided column FFTs via
/// per-tile transposes — no whole-matrix transpose passes.
pub fn dft2d_fused(m: &mut SignalMatrix, dir: Direction, threads: usize) {
    assert_eq!(m.rows, m.cols, "square signal matrix required");
    let n = m.rows;
    row_ffts_local(m, 0, n, dir, threads);
    fft_cols_fused(ExecCtx::global(), m, dir, threads);
}

/// The pre-pipeline four-step path (row FFTs → transpose → row FFTs →
/// transpose) — the bit-exactness oracle for the fused pipeline.
pub fn dft2d_barrier(m: &mut SignalMatrix, dir: Direction, threads: usize) {
    assert_eq!(m.rows, m.cols, "square signal matrix required");
    let n = m.rows;
    row_ffts_local(m, 0, n, dir, threads);
    transpose_in_place_parallel(m, DEFAULT_BLOCK, threads);
    row_ffts_local(m, 0, n, dir, threads);
    transpose_in_place_parallel(m, DEFAULT_BLOCK, threads);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dft::naive_dft2d;

    #[test]
    fn dft2d_matches_naive() {
        for &n in &[4usize, 8, 16, 24] {
            let orig = SignalMatrix::random(n, n, n as u64);
            let mut m = orig.clone();
            dft2d(&mut m, Direction::Forward, 1);
            let want = naive_dft2d(&orig);
            let scale = want.norm().max(1.0);
            assert!(
                m.max_abs_diff(&want) / scale < 1e-10,
                "n={n}: {}",
                m.max_abs_diff(&want) / scale
            );
        }
    }

    #[test]
    fn dft2d_threads_invariant() {
        let orig = SignalMatrix::random(32, 32, 5);
        let mut a = orig.clone();
        let mut b = orig.clone();
        dft2d(&mut a, Direction::Forward, 1);
        dft2d(&mut b, Direction::Forward, 4);
        assert!(a.max_abs_diff(&b) < 1e-12);
    }

    #[test]
    fn dft2d_roundtrip() {
        let orig = SignalMatrix::random(16, 16, 6);
        let mut m = orig.clone();
        dft2d(&mut m, Direction::Forward, 2);
        dft2d(&mut m, Direction::Inverse, 2);
        assert!(m.max_abs_diff(&orig) < 1e-10);
    }

    #[test]
    fn row_ffts_local_partial_range() {
        // transforming rows [2, 5) must not touch other rows
        let orig = SignalMatrix::random(8, 16, 7);
        let mut m = orig.clone();
        row_ffts_local(&mut m, 2, 3, Direction::Forward, 2);
        for r in [0usize, 1, 5, 6, 7] {
            for c in 0..16 {
                assert_eq!(m.get(r, c), orig.get(r, c), "row {r} modified");
            }
        }
        // and the transformed rows match a full serial transform
        let mut want = orig.clone();
        row_ffts_local(&mut want, 0, 8, Direction::Forward, 1);
        for r in 2..5 {
            for c in 0..16 {
                let (ar, ai) = m.get(r, c);
                let (br, bi) = want.get(r, c);
                assert!((ar - br).abs() < 1e-12 && (ai - bi).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn fused_matches_barrier_bitwise() {
        // 24 (mixed-radix), 22 (Bluestein), 96 (two column tiles)
        for &n in &[22usize, 24, 96] {
            for dir in [Direction::Forward, Direction::Inverse] {
                let orig = SignalMatrix::random(n, n, n as u64 + 13);
                let mut fused = orig.clone();
                let mut barrier = orig.clone();
                dft2d_with_mode(&mut fused, dir, 3, PipelineMode::Fused);
                dft2d_with_mode(&mut barrier, dir, 3, PipelineMode::Barrier);
                assert_eq!(
                    fused.max_abs_diff(&barrier),
                    0.0,
                    "n={n} {dir:?}: fused pipeline must be bit-exact vs barrier"
                );
            }
        }
    }

    #[test]
    fn zero_rows_is_noop() {
        let orig = SignalMatrix::random(4, 8, 1);
        let mut m = orig.clone();
        row_ffts_local(&mut m, 2, 0, Direction::Forward, 4);
        assert_eq!(m, orig);
    }

    #[test]
    fn non_pow2_rows_supported() {
        // 24 = 2^3·3 → mixed-radix; 22 = 2·11 → Bluestein fallback
        for &n in &[24usize, 22] {
            let orig = SignalMatrix::random(3, n, 8);
            let mut m = orig.clone();
            row_ffts_local(&mut m, 0, 3, Direction::Forward, 1);
            let want = crate::dft::naive_dft_rows(&orig, false);
            let scale = want.norm().max(1.0);
            assert!(m.max_abs_diff(&want) / scale < 1e-10, "n={n}");
        }
    }
}
