//! Runtime configuration: defaults < config file < env < CLI.
//!
//! File format is `key = value` lines (`#` comments) — deliberately not
//! TOML-complete since the offline vendor set has no toml crate and the
//! config surface is flat.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// All tunables of the system with their provenance-ordered overrides.
#[derive(Clone, Debug, PartialEq)]
pub struct Config {
    /// Directory holding AOT artifacts + manifest.tsv.
    pub artifacts_dir: PathBuf,
    /// Directory for figure CSVs and reports.
    pub results_dir: PathBuf,
    /// Number of abstract processors p (paper: 2 for MKL, 4 for FFTW).
    pub groups: usize,
    /// Threads per group t (paper: 18 for MKL, 9 for FFTW).
    pub threads_per_group: usize,
    /// FPM identity tolerance ε (paper example: 0.05).
    pub eps: f64,
    /// Transpose block size (paper Appendix A: 64).
    pub transpose_block: usize,
    /// Repetition scale divisor for MeanUsingTtest (1 = paper-exact).
    pub rep_scale: usize,
    /// Deterministic seed for simulator noise.
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            artifacts_dir: PathBuf::from("artifacts"),
            results_dir: PathBuf::from("results"),
            groups: 2,
            threads_per_group: 2,
            eps: 0.05,
            transpose_block: 64,
            rep_scale: 100,
            seed: 0x5EED,
        }
    }
}

impl Config {
    /// Load with full precedence: defaults, then `path` (if it exists),
    /// then `HCLFFT_*` environment variables.
    pub fn load(path: Option<&Path>) -> Result<Config, String> {
        let mut cfg = Config::default();
        if let Some(p) = path {
            if p.exists() {
                cfg.apply_map(&parse_file(p)?)?;
            } else {
                return Err(format!("config file not found: {}", p.display()));
            }
        } else {
            let default_path = Path::new("hclfft.conf");
            if default_path.exists() {
                cfg.apply_map(&parse_file(default_path)?)?;
            }
        }
        cfg.apply_env();
        Ok(cfg)
    }

    fn apply_map(&mut self, map: &BTreeMap<String, String>) -> Result<(), String> {
        for (k, v) in map {
            self.set(k, v)?;
        }
        Ok(())
    }

    fn apply_env(&mut self) {
        for (key, field) in [
            ("HCLFFT_ARTIFACTS_DIR", "artifacts_dir"),
            ("HCLFFT_RESULTS_DIR", "results_dir"),
            ("HCLFFT_GROUPS", "groups"),
            ("HCLFFT_THREADS_PER_GROUP", "threads_per_group"),
            ("HCLFFT_EPS", "eps"),
            ("HCLFFT_TRANSPOSE_BLOCK", "transpose_block"),
            ("HCLFFT_REP_SCALE", "rep_scale"),
            ("HCLFFT_SEED", "seed"),
        ] {
            if let Ok(v) = std::env::var(key) {
                // env values are best-effort; ignore malformed ones
                let _ = self.set(field, &v);
            }
        }
    }

    /// Set one field by name (config-file / env plumbing).
    pub fn set(&mut self, key: &str, value: &str) -> Result<(), String> {
        let bad = |k: &str, v: &str| format!("config: invalid value `{v}` for `{k}`");
        match key {
            "artifacts_dir" => self.artifacts_dir = PathBuf::from(value),
            "results_dir" => self.results_dir = PathBuf::from(value),
            "groups" => self.groups = value.parse().map_err(|_| bad(key, value))?,
            "threads_per_group" => {
                self.threads_per_group = value.parse().map_err(|_| bad(key, value))?
            }
            "eps" => self.eps = value.parse().map_err(|_| bad(key, value))?,
            "transpose_block" => {
                self.transpose_block = value.parse().map_err(|_| bad(key, value))?
            }
            "rep_scale" => self.rep_scale = value.parse().map_err(|_| bad(key, value))?,
            "seed" => self.seed = value.parse().map_err(|_| bad(key, value))?,
            other => return Err(format!("config: unknown key `{other}`")),
        }
        Ok(())
    }
}

fn parse_file(path: &Path) -> Result<BTreeMap<String, String>, String> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| format!("config: cannot read {}: {e}", path.display()))?;
    parse_str(&text).map_err(|e| format!("{}: {e}", path.display()))
}

/// Parse `key = value` lines; `#` starts a comment; blank lines skipped.
pub fn parse_str(text: &str) -> Result<BTreeMap<String, String>, String> {
    let mut map = BTreeMap::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        let Some((k, v)) = line.split_once('=') else {
            return Err(format!("line {}: expected `key = value`, got `{raw}`", lineno + 1));
        };
        map.insert(k.trim().to_string(), v.trim().to_string());
    }
    Ok(map)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_sane() {
        let c = Config::default();
        assert_eq!(c.transpose_block, 64);
        assert!(c.eps > 0.0);
        assert!(c.groups >= 1);
    }

    #[test]
    fn parse_str_basics() {
        let m = parse_str("a = 1\n# comment\n  b=two  # trailing\n\n").unwrap();
        assert_eq!(m["a"], "1");
        assert_eq!(m["b"], "two");
        assert_eq!(m.len(), 2);
    }

    #[test]
    fn parse_str_rejects_garbage() {
        assert!(parse_str("not a kv line").is_err());
    }

    #[test]
    fn set_fields_and_unknown_key() {
        let mut c = Config::default();
        c.set("groups", "4").unwrap();
        c.set("eps", "0.1").unwrap();
        assert_eq!(c.groups, 4);
        assert_eq!(c.eps, 0.1);
        assert!(c.set("groups", "x").is_err());
        assert!(c.set("nope", "1").is_err());
    }

    #[test]
    fn file_roundtrip() {
        let dir = std::env::temp_dir().join("hclfft_config_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("c.conf");
        std::fs::write(&p, "groups = 6\nthreads_per_group = 6\nseed = 42\n").unwrap();
        let c = Config::load(Some(&p)).unwrap();
        assert_eq!(c.groups, 6);
        assert_eq!(c.threads_per_group, 6);
        assert_eq!(c.seed, 42);
    }

    #[test]
    fn missing_explicit_file_errors() {
        assert!(Config::load(Some(Path::new("/nonexistent/x.conf"))).is_err());
    }
}
