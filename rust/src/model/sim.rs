//! `SimModel` — the calibrated virtual testbed behind [`PerfModel`].
//!
//! Thin adapter over [`crate::simulator::fpm::SimTestbed`]: sections are
//! computed lazily from the package model on the paper's 128-grid
//! (memory-capped), so the virtual-time serving path and the planning
//! algorithms consume the exact same curves as the figure campaigns —
//! deterministic at paper-scale N in microseconds.

use crate::coordinator::group::GroupConfig;
use crate::model::surface::{time_from_speed, Curve};
use crate::model::PerfModel;
use crate::simulator::fpm::SimTestbed;
use crate::simulator::Package;

/// A virtual-testbed performance model (package + group configuration).
#[derive(Clone, Debug)]
pub struct SimModel {
    tb: SimTestbed,
}

impl SimModel {
    pub fn new(package: Package, cfg: GroupConfig) -> SimModel {
        SimModel { tb: SimTestbed::new(package, cfg) }
    }

    /// With the package's paper-best (p, t).
    pub fn paper_best(package: Package) -> SimModel {
        SimModel { tb: SimTestbed::paper_best(package) }
    }
}

impl PerfModel for SimModel {
    fn model_name(&self) -> String {
        format!("sim-{}", self.tb.model.package.name())
    }

    fn groups(&self) -> usize {
        self.tb.cfg.p
    }

    fn plane_section(&self, g: usize, n: usize) -> Curve {
        // SimTestbed groups are 1-based (paper numbering)
        self.tb.plane_section(g + 1, n)
    }

    fn column_section(&self, g: usize, d: usize, n: usize, window: usize) -> Curve {
        self.tb.column_section(g + 1, d, n, window)
    }

    fn predict_time(&self, x: usize, y: usize) -> Option<f64> {
        let p = self.groups().max(1);
        let share = (x / p).max(1);
        let total: f64 = (1..=p)
            .map(|g| self.tb.model.group_speed(share, y, g, p, self.tb.cfg.t))
            .sum();
        if total <= 0.0 {
            return None;
        }
        Some(time_from_speed(x, y, total))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sections_match_testbed() {
        let m = SimModel::paper_best(Package::Mkl);
        let a = m.plane_section(0, 24_704);
        let b = m.tb.plane_section(1, 24_704);
        assert_eq!(a, b);
        let ca = m.column_section(1, 11_648, 24_704, 2048);
        let cb = m.tb.column_section(2, 11_648, 24_704, 2048);
        assert_eq!(ca, cb);
    }

    #[test]
    fn predicts_positive_finite_times() {
        let m = SimModel::paper_best(Package::Fftw3);
        let t = m.predict_time(2 * 8_064, 8_064).unwrap();
        assert!(t > 0.0 && t.is_finite());
        // bigger problems take longer
        let t2 = m.predict_time(2 * 16_064, 16_064).unwrap();
        assert!(t2 > t);
    }
}
