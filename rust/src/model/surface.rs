//! FPM surfaces — the shared data types of every performance model.
//!
//! The paper's FPM is a *discrete 3D function of performance against
//! problem size*: `S_i = {((x, y), s_i(x, y))}` where `s_i(x, y)` is the
//! speed of abstract processor `i` executing `x` row 1D-FFTs of length
//! `y`, computed as `s = 2.5·x·y·log2(y) / t` (Section III-C).
//!
//! The two geometric operations the algorithms need are the *plane
//! section* `y = N` (PFFT-FPM Step 1a — gives speed-vs-x curves for
//! partitioning, Figures 9-10) and the *column section* `x = d_i`
//! (PFFT-FPM-PAD Step 2 — gives speed-vs-y curves for pad selection,
//! Figures 11-12).
//!
//! This module is also the *single ingestion point* for raw timing
//! measurements ([`sanitize_time`] / [`speed_from_time_sanitized`]):
//! every producer — the offline profiler, the serving executor, the
//! online model — routes observed times through it, so a sub-resolution
//! timer reading (~0 ns on a fast point) or a NaN from a degenerate
//! t-test can never reach the positivity asserts in [`Curve::new`] or
//! [`speed_from_time`].

use std::path::Path;

/// Timer-resolution floor (seconds). Observed times are clamped up to
/// this before the speed formula divides by them: a measurement of
/// ~0 ns means "faster than the clock can see", not infinite speed.
pub const MIN_TIME_S: f64 = 1e-9;

/// Sanitize one raw timing observation: `None` for non-finite or
/// negative values (clock error, degenerate t-test), otherwise the time
/// clamped up to [`MIN_TIME_S`].
pub fn sanitize_time(t_seconds: f64) -> Option<f64> {
    if !t_seconds.is_finite() || t_seconds < 0.0 {
        return None;
    }
    Some(t_seconds.max(MIN_TIME_S))
}

/// The paper's speed formula: speed (MFLOPs if t in seconds and the
/// constant absorbed) of executing `x` row FFTs of length `y` in time `t`.
pub fn speed_from_time(x: usize, y: usize, t_seconds: f64) -> f64 {
    assert!(t_seconds > 0.0, "speed_from_time: nonpositive time");
    2.5 * x as f64 * y as f64 * (y as f64).log2() / t_seconds / 1e6
}

/// [`speed_from_time`] behind the sanitizer: `None` when the
/// observation is unusable (NaN/negative time, or a degenerate point
/// whose speed would not be positive and finite). This is the form
/// measurement producers must use.
pub fn speed_from_time_sanitized(x: usize, y: usize, t_seconds: f64) -> Option<f64> {
    let t = sanitize_time(t_seconds)?;
    let s = speed_from_time(x, y, t);
    (s > 0.0 && s.is_finite()).then_some(s)
}

/// Inverse: execution time (seconds) of `x` row FFTs of length `y` at
/// speed `s` MFLOPs.
pub fn time_from_speed(x: usize, y: usize, s_mflops: f64) -> f64 {
    assert!(s_mflops > 0.0, "time_from_speed: nonpositive speed");
    2.5 * x as f64 * y as f64 * (y as f64).log2() / (s_mflops * 1e6)
}

/// Eq. 1: width of performance variation between two speeds (percent).
pub fn variation_pct(s1: f64, s2: f64) -> f64 {
    (s1 - s2).abs() / s1.min(s2) * 100.0
}

/// A speed-vs-x curve (one plane or column section), x strictly ascending.
#[derive(Clone, Debug, PartialEq)]
pub struct Curve {
    /// problem-size coordinate (rows x for plane sections, length y for
    /// column sections)
    pub xs: Vec<usize>,
    /// speed in MFLOPs at each coordinate
    pub speeds: Vec<f64>,
}

impl Curve {
    pub fn new(xs: Vec<usize>, speeds: Vec<f64>) -> Self {
        assert_eq!(xs.len(), speeds.len(), "curve arity mismatch");
        assert!(xs.windows(2).all(|w| w[0] < w[1]), "curve xs must be ascending");
        assert!(speeds.iter().all(|&s| s > 0.0 && s.is_finite()), "curve speeds must be positive");
        Curve { xs, speeds }
    }

    pub fn len(&self) -> usize {
        self.xs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.xs.is_empty()
    }

    /// Speed at exactly-gridded `x` (None if not a grid point).
    pub fn speed_at(&self, x: usize) -> Option<f64> {
        self.xs.binary_search(&x).ok().map(|i| self.speeds[i])
    }

    /// Speed at `x` with nearest-grid-point fallback.
    pub fn speed_nearest(&self, x: usize) -> f64 {
        assert!(!self.is_empty());
        match self.xs.binary_search(&x) {
            Ok(i) => self.speeds[i],
            Err(0) => self.speeds[0],
            Err(i) if i == self.xs.len() => self.speeds[i - 1],
            Err(i) => {
                // nearest neighbour; ties toward the smaller grid point
                if x - self.xs[i - 1] <= self.xs[i] - x {
                    self.speeds[i - 1]
                } else {
                    self.speeds[i]
                }
            }
        }
    }
}

/// A discrete FPM surface on an (x, y) grid. Missing points (the paper's
/// "built until permissible problem size" memory cap) hold `None`.
#[derive(Clone, Debug, PartialEq)]
pub struct SpeedFunction {
    pub name: String,
    /// ascending x grid (number of rows)
    pub xs: Vec<usize>,
    /// ascending y grid (row length)
    pub ys: Vec<usize>,
    /// speeds\[ix * ys.len() + iy\] in MFLOPs; None = unmeasured
    speeds: Vec<Option<f64>>,
}

impl SpeedFunction {
    pub fn new(name: &str, xs: Vec<usize>, ys: Vec<usize>) -> Self {
        assert!(xs.windows(2).all(|w| w[0] < w[1]), "xs must be ascending");
        assert!(ys.windows(2).all(|w| w[0] < w[1]), "ys must be ascending");
        let len = xs.len() * ys.len();
        SpeedFunction { name: name.to_string(), xs, ys, speeds: vec![None; len] }
    }

    /// Build from a closure over the full grid (simulator path).
    pub fn from_fn(
        name: &str,
        xs: Vec<usize>,
        ys: Vec<usize>,
        f: impl Fn(usize, usize) -> Option<f64>,
    ) -> Self {
        let mut s = SpeedFunction::new(name, xs, ys);
        for ix in 0..s.xs.len() {
            for iy in 0..s.ys.len() {
                let v = f(s.xs[ix], s.ys[iy]);
                debug_assert!(v.map_or(true, |v| v > 0.0 && v.is_finite()));
                s.speeds[ix * s.ys.len() + iy] = v;
            }
        }
        s
    }

    pub fn set(&mut self, x: usize, y: usize, speed: f64) {
        let ix = self.xs.binary_search(&x).expect("x not on grid");
        let iy = self.ys.binary_search(&y).expect("y not on grid");
        self.speeds[ix * self.ys.len() + iy] = Some(speed);
    }

    pub fn get(&self, x: usize, y: usize) -> Option<f64> {
        let ix = self.xs.binary_search(&x).ok()?;
        let iy = self.ys.binary_search(&y).ok()?;
        self.speeds[ix * self.ys.len() + iy]
    }

    /// Plane section `y = n` (Step 1a): the speed-vs-x curve used by the
    /// partitioning algorithms. `n` snaps to the nearest y grid point.
    pub fn plane_section(&self, n: usize) -> Curve {
        let iy = nearest_index(&self.ys, n);
        let mut xs = Vec::new();
        let mut speeds = Vec::new();
        for (ix, &x) in self.xs.iter().enumerate() {
            if let Some(s) = self.speeds[ix * self.ys.len() + iy] {
                xs.push(x);
                speeds.push(s);
            }
        }
        Curve::new(xs, speeds)
    }

    /// Column section `x = d` (PAD Step 2): the speed-vs-y curve used for
    /// pad-length selection. `d` snaps to the nearest x grid point.
    pub fn column_section(&self, d: usize) -> Curve {
        let ix = nearest_index(&self.xs, d);
        let mut ys = Vec::new();
        let mut speeds = Vec::new();
        for (iy, &y) in self.ys.iter().enumerate() {
            if let Some(s) = self.speeds[ix * self.ys.len() + iy] {
                ys.push(y);
                speeds.push(s);
            }
        }
        Curve::new(ys, speeds)
    }

    /// The y grid point actually used by a plane section at `n`.
    pub fn snap_y(&self, n: usize) -> usize {
        self.ys[nearest_index(&self.ys, n)]
    }

    /// The x grid point actually used by a column section at `d`.
    pub fn snap_x(&self, d: usize) -> usize {
        self.xs[nearest_index(&self.xs, d)]
    }

    /// Count of measured points.
    pub fn measured_points(&self) -> usize {
        self.speeds.iter().filter(|s| s.is_some()).count()
    }

    /// Serialize as TSV: `x<TAB>y<TAB>speed` with a header comment.
    pub fn write_tsv(&self, path: &Path) -> std::io::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut out = format!("# speed function: {}\n# x\ty\tmflops\n", self.name);
        for (ix, &x) in self.xs.iter().enumerate() {
            for (iy, &y) in self.ys.iter().enumerate() {
                if let Some(s) = self.speeds[ix * self.ys.len() + iy] {
                    out.push_str(&format!("{x}\t{y}\t{s:.6}\n"));
                }
            }
        }
        std::fs::write(path, out)
    }

    /// Serialize to a JSON value: grids plus the dense speed array with
    /// `null` for unmeasured points. Used by the service wisdom store to
    /// persist measured surfaces (the paper's §V "96-hour" artifact)
    /// alongside the plan they produced.
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let speeds: Vec<Json> = self
            .speeds
            .iter()
            .map(|s| match s {
                Some(v) => Json::Num(*v),
                None => Json::Null,
            })
            .collect();
        Json::obj()
            .set("name", self.name.as_str())
            .set("xs", self.xs.clone())
            .set("ys", self.ys.clone())
            .set("speeds", Json::Arr(speeds))
    }

    /// Inverse of [`SpeedFunction::to_json`].
    pub fn from_json(j: &crate::util::json::Json) -> Result<SpeedFunction, String> {
        use crate::util::json::Json;
        let name = j.get("name").and_then(Json::as_str).ok_or("fpm json: missing name")?;
        let grid = |key: &str| -> Result<Vec<usize>, String> {
            j.get(key)
                .and_then(Json::as_arr)
                .ok_or(format!("fpm json: missing {key}"))?
                .iter()
                .map(|v| v.as_usize().ok_or(format!("fpm json: bad {key} entry")))
                .collect()
        };
        let xs = grid("xs")?;
        let ys = grid("ys")?;
        let raw = j.get("speeds").and_then(Json::as_arr).ok_or("fpm json: missing speeds")?;
        if raw.len() != xs.len() * ys.len() {
            return Err(format!(
                "fpm json: speeds arity {} != {}x{}",
                raw.len(),
                xs.len(),
                ys.len()
            ));
        }
        let speeds: Vec<Option<f64>> = raw
            .iter()
            .map(|v| match v {
                Json::Null => Ok(None),
                other => other.as_f64().map(Some).ok_or("fpm json: bad speed".to_string()),
            })
            .collect::<Result<_, _>>()?;
        if xs.windows(2).any(|w| w[0] >= w[1]) || ys.windows(2).any(|w| w[0] >= w[1]) {
            return Err("fpm json: grids must be strictly ascending".to_string());
        }
        Ok(SpeedFunction { name: name.to_string(), xs, ys, speeds })
    }

    /// Parse the TSV produced by [`write_tsv`].
    pub fn read_tsv(path: &Path) -> Result<SpeedFunction, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("fpm: cannot read {}: {e}", path.display()))?;
        let mut name = path.file_stem().map(|s| s.to_string_lossy().into_owned()).unwrap_or_default();
        let mut points: Vec<(usize, usize, f64)> = Vec::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.trim();
            if let Some(rest) = line.strip_prefix("# speed function:") {
                name = rest.trim().to_string();
                continue;
            }
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut it = line.split('\t');
            let parse = |tok: Option<&str>| -> Result<f64, String> {
                tok.ok_or_else(|| format!("line {}: short row", lineno + 1))?
                    .parse()
                    .map_err(|_| format!("line {}: bad number", lineno + 1))
            };
            let x = parse(it.next())? as usize;
            let y = parse(it.next())? as usize;
            let s = parse(it.next())?;
            points.push((x, y, s));
        }
        if points.is_empty() {
            return Err(format!("fpm: no data points in {}", path.display()));
        }
        let mut xs: Vec<usize> = points.iter().map(|p| p.0).collect();
        let mut ys: Vec<usize> = points.iter().map(|p| p.1).collect();
        xs.sort_unstable();
        xs.dedup();
        ys.sort_unstable();
        ys.dedup();
        let mut fpm = SpeedFunction::new(&name, xs, ys);
        for (x, y, s) in points {
            fpm.set(x, y, s);
        }
        Ok(fpm)
    }
}

fn nearest_index(grid: &[usize], v: usize) -> usize {
    assert!(!grid.is_empty(), "empty grid");
    match grid.binary_search(&v) {
        Ok(i) => i,
        Err(0) => 0,
        Err(i) if i == grid.len() => grid.len() - 1,
        Err(i) => {
            if v - grid[i - 1] <= grid[i] - v {
                i - 1
            } else {
                i
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn demo_fpm() -> SpeedFunction {
        // speed rises with x, dips at y=256
        SpeedFunction::from_fn(
            "demo",
            vec![128, 256, 384, 512],
            vec![128, 256, 512],
            |x, y| {
                let base = 1000.0 + x as f64;
                Some(if y == 256 { base * 0.5 } else { base })
            },
        )
    }

    #[test]
    fn sub_resolution_and_nan_times_are_sanitized() {
        // regression: a fast point measured at ~0 ns (or a NaN mean from
        // a degenerate t-test) used to panic `speed_from_time` /
        // `Curve::new`; the ingestion point clamps/rejects instead
        assert_eq!(sanitize_time(0.0), Some(MIN_TIME_S));
        assert_eq!(sanitize_time(1e-15), Some(MIN_TIME_S));
        assert_eq!(sanitize_time(0.25), Some(0.25));
        assert_eq!(sanitize_time(f64::NAN), None);
        assert_eq!(sanitize_time(f64::INFINITY), None);
        assert_eq!(sanitize_time(-1.0), None);
        let s = speed_from_time_sanitized(128, 1024, 0.0).expect("clamped, not panicking");
        assert!(s > 0.0 && s.is_finite());
        assert_eq!(speed_from_time_sanitized(128, 1024, f64::NAN), None);
        // y = 1 has zero flops by the formula — speed 0 is rejected, not
        // fed into Curve::new's positivity assert
        assert_eq!(speed_from_time_sanitized(4, 1, 0.5), None);
    }

    #[test]
    fn speed_formula_roundtrip() {
        let t = 0.01;
        let s = speed_from_time(100, 1024, t);
        let t2 = time_from_speed(100, 1024, s);
        assert!((t - t2).abs() < 1e-12);
        // 2.5 * 1 * 2 * 1 = 5 flops in 1s = 5e-6 MFLOPs
        assert!((speed_from_time(1, 2, 1.0) - 5e-6).abs() < 1e-18);
    }

    #[test]
    fn variation_matches_eq1() {
        assert!((variation_pct(150.0, 100.0) - 50.0).abs() < 1e-12);
        assert!((variation_pct(100.0, 150.0) - 50.0).abs() < 1e-12);
        assert_eq!(variation_pct(100.0, 100.0), 0.0);
    }

    #[test]
    fn plane_section_extracts_row() {
        let f = demo_fpm();
        let c = f.plane_section(256);
        assert_eq!(c.xs, vec![128, 256, 384, 512]);
        assert!((c.speeds[0] - (1000.0 + 128.0) * 0.5).abs() < 1e-9);
        // snapping: y=300 snaps to 256
        assert_eq!(f.snap_y(300), 256);
        let c2 = f.plane_section(300);
        assert_eq!(c, c2);
    }

    #[test]
    fn column_section_extracts_col() {
        let f = demo_fpm();
        let c = f.column_section(384);
        assert_eq!(c.xs, vec![128, 256, 512]);
        assert!((c.speeds[1] - (1000.0 + 384.0) * 0.5).abs() < 1e-9);
    }

    #[test]
    fn missing_points_skipped() {
        let mut f = SpeedFunction::new("gappy", vec![1, 2], vec![10, 20]);
        f.set(1, 10, 5.0);
        f.set(2, 10, 6.0);
        f.set(1, 20, 7.0);
        // (2, 20) unmeasured — column_section(2) only has y=10
        let c = f.column_section(2);
        assert_eq!(c.xs, vec![10]);
        assert_eq!(f.measured_points(), 3);
    }

    #[test]
    fn curve_nearest_lookup() {
        let c = Curve::new(vec![10, 20, 40], vec![1.0, 2.0, 3.0]);
        assert_eq!(c.speed_at(20), Some(2.0));
        assert_eq!(c.speed_at(25), None);
        assert_eq!(c.speed_nearest(5), 1.0);
        assert_eq!(c.speed_nearest(24), 2.0);
        assert_eq!(c.speed_nearest(31), 3.0);
        assert_eq!(c.speed_nearest(100), 3.0);
    }

    #[test]
    #[should_panic(expected = "ascending")]
    fn curve_rejects_unsorted() {
        Curve::new(vec![2, 1], vec![1.0, 1.0]);
    }

    #[test]
    fn json_roundtrip_with_gaps() {
        let mut f = SpeedFunction::new("gappy", vec![1, 2], vec![10, 20]);
        f.set(1, 10, 5.5);
        f.set(2, 20, 7.25);
        let text = f.to_json().to_string();
        let g = SpeedFunction::from_json(&crate::util::json::Json::parse(&text).unwrap()).unwrap();
        assert_eq!(g.name, "gappy");
        assert_eq!(g.xs, f.xs);
        assert_eq!(g.ys, f.ys);
        assert_eq!(g.get(1, 10), Some(5.5));
        assert_eq!(g.get(2, 20), Some(7.25));
        assert_eq!(g.get(1, 20), None);
        assert_eq!(g.get(2, 10), None);
    }

    #[test]
    fn json_rejects_malformed() {
        use crate::util::json::Json;
        assert!(SpeedFunction::from_json(&Json::Null).is_err());
        let bad = Json::obj()
            .set("name", "x")
            .set("xs", vec![1usize, 2])
            .set("ys", vec![10usize])
            .set("speeds", Json::Arr(vec![Json::Num(1.0)])); // arity 1 != 2
        assert!(SpeedFunction::from_json(&bad).is_err());
    }

    #[test]
    fn tsv_roundtrip() {
        let f = demo_fpm();
        let path = std::env::temp_dir().join("hclfft_fpm_test/demo.tsv");
        f.write_tsv(&path).unwrap();
        let g = SpeedFunction::read_tsv(&path).unwrap();
        assert_eq!(g.name, "demo");
        assert_eq!(g.xs, f.xs);
        assert_eq!(g.ys, f.ys);
        for &x in &f.xs {
            for &y in &f.ys {
                let (a, b) = (f.get(x, y).unwrap(), g.get(x, y).unwrap());
                assert!((a - b).abs() < 1e-6);
            }
        }
    }
}
