//! `OnlineModel` — the model that learns from live traffic.
//!
//! Every served batch is a free `(x, y, t)` measurement. This model
//! folds those timings into per-point running estimates using the same
//! statistics as the paper's `MeanUsingTtest` methodology (Algorithm 8:
//! sample mean + Student's-t confidence interval, here streamed via
//! running sums instead of a closed measurement loop), and watches the
//! stream for *drift* with the paper's Eq-1 `variation_pct`: when the
//! mean of the most recent window of observations differs from the
//! established estimate by more than the drift threshold, the point is
//! re-based onto the new regime and a [`DriftEvent`] is emitted — the
//! serving layer reacts by invalidating the affected wisdom partitions
//! and re-planning.
//!
//! An `OnlineModel` usually wraps a *base* model (the profiler's
//! [`StaticModel`](crate::model::StaticModel) surfaces or the virtual
//! [`SimModel`](crate::model::SimModel)): refined point estimates win
//! where observations exist; section queries return the base sections
//! rescaled by the observed speed ratio, so POPTA/HPOPTA and pad
//! selection re-run against curves that follow the machine.
//!
//! Estimator invariants (property-tested in `proptests.rs`):
//! * the per-point estimate is order-invariant under permutation of a
//!   stationary sample stream (running sums, no order-dependent state);
//! * the *reported* confidence interval never widens as samples
//!   accumulate (it is the tightest CI achieved so far);
//! * the drift detector does not fire on a stationary stream whose
//!   noise is small relative to the threshold.

use std::collections::{BTreeMap, VecDeque};
use std::sync::Arc;

use crate::model::surface::{sanitize_time, variation_pct, Curve, MIN_TIME_S};
use crate::model::{PerfModel, Phase};
use crate::stats::ttest::t_inv_cdf;
use crate::util::json::Json;

/// Drift-detection knobs.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DriftPolicy {
    /// Eq-1 variation width (percent) between the established mean and
    /// the recent-window mean above which a point is declared drifted.
    pub drift_pct: f64,
    /// Size of the recent-observation window compared against the
    /// established estimate.
    pub window: usize,
    /// Observations a point must accumulate before drift checks begin
    /// (the establishment phase).
    pub min_established: u64,
    /// Confidence level for the reported interval (paper: 0.95).
    pub cl: f64,
    /// Drift is only *declared* once the established estimate itself is
    /// trustworthy: its reported relative CI must be at or below this
    /// (Algorithm 8's acceptance spirit). Keeps noisy real-engine
    /// timings (µs-scale batches) from firing spurious re-plans while
    /// the exact virtual-time path converges to CI 0 immediately.
    pub max_established_ci: f64,
}

impl Default for DriftPolicy {
    fn default() -> Self {
        DriftPolicy {
            drift_pct: 40.0,
            window: 4,
            min_established: 4,
            cl: 0.95,
            max_established_ci: 0.05,
        }
    }
}

/// What kind of machine change a drift event looks like, judged from
/// the phase-resolved observation streams at the drifted point.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum DriftClass {
    /// Both pipeline phases shifted together (or only the compute-bound
    /// row phase did): the machine computes at a different speed —
    /// frequency scaling, a different core set, thermal throttling.
    Compute,
    /// The memory-bound column phase shifted disproportionately: memory
    /// bandwidth changed — a co-tenant saturating the bus, NUMA
    /// migration, hugepage loss.
    Memory,
    /// No phase-resolved evidence at this point (phase streams too
    /// short, or the consumer only feeds whole-request timings).
    #[default]
    Unknown,
}

impl DriftClass {
    pub fn name(&self) -> &'static str {
        match self {
            DriftClass::Compute => "compute",
            DriftClass::Memory => "memory",
            DriftClass::Unknown => "unknown",
        }
    }

    pub fn parse(s: &str) -> DriftClass {
        match s {
            "compute" => DriftClass::Compute,
            "memory" => DriftClass::Memory,
            _ => DriftClass::Unknown,
        }
    }
}

/// One detected regime change at a model point.
#[derive(Clone, Debug, PartialEq)]
pub struct DriftEvent {
    pub x: usize,
    pub y: usize,
    /// established mean seconds before the shift
    pub expected_s: f64,
    /// recent-window mean seconds that contradicted it
    pub observed_s: f64,
    /// Eq-1 width between the two (percent)
    pub variation_pct: f64,
    /// model-wide observation count when the event fired
    pub at_observation: u64,
    /// compute vs memory-bandwidth judgement from the phase streams
    pub class: DriftClass,
}

/// Running estimate for one `(x, y)` point: established running sums
/// (order-invariant) plus the recent window the drift detector compares
/// against them.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct PointStat {
    count: u64,
    sum: f64,
    sumsq: f64,
    best_ci_rel: f64,
    window: Vec<f64>,
    /// regime changes this point has been through
    pub drift_count: u32,
}

impl PointStat {
    fn new() -> PointStat {
        PointStat { best_ci_rel: f64::INFINITY, ..PointStat::default() }
    }

    /// Total observations folded in (established + pending window).
    pub fn samples(&self) -> u64 {
        self.count + self.window.len() as u64
    }

    /// Mean over every observation since the last regime change.
    pub fn mean(&self) -> f64 {
        let n = self.samples();
        if n == 0 {
            return 0.0;
        }
        (self.sum + self.window.iter().sum::<f64>()) / n as f64
    }

    fn established_mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    /// Relative half-width of the Student's-t confidence interval over
    /// the current sample set (Algorithm 8's `clOut·reps/sum`), computed
    /// from running sums — order-invariant. Infinite below 2 samples.
    pub fn ci_rel(&self, cl: f64) -> f64 {
        let n = self.samples();
        if n < 2 {
            return f64::INFINITY;
        }
        let nf = n as f64;
        let sum = self.sum + self.window.iter().sum::<f64>();
        let sumsq = self.sumsq + self.window.iter().map(|v| v * v).sum::<f64>();
        let mean = sum / nf;
        if mean <= 0.0 {
            return f64::INFINITY;
        }
        let var = ((sumsq - sum * sum / nf) / (nf - 1.0)).max(0.0);
        let t = t_inv_cdf(cl, nf - 1.0);
        t * var.sqrt() / nf.sqrt() / mean
    }

    /// The tightest relative CI achieved so far — monotone non-widening
    /// as evidence accumulates (resets only on drift, a regime change).
    pub fn reported_ci_rel(&self) -> f64 {
        self.best_ci_rel
    }

    fn fold(&mut self, t: f64) {
        self.count += 1;
        self.sum += t;
        self.sumsq += t * t;
    }

    fn merge_window(&mut self) {
        for t in std::mem::take(&mut self.window) {
            self.fold(t);
        }
    }

    fn rebase_to_window(&mut self) {
        let win = std::mem::take(&mut self.window);
        self.count = 0;
        self.sum = 0.0;
        self.sumsq = 0.0;
        self.best_ci_rel = f64::INFINITY;
        self.drift_count += 1;
        for t in win {
            self.fold(t);
        }
    }
}

/// Running per-phase estimate at one point: established running sums
/// plus a bounded window of the most recent samples. Backs the
/// compute-vs-memory drift classification — never fires drift itself.
/// Live diagnostics only (not persisted; a fresh session re-learns the
/// phase split within a few served batches).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct PhaseStat {
    count: u64,
    sum: f64,
    recent: VecDeque<f64>,
}

impl PhaseStat {
    fn push(&mut self, t: f64, window: usize) {
        self.count += 1;
        self.sum += t;
        self.recent.push_back(t);
        while self.recent.len() > window.max(1) {
            self.recent.pop_front();
        }
    }

    /// Total samples folded in.
    pub fn samples(&self) -> u64 {
        self.count
    }

    /// Mean over every sample (both regimes' worth during a shift).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    /// Mean of every sample *before* the recent window — the phase's
    /// established regime. `None` until samples outnumber the window.
    pub fn established_mean(&self) -> Option<f64> {
        let k = self.recent.len() as u64;
        if self.count <= k {
            return None;
        }
        let rsum: f64 = self.recent.iter().sum();
        Some((self.sum - rsum) / (self.count - k) as f64)
    }

    /// Mean of the recent window.
    pub fn recent_mean(&self) -> Option<f64> {
        if self.recent.is_empty() {
            return None;
        }
        Some(self.recent.iter().sum::<f64>() / self.recent.len() as f64)
    }

    /// Eq-1 variation width (percent) between the established regime
    /// and the recent window — this phase's share of a detected shift.
    pub fn shift_pct(&self) -> Option<f64> {
        let e = self.established_mean()?;
        let r = self.recent_mean()?;
        Some(variation_pct(e.max(MIN_TIME_S), r.max(MIN_TIME_S)))
    }

    /// Start a new regime from the recent window (called when the
    /// whole-point drift detector declares a shift).
    fn rebase(&mut self) {
        self.count = self.recent.len() as u64;
        self.sum = self.recent.iter().sum();
        self.recent.clear();
    }
}

/// The live model: refined per-point estimates + drift log over an
/// optional base model.
#[derive(Clone)]
pub struct OnlineModel {
    name: String,
    policy: DriftPolicy,
    base: Option<Arc<dyn PerfModel>>,
    points: BTreeMap<(usize, usize), PointStat>,
    /// phase-resolved streams keyed (phase, x, y) — drift diagnostics
    phases: BTreeMap<(Phase, usize, usize), PhaseStat>,
    drift_log: Vec<DriftEvent>,
    observations: u64,
    dropped: u64,
}

impl std::fmt::Debug for OnlineModel {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("OnlineModel")
            .field("name", &self.name)
            .field("policy", &self.policy)
            .field("has_base", &self.base.is_some())
            .field("points", &self.points.len())
            .field("phase_streams", &self.phases.len())
            .field("drift_events", &self.drift_log.len())
            .field("observations", &self.observations)
            .field("dropped", &self.dropped)
            .finish()
    }
}

impl OnlineModel {
    pub fn new(name: &str, policy: DriftPolicy) -> OnlineModel {
        OnlineModel {
            name: name.to_string(),
            policy,
            base: None,
            points: BTreeMap::new(),
            phases: BTreeMap::new(),
            drift_log: Vec::new(),
            observations: 0,
            dropped: 0,
        }
    }

    pub fn with_base(mut self, base: Arc<dyn PerfModel>) -> OnlineModel {
        self.base = Some(base);
        self
    }

    /// Attach/replace the base model (e.g. after a fresh offline
    /// profiling pass refreshed the static surfaces).
    pub fn set_base(&mut self, base: Arc<dyn PerfModel>) {
        self.base = Some(base);
    }

    pub fn policy(&self) -> DriftPolicy {
        self.policy
    }

    /// Count of distinct refined points.
    pub fn len(&self) -> usize {
        self.points.len()
    }

    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }

    /// Total observations accepted (sanitized) so far.
    pub fn observations(&self) -> u64 {
        self.observations
    }

    /// Observations rejected by the sanitizer (NaN/negative times).
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    pub fn drift_events(&self) -> &[DriftEvent] {
        &self.drift_log
    }

    pub fn points(&self) -> impl Iterator<Item = (&(usize, usize), &PointStat)> {
        self.points.iter()
    }

    pub fn point(&self, x: usize, y: usize) -> Option<&PointStat> {
        self.points.get(&(x, y))
    }

    /// The phase-resolved stream at `(phase, x, y)`, if any arrived.
    pub fn phase_stat(&self, phase: Phase, x: usize, y: usize) -> Option<&PhaseStat> {
        self.phases.get(&(phase, x, y))
    }

    /// Mean (row, col) phase seconds at `(x, y)` — the phase breakdown
    /// drift re-plans inspect. `None` until both phases have samples.
    pub fn phase_breakdown(&self, x: usize, y: usize) -> Option<(f64, f64)> {
        let row = self.phases.get(&(Phase::Row, x, y)).filter(|p| p.samples() > 0)?;
        let col = self.phases.get(&(Phase::Col, x, y)).filter(|p| p.samples() > 0)?;
        Some((row.mean(), col.mean()))
    }

    /// Judge a just-detected whole-point shift from the phase streams,
    /// then rebase those streams onto the new regime. A shift counts as
    /// significant for a phase at half the whole-point drift threshold
    /// (phase streams are noisier than whole-request walls); the column
    /// phase dominating by 1.5× marks memory-bandwidth drift.
    fn classify_and_rebase_phases(&mut self, x: usize, y: usize) -> DriftClass {
        let sig = self.policy.drift_pct / 2.0;
        let row = self.phases.get(&(Phase::Row, x, y)).and_then(PhaseStat::shift_pct);
        let col = self.phases.get(&(Phase::Col, x, y)).and_then(PhaseStat::shift_pct);
        let class = match (row, col) {
            (Some(r), Some(c)) => {
                if c > sig && c > 1.5 * r {
                    DriftClass::Memory
                } else if r > sig {
                    DriftClass::Compute
                } else if c > sig {
                    DriftClass::Memory
                } else {
                    DriftClass::Unknown
                }
            }
            _ => DriftClass::Unknown,
        };
        for phase in [Phase::Row, Phase::Col] {
            if let Some(p) = self.phases.get_mut(&(phase, x, y)) {
                p.rebase();
            }
        }
        class
    }

    /// Refined time estimate at exactly `(x, y)` — observations only,
    /// never the base model. `None` until the point has at least two
    /// accepted samples.
    pub fn refined_time(&self, x: usize, y: usize) -> Option<f64> {
        let p = self.points.get(&(x, y))?;
        (p.samples() >= 2).then(|| p.mean())
    }

    /// Does any point carry enough samples to inform re-planning?
    pub fn has_refined(&self) -> bool {
        self.points.values().any(|p| p.samples() >= self.policy.min_established)
    }

    /// Observed speed ratio vs the base model (geometric mean of
    /// `base_time / observed_time` over refined points): < 1 means the
    /// machine runs slower than the base believed. 1.0 without a base
    /// or without refined data.
    pub fn speed_scale(&self) -> f64 {
        let Some(base) = &self.base else { return 1.0 };
        let mut logsum = 0.0;
        let mut k = 0usize;
        for ((x, y), p) in &self.points {
            if p.samples() < self.policy.min_established {
                continue;
            }
            let m = p.mean();
            if let Some(bt) = base.predict_time(*x, *y) {
                if bt > 0.0 && m > 0.0 {
                    logsum += (bt / m).ln();
                    k += 1;
                }
            }
        }
        if k == 0 {
            1.0
        } else {
            (logsum / k as f64).exp()
        }
    }

    fn scaled(&self, c: Curve) -> Curve {
        let s = self.speed_scale();
        if s == 1.0 || c.is_empty() {
            return c;
        }
        Curve::new(c.xs, c.speeds.into_iter().map(|v| v * s).collect())
    }
}

impl PerfModel for OnlineModel {
    fn model_name(&self) -> String {
        self.name.clone()
    }

    fn groups(&self) -> usize {
        self.base.as_ref().map_or(0, |b| b.groups())
    }

    /// Base sections rescaled by the observed speed ratio — the
    /// "refreshed sections" POPTA/HPOPTA re-run against after drift.
    fn plane_section(&self, g: usize, n: usize) -> Curve {
        match &self.base {
            Some(b) => self.scaled(b.plane_section(g, n)),
            None => Curve::new(Vec::new(), Vec::new()),
        }
    }

    fn column_section(&self, g: usize, d: usize, n: usize, window: usize) -> Curve {
        match &self.base {
            Some(b) => self.scaled(b.column_section(g, d, n, window)),
            None => Curve::new(Vec::new(), Vec::new()),
        }
    }

    fn predict_time(&self, x: usize, y: usize) -> Option<f64> {
        if let Some(t) = self.refined_time(x, y) {
            return Some(t);
        }
        let base = self.base.as_ref()?.predict_time(x, y)?;
        Some(base / self.speed_scale())
    }

    /// Fold one observation (sanitized here — the model layer's single
    /// ingestion point) and run the drift check.
    fn observe(&mut self, x: usize, y: usize, t_seconds: f64) -> Option<DriftEvent> {
        let Some(t) = sanitize_time(t_seconds) else {
            self.dropped += 1;
            return None;
        };
        self.observations += 1;
        let policy = self.policy;
        let at = self.observations;
        let p = self.points.entry((x, y)).or_insert_with(PointStat::new);
        let event = if p.count < policy.min_established {
            p.fold(t);
            None
        } else {
            p.window.push(t);
            if p.window.len() < policy.window {
                None
            } else {
                let wmean = p.window.iter().sum::<f64>() / p.window.len() as f64;
                let emean = p.established_mean();
                let width = variation_pct(emean.max(MIN_TIME_S), wmean.max(MIN_TIME_S));
                if width > policy.drift_pct && p.best_ci_rel <= policy.max_established_ci {
                    p.rebase_to_window();
                    Some(DriftEvent {
                        x,
                        y,
                        expected_s: emean,
                        observed_s: wmean,
                        variation_pct: width,
                        at_observation: at,
                        class: DriftClass::Unknown,
                    })
                } else {
                    p.merge_window();
                    None
                }
            }
        };
        let ci = p.ci_rel(policy.cl);
        if ci < p.best_ci_rel {
            p.best_ci_rel = ci;
        }
        // classify from the phase streams *before* they rebase (the
        // point borrow above has ended; the streams still hold the
        // pre-shift regime as their established means)
        let event = event.map(|mut e| {
            e.class = self.classify_and_rebase_phases(x, y);
            e
        });
        if let Some(e) = &event {
            self.drift_log.push(e.clone());
        }
        event
    }

    /// Fold a phase-resolved timing (sanitized like every observation).
    /// Phase streams never fire drift — they feed the classification
    /// attached to whole-point drift events.
    fn observe_phase(&mut self, phase: Phase, x: usize, y: usize, t_seconds: f64) {
        if phase == Phase::Whole {
            let _ = self.observe(x, y, t_seconds);
            return;
        }
        let Some(t) = sanitize_time(t_seconds) else {
            self.dropped += 1;
            return;
        };
        let window = self.policy.window;
        self.phases.entry((phase, x, y)).or_default().push(t, window);
    }
}

impl OnlineModel {
    /// Serialize the model deltas + drift log (the base model is not
    /// persisted — it is reattached from the wisdom surfaces / the
    /// simulator at load time). Pending window samples are folded into
    /// the persisted sums.
    pub fn to_json(&self) -> Json {
        let points: Vec<Json> = self
            .points
            .iter()
            .map(|(&(x, y), p)| {
                let winsum: f64 = p.window.iter().sum();
                let winsumsq: f64 = p.window.iter().map(|v| v * v).sum();
                let mut o = Json::obj()
                    .set("x", x)
                    .set("y", y)
                    .set("count", p.samples() as i64)
                    .set("sum", p.sum + winsum)
                    .set("sumsq", p.sumsq + winsumsq)
                    .set("drift_count", p.drift_count as i64);
                if p.best_ci_rel.is_finite() {
                    o = o.set("best_ci_rel", p.best_ci_rel);
                }
                o
            })
            .collect();
        let drift: Vec<Json> = self
            .drift_log
            .iter()
            .map(|e| {
                Json::obj()
                    .set("x", e.x)
                    .set("y", e.y)
                    .set("expected_s", e.expected_s)
                    .set("observed_s", e.observed_s)
                    .set("variation_pct", e.variation_pct)
                    .set("at_observation", e.at_observation as i64)
                    .set("class", e.class.name())
            })
            .collect();
        Json::obj()
            .set("name", self.name.as_str())
            .set("drift_pct", self.policy.drift_pct)
            .set("window", self.policy.window)
            .set("min_established", self.policy.min_established as i64)
            .set("cl", self.policy.cl)
            .set("max_established_ci", self.policy.max_established_ci)
            .set("observations", self.observations as i64)
            .set("dropped", self.dropped as i64)
            .set("points", Json::Arr(points))
            .set("drift_log", Json::Arr(drift))
    }

    /// Inverse of [`OnlineModel::to_json`] (base left unattached).
    pub fn from_json(j: &Json) -> Result<OnlineModel, String> {
        let name =
            j.get("name").and_then(Json::as_str).ok_or("model json: missing name")?.to_string();
        let f = |k: &str| j.get(k).and_then(Json::as_f64).ok_or(format!("model json: missing {k}"));
        let u = |k: &str| {
            j.get(k).and_then(Json::as_usize).ok_or(format!("model json: missing {k}"))
        };
        let policy = DriftPolicy {
            drift_pct: f("drift_pct")?,
            window: u("window")?,
            min_established: u("min_established")? as u64,
            cl: f("cl")?,
            max_established_ci: j
                .get("max_established_ci")
                .and_then(Json::as_f64)
                .unwrap_or_else(|| DriftPolicy::default().max_established_ci),
        };
        let mut m = OnlineModel::new(&name, policy);
        m.observations = u("observations")? as u64;
        m.dropped = u("dropped")? as u64;
        for pj in j.get("points").and_then(Json::as_arr).ok_or("model json: missing points")? {
            let pu = |k: &str| {
                pj.get(k).and_then(Json::as_usize).ok_or(format!("model json: bad point {k}"))
            };
            let pf = |k: &str| {
                pj.get(k).and_then(Json::as_f64).ok_or(format!("model json: bad point {k}"))
            };
            let stat = PointStat {
                count: pu("count")? as u64,
                sum: pf("sum")?,
                sumsq: pf("sumsq")?,
                best_ci_rel: pj
                    .get("best_ci_rel")
                    .and_then(Json::as_f64)
                    .unwrap_or(f64::INFINITY),
                window: Vec::new(),
                drift_count: pu("drift_count")? as u32,
            };
            m.points.insert((pu("x")?, pu("y")?), stat);
        }
        for ej in j.get("drift_log").and_then(Json::as_arr).unwrap_or(&[]) {
            let eu = |k: &str| {
                ej.get(k).and_then(Json::as_usize).ok_or(format!("model json: bad drift {k}"))
            };
            let ef = |k: &str| {
                ej.get(k).and_then(Json::as_f64).ok_or(format!("model json: bad drift {k}"))
            };
            m.drift_log.push(DriftEvent {
                x: eu("x")?,
                y: eu("y")?,
                expected_s: ef("expected_s")?,
                observed_s: ef("observed_s")?,
                variation_pct: ef("variation_pct")?,
                at_observation: eu("at_observation")? as u64,
                // absent in pre-pipeline files — loads as Unknown
                class: ej
                    .get("class")
                    .and_then(Json::as_str)
                    .map(DriftClass::parse)
                    .unwrap_or_default(),
            });
        }
        Ok(m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{SpeedFunction, StaticModel};

    fn flat_base(speed: f64) -> Arc<dyn PerfModel> {
        Arc::new(StaticModel::new(
            (0..2)
                .map(|g| {
                    SpeedFunction::from_fn(
                        &format!("b{g}"),
                        vec![64, 128, 256],
                        vec![128, 256],
                        move |_, _| Some(speed),
                    )
                })
                .collect(),
        ))
    }

    #[test]
    fn refines_toward_observed_mean() {
        let mut m = OnlineModel::new("t", DriftPolicy::default());
        assert_eq!(m.refined_time(256, 128), None);
        for _ in 0..6 {
            assert!(m.observe(256, 128, 0.02).is_none());
        }
        let t = m.refined_time(256, 128).unwrap();
        assert!((t - 0.02).abs() < 1e-12);
        assert_eq!(m.observations(), 6);
        assert!(m.has_refined());
    }

    #[test]
    fn sanitizer_drops_nan_and_clamps_zero() {
        // regression for the sub-resolution timing panic: neither input
        // may panic, NaN must be dropped, ~0 must clamp to MIN_TIME_S
        let mut m = OnlineModel::new("t", DriftPolicy::default());
        assert!(m.observe(64, 128, f64::NAN).is_none());
        assert_eq!(m.dropped(), 1);
        assert_eq!(m.observations(), 0);
        m.observe(64, 128, 0.0);
        m.observe(64, 128, 0.0);
        assert_eq!(m.refined_time(64, 128), Some(MIN_TIME_S));
    }

    #[test]
    fn drift_fires_on_regime_shift_and_rebases() {
        let mut m = OnlineModel::new("t", DriftPolicy::default());
        for _ in 0..8 {
            assert!(m.observe(256, 128, 0.01).is_none(), "stationary stream must not drift");
        }
        // 3x slowdown: the 4-observation window contradicts the mean
        let mut fired = None;
        for _ in 0..4 {
            fired = m.observe(256, 128, 0.03);
        }
        let e = fired.expect("drift within one window");
        assert!((e.expected_s - 0.01).abs() < 1e-12);
        assert!((e.observed_s - 0.03).abs() < 1e-12);
        assert!(e.variation_pct > 100.0);
        assert_eq!(m.drift_events().len(), 1);
        // estimate re-based onto the new regime
        assert!((m.refined_time(256, 128).unwrap() - 0.03).abs() < 1e-12);
        assert_eq!(m.point(256, 128).unwrap().drift_count, 1);
    }

    /// Drive a point past establishment with per-phase timings, then
    /// shift the regime by `row_f`/`col_f` and return the drift event.
    fn drift_with_phases(row_s: f64, col_s: f64, row_f: f64, col_f: f64) -> DriftEvent {
        let mut m = OnlineModel::new("t", DriftPolicy::default());
        let (x, y) = (256usize, 128usize);
        for _ in 0..8 {
            m.observe_phase(Phase::Row, x, y, row_s);
            m.observe_phase(Phase::Col, x, y, col_s);
            assert!(m.observe(x, y, row_s + col_s).is_none());
        }
        let mut fired = None;
        for _ in 0..4 {
            m.observe_phase(Phase::Row, x, y, row_s * row_f);
            m.observe_phase(Phase::Col, x, y, col_s * col_f);
            fired = m.observe(x, y, row_s * row_f + col_s * col_f);
        }
        fired.expect("shift must fire drift within one window")
    }

    #[test]
    fn memory_drift_classified_from_column_phase() {
        // only the memory-bound column phase slows: bandwidth drift
        let e = drift_with_phases(0.01, 0.01, 1.0, 4.0);
        assert_eq!(e.class, DriftClass::Memory, "{e:?}");
    }

    #[test]
    fn compute_drift_classified_from_uniform_shift() {
        // both phases slow together: the machine computes slower
        let e = drift_with_phases(0.01, 0.01, 3.0, 3.0);
        assert_eq!(e.class, DriftClass::Compute, "{e:?}");
    }

    #[test]
    fn drift_without_phase_streams_is_unknown() {
        let mut m = OnlineModel::new("t", DriftPolicy::default());
        for _ in 0..8 {
            assert!(m.observe(256, 128, 0.01).is_none());
        }
        let mut fired = None;
        for _ in 0..4 {
            fired = m.observe(256, 128, 0.03);
        }
        assert_eq!(fired.unwrap().class, DriftClass::Unknown);
    }

    #[test]
    fn phase_breakdown_reports_means() {
        let mut m = OnlineModel::new("t", DriftPolicy::default());
        assert_eq!(m.phase_breakdown(64, 64), None);
        for _ in 0..3 {
            m.observe_phase(Phase::Row, 64, 64, 0.02);
            m.observe_phase(Phase::Col, 64, 64, 0.01);
        }
        let (r, c) = m.phase_breakdown(64, 64).unwrap();
        assert!((r - 0.02).abs() < 1e-12 && (c - 0.01).abs() < 1e-12);
        // phase streams are sanitized like whole observations
        m.observe_phase(Phase::Row, 64, 64, f64::NAN);
        assert_eq!(m.dropped(), 1);
        // Whole delegates to observe()
        m.observe_phase(Phase::Whole, 64, 64, 0.03);
        assert_eq!(m.point(64, 64).unwrap().samples(), 1);
    }

    #[test]
    fn drift_class_json_roundtrips_and_v2_defaults_unknown() {
        let e = drift_with_phases(0.01, 0.02, 1.0, 5.0);
        assert_eq!(DriftClass::parse(e.class.name()), e.class);
        // a v2 drift entry without `class` loads as Unknown
        let mut m = OnlineModel::new("t", DriftPolicy::default());
        for _ in 0..8 {
            m.observe(8, 8, 0.01);
        }
        for _ in 0..4 {
            m.observe(8, 8, 0.05);
        }
        let mut j = Json::parse(&m.to_json().to_string()).unwrap();
        // strip the class field to simulate an old file
        if let Json::Obj(pairs) = &mut j {
            for (k, v) in pairs.iter_mut() {
                if k == "drift_log" {
                    if let Json::Arr(evs) = v {
                        for ev in evs.iter_mut() {
                            if let Json::Obj(fields) = ev {
                                fields.retain(|(k, _)| k != "class");
                            }
                        }
                    }
                }
            }
        }
        let back = OnlineModel::from_json(&j).unwrap();
        assert_eq!(back.drift_events()[0].class, DriftClass::Unknown);
    }

    #[test]
    fn sections_rescale_with_observed_speed() {
        let base = flat_base(100.0);
        let mut m = OnlineModel::new("t", DriftPolicy::default()).with_base(base.clone());
        let before = m.plane_section(0, 128);
        // observe the machine running 2x slower than the base predicts
        let base_t = base.predict_time(256, 128).unwrap();
        for _ in 0..6 {
            m.observe(256, 128, base_t * 2.0);
        }
        let scale = m.speed_scale();
        assert!((scale - 0.5).abs() < 1e-9, "scale {scale}");
        let after = m.plane_section(0, 128);
        for (a, b) in after.speeds.iter().zip(&before.speeds) {
            assert!((a - b * 0.5).abs() < 1e-9);
        }
        // predictions without refined data also rescale
        let pred = m.predict_time(128, 128).unwrap();
        let unscaled = base.predict_time(128, 128).unwrap();
        assert!((pred - unscaled * 2.0).abs() < 1e-12);
    }

    #[test]
    fn reported_ci_is_monotone_and_json_roundtrips() {
        let mut m = OnlineModel::new("t", DriftPolicy::default());
        let mut last = f64::INFINITY;
        for i in 0..32u32 {
            m.observe(128, 128, 0.01 * (1.0 + 0.03 * ((i % 5) as f64 - 2.0)));
            let ci = m.point(128, 128).unwrap().reported_ci_rel();
            assert!(ci <= last + 1e-15, "CI widened: {ci} > {last}");
            last = ci;
        }
        assert!(last.is_finite());
        let j = Json::parse(&m.to_json().to_string()).unwrap();
        let back = OnlineModel::from_json(&j).unwrap();
        assert_eq!(back.observations(), m.observations());
        assert_eq!(back.len(), 1);
        let (a, b) = (back.point(128, 128).unwrap(), m.point(128, 128).unwrap());
        assert_eq!(a.samples(), b.samples());
        assert!((a.mean() - b.mean()).abs() < 1e-15);
        assert_eq!(back.drift_events(), m.drift_events());
    }
}
