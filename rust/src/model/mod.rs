//! The unified performance-model subsystem.
//!
//! Everything the planning, padding, scheduling, admission and wisdom
//! layers know about machine performance flows through this module.
//! Before this layer existed the FPM machinery was scattered across four
//! places (coordinator types, offline profiler builds, simulator virtual
//! surfaces, frozen wisdom surfaces) and never improved after startup —
//! even though every served batch is a free `(x, y, t)` measurement.
//!
//! * [`surface`] — the shared data types: discrete 3D speed surfaces
//!   ([`SpeedFunction`]), section curves ([`Curve`]), the paper's speed
//!   formula, Eq-1 variation width, and the *single sanitized ingestion
//!   point* for raw timings ([`sanitize_time`],
//!   [`speed_from_time_sanitized`]).
//! * [`PerfModel`] — the trait every consumer plans against: plane
//!   sections (POPTA/HPOPTA partitioning), column sections (pad
//!   selection), whole-platform time prediction (SPJF scheduling +
//!   admission), and observation folding (online refinement).
//! * [`StaticModel`] — measured surfaces from the offline profiler or a
//!   persisted wisdom record (the paper's frozen §V artifact).
//! * [`SimModel`] — the calibrated virtual testbed
//!   ([`crate::simulator::fpm::SimTestbed`]) behind the same trait.
//! * [`OnlineModel`] — learns from live traffic: folds per-batch timings
//!   into per-point running estimates (the `MeanUsingTtest` statistics,
//!   streamed), detects drift via the paper's Eq-1 `variation_pct`, and
//!   lets the serving layer invalidate wisdom and re-plan against
//!   sections rescaled to the machine's current speed.
//! * [`PortfolioModel`] — lifts the modeling one level up, to the
//!   paper's *package* axis: per-engine cost surfaces keyed
//!   `(engine, n, kind)` answer which registered engine should run a
//!   request, with drift on the incumbent forcing a re-pick.

pub mod online;
pub mod portfolio;
pub mod sim;
pub mod static_model;
pub mod surface;

pub use online::{DriftClass, DriftEvent, DriftPolicy, OnlineModel, PhaseStat, PointStat};
pub use portfolio::{PortfolioModel, RepickEvent};
pub use sim::SimModel;
pub use static_model::StaticModel;
pub use surface::{
    sanitize_time, speed_from_time, speed_from_time_sanitized, time_from_speed, variation_pct,
    Curve, SpeedFunction, MIN_TIME_S,
};

/// Which part of a 2D pipeline execution a timing observation covers.
///
/// The serving executor times the two stages of every forward batch
/// separately: the row-FFT stage (compute-bound) and the column stage
/// (the strided gather/FFT/scatter tiles under the fused pipeline, the
/// transpose passes under the barrier path — memory-bound either way).
/// Phase-resolved observations let the drift detector tell a machine
/// that *computes* slower from one whose *memory bandwidth* degraded
/// (e.g. a co-tenant saturating the bus): the former shifts both
/// phases, the latter shifts the column phase disproportionately.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Phase {
    /// Whole-request wall time (the prediction/observation point).
    Whole,
    /// The row-FFT stage.
    Row,
    /// The column stage (strided tiles / transposes).
    Col,
}

impl Phase {
    pub fn name(&self) -> &'static str {
        match self {
            Phase::Whole => "whole",
            Phase::Row => "row",
            Phase::Col => "col",
        }
    }
}

/// A performance model of one execution platform: `groups()` abstract
/// processors with per-group speed sections and a whole-platform time
/// predictor. The geometric queries mirror the paper's two FPM
/// operations (§III-C/D); `predict_time`/`observe` close the loop that
/// turns the offline method into an adaptive serving system.
pub trait PerfModel: Send + Sync {
    /// Model name for reports.
    fn model_name(&self) -> String;

    /// Number of abstract processors the model describes.
    fn groups(&self) -> usize;

    /// Plane section `y = n` for group `g` (0-based): the speed-vs-x
    /// curve POPTA/HPOPTA partition over. May be empty when the model
    /// has no data for the group.
    fn plane_section(&self, g: usize, n: usize) -> Curve;

    /// Column section `x = d` for group `g`: the speed-vs-y curve pad
    /// selection searches, restricted to `y <= n + window` (candidates
    /// above `n`, plus the unpadded reference at/below `n`).
    fn column_section(&self, g: usize, d: usize, n: usize, window: usize) -> Curve;

    /// Predicted whole-platform seconds for executing `x` row 1D-FFTs of
    /// length `y` (all groups working concurrently). `None` when the
    /// model has no information near `(x, y)`.
    fn predict_time(&self, x: usize, y: usize) -> Option<f64>;

    /// Fold one timing observation into the model (no-op for models that
    /// cannot learn). Returns a drift event when the observation stream
    /// contradicts the model's established estimate.
    fn observe(&mut self, _x: usize, _y: usize, _t_seconds: f64) -> Option<DriftEvent> {
        None
    }

    /// Fold one *phase-resolved* timing observation ([`Phase::Row`] /
    /// [`Phase::Col`] of the 2D pipeline) into the model. Phase streams
    /// never fire drift themselves — they feed the compute-vs-memory
    /// classification attached to whole-point drift events. No-op for
    /// models that cannot learn; [`Phase::Whole`] delegates to
    /// [`PerfModel::observe`] (the returned event, if any, is dropped —
    /// drive whole-point observations through `observe` directly).
    fn observe_phase(&mut self, phase: Phase, x: usize, y: usize, t_seconds: f64) {
        if phase == Phase::Whole {
            let _ = self.observe(x, y, t_seconds);
        }
    }
}

/// Shared `predict_time` implementation for section-backed models: each
/// group contributes the speed of its balanced share `x / p` at row
/// length `y`; the summed speed prices the whole platform.
pub(crate) fn predict_time_via_sections(model: &dyn PerfModel, x: usize, y: usize) -> Option<f64> {
    let p = model.groups().max(1);
    let share = (x / p).max(1);
    let mut total = 0.0;
    let mut informed = 0usize;
    for g in 0..p {
        let section = model.plane_section(g, y);
        if !section.is_empty() {
            total += section.speed_nearest(share);
            informed += 1;
        }
    }
    if informed == 0 || total <= 0.0 {
        return None;
    }
    // uninformed groups contribute no speed: the estimate degrades
    // conservatively (longer predicted time) instead of guessing
    Some(time_from_speed(x, y, total))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn section_prediction_sums_group_speeds() {
        let fpms: Vec<SpeedFunction> = (0..2)
            .map(|g| {
                SpeedFunction::from_fn("m", vec![64, 128], vec![128], move |_, _| {
                    Some(100.0 * (g + 1) as f64)
                })
            })
            .collect();
        let m = StaticModel::new(fpms);
        // total speed 300 MFLOPs pricing 128 rows of length 128
        let t = m.predict_time(128, 128).unwrap();
        let want = time_from_speed(128, 128, 300.0);
        assert!((t - want).abs() < 1e-12, "{t} vs {want}");
    }

    #[test]
    fn empty_model_predicts_nothing() {
        let m = StaticModel::new(vec![SpeedFunction::new("e", vec![1, 2], vec![128])]);
        assert_eq!(m.predict_time(4, 128), None);
    }
}
