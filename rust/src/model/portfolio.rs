//! `PortfolioModel` — model-driven choice *among* engines.
//!
//! The paper's central move is selecting among FFT packages (FFTW-2,
//! FFTW-3, MKL) by their measured performance models; the repo's serving
//! layer previously planned only *within* one engine per service —
//! engine choice was a config knob, never a model output. This module
//! makes that choice the model's job:
//!
//! * the portfolio holds one **cost surface per member engine**, keyed
//!   `(engine, n, kind)` — whole-platform predicted seconds for a 2D
//!   transform of size `n` and transform kind, profiled cold (wisdom
//!   records / simulator beliefs) and refined by the same per-engine
//!   [`OnlineModel`](crate::model::OnlineModel) streams that already
//!   drive drift detection,
//! * [`PortfolioModel::best_engine`] answers "which engine runs this
//!   request" — the admission-side resolution that must happen *before*
//!   bucketing, because batch buckets key on the engine,
//! * picks are **sticky**: once an incumbent wins `(n, kind)` it keeps
//!   winning (no flapping on noise) until
//!   [`PortfolioModel::note_drift`] invalidates every pick held by a
//!   drifted engine — the next request at that point re-resolves against
//!   the refreshed surfaces, and an actual engine change is recorded in
//!   the [`RepickEvent`] log.
//!
//! Surfaces are persisted in wisdom JSON v5 (a `"portfolio"` object next
//! to `records`/`models`/`tiles`); v4 files load with an empty
//! portfolio. See the README "Engine portfolio" section for the
//! lifecycle walk-through.

use std::collections::BTreeMap;

use crate::coordinator::engine::EngineId;
use crate::dft::real::TransformKind;
use crate::util::json::Json;

/// One logged engine change: drift on `from` invalidated the pick at
/// `(n, kind)` and the next resolution chose `to`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RepickEvent {
    pub n: usize,
    pub kind: TransformKind,
    pub from: EngineId,
    pub to: EngineId,
}

impl std::fmt::Display for RepickEvent {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "n {} {} {} -> {}", self.n, self.kind.name(), self.from, self.to)
    }
}

/// Per-`(engine, n, kind)` cost surfaces plus the sticky pick cache.
///
/// `best_engine` is deterministic: exact-point surfaces first, nearest-n
/// fallback scaled by the `n² log n` work ratio, ties broken by member
/// registration order.
#[derive(Clone, Debug, Default)]
pub struct PortfolioModel {
    members: Vec<EngineId>,
    /// predicted whole-transform seconds per (engine, n, kind)
    surfaces: BTreeMap<(EngineId, usize, TransformKind), f64>,
    /// sticky incumbents per (n, kind)
    picks: BTreeMap<(usize, TransformKind), EngineId>,
    /// old incumbents whose pick was drift-invalidated, awaiting the
    /// re-resolution that decides whether an actual switch happened
    pending: BTreeMap<(usize, TransformKind), EngineId>,
    repicks: Vec<RepickEvent>,
}

impl PortfolioModel {
    /// A portfolio over `members` (registration order breaks cost ties).
    /// `Portfolio` itself is not a member and is skipped if passed.
    pub fn new(members: Vec<EngineId>) -> PortfolioModel {
        let mut seen = Vec::new();
        for m in members {
            if m != EngineId::Portfolio && !seen.contains(&m) {
                seen.push(m);
            }
        }
        PortfolioModel { members: seen, ..PortfolioModel::default() }
    }

    pub fn members(&self) -> &[EngineId] {
        &self.members
    }

    /// Replace the member list (a service restart may register a
    /// different engine set than the persisted portfolio knew).
    /// Surfaces are kept — they stay keyed by engine and re-apply if the
    /// member returns — but picks held by engines no longer registered
    /// are dropped so resolution cannot route to a missing backend.
    pub fn set_members(&mut self, members: Vec<EngineId>) {
        let fresh = PortfolioModel::new(members);
        let keep = fresh.members;
        self.picks.retain(|_, e| keep.contains(e));
        self.pending.retain(|_, e| keep.contains(e));
        self.members = keep;
    }

    pub fn is_empty(&self) -> bool {
        self.members.is_empty() && self.surfaces.is_empty()
    }

    /// Install/overwrite the cold-profiled cost at one surface point.
    pub fn set_surface(&mut self, engine: EngineId, n: usize, kind: TransformKind, seconds: f64) {
        if seconds.is_finite() && seconds > 0.0 {
            self.surfaces.insert((engine, n, kind), seconds);
        }
    }

    /// Fold one observed/refined cost into the surface: new points are
    /// installed as-is, existing points blend (equal-weight EWMA) so a
    /// single noisy batch cannot swing the portfolio.
    pub fn observe_cost(&mut self, engine: EngineId, n: usize, kind: TransformKind, seconds: f64) {
        if !(seconds.is_finite() && seconds > 0.0) {
            return;
        }
        let slot = self.surfaces.entry((engine, n, kind)).or_insert(seconds);
        *slot = 0.5 * *slot + 0.5 * seconds;
    }

    /// The stored cost at an exact surface point.
    pub fn surface(&self, engine: EngineId, n: usize, kind: TransformKind) -> Option<f64> {
        self.surfaces.get(&(engine, n, kind)).copied()
    }

    /// Number of stored surface points.
    pub fn surface_len(&self) -> usize {
        self.surfaces.len()
    }

    /// Estimated cost for `engine` at `(n, kind)`: the exact point if
    /// stored, else the nearest-n point for the same `(engine, kind)`
    /// scaled by the `n² log₂ n` 2D-FFT work ratio. `None` when the
    /// engine has no surface data for this kind at all.
    pub fn estimate(&self, engine: EngineId, n: usize, kind: TransformKind) -> Option<f64> {
        if let Some(t) = self.surfaces.get(&(engine, n, kind)) {
            return Some(*t);
        }
        let mut best: Option<(usize, f64)> = None;
        for (&(e, sn, k), &t) in &self.surfaces {
            if e == engine && k == kind {
                let dist = sn.abs_diff(n);
                if best.map(|(d, _)| dist < d).unwrap_or(true) {
                    best = Some((dist, t * work_ratio(n, sn)));
                }
            }
        }
        best.map(|(_, t)| t)
    }

    /// Resolve the engine that should run a `(n, kind)` request.
    ///
    /// Sticky: a cached incumbent is returned without re-scoring until
    /// [`note_drift`](PortfolioModel::note_drift) evicts it. On a cold
    /// or evicted point the members are scored via
    /// [`estimate`](PortfolioModel::estimate) (missing data loses to any
    /// data; all-missing falls back to the first member so admission
    /// always has an answer), the winner is cached, and — if the point
    /// was drift-evicted and the winner differs from the old incumbent —
    /// a [`RepickEvent`] is logged.
    ///
    /// `p` (requested thread budget) is accepted for signature stability
    /// but does not discriminate yet: each member executes at its own
    /// paper-best grouping, so the surfaces are already per-engine
    /// whole-platform costs.
    pub fn best_engine(&mut self, n: usize, kind: TransformKind, p: usize) -> Option<EngineId> {
        let _ = p;
        if let Some(&e) = self.picks.get(&(n, kind)) {
            return Some(e);
        }
        let mut winner: Option<(EngineId, f64)> = None;
        for &m in &self.members {
            if let Some(t) = self.estimate(m, n, kind) {
                if winner.map(|(_, best)| t < best).unwrap_or(true) {
                    winner = Some((m, t));
                }
            }
        }
        let pick = winner.map(|(e, _)| e).or_else(|| self.members.first().copied())?;
        self.picks.insert((n, kind), pick);
        if let Some(old) = self.pending.remove(&(n, kind)) {
            if old != pick {
                self.repicks.push(RepickEvent { n, kind, from: old, to: pick });
            }
        }
        Some(pick)
    }

    /// Peek at the cached incumbent without resolving.
    pub fn pick(&self, n: usize, kind: TransformKind) -> Option<EngineId> {
        self.picks.get(&(n, kind)).copied()
    }

    /// All cached incumbents, ordered by `(n, kind)`.
    pub fn picks(&self) -> Vec<(usize, TransformKind, EngineId)> {
        self.picks.iter().map(|(&(n, k), &e)| (n, k, e)).collect()
    }

    /// The drift detector fired on `engine`: evict every pick it holds
    /// so those points re-resolve against the refreshed surfaces.
    /// Returns how many picks were evicted.
    pub fn note_drift(&mut self, engine: EngineId) -> usize {
        let evicted: Vec<(usize, TransformKind)> = self
            .picks
            .iter()
            .filter(|(_, &e)| e == engine)
            .map(|(&key, _)| key)
            .collect();
        for key in &evicted {
            self.picks.remove(key);
            self.pending.insert(*key, engine);
        }
        evicted.len()
    }

    /// Scale every surface point of `engine` by `time_factor` (> 1 =
    /// slower). The serving layer applies the drift event's observed
    /// speed change so the very next re-pick sees the degraded engine —
    /// without waiting for fresh per-point observations to trickle in.
    pub fn scale_engine(&mut self, engine: EngineId, time_factor: f64) {
        if !(time_factor.is_finite() && time_factor > 0.0) {
            return;
        }
        for ((e, _, _), t) in self.surfaces.iter_mut() {
            if *e == engine {
                *t *= time_factor;
            }
        }
    }

    /// Chronological log of actual engine changes (drift → re-pick).
    pub fn repick_log(&self) -> &[RepickEvent] {
        &self.repicks
    }

    /// Wisdom v5 `"portfolio"` object.
    pub fn to_json(&self) -> Json {
        let members: Vec<Json> =
            self.members.iter().map(|m| Json::Str(m.as_str().to_string())).collect();
        let surfaces: Vec<Json> = self
            .surfaces
            .iter()
            .map(|(&(e, n, k), &t)| {
                Json::obj()
                    .set("engine", e.as_str())
                    .set("n", n)
                    .set("kind", k.name())
                    .set("t", t)
            })
            .collect();
        let picks: Vec<Json> = self
            .picks
            .iter()
            .map(|(&(n, k), &e)| {
                Json::obj().set("n", n).set("kind", k.name()).set("engine", e.as_str())
            })
            .collect();
        Json::obj()
            .set("members", Json::Arr(members))
            .set("surfaces", Json::Arr(surfaces))
            .set("picks", Json::Arr(picks))
    }

    /// Parse a persisted portfolio. Unknown engine names are a hard
    /// error — the typed id layer does not silently drop surfaces.
    pub fn from_json(j: &Json) -> Result<PortfolioModel, String> {
        let engine_of = |j: &Json, ctx: &str| -> Result<EngineId, String> {
            let s = j
                .get("engine")
                .and_then(Json::as_str)
                .ok_or_else(|| format!("portfolio {ctx}: missing engine"))?;
            EngineId::parse(s).ok_or_else(|| format!("portfolio {ctx}: unknown engine `{s}`"))
        };
        let kind_of = |j: &Json, ctx: &str| -> Result<TransformKind, String> {
            let s = j
                .get("kind")
                .and_then(Json::as_str)
                .ok_or_else(|| format!("portfolio {ctx}: missing kind"))?;
            TransformKind::parse(s).ok_or_else(|| format!("portfolio {ctx}: unknown kind `{s}`"))
        };
        let mut members = Vec::new();
        if let Some(arr) = j.get("members").and_then(Json::as_arr) {
            for m in arr {
                let s = m.as_str().ok_or("portfolio members: non-string entry")?;
                members.push(
                    EngineId::parse(s)
                        .ok_or_else(|| format!("portfolio members: unknown engine `{s}`"))?,
                );
            }
        }
        let mut out = PortfolioModel::new(members);
        if let Some(arr) = j.get("surfaces").and_then(Json::as_arr) {
            for s in arr {
                let e = engine_of(s, "surface")?;
                let n = s
                    .get("n")
                    .and_then(Json::as_usize)
                    .ok_or("portfolio surface: bad n")?;
                let k = kind_of(s, "surface")?;
                let t = s.get("t").and_then(Json::as_f64).ok_or("portfolio surface: bad t")?;
                out.set_surface(e, n, k, t);
            }
        }
        if let Some(arr) = j.get("picks").and_then(Json::as_arr) {
            for p in arr {
                let e = engine_of(p, "pick")?;
                let n = p.get("n").and_then(Json::as_usize).ok_or("portfolio pick: bad n")?;
                let k = kind_of(p, "pick")?;
                out.picks.insert((n, k), e);
            }
        }
        Ok(out)
    }
}

/// `n² log₂ n` work ratio for scaling a cost from size `from` to `to`.
fn work_ratio(to: usize, from: usize) -> f64 {
    let (t, f) = (to.max(2) as f64, from.max(2) as f64);
    (t * t * t.log2()) / (f * f * f.log2())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simulator::Package;

    const FFTW3: EngineId = EngineId::Sim(Package::Fftw3);
    const MKL: EngineId = EngineId::Sim(Package::Mkl);

    fn two_member() -> PortfolioModel {
        PortfolioModel::new(vec![FFTW3, MKL])
    }

    #[test]
    fn picks_cheapest_and_sticks() {
        let mut p = two_member();
        p.set_surface(FFTW3, 1024, TransformKind::C2c, 0.010);
        p.set_surface(MKL, 1024, TransformKind::C2c, 0.004);
        assert_eq!(p.best_engine(1024, TransformKind::C2c, 4), Some(MKL));
        // incumbent sticks even when the rival's surface improves
        p.set_surface(FFTW3, 1024, TransformKind::C2c, 0.001);
        assert_eq!(p.best_engine(1024, TransformKind::C2c, 4), Some(MKL));
    }

    #[test]
    fn per_point_crossover() {
        let mut p = two_member();
        p.set_surface(MKL, 512, TransformKind::C2c, 0.001);
        p.set_surface(FFTW3, 512, TransformKind::C2c, 0.002);
        p.set_surface(MKL, 8192, TransformKind::C2c, 0.50);
        p.set_surface(FFTW3, 8192, TransformKind::C2c, 0.30);
        assert_eq!(p.best_engine(512, TransformKind::C2c, 4), Some(MKL));
        assert_eq!(p.best_engine(8192, TransformKind::C2c, 4), Some(FFTW3));
    }

    #[test]
    fn nearest_n_fallback_scales_by_work() {
        let mut p = two_member();
        p.set_surface(MKL, 1000, TransformKind::C2c, 0.1);
        let est = p.estimate(MKL, 2000, TransformKind::C2c).unwrap();
        assert!(est > 0.4 && est < 0.6, "{est}"); // ~4.4x the 1000-point cost
        // no data for this kind at all -> None
        assert_eq!(p.estimate(MKL, 2000, TransformKind::R2c), None);
    }

    #[test]
    fn cold_portfolio_falls_back_to_first_member() {
        let mut p = two_member();
        assert_eq!(p.best_engine(4096, TransformKind::C2c, 2), Some(FFTW3));
        assert!(PortfolioModel::new(vec![]).best_engine(64, TransformKind::C2c, 1).is_none());
    }

    #[test]
    fn drift_evicts_and_logs_repick() {
        let mut p = two_member();
        p.set_surface(FFTW3, 1024, TransformKind::C2c, 0.010);
        p.set_surface(MKL, 1024, TransformKind::C2c, 0.004);
        assert_eq!(p.best_engine(1024, TransformKind::C2c, 4), Some(MKL));
        // MKL drifts 5x slower: evict its pick, degrade its surface
        assert_eq!(p.note_drift(MKL), 1);
        p.scale_engine(MKL, 5.0);
        assert_eq!(p.best_engine(1024, TransformKind::C2c, 4), Some(FFTW3));
        assert_eq!(
            p.repick_log(),
            &[RepickEvent { n: 1024, kind: TransformKind::C2c, from: MKL, to: FFTW3 }]
        );
        // re-resolving to the same engine logs nothing
        assert_eq!(p.note_drift(FFTW3), 1);
        assert_eq!(p.best_engine(1024, TransformKind::C2c, 4), Some(FFTW3));
        assert_eq!(p.repick_log().len(), 1);
    }

    #[test]
    fn json_roundtrip_and_unknown_engine_rejected() {
        let mut p = two_member();
        p.set_surface(MKL, 512, TransformKind::R2c, 0.003);
        p.set_surface(FFTW3, 512, TransformKind::C2c, 0.007);
        assert_eq!(p.best_engine(512, TransformKind::C2c, 4), Some(FFTW3));
        let j = p.to_json();
        let back = PortfolioModel::from_json(&j).unwrap();
        assert_eq!(back.members(), p.members());
        assert_eq!(back.surface(MKL, 512, TransformKind::R2c), Some(0.003));
        assert_eq!(back.pick(512, TransformKind::C2c), Some(FFTW3));

        let bad = Json::parse(r#"{"members": ["cufft"]}"#).unwrap();
        assert!(PortfolioModel::from_json(&bad).is_err());
    }

    #[test]
    fn observe_blends() {
        let mut p = two_member();
        p.observe_cost(MKL, 256, TransformKind::C2c, 0.4);
        assert_eq!(p.surface(MKL, 256, TransformKind::C2c), Some(0.4));
        p.observe_cost(MKL, 256, TransformKind::C2c, 0.2);
        let t = p.surface(MKL, 256, TransformKind::C2c).unwrap();
        assert!((t - 0.3).abs() < 1e-12, "{t}");
    }
}
