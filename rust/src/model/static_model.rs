//! `StaticModel` — measured FPM surfaces behind the [`PerfModel`] trait.
//!
//! Wraps the per-group [`SpeedFunction`]s produced by the offline
//! profiler (§V-B construction) or loaded from a persisted wisdom
//! record. This is the paper's frozen artifact: it answers section and
//! prediction queries but ignores observations — live refinement is
//! [`crate::model::OnlineModel`]'s job (typically with a `StaticModel`
//! as its base).

use crate::model::surface::{Curve, SpeedFunction};
use crate::model::PerfModel;

/// Per-group measured speed surfaces (index = abstract processor).
#[derive(Clone, Debug, PartialEq)]
pub struct StaticModel {
    fpms: Vec<SpeedFunction>,
}

impl StaticModel {
    pub fn new(fpms: Vec<SpeedFunction>) -> StaticModel {
        StaticModel { fpms }
    }

    /// Borrow-friendly constructor for callers holding `&[SpeedFunction]`.
    pub fn from_slice(fpms: &[SpeedFunction]) -> StaticModel {
        StaticModel { fpms: fpms.to_vec() }
    }

    pub fn surfaces(&self) -> &[SpeedFunction] {
        &self.fpms
    }

    pub fn is_empty(&self) -> bool {
        self.fpms.is_empty()
    }
}

impl PerfModel for StaticModel {
    fn model_name(&self) -> String {
        self.fpms.first().map(|f| f.name.clone()).unwrap_or_else(|| "static".to_string())
    }

    fn groups(&self) -> usize {
        self.fpms.len()
    }

    fn plane_section(&self, g: usize, n: usize) -> Curve {
        self.fpms[g].plane_section(n)
    }

    fn column_section(&self, g: usize, d: usize, n: usize, window: usize) -> Curve {
        let full = self.fpms[g].column_section(d);
        let cap = n.saturating_add(window);
        let mut ys = Vec::new();
        let mut speeds = Vec::new();
        for (i, &y) in full.xs.iter().enumerate() {
            if y <= cap {
                ys.push(y);
                speeds.push(full.speeds[i]);
            }
        }
        Curve::new(ys, speeds)
    }

    fn predict_time(&self, x: usize, y: usize) -> Option<f64> {
        crate::model::predict_time_via_sections(self, x, y)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn demo() -> StaticModel {
        StaticModel::new(
            (0..2)
                .map(|g| {
                    SpeedFunction::from_fn(
                        &format!("g{g}"),
                        vec![4, 8, 16],
                        vec![64, 128, 256],
                        move |x, _| Some(100.0 + g as f64 * 50.0 + x as f64),
                    )
                })
                .collect(),
        )
    }

    #[test]
    fn sections_match_underlying_surfaces() {
        let m = demo();
        let c = m.plane_section(1, 128);
        assert_eq!(c.xs, vec![4, 8, 16]);
        assert_eq!(c.speeds[0], 154.0);
        // column section restricted by window: only y <= 64 + 64
        let col = m.column_section(0, 8, 64, 64);
        assert_eq!(col.xs, vec![64, 128]);
        // unbounded window keeps everything
        let all = m.column_section(0, 8, 64, usize::MAX);
        assert_eq!(all.xs, vec![64, 128, 256]);
    }

    #[test]
    fn groups_and_name() {
        let m = demo();
        assert_eq!(m.groups(), 2);
        assert_eq!(m.model_name(), "g0");
    }
}
