//! Async completion tickets.
//!
//! [`crate::serve::front::ShardedFront::submit`] is non-blocking: it
//! returns a [`Ticket`] the moment the request is admitted and routed. The outcome arrives later — from the shard's
//! executing worker — and can be consumed three ways:
//!
//! * [`Ticket::poll`] — non-blocking; takes the outcome once it is ready;
//! * [`Ticket::wait`] — blocks until the outcome arrives (the async API's
//!   bridge back to the blocking world);
//! * [`Ticket::on_done`] — registers a callback invoked with a reference
//!   to the outcome the moment it completes (immediately, if it already
//!   has). The TCP front end serializes responses from this hook.
//!
//! The outcome is delivered exactly once by the service's completion
//! contract; `poll`/`wait` *take* it (first consumer wins), `on_done`
//! observes it by reference before any consumer takes it.

use std::sync::{Arc, Condvar, Mutex};

use crate::service::{Dft2dResponse, ServiceError};

/// What a completed request resolves to.
pub type Outcome = Result<Dft2dResponse, ServiceError>;

/// Callback signature for [`Ticket::on_done`]. Callbacks run on the
/// completing worker thread while the ticket's internal lock is held:
/// they must not call back into the same ticket and should stay short.
pub type DoneFn = Box<dyn FnOnce(&Outcome) + Send>;

#[derive(Default)]
struct TicketInner {
    outcome: Option<Outcome>,
    /// a consumer already took the outcome (poll/wait return nothing
    /// more; late callbacks are dropped)
    taken: bool,
    callbacks: Vec<DoneFn>,
}

struct TicketState {
    m: Mutex<TicketInner>,
    cv: Condvar,
}

/// Handle for one admitted request on the sharded front end.
pub struct Ticket {
    id: u64,
    shard: usize,
    state: Arc<TicketState>,
}

/// The completion side of a [`Ticket`] — moved into the shard service's
/// completion callback; consuming it delivers the outcome exactly once.
pub(crate) struct TicketCompleter {
    state: Arc<TicketState>,
}

impl Ticket {
    /// A pending ticket plus its completer.
    pub(crate) fn pending(id: u64, shard: usize) -> (Ticket, TicketCompleter) {
        let state = Arc::new(TicketState {
            m: Mutex::new(TicketInner::default()),
            cv: Condvar::new(),
        });
        (Ticket { id, shard, state: Arc::clone(&state) }, TicketCompleter { state })
    }

    /// Front-assigned request id (note: the shard service assigns its
    /// own internal ids; [`Dft2dResponse::id`] may differ).
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Index of the shard the router placed this request on.
    pub fn shard(&self) -> usize {
        self.shard
    }

    /// Has the outcome arrived (or already been consumed)?
    pub fn is_done(&self) -> bool {
        let g = self.state.m.lock().unwrap();
        g.outcome.is_some() || g.taken
    }

    /// Non-blocking: take the outcome if it is ready. Returns `None`
    /// while pending and after a consumer has already taken it.
    pub fn poll(&self) -> Option<Outcome> {
        let mut g = self.state.m.lock().unwrap();
        let out = g.outcome.take();
        if out.is_some() {
            g.taken = true;
        }
        out
    }

    /// Block until the outcome arrives and take it. If another consumer
    /// (an earlier `poll`) already took it, resolves to
    /// [`ServiceError::Disconnected`].
    pub fn wait(self) -> Outcome {
        let mut g = self.state.m.lock().unwrap();
        loop {
            if let Some(out) = g.outcome.take() {
                g.taken = true;
                return out;
            }
            if g.taken {
                return Err(ServiceError::Disconnected);
            }
            g = self.state.cv.wait(g).unwrap();
        }
    }

    /// Register a completion callback. Fires exactly once with a
    /// reference to the outcome — immediately if the ticket already
    /// completed, from the completing worker otherwise. Registered
    /// after a consumer took the outcome, the callback is dropped
    /// (there is nothing left to show it).
    pub fn on_done(&self, cb: DoneFn) {
        let mut g = self.state.m.lock().unwrap();
        match &g.outcome {
            Some(out) => cb(out),
            None => {
                if !g.taken {
                    g.callbacks.push(cb);
                }
            }
        }
    }
}

impl TicketCompleter {
    /// Deliver the outcome: run every registered callback, then park the
    /// outcome for `poll`/`wait` and wake blocked waiters.
    pub(crate) fn complete(self, outcome: Outcome) {
        let mut g = self.state.m.lock().unwrap();
        for cb in g.callbacks.drain(..) {
            cb(&outcome);
        }
        g.outcome = Some(outcome);
        drop(g);
        self.state.cv.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dft::SignalMatrix;
    use crate::service::ResponseReport;

    fn dummy_response(id: u64) -> Dft2dResponse {
        Dft2dResponse {
            id,
            matrix: SignalMatrix::zeros(2, 2),
            report: ResponseReport {
                engine: crate::coordinator::engine::EngineId::Native,
                d: vec![2],
                pads: vec![2],
                algorithm: "test".into(),
                batched_with: 1,
                planned_cold: false,
                queue_wait_s: 0.0,
                latency_s: 0.0,
                predicted_s: 0.0,
                executed_s: 0.0,
                virtual_done_s: None,
            },
        }
    }

    #[test]
    fn poll_then_complete_then_poll() {
        let (t, c) = Ticket::pending(7, 1);
        assert_eq!(t.id(), 7);
        assert_eq!(t.shard(), 1);
        assert!(!t.is_done());
        assert!(t.poll().is_none());
        c.complete(Ok(dummy_response(7)));
        assert!(t.is_done());
        let out = t.poll().expect("outcome ready");
        assert_eq!(out.unwrap().id, 7);
        // second poll: already consumed
        assert!(t.poll().is_none());
        assert!(t.is_done());
    }

    #[test]
    fn wait_blocks_until_completion() {
        let (t, c) = Ticket::pending(1, 0);
        let h = std::thread::spawn(move || t.wait());
        std::thread::sleep(std::time::Duration::from_millis(20));
        c.complete(Err(ServiceError::ShuttingDown));
        assert_eq!(h.join().unwrap().unwrap_err(), ServiceError::ShuttingDown);
    }

    #[test]
    fn on_done_fires_once_before_or_after_completion() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        // registered before completion
        let (t, c) = Ticket::pending(1, 0);
        let hits = Arc::new(AtomicUsize::new(0));
        let h2 = Arc::clone(&hits);
        t.on_done(Box::new(move |out| {
            assert!(out.is_ok());
            h2.fetch_add(1, Ordering::SeqCst);
        }));
        c.complete(Ok(dummy_response(1)));
        assert_eq!(hits.load(Ordering::SeqCst), 1);
        // registered after completion: fires immediately
        let h3 = Arc::clone(&hits);
        t.on_done(Box::new(move |_| {
            h3.fetch_add(1, Ordering::SeqCst);
        }));
        assert_eq!(hits.load(Ordering::SeqCst), 2);
        // after the outcome is consumed, late callbacks are dropped
        assert!(t.poll().is_some());
        let h4 = Arc::clone(&hits);
        t.on_done(Box::new(move |_| {
            h4.fetch_add(1, Ordering::SeqCst);
        }));
        assert_eq!(hits.load(Ordering::SeqCst), 2);
    }
}
