//! Open-loop load generation and the deterministic routing harness.
//!
//! Closed-loop benching (`serve-bench --mode closed`) lets the system
//! set the pace: a slow server simply receives requests more slowly, so
//! queueing collapse is invisible. **Open-loop** generation submits on a
//! fixed or Poisson arrival schedule regardless of completions, and
//! measures latency **from the scheduled arrival instant** — exactly
//! what an external client observes, coordinated-omission-free. Under
//! overload the bounded front end sheds; the report separates shed
//! arrivals from the latency distribution of accepted ones.
//!
//! Two drivers share one [`OpenLoopReport`]:
//!
//! * [`run_open_loop`] — wall-clock, against a live [`ShardedFront`].
//! * [`run_virtual_open_loop`] — no threads, no clocks: modeled shards
//!   (true cost vs model-believed cost per request class) replayed
//!   against the **real** [`Router`] placement logic in virtual time.
//!   Same seed, same schedule, same result on every machine — this is
//!   the harness that proves model-driven placement beats round-robin
//!   before any socket exists.

use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

use crate::service::stats::percentile;
use crate::service::{Dft2dRequest, ServiceError};
use crate::util::json::Json;
use crate::util::prng::Xoshiro256;
use crate::util::table::{fnum, Table};

use super::front::ShardedFront;
use super::router::{RoutePolicy, Router, ShardEstimate};

/// Arrival process for open-loop generation. Times are seconds from the
/// start of the run; schedules are deterministic given the seed.
#[derive(Clone, Copy, Debug)]
pub enum Arrivals {
    /// evenly spaced arrivals at `rate_rps`
    Fixed { rate_rps: f64 },
    /// Poisson process: exponential inter-arrival gaps at `rate_rps`
    Poisson { rate_rps: f64, seed: u64 },
}

impl Arrivals {
    pub fn rate_rps(&self) -> f64 {
        match self {
            Arrivals::Fixed { rate_rps } | Arrivals::Poisson { rate_rps, .. } => *rate_rps,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Arrivals::Fixed { .. } => "fixed",
            Arrivals::Poisson { .. } => "poisson",
        }
    }

    /// Parse a CLI value (`fixed` | `poisson`).
    pub fn parse(s: &str, rate_rps: f64, seed: u64) -> Option<Arrivals> {
        match s.trim().to_ascii_lowercase().as_str() {
            "fixed" | "uniform" => Some(Arrivals::Fixed { rate_rps }),
            "poisson" => Some(Arrivals::Poisson { rate_rps, seed }),
            _ => None,
        }
    }

    /// The arrival instants for `count` requests (non-decreasing).
    pub fn schedule(&self, count: usize) -> Vec<f64> {
        match *self {
            Arrivals::Fixed { rate_rps } => {
                let gap = 1.0 / rate_rps.max(1e-9);
                (0..count).map(|i| i as f64 * gap).collect()
            }
            Arrivals::Poisson { rate_rps, seed } => {
                let mut rng = Xoshiro256::seeded(seed);
                let rate = rate_rps.max(1e-9);
                let mut t = 0.0;
                (0..count)
                    .map(|_| {
                        // exponential gap via inverse CDF; next_f64 is in
                        // [0,1) so the log argument stays positive
                        t += -(1.0 - rng.next_f64()).ln() / rate;
                        t
                    })
                    .collect()
            }
        }
    }
}

/// What one open-loop run produced.
#[derive(Clone, Debug)]
pub struct OpenLoopReport {
    pub policy: String,
    pub arrivals: String,
    /// arrivals generated (accepted + shed + failed-at-submit)
    pub offered: usize,
    pub accepted: usize,
    /// accepted requests that resolved Ok
    pub completed: usize,
    /// arrivals refused by backpressure (`Overloaded`)
    pub shed: usize,
    /// submit-time rejections other than shedding, plus failed outcomes
    pub failed: usize,
    pub duration_s: f64,
    pub offered_rps: f64,
    /// latency of accepted requests, measured from scheduled arrival
    pub latency_mean_s: f64,
    pub latency_p50_s: f64,
    pub latency_p95_s: f64,
    pub latency_p99_s: f64,
    pub latency_max_s: f64,
    /// mean relative error of the model's predicted vs actual time
    pub predicted_err_mean: f64,
    /// drift-driven router re-scores during the run (live runs only)
    pub rescore_events: u64,
}

fn build_report(
    policy: &str,
    arrivals: &str,
    offered: usize,
    shed: usize,
    failed: usize,
    mut latencies: Vec<f64>,
    pred_errs: &[f64],
    duration_s: f64,
    rescore_events: u64,
) -> OpenLoopReport {
    let accepted = latencies.len();
    latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = if latencies.is_empty() {
        0.0
    } else {
        latencies.iter().sum::<f64>() / latencies.len() as f64
    };
    let err_mean = if pred_errs.is_empty() {
        0.0
    } else {
        pred_errs.iter().sum::<f64>() / pred_errs.len() as f64
    };
    OpenLoopReport {
        policy: policy.to_string(),
        arrivals: arrivals.to_string(),
        offered,
        accepted,
        completed: accepted,
        shed,
        failed,
        duration_s,
        offered_rps: if duration_s > 0.0 { offered as f64 / duration_s } else { 0.0 },
        latency_mean_s: mean,
        latency_p50_s: percentile(&latencies, 0.50),
        latency_p95_s: percentile(&latencies, 0.95),
        latency_p99_s: percentile(&latencies, 0.99),
        latency_max_s: latencies.last().copied().unwrap_or(0.0),
        predicted_err_mean: err_mean,
        rescore_events,
    }
}

impl OpenLoopReport {
    pub fn render(&self, title: &str) -> String {
        let ms = |s: f64| format!("{:.3} ms", s * 1e3);
        let mut t = Table::new(title, &["metric", "value"]);
        t.row(vec!["policy".into(), self.policy.clone()]);
        t.row(vec!["arrivals".into(), self.arrivals.clone()]);
        t.row(vec!["offered".into(), self.offered.to_string()]);
        t.row(vec!["accepted".into(), self.accepted.to_string()]);
        t.row(vec!["shed".into(), self.shed.to_string()]);
        t.row(vec!["failed".into(), self.failed.to_string()]);
        t.row(vec!["offered rate".into(), format!("{} rps", fnum(self.offered_rps, 1))]);
        t.row(vec!["latency mean".into(), ms(self.latency_mean_s)]);
        t.row(vec!["latency p50".into(), ms(self.latency_p50_s)]);
        t.row(vec!["latency p95".into(), ms(self.latency_p95_s)]);
        t.row(vec!["latency p99".into(), ms(self.latency_p99_s)]);
        t.row(vec!["latency max".into(), ms(self.latency_max_s)]);
        t.row(vec![
            "predicted-time rel err".into(),
            format!("{:.1}%", self.predicted_err_mean * 100.0),
        ]);
        t.row(vec!["router re-scores".into(), self.rescore_events.to_string()]);
        t.render()
    }

    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("policy", self.policy.as_str())
            .set("arrivals", self.arrivals.as_str())
            .set("offered", self.offered)
            .set("accepted", self.accepted)
            .set("completed", self.completed)
            .set("shed", self.shed)
            .set("failed", self.failed)
            .set("duration_s", self.duration_s)
            .set("offered_rps", self.offered_rps)
            .set("latency_mean_s", self.latency_mean_s)
            .set("latency_p50_s", self.latency_p50_s)
            .set("latency_p95_s", self.latency_p95_s)
            .set("latency_p99_s", self.latency_p99_s)
            .set("latency_max_s", self.latency_max_s)
            .set("predicted_err_mean", self.predicted_err_mean)
            .set("rescore_events", self.rescore_events as i64)
    }
}

/// Parameters for a live open-loop run.
pub struct OpenLoopSpec {
    pub requests: usize,
    pub arrivals: Arrivals,
}

struct Latch {
    m: Mutex<LatchState>,
    cv: Condvar,
}

#[derive(Default)]
struct LatchState {
    resolved: usize,
    completed: usize,
    failed: usize,
    latencies_s: Vec<f64>,
    pred_errs: Vec<f64>,
}

/// Drive a live front end open-loop: submit on the schedule no matter
/// what, count sheds, then wait for every accepted ticket to resolve.
/// `make_req` builds the i-th request (vary n/kind per index at will).
pub fn run_open_loop(
    front: &ShardedFront,
    make_req: impl Fn(usize) -> Dft2dRequest,
    spec: &OpenLoopSpec,
) -> OpenLoopReport {
    let schedule = spec.arrivals.schedule(spec.requests);
    let latch = Arc::new(Latch { m: Mutex::new(LatchState::default()), cv: Condvar::new() });
    let start = Instant::now();
    let mut shed = 0usize;
    let mut submit_failed = 0usize;
    let mut accepted = 0usize;
    for (i, &at) in schedule.iter().enumerate() {
        let now = start.elapsed().as_secs_f64();
        if at > now {
            std::thread::sleep(std::time::Duration::from_secs_f64(at - now));
        }
        match front.submit(make_req(i)) {
            Ok(ticket) => {
                accepted += 1;
                let latch = Arc::clone(&latch);
                ticket.on_done(Box::new(move |outcome| {
                    let done = start.elapsed().as_secs_f64();
                    let mut g = latch.m.lock().unwrap();
                    g.resolved += 1;
                    match outcome {
                        Ok(resp) => {
                            g.completed += 1;
                            // open-loop latency: from the *scheduled*
                            // arrival, so submit-side stalls count too
                            g.latencies_s.push((done - at).max(0.0));
                            if resp.report.executed_s > 0.0 {
                                g.pred_errs.push(
                                    (resp.report.predicted_s - resp.report.executed_s).abs()
                                        / resp.report.executed_s,
                                );
                            }
                        }
                        Err(_) => g.failed += 1,
                    }
                    latch.cv.notify_all();
                }));
            }
            Err(ServiceError::Overloaded { .. }) => shed += 1,
            Err(_) => submit_failed += 1,
        }
    }
    let (completed_failed, latencies, pred_errs) = {
        let mut g = latch.m.lock().unwrap();
        while g.resolved < accepted {
            g = latch.cv.wait(g).unwrap();
        }
        (g.failed, std::mem::take(&mut g.latencies_s), std::mem::take(&mut g.pred_errs))
    };
    let duration_s = start.elapsed().as_secs_f64();
    let stats = front.stats();
    build_report(
        front.policy().name(),
        spec.arrivals.name(),
        spec.requests,
        shed,
        submit_failed + completed_failed,
        latencies,
        &pred_errs,
        duration_s,
        stats.rescore_events,
    )
}

/// One modeled shard for the virtual harness: what requests of each
/// class *actually* cost on it, and what its model *believes* they cost
/// (the router only ever sees the beliefs).
#[derive(Clone, Debug)]
pub struct VirtualShard {
    pub name: String,
    /// true execution seconds, indexed by request class
    pub true_s: Vec<f64>,
    /// model-believed execution seconds, same indexing
    pub believed_s: Vec<f64>,
}

/// Parameters for a virtual-time run.
pub struct VirtualSpec {
    pub requests: usize,
    pub arrivals: Arrivals,
    /// admission window, as in [`super::front::FrontConfig::capacity`]
    pub capacity: usize,
    pub policy: RoutePolicy,
    /// request i gets class `classes[i % classes.len()]`
    pub classes: Vec<usize>,
}

/// Replay an arrival schedule against modeled shards in virtual time,
/// using the real [`Router`] for placement. Each shard executes its
/// queue serially; admission counts requests in flight exactly like the
/// live front end. Fully deterministic — no threads, no wall clock.
pub fn run_virtual_open_loop(shards: &[VirtualShard], spec: &VirtualSpec) -> OpenLoopReport {
    assert!(!shards.is_empty(), "virtual run needs at least one shard");
    assert!(spec.capacity >= 1, "admission capacity must be >= 1");
    let router = Router::new(spec.policy, shards.len());
    let schedule = spec.arrivals.schedule(spec.requests);
    // per-shard clocks: when the shard is truly free, and when the
    // router's beliefs say it is free
    let mut free_at = vec![0.0f64; shards.len()];
    let mut believed_free_at = vec![0.0f64; shards.len()];
    let mut finishes: Vec<f64> = Vec::with_capacity(spec.requests);
    let mut latencies = Vec::with_capacity(spec.requests);
    let mut pred_errs = Vec::with_capacity(spec.requests);
    let mut shed = 0usize;
    let mut last_event = 0.0f64;
    for (i, &at) in schedule.iter().enumerate() {
        last_event = last_event.max(at);
        let class = spec.classes[i % spec.classes.len()];
        // admitted-but-unfinished at this instant (the live front's
        // inflight window, reconstructed from recorded finish times)
        let inflight = finishes.iter().filter(|&&f| f > at).count();
        if inflight >= spec.capacity {
            shed += 1;
            continue;
        }
        let estimates: Vec<ShardEstimate> = shards
            .iter()
            .enumerate()
            .map(|(j, sh)| ShardEstimate {
                cost_s: sh.believed_s[class],
                backlog_s: (believed_free_at[j] - at).max(0.0),
            })
            .collect();
        let idx = router.place(&estimates);
        let start = free_at[idx].max(at);
        let finish = start + shards[idx].true_s[class];
        free_at[idx] = finish;
        believed_free_at[idx] = believed_free_at[idx].max(at) + shards[idx].believed_s[class];
        finishes.push(finish);
        last_event = last_event.max(finish);
        let actual_latency = finish - at;
        latencies.push(actual_latency);
        let predicted_latency = estimates[idx].finish_s();
        if actual_latency > 0.0 {
            pred_errs.push((predicted_latency - actual_latency).abs() / actual_latency);
        }
    }
    build_report(
        spec.policy.name(),
        spec.arrivals.name(),
        spec.requests,
        shed,
        0,
        latencies,
        &pred_errs,
        last_event,
        router.rescore_events(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn schedules_are_deterministic_and_sorted() {
        let fixed = Arrivals::Fixed { rate_rps: 100.0 }.schedule(5);
        assert_eq!(fixed.len(), 5);
        for (i, t) in fixed.iter().enumerate() {
            assert!((t - i as f64 * 0.01).abs() < 1e-12, "arrival {i} at {t}");
        }
        let a = Arrivals::Poisson { rate_rps: 50.0, seed: 9 }.schedule(64);
        let b = Arrivals::Poisson { rate_rps: 50.0, seed: 9 }.schedule(64);
        assert_eq!(a, b);
        assert!(a.windows(2).all(|w| w[0] <= w[1]));
        // mean inter-arrival should be in the right ballpark (1/50 s)
        let mean_gap = a.last().unwrap() / a.len() as f64;
        assert!(mean_gap > 0.005 && mean_gap < 0.08, "mean gap {mean_gap}");
    }

    fn two_shards(fast: f64, slow: f64) -> Vec<VirtualShard> {
        vec![
            VirtualShard {
                name: "fast".into(),
                true_s: vec![fast],
                believed_s: vec![fast * 1.02],
            },
            VirtualShard {
                name: "slow".into(),
                true_s: vec![slow],
                believed_s: vec![slow * 0.98],
            },
        ]
    }

    #[test]
    fn virtual_overload_sheds_and_bounds_tail() {
        // 2 shards that each take 100 ms, offered 40 rps against ~20 rps
        // of capacity: roughly half the arrivals must shed, and accepted
        // latency stays bounded by (capacity+1) * service time
        let shards = two_shards(0.1, 0.1);
        let spec = VirtualSpec {
            requests: 200,
            arrivals: Arrivals::Poisson { rate_rps: 40.0, seed: 7 },
            capacity: 4,
            policy: RoutePolicy::ModelFinishTime,
            classes: vec![0],
        };
        let rep = run_virtual_open_loop(&shards, &spec);
        assert!(rep.shed > 0, "overload must shed (got {})", rep.shed);
        assert_eq!(rep.offered, 200);
        assert_eq!(rep.accepted + rep.shed, 200);
        assert!(
            rep.latency_p99_s <= 0.1 * (spec.capacity as f64 + 1.0),
            "p99 {} not bounded by the admission window",
            rep.latency_p99_s
        );
    }

    #[test]
    fn model_routing_beats_round_robin_on_heterogeneous_shards() {
        // shard 1 is 4x slower; round-robin sends it half the traffic
        // anyway, the model policy only what its queue justifies
        let shards = two_shards(0.02, 0.08);
        let mk_spec = |policy| VirtualSpec {
            requests: 300,
            arrivals: Arrivals::Poisson { rate_rps: 30.0, seed: 11 },
            capacity: 8,
            policy,
            classes: vec![0],
        };
        let model = run_virtual_open_loop(&shards, &mk_spec(RoutePolicy::ModelFinishTime));
        let rr = run_virtual_open_loop(&shards, &mk_spec(RoutePolicy::RoundRobin));
        assert!(
            model.latency_p95_s < rr.latency_p95_s,
            "model p95 {} should beat round-robin p95 {}",
            model.latency_p95_s,
            rr.latency_p95_s
        );
        // beliefs are within a few percent of truth, so predicted
        // completion times must track actual ones closely
        assert!(
            model.predicted_err_mean < 0.25,
            "model-policy prediction error too large: {}",
            model.predicted_err_mean
        );
        assert!(
            model.shed <= rr.shed,
            "model routing should not shed more than round-robin ({} vs {})",
            model.shed,
            rr.shed
        );
    }
}
