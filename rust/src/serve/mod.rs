//! Sharded, asynchronous, network-fronted serving.
//!
//! [`crate::service`] is one worker pool over one engine registry — a
//! single blocking queue. This module layers the paper's thesis (*the
//! performance model decides placement*) on top of it:
//!
//! * [`ticket`] — non-blocking submission: [`front::ShardedFront::submit`]
//!   returns a [`ticket::Ticket`] immediately; callers poll, block, or
//!   register a completion callback.
//! * [`front`] — the sharded front end: one [`crate::service::Dft2dService`]
//!   per configured core subset (POPTA partitions are per-p, so every
//!   shard plans for its own p), a **bounded admission window** with
//!   explicit backpressure (arrivals beyond capacity are shed with a
//!   typed [`crate::service::ServiceError::Overloaded`] carrying the
//!   FPM-predicted wait), and per-shard + aggregate stats through
//!   [`crate::service::stats::StatsCollector`].
//! * [`router`] — placement: each request goes to the shard with the
//!   lowest **model-predicted completion time** (predicted execution
//!   cost from that shard's live [`crate::model::OnlineModel`] plus its
//!   model-priced backlog). Costs are cached per `(shard, n, kind)` and
//!   the cache is purged — placement re-scored — whenever a shard's
//!   model fires a drift event. Round-robin is kept as the control
//!   arm the benches compare against.
//! * [`wire`] / [`net`] — a zero-dependency length-prefixed TCP front
//!   end (`std::net`): binary frames carrying (n, kind, direction,
//!   deadline, payload planes), a threaded server, and the matching
//!   blocking client the `serve-net` CLI and smoke tests drive.
//! * [`loadgen`] — **open-loop** load generation: fixed or Poisson
//!   arrival schedules where latency is measured **from arrival**, not
//!   from dequeue, so the subsystem is judged on latency-under-load.
//!   A deterministic virtual-time harness replays the same arrival
//!   schedule against modeled shards through the *real* router, which
//!   is how model-vs-round-robin placement is compared reproducibly.
//!
//! Request lifecycle: **submit → shed-or-admit → route → shard service
//! (batch/plan/execute) → ticket completion**. Everything below the
//! router is the PR-3/5 service unchanged — bit-exactness of routed
//! output vs the single-service oracle is property-tested in
//! `rust/tests/serve_integration.rs`.

pub mod front;
pub mod loadgen;
pub mod net;
pub mod router;
pub mod ticket;
pub mod wire;

pub use front::{FrontBuilder, FrontConfig, FrontStats, ShardedFront};
pub use loadgen::{
    run_open_loop, run_virtual_open_loop, Arrivals, OpenLoopReport, OpenLoopSpec, VirtualShard,
    VirtualSpec,
};
pub use net::{NetClient, NetConfig, NetServer};
pub use router::{RoutePolicy, Router, ShardEstimate};
pub use ticket::Ticket;
