//! TCP front end: threaded server + blocking client over [`super::wire`].
//!
//! [`NetServer`] accepts connections on a `std::net::TcpListener` (one
//! thread per connection — the expensive part of a request is the
//! transform, not the socket) and forwards decoded requests into a
//! [`ShardedFront`]. Responses are written from ticket completion
//! callbacks, so a connection can pipeline: many requests in flight,
//! replies coming back **in completion order**, matched by `req_id`.
//! Submit-time rejections (validation, shed, drain) return typed
//! [`wire::Frame::Error`] frames carrying the stable
//! [`crate::service::ServiceError::code`] value immediately.
//!
//! Shutdown is cooperative and drains: the stop flag interrupts reads
//! at frame boundaries, [`NetServer::shutdown`] drains the front end
//! (every admitted request still resolves, and its response is written
//! before the writer handles drop), joins every thread, and closes the
//! listener. A client may request this remotely with a shutdown frame
//! when [`NetConfig::allow_remote_shutdown`] is set — the `serve-net`
//! smoke test's clean-exit path.

use std::io::{self, Read};
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::dft::real::{half_cols, TransformKind};
use crate::dft::SignalMatrix;
use crate::service::Dft2dRequest;

use super::front::ShardedFront;
use super::wire::{self, Frame, WireRequest, WireResponse, DEFAULT_MAX_FRAME};

/// How long a blocked read waits before re-checking the stop flag.
const READ_TICK: Duration = Duration::from_millis(200);
/// Stalled mid-frame reads tolerated after stop before the connection
/// is abandoned (~2 s at `READ_TICK`).
const DRAIN_TICKS: u32 = 10;

#[derive(Clone, Copy, Debug)]
pub struct NetConfig {
    /// per-frame payload cap (protects the server's allocator)
    pub max_frame_bytes: usize,
    /// honor client shutdown frames (off by default: a remote peer
    /// should not be able to stop the server unless explicitly enabled)
    pub allow_remote_shutdown: bool,
}

impl Default for NetConfig {
    fn default() -> NetConfig {
        NetConfig { max_frame_bytes: DEFAULT_MAX_FRAME, allow_remote_shutdown: false }
    }
}

/// The serving socket: accept loop + per-connection reader threads.
pub struct NetServer {
    front: ShardedFront,
    local: SocketAddr,
    stop: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
    conns: Arc<Mutex<Vec<JoinHandle<()>>>>,
}

enum ReadOutcome {
    Frame(Frame),
    /// peer closed (or stop was requested) at a frame boundary
    Closed,
}

/// `read_exact` with stop-awareness: a read timeout at a frame boundary
/// checks the stop flag and returns `Closed` instead of blocking the
/// drain; mid-frame timeouts keep reading (a frame must never be torn)
/// until the peer stalls past the drain budget.
fn read_full(
    stream: &mut TcpStream,
    buf: &mut [u8],
    stop: &AtomicBool,
    at_boundary: bool,
) -> io::Result<Option<()>> {
    let mut got = 0usize;
    let mut stalled = 0u32;
    while got < buf.len() {
        match stream.read(&mut buf[got..]) {
            Ok(0) => {
                if got == 0 && at_boundary {
                    return Ok(None);
                }
                return Err(io::Error::new(
                    io::ErrorKind::UnexpectedEof,
                    "peer closed mid-frame",
                ));
            }
            Ok(k) => {
                got += k;
                stalled = 0;
            }
            Err(e)
                if e.kind() == io::ErrorKind::WouldBlock
                    || e.kind() == io::ErrorKind::TimedOut =>
            {
                if stop.load(Ordering::Acquire) {
                    if got == 0 && at_boundary {
                        return Ok(None);
                    }
                    stalled += 1;
                    if stalled > DRAIN_TICKS {
                        return Err(io::Error::new(
                            io::ErrorKind::TimedOut,
                            "peer stalled mid-frame during drain",
                        ));
                    }
                }
            }
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e),
        }
    }
    Ok(Some(()))
}

fn read_frame_interruptible(
    stream: &mut TcpStream,
    max_len: usize,
    stop: &AtomicBool,
) -> io::Result<ReadOutcome> {
    let mut len_buf = [0u8; 4];
    if read_full(stream, &mut len_buf, stop, true)?.is_none() {
        return Ok(ReadOutcome::Closed);
    }
    let len = u32::from_le_bytes(len_buf) as usize;
    if len > max_len {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            format!("announced frame of {len} bytes exceeds cap {max_len}"),
        ));
    }
    let mut payload = vec![0u8; len];
    if read_full(stream, &mut payload, stop, false)?.is_none() {
        return Ok(ReadOutcome::Closed);
    }
    Ok(ReadOutcome::Frame(wire::decode_payload(&payload)?))
}

/// Decode a wire request into a service request. Geometry follows the
/// kind (c2r inputs are the packed `n x (n/2+1)` half spectrum); the
/// service's admission validation rejects mismatched payloads.
fn to_service_request(wr: WireRequest) -> Dft2dRequest {
    let n = wr.n as usize;
    let (rows, cols) = match wr.kind {
        TransformKind::C2r => (n, half_cols(n)),
        _ => (n, n),
    };
    let re = wr.re;
    let im = if wr.im.is_empty() { vec![0.0; re.len()] } else { wr.im };
    Dft2dRequest {
        n,
        matrix: SignalMatrix { rows, cols, re, im },
        direction: wr.direction,
        kind: wr.kind,
        engine: wr.engine,
        deadline_hint: (wr.deadline_us > 0).then(|| wr.deadline_us as f64 / 1e6),
    }
}

fn serve_connection(
    mut stream: TcpStream,
    front: ShardedFront,
    cfg: NetConfig,
    stop: Arc<AtomicBool>,
) {
    let _ = stream.set_read_timeout(Some(READ_TICK));
    let writer = match stream.try_clone() {
        Ok(w) => Arc::new(Mutex::new(w)),
        Err(_) => return,
    };
    loop {
        let frame = match read_frame_interruptible(&mut stream, cfg.max_frame_bytes, &stop) {
            Ok(ReadOutcome::Frame(f)) => f,
            Ok(ReadOutcome::Closed) => return,
            Err(_) => return,
        };
        match frame {
            Frame::Request(wr) => {
                let req_id = wr.req_id;
                let req = to_service_request(wr);
                match front.submit(req) {
                    Ok(ticket) => {
                        let shard = ticket.shard() as u32;
                        let w = Arc::clone(&writer);
                        ticket.on_done(Box::new(move |outcome| {
                            let frame = match outcome {
                                Ok(resp) => Frame::Response(WireResponse {
                                    req_id,
                                    rows: resp.matrix.rows as u64,
                                    cols: resp.matrix.cols as u64,
                                    predicted_s: resp.report.predicted_s,
                                    executed_s: resp.report.executed_s,
                                    server_latency_s: resp.report.latency_s,
                                    shard,
                                    re: resp.matrix.re.clone(),
                                    im: resp.matrix.im.clone(),
                                }),
                                Err(e) => Frame::Error {
                                    req_id,
                                    code: e.code(),
                                    message: e.to_string(),
                                },
                            };
                            // a vanished client is its own problem
                            let _ = wire::write_frame(&mut *w.lock().unwrap(), &frame);
                        }));
                    }
                    Err(e) => {
                        let frame = Frame::Error {
                            req_id,
                            code: e.code(),
                            message: e.to_string(),
                        };
                        if wire::write_frame(&mut *writer.lock().unwrap(), &frame).is_err() {
                            return;
                        }
                    }
                }
            }
            Frame::Shutdown { req_id } => {
                if cfg.allow_remote_shutdown {
                    let _ = wire::write_frame(
                        &mut *writer.lock().unwrap(),
                        &Frame::ShutdownAck { req_id },
                    );
                    stop.store(true, Ordering::Release);
                    return;
                }
                let frame = Frame::Error {
                    req_id,
                    code: 0,
                    message: "remote shutdown disabled on this server".into(),
                };
                if wire::write_frame(&mut *writer.lock().unwrap(), &frame).is_err() {
                    return;
                }
            }
            other => {
                let frame = Frame::Error {
                    req_id: other.req_id(),
                    code: 0,
                    message: "unexpected frame from client".into(),
                };
                if wire::write_frame(&mut *writer.lock().unwrap(), &frame).is_err() {
                    return;
                }
            }
        }
    }
}

impl NetServer {
    /// Bind and start accepting. `addr` like `127.0.0.1:0` (port 0 picks
    /// a free ephemeral port; read it back via [`NetServer::local_addr`]).
    pub fn bind<A: ToSocketAddrs>(
        front: ShardedFront,
        addr: A,
        cfg: NetConfig,
    ) -> io::Result<NetServer> {
        let listener = TcpListener::bind(addr)?;
        listener.set_nonblocking(true)?;
        let local = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let conns: Arc<Mutex<Vec<JoinHandle<()>>>> = Arc::new(Mutex::new(Vec::new()));
        let accept = {
            let front = front.clone();
            let stop = Arc::clone(&stop);
            let conns = Arc::clone(&conns);
            std::thread::spawn(move || {
                while !stop.load(Ordering::Acquire) {
                    match listener.accept() {
                        Ok((stream, _)) => {
                            let _ = stream.set_nonblocking(false);
                            let front = front.clone();
                            let stop = Arc::clone(&stop);
                            let h = std::thread::spawn(move || {
                                serve_connection(stream, front, cfg, stop)
                            });
                            conns.lock().unwrap().push(h);
                        }
                        Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                            std::thread::sleep(Duration::from_millis(5));
                        }
                        Err(_) => std::thread::sleep(Duration::from_millis(5)),
                    }
                }
                // listener drops here: the port closes as accept exits
            })
        };
        Ok(NetServer { front, local, stop, accept: Some(accept), conns })
    }

    pub fn local_addr(&self) -> SocketAddr {
        self.local
    }

    /// Has a stop been requested (remotely or via [`NetServer::shutdown`])?
    pub fn is_stopped(&self) -> bool {
        self.stop.load(Ordering::Acquire)
    }

    /// Block until a stop is requested — the server-mode CLI parks here
    /// until a client's shutdown frame (or process signal) arrives.
    pub fn wait_until_stopped(&self) {
        while !self.stop.load(Ordering::Acquire) {
            std::thread::sleep(Duration::from_millis(50));
        }
    }

    /// Stop accepting, drain the front end (admitted work completes and
    /// its responses are written), join every thread, close the socket.
    pub fn shutdown(&mut self) {
        self.stop.store(true, Ordering::Release);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        self.front.shutdown();
        let mut conns = self.conns.lock().unwrap();
        for h in conns.drain(..) {
            let _ = h.join();
        }
    }

    pub fn front(&self) -> &ShardedFront {
        &self.front
    }
}

impl Drop for NetServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Blocking client for the wire protocol (used by `serve-net --connect`
/// and the integration tests).
pub struct NetClient {
    stream: TcpStream,
    max_frame: usize,
    next_id: u64,
}

impl NetClient {
    pub fn connect<A: ToSocketAddrs>(addr: A) -> io::Result<NetClient> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true).ok();
        Ok(NetClient { stream, max_frame: DEFAULT_MAX_FRAME, next_id: 1 })
    }

    /// Send one request and block for its reply. A zero `req_id` is
    /// replaced with a fresh client-side id; replies are matched by id
    /// (frames for other in-flight ids are skipped, so a caller that
    /// interleaves submissions on one socket still pairs correctly).
    /// Returns `Ok(Err((code, message)))` for a typed server rejection.
    pub fn roundtrip(
        &mut self,
        mut req: WireRequest,
    ) -> io::Result<Result<WireResponse, (u16, String)>> {
        if req.req_id == 0 {
            req.req_id = self.next_id;
            self.next_id += 1;
        }
        let want = req.req_id;
        wire::write_frame(&mut self.stream, &Frame::Request(req))?;
        loop {
            match wire::read_frame(&mut self.stream, self.max_frame)? {
                Frame::Response(r) if r.req_id == want => return Ok(Ok(r)),
                Frame::Error { req_id, code, message } if req_id == want => {
                    return Ok(Err((code, message)));
                }
                _ => {}
            }
        }
    }

    /// Ask the server to drain and exit. `Ok(true)` when acknowledged,
    /// `Ok(false)` when the server has remote shutdown disabled.
    pub fn shutdown_server(&mut self) -> io::Result<bool> {
        let id = self.next_id;
        self.next_id += 1;
        wire::write_frame(&mut self.stream, &Frame::Shutdown { req_id: id })?;
        loop {
            match wire::read_frame(&mut self.stream, self.max_frame)? {
                Frame::ShutdownAck { req_id } if req_id == id => return Ok(true),
                Frame::Error { req_id, .. } if req_id == id => return Ok(false),
                _ => {}
            }
        }
    }
}
