//! Model-driven shard placement.
//!
//! The router answers one question: *given what every shard's
//! performance model currently believes, where does this request finish
//! earliest?* Each shard is summarized as a [`ShardEstimate`] —
//! model-predicted execution cost for the request plus the model-priced
//! backlog already admitted to the shard — and
//! [`Router::place`] picks the argmin of `backlog_s + cost_s`
//! (predicted completion time). The function is pure over the estimate
//! slice, so the deterministic virtual-time harness
//! ([`crate::serve::loadgen`]) exercises the *same* placement logic the
//! live front end runs.
//!
//! Costs are memoized per `(shard, engine, n, kind)` — a cost lookup
//! walks the shard's model/wisdom locks, and open-loop arrival rates
//! would pay it per arrival. The engine axis matters under the engine
//! portfolio: the same `(n, kind)` on the same shard prices differently
//! per [`EngineId`], and a portfolio re-pick must not serve a stale
//! single-engine cost. The cache is **drift-aware**: [`Router::note_drift`]
//! compares the shard's drift-event counter against the last value seen
//! and purges that shard's entries when it moved, so placement re-scores
//! against the refreshed model the very next arrival (the
//! `rescore_events` counter makes this observable).
//!
//! [`RoutePolicy::RoundRobin`] keeps the model out of the decision —
//! the control arm every model-vs-baseline comparison in
//! `serve-bench --mode open` runs against.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::coordinator::engine::EngineId;
use crate::dft::real::TransformKind;

/// Placement policy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RoutePolicy {
    /// lowest model-predicted completion time (backlog + cost)
    ModelFinishTime,
    /// ignore the model; rotate through shards
    RoundRobin,
}

impl RoutePolicy {
    pub fn name(&self) -> &'static str {
        match self {
            RoutePolicy::ModelFinishTime => "model",
            RoutePolicy::RoundRobin => "round-robin",
        }
    }

    /// Parse a CLI value (`model` | `round-robin`/`rr`).
    pub fn parse(s: &str) -> Option<RoutePolicy> {
        match s.trim().to_ascii_lowercase().as_str() {
            "model" | "finish-time" => Some(RoutePolicy::ModelFinishTime),
            "round-robin" | "rr" => Some(RoutePolicy::RoundRobin),
            _ => None,
        }
    }
}

/// One shard's scoring inputs for one request.
#[derive(Clone, Copy, Debug, Default)]
pub struct ShardEstimate {
    /// model-predicted seconds to execute this request on this shard
    pub cost_s: f64,
    /// model-priced seconds of work already admitted to this shard
    pub backlog_s: f64,
}

impl ShardEstimate {
    /// Predicted completion time relative to now.
    pub fn finish_s(&self) -> f64 {
        self.backlog_s + self.cost_s
    }
}

/// The placement engine: policy + drift-aware cost cache.
pub struct Router {
    policy: RoutePolicy,
    rr_next: AtomicUsize,
    /// last drift-event count seen per shard
    seen_drift: Mutex<Vec<u64>>,
    /// (shard, engine, n, kind) → predicted cost seconds
    costs: Mutex<BTreeMap<(usize, EngineId, usize, TransformKind), f64>>,
    rescores: AtomicU64,
}

impl Router {
    pub fn new(policy: RoutePolicy, shards: usize) -> Router {
        Router {
            policy,
            rr_next: AtomicUsize::new(0),
            seen_drift: Mutex::new(vec![0; shards]),
            costs: Mutex::new(BTreeMap::new()),
            rescores: AtomicU64::new(0),
        }
    }

    pub fn policy(&self) -> RoutePolicy {
        self.policy
    }

    /// Pick a shard index for one request. Model policy: argmin of
    /// predicted completion time, ties to the lower index (deterministic).
    /// Round-robin ignores the estimates entirely.
    pub fn place(&self, estimates: &[ShardEstimate]) -> usize {
        assert!(!estimates.is_empty(), "place() needs at least one shard");
        match self.policy {
            RoutePolicy::RoundRobin => {
                self.rr_next.fetch_add(1, Ordering::Relaxed) % estimates.len()
            }
            RoutePolicy::ModelFinishTime => {
                let mut best = 0usize;
                for (i, e) in estimates.iter().enumerate().skip(1) {
                    if e.finish_s() < estimates[best].finish_s() {
                        best = i;
                    }
                }
                best
            }
        }
    }

    /// Cached predicted cost for `(shard, engine, n, kind)`, if still
    /// valid.
    pub fn cached_cost(
        &self,
        shard: usize,
        engine: EngineId,
        n: usize,
        kind: TransformKind,
    ) -> Option<f64> {
        self.costs.lock().unwrap().get(&(shard, engine, n, kind)).copied()
    }

    /// Memoize a freshly computed predicted cost.
    pub fn store_cost(
        &self,
        shard: usize,
        engine: EngineId,
        n: usize,
        kind: TransformKind,
        cost_s: f64,
    ) {
        self.costs.lock().unwrap().insert((shard, engine, n, kind), cost_s);
    }

    /// Feed the shard's current drift-event counter. When it moved since
    /// the last call, the shard's cached costs are purged (placement
    /// re-scores against the refreshed model) and `true` is returned.
    pub fn note_drift(&self, shard: usize, drift_total: u64) -> bool {
        {
            let mut seen = self.seen_drift.lock().unwrap();
            if shard >= seen.len() {
                seen.resize(shard + 1, 0);
            }
            if seen[shard] == drift_total {
                return false;
            }
            seen[shard] = drift_total;
        }
        self.costs.lock().unwrap().retain(|&(s, _, _, _), _| s != shard);
        self.rescores.fetch_add(1, Ordering::Relaxed);
        true
    }

    /// How many drift-driven re-scores have happened.
    pub fn rescore_events(&self) -> u64 {
        self.rescores.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn est(cost: f64, backlog: f64) -> ShardEstimate {
        ShardEstimate { cost_s: cost, backlog_s: backlog }
    }

    #[test]
    fn model_policy_picks_lowest_finish_time() {
        let r = Router::new(RoutePolicy::ModelFinishTime, 3);
        // shard 1 is slower per request but idle; shard 0 fast but backed up
        let picks = r.place(&[est(0.1, 1.0), est(0.3, 0.0), est(0.2, 0.5)]);
        assert_eq!(picks, 1);
        // ties break to the lower index
        assert_eq!(r.place(&[est(0.5, 0.0), est(0.5, 0.0)]), 0);
    }

    #[test]
    fn round_robin_rotates() {
        let r = Router::new(RoutePolicy::RoundRobin, 3);
        let e = [est(1.0, 0.0), est(0.0, 0.0), est(0.0, 0.0)];
        assert_eq!(r.place(&e), 0);
        assert_eq!(r.place(&e), 1);
        assert_eq!(r.place(&e), 2);
        assert_eq!(r.place(&e), 0);
    }

    #[test]
    fn drift_purges_only_that_shards_costs() {
        let native = EngineId::Native;
        let r = Router::new(RoutePolicy::ModelFinishTime, 2);
        r.store_cost(0, native, 1024, TransformKind::C2c, 0.5);
        r.store_cost(1, native, 1024, TransformKind::C2c, 0.7);
        // unchanged counter: no rescore
        assert!(!r.note_drift(0, 0));
        assert_eq!(r.rescore_events(), 0);
        // drift on shard 0 purges shard 0's cache only
        assert!(r.note_drift(0, 1));
        assert_eq!(r.rescore_events(), 1);
        assert!(r.cached_cost(0, native, 1024, TransformKind::C2c).is_none());
        assert_eq!(r.cached_cost(1, native, 1024, TransformKind::C2c), Some(0.7));
        // same counter again: cache stays
        r.store_cost(0, native, 1024, TransformKind::C2c, 0.9);
        assert!(!r.note_drift(0, 1));
        assert_eq!(r.cached_cost(0, native, 1024, TransformKind::C2c), Some(0.9));
    }

    #[test]
    fn cost_cache_is_engine_aware() {
        use crate::simulator::Package;
        let r = Router::new(RoutePolicy::ModelFinishTime, 1);
        let (a, b) = (EngineId::Sim(Package::Mkl), EngineId::Sim(Package::Fftw3));
        r.store_cost(0, a, 1024, TransformKind::C2c, 0.2);
        // a different engine at the same (shard, n, kind) is a miss
        assert_eq!(r.cached_cost(0, b, 1024, TransformKind::C2c), None);
        assert_eq!(r.cached_cost(0, a, 1024, TransformKind::C2c), Some(0.2));
    }

    #[test]
    fn policy_parse_names() {
        assert_eq!(RoutePolicy::parse("model"), Some(RoutePolicy::ModelFinishTime));
        assert_eq!(RoutePolicy::parse("rr"), Some(RoutePolicy::RoundRobin));
        assert_eq!(RoutePolicy::parse("round-robin"), Some(RoutePolicy::RoundRobin));
        assert_eq!(RoutePolicy::parse("nope"), None);
        assert_eq!(RoutePolicy::ModelFinishTime.name(), "model");
        assert_eq!(RoutePolicy::RoundRobin.name(), "round-robin");
    }
}
