//! The sharded front end: bounded admission + model-driven placement.
//!
//! A [`ShardedFront`] owns one [`Dft2dService`] per shard (each shard is
//! meant to be built for its own core subset, so every POPTA plan inside
//! it is computed for that shard's p) and a [`Router`] that places each
//! admitted request on the shard with the lowest model-predicted
//! completion time.
//!
//! Admission is a single bounded window across all shards: at most
//! `capacity` requests may be in flight (admitted, not yet completed).
//! An arrival beyond that is **shed** — the submit returns
//! [`ServiceError::Overloaded`] immediately, carrying the FPM-predicted
//! wait a retrying client should expect — instead of queueing without
//! bound. That keeps the open-loop tail finite: under overload the
//! latency of *accepted* work stays near the model's predicted
//! completion times while the excess is refused up front.
//!
//! [`ShardedFront::submit`] never blocks on transform work: it
//! validates/sheds/routes and hands back a [`Ticket`]. Completion flows
//! from the shard worker through the service's callback into the ticket,
//! where front-end latency is measured **from submission** (arrival),
//! not from dequeue — the number an external client actually observes.

use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::coordinator::engine::EngineId;
use crate::service::stats::{ServiceStats, StatsCollector};
use crate::service::{Dft2dRequest, Dft2dService, ServiceBuilder, ServiceError};
use crate::stats::harness::fft2d_flops;

use super::router::{RoutePolicy, Router, ShardEstimate};
use super::ticket::Ticket;

/// Front-end admission/placement knobs.
#[derive(Clone, Copy, Debug)]
pub struct FrontConfig {
    /// max requests in flight (admitted, not yet completed) across all
    /// shards — arrivals beyond this are shed with `Overloaded`
    pub capacity: usize,
    /// placement policy (model-predicted finish time, or round-robin)
    pub policy: RoutePolicy,
}

impl Default for FrontConfig {
    fn default() -> FrontConfig {
        FrontConfig { capacity: 64, policy: RoutePolicy::ModelFinishTime }
    }
}

/// Per-shard runtime state.
struct ShardRt {
    name: String,
    svc: Dft2dService,
    /// requests admitted to this shard and not yet completed
    outstanding: AtomicUsize,
    /// model-priced seconds of that outstanding work (the router's
    /// backlog term; decremented as completions arrive)
    outstanding_s: Mutex<f64>,
}

struct FrontInner {
    cfg: FrontConfig,
    shards: Vec<ShardRt>,
    router: Router,
    inflight: AtomicUsize,
    draining: AtomicBool,
    stats: StatsCollector,
    next_id: AtomicU64,
    started: Instant,
}

/// Sharded async serving front end. Cheap to clone (shared handle).
#[derive(Clone)]
pub struct ShardedFront {
    inner: Arc<FrontInner>,
}

/// Builds a [`ShardedFront`] from named per-shard [`ServiceBuilder`]s.
/// Pass paused builders ([`ServiceBuilder::paused`]) and call
/// [`ShardedFront::start`] later for deterministic virtual-time tests.
pub struct FrontBuilder {
    cfg: FrontConfig,
    shards: Vec<(String, ServiceBuilder)>,
}

impl FrontBuilder {
    pub fn new(cfg: FrontConfig) -> FrontBuilder {
        FrontBuilder { cfg, shards: Vec::new() }
    }

    /// Add a shard. The builder is consumed and built into a live (or
    /// paused, if so configured) [`Dft2dService`].
    pub fn shard(mut self, name: &str, builder: ServiceBuilder) -> FrontBuilder {
        self.shards.push((name.to_string(), builder));
        self
    }

    pub fn build(self) -> ShardedFront {
        assert!(!self.shards.is_empty(), "front end needs at least one shard");
        assert!(self.cfg.capacity >= 1, "admission capacity must be >= 1");
        let shard_count = self.shards.len();
        let shards = self
            .shards
            .into_iter()
            .map(|(name, b)| ShardRt {
                name,
                svc: b.build(),
                outstanding: AtomicUsize::new(0),
                outstanding_s: Mutex::new(0.0),
            })
            .collect();
        ShardedFront {
            inner: Arc::new(FrontInner {
                router: Router::new(self.cfg.policy, shard_count),
                cfg: self.cfg,
                shards,
                inflight: AtomicUsize::new(0),
                draining: AtomicBool::new(false),
                stats: StatsCollector::new(),
                next_id: AtomicU64::new(1),
                started: Instant::now(),
            }),
        }
    }
}

/// Aggregate + per-shard counters for one front end.
pub struct FrontStats {
    /// front-end view: latencies from submission, front-side sheds
    pub total: ServiceStats,
    /// each shard service's own lifetime stats, by shard name
    pub shards: Vec<(String, ServiceStats)>,
    /// drift-driven router re-scores so far
    pub rescore_events: u64,
}

impl FrontStats {
    pub fn render(&self) -> String {
        let mut out = self.total.render_table("front end (aggregate, latency from arrival)");
        for (name, s) in &self.shards {
            out.push('\n');
            out.push_str(&s.render_table(&format!("shard {name}")));
        }
        out.push_str(&format!("\nrouter re-scores after drift: {}\n", self.rescore_events));
        out
    }
}

impl ShardedFront {
    /// Start every shard's workers (no-op for shards already running).
    pub fn start(&self) {
        for sh in &self.inner.shards {
            sh.svc.start();
        }
    }

    pub fn shard_count(&self) -> usize {
        self.inner.shards.len()
    }

    pub fn shard_name(&self, i: usize) -> &str {
        &self.inner.shards[i].name
    }

    /// Direct handle to one shard's service (tests use this to inject
    /// drift or snapshot wisdom; production traffic goes via `submit`).
    pub fn shard_service(&self, i: usize) -> &Dft2dService {
        &self.inner.shards[i].svc
    }

    /// Requests currently admitted and not yet completed.
    pub fn inflight(&self) -> usize {
        self.inner.inflight.load(Ordering::Acquire)
    }

    pub fn policy(&self) -> RoutePolicy {
        self.inner.router.policy()
    }

    pub fn is_draining(&self) -> bool {
        self.inner.draining.load(Ordering::Acquire)
    }

    /// Non-blocking submit: shed-or-admit, route, enqueue on the chosen
    /// shard, return a [`Ticket`]. On `Ok`, the ticket resolves exactly
    /// once; on `Err`, nothing was enqueued anywhere.
    pub fn submit(&self, req: Dft2dRequest) -> Result<Ticket, ServiceError> {
        let inner = &self.inner;
        if inner.draining.load(Ordering::Acquire) {
            return Err(ServiceError::ShuttingDown);
        }
        // typed engine identity up front: an unknown engine name is the
        // stable UnknownEngine rejection (code 1) before an admission
        // slot is even reserved. `portfolio` is a valid id here — each
        // shard resolves it to a member at its own admission.
        let Some(engine) = EngineId::parse(&req.engine) else {
            inner.stats.record_rejection();
            return Err(ServiceError::UnknownEngine(req.engine));
        };
        // Reserve an admission slot, or shed. CAS keeps the window exact
        // under concurrent submitters.
        let mut cur = inner.inflight.load(Ordering::Acquire);
        loop {
            if cur >= inner.cfg.capacity {
                inner.stats.record_shed();
                return Err(ServiceError::Overloaded {
                    queued: cur,
                    capacity: inner.cfg.capacity,
                    predicted_wait_s: self.shortest_backlog_s(),
                });
            }
            match inner.inflight.compare_exchange_weak(
                cur,
                cur + 1,
                Ordering::AcqRel,
                Ordering::Acquire,
            ) {
                Ok(_) => break,
                Err(seen) => cur = seen,
            }
        }

        // Score every shard: model-predicted cost (cached until that
        // shard's model drifts) + model-priced outstanding backlog.
        let mut estimates = Vec::with_capacity(inner.shards.len());
        let mut costs = Vec::with_capacity(inner.shards.len());
        for (i, sh) in inner.shards.iter().enumerate() {
            inner.router.note_drift(i, sh.svc.drift_events_total());
            let cost_s = match inner.router.cached_cost(i, engine, req.n, req.kind) {
                Some(c) => c,
                None => {
                    let c = sh.svc.predicted_cost(&req.engine, req.n, req.kind);
                    inner.router.store_cost(i, engine, req.n, req.kind, c);
                    c
                }
            };
            let backlog_s = *sh.outstanding_s.lock().unwrap();
            estimates.push(ShardEstimate { cost_s, backlog_s });
            costs.push(cost_s);
        }
        let idx = inner.router.place(&estimates);
        let cost = costs[idx];
        let flops = fft2d_flops(req.n) * req.kind.flops_factor();
        let id = inner.next_id.fetch_add(1, Ordering::Relaxed);
        let arrived = Instant::now();

        // Reserve the shard's backlog *before* handing the request over:
        // the completion callback may fire on a worker thread before
        // submit_with even returns.
        let sh = &inner.shards[idx];
        sh.outstanding.fetch_add(1, Ordering::AcqRel);
        *sh.outstanding_s.lock().unwrap() += cost;

        let (ticket, completer) = Ticket::pending(id, idx);
        let cb_inner = Arc::clone(inner);
        let done = Box::new(move |r: Result<crate::service::Dft2dResponse, ServiceError>| {
            let sh = &cb_inner.shards[idx];
            {
                let mut s = sh.outstanding_s.lock().unwrap();
                *s = (*s - cost).max(0.0);
            }
            sh.outstanding.fetch_sub(1, Ordering::AcqRel);
            cb_inner.inflight.fetch_sub(1, Ordering::AcqRel);
            match &r {
                Ok(resp) => cb_inner.stats.record_completion(
                    arrived.elapsed().as_secs_f64(),
                    resp.report.queue_wait_s,
                    flops,
                ),
                Err(_) => cb_inner.stats.record_failure(),
            }
            completer.complete(r);
        });
        match sh.svc.submit_with(req, done) {
            Ok(_) => Ok(ticket),
            Err(e) => {
                // synchronous rejection: the callback will never fire,
                // so roll the reservations back here
                {
                    let mut s = sh.outstanding_s.lock().unwrap();
                    *s = (*s - cost).max(0.0);
                }
                sh.outstanding.fetch_sub(1, Ordering::AcqRel);
                inner.inflight.fetch_sub(1, Ordering::AcqRel);
                inner.stats.record_rejection();
                Err(e)
            }
        }
    }

    /// Cheapest model-priced backlog across shards — the predicted wait
    /// quoted to shed clients.
    fn shortest_backlog_s(&self) -> f64 {
        self.inner
            .shards
            .iter()
            .map(|sh| *sh.outstanding_s.lock().unwrap())
            .fold(f64::INFINITY, f64::min)
            .min(f64::MAX)
    }

    /// Drain and stop: new submits are rejected with `ShuttingDown`,
    /// every already-admitted request still executes and resolves its
    /// ticket, then the shard worker pools exit.
    pub fn shutdown(&self) {
        self.inner.draining.store(true, Ordering::Release);
        for sh in &self.inner.shards {
            // paused shards must run their accepted work before the
            // drain completes; start() is a no-op when already running
            sh.svc.start();
            sh.svc.shutdown();
        }
    }

    pub fn stats(&self) -> FrontStats {
        let wall_s = self.inner.started.elapsed().as_secs_f64();
        FrontStats {
            total: self.inner.stats.snapshot(wall_s),
            shards: self
                .inner
                .shards
                .iter()
                .map(|sh| (sh.name.clone(), sh.svc.stats()))
                .collect(),
            rescore_events: self.inner.router.rescore_events(),
        }
    }
}
