//! Length-prefixed binary wire protocol for the TCP front end.
//!
//! Zero-dependency (`std::io` only) framing shared by [`super::net`]'s
//! server and client. Every frame on the socket is
//!
//! ```text
//! u32 LE payload length | payload
//! ```
//!
//! and every payload starts with a fixed 16-byte header:
//!
//! ```text
//! offset  size  field
//! 0       4     magic "HCLF"
//! 4       1     version (1)
//! 5       1     opcode  (1 request, 2 response, 3 error,
//!                        4 shutdown, 5 shutdown-ack)
//! 6       2     reserved (0)
//! 8       8     req_id u64 LE (client-chosen; echoed in the reply)
//! ```
//!
//! Request body (after the header): `deadline_us u64` (0 = none),
//! `n u64`, `kind u8` (0 c2c / 1 r2c / 2 c2r), `direction u8`
//! (0 forward / 1 inverse), `engine_len u16` + UTF-8 engine name,
//! `re_count u64`, `im_count u64`, then the planes as f64 LE. An empty
//! `im` plane (count 0) means "all zeros" — the common real-signal case
//! ships half the bytes.
//!
//! The engine name's wire encoding is the canonical `EngineId`
//! spelling (`native`, `pjrt`, `sim-fftw2`, `sim-fftw3`, `sim-mkl`,
//! `portfolio` — see
//! [`EngineId::as_str`](crate::coordinator::engine::EngineId::as_str)).
//! Decode deliberately does **not** validate the name: an unknown
//! engine is an *admission* concern, rejected there as the typed
//! [`ServiceError::UnknownEngine`](crate::service::ServiceError) (stable
//! code 1) and shipped back as an error frame — not a protocol error
//! that would tear down the connection.
//!
//! Response body: `rows u64`, `cols u64`, `predicted_s f64`,
//! `executed_s f64`, `server_latency_s f64`, `shard u32`, `re_count
//! u64`, `im_count u64`, planes. Error body: `code u16` (the stable
//! [`crate::service::ServiceError::code`] mapping), `msg_len u32`,
//! UTF-8 message. Shutdown and shutdown-ack are header-only.
//!
//! Decoding is strict: bad magic/version/opcode, truncated bodies, or a
//! length prefix above the configured cap all surface as
//! [`std::io::ErrorKind::InvalidData`] — a misbehaving peer can not
//! make the server allocate unbounded memory or misparse a frame.

use std::io::{self, Read, Write};

use crate::dft::fft::Direction;
use crate::dft::real::TransformKind;

pub const MAGIC: [u8; 4] = *b"HCLF";
pub const VERSION: u8 = 1;
/// Default cap on one frame's payload (1 GiB covers n=8192 c2c planes).
pub const DEFAULT_MAX_FRAME: usize = 1 << 30;

const HEADER_LEN: usize = 16;

const OP_REQUEST: u8 = 1;
const OP_RESPONSE: u8 = 2;
const OP_ERROR: u8 = 3;
const OP_SHUTDOWN: u8 = 4;
const OP_SHUTDOWN_ACK: u8 = 5;

/// A transform request as it travels on the wire.
#[derive(Clone, Debug, PartialEq)]
pub struct WireRequest {
    pub req_id: u64,
    /// latency budget in microseconds; 0 = no deadline
    pub deadline_us: u64,
    pub n: u64,
    pub kind: TransformKind,
    pub direction: Direction,
    pub engine: String,
    pub re: Vec<f64>,
    /// empty = all-zero imaginary plane (real signals ship half the bytes)
    pub im: Vec<f64>,
}

/// A completed transform as it travels back.
#[derive(Clone, Debug, PartialEq)]
pub struct WireResponse {
    pub req_id: u64,
    pub rows: u64,
    pub cols: u64,
    pub predicted_s: f64,
    pub executed_s: f64,
    /// server-side latency from admission to completion
    pub server_latency_s: f64,
    /// shard index the router placed the request on
    pub shard: u32,
    pub re: Vec<f64>,
    pub im: Vec<f64>,
}

/// Every message the protocol can carry.
#[derive(Clone, Debug, PartialEq)]
pub enum Frame {
    Request(WireRequest),
    Response(WireResponse),
    /// typed rejection: `code` is the stable `ServiceError::code` value
    Error { req_id: u64, code: u16, message: String },
    /// client asks the server to drain and exit (if enabled)
    Shutdown { req_id: u64 },
    ShutdownAck { req_id: u64 },
}

impl Frame {
    pub fn req_id(&self) -> u64 {
        match self {
            Frame::Request(r) => r.req_id,
            Frame::Response(r) => r.req_id,
            Frame::Error { req_id, .. }
            | Frame::Shutdown { req_id }
            | Frame::ShutdownAck { req_id } => *req_id,
        }
    }
}

fn bad(msg: impl Into<String>) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, msg.into())
}

fn kind_code(kind: TransformKind) -> u8 {
    match kind {
        TransformKind::C2c => 0,
        TransformKind::R2c => 1,
        TransformKind::C2r => 2,
    }
}

fn kind_from(code: u8) -> io::Result<TransformKind> {
    match code {
        0 => Ok(TransformKind::C2c),
        1 => Ok(TransformKind::R2c),
        2 => Ok(TransformKind::C2r),
        other => Err(bad(format!("unknown transform kind code {other}"))),
    }
}

struct Enc {
    buf: Vec<u8>,
}

impl Enc {
    fn new(opcode: u8, req_id: u64) -> Enc {
        let mut buf = Vec::with_capacity(64);
        buf.extend_from_slice(&MAGIC);
        buf.push(VERSION);
        buf.push(opcode);
        buf.extend_from_slice(&[0, 0]);
        buf.extend_from_slice(&req_id.to_le_bytes());
        Enc { buf }
    }

    fn u16(&mut self, v: u16) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    fn f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    fn plane(&mut self, xs: &[f64]) {
        self.buf.reserve(xs.len() * 8);
        for x in xs {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }
}

struct Dec<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Dec<'a> {
    fn take(&mut self, len: usize) -> io::Result<&'a [u8]> {
        let end = self.pos.checked_add(len).ok_or_else(|| bad("length overflow"))?;
        if end > self.buf.len() {
            return Err(bad("truncated frame body"));
        }
        let s = &self.buf[self.pos..end];
        self.pos = end;
        Ok(s)
    }

    fn u8(&mut self) -> io::Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> io::Result<u16> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    fn u32(&mut self) -> io::Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> io::Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f64(&mut self) -> io::Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn plane(&mut self, count: u64) -> io::Result<Vec<f64>> {
        let count = usize::try_from(count).map_err(|_| bad("plane count overflow"))?;
        let raw = self.take(count.checked_mul(8).ok_or_else(|| bad("plane bytes overflow"))?)?;
        let mut out = Vec::with_capacity(count);
        for chunk in raw.chunks_exact(8) {
            out.push(f64::from_le_bytes(chunk.try_into().unwrap()));
        }
        Ok(out)
    }

    fn done(&self) -> io::Result<()> {
        if self.pos == self.buf.len() {
            Ok(())
        } else {
            Err(bad("trailing bytes after frame body"))
        }
    }
}

/// Serialize one frame's payload (everything after the length prefix).
pub fn encode_payload(frame: &Frame) -> Vec<u8> {
    match frame {
        Frame::Request(r) => {
            let mut e = Enc::new(OP_REQUEST, r.req_id);
            e.u64(r.deadline_us);
            e.u64(r.n);
            e.buf.push(kind_code(r.kind));
            e.buf.push(match r.direction {
                Direction::Forward => 0,
                Direction::Inverse => 1,
            });
            let name = r.engine.as_bytes();
            e.u16(name.len() as u16);
            e.buf.extend_from_slice(name);
            e.u64(r.re.len() as u64);
            e.u64(r.im.len() as u64);
            e.plane(&r.re);
            e.plane(&r.im);
            e.buf
        }
        Frame::Response(r) => {
            let mut e = Enc::new(OP_RESPONSE, r.req_id);
            e.u64(r.rows);
            e.u64(r.cols);
            e.f64(r.predicted_s);
            e.f64(r.executed_s);
            e.f64(r.server_latency_s);
            e.u32(r.shard);
            e.u64(r.re.len() as u64);
            e.u64(r.im.len() as u64);
            e.plane(&r.re);
            e.plane(&r.im);
            e.buf
        }
        Frame::Error { req_id, code, message } => {
            let mut e = Enc::new(OP_ERROR, *req_id);
            e.u16(*code);
            let msg = message.as_bytes();
            e.u32(msg.len() as u32);
            e.buf.extend_from_slice(msg);
            e.buf
        }
        Frame::Shutdown { req_id } => Enc::new(OP_SHUTDOWN, *req_id).buf,
        Frame::ShutdownAck { req_id } => Enc::new(OP_SHUTDOWN_ACK, *req_id).buf,
    }
}

/// Parse one frame payload (strict: every violation is `InvalidData`).
pub fn decode_payload(payload: &[u8]) -> io::Result<Frame> {
    if payload.len() < HEADER_LEN {
        return Err(bad("frame shorter than header"));
    }
    if payload[0..4] != MAGIC {
        return Err(bad("bad magic"));
    }
    if payload[4] != VERSION {
        return Err(bad(format!("unsupported protocol version {}", payload[4])));
    }
    let opcode = payload[5];
    if payload[6] != 0 || payload[7] != 0 {
        return Err(bad("nonzero reserved header bytes"));
    }
    let req_id = u64::from_le_bytes(payload[8..16].try_into().unwrap());
    let mut d = Dec { buf: payload, pos: HEADER_LEN };
    match opcode {
        OP_REQUEST => {
            let deadline_us = d.u64()?;
            let n = d.u64()?;
            let kind = kind_from(d.u8()?)?;
            let direction = match d.u8()? {
                0 => Direction::Forward,
                1 => Direction::Inverse,
                other => return Err(bad(format!("unknown direction code {other}"))),
            };
            let name_len = d.u16()? as usize;
            let engine = String::from_utf8(d.take(name_len)?.to_vec())
                .map_err(|_| bad("engine name is not UTF-8"))?;
            let re_count = d.u64()?;
            let im_count = d.u64()?;
            let re = d.plane(re_count)?;
            let im = d.plane(im_count)?;
            d.done()?;
            Ok(Frame::Request(WireRequest {
                req_id,
                deadline_us,
                n,
                kind,
                direction,
                engine,
                re,
                im,
            }))
        }
        OP_RESPONSE => {
            let rows = d.u64()?;
            let cols = d.u64()?;
            let predicted_s = d.f64()?;
            let executed_s = d.f64()?;
            let server_latency_s = d.f64()?;
            let shard = d.u32()?;
            let re_count = d.u64()?;
            let im_count = d.u64()?;
            let re = d.plane(re_count)?;
            let im = d.plane(im_count)?;
            d.done()?;
            Ok(Frame::Response(WireResponse {
                req_id,
                rows,
                cols,
                predicted_s,
                executed_s,
                server_latency_s,
                shard,
                re,
                im,
            }))
        }
        OP_ERROR => {
            let code = d.u16()?;
            let msg_len = d.u32()? as usize;
            let message = String::from_utf8(d.take(msg_len)?.to_vec())
                .map_err(|_| bad("error message is not UTF-8"))?;
            d.done()?;
            Ok(Frame::Error { req_id, code, message })
        }
        OP_SHUTDOWN => {
            d.done()?;
            Ok(Frame::Shutdown { req_id })
        }
        OP_SHUTDOWN_ACK => {
            d.done()?;
            Ok(Frame::ShutdownAck { req_id })
        }
        other => Err(bad(format!("unknown opcode {other}"))),
    }
}

/// Write one frame: length prefix + payload, then flush.
pub fn write_frame<W: Write>(w: &mut W, frame: &Frame) -> io::Result<()> {
    let payload = encode_payload(frame);
    let len = u32::try_from(payload.len())
        .map_err(|_| bad(format!("frame payload too large: {} bytes", payload.len())))?;
    w.write_all(&len.to_le_bytes())?;
    w.write_all(&payload)?;
    w.flush()
}

/// Blocking read of one frame. `max_len` bounds the allocation a peer
/// can force; a larger announced payload is rejected before reading it.
pub fn read_frame<R: Read>(r: &mut R, max_len: usize) -> io::Result<Frame> {
    let mut len_buf = [0u8; 4];
    r.read_exact(&mut len_buf)?;
    let len = u32::from_le_bytes(len_buf) as usize;
    if len > max_len {
        return Err(bad(format!("announced frame of {len} bytes exceeds cap {max_len}")));
    }
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload)?;
    decode_payload(&payload)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(f: &Frame) -> Frame {
        let mut buf = Vec::new();
        write_frame(&mut buf, f).unwrap();
        read_frame(&mut buf.as_slice(), DEFAULT_MAX_FRAME).unwrap()
    }

    #[test]
    fn request_roundtrips_bit_exact() {
        let req = Frame::Request(WireRequest {
            req_id: 42,
            deadline_us: 1_500_000,
            n: 8,
            kind: TransformKind::R2c,
            direction: Direction::Forward,
            engine: "native".into(),
            re: (0..64).map(|i| (i as f64).sin()).collect(),
            im: Vec::new(),
        });
        assert_eq!(roundtrip(&req), req);
        assert_eq!(req.req_id(), 42);
    }

    #[test]
    fn response_error_and_shutdown_roundtrip() {
        let resp = Frame::Response(WireResponse {
            req_id: 7,
            rows: 8,
            cols: 5,
            predicted_s: 0.25,
            executed_s: 0.5,
            server_latency_s: 0.75,
            shard: 3,
            re: vec![1.0, -2.0],
            im: vec![0.5, 0.25],
        });
        assert_eq!(roundtrip(&resp), resp);
        let err = Frame::Error { req_id: 9, code: 8, message: "overloaded: 4/4".into() };
        assert_eq!(roundtrip(&err), err);
        let shut = Frame::Shutdown { req_id: 1 };
        assert_eq!(roundtrip(&shut), shut);
        let ack = Frame::ShutdownAck { req_id: 1 };
        assert_eq!(roundtrip(&ack), ack);
    }

    #[test]
    fn corrupt_frames_are_invalid_data() {
        let mut buf = Vec::new();
        write_frame(&mut buf, &Frame::Shutdown { req_id: 1 }).unwrap();
        // flip the magic
        buf[4] = b'X';
        let e = read_frame(&mut buf.as_slice(), DEFAULT_MAX_FRAME).unwrap_err();
        assert_eq!(e.kind(), std::io::ErrorKind::InvalidData);
        // announced length above the cap is rejected before allocation
        let huge = (DEFAULT_MAX_FRAME as u32 + 1).to_le_bytes();
        let e = read_frame(&mut huge.as_slice(), 1024).unwrap_err();
        assert_eq!(e.kind(), std::io::ErrorKind::InvalidData);
        // truncated body
        let mut ok = Vec::new();
        write_frame(
            &mut ok,
            &Frame::Error { req_id: 2, code: 1, message: "nope".into() },
        )
        .unwrap();
        let cut = &ok[..ok.len() - 2];
        assert!(read_frame(&mut &cut[..], DEFAULT_MAX_FRAME).is_err());
    }

    #[test]
    fn kind_codes_are_stable() {
        for (kind, code) in [
            (TransformKind::C2c, 0u8),
            (TransformKind::R2c, 1),
            (TransformKind::C2r, 2),
        ] {
            assert_eq!(kind_code(kind), code);
            assert_eq!(kind_from(code).unwrap(), kind);
        }
        assert!(kind_from(3).is_err());
    }
}
