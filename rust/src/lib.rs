//! # hclfft — model-based performance optimization of multithreaded 2D-DFT
//!
//! Reproduction of *"Novel Model-based Methods for Performance Optimization
//! of Multithreaded 2D Discrete Fourier Transform on Multicore Processors"*
//! (Khokhriakov, Reddy, Lastovetsky — 2018).
//!
//! The crate is organised as the Layer-3 (rust) coordinator of a three-layer
//! rust + JAX + Pallas stack:
//!
//! * [`coordinator`] — the paper's contribution: functional performance
//!   models (FPMs), the POPTA / HPOPTA data-partitioning algorithms, and the
//!   `PFFT-LB` / `PFFT-FPM` / `PFFT-FPM-PAD` parallel 2D-DFT drivers.
//! * [`runtime`] — PJRT client wrapper that loads the AOT-compiled JAX /
//!   Pallas row-FFT artifacts (`artifacts/*.hlo.txt`) and executes them.
//! * [`dft`] — a from-scratch native FFT substrate (mixed-radix 2/3/5
//!   Stockham for 5-smooth lengths, radix-2, Bluestein fallback for
//!   non-smooth lengths, blocked transpose) plus the shared execution
//!   context ([`dft::exec::ExecCtx`]: one persistent worker pool +
//!   per-thread scratch arenas), the fused tiled 2D pipeline
//!   ([`dft::pipeline`]: stage-DAG tile scheduling + strided column
//!   FFTs — no whole-matrix transpose barriers), and the real-input
//!   path ([`dft::real`]: r2c pair kernel, Hermitian-packed
//!   `N×(N/2+1)` storage, c2r inverse — ~half the flops of c2c for
//!   real signals), used as the multithreaded compute engine and as an
//!   independent numeric oracle.
//! * [`simulator`] — calibrated performance models of the three FFT packages
//!   the paper studies (FFTW-2.1.5, FFTW-3.3.7, Intel MKL FFT); substitutes
//!   for the Haswell-36-core testbed that is not available here.
//! * [`model`] — the unified performance-model subsystem: FPM surfaces
//!   and sections, the [`model::PerfModel`] trait every planning /
//!   scheduling / admission consumer goes through, and its three
//!   implementations — static (measured), sim (virtual testbed) and
//!   online (learns from live traffic, detects drift, drives
//!   re-planning).
//! * [`stats`] — the paper's Student's-t measurement methodology
//!   (`MeanUsingTtest`, Algorithm 8) plus the bench harness built on it.
//! * [`figures`] — regenerates every figure/table of the paper's evaluation.
//! * [`service`] — the model-driven serving layer: a concurrent 2D-DFT
//!   server with size-bucketed batching, a persistent plan/partition
//!   *wisdom* store (FFTW-style), FPM-informed admission and
//!   shortest-predicted-job-first scheduling with a starvation bound,
//!   latency/throughput stats, and a deterministic virtual-time path via
//!   [`simulator`] for paper-scale scheduling tests. Request lifecycle:
//!   **submit → admit → batch → execute → respond** (see the module docs
//!   and README §Serving).
//! * [`serve`] — the sharded async serving front end layered on
//!   [`service`]: non-blocking submits resolving through
//!   [`serve::Ticket`]s, a bounded admission window that sheds with a
//!   typed `Overloaded` error carrying the model-predicted wait, a
//!   model-driven router placing each request on the shard with the
//!   lowest predicted completion time (re-scored on drift events), a
//!   zero-dependency length-prefixed TCP wire protocol + threaded
//!   server/client, and open-loop (fixed/Poisson) load generation with
//!   a deterministic virtual-time routing harness. Request lifecycle:
//!   **submit → shed-or-admit → route → shard service → ticket** (see
//!   README §Serving architecture).

pub mod cli;
pub mod config;
pub mod coordinator;
pub mod dft;
pub mod figures;
pub mod model;
pub mod profiler;
pub mod runtime;
pub mod serve;
pub mod service;
pub mod simulator;
pub mod stats;
pub mod util;
