//! Abstract processor groups — the paper's (p, t) configurations.
//!
//! An *abstract processor* is a group of t threads executing one
//! multithreaded row-FFT routine; p groups run in parallel. The paper
//! fixes the candidate set {(2,18), (4,9), (6,6), (9,4), (12,3)} on its
//! 36-core testbed and picks the best *experimentally per package*
//! (MKL → (2,18), FFTW → (4,9)). [`best_config`] reproduces that
//! selection procedure for any measurement closure.

/// One (p, t) abstract-processor configuration.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct GroupConfig {
    /// number of abstract processors (groups)
    pub p: usize,
    /// threads per group
    pub t: usize,
}

impl GroupConfig {
    pub fn new(p: usize, t: usize) -> Self {
        assert!(p >= 1 && t >= 1);
        GroupConfig { p, t }
    }

    /// Total thread count p·t.
    pub fn total_threads(&self) -> usize {
        self.p * self.t
    }
}

impl std::fmt::Display for GroupConfig {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "(p={}, t={})", self.p, self.t)
    }
}

/// The paper's candidate configurations for a 36-thread budget
/// (§IV-A: MKL candidates {(2,18),(4,9),(6,6),(9,4),(12,3)}).
pub fn paper_candidates() -> Vec<GroupConfig> {
    vec![
        GroupConfig::new(2, 18),
        GroupConfig::new(4, 9),
        GroupConfig::new(6, 6),
        GroupConfig::new(9, 4),
        GroupConfig::new(12, 3),
    ]
}

/// All (p, t) factorizations of a thread budget (ordered by p).
pub fn candidates_for_budget(total: usize) -> Vec<GroupConfig> {
    (2..=total)
        .filter(|p| total % p == 0)
        .map(|p| GroupConfig::new(p, total / p))
        .collect()
}

/// The paper's selection procedure: measure each candidate with the
/// load-balanced algorithm and keep the fastest (§IV-A "obtained from
/// the best load-balanced configuration observed experimentally").
pub fn best_config(
    candidates: &[GroupConfig],
    mut measure_seconds: impl FnMut(GroupConfig) -> f64,
) -> Option<(GroupConfig, f64)> {
    candidates
        .iter()
        .map(|&c| (c, measure_seconds(c)))
        .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
}

/// Row offsets implied by a distribution d: group i owns rows
/// [offsets[i], offsets[i+1]).
pub fn row_offsets(d: &[usize]) -> Vec<usize> {
    let mut offsets = Vec::with_capacity(d.len() + 1);
    let mut acc = 0;
    offsets.push(0);
    for &di in d {
        acc += di;
        offsets.push(acc);
    }
    offsets
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_candidates_are_36_threads() {
        for c in paper_candidates() {
            assert_eq!(c.total_threads(), 36, "{c}");
        }
    }

    #[test]
    fn budget_factorizations() {
        let cs = candidates_for_budget(12);
        assert!(cs.contains(&GroupConfig::new(2, 6)));
        assert!(cs.contains(&GroupConfig::new(4, 3)));
        assert!(cs.contains(&GroupConfig::new(12, 1)));
        for c in cs {
            assert_eq!(c.total_threads(), 12);
        }
    }

    #[test]
    fn best_config_picks_minimum() {
        let cands = paper_candidates();
        // pretend (4,9) is fastest, as the paper found for FFTW
        let (best, t) = best_config(&cands, |c| if c.p == 4 { 1.0 } else { 2.0 }).unwrap();
        assert_eq!(best, GroupConfig::new(4, 9));
        assert_eq!(t, 1.0);
    }

    #[test]
    fn offsets_accumulate() {
        assert_eq!(row_offsets(&[5, 3, 2, 6]), vec![0, 5, 8, 10, 16]);
        assert_eq!(row_offsets(&[]), vec![0]);
    }

    #[test]
    #[should_panic]
    fn zero_groups_rejected() {
        GroupConfig::new(0, 4);
    }
}
