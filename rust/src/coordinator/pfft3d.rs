//! PFFT-FPM-3D — the model-based parallel 3D-DFT (paper §VII future
//! work, built on the 2D machinery).
//!
//! Slab decomposition: the n×n×n cube's *slabs* (axis 0) are distributed
//! across p abstract processors by the same POPTA/HPOPTA step used for
//! 2D rows — each slab contributes n rows of length n per axis pass, so
//! the FPM plane section at y = n prices slab work exactly like row
//! work (x = slabs·n rows). The axis-0 pass rotates (d↔r) and reuses the
//! same distribution.

use crate::coordinator::engine::{EngineError, RowFftEngine};
use crate::coordinator::group::row_offsets;
use crate::coordinator::partition::{balanced, Partition, PartitionError};
use crate::dft::dft3d::{rotate_d_c, transpose_slabs, SignalCube};
use crate::dft::fft::Direction;
use crate::model::SpeedFunction;

/// Plan the slab distribution from FPM plane sections at y = n: the
/// curves' x axis is rows, so slab counts are planned on the (n·slabs)
/// row scale and converted back.
pub fn plan_slabs(fpms: &[SpeedFunction], n: usize, eps: f64) -> Result<Partition, PartitionError> {
    let part = crate::coordinator::pfft::plan_partition_fpms(fpms, n, eps)?;
    Ok(part)
}

/// Execute the model-based parallel 3D-DFT: three batched-row-FFT passes
/// with slab-partitioned concurrency, per-slab transposes and the axis
/// rotation handled by the coordinator.
pub fn pfft_fpm_3d(
    engine: &dyn RowFftEngine,
    cube: &mut SignalCube,
    d_slabs: &[usize],
    threads_per_group: usize,
    transpose_block: usize,
) -> Result<(), EngineError> {
    let n = cube.n;
    assert_eq!(d_slabs.iter().sum::<usize>(), n, "slab distribution must cover the cube");

    // pass 1: axis 2 (contiguous rows per slab range)
    slab_row_pass(engine, cube, d_slabs, threads_per_group)?;
    // pass 2: axis 1 via per-slab transpose
    parallel_transpose_slabs(cube, d_slabs, transpose_block, threads_per_group);
    slab_row_pass(engine, cube, d_slabs, threads_per_group)?;
    parallel_transpose_slabs(cube, d_slabs, transpose_block, threads_per_group);
    // pass 3: axis 0 via rotation (serial — O(n^3) swaps, bandwidth-bound)
    rotate_d_c(cube);
    slab_row_pass(engine, cube, d_slabs, threads_per_group)?;
    rotate_d_c(cube);
    Ok(())
}

/// Balanced 3D baseline (the PFFT-LB analogue).
pub fn pfft_lb_3d(
    engine: &dyn RowFftEngine,
    cube: &mut SignalCube,
    p: usize,
    threads_per_group: usize,
    transpose_block: usize,
) -> Result<(), EngineError> {
    let d = balanced(p, cube.n).d;
    pfft_fpm_3d(engine, cube, &d, threads_per_group, transpose_block)
}

/// One batched row-FFT pass with slabs partitioned across groups.
fn slab_row_pass(
    engine: &dyn RowFftEngine,
    cube: &mut SignalCube,
    d_slabs: &[usize],
    threads_per_group: usize,
) -> Result<(), EngineError> {
    let n = cube.n;
    let n2 = n * n;
    let offsets = row_offsets(d_slabs);

    let mut re_rest: &mut [f64] = &mut cube.re;
    let mut im_rest: &mut [f64] = &mut cube.im;
    let mut slices: Vec<(&mut [f64], &mut [f64])> = Vec::with_capacity(d_slabs.len());
    for i in 0..d_slabs.len() {
        let len = (offsets[i + 1] - offsets[i]) * n2;
        let (re_here, re_next) = re_rest.split_at_mut(len);
        let (im_here, im_next) = im_rest.split_at_mut(len);
        re_rest = re_next;
        im_rest = im_next;
        slices.push((re_here, im_here));
    }

    let errors: std::sync::Mutex<Vec<EngineError>> = std::sync::Mutex::new(Vec::new());
    std::thread::scope(|scope| {
        for (i, (re, im)) in slices.into_iter().enumerate() {
            let slabs = d_slabs[i];
            if slabs == 0 {
                continue;
            }
            let errors = &errors;
            scope.spawn(move || {
                if let Err(e) =
                    engine.fft_rows(re, im, slabs * n, n, Direction::Forward, threads_per_group)
                {
                    errors.lock().unwrap().push(e);
                }
            });
        }
    });
    match errors.into_inner().unwrap().into_iter().next() {
        Some(e) => Err(e),
        None => Ok(()),
    }
}

/// Per-slab transposes with the slab ranges assigned to groups.
fn parallel_transpose_slabs(
    cube: &mut SignalCube,
    d_slabs: &[usize],
    block: usize,
    threads: usize,
) {
    // slabs are independent; reuse the serial helper per range (groups
    // proceed sequentially here — transpose is bandwidth-bound on this
    // host and the ranges share the memory bus anyway)
    let offsets = row_offsets(d_slabs);
    for i in 0..d_slabs.len() {
        transpose_slabs(cube, offsets[i], offsets[i + 1], block, threads);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::NativeEngine;
    use crate::dft::dft3d::{dft3d, naive_dft3d};

    #[test]
    fn pfft3d_matches_naive() {
        let n = 4;
        let orig = SignalCube::random(n, 1);
        let mut c = orig.clone();
        pfft_fpm_3d(&NativeEngine, &mut c, &[1, 3], 1, 16).unwrap();
        let want = naive_dft3d(&orig);
        let scale = want.norm().max(1.0);
        assert!(c.max_abs_diff(&want) / scale < 1e-10);
    }

    #[test]
    fn pfft3d_matches_single_group_dft3d() {
        let n = 8;
        let orig = SignalCube::random(n, 2);
        let mut a = orig.clone();
        pfft_fpm_3d(&NativeEngine, &mut a, &[3, 5], 1, 16).unwrap();
        let mut b = orig.clone();
        dft3d(&mut b, Direction::Forward, 1);
        assert!(a.max_abs_diff(&b) < 1e-12);
    }

    #[test]
    fn pfft3d_lb_balanced() {
        let n = 6;
        let orig = SignalCube::random(n, 3);
        let mut a = orig.clone();
        pfft_lb_3d(&NativeEngine, &mut a, 3, 1, 16).unwrap();
        let mut b = orig.clone();
        dft3d(&mut b, Direction::Forward, 1);
        assert!(a.max_abs_diff(&b) < 1e-12);
    }

    #[test]
    fn zero_slab_groups_allowed() {
        let n = 4;
        let orig = SignalCube::random(n, 4);
        let mut c = orig.clone();
        pfft_fpm_3d(&NativeEngine, &mut c, &[0, 4, 0], 1, 16).unwrap();
        let mut want = orig.clone();
        dft3d(&mut want, Direction::Forward, 1);
        assert!(c.max_abs_diff(&want) < 1e-12);
    }

    #[test]
    #[should_panic(expected = "slab distribution")]
    fn wrong_slab_sum_panics() {
        let mut c = SignalCube::random(4, 5);
        let _ = pfft_fpm_3d(&NativeEngine, &mut c, &[1, 1], 1, 16);
    }
}
