//! `RowFftEngine` — the compute abstraction the PFFT drivers dispatch to
//! — plus the typed engine identity layer built on top of it.
//!
//! The paper's abstract processors execute "series of row 1D-FFTs"
//! (`1D_ROW_FFTS_LOCAL`); the engine trait is exactly that call. Three
//! implementations:
//!
//! * [`NativeEngine`] — the from-scratch rust FFT ([`crate::dft`]),
//!   dispatching through the shared executor
//!   ([`crate::dft::exec::fft_rows_pooled`]): mixed-radix for 5-smooth
//!   lengths, Bluestein fallback, persistent pool, per-thread scratch,
//! * `PjrtEngine` ([`crate::runtime`]) — AOT JAX/Pallas artifacts,
//! * a virtual-time engine in [`crate::simulator`] for paper-scale sizes.
//!
//! Engines operate on raw split-plane row slices so the drivers can hand
//! disjoint row ranges to concurrent abstract-processor threads with
//! `split_at_mut` — no interior locking on the hot path.
//!
//! On top of the trait sit the identity and construction APIs the rest
//! of the repo names engines by:
//!
//! * [`EngineId`] — the first-class engine identity (the paper's
//!   *package* axis: choosing among FFT implementations is itself a
//!   model decision). Replaces the bare strings previously threaded
//!   through wisdom keys, batch keys and service admission; parse one
//!   with [`EngineId::parse`]/`FromStr`, render with `Display`/
//!   [`EngineId::as_str`]. The canonical string is also the wire and
//!   persistence encoding, so old wisdom files and old clients
//!   interoperate losslessly.
//! * [`EngineRegistry`] — the single construction seam: every consumer
//!   (CLI subcommands, `Dft2dService`, the serve front end) obtains a
//!   backend through [`EngineRegistry::build`] instead of a per-call-site
//!   `match` on strings.

use crate::dft::fft::Direction;
use crate::dft::real::TransformKind;
use crate::simulator::Package;

/// Errors an engine can raise (artifact-backed engines can fail on
/// unsupported shapes; the native engine is total). Display/Error are
/// hand-implemented — the offline vendor set has no `thiserror`.
#[derive(Debug)]
pub enum EngineError {
    /// The engine cannot execute rows of this length. Engines construct
    /// it with [`EngineError::unsupported_length`] (they do not know the
    /// transform kind); the batching layer attaches the request context
    /// via [`EngineError::with_kind`] so a mid-batch failure names the
    /// `(n, kind, engine)` the admission-side validation knew.
    UnsupportedLength {
        n: usize,
        engine: String,
        kind: Option<TransformKind>,
    },
    Runtime(String),
}

impl EngineError {
    /// An unsupported-length error with no transform-kind context yet.
    pub fn unsupported_length(n: usize, engine: impl Into<String>) -> EngineError {
        EngineError::UnsupportedLength { n, engine: engine.into(), kind: None }
    }

    /// Attach the transform kind the failing batch was executing —
    /// engines raise length errors without it, the service layer has it.
    pub fn with_kind(self, kind: TransformKind) -> EngineError {
        match self {
            EngineError::UnsupportedLength { n, engine, .. } => {
                EngineError::UnsupportedLength { n, engine, kind: Some(kind) }
            }
            other => other,
        }
    }
}

impl std::fmt::Display for EngineError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            EngineError::UnsupportedLength { n, engine, kind: Some(k) } => {
                write!(f, "row length {n} ({} plane) not supported by engine `{engine}`", k.name())
            }
            EngineError::UnsupportedLength { n, engine, kind: None } => {
                write!(f, "row length {n} not supported by engine `{engine}`")
            }
            EngineError::Runtime(msg) => write!(f, "runtime failure: {msg}"),
        }
    }
}

impl std::error::Error for EngineError {}

/// First-class engine identity.
///
/// `Copy` + `Ord` so it keys ordered maps directly (wisdom records,
/// batch buckets, portfolio surfaces). The canonical string
/// ([`EngineId::as_str`]) is the stable wire encoding: requests carry it
/// as `u16 len + UTF-8` on the TCP protocol and wisdom JSON persists it,
/// so every pre-redesign artifact and client parses forward losslessly.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum EngineId {
    /// the from-scratch rust FFT ([`NativeEngine`])
    Native,
    /// AOT JAX/Pallas artifacts via PJRT ([`crate::runtime`])
    Pjrt,
    /// deterministic virtual-time testbed backend for one calibrated
    /// package ([`crate::simulator`])
    Sim(Package),
    /// not one engine but a policy: admission resolves each request to
    /// the fastest registered member engine per `(n, kind)` via the
    /// portfolio model ([`crate::model::PortfolioModel`])
    Portfolio,
}

impl EngineId {
    /// Every id (construction-order stable; used by roundtrip tests).
    pub const ALL: [EngineId; 6] = [
        EngineId::Native,
        EngineId::Pjrt,
        EngineId::Sim(Package::Fftw2),
        EngineId::Sim(Package::Fftw3),
        EngineId::Sim(Package::Mkl),
        EngineId::Portfolio,
    ];

    /// Canonical name — also the persisted/wire spelling. Stable.
    pub const fn as_str(&self) -> &'static str {
        match self {
            EngineId::Native => "native",
            EngineId::Pjrt => "pjrt",
            EngineId::Sim(Package::Fftw2) => "sim-fftw2",
            EngineId::Sim(Package::Fftw3) => "sim-fftw3",
            EngineId::Sim(Package::Mkl) => "sim-mkl",
            EngineId::Portfolio => "portfolio",
        }
    }

    /// Parse an engine name. Canonical spellings plus every
    /// `sim-<alias>` the package parser accepts (`sim-fftw-3.3.7`, ...),
    /// so engine strings from old wisdom files and old clients all
    /// resolve to the same typed id.
    pub fn parse(s: &str) -> Option<EngineId> {
        match s {
            "native" => Some(EngineId::Native),
            "pjrt" => Some(EngineId::Pjrt),
            "portfolio" => Some(EngineId::Portfolio),
            _ => s.strip_prefix("sim-").and_then(Package::parse).map(EngineId::Sim),
        }
    }

    /// Stable numeric encoding for compact binary contexts. Append-only:
    /// codes are never reassigned (the same contract as
    /// [`crate::service::ServiceError::code`]).
    pub const fn wire_code(&self) -> u8 {
        match self {
            EngineId::Native => 0,
            EngineId::Pjrt => 1,
            EngineId::Sim(Package::Fftw2) => 2,
            EngineId::Sim(Package::Fftw3) => 3,
            EngineId::Sim(Package::Mkl) => 4,
            EngineId::Portfolio => 5,
        }
    }

    pub const fn from_wire_code(code: u8) -> Option<EngineId> {
        match code {
            0 => Some(EngineId::Native),
            1 => Some(EngineId::Pjrt),
            2 => Some(EngineId::Sim(Package::Fftw2)),
            3 => Some(EngineId::Sim(Package::Fftw3)),
            4 => Some(EngineId::Sim(Package::Mkl)),
            5 => Some(EngineId::Portfolio),
            _ => None,
        }
    }

    /// Is this a virtual-time testbed backend?
    pub const fn is_sim(&self) -> bool {
        matches!(self, EngineId::Sim(_))
    }

    /// The calibrated package behind a `sim-*` id.
    pub const fn package(&self) -> Option<Package> {
        match self {
            EngineId::Sim(p) => Some(*p),
            _ => None,
        }
    }
}

impl std::fmt::Display for EngineId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

impl std::str::FromStr for EngineId {
    type Err = String;

    fn from_str(s: &str) -> Result<EngineId, String> {
        EngineId::parse(s).ok_or_else(|| {
            format!("unknown engine `{s}` (native|pjrt|sim-fftw2|sim-fftw3|sim-mkl|portfolio)")
        })
    }
}

/// One backend as [`EngineRegistry::build`] constructs it.
pub enum BuiltEngine {
    /// a real engine executing FFTs, shareable across worker threads
    Real(std::sync::Arc<dyn RowFftEngine + Send + Sync>),
    /// a virtual-time backend: requests are priced by the calibrated
    /// package model, never executed
    Virtual(Package),
}

/// The single engine-construction seam. Replaces the per-call-site
/// `match engine_name { ... }` arms previously scattered across the CLI
/// subcommands, `ServiceBuilder` and the serve front end — a new engine
/// (FFTW FFI, revived PJRT) slots in here once and every consumer gets
/// it.
#[derive(Clone, Debug, Default)]
pub struct EngineRegistry {
    artifacts: Option<std::path::PathBuf>,
}

impl EngineRegistry {
    /// A registry for artifact-free engines (everything but `pjrt`).
    pub fn new() -> EngineRegistry {
        EngineRegistry::default()
    }

    /// A registry that can additionally build the artifact-backed
    /// `pjrt` engine from `<dir>/manifest.tsv`.
    pub fn with_artifacts(dir: impl Into<std::path::PathBuf>) -> EngineRegistry {
        EngineRegistry { artifacts: Some(dir.into()) }
    }

    /// Construct the backend for an id. `Portfolio` is deliberately not
    /// buildable — it is a planning mode resolved at admission, not an
    /// engine; register its members and enable it via
    /// `ServiceBuilder::portfolio`.
    pub fn build(&self, id: EngineId) -> Result<BuiltEngine, String> {
        match id {
            EngineId::Native => Ok(BuiltEngine::Real(std::sync::Arc::new(NativeEngine))),
            EngineId::Pjrt => {
                let dir = self.artifacts.as_ref().ok_or_else(|| {
                    "engine `pjrt` needs an artifacts directory \
                     (EngineRegistry::with_artifacts / --artifacts)"
                        .to_string()
                })?;
                let eng = crate::runtime::PjrtRowFftEngine::load(dir).map_err(|e| e.to_string())?;
                Ok(BuiltEngine::Real(std::sync::Arc::new(eng)))
            }
            EngineId::Sim(pkg) => Ok(BuiltEngine::Virtual(pkg)),
            EngineId::Portfolio => Err(
                "`portfolio` is a planning mode, not a buildable engine: register member \
                 engines and resolve per request (ServiceBuilder::portfolio)"
                    .to_string(),
            ),
        }
    }
}

/// A compute engine executing batches of row 1D-FFTs in place.
pub trait RowFftEngine: Sync {
    /// Engine name for reports.
    fn name(&self) -> &str;

    /// Execute `rows` 1D-FFTs of length `n` over the contiguous
    /// split-plane buffers (`re.len() == rows * n`), using up to
    /// `threads` worker threads (the abstract processor's `t`).
    fn fft_rows(
        &self,
        re: &mut [f64],
        im: &mut [f64],
        rows: usize,
        n: usize,
        dir: Direction,
        threads: usize,
    ) -> Result<(), EngineError>;

    /// Row lengths this engine supports, or None for "any length".
    /// PFFT-FPM-PAD restricts pad candidates to supported lengths.
    fn supported_lengths(&self) -> Option<Vec<usize>> {
        None
    }

    /// Pad-candidate row lengths in `(n, n + window]` worth measuring
    /// for this engine (PFFT-FPM-PAD Step 2's search grid — the y grid
    /// of the measured surfaces the [`crate::model`] layer later serves
    /// column sections from). Default:
    /// the paper's 128-step grid, intersected with `supported_lengths`
    /// when the engine restricts lengths. Engines with a fast-length
    /// structure (e.g. the native mixed-radix kernel's 5-smooth
    /// lengths) override this so the pad search only prices lengths
    /// they are actually fast at — letting PFFT-FPM-PAD pick 640
    /// instead of jumping to 1024.
    fn pad_candidates(&self, n: usize, window: usize) -> Vec<usize> {
        let grid = crate::coordinator::pad::grid_candidates(n, window, 128);
        match self.supported_lengths() {
            None => grid,
            Some(supported) => grid.into_iter().filter(|y| supported.contains(y)).collect(),
        }
    }
}

/// The native rust FFT engine (mixed-radix + Bluestein, plan-cached).
/// A thin veneer over the shared executor: the row-FFT inner loop lives
/// exactly once, in [`crate::dft::exec::fft_rows_pooled`].
#[derive(Debug, Default, Clone, Copy)]
pub struct NativeEngine;

impl RowFftEngine for NativeEngine {
    fn name(&self) -> &str {
        "native"
    }

    fn fft_rows(
        &self,
        re: &mut [f64],
        im: &mut [f64],
        rows: usize,
        n: usize,
        dir: Direction,
        threads: usize,
    ) -> Result<(), EngineError> {
        debug_assert_eq!(re.len(), rows * n);
        crate::dft::exec::fft_rows_pooled(
            crate::dft::exec::ExecCtx::global(),
            re,
            im,
            rows,
            n,
            dir,
            threads,
        );
        Ok(())
    }

    /// Mixed-radix makes every 5-smooth length a fast length: restrict
    /// the pad search to 5-smooth points on the paper's 128-grid (with
    /// the plain grid as fallback when the window holds none).
    fn pad_candidates(&self, n: usize, window: usize) -> Vec<usize> {
        let smooth = crate::coordinator::pad::smooth_grid_candidates(n, window, 128);
        if smooth.is_empty() {
            crate::coordinator::pad::grid_candidates(n, window, 128)
        } else {
            smooth
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dft::{naive_dft_rows, SignalMatrix};

    #[test]
    fn native_engine_matches_naive() {
        let engine = NativeEngine;
        for &(rows, n) in &[(4usize, 16usize), (3, 24), (8, 128)] {
            let orig = SignalMatrix::random(rows, n, 9);
            let mut m = orig.clone();
            engine
                .fft_rows(&mut m.re, &mut m.im, rows, n, Direction::Forward, 2)
                .unwrap();
            let want = naive_dft_rows(&orig, false);
            let scale = want.norm().max(1.0);
            assert!(m.max_abs_diff(&want) / scale < 1e-9, "rows={rows} n={n}");
        }
    }

    #[test]
    fn native_engine_thread_count_invariant() {
        let engine = NativeEngine;
        let orig = SignalMatrix::random(16, 64, 3);
        let mut a = orig.clone();
        let mut b = orig.clone();
        engine.fft_rows(&mut a.re, &mut a.im, 16, 64, Direction::Forward, 1).unwrap();
        engine.fft_rows(&mut b.re, &mut b.im, 16, 64, Direction::Forward, 5).unwrap();
        assert!(a.max_abs_diff(&b) < 1e-14);
    }

    #[test]
    fn native_engine_supports_all_lengths() {
        assert_eq!(NativeEngine.supported_lengths(), None);
    }

    #[test]
    fn native_engine_non_pow2_smooth_matches_naive() {
        // the paper's 128·k sizes route through mixed-radix now
        let engine = NativeEngine;
        for &n in &[96usize, 384] {
            let orig = SignalMatrix::random(4, n, 13);
            let mut m = orig.clone();
            engine
                .fft_rows(&mut m.re, &mut m.im, 4, n, Direction::Forward, 3)
                .unwrap();
            let want = naive_dft_rows(&orig, false);
            let scale = want.norm().max(1.0);
            assert!(m.max_abs_diff(&want) / scale < 1e-9, "n={n}");
        }
    }

    #[test]
    fn small_row_count_large_n_still_bit_exact() {
        // regression: rows < threads used to clamp the thread budget;
        // the executor now splits within rows — values must not change
        let engine = NativeEngine;
        let n = crate::dft::exec::STAGE_PARALLEL_MIN_N;
        let orig = SignalMatrix::random(2, n, 21);
        let mut a = orig.clone();
        let mut b = orig.clone();
        engine.fft_rows(&mut a.re, &mut a.im, 2, n, Direction::Forward, 1).unwrap();
        engine.fft_rows(&mut b.re, &mut b.im, 2, n, Direction::Forward, 8).unwrap();
        assert_eq!(a.max_abs_diff(&b), 0.0);
        // and the chunking policy actually fans out past the row count
        assert_eq!(crate::dft::exec::work_units(2, n, 8), 8);
    }

    #[test]
    fn native_pad_candidates_are_five_smooth() {
        let c = NativeEngine.pad_candidates(384, 512);
        assert_eq!(c, vec![512, 640, 768], "896 = 128·7 must be filtered out");
        for &y in &c {
            assert!(crate::dft::radix::is_five_smooth(y));
        }
        // default (trait) grid keeps every 128-multiple
        struct AnyEngine;
        impl RowFftEngine for AnyEngine {
            fn name(&self) -> &str {
                "any"
            }
            fn fft_rows(
                &self,
                _re: &mut [f64],
                _im: &mut [f64],
                _rows: usize,
                _n: usize,
                _dir: Direction,
                _threads: usize,
            ) -> Result<(), EngineError> {
                Ok(())
            }
        }
        assert_eq!(AnyEngine.pad_candidates(384, 512), vec![512, 640, 768, 896]);
    }
}
