//! `RowFftEngine` — the compute abstraction the PFFT drivers dispatch to.
//!
//! The paper's abstract processors execute "series of row 1D-FFTs"
//! (`1D_ROW_FFTS_LOCAL`); the engine trait is exactly that call. Three
//! implementations:
//!
//! * [`NativeEngine`] — the from-scratch rust FFT ([`crate::dft`]),
//! * `PjrtEngine` ([`crate::runtime`]) — AOT JAX/Pallas artifacts,
//! * a virtual-time engine in [`crate::simulator`] for paper-scale sizes.
//!
//! Engines operate on raw split-plane row slices so the drivers can hand
//! disjoint row ranges to concurrent abstract-processor threads with
//! `split_at_mut` — no interior locking on the hot path.

use crate::dft::fft::Direction;

/// Errors an engine can raise (artifact-backed engines can fail on
/// unsupported shapes; the native engine is total). Display/Error are
/// hand-implemented — the offline vendor set has no `thiserror`.
#[derive(Debug)]
pub enum EngineError {
    UnsupportedLength(usize, String),
    Runtime(String),
}

impl std::fmt::Display for EngineError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            EngineError::UnsupportedLength(n, engine) => {
                write!(f, "row length {n} not supported by engine `{engine}`")
            }
            EngineError::Runtime(msg) => write!(f, "runtime failure: {msg}"),
        }
    }
}

impl std::error::Error for EngineError {}

/// A compute engine executing batches of row 1D-FFTs in place.
pub trait RowFftEngine: Sync {
    /// Engine name for reports.
    fn name(&self) -> &str;

    /// Execute `rows` 1D-FFTs of length `n` over the contiguous
    /// split-plane buffers (`re.len() == rows * n`), using up to
    /// `threads` worker threads (the abstract processor's `t`).
    fn fft_rows(
        &self,
        re: &mut [f64],
        im: &mut [f64],
        rows: usize,
        n: usize,
        dir: Direction,
        threads: usize,
    ) -> Result<(), EngineError>;

    /// Row lengths this engine supports, or None for "any length".
    /// PFFT-FPM-PAD restricts pad candidates to supported lengths.
    fn supported_lengths(&self) -> Option<Vec<usize>> {
        None
    }
}

/// The native rust FFT engine (radix-2 + Bluestein, plan-cached).
#[derive(Debug, Default, Clone, Copy)]
pub struct NativeEngine;

impl RowFftEngine for NativeEngine {
    fn name(&self) -> &str {
        "native"
    }

    fn fft_rows(
        &self,
        re: &mut [f64],
        im: &mut [f64],
        rows: usize,
        n: usize,
        dir: Direction,
        threads: usize,
    ) -> Result<(), EngineError> {
        debug_assert_eq!(re.len(), rows * n);
        let threads = threads.max(1).min(rows.max(1));
        if threads <= 1 || rows <= 1 {
            fft_rows_chunk(re, im, rows, n, dir);
            return Ok(());
        }
        let rows_per = rows.div_ceil(threads);
        std::thread::scope(|scope| {
            for (rc, ic) in re.chunks_mut(rows_per * n).zip(im.chunks_mut(rows_per * n)) {
                scope.spawn(move || {
                    fft_rows_chunk(rc, ic, rc.len() / n, n, dir);
                });
            }
        });
        Ok(())
    }
}

fn fft_rows_chunk(re: &mut [f64], im: &mut [f64], rows: usize, n: usize, dir: Direction) {
    if n.is_power_of_two() {
        let plan = crate::dft::plan::PlanCache::global().pow2(n);
        let mut sr = vec![0.0; n];
        let mut si = vec![0.0; n];
        for r in 0..rows {
            let span = r * n..(r + 1) * n;
            crate::dft::fft::fft_row_pow2(
                &mut re[span.clone()],
                &mut im[span],
                &mut sr,
                &mut si,
                &plan,
                dir,
            );
        }
    } else {
        let plan = crate::dft::plan::PlanCache::global().bluestein(n);
        let m = plan.scratch_len();
        let mut br = vec![0.0; m];
        let mut bi = vec![0.0; m];
        let mut sr = vec![0.0; m];
        let mut si = vec![0.0; m];
        for r in 0..rows {
            let span = r * n..(r + 1) * n;
            crate::dft::bluestein::fft_row_bluestein(
                &mut re[span.clone()],
                &mut im[span],
                &plan,
                dir,
                &mut br,
                &mut bi,
                &mut sr,
                &mut si,
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dft::{naive_dft_rows, SignalMatrix};

    #[test]
    fn native_engine_matches_naive() {
        let engine = NativeEngine;
        for &(rows, n) in &[(4usize, 16usize), (3, 24), (8, 128)] {
            let orig = SignalMatrix::random(rows, n, 9);
            let mut m = orig.clone();
            engine
                .fft_rows(&mut m.re, &mut m.im, rows, n, Direction::Forward, 2)
                .unwrap();
            let want = naive_dft_rows(&orig, false);
            let scale = want.norm().max(1.0);
            assert!(m.max_abs_diff(&want) / scale < 1e-9, "rows={rows} n={n}");
        }
    }

    #[test]
    fn native_engine_thread_count_invariant() {
        let engine = NativeEngine;
        let orig = SignalMatrix::random(16, 64, 3);
        let mut a = orig.clone();
        let mut b = orig.clone();
        engine.fft_rows(&mut a.re, &mut a.im, 16, 64, Direction::Forward, 1).unwrap();
        engine.fft_rows(&mut b.re, &mut b.im, 16, 64, Direction::Forward, 5).unwrap();
        assert!(a.max_abs_diff(&b) < 1e-14);
    }

    #[test]
    fn native_engine_supports_all_lengths() {
        assert_eq!(NativeEngine.supported_lengths(), None);
    }
}
