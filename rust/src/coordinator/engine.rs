//! `RowFftEngine` — the compute abstraction the PFFT drivers dispatch to.
//!
//! The paper's abstract processors execute "series of row 1D-FFTs"
//! (`1D_ROW_FFTS_LOCAL`); the engine trait is exactly that call. Three
//! implementations:
//!
//! * [`NativeEngine`] — the from-scratch rust FFT ([`crate::dft`]),
//!   dispatching through the shared executor
//!   ([`crate::dft::exec::fft_rows_pooled`]): mixed-radix for 5-smooth
//!   lengths, Bluestein fallback, persistent pool, per-thread scratch,
//! * `PjrtEngine` ([`crate::runtime`]) — AOT JAX/Pallas artifacts,
//! * a virtual-time engine in [`crate::simulator`] for paper-scale sizes.
//!
//! Engines operate on raw split-plane row slices so the drivers can hand
//! disjoint row ranges to concurrent abstract-processor threads with
//! `split_at_mut` — no interior locking on the hot path.

use crate::dft::fft::Direction;

/// Errors an engine can raise (artifact-backed engines can fail on
/// unsupported shapes; the native engine is total). Display/Error are
/// hand-implemented — the offline vendor set has no `thiserror`.
#[derive(Debug)]
pub enum EngineError {
    UnsupportedLength(usize, String),
    Runtime(String),
}

impl std::fmt::Display for EngineError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            EngineError::UnsupportedLength(n, engine) => {
                write!(f, "row length {n} not supported by engine `{engine}`")
            }
            EngineError::Runtime(msg) => write!(f, "runtime failure: {msg}"),
        }
    }
}

impl std::error::Error for EngineError {}

/// A compute engine executing batches of row 1D-FFTs in place.
pub trait RowFftEngine: Sync {
    /// Engine name for reports.
    fn name(&self) -> &str;

    /// Execute `rows` 1D-FFTs of length `n` over the contiguous
    /// split-plane buffers (`re.len() == rows * n`), using up to
    /// `threads` worker threads (the abstract processor's `t`).
    fn fft_rows(
        &self,
        re: &mut [f64],
        im: &mut [f64],
        rows: usize,
        n: usize,
        dir: Direction,
        threads: usize,
    ) -> Result<(), EngineError>;

    /// Row lengths this engine supports, or None for "any length".
    /// PFFT-FPM-PAD restricts pad candidates to supported lengths.
    fn supported_lengths(&self) -> Option<Vec<usize>> {
        None
    }

    /// Pad-candidate row lengths in `(n, n + window]` worth measuring
    /// for this engine (PFFT-FPM-PAD Step 2's search grid — the y grid
    /// of the measured surfaces the [`crate::model`] layer later serves
    /// column sections from). Default:
    /// the paper's 128-step grid, intersected with `supported_lengths`
    /// when the engine restricts lengths. Engines with a fast-length
    /// structure (e.g. the native mixed-radix kernel's 5-smooth
    /// lengths) override this so the pad search only prices lengths
    /// they are actually fast at — letting PFFT-FPM-PAD pick 640
    /// instead of jumping to 1024.
    fn pad_candidates(&self, n: usize, window: usize) -> Vec<usize> {
        let grid = crate::coordinator::pad::grid_candidates(n, window, 128);
        match self.supported_lengths() {
            None => grid,
            Some(supported) => grid.into_iter().filter(|y| supported.contains(y)).collect(),
        }
    }
}

/// The native rust FFT engine (mixed-radix + Bluestein, plan-cached).
/// A thin veneer over the shared executor: the row-FFT inner loop lives
/// exactly once, in [`crate::dft::exec::fft_rows_pooled`].
#[derive(Debug, Default, Clone, Copy)]
pub struct NativeEngine;

impl RowFftEngine for NativeEngine {
    fn name(&self) -> &str {
        "native"
    }

    fn fft_rows(
        &self,
        re: &mut [f64],
        im: &mut [f64],
        rows: usize,
        n: usize,
        dir: Direction,
        threads: usize,
    ) -> Result<(), EngineError> {
        debug_assert_eq!(re.len(), rows * n);
        crate::dft::exec::fft_rows_pooled(
            crate::dft::exec::ExecCtx::global(),
            re,
            im,
            rows,
            n,
            dir,
            threads,
        );
        Ok(())
    }

    /// Mixed-radix makes every 5-smooth length a fast length: restrict
    /// the pad search to 5-smooth points on the paper's 128-grid (with
    /// the plain grid as fallback when the window holds none).
    fn pad_candidates(&self, n: usize, window: usize) -> Vec<usize> {
        let smooth = crate::coordinator::pad::smooth_grid_candidates(n, window, 128);
        if smooth.is_empty() {
            crate::coordinator::pad::grid_candidates(n, window, 128)
        } else {
            smooth
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dft::{naive_dft_rows, SignalMatrix};

    #[test]
    fn native_engine_matches_naive() {
        let engine = NativeEngine;
        for &(rows, n) in &[(4usize, 16usize), (3, 24), (8, 128)] {
            let orig = SignalMatrix::random(rows, n, 9);
            let mut m = orig.clone();
            engine
                .fft_rows(&mut m.re, &mut m.im, rows, n, Direction::Forward, 2)
                .unwrap();
            let want = naive_dft_rows(&orig, false);
            let scale = want.norm().max(1.0);
            assert!(m.max_abs_diff(&want) / scale < 1e-9, "rows={rows} n={n}");
        }
    }

    #[test]
    fn native_engine_thread_count_invariant() {
        let engine = NativeEngine;
        let orig = SignalMatrix::random(16, 64, 3);
        let mut a = orig.clone();
        let mut b = orig.clone();
        engine.fft_rows(&mut a.re, &mut a.im, 16, 64, Direction::Forward, 1).unwrap();
        engine.fft_rows(&mut b.re, &mut b.im, 16, 64, Direction::Forward, 5).unwrap();
        assert!(a.max_abs_diff(&b) < 1e-14);
    }

    #[test]
    fn native_engine_supports_all_lengths() {
        assert_eq!(NativeEngine.supported_lengths(), None);
    }

    #[test]
    fn native_engine_non_pow2_smooth_matches_naive() {
        // the paper's 128·k sizes route through mixed-radix now
        let engine = NativeEngine;
        for &n in &[96usize, 384] {
            let orig = SignalMatrix::random(4, n, 13);
            let mut m = orig.clone();
            engine
                .fft_rows(&mut m.re, &mut m.im, 4, n, Direction::Forward, 3)
                .unwrap();
            let want = naive_dft_rows(&orig, false);
            let scale = want.norm().max(1.0);
            assert!(m.max_abs_diff(&want) / scale < 1e-9, "n={n}");
        }
    }

    #[test]
    fn small_row_count_large_n_still_bit_exact() {
        // regression: rows < threads used to clamp the thread budget;
        // the executor now splits within rows — values must not change
        let engine = NativeEngine;
        let n = crate::dft::exec::STAGE_PARALLEL_MIN_N;
        let orig = SignalMatrix::random(2, n, 21);
        let mut a = orig.clone();
        let mut b = orig.clone();
        engine.fft_rows(&mut a.re, &mut a.im, 2, n, Direction::Forward, 1).unwrap();
        engine.fft_rows(&mut b.re, &mut b.im, 2, n, Direction::Forward, 8).unwrap();
        assert_eq!(a.max_abs_diff(&b), 0.0);
        // and the chunking policy actually fans out past the row count
        assert_eq!(crate::dft::exec::work_units(2, n, 8), 8);
    }

    #[test]
    fn native_pad_candidates_are_five_smooth() {
        let c = NativeEngine.pad_candidates(384, 512);
        assert_eq!(c, vec![512, 640, 768], "896 = 128·7 must be filtered out");
        for &y in &c {
            assert!(crate::dft::radix::is_five_smooth(y));
        }
        // default (trait) grid keeps every 128-multiple
        struct AnyEngine;
        impl RowFftEngine for AnyEngine {
            fn name(&self) -> &str {
                "any"
            }
            fn fft_rows(
                &self,
                _re: &mut [f64],
                _im: &mut [f64],
                _rows: usize,
                _n: usize,
                _dir: Direction,
                _threads: usize,
            ) -> Result<(), EngineError> {
                Ok(())
            }
        }
        assert_eq!(AnyEngine.pad_candidates(384, 512), vec![512, 640, 768, 896]);
    }
}
