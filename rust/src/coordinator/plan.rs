//! `PlannedTransform` — the reusable outcome of PFFT planning.
//!
//! Steps 1 (POPTA/HPOPTA partitioning) and 2 (`Determine_Pad_Length`)
//! are the expensive, input-independent part of every PFFT-FPM /
//! PFFT-FPM-PAD run: they depend only on (engine, N, p, ε), never on the
//! signal itself. This module bundles their result into one value that
//!
//! * the drivers execute directly ([`PlannedTransform::execute`]),
//! * the serving layer memoizes in its wisdom store
//!   ([`crate::service::wisdom`]) and persists as JSON, and
//! * `main.rs` / the benches build once and reuse across repetitions —
//!   the shared seam that used to be duplicated between
//!   `coordinator/pfft.rs` and `coordinator/pad.rs` call sites.
//!
//! A plan also **compiles** into an [`ExecPipeline`]: the tile schedule
//! of the fused execution path. Row-stage tiles carry each group's pad
//! length as a scratch *stride* (Algorithm 7's padded work matrix,
//! tile-sized), column-stage tiles transpose-gather their columns into
//! scratch at the same stride — so padding is a stride choice inside a
//! cache-resident tile, never a whole-matrix `pad_cols`/`crop_cols`
//! copy, and the two transpose barriers of the four-step skeleton
//! disappear. Compilation is input-independent, like the plan itself.
//!
//! Plans are kernel-generation-relative: the FPM surfaces they are
//! planned over describe one row kernel
//! ([`crate::dft::radix::kernel_generation`] — scalar, AVX2, or the
//! FMA generation), so persisted plans/wisdom re-measure when the
//! runtime-detected generation changes. Below a dispatch tile, rows
//! additionally advance in model-chosen multi-row kernel tiles
//! ([`crate::dft::exec::preferred_row_tile`]); that choice is made at
//! execution time from the same `PerfModel`-shaped surface, so it needs
//! no plan-level state.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use crate::coordinator::engine::{EngineError, RowFftEngine};
use crate::coordinator::group::row_offsets;
use crate::coordinator::pad::{pads_for_distribution, PadCost, PadDecision};
use crate::coordinator::partition::{balanced, Algorithm, PartitionError};
use crate::coordinator::pfft::{
    pfft_fpm_pad_with_mode, pfft_fpm_with_mode, plan_partition, PfftReport,
};
use crate::dft::exec::{with_scratch, ExecCtx};
use crate::dft::fft::Direction;
use crate::dft::pipeline::{
    default_mode, gather_col_tile, scatter_col_tile, PipelineMode, SendPtr, StageDag,
    DEFAULT_COL_TILE, DEFAULT_ROW_TILE,
};
use crate::dft::real::TransformKind;
use crate::dft::SignalMatrix;
use crate::model::{PerfModel, SpeedFunction, StaticModel};

/// A fully planned N×N 2D-DFT: row distribution + per-group pad lengths.
#[derive(Clone, Debug, PartialEq)]
pub struct PlannedTransform {
    /// problem size (rows == cols == n)
    pub n: usize,
    /// rows per abstract processor, Σ = n
    pub d: Vec<usize>,
    /// per-processor pad decisions (n_padded == n when unpadded)
    pub pads: Vec<PadDecision>,
    /// which partitioning algorithm produced `d`
    pub algorithm: Algorithm,
    /// predicted makespan in relative `x / s(x)` units (NaN when
    /// unavailable, e.g. the balanced fallback)
    pub makespan: f64,
    /// which transform this plan targets (c2c or the real r2c plane —
    /// real planes run ~2x faster, so their FPM surfaces and hence
    /// their POPTA/HPOPTA partitions are measured and keyed separately)
    pub kind: TransformKind,
}

impl PlannedTransform {
    /// Plan from any performance model: ε-identity test + POPTA/HPOPTA
    /// over the model's plane sections, then the pad search over its
    /// column sections (windowed to `pad_window` above N) when
    /// `pad_cost` is given, or trivial pads (exact row length) when
    /// `None`. This is the single planning entry point — static
    /// surfaces, the virtual testbed and the online model all plan
    /// through it.
    pub fn from_model(
        model: &dyn PerfModel,
        n: usize,
        eps: f64,
        pad_cost: Option<PadCost>,
        pad_window: usize,
    ) -> Result<PlannedTransform, PartitionError> {
        let part = plan_partition(model, n, eps)?;
        let pads = match pad_cost {
            Some(cost) => pads_for_distribution(model, &part.d, n, pad_window, cost),
            None => trivial_pads(part.d.len(), n),
        };
        Ok(PlannedTransform {
            n,
            d: part.d,
            pads,
            algorithm: part.algorithm,
            makespan: part.makespan,
            kind: TransformKind::C2c,
        })
    }

    /// Re-key this plan for another transform kind (builder style). The
    /// partition math is kind-agnostic — what differs per kind is which
    /// measured surfaces fed the model, which the caller controls.
    pub fn with_kind(mut self, kind: TransformKind) -> PlannedTransform {
        self.kind = kind;
        self
    }

    /// [`PlannedTransform::from_model`] over raw measured surfaces
    /// (wraps them in a [`StaticModel`]; unbounded pad window — the
    /// measured grid already bounds the candidates).
    pub fn from_fpms(
        fpms: &[SpeedFunction],
        n: usize,
        eps: f64,
        pad_cost: Option<PadCost>,
    ) -> Result<PlannedTransform, PartitionError> {
        Self::from_model(&StaticModel::from_slice(fpms), n, eps, pad_cost, usize::MAX)
    }

    /// The model-free fallback: balanced rows, no padding. Used when
    /// planning inputs are degenerate (empty curves, unreachable N).
    pub fn balanced_fallback(p: usize, n: usize) -> PlannedTransform {
        let part = balanced(p, n);
        PlannedTransform {
            n,
            d: part.d.clone(),
            pads: trivial_pads(part.d.len(), n),
            algorithm: Algorithm::Balanced,
            makespan: f64::NAN,
            kind: TransformKind::C2c,
        }
    }

    /// Number of abstract processors.
    pub fn groups(&self) -> usize {
        self.d.len()
    }

    /// Padded row length per processor (== n when unpadded).
    pub fn pad_lens(&self) -> Vec<usize> {
        self.pads.iter().map(|p| p.n_padded).collect()
    }

    /// Does any processor actually pad?
    pub fn is_padded(&self) -> bool {
        self.pads.iter().any(|p| p.n_padded > self.n)
    }

    /// Execute the planned transform on one signal matrix — dispatches to
    /// PFFT-FPM or PFFT-FPM-PAD depending on whether padding is active,
    /// under the process-wide [`PipelineMode`].
    pub fn execute(
        &self,
        engine: &dyn RowFftEngine,
        m: &mut SignalMatrix,
        threads_per_group: usize,
        transpose_block: usize,
    ) -> Result<PfftReport, EngineError> {
        self.execute_with_mode(engine, m, threads_per_group, transpose_block, default_mode())
    }

    /// [`PlannedTransform::execute`] with an explicit pipeline mode
    /// (tests and A/B benches).
    pub fn execute_with_mode(
        &self,
        engine: &dyn RowFftEngine,
        m: &mut SignalMatrix,
        threads_per_group: usize,
        transpose_block: usize,
        mode: PipelineMode,
    ) -> Result<PfftReport, EngineError> {
        assert_eq!(
            self.kind,
            TransformKind::C2c,
            "real-kind plans execute via coordinator::real, not the c2c drivers"
        );
        if self.is_padded() {
            pfft_fpm_pad_with_mode(
                engine,
                m,
                &self.d,
                &self.pads,
                threads_per_group,
                transpose_block,
                mode,
            )
        } else {
            pfft_fpm_with_mode(engine, m, &self.d, threads_per_group, transpose_block, mode)
        }
    }

    /// Compile this plan into its fused-execution tile schedule.
    pub fn pipeline(&self) -> ExecPipeline {
        let pad_lens = self.pad_lens();
        let pads = if self.is_padded() { Some(pad_lens.as_slice()) } else { None };
        ExecPipeline::compile(self.n, &self.d, pads)
    }

    /// Predicted execution seconds of the two row phases from the stored
    /// relative makespan: `x/s` units × `2.5·n·log2(n) / 1e6` converts to
    /// seconds (the constant the minimax cancelled out). Falls back to a
    /// flat speed assumption when the makespan is unavailable. Real-kind
    /// plans: the makespan already reflects the real plane's measured
    /// (~2x faster) surfaces, so only the flat fallback needs the
    /// kind's flop factor.
    pub fn predicted_seconds(&self, fallback_mflops: f64) -> f64 {
        let n = self.n as f64;
        if self.makespan.is_finite() && self.makespan > 0.0 {
            2.0 * self.makespan * 2.5 * n * n.log2() / 1e6
        } else {
            crate::stats::harness::fft2d_flops(self.n) * self.kind.flops_factor()
                / (fallback_mflops.max(1.0) * 1e6)
        }
    }
}

pub(crate) fn trivial_pads(p: usize, n: usize) -> Vec<PadDecision> {
    vec![PadDecision { n_padded: n, t_unpadded: 0.0, t_padded: 0.0 }; p]
}

// ---------------------------------------------------------------------------
// The compiled execution pipeline
// ---------------------------------------------------------------------------

/// One tile of a pipeline stage: `len` rows (row stage) or columns
/// (column stage) starting at `start`, transformed at FFT length
/// `fft_len` (== n unpadded; the group's pad length otherwise, applied
/// as the scratch stride).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TileSpec {
    pub start: usize,
    pub len: usize,
    pub fft_len: usize,
}

/// Per-phase execution time of one pipeline run over a whole batch.
///
/// Fused mode reports summed per-tile busy seconds (work time across
/// all cooperating workers; can exceed wall time); barrier mode reports
/// wall seconds of the row-FFT phases (`row_s`) and of the transpose
/// passes (`col_s`). In both modes `col_s` tracks the memory-bound
/// share of the transform — the signal behind the model layer's
/// compute-vs-memory drift classification.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct PhaseTimings {
    pub row_s: f64,
    pub col_s: f64,
}

/// The compiled form of a [`PlannedTransform`]: the tile schedule the
/// fused execution path runs as a [`StageDag`] on the shared pool.
///
/// Row tiles partition each group's row range ([`DEFAULT_ROW_TILE`]
/// rows each); column tiles partition the same ranges *as columns*
/// ([`DEFAULT_COL_TILE`] wide) — in phase 2 the distribution `d`
/// governs columns, since the transposed matrix's rows are the original
/// columns. In a batched execution each matrix gets its own row → join
/// → column subgraph, so one matrix's column tiles execute while the
/// next matrix's row tiles are still in flight: work flows through the
/// batch with no per-phase barrier, and the slowest group only delays
/// its own matrix's column start.
#[derive(Clone, Debug, PartialEq)]
pub struct ExecPipeline {
    pub n: usize,
    pub row_tiles: Vec<TileSpec>,
    pub col_tiles: Vec<TileSpec>,
}

impl ExecPipeline {
    /// Build the tile schedule for distribution `d` over an n×n matrix
    /// (pad lengths per group when given; every pad must be ≥ n).
    pub fn compile(n: usize, d: &[usize], pad_lens: Option<&[usize]>) -> ExecPipeline {
        if let Some(p) = pad_lens {
            assert_eq!(p.len(), d.len());
            assert!(p.iter().all(|&v| v >= n), "pad length below N");
        }
        let offsets = row_offsets(d);
        let mut row_tiles = Vec::new();
        let mut col_tiles = Vec::new();
        for (i, &di) in d.iter().enumerate() {
            if di == 0 {
                continue;
            }
            let v = pad_lens.map(|p| p[i]).unwrap_or(n);
            let end = offsets[i] + di;
            let mut r = offsets[i];
            while r < end {
                let len = DEFAULT_ROW_TILE.min(end - r);
                row_tiles.push(TileSpec { start: r, len, fft_len: v });
                r += len;
            }
            let mut c = offsets[i];
            while c < end {
                let len = DEFAULT_COL_TILE.min(end - c);
                col_tiles.push(TileSpec { start: c, len, fft_len: v });
                c += len;
            }
        }
        ExecPipeline { n, row_tiles, col_tiles }
    }

    /// Execute the pipeline over a batch of same-size matrices with up
    /// to `workers` cooperating pool jobs. Bit-exact vs the barrier
    /// four-step path for any engine whose `fft_rows` transforms each
    /// row independently of batching (the documented engine contract).
    pub fn execute_batch(
        &self,
        engine: &dyn RowFftEngine,
        mats: &mut [&mut SignalMatrix],
        workers: usize,
    ) -> Result<PhaseTimings, EngineError> {
        let n = self.n;
        for m in mats.iter() {
            assert_eq!((m.rows, m.cols), (n, n), "pipeline matrix shape mismatch");
        }
        if mats.is_empty() || n == 0 {
            return Ok(PhaseTimings::default());
        }
        let errors: Mutex<Vec<EngineError>> = Mutex::new(Vec::new());
        let row_ns = AtomicU64::new(0);
        let col_ns = AtomicU64::new(0);

        let ptrs: Vec<(SendPtr, SendPtr)> = mats
            .iter_mut()
            .map(|m| {
                let mm: &mut SignalMatrix = &mut **m;
                (SendPtr(mm.re.as_mut_ptr()), SendPtr(mm.im.as_mut_ptr()))
            })
            .collect();

        let mut dag = StageDag::new();
        for &(re_ptr, im_ptr) in &ptrs {
            let mut row_ids = Vec::with_capacity(self.row_tiles.len());
            for &tile in &self.row_tiles {
                let errors = &errors;
                let row_ns = &row_ns;
                row_ids.push(dag.add(move || {
                    // rebind the wrappers whole (2021 precise capture)
                    let (re_ptr, im_ptr) = (re_ptr, im_ptr);
                    // SAFETY: each row tile materializes `&mut` over its
                    // OWN disjoint row range only (tiles partition the
                    // rows; distinct matrices use distinct buffers);
                    // column tasks are ordered strictly after every row
                    // tile by DAG edges, so these slices are dead before
                    // any cross-range access; run() returns only after
                    // all tasks end, so the borrows in `mats` outlive
                    // every access.
                    let (re, im) = unsafe {
                        (
                            std::slice::from_raw_parts_mut(
                                re_ptr.0.add(tile.start * n),
                                tile.len * n,
                            ),
                            std::slice::from_raw_parts_mut(
                                im_ptr.0.add(tile.start * n),
                                tile.len * n,
                            ),
                        )
                    };
                    let t0 = Instant::now();
                    let r = row_tile_ffts(engine, re, im, n, tile);
                    row_ns.fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
                    if let Err(e) = r {
                        errors.lock().unwrap().push(e);
                    }
                }));
            }
            // a no-op join keeps the edge count O(R + C), not R·C
            let join = dag.add(|| {});
            for id in row_ids {
                dag.add_edge(id, join);
            }
            for &tile in &self.col_tiles {
                let errors = &errors;
                let col_ns = &col_ns;
                let cid = dag.add(move || {
                    let (re_ptr, im_ptr) = (re_ptr, im_ptr);
                    let t0 = Instant::now();
                    let r = col_tile_ffts(engine, re_ptr, im_ptr, n, tile);
                    col_ns.fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
                    if let Err(e) = r {
                        errors.lock().unwrap().push(e);
                    }
                });
                dag.add_edge(join, cid);
            }
        }
        dag.run(ExecCtx::global(), workers);

        match errors.into_inner().unwrap().into_iter().next() {
            Some(e) => Err(e),
            None => Ok(PhaseTimings {
                row_s: row_ns.load(Ordering::Relaxed) as f64 * 1e-9,
                col_s: col_ns.load(Ordering::Relaxed) as f64 * 1e-9,
            }),
        }
    }
}

/// One row-stage tile over its own `tile.len × n` row slice: FFT in
/// place (unpadded), or via a stride-`fft_len` scratch work tile
/// (Algorithm 7, tile-sized).
fn row_tile_ffts(
    engine: &dyn RowFftEngine,
    re: &mut [f64],
    im: &mut [f64],
    n: usize,
    tile: TileSpec,
) -> Result<(), EngineError> {
    debug_assert_eq!(re.len(), tile.len * n);
    if tile.fft_len == n {
        return engine.fft_rows(re, im, tile.len, n, Direction::Forward, 1);
    }
    let v = tile.fft_len;
    with_scratch(|scratch| {
        let (wre, wim) = scratch.pair(tile.len * v);
        for r in 0..tile.len {
            let src = r * n;
            wre[r * v..r * v + n].copy_from_slice(&re[src..src + n]);
            wim[r * v..r * v + n].copy_from_slice(&im[src..src + n]);
        }
        engine.fft_rows(wre, wim, tile.len, v, Direction::Forward, 1)?;
        for r in 0..tile.len {
            let dst = r * n;
            re[dst..dst + n].copy_from_slice(&wre[r * v..r * v + n]);
            im[dst..dst + n].copy_from_slice(&wim[r * v..r * v + n]);
        }
        Ok(())
    })
}

/// One column-stage tile: transpose-gather columns `[start, start+len)`
/// into scratch rows of length `fft_len` (zero tail = stride-choice
/// padding), one engine call, scatter the first n spectrum points back.
/// This replaces the transpose barrier *and* the padded copy. The
/// gather/scatter are the shared raw-pointer primitives
/// ([`gather_col_tile`]/[`scatter_col_tile`]) — concurrent column
/// tiles never hold overlapping `&mut` plane slices.
fn col_tile_ffts(
    engine: &dyn RowFftEngine,
    re: SendPtr,
    im: SendPtr,
    n: usize,
    tile: TileSpec,
) -> Result<(), EngineError> {
    let (c0, w, v) = (tile.start, tile.len, tile.fft_len);
    with_scratch(|scratch| {
        let (wre, wim) = scratch.pair(w * v);
        // SAFETY: the DAG schedules this task strictly after every row
        // tile of its matrix, column tiles own pairwise-disjoint column
        // sets, and `execute_batch` holds the plane borrows until the
        // DAG run returns.
        unsafe { gather_col_tile(re, im, n, n, c0, c0 + w, v, wre, wim) };
        engine.fft_rows(wre, wim, w, v, Direction::Forward, 1)?;
        unsafe { scatter_col_tile(re, im, n, n, c0, c0 + w, v, wre, wim) };
        Ok(())
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::NativeEngine;
    use crate::dft::naive_dft2d;

    fn flat_fpm(name: &str, n: usize, speed: f64) -> SpeedFunction {
        SpeedFunction::from_fn(
            name,
            (1..=8).map(|k| k * n / 8).collect(),
            vec![n],
            move |_, _| Some(speed),
        )
    }

    #[test]
    fn plan_without_pads_is_unpadded() {
        let n = 16;
        let fpms = vec![flat_fpm("a", n, 100.0), flat_fpm("b", n, 100.0)];
        let plan = PlannedTransform::from_fpms(&fpms, n, 0.05, None).unwrap();
        assert_eq!(plan.d.iter().sum::<usize>(), n);
        assert!(!plan.is_padded());
        assert_eq!(plan.pad_lens(), vec![n; 2]);
    }

    #[test]
    fn execute_matches_oracle() {
        let n = 16;
        let fpms = vec![flat_fpm("a", n, 100.0), flat_fpm("b", n, 300.0)];
        let plan = PlannedTransform::from_fpms(&fpms, n, 0.05, Some(PadCost::PaperRatio)).unwrap();
        let orig = SignalMatrix::random(n, n, 7);
        let mut m = orig.clone();
        plan.execute(&NativeEngine, &mut m, 1, 64).unwrap();
        let want = naive_dft2d(&orig);
        let err = m.max_abs_diff(&want) / want.norm().max(1.0);
        assert!(err < 1e-10, "rel err {err}");
    }

    #[test]
    fn balanced_fallback_covers_all_rows() {
        let plan = PlannedTransform::balanced_fallback(3, 10);
        assert_eq!(plan.d, vec![4, 3, 3]);
        assert_eq!(plan.algorithm, Algorithm::Balanced);
        assert!(!plan.is_padded());
        assert!(plan.makespan.is_nan());
    }

    #[test]
    fn pipeline_tiles_cover_rows_and_cols() {
        let n = 200;
        let d = vec![70, 0, 130];
        let pads = vec![n, n, 240];
        let pipe = ExecPipeline::compile(n, &d, Some(pads.as_slice()));
        // row tiles cover [0, n) exactly once, in order, ≤ tile size
        let mut covered = 0usize;
        for t in &pipe.row_tiles {
            assert_eq!(t.start, covered);
            assert!(t.len >= 1 && t.len <= DEFAULT_ROW_TILE);
            covered += t.len;
        }
        assert_eq!(covered, n);
        let mut covered = 0usize;
        for t in &pipe.col_tiles {
            assert_eq!(t.start, covered);
            assert!(t.len >= 1 && t.len <= DEFAULT_COL_TILE);
            covered += t.len;
        }
        assert_eq!(covered, n);
        // tiles inside the padded group carry its pad as fft_len and
        // never straddle the group boundary
        for t in pipe.row_tiles.iter().chain(&pipe.col_tiles) {
            let expect = if t.start >= 70 { 240 } else { n };
            assert_eq!(t.fft_len, expect, "tile at {}", t.start);
            assert!(t.start + t.len <= if t.start >= 70 { n } else { 70 });
        }
    }

    #[test]
    fn fused_execute_matches_barrier_bitwise() {
        let n = 96;
        for padded in [false, true] {
            let fpms = vec![flat_fpm("a", n, 100.0), flat_fpm("b", n, 280.0)];
            let mut plan =
                PlannedTransform::from_fpms(&fpms, n, 0.05, None).unwrap();
            if padded {
                // force a pad on group 1 so the stride path runs
                plan.pads[1] = PadDecision { n_padded: 120, t_unpadded: 1.0, t_padded: 0.5 };
                assert!(plan.is_padded());
            }
            let orig = SignalMatrix::random(n, n, 21 + padded as u64);
            let mut fused = orig.clone();
            let mut barrier = orig.clone();
            plan.execute_with_mode(&NativeEngine, &mut fused, 2, 64, PipelineMode::Fused)
                .unwrap();
            plan.execute_with_mode(&NativeEngine, &mut barrier, 2, 64, PipelineMode::Barrier)
                .unwrap();
            assert_eq!(
                fused.max_abs_diff(&barrier),
                0.0,
                "padded={padded}: fused must be bit-exact vs barrier"
            );
            // and both are actually correct
            let want = naive_dft2d(&orig);
            let err = fused.max_abs_diff(&want) / want.norm().max(1.0);
            assert!(err < 1e-9, "padded={padded}: rel err {err}");
        }
    }

    #[test]
    fn pipeline_batch_matches_singles_bitwise() {
        let n = 64;
        let fpms = vec![flat_fpm("a", n, 100.0), flat_fpm("b", n, 100.0)];
        let plan = PlannedTransform::from_fpms(&fpms, n, 0.05, None).unwrap();
        let pipe = plan.pipeline();
        let origs: Vec<SignalMatrix> = (0..3).map(|s| SignalMatrix::random(n, n, 40 + s)).collect();
        let mut singles = origs.clone();
        for m in singles.iter_mut() {
            plan.execute_with_mode(&NativeEngine, m, 1, 64, PipelineMode::Barrier).unwrap();
        }
        let mut batched = origs.clone();
        {
            let mut refs: Vec<&mut SignalMatrix> = batched.iter_mut().collect();
            let timings = pipe.execute_batch(&NativeEngine, &mut refs, 4).unwrap();
            assert!(timings.row_s >= 0.0 && timings.col_s >= 0.0);
        }
        for (b, s) in batched.iter().zip(&singles) {
            assert_eq!(b.max_abs_diff(s), 0.0);
        }
    }

    #[test]
    fn pipeline_worker_count_invariant_bitwise() {
        // tile-scheduler determinism: any worker count, same bits
        let n = 80;
        let pipe = ExecPipeline::compile(n, &[50, 30], Some(&[96, 80][..]));
        let orig = SignalMatrix::random(n, n, 77);
        let mut reference: Option<SignalMatrix> = None;
        for workers in [1usize, 2, 8] {
            let mut m = orig.clone();
            pipe.execute_batch(&NativeEngine, &mut [&mut m], workers).unwrap();
            match &reference {
                None => reference = Some(m),
                Some(want) => assert_eq!(
                    m.max_abs_diff(want),
                    0.0,
                    "workers={workers} changed the bits"
                ),
            }
        }
    }

    #[test]
    fn predicted_seconds_positive() {
        let n = 1024;
        let fpms = vec![flat_fpm("a", n, 100.0), flat_fpm("b", n, 100.0)];
        let plan = PlannedTransform::from_fpms(&fpms, n, 0.05, None).unwrap();
        let t = plan.predicted_seconds(500.0);
        assert!(t > 0.0 && t.is_finite());
        // fallback path too
        let fb = PlannedTransform::balanced_fallback(2, n).predicted_seconds(500.0);
        assert!(fb > 0.0 && fb.is_finite());
    }
}
