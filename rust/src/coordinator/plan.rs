//! `PlannedTransform` — the reusable outcome of PFFT planning.
//!
//! Steps 1 (POPTA/HPOPTA partitioning) and 2 (`Determine_Pad_Length`)
//! are the expensive, input-independent part of every PFFT-FPM /
//! PFFT-FPM-PAD run: they depend only on (engine, N, p, ε), never on the
//! signal itself. This module bundles their result into one value that
//!
//! * the drivers execute directly ([`PlannedTransform::execute`]),
//! * the serving layer memoizes in its wisdom store
//!   ([`crate::service::wisdom`]) and persists as JSON, and
//! * `main.rs` / the benches build once and reuse across repetitions —
//!   the shared seam that used to be duplicated between
//!   `coordinator/pfft.rs` and `coordinator/pad.rs` call sites.

use crate::coordinator::engine::{EngineError, RowFftEngine};
use crate::coordinator::pad::{pads_for_distribution, PadCost, PadDecision};
use crate::coordinator::partition::{balanced, Algorithm, PartitionError};
use crate::coordinator::pfft::{pfft_fpm, pfft_fpm_pad, plan_partition, PfftReport};
use crate::dft::SignalMatrix;
use crate::model::{PerfModel, SpeedFunction, StaticModel};

/// A fully planned N×N 2D-DFT: row distribution + per-group pad lengths.
#[derive(Clone, Debug, PartialEq)]
pub struct PlannedTransform {
    /// problem size (rows == cols == n)
    pub n: usize,
    /// rows per abstract processor, Σ = n
    pub d: Vec<usize>,
    /// per-processor pad decisions (n_padded == n when unpadded)
    pub pads: Vec<PadDecision>,
    /// which partitioning algorithm produced `d`
    pub algorithm: Algorithm,
    /// predicted makespan in relative `x / s(x)` units (NaN when
    /// unavailable, e.g. the balanced fallback)
    pub makespan: f64,
}

impl PlannedTransform {
    /// Plan from any performance model: ε-identity test + POPTA/HPOPTA
    /// over the model's plane sections, then the pad search over its
    /// column sections (windowed to `pad_window` above N) when
    /// `pad_cost` is given, or trivial pads (exact row length) when
    /// `None`. This is the single planning entry point — static
    /// surfaces, the virtual testbed and the online model all plan
    /// through it.
    pub fn from_model(
        model: &dyn PerfModel,
        n: usize,
        eps: f64,
        pad_cost: Option<PadCost>,
        pad_window: usize,
    ) -> Result<PlannedTransform, PartitionError> {
        let part = plan_partition(model, n, eps)?;
        let pads = match pad_cost {
            Some(cost) => pads_for_distribution(model, &part.d, n, pad_window, cost),
            None => trivial_pads(part.d.len(), n),
        };
        Ok(PlannedTransform {
            n,
            d: part.d,
            pads,
            algorithm: part.algorithm,
            makespan: part.makespan,
        })
    }

    /// [`PlannedTransform::from_model`] over raw measured surfaces
    /// (wraps them in a [`StaticModel`]; unbounded pad window — the
    /// measured grid already bounds the candidates).
    pub fn from_fpms(
        fpms: &[SpeedFunction],
        n: usize,
        eps: f64,
        pad_cost: Option<PadCost>,
    ) -> Result<PlannedTransform, PartitionError> {
        Self::from_model(&StaticModel::from_slice(fpms), n, eps, pad_cost, usize::MAX)
    }

    /// The model-free fallback: balanced rows, no padding. Used when
    /// planning inputs are degenerate (empty curves, unreachable N).
    pub fn balanced_fallback(p: usize, n: usize) -> PlannedTransform {
        let part = balanced(p, n);
        PlannedTransform {
            n,
            d: part.d.clone(),
            pads: trivial_pads(part.d.len(), n),
            algorithm: Algorithm::Balanced,
            makespan: f64::NAN,
        }
    }

    /// Number of abstract processors.
    pub fn groups(&self) -> usize {
        self.d.len()
    }

    /// Padded row length per processor (== n when unpadded).
    pub fn pad_lens(&self) -> Vec<usize> {
        self.pads.iter().map(|p| p.n_padded).collect()
    }

    /// Does any processor actually pad?
    pub fn is_padded(&self) -> bool {
        self.pads.iter().any(|p| p.n_padded > self.n)
    }

    /// Execute the planned transform on one signal matrix — dispatches to
    /// PFFT-FPM or PFFT-FPM-PAD depending on whether padding is active.
    pub fn execute(
        &self,
        engine: &dyn RowFftEngine,
        m: &mut SignalMatrix,
        threads_per_group: usize,
        transpose_block: usize,
    ) -> Result<PfftReport, EngineError> {
        if self.is_padded() {
            pfft_fpm_pad(engine, m, &self.d, &self.pads, threads_per_group, transpose_block)
        } else {
            pfft_fpm(engine, m, &self.d, threads_per_group, transpose_block)
        }
    }

    /// Predicted execution seconds of the two row phases from the stored
    /// relative makespan: `x/s` units × `2.5·n·log2(n) / 1e6` converts to
    /// seconds (the constant the minimax cancelled out). Falls back to a
    /// flat speed assumption when the makespan is unavailable.
    pub fn predicted_seconds(&self, fallback_mflops: f64) -> f64 {
        let n = self.n as f64;
        if self.makespan.is_finite() && self.makespan > 0.0 {
            2.0 * self.makespan * 2.5 * n * n.log2() / 1e6
        } else {
            crate::stats::harness::fft2d_flops(self.n) / (fallback_mflops.max(1.0) * 1e6)
        }
    }
}

fn trivial_pads(p: usize, n: usize) -> Vec<PadDecision> {
    vec![PadDecision { n_padded: n, t_unpadded: 0.0, t_padded: 0.0 }; p]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::NativeEngine;
    use crate::dft::naive_dft2d;

    fn flat_fpm(name: &str, n: usize, speed: f64) -> SpeedFunction {
        SpeedFunction::from_fn(
            name,
            (1..=8).map(|k| k * n / 8).collect(),
            vec![n],
            move |_, _| Some(speed),
        )
    }

    #[test]
    fn plan_without_pads_is_unpadded() {
        let n = 16;
        let fpms = vec![flat_fpm("a", n, 100.0), flat_fpm("b", n, 100.0)];
        let plan = PlannedTransform::from_fpms(&fpms, n, 0.05, None).unwrap();
        assert_eq!(plan.d.iter().sum::<usize>(), n);
        assert!(!plan.is_padded());
        assert_eq!(plan.pad_lens(), vec![n; 2]);
    }

    #[test]
    fn execute_matches_oracle() {
        let n = 16;
        let fpms = vec![flat_fpm("a", n, 100.0), flat_fpm("b", n, 300.0)];
        let plan = PlannedTransform::from_fpms(&fpms, n, 0.05, Some(PadCost::PaperRatio)).unwrap();
        let orig = SignalMatrix::random(n, n, 7);
        let mut m = orig.clone();
        plan.execute(&NativeEngine, &mut m, 1, 64).unwrap();
        let want = naive_dft2d(&orig);
        let err = m.max_abs_diff(&want) / want.norm().max(1.0);
        assert!(err < 1e-10, "rel err {err}");
    }

    #[test]
    fn balanced_fallback_covers_all_rows() {
        let plan = PlannedTransform::balanced_fallback(3, 10);
        assert_eq!(plan.d, vec![4, 3, 3]);
        assert_eq!(plan.algorithm, Algorithm::Balanced);
        assert!(!plan.is_padded());
        assert!(plan.makespan.is_nan());
    }

    #[test]
    fn predicted_seconds_positive() {
        let n = 1024;
        let fpms = vec![flat_fpm("a", n, 100.0), flat_fpm("b", n, 100.0)];
        let plan = PlannedTransform::from_fpms(&fpms, n, 0.05, None).unwrap();
        let t = plan.predicted_seconds(500.0);
        assert!(t > 0.0 && t.is_finite());
        // fallback path too
        let fb = PlannedTransform::balanced_fallback(2, n).predicted_seconds(500.0);
        assert!(fb > 0.0 && fb.is_finite());
    }
}
