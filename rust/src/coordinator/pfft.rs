//! The parallel 2D-DFT drivers: PFFT-LB, PFFT-FPM, PFFT-FPM-PAD
//! (paper Algorithms 1-5).
//!
//! All three share the same four-step skeleton over p abstract
//! processors (row FFTs → transpose → row FFTs → transpose); they differ
//! only in *how rows are distributed* (balanced vs FPM-optimal) and in
//! *row length* (exact N vs per-processor padded N_i):
//!
//! * `PFFT-LB`   — Section III-B: equal rows per processor.
//! * `PFFT-FPM`  — Section III-C: rows from POPTA/HPOPTA; possibly
//!   deliberately imbalanced.
//! * `PFFT-FPM-PAD` — Section III-D: FPM rows + per-processor padded row
//!   lengths from `Determine_Pad_Length`.
//!
//! Groups run as jobs on the shared [`crate::dft::exec::ExecCtx`] pool
//! over disjoint row ranges obtained with `split_at_mut` — no per-call
//! thread spawns. Under [`PipelineMode::Fused`] (the default) the
//! four-step skeleton compiles to a tile-granular
//! [`crate::coordinator::plan::ExecPipeline`]: strided column FFTs
//! replace both transpose barriers and each group's pad length becomes
//! a tile stride. [`PipelineMode::Barrier`] keeps the original
//! phase-barrier execution (the paper's Appendix A blocked transpose
//! with the full p·t thread budget) as the fallback and bit-exactness
//! oracle — the two modes produce identical bits.

use crate::coordinator::engine::{EngineError, RowFftEngine};
use crate::coordinator::group::{row_offsets, GroupConfig};
use crate::coordinator::pad::{PadCost, PadDecision};
use crate::coordinator::partition::{
    average_curve, balanced, curves_identical, hpopta, popta, Partition, PartitionError,
};
use crate::dft::fft::Direction;
use crate::dft::pipeline::{default_mode, PipelineMode};
use crate::dft::transpose::transpose_in_place_parallel;
use crate::dft::SignalMatrix;
use crate::model::{PerfModel, SpeedFunction};

// The real-input (r2c) variants of the drivers live in
// [`crate::coordinator::real`]; re-exported here so the driver family
// is importable from one place.
pub use crate::coordinator::real::{
    pfft_fpm_pad_real, pfft_fpm_pad_real_with_mode, pfft_fpm_real, pfft_fpm_real_with_mode,
};

/// What a driver run did (for reports and EXPERIMENTS.md records).
#[derive(Clone, Debug)]
pub struct PfftReport {
    pub algorithm: String,
    pub d: Vec<usize>,
    /// padded row length per processor (== N when unpadded)
    pub pads: Vec<usize>,
    pub elapsed_s: f64,
    pub threads_per_group: usize,
}

/// Step-1 planning (Algorithm 2 `PARTITION`): ε-identity test over the
/// model's plane sections, then POPTA on the harmonic average or HPOPTA
/// on the per-processor curves. Consumes any [`PerfModel`] — measured
/// surfaces, the virtual testbed, or the online model's live sections.
pub fn plan_partition(
    model: &dyn PerfModel,
    n: usize,
    eps: f64,
) -> Result<Partition, PartitionError> {
    let p = model.groups();
    let curves: Vec<_> = (0..p).map(|g| model.plane_section(g, n)).collect();
    if curves_identical(&curves, eps) {
        let avg = average_curve(&curves);
        popta(&avg, p, n)
    } else {
        hpopta(&curves, n)
    }
}

/// [`plan_partition`] over raw measured surfaces (wraps them in a
/// [`crate::model::StaticModel`]).
pub fn plan_partition_fpms(
    fpms: &[SpeedFunction],
    n: usize,
    eps: f64,
) -> Result<Partition, PartitionError> {
    plan_partition(&crate::model::StaticModel::from_slice(fpms), n, eps)
}

/// PFFT-LB (Section III-B): balanced distribution, exact row length.
pub fn pfft_lb(
    engine: &dyn RowFftEngine,
    m: &mut SignalMatrix,
    cfg: GroupConfig,
    transpose_block: usize,
) -> Result<PfftReport, EngineError> {
    let d = balanced(cfg.p, m.rows).d;
    run_four_steps(engine, m, &d, None, cfg.t, transpose_block, "PFFT-LB", default_mode())
}

/// PFFT-FPM (Section III-C / Algorithm 1): FPM-optimal distribution,
/// exact row length. Uses the process-wide [`PipelineMode`].
pub fn pfft_fpm(
    engine: &dyn RowFftEngine,
    m: &mut SignalMatrix,
    d: &[usize],
    threads_per_group: usize,
    transpose_block: usize,
) -> Result<PfftReport, EngineError> {
    pfft_fpm_with_mode(engine, m, d, threads_per_group, transpose_block, default_mode())
}

/// [`pfft_fpm`] with an explicit pipeline mode (A/B benches and the
/// bit-exactness tests, which must not race on the process default).
pub fn pfft_fpm_with_mode(
    engine: &dyn RowFftEngine,
    m: &mut SignalMatrix,
    d: &[usize],
    threads_per_group: usize,
    transpose_block: usize,
    mode: PipelineMode,
) -> Result<PfftReport, EngineError> {
    run_four_steps(engine, m, d, None, threads_per_group, transpose_block, "PFFT-FPM", mode)
}

/// PFFT-FPM-PAD (Section III-D): FPM-optimal distribution with
/// per-processor padded row lengths. Uses the process-wide
/// [`PipelineMode`].
pub fn pfft_fpm_pad(
    engine: &dyn RowFftEngine,
    m: &mut SignalMatrix,
    d: &[usize],
    pads: &[PadDecision],
    threads_per_group: usize,
    transpose_block: usize,
) -> Result<PfftReport, EngineError> {
    pfft_fpm_pad_with_mode(engine, m, d, pads, threads_per_group, transpose_block, default_mode())
}

/// [`pfft_fpm_pad`] with an explicit pipeline mode.
pub fn pfft_fpm_pad_with_mode(
    engine: &dyn RowFftEngine,
    m: &mut SignalMatrix,
    d: &[usize],
    pads: &[PadDecision],
    threads_per_group: usize,
    transpose_block: usize,
    mode: PipelineMode,
) -> Result<PfftReport, EngineError> {
    let pad_lens: Vec<usize> = pads.iter().map(|p| p.n_padded).collect();
    run_four_steps(
        engine,
        m,
        d,
        Some(&pad_lens),
        threads_per_group,
        transpose_block,
        "PFFT-FPM-PAD",
        mode,
    )
}

/// Plan + execute PFFT-FPM-PAD end to end from FPM surfaces.
///
/// Thin wrapper over [`crate::coordinator::plan::PlannedTransform`] —
/// callers that run the same size repeatedly (benches, the `service`
/// layer) should build the `PlannedTransform` once and call
/// `execute` per matrix instead.
pub fn pfft_fpm_pad_planned(
    engine: &dyn RowFftEngine,
    m: &mut SignalMatrix,
    fpms: &[SpeedFunction],
    eps: f64,
    threads_per_group: usize,
    transpose_block: usize,
) -> Result<PfftReport, EngineError> {
    let plan = crate::coordinator::plan::PlannedTransform::from_fpms(
        fpms,
        m.rows,
        eps,
        Some(PadCost::PaperRatio),
    )
    .map_err(|e| EngineError::Runtime(format!("partition failed: {e}")))?;
    plan.execute(engine, m, threads_per_group, transpose_block)
}

/// The shared four-step skeleton (Algorithm 3 `PFFT_LIMB`). Fused mode
/// compiles (d, pads) into the tile pipeline; barrier mode runs the
/// literal four steps with full-matrix transposes between phases.
#[allow(clippy::too_many_arguments)]
fn run_four_steps(
    engine: &dyn RowFftEngine,
    m: &mut SignalMatrix,
    d: &[usize],
    pad_lens: Option<&[usize]>,
    threads_per_group: usize,
    transpose_block: usize,
    label: &str,
    mode: PipelineMode,
) -> Result<PfftReport, EngineError> {
    assert_eq!(m.rows, m.cols, "square signal matrix required");
    let n = m.rows;
    assert_eq!(d.iter().sum::<usize>(), n, "distribution must cover all rows");
    if let Some(p) = pad_lens {
        assert_eq!(p.len(), d.len());
        assert!(p.iter().all(|&v| v >= n), "pad length below N");
    }
    let total_threads = d.len() * threads_per_group;
    let started = std::time::Instant::now();

    match mode {
        PipelineMode::Fused => {
            let pipe = crate::coordinator::plan::ExecPipeline::compile(n, d, pad_lens);
            pipe.execute_batch(engine, &mut [&mut *m], total_threads)?;
        }
        PipelineMode::Barrier => {
            // Step 1/2: row FFTs on d-partitioned rows, then transpose.
            row_phase(engine, m, d, pad_lens, threads_per_group)?;
            transpose_in_place_parallel(m, transpose_block, total_threads);
            // Step 3/4: same again (the transposed matrix's rows are
            // the original columns).
            row_phase(engine, m, d, pad_lens, threads_per_group)?;
            transpose_in_place_parallel(m, transpose_block, total_threads);
        }
    }

    Ok(PfftReport {
        algorithm: label.to_string(),
        d: d.to_vec(),
        pads: pad_lens.map(|p| p.to_vec()).unwrap_or_else(|| vec![n; d.len()]),
        elapsed_s: started.elapsed().as_secs_f64(),
        threads_per_group,
    })
}

/// One row phase: each abstract processor transforms its row range
/// concurrently. With padding, a processor works on a local padded copy
/// (the paper's work-matrix technique) and writes back the first N
/// columns.
fn row_phase(
    engine: &dyn RowFftEngine,
    m: &mut SignalMatrix,
    d: &[usize],
    pad_lens: Option<&[usize]>,
    threads_per_group: usize,
) -> Result<(), EngineError> {
    let n = m.cols;
    let offsets = row_offsets(d);

    // carve disjoint per-group row slices
    let mut re_rest: &mut [f64] = &mut m.re;
    let mut im_rest: &mut [f64] = &mut m.im;
    let mut slices: Vec<(&mut [f64], &mut [f64])> = Vec::with_capacity(d.len());
    for i in 0..d.len() {
        let len = (offsets[i + 1] - offsets[i]) * n;
        let (re_here, re_next) = re_rest.split_at_mut(len);
        let (im_here, im_next) = im_rest.split_at_mut(len);
        re_rest = re_next;
        im_rest = im_next;
        slices.push((re_here, im_here));
    }

    let errors: std::sync::Mutex<Vec<EngineError>> = std::sync::Mutex::new(Vec::new());
    let mut jobs: Vec<crate::dft::exec::Job> = Vec::with_capacity(d.len());
    for (i, (re, im)) in slices.into_iter().enumerate() {
        let rows = d[i];
        if rows == 0 {
            continue;
        }
        let pad = pad_lens.map(|p| p[i]).unwrap_or(n);
        let errors = &errors;
        jobs.push(Box::new(move || {
            let r = if pad == n {
                engine.fft_rows(re, im, rows, n, Direction::Forward, threads_per_group)
            } else {
                fft_rows_padded(engine, re, im, rows, n, pad, threads_per_group)
            };
            if let Err(e) = r {
                errors.lock().unwrap().push(e);
            }
        }));
    }
    crate::dft::exec::ExecCtx::global().run_jobs(jobs);

    match errors.into_inner().unwrap().into_iter().next() {
        Some(e) => Err(e),
        None => Ok(()),
    }
}

/// Padded row FFTs (Algorithm 7 `1D_ROW_FFTS_LOCAL_PADDED`): copy the
/// rows into a (rows × pad) zeroed work buffer leased from the calling
/// thread's scratch arena, transform at length `pad`, copy the first
/// `n` columns back. Shared with the real path's barrier column phase
/// ([`crate::coordinator::real`]).
pub(crate) fn fft_rows_padded(
    engine: &dyn RowFftEngine,
    re: &mut [f64],
    im: &mut [f64],
    rows: usize,
    n: usize,
    pad: usize,
    threads: usize,
) -> Result<(), EngineError> {
    debug_assert!(pad > n);
    crate::dft::exec::with_scratch(|scratch| {
        let (wre, wim) = scratch.pair(rows * pad);
        for r in 0..rows {
            wre[r * pad..r * pad + n].copy_from_slice(&re[r * n..(r + 1) * n]);
            wim[r * pad..r * pad + n].copy_from_slice(&im[r * n..(r + 1) * n]);
        }
        engine.fft_rows(wre, wim, rows, pad, Direction::Forward, threads)?;
        for r in 0..rows {
            re[r * n..(r + 1) * n].copy_from_slice(&wre[r * pad..r * pad + n]);
            im[r * n..(r + 1) * n].copy_from_slice(&wim[r * pad..r * pad + n]);
        }
        Ok(())
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::NativeEngine;
    use crate::dft::naive_dft2d;

    fn rel_err(a: &SignalMatrix, b: &SignalMatrix) -> f64 {
        a.max_abs_diff(b) / b.norm().max(1.0)
    }

    #[test]
    fn pfft_lb_matches_naive_2d() {
        for &n in &[8usize, 16, 24] {
            let orig = SignalMatrix::random(n, n, n as u64);
            let mut m = orig.clone();
            let rep = pfft_lb(&NativeEngine, &mut m, GroupConfig::new(2, 2), 64).unwrap();
            assert_eq!(rep.d.iter().sum::<usize>(), n);
            let want = naive_dft2d(&orig);
            assert!(rel_err(&m, &want) < 1e-10, "n={n}: {}", rel_err(&m, &want));
        }
    }

    #[test]
    fn pfft_fpm_imbalanced_matches_naive_2d() {
        let n = 16;
        let orig = SignalMatrix::random(n, n, 5);
        let mut m = orig.clone();
        // the paper's Figure 8 distribution d = {5, 3, 2, 6}
        let rep = pfft_fpm(&NativeEngine, &mut m, &[5, 3, 2, 6], 1, 64).unwrap();
        assert_eq!(rep.algorithm, "PFFT-FPM");
        let want = naive_dft2d(&orig);
        assert!(rel_err(&m, &want) < 1e-10);
    }

    #[test]
    fn zero_row_groups_allowed() {
        let n = 8;
        let orig = SignalMatrix::random(n, n, 2);
        let mut m = orig.clone();
        pfft_fpm(&NativeEngine, &mut m, &[0, 8, 0], 1, 64).unwrap();
        let want = naive_dft2d(&orig);
        assert!(rel_err(&m, &want) < 1e-10);
    }

    #[test]
    fn pad_zero_length_equals_fpm() {
        let n = 16;
        let orig = SignalMatrix::random(n, n, 7);
        let mut a = orig.clone();
        let mut b = orig.clone();
        pfft_fpm(&NativeEngine, &mut a, &[8, 8], 1, 64).unwrap();
        let pads = vec![
            PadDecision { n_padded: n, t_unpadded: 1.0, t_padded: 1.0 },
            PadDecision { n_padded: n, t_unpadded: 1.0, t_padded: 1.0 },
        ];
        pfft_fpm_pad(&NativeEngine, &mut b, &[8, 8], &pads, 1, 64).unwrap();
        assert!(a.max_abs_diff(&b) < 1e-14);
    }

    #[test]
    fn pad_is_spectral_interpolation_per_row_phase() {
        // One row-phase with padding must equal: zero-pad rows to V,
        // V-point FFT, take first n columns (the paper's semantics).
        let (rows, n, v) = (4usize, 16usize, 24usize);
        let orig = SignalMatrix::random(rows, n, 11);
        let mut got = orig.clone();
        fft_rows_padded(
            &NativeEngine,
            &mut got.re,
            &mut got.im,
            rows,
            n,
            v,
            1,
        )
        .unwrap();
        let padded = orig.pad_cols(v);
        let mut want = padded.clone();
        NativeEngine
            .fft_rows(&mut want.re, &mut want.im, rows, v, Direction::Forward, 1)
            .unwrap();
        let want = want.crop_cols(n);
        assert!(got.max_abs_diff(&want) < 1e-12);
    }

    #[test]
    fn fused_drivers_match_barrier_bitwise() {
        let n = 48;
        let orig = SignalMatrix::random(n, n, 31);
        // imbalanced FPM distribution, mixed pad lengths (group 1 pads)
        let d = vec![20usize, 17, 11];
        let pads = vec![
            PadDecision { n_padded: n, t_unpadded: 1.0, t_padded: 1.0 },
            PadDecision { n_padded: 60, t_unpadded: 1.0, t_padded: 0.5 },
            PadDecision { n_padded: n, t_unpadded: 1.0, t_padded: 1.0 },
        ];
        let mut fused = orig.clone();
        let mut barrier = orig.clone();
        pfft_fpm_pad_with_mode(
            &NativeEngine, &mut fused, &d, &pads, 2, 64, crate::dft::pipeline::PipelineMode::Fused,
        )
        .unwrap();
        pfft_fpm_pad_with_mode(
            &NativeEngine,
            &mut barrier,
            &d,
            &pads,
            2,
            64,
            crate::dft::pipeline::PipelineMode::Barrier,
        )
        .unwrap();
        assert_eq!(fused.max_abs_diff(&barrier), 0.0, "fused PFFT-FPM-PAD must be bit-exact");
        // and correct against the oracle
        let want = naive_dft2d(&orig);
        assert!(rel_err(&fused, &want) < 1e-9, "{}", rel_err(&fused, &want));

        // unpadded driver too
        let mut fused = orig.clone();
        let mut barrier = orig.clone();
        pfft_fpm_with_mode(
            &NativeEngine, &mut fused, &d, 1, 64, crate::dft::pipeline::PipelineMode::Fused,
        )
        .unwrap();
        pfft_fpm_with_mode(
            &NativeEngine, &mut barrier, &d, 1, 64, crate::dft::pipeline::PipelineMode::Barrier,
        )
        .unwrap();
        assert_eq!(fused.max_abs_diff(&barrier), 0.0, "fused PFFT-FPM must be bit-exact");
    }

    #[test]
    fn plan_partition_homogeneous_uses_popta() {
        use crate::coordinator::partition::Algorithm;
        let fpm = SpeedFunction::from_fn(
            "g",
            (1..=8).map(|k| k * 2).collect(),
            vec![16],
            |x, _| Some(100.0 + x as f64 * 0.01),
        );
        let part = plan_partition_fpms(&[fpm.clone(), fpm], 16, 0.05).unwrap();
        assert_eq!(part.algorithm, Algorithm::Popta);
        assert_eq!(part.d.iter().sum::<usize>(), 16);
    }

    #[test]
    fn plan_partition_heterogeneous_uses_hpopta() {
        use crate::coordinator::partition::Algorithm;
        let f1 = SpeedFunction::from_fn(
            "g1",
            (1..=8).map(|k| k * 2).collect(),
            vec![16],
            |_, _| Some(100.0),
        );
        let f2 = SpeedFunction::from_fn(
            "g2",
            (1..=8).map(|k| k * 2).collect(),
            vec![16],
            |_, _| Some(300.0),
        );
        let part = plan_partition_fpms(&[f1, f2], 16, 0.05).unwrap();
        assert_eq!(part.algorithm, Algorithm::Hpopta);
        // faster processor gets more rows
        assert!(part.d[1] > part.d[0], "{:?}", part.d);
    }

    #[test]
    #[should_panic(expected = "distribution must cover")]
    fn wrong_distribution_sum_panics() {
        let mut m = SignalMatrix::random(8, 8, 1);
        let _ = pfft_fpm(&NativeEngine, &mut m, &[3, 3], 1, 64);
    }

    #[test]
    fn report_contents() {
        let n = 8;
        let mut m = SignalMatrix::random(n, n, 3);
        let rep = pfft_lb(&NativeEngine, &mut m, GroupConfig::new(2, 1), 64).unwrap();
        assert_eq!(rep.algorithm, "PFFT-LB");
        assert_eq!(rep.d, vec![4, 4]);
        assert_eq!(rep.pads, vec![8, 8]);
        assert!(rep.elapsed_s >= 0.0);
    }
}
