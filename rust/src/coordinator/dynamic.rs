//! Dynamic load-balancing baseline — the related-work comparator
//! (paper §VI-C): a centralized work-stealing row scheduler, the
//! classical alternative to model-based *static* partitioning.
//!
//! Groups pull fixed-size row chunks from a shared atomic counter until
//! the matrix is exhausted. No model is consulted; balance emerges at
//! run time at the cost of (a) chunk-granularity idle tails and (b) no
//! ability to exploit the speed function's shape (a group never *skips*
//! a row count its speed function is bad at — the paper's core
//! advantage for PFFT-FPM). The ablation bench and the virtual-campaign
//! comparison quantify exactly that gap.

use std::sync::atomic::{AtomicUsize, Ordering};

use crate::coordinator::engine::{EngineError, RowFftEngine};
use crate::dft::fft::Direction;
use crate::dft::transpose::transpose_in_place_parallel;
use crate::dft::SignalMatrix;

/// Default rows pulled per steal.
pub const DEFAULT_CHUNK: usize = 16;

/// Report of a dynamic run.
#[derive(Clone, Debug)]
pub struct DynamicReport {
    pub elapsed_s: f64,
    /// chunks executed per group (work actually stolen)
    pub chunks_per_group: Vec<usize>,
}

/// 2D-DFT with dynamic (work-stealing) row scheduling: the same
/// four-step skeleton as PFFT-LB, but each row phase distributes rows at
/// run time.
pub fn pfft_dynamic(
    engine: &dyn RowFftEngine,
    m: &mut SignalMatrix,
    p: usize,
    threads_per_group: usize,
    chunk: usize,
    transpose_block: usize,
) -> Result<DynamicReport, EngineError> {
    assert_eq!(m.rows, m.cols, "square signal matrix required");
    assert!(p >= 1 && chunk >= 1);
    let started = std::time::Instant::now();
    let mut chunks_per_group = vec![0usize; p];

    for _phase in 0..2 {
        let counts = dynamic_row_phase(engine, m, p, threads_per_group, chunk)?;
        for (acc, c) in chunks_per_group.iter_mut().zip(counts) {
            *acc += c;
        }
        transpose_in_place_parallel(m, transpose_block, p * threads_per_group);
    }

    Ok(DynamicReport { elapsed_s: started.elapsed().as_secs_f64(), chunks_per_group })
}

/// One dynamically-scheduled row phase. Rows are handed out in
/// `chunk`-sized slices via an atomic cursor; each slice is transformed
/// in place through a raw-parts window (disjoint by construction).
fn dynamic_row_phase(
    engine: &dyn RowFftEngine,
    m: &mut SignalMatrix,
    p: usize,
    threads_per_group: usize,
    chunk: usize,
) -> Result<Vec<usize>, EngineError> {
    let n = m.cols;
    let rows = m.rows;
    let cursor = AtomicUsize::new(0);
    let errors: std::sync::Mutex<Vec<EngineError>> = std::sync::Mutex::new(Vec::new());
    let counts: Vec<AtomicUsize> = (0..p).map(|_| AtomicUsize::new(0)).collect();

    let re_ptr = SendPtr(m.re.as_mut_ptr());
    let im_ptr = SendPtr(m.im.as_mut_ptr());

    std::thread::scope(|scope| {
        for g in 0..p {
            let cursor = &cursor;
            let errors = &errors;
            let counts = &counts;
            let re_ptr = re_ptr;
            let im_ptr = im_ptr;
            scope.spawn(move || {
                let (re_ptr, im_ptr) = (re_ptr, im_ptr); // whole-struct capture
                loop {
                    let start = cursor.fetch_add(chunk, Ordering::Relaxed);
                    if start >= rows {
                        break;
                    }
                    let take = chunk.min(rows - start);
                    // SAFETY: [start, start+take) row windows are disjoint
                    // across steals (the atomic cursor hands each range to
                    // exactly one group).
                    let re = unsafe {
                        std::slice::from_raw_parts_mut(re_ptr.0.add(start * n), take * n)
                    };
                    let im = unsafe {
                        std::slice::from_raw_parts_mut(im_ptr.0.add(start * n), take * n)
                    };
                    if let Err(e) =
                        engine.fft_rows(re, im, take, n, Direction::Forward, threads_per_group)
                    {
                        errors.lock().unwrap().push(e);
                        break;
                    }
                    counts[g].fetch_add(1, Ordering::Relaxed);
                }
            });
        }
    });

    match errors.into_inner().unwrap().into_iter().next() {
        Some(e) => Err(e),
        None => Ok(counts.into_iter().map(|c| c.into_inner()).collect()),
    }
}

#[derive(Clone, Copy)]
struct SendPtr(*mut f64);
// SAFETY: disjoint row windows, see dynamic_row_phase.
unsafe impl Send for SendPtr {}

/// Virtual-time model of the dynamic scheduler for the simulator
/// campaign: greedy list scheduling of `ceil(n/chunk)` chunks onto p
/// groups with per-group speeds from the FPM plane section — the
/// standard earliest-finish heuristic a dynamic balancer converges to.
pub fn dynamic_virtual_time(
    curves: &[crate::coordinator::fpm::Curve],
    n: usize,
    chunk: usize,
    flops_per_row: f64,
) -> f64 {
    let p = curves.len();
    let mut finish = vec![0.0f64; p];
    let mut left = n;
    while left > 0 {
        let take = chunk.min(left);
        // the idle-first group takes the next chunk
        let g = (0..p)
            .min_by(|&a, &b| finish[a].partial_cmp(&finish[b]).unwrap())
            .unwrap();
        // dynamic schedulers execute *chunk-sized* batches: the group's
        // speed is its FPM value at the chunk size, not at its total —
        // this is precisely the information loss vs model-based planning
        let speed = curves[g].speed_nearest(take);
        // same relative-cost unit as partition::point_cost (rows/speed,
        // scaled by flops_per_row) so the makespans are comparable
        finish[g] += take as f64 * flops_per_row / speed;
        left -= take;
    }
    finish.into_iter().fold(0.0, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::NativeEngine;
    use crate::coordinator::fpm::Curve;
    use crate::dft::naive_dft2d;

    #[test]
    fn dynamic_matches_oracle() {
        let n = 32;
        let orig = SignalMatrix::random(n, n, 5);
        let mut m = orig.clone();
        let rep = pfft_dynamic(&NativeEngine, &mut m, 3, 1, 4, 16).unwrap();
        let want = naive_dft2d(&orig);
        let err = m.max_abs_diff(&want) / want.norm().max(1.0);
        assert!(err < 1e-10, "rel err {err}");
        // all chunks accounted for: 2 phases x ceil(32/4) = 16 chunks
        assert_eq!(rep.chunks_per_group.iter().sum::<usize>(), 16);
    }

    #[test]
    fn dynamic_single_group_equals_serial() {
        let n = 16;
        let orig = SignalMatrix::random(n, n, 6);
        let mut a = orig.clone();
        pfft_dynamic(&NativeEngine, &mut a, 1, 1, 8, 16).unwrap();
        let mut b = orig.clone();
        crate::dft::dft2d::dft2d(&mut b, Direction::Forward, 1);
        assert!(a.max_abs_diff(&b) < 1e-12);
    }

    #[test]
    fn dynamic_chunk_size_invariant_result() {
        let n = 24;
        let orig = SignalMatrix::random(n, n, 7);
        let mut a = orig.clone();
        let mut b = orig.clone();
        pfft_dynamic(&NativeEngine, &mut a, 2, 1, 1, 8).unwrap();
        pfft_dynamic(&NativeEngine, &mut b, 2, 1, 16, 8).unwrap();
        assert!(a.max_abs_diff(&b) < 1e-12);
    }

    #[test]
    fn virtual_dynamic_cannot_exploit_speed_spikes() {
        // a spike at x=12 that HPOPTA exploits is invisible to a chunked
        // dynamic scheduler working at chunk=4 granularity
        let fast = Curve::new(vec![4, 8, 12, 16], vec![100.0, 100.0, 600.0, 100.0]);
        let slow = Curve::new(vec![4, 8, 12, 16], vec![100.0, 100.0, 100.0, 100.0]);
        let t_dyn = dynamic_virtual_time(&[fast.clone(), slow.clone()], 16, 4, 1.0);
        let part = crate::coordinator::partition::hpopta(&[fast, slow], 16).unwrap();
        // hpopta found (12, 4): makespan 0.04; dynamic pays 8/100 = 0.08
        assert!(part.makespan < t_dyn * 0.8, "static {} dynamic {t_dyn}", part.makespan);
    }

    #[test]
    fn virtual_dynamic_balances_flat_speeds() {
        let c = Curve::new(vec![4, 8, 16], vec![100.0, 100.0, 100.0]);
        let t = dynamic_virtual_time(&[c.clone(), c], 32, 4, 1.0);
        // two groups, 32 rows at 100: perfect halves = 0.16
        assert!((t - 0.16).abs() < 1e-12);
    }
}
