//! Bi-objective (time, energy) partitioning — the companion extension
//! the paper builds on (Reddy & Lastovetsky, IEEE ToC 2017, ref [36]:
//! "Bi-objective optimization of data-parallel applications on
//! homogeneous multicore clusters for performance and energy").
//!
//! Alongside each speed function s_i(x) the profiler (or simulator)
//! provides a discrete *energy function* e_i(x) — joules consumed by
//! processor i executing x rows. Two solvers:
//!
//! * [`eopta`] — minimize total energy subject to Σd_i = N (the
//!   energy-optimal distribution, ignoring time): exact min-cost DP on
//!   the reachable-sum lattice.
//! * [`pareto_front`] — the full time/energy Pareto front via an
//!   ε-constraint sweep over the candidate makespans (for each feasible
//!   time bound T, the minimum-energy distribution among those with
//!   makespan ≤ T).

use crate::coordinator::fpm::Curve;
use crate::coordinator::partition::PartitionError;

/// An energy function: joules for executing x rows (x ascending, same
/// grid convention as [`Curve`] — reuse it with "speeds" = joules).
pub type EnergyCurve = Curve;

/// A (time, energy, distribution) point.
#[derive(Clone, Debug, PartialEq)]
pub struct BiPoint {
    pub makespan: f64,
    pub energy: f64,
    pub d: Vec<usize>,
}

/// Minimize total energy Σ e_i(d_i) with Σ d_i = n, each d_i on its
/// grid (or 0, costing 0 J), optionally bounded by per-point time ≤
/// t_max (cost unit: x / speed, as in `partition`).
pub fn eopta(
    speed: &[Curve],
    energy: &[EnergyCurve],
    n: usize,
    t_max: f64,
) -> Result<BiPoint, PartitionError> {
    let p = speed.len();
    if p == 0 {
        return Err(PartitionError::NoProcessors);
    }
    assert_eq!(p, energy.len(), "speed/energy arity mismatch");
    for (i, c) in speed.iter().enumerate() {
        if c.is_empty() {
            return Err(PartitionError::EmptyCurve(i));
        }
    }
    if n == 0 {
        return Ok(BiPoint { makespan: 0.0, energy: 0.0, d: vec![0; p] });
    }

    // common grid step
    let mut step = n;
    for c in speed {
        for &x in &c.xs {
            step = gcd(step, x);
        }
    }
    let units = n / step;

    // DP: best[s] = min energy to reach sum s; parent for reconstruction
    const INF: f64 = f64::INFINITY;
    let mut best = vec![INF; units + 1];
    let mut choice: Vec<Vec<u32>> = Vec::with_capacity(p);
    best[0] = 0.0;
    for i in 0..p {
        let allowed: Vec<(usize, f64)> = speed[i]
            .xs
            .iter()
            .zip(&speed[i].speeds)
            .filter(|(&x, &s)| x <= n && (x as f64 / s) <= t_max + 1e-15)
            .filter_map(|(&x, _)| energy[i].speed_at(x).map(|e| (x / step, e)))
            .collect();
        let mut next = vec![INF; units + 1];
        let mut ch = vec![u32::MAX; units + 1];
        for s in 0..=units {
            if best[s] == INF {
                continue;
            }
            // taking zero rows costs zero energy
            if best[s] < next[s] {
                next[s] = best[s];
                ch[s] = 0;
            }
            for &(du, e) in &allowed {
                let t = s + du;
                if t <= units && best[s] + e < next[t] {
                    next[t] = best[s] + e;
                    ch[t] = du as u32;
                }
            }
        }
        best = next;
        choice.push(ch);
    }

    if best[units] == INF {
        let max_total: usize = speed.iter().map(|c| *c.xs.last().unwrap()).sum();
        return Err(PartitionError::Unreachable { n, max_total });
    }

    // reconstruct
    let mut d = vec![0usize; p];
    let mut s = units;
    for i in (0..p).rev() {
        let du = choice[i][s] as usize;
        d[i] = du * step;
        s -= du;
    }
    let makespan = d
        .iter()
        .zip(speed)
        .filter(|(&di, _)| di > 0)
        .map(|(&di, c)| di as f64 / c.speed_at(di).expect("grid point"))
        .fold(0.0f64, f64::max);
    Ok(BiPoint { makespan, energy: best[units], d })
}

/// Time/energy Pareto front via ε-constraint: for every candidate
/// makespan T (ascending), solve min-energy with time ≤ T and keep the
/// non-dominated outcomes.
pub fn pareto_front(
    speed: &[Curve],
    energy: &[EnergyCurve],
    n: usize,
) -> Result<Vec<BiPoint>, PartitionError> {
    let mut candidates: Vec<f64> = speed
        .iter()
        .flat_map(|c| c.xs.iter().zip(&c.speeds).map(|(&x, &s)| x as f64 / s))
        .collect();
    candidates.sort_by(|a, b| a.partial_cmp(b).unwrap());
    candidates.dedup_by(|a, b| (*a - *b).abs() < f64::EPSILON * a.abs().max(1.0));

    let mut front: Vec<BiPoint> = Vec::new();
    for &t in &candidates {
        let Ok(pt) = eopta(speed, energy, n, t) else { continue };
        // keep if it strictly improves energy over the current best
        match front.last() {
            Some(prev) if pt.energy >= prev.energy - 1e-12 => {}
            _ => front.push(pt),
        }
    }
    if front.is_empty() {
        let max_total: usize = speed.iter().map(|c| *c.xs.last().unwrap()).sum();
        return Err(PartitionError::Unreachable { n, max_total });
    }
    Ok(front)
}

fn gcd(a: usize, b: usize) -> usize {
    if b == 0 {
        a
    } else {
        gcd(b, a % b)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::partition::hpopta;

    fn curve(points: &[(usize, f64)]) -> Curve {
        Curve::new(points.iter().map(|p| p.0).collect(), points.iter().map(|p| p.1).collect())
    }

    #[test]
    fn eopta_prefers_efficient_processor() {
        // equal speeds, but proc 1 burns half the energy: give it all
        let s = curve(&[(4, 100.0), (8, 100.0)]);
        let e_hungry = curve(&[(4, 40.0), (8, 80.0)]);
        let e_frugal = curve(&[(4, 20.0), (8, 40.0)]);
        let pt = eopta(&[s.clone(), s], &[e_hungry, e_frugal], 8, f64::INFINITY).unwrap();
        assert_eq!(pt.d, vec![0, 8]);
        assert!((pt.energy - 40.0).abs() < 1e-12);
    }

    #[test]
    fn time_bound_forces_spread() {
        // all on one proc takes 8/100 = 0.08; bound 0.05 forces a split
        let s = curve(&[(4, 100.0), (8, 100.0)]);
        let e = curve(&[(4, 20.0), (8, 40.0)]);
        let tight = eopta(&[s.clone(), s.clone()], &[e.clone(), e.clone()], 8, 0.05).unwrap();
        assert_eq!(tight.d, vec![4, 4]);
        assert!(tight.makespan <= 0.05 + 1e-12);
    }

    #[test]
    fn pareto_front_is_monotone() {
        // heterogeneous speeds + energies: front must trade time for energy
        let s1 = curve(&[(4, 200.0), (8, 200.0), (12, 200.0)]);
        let s2 = curve(&[(4, 50.0), (8, 50.0), (12, 50.0)]);
        let e1 = curve(&[(4, 100.0), (8, 200.0), (12, 300.0)]); // fast but hungry
        let e2 = curve(&[(4, 10.0), (8, 20.0), (12, 30.0)]); // slow but frugal
        let front = pareto_front(&[s1, s2], &[e1, e2], 12).unwrap();
        assert!(!front.is_empty());
        for w in front.windows(2) {
            assert!(w[1].makespan >= w[0].makespan - 1e-12, "time must not improve");
            assert!(w[1].energy < w[0].energy, "energy must strictly improve");
        }
        // the energy-minimal end pushes work to the frugal processor
        let last = front.last().unwrap();
        assert!(last.d[1] >= last.d[0], "{:?}", last.d);
    }

    #[test]
    fn unconstrained_eopta_energy_no_worse_than_time_optimal() {
        let s1 = curve(&[(4, 100.0), (8, 300.0), (12, 100.0)]);
        let s2 = curve(&[(4, 120.0), (8, 90.0), (12, 110.0)]);
        let e1 = curve(&[(4, 50.0), (8, 60.0), (12, 200.0)]);
        let e2 = curve(&[(4, 30.0), (8, 100.0), (12, 150.0)]);
        let n = 12;
        let time_opt = hpopta(&[s1.clone(), s2.clone()], n).unwrap();
        let time_opt_energy: f64 = time_opt
            .d
            .iter()
            .zip([&e1, &e2])
            .filter(|(&di, _)| di > 0)
            .map(|(&di, e)| e.speed_at(di).unwrap())
            .sum();
        let energy_opt = eopta(&[s1, s2], &[e1, e2], n, f64::INFINITY).unwrap();
        assert!(energy_opt.energy <= time_opt_energy + 1e-12);
    }

    #[test]
    fn zero_n_and_errors() {
        let s = curve(&[(4, 10.0)]);
        let e = curve(&[(4, 5.0)]);
        let pt = eopta(&[s.clone()], &[e.clone()], 0, f64::INFINITY).unwrap();
        assert_eq!(pt.d, vec![0]);
        assert!(eopta(&[], &[], 4, f64::INFINITY).is_err());
        assert!(matches!(
            eopta(&[s], &[e], 100, f64::INFINITY).unwrap_err(),
            PartitionError::Unreachable { .. }
        ));
    }
}
