//! Model-based data partitioning: POPTA and HPOPTA.
//!
//! The paper invokes POPTA (Lastovetsky & Reddy, TPDS 2017) for identical
//! speed functions and HPOPTA (Khaleghzadeh et al., TPDS 2018) for
//! heterogeneous ones (PFFT-FPM Step 1). Both find the distribution
//! `d = {d_1..d_p}`, Σd_i = N, minimizing the parallel execution time
//! `max_i time_i(d_i)` for the *most general* (non-monotonic,
//! non-convex) discrete speed functions — the optimal solution may be
//! deliberately load-imbalanced.
//!
//! Implementation: exact on the discrete grid. Candidate makespans are
//! the O(p·m) per-processor point times; a binary search over them asks
//! "can processors, each restricted to {0} ∪ {x : time_i(x) ≤ T}, pick
//! d_i summing to N?" — answered by a reachable-sum bitset DP with parent
//! pointers for reconstruction. This is O(p·m·N/step) per check, exact,
//! and fast for the paper's grids (step 128, m ≤ 500, p ≤ 12). The same
//! machinery solves POPTA with p copies of one curve (matching the
//! original algorithm's output on all our test grids, including the
//! brute-force cross-check).

use crate::coordinator::fpm::{variation_pct, Curve};

/// Outcome of a partitioning run.
#[derive(Clone, Debug, PartialEq)]
pub struct Partition {
    /// rows per abstract processor, Σ = N (entries may be 0)
    pub d: Vec<usize>,
    /// predicted makespan, in the same unit as `cost` (relative time)
    pub makespan: f64,
    /// which algorithm produced it
    pub algorithm: Algorithm,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Algorithm {
    Popta,
    Hpopta,
    Balanced,
}

impl Algorithm {
    /// Stable lowercase name (wisdom-store serialization).
    pub fn name(&self) -> &'static str {
        match self {
            Algorithm::Popta => "popta",
            Algorithm::Hpopta => "hpopta",
            Algorithm::Balanced => "balanced",
        }
    }

    /// Inverse of [`Algorithm::name`].
    pub fn parse(s: &str) -> Option<Algorithm> {
        match s {
            "popta" => Some(Algorithm::Popta),
            "hpopta" => Some(Algorithm::Hpopta),
            "balanced" => Some(Algorithm::Balanced),
            _ => None,
        }
    }
}

/// Errors from partitioning. Display/Error are hand-implemented — the
/// offline vendor set has no `thiserror`.
#[derive(Debug, PartialEq)]
pub enum PartitionError {
    NoProcessors,
    EmptyCurve(usize),
    Unreachable { n: usize, max_total: usize },
    UnalignedGrid,
}

impl std::fmt::Display for PartitionError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PartitionError::NoProcessors => write!(f, "no processors given"),
            PartitionError::EmptyCurve(i) => write!(f, "curve {i} is empty"),
            PartitionError::Unreachable { n, max_total } => write!(
                f,
                "N = {n} is not reachable with the given curves (max total {max_total})"
            ),
            PartitionError::UnalignedGrid => {
                write!(f, "curve grids are not aligned to a common step")
            }
        }
    }
}

impl std::error::Error for PartitionError {}

/// Relative execution time of x rows at curve speed s(x): `x / s(x)`.
/// The absolute scale (2.5·N·log2 N / 1e-6) is constant across processors
/// for a fixed row length N, so it cancels in the minimax.
fn point_cost(x: usize, speed: f64) -> f64 {
    x as f64 / speed
}

/// The paper's Step 1b ε-identity test: are the p plane-section curves
/// identical within tolerance `eps` (fraction, e.g. 0.05 = 5%)?
/// Returns false (heterogeneous) if any shared grid point differs by more.
pub fn curves_identical(curves: &[Curve], eps: f64) -> bool {
    if curves.len() <= 1 {
        return true;
    }
    let base = &curves[0];
    for (k, &x) in base.xs.iter().enumerate() {
        let mut mn = base.speeds[k];
        let mut mx = base.speeds[k];
        for c in &curves[1..] {
            match c.speed_at(x) {
                Some(s) => {
                    mn = mn.min(s);
                    mx = mx.max(s);
                }
                None => return false, // differing grids ⇒ not identical
            }
        }
        if variation_pct(mx, mn) / 100.0 > eps {
            return false;
        }
    }
    true
}

/// The paper's Step 1c averaging: harmonic-mean speed function
/// `s_avg(x) = p / Σ_j 1/s_j(x)` over the shared grid.
pub fn average_curve(curves: &[Curve]) -> Curve {
    assert!(!curves.is_empty());
    let p = curves.len() as f64;
    let base = &curves[0];
    let mut xs = Vec::new();
    let mut speeds = Vec::new();
    for (k, &x) in base.xs.iter().enumerate() {
        let mut inv_sum = 1.0 / base.speeds[k];
        let mut all = true;
        for c in &curves[1..] {
            match c.speed_at(x) {
                Some(s) => inv_sum += 1.0 / s,
                None => {
                    all = false;
                    break;
                }
            }
        }
        if all {
            xs.push(x);
            speeds.push(p / inv_sum);
        }
    }
    Curve::new(xs, speeds)
}

/// POPTA: optimal distribution of `n` rows over `p` processors sharing
/// one speed curve.
pub fn popta(curve: &Curve, p: usize, n: usize) -> Result<Partition, PartitionError> {
    let curves: Vec<Curve> = std::iter::repeat(curve.clone()).take(p).collect();
    let mut part = hpopta(&curves, n)?;
    part.algorithm = Algorithm::Popta;
    Ok(part)
}

/// HPOPTA: optimal distribution of `n` rows over processors with
/// individual speed curves. Exact minimax over the discrete grid.
pub fn hpopta(curves: &[Curve], n: usize) -> Result<Partition, PartitionError> {
    let p = curves.len();
    if p == 0 {
        return Err(PartitionError::NoProcessors);
    }
    for (i, c) in curves.iter().enumerate() {
        if c.is_empty() {
            return Err(PartitionError::EmptyCurve(i));
        }
    }
    if n == 0 {
        return Ok(Partition { d: vec![0; p], makespan: 0.0, algorithm: Algorithm::Hpopta });
    }

    // grid step: gcd of all x values and n, so sums map onto a dense array
    let mut step = n;
    for c in curves {
        for &x in &c.xs {
            step = gcd(step, x);
        }
    }
    if step == 0 {
        return Err(PartitionError::UnalignedGrid);
    }
    let units = n / step; // target in grid units

    let max_total: usize = curves.iter().map(|c| *c.xs.last().unwrap()).sum();
    if max_total < n {
        return Err(PartitionError::Unreachable { n, max_total });
    }

    // candidate makespans: every per-processor point time (dedup/sorted)
    let mut candidates: Vec<f64> = curves
        .iter()
        .flat_map(|c| c.xs.iter().zip(&c.speeds).map(|(&x, &s)| point_cost(x, s)))
        .collect();
    candidates.sort_by(|a, b| a.partial_cmp(b).unwrap());
    candidates.dedup_by(|a, b| (*a - *b).abs() < f64::EPSILON * a.abs().max(1.0));

    // binary search the smallest feasible candidate
    let mut lo = 0usize;
    let mut hi = candidates.len() - 1;
    if !feasible(curves, units, step, candidates[hi]).0 {
        return Err(PartitionError::Unreachable { n, max_total });
    }
    while lo < hi {
        let mid = (lo + hi) / 2;
        if feasible(curves, units, step, candidates[mid]).0 {
            hi = mid;
        } else {
            lo = mid + 1;
        }
    }
    let t_opt = candidates[lo];
    let (ok, d) = feasible(curves, units, step, t_opt);
    debug_assert!(ok);
    let d = d.expect("feasible returned a distribution");

    // true makespan = max over used points of their cost
    let makespan = d
        .iter()
        .zip(curves)
        .filter(|(&di, _)| di > 0)
        .map(|(&di, c)| point_cost(di, c.speed_at(di).expect("grid point")))
        .fold(0.0f64, f64::max);

    Ok(Partition { d, makespan, algorithm: Algorithm::Hpopta })
}

/// Reachable-sum DP: can each processor pick d_i in {0} ∪ {x: cost ≤ T}
/// with Σ d_i / step = units? Returns the distribution on success.
fn feasible(
    curves: &[Curve],
    units: usize,
    step: usize,
    t_max: f64,
) -> (bool, Option<Vec<usize>>) {
    let p = curves.len();
    // reach[s] after processing processors 0..i; parent choice for
    // reconstruction: choice[i][s] = x taken by processor i to land on s
    let mut reach = vec![false; units + 1];
    reach[0] = true;
    let mut choice: Vec<Vec<u32>> = Vec::with_capacity(p);

    for c in curves {
        let allowed: Vec<usize> = c
            .xs
            .iter()
            .zip(&c.speeds)
            .filter(|(&x, &s)| x <= units * step && point_cost(x, s) <= t_max + 1e-15)
            .map(|(&x, _)| x / step)
            .collect();
        let mut next = vec![false; units + 1];
        let mut ch = vec![u32::MAX; units + 1];
        for s in 0..=units {
            if !reach[s] {
                continue;
            }
            // taking 0 rows
            if !next[s] {
                next[s] = true;
                ch[s] = 0;
            }
            for &a in &allowed {
                let t = s + a;
                if t <= units && !next[t] {
                    next[t] = true;
                    ch[t] = a as u32;
                }
            }
        }
        choice.push(ch);
        reach = next;
    }

    if !reach[units] {
        return (false, None);
    }
    // reconstruct back-to-front
    let mut d = vec![0usize; p];
    let mut s = units;
    for i in (0..p).rev() {
        let a = choice[i][s] as usize;
        d[i] = a * step;
        s -= a;
    }
    debug_assert_eq!(s, 0);
    (true, Some(d))
}

/// Balanced (PFFT-LB) distribution: N/p each, remainder spread from the
/// front — the baseline the model-based algorithms beat.
pub fn balanced(p: usize, n: usize) -> Partition {
    assert!(p > 0);
    let base = n / p;
    let rem = n % p;
    let d: Vec<usize> = (0..p).map(|i| base + usize::from(i < rem)).collect();
    Partition { d, makespan: f64::NAN, algorithm: Algorithm::Balanced }
}

/// Predicted makespan of an arbitrary distribution under given curves
/// (nearest-grid speeds; used to compare optimal vs balanced).
pub fn predict_makespan(curves: &[Curve], d: &[usize]) -> f64 {
    d.iter()
        .zip(curves)
        .filter(|(&di, _)| di > 0)
        .map(|(&di, c)| point_cost(di, c.speed_nearest(di)))
        .fold(0.0f64, f64::max)
}

fn gcd(a: usize, b: usize) -> usize {
    if b == 0 {
        a
    } else {
        gcd(b, a % b)
    }
}

/// Exhaustive minimax reference (tests only): try every gridded
/// assignment. Exponential — keep grids tiny.
pub fn brute_force(curves: &[Curve], n: usize) -> Option<(Vec<usize>, f64)> {
    let p = curves.len();
    let mut best: Option<(Vec<usize>, f64)> = None;
    let mut d = vec![0usize; p];
    fn rec(
        curves: &[Curve],
        n: usize,
        i: usize,
        d: &mut Vec<usize>,
        acc: usize,
        cur_max: f64,
        best: &mut Option<(Vec<usize>, f64)>,
    ) {
        if i == curves.len() {
            if acc == n {
                match best {
                    Some((_, m)) if *m <= cur_max => {}
                    _ => *best = Some((d.clone(), cur_max)),
                }
            }
            return;
        }
        // option: zero rows
        d[i] = 0;
        rec(curves, n, i + 1, d, acc, cur_max, best);
        for (k, &x) in curves[i].xs.iter().enumerate() {
            if acc + x > n {
                continue;
            }
            d[i] = x;
            let c = point_cost(x, curves[i].speeds[k]);
            rec(curves, n, i + 1, d, acc + x, cur_max.max(c), best);
        }
        d[i] = 0;
    }
    rec(curves, n, 0, &mut d, 0, 0.0, &mut best);
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    fn curve(points: &[(usize, f64)]) -> Curve {
        Curve::new(points.iter().map(|p| p.0).collect(), points.iter().map(|p| p.1).collect())
    }

    #[test]
    fn identical_curves_detected() {
        let a = curve(&[(10, 100.0), (20, 200.0)]);
        let b = curve(&[(10, 103.0), (20, 198.0)]);
        assert!(curves_identical(&[a.clone(), b.clone()], 0.05));
        assert!(!curves_identical(&[a.clone(), b], 0.01));
        assert!(curves_identical(&[a], 0.0));
    }

    #[test]
    fn heterogeneous_grids_not_identical() {
        let a = curve(&[(10, 100.0)]);
        let b = curve(&[(20, 100.0)]);
        assert!(!curves_identical(&[a, b], 0.5));
    }

    #[test]
    fn average_is_harmonic_mean() {
        let a = curve(&[(10, 100.0), (20, 300.0)]);
        let b = curve(&[(10, 200.0), (20, 300.0)]);
        let avg = average_curve(&[a, b]);
        // harmonic mean of 100, 200 = 2/(1/100+1/200) = 133.33
        assert!((avg.speeds[0] - 400.0 / 3.0).abs() < 1e-9);
        assert!((avg.speeds[1] - 300.0).abs() < 1e-9);
    }

    #[test]
    fn balanced_splits_remainder() {
        assert_eq!(balanced(4, 16).d, vec![4, 4, 4, 4]);
        assert_eq!(balanced(4, 18).d, vec![5, 5, 4, 4]);
        assert_eq!(balanced(3, 2).d, vec![1, 1, 0]);
    }

    #[test]
    fn hpopta_balances_flat_speeds() {
        // flat identical speeds ⇒ optimum is the balanced split
        let c = curve(&[(4, 100.0), (8, 100.0), (12, 100.0), (16, 100.0)]);
        let part = hpopta(&[c.clone(), c], 16).unwrap();
        assert_eq!(part.d.iter().sum::<usize>(), 16);
        assert_eq!(part.d, vec![8, 8]);
        assert!((part.makespan - 0.08).abs() < 1e-12);
    }

    #[test]
    fn hpopta_exploits_speed_spike() {
        // proc 0 has a huge speed spike at x=12: give it more than half
        let fast = curve(&[(4, 100.0), (8, 100.0), (12, 600.0), (16, 100.0)]);
        let slow = curve(&[(4, 100.0), (8, 100.0), (12, 100.0), (16, 100.0)]);
        let part = hpopta(&[fast, slow], 16).unwrap();
        assert_eq!(part.d, vec![12, 4]);
        // makespan = max(12/600, 4/100) = 0.04 < balanced 0.08
        assert!((part.makespan - 0.04).abs() < 1e-12);
    }

    #[test]
    fn hpopta_matches_brute_force_random() {
        use crate::util::prng::Xoshiro256;
        let mut rng = Xoshiro256::seeded(42);
        for case in 0..40 {
            let p = rng.range_usize(2, 3);
            let m = rng.range_usize(3, 5);
            let step = 2usize;
            let curves: Vec<Curve> = (0..p)
                .map(|_| {
                    let xs: Vec<usize> = (1..=m).map(|k| k * step).collect();
                    let speeds: Vec<f64> =
                        (0..m).map(|_| 50.0 + rng.next_f64() * 500.0).collect();
                    Curve::new(xs, speeds)
                })
                .collect();
            let n = step * rng.range_usize(1, p * m);
            let bf = brute_force(&curves, n);
            let hp = hpopta(&curves, n);
            match bf {
                Some((_, bf_makespan)) => {
                    let part = hp.unwrap_or_else(|e| panic!("case {case}: {e}"));
                    assert_eq!(part.d.iter().sum::<usize>(), n, "case {case}");
                    assert!(
                        (part.makespan - bf_makespan).abs() < 1e-9,
                        "case {case}: hpopta {} vs brute {}",
                        part.makespan,
                        bf_makespan
                    );
                }
                None => assert!(hp.is_err(), "case {case}: brute says infeasible"),
            }
        }
    }

    #[test]
    fn hpopta_beats_or_ties_balanced() {
        let a = curve(&[(64, 100.0), (128, 80.0), (192, 240.0), (256, 90.0)]);
        let b = curve(&[(64, 110.0), (128, 90.0), (192, 100.0), (256, 85.0)]);
        let n = 256;
        let part = hpopta(&[a.clone(), b.clone()], n).unwrap();
        let bal = predict_makespan(&[a, b], &balanced(2, n).d);
        assert!(part.makespan <= bal + 1e-12, "opt {} bal {bal}", part.makespan);
    }

    #[test]
    fn popta_homogeneous() {
        let c = curve(&[(4, 10.0), (8, 30.0), (12, 20.0)]);
        let part = popta(&c, 3, 24).unwrap();
        assert_eq!(part.algorithm, Algorithm::Popta);
        assert_eq!(part.d.iter().sum::<usize>(), 24);
        // optimum: each takes 8 at speed 30 → cost 8/30 ≈ 0.2667
        assert_eq!(part.d, vec![8, 8, 8]);
    }

    #[test]
    fn unreachable_n_errors() {
        let c = curve(&[(4, 10.0)]);
        let err = hpopta(&[c.clone(), c], 100).unwrap_err();
        assert!(matches!(err, PartitionError::Unreachable { .. }));
    }

    #[test]
    fn zero_n_gives_zero_distribution() {
        let c = curve(&[(4, 10.0)]);
        let part = hpopta(&[c], 0).unwrap();
        assert_eq!(part.d, vec![0]);
        assert_eq!(part.makespan, 0.0);
    }

    #[test]
    fn empty_inputs_error() {
        assert_eq!(hpopta(&[], 4).unwrap_err(), PartitionError::NoProcessors);
        let empty = Curve::new(vec![], vec![]);
        assert!(matches!(
            hpopta(&[empty], 4).unwrap_err(),
            PartitionError::EmptyCurve(0)
        ));
    }

    #[test]
    fn paper_example_shape() {
        // Figures 9-10: two 18-thread groups, N=24704, HPOPTA gives the
        // imbalanced (11648, 13056). Build curves with that optimum:
        // group2 slightly faster near 13056, group1 best at 11648.
        let step = 128;
        let xs: Vec<usize> = (1..=24704 / 128).map(|k| k * step).collect();
        let speed1: Vec<f64> = xs
            .iter()
            .map(|&x| if x == 11648 { 9000.0 } else { 6000.0 })
            .collect();
        let speed2: Vec<f64> = xs
            .iter()
            .map(|&x| if x == 13056 { 10000.0 } else { 6000.0 })
            .collect();
        let part = hpopta(
            &[Curve::new(xs.clone(), speed1), Curve::new(xs, speed2)],
            24704,
        )
        .unwrap();
        assert_eq!(part.d, vec![11648, 13056]);
    }
}
