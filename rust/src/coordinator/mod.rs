//! L3 coordinator — the paper's contribution.
//!
//! * [`fpm`] — functional performance models: discrete 3D speed surfaces
//!   `s_i(x, y)`, plane/column sections, Eq-1 variation width, and the
//!   paper's speed formula `s = 2.5·x·y·log2(y) / t`.
//! * [`partition`] — the data-partitioning algorithms: the ε-identity
//!   test (PFFT-FPM Step 1b), speed-function averaging (Step 1c),
//!   **POPTA** (homogeneous) and **HPOPTA** (heterogeneous), exact on the
//!   discrete grid via binary search over candidate makespans + a
//!   reachable-sum DP.
//! * [`pad`] — `Determine_Pad_Length` (PFFT-FPM-PAD Step 2).
//! * [`group`] — abstract processor (p, t) configurations.
//! * [`engine`] — the `RowFftEngine` abstraction the drivers dispatch to
//!   (native rust FFT, PJRT artifacts, or the virtual-time simulator).
//! * [`pfft`] — the parallel 2D-DFT drivers: `PFFT-LB`, `PFFT-FPM`,
//!   `PFFT-FPM-PAD` (Algorithms 1-5).
//! * [`real`] — the real-input variants: planned r2c execution
//!   (`pfft_fpm_real` / `pfft_fpm_pad_real`, the batched stage-DAG
//!   executor) over Hermitian-packed `N×(N/2+1)` storage — roughly
//!   half the flops of the c2c drivers for real-valued signals.
//! * [`plan`] — [`plan::PlannedTransform`]: the reusable partition+pad
//!   planning outcome the drivers execute and the serving layer's wisdom
//!   store memoizes (now carrying a
//!   [`crate::dft::real::TransformKind`]), plus its compiled
//!   [`plan::ExecPipeline`] form — the tile schedule of the fused
//!   (transpose-free) execution path.

pub mod dynamic;
pub mod energy;
pub mod engine;
pub mod fpm;
pub mod group;
pub mod pad;
pub mod partition;
pub mod pfft;
pub mod pfft3d;
pub mod plan;
pub mod real;

pub use plan::{ExecPipeline, PhaseTimings, PlannedTransform};
