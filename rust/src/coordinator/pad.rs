//! `Determine_Pad_Length` — PFFT-FPM-PAD Step 2.
//!
//! Given processor i's distribution d[i] and its FPM column section
//! `x = d[i]` (speed vs row length y), pick
//!
//!   N_padded = argmin_{V ∈ (N, y_m]}  d[i]·V / s_i(d[i], V)
//!              subject to  d[i]·V / s_i(d[i], V)  <  d[i]·N / s_i(d[i], N)
//!
//! i.e. the row length with the smallest execution-time estimate that
//! beats the unpadded one; 0-length pad when no such point exists. The
//! paper uses the ratio `x·y / s(x,y)` as the time proxy (Section III-D);
//! we implement that literally and also offer the exact-flops variant
//! `2.5·x·y·log2(y) / s` behind [`PadCost`] (ablation bench
//! `figures --fig pad-ablation`).
//!
//! NOTE on semantics: zero-padding a length-N signal to V and taking a
//! V-point DFT yields a *spectral interpolation*, not the N-point DFT —
//! the paper trades exactness for speed here. Our engines implement the
//! paper's scheme verbatim; the correctness-preserving alternative
//! (Bluestein chirp-z, which pads internally without changing the
//! transform) is what the native engine uses for non-pow2 lengths. See
//! DESIGN.md §Substitutions.

use crate::model::{Curve, PerfModel};

/// Which execution-time proxy the argmin uses.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum PadCost {
    /// The paper's literal ratio x·y/s.
    #[default]
    PaperRatio,
    /// Exact flops model 2.5·x·y·log2(y)/s.
    ExactFlops,
}

/// Decision record for one processor's padding.
#[derive(Clone, Debug, PartialEq)]
pub struct PadDecision {
    /// chosen padded row length (== n when no padding helps)
    pub n_padded: usize,
    /// predicted time proxy at n (unpadded)
    pub t_unpadded: f64,
    /// predicted time proxy at n_padded
    pub t_padded: f64,
}

impl PadDecision {
    pub fn is_padded(&self) -> bool {
        self.n_padded_gain() > 0.0
    }

    /// Predicted relative gain (0 when unpadded).
    pub fn n_padded_gain(&self) -> f64 {
        if self.t_unpadded > 0.0 && self.t_padded < self.t_unpadded {
            1.0 - self.t_padded / self.t_unpadded
        } else {
            0.0
        }
    }
}

/// The paper's pad-candidate grid: multiples of `step` in `(n, n + window]`
/// (§V-B uses a 128-point grid).
pub fn grid_candidates(n: usize, window: usize, step: usize) -> Vec<usize> {
    let step = step.max(1);
    let mut v = Vec::new();
    let mut y = (n / step + 1) * step;
    while y <= n + window {
        v.push(y);
        y += step;
    }
    v
}

/// 5-smooth pad candidates on the grid: multiples of `step` in
/// `(n, n + window]` whose only prime factors are {2, 3, 5} — the
/// lengths the native mixed-radix kernel transforms at full speed
/// (e.g. for N = 384 this yields {512, 640, 768} and drops 896 = 128·7,
/// so PFFT-FPM-PAD can pick 640 instead of jumping to a power of two).
pub fn smooth_grid_candidates(n: usize, window: usize, step: usize) -> Vec<usize> {
    grid_candidates(n, window, step)
        .into_iter()
        .filter(|&y| crate::dft::radix::is_five_smooth(y))
        .collect()
}

fn cost(x: usize, y: usize, speed: f64, model: PadCost) -> f64 {
    match model {
        PadCost::PaperRatio => x as f64 * y as f64 / speed,
        PadCost::ExactFlops => 2.5 * x as f64 * y as f64 * (y as f64).log2() / speed,
    }
}

/// Pad-length selection over a column-section curve (y ascending).
/// `x` is the processor's row count d[i]; `n` the unpadded row length.
pub fn determine_pad_length(column: &Curve, x: usize, n: usize, model: PadCost) -> PadDecision {
    // speed at the unpadded point (nearest grid if n not measured)
    let s_n = column.speed_at(n).unwrap_or_else(|| column.speed_nearest(n));
    let t_unpadded = cost(x, n, s_n, model);

    let mut best_v = n;
    let mut best_t = t_unpadded;
    for (k, &v) in column.xs.iter().enumerate() {
        if v <= n {
            continue; // only (N, y_m] candidates
        }
        let t = cost(x, v, column.speeds[k], model);
        if t < best_t {
            best_t = t;
            best_v = v;
        }
    }
    PadDecision { n_padded: best_v, t_unpadded, t_padded: best_t }
}

/// Per-processor pad decisions from a performance model (PAD Step 2):
/// the column section x = d[i] of group i, windowed to `(n, n + window]`
/// candidates, then the argmin.
pub fn pads_for_distribution(
    model: &dyn PerfModel,
    d: &[usize],
    n: usize,
    window: usize,
    cost: PadCost,
) -> Vec<PadDecision> {
    assert_eq!(model.groups(), d.len(), "model group count must match the distribution");
    d.iter()
        .enumerate()
        .map(|(g, &di)| {
            if di == 0 {
                return PadDecision { n_padded: n, t_unpadded: 0.0, t_padded: 0.0 };
            }
            let column = model.column_section(g, di, n, window);
            if column.is_empty() {
                return PadDecision { n_padded: n, t_unpadded: 0.0, t_padded: 0.0 };
            }
            determine_pad_length(&column, di, n, cost)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn col(points: &[(usize, f64)]) -> Curve {
        Curve::new(points.iter().map(|p| p.0).collect(), points.iter().map(|p| p.1).collect())
    }

    #[test]
    fn picks_faster_larger_size() {
        // speed collapses at y=1000 but is excellent at y=1024:
        // t(1000) = 100*1000/50 = 2000; t(1024) = 100*1024/600 ≈ 170.7
        let c = col(&[(512, 500.0), (1000, 50.0), (1024, 600.0), (2048, 400.0)]);
        let dec = determine_pad_length(&c, 100, 1000, PadCost::PaperRatio);
        assert_eq!(dec.n_padded, 1024);
        assert!(dec.is_padded());
        assert!(dec.n_padded_gain() > 0.9);
    }

    #[test]
    fn no_pad_when_nothing_beats_n() {
        let c = col(&[(1000, 500.0), (1024, 400.0), (2048, 100.0)]);
        let dec = determine_pad_length(&c, 10, 1000, PadCost::PaperRatio);
        assert_eq!(dec.n_padded, 1000);
        assert!(!dec.is_padded());
        assert_eq!(dec.n_padded_gain(), 0.0);
    }

    #[test]
    fn smaller_sizes_never_chosen() {
        // y=512 is blazing fast but below N — must be ignored
        let c = col(&[(512, 9999.0), (1000, 100.0), (2048, 150.0)]);
        let dec = determine_pad_length(&c, 10, 1000, PadCost::PaperRatio);
        // t(1000)=10*1000/100=100; t(2048)=10*2048/150=136.5 → no pad
        assert_eq!(dec.n_padded, 1000);
    }

    #[test]
    fn argmin_takes_global_minimum() {
        // two beneficial candidates; the better one wins
        let c = col(&[(1000, 100.0), (1024, 300.0), (1152, 500.0)]);
        let dec = determine_pad_length(&c, 10, 1000, PadCost::PaperRatio);
        // t(1024)=34.1, t(1152)=23.0 → 1152
        assert_eq!(dec.n_padded, 1152);
    }

    #[test]
    fn exact_flops_model_differs_when_log_matters() {
        // paper ratio ignores log2(y) growth; candidates chosen near the
        // break-even flip between models
        let c = col(&[(1024, 100.0), (4096, 110.0)]);
        let paper = determine_pad_length(&c, 10, 1024, PadCost::PaperRatio);
        // paper: t(1024)=102.4, t(4096)=372 → no pad for both models here;
        // make speed high enough that ratio pads but flops (log 4096/log
        // 1024 = 1.2x extra work) does not:
        let c2 = col(&[(1024, 100.0), (4096, 405.0)]);
        let p2 = determine_pad_length(&c2, 10, 1024, PadCost::PaperRatio);
        let e2 = determine_pad_length(&c2, 10, 1024, PadCost::ExactFlops);
        assert_eq!(paper.n_padded, 1024);
        assert_eq!(p2.n_padded, 4096); // 10*4096/405 = 101.1 < 102.4
        assert_eq!(e2.n_padded, 1024); // ×(12/10) work ⇒ 121.4 > 102.4·1.0
    }

    #[test]
    fn paper_example_24704_pads_to_24960() {
        // Figures 11-12: both groups pad N=24704 → 24960. Build sections
        // where 24960 is the first dominating larger size.
        let xs: Vec<usize> = (24704 / 128..=25600 / 128).map(|k| k * 128).collect();
        let speeds: Vec<f64> = xs
            .iter()
            .map(|&y| if y == 24960 { 12000.0 } else { 7000.0 })
            .collect();
        let c = Curve::new(xs, speeds);
        for &x in &[11648usize, 13056] {
            let dec = determine_pad_length(&c, x, 24704, PadCost::PaperRatio);
            assert_eq!(dec.n_padded, 24960, "x={x}");
        }
    }

    #[test]
    fn grid_candidates_cover_window() {
        assert_eq!(grid_candidates(384, 512, 128), vec![512, 640, 768, 896]);
        // n off-grid still starts at the next multiple
        assert_eq!(grid_candidates(400, 300, 128), vec![512, 640]);
        assert_eq!(grid_candidates(384, 100, 128), Vec::<usize>::new());
    }

    #[test]
    fn smooth_candidates_drop_non_smooth_lengths() {
        assert_eq!(smooth_grid_candidates(384, 512, 128), vec![512, 640, 768]);
        // 1664 = 128·13 and 1792 = 128·14 are dropped; 1536 = 2^9·3 kept
        assert_eq!(smooth_grid_candidates(1408, 512, 128), vec![1536, 1920]);
    }

    #[test]
    fn zero_rows_processor_gets_trivial_decision() {
        use crate::model::{SpeedFunction, StaticModel};
        let fpm = SpeedFunction::from_fn("f", vec![128], vec![1024, 2048], |_, _| Some(100.0));
        let model = StaticModel::new(vec![fpm.clone(), fpm]);
        let pads =
            pads_for_distribution(&model, &[0, 128], 1024, usize::MAX, PadCost::PaperRatio);
        assert_eq!(pads[0].n_padded, 1024);
        assert!(!pads[0].is_padded());
        assert_eq!(pads.len(), 2);
    }
}
