//! Functional performance models — moved to [`crate::model`], the
//! unified online performance-model subsystem (surfaces + the
//! [`crate::model::PerfModel`] trait + static/sim/online
//! implementations).
//!
//! This module remains as a re-export shim so existing
//! `coordinator::fpm::*` import paths keep compiling; new code should
//! import from `crate::model` directly.

pub use crate::model::surface::{
    sanitize_time, speed_from_time, speed_from_time_sanitized, time_from_speed, variation_pct,
    Curve, SpeedFunction, MIN_TIME_S,
};
