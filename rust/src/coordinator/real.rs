//! Engine-generic execution of planned **real-input** (r2c) 2D
//! transforms — the coordinator face of [`crate::dft::real`].
//!
//! The c2c drivers execute a [`PlannedTransform`] in place over an
//! `N×N` complex matrix; the real path is out-of-place by nature (an
//! `N×N` real signal in, a Hermitian-packed `N×(N/2+1)` half spectrum
//! out), so it gets its own executor built from the same pieces:
//!
//! * **row phase**: each group's row range runs the r2c pair kernel —
//!   two real rows per complex FFT at the group's pad length (the tile
//!   gather doubles as Algorithm 7's padded work matrix), tiled in
//!   [`crate::dft::pipeline::DEFAULT_ROW_TILE`]-row steps so pairing is
//!   identical under every execution strategy;
//! * **column phase**: complex FFTs down the `N/2+1` *stored* columns
//!   only — the packed layout halves phase-2 work too. Under
//!   [`PipelineMode::Fused`] the column tiles of the plan's compiled
//!   schedule are clipped to the packed width and run on the same
//!   [`StageDag`] as the row tiles (one graph across a whole batch, no
//!   phase barrier); under [`PipelineMode::Barrier`] the packed
//!   rectangle is transposed out of place and the groups run padded row
//!   FFTs over their clipped ranges. Both modes feed every logical
//!   vector to the same kernel — outputs are bit-identical.
//!
//! [`pfft_fpm_real`] / [`pfft_fpm_pad_real`] are the real variants of
//! the paper's drivers (re-exported from [`crate::coordinator::pfft`]);
//! the serving layer batches through
//! [`execute_real_batch_with_mode`].

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use crate::coordinator::engine::{EngineError, RowFftEngine};
use crate::coordinator::group::row_offsets;
use crate::coordinator::pad::PadDecision;
use crate::coordinator::partition::Algorithm;
use crate::coordinator::pfft::fft_rows_padded;
use crate::coordinator::plan::{trivial_pads, PhaseTimings, PlannedTransform, TileSpec};
use crate::dft::exec::{with_scratch, ExecCtx, Job};
use crate::dft::fft::Direction;
use crate::dft::pipeline::{
    default_mode, gather_col_tile, scatter_col_tile, PipelineMode, SendPtr, StageDag,
};
use crate::dft::real::{half_cols, pack_pairs_tile, unpack_pairs_tile, RealMatrix, TransformKind};
use crate::dft::transpose::transposed;
use crate::dft::SignalMatrix;

/// One r2c row tile over an arbitrary engine: pack the tile's row pairs
/// into leased scratch at stride `v`, one engine call over the pairs,
/// Hermitian unpack into the packed dst rows.
#[allow(clippy::too_many_arguments)]
pub fn r2c_tile_engine(
    engine: &dyn RowFftEngine,
    src_tile: &[f64],
    dst_re: &mut [f64],
    dst_im: &mut [f64],
    rows: usize,
    n: usize,
    v: usize,
    threads: usize,
) -> Result<(), EngineError> {
    let nc = half_cols(n);
    with_scratch(|s| {
        let pairs = rows.div_ceil(2);
        let (wre, wim) = s.pair(pairs * v);
        pack_pairs_tile(src_tile, rows, n, v, wre, wim);
        engine.fft_rows(wre, wim, pairs, v, Direction::Forward, threads)?;
        unpack_pairs_tile(wre, wim, rows, nc, v, dst_re, dst_im);
        Ok(())
    })
}

/// r2c row phase over a contiguous row range of an arbitrary engine:
/// [`crate::dft::pipeline::DEFAULT_ROW_TILE`]-row tiles (an even count,
/// so pairing never depends on how the range is later split), serial
/// tile loop with the engine's own `threads` parallelism per call. The
/// profiler measures real-plane FPM surfaces through this entry point.
#[allow(clippy::too_many_arguments)]
pub fn r2c_rows_engine(
    engine: &dyn RowFftEngine,
    src: &[f64],
    dst_re: &mut [f64],
    dst_im: &mut [f64],
    rows: usize,
    n: usize,
    v: usize,
    threads: usize,
) -> Result<(), EngineError> {
    let nc = half_cols(n);
    debug_assert_eq!(src.len(), rows * n);
    debug_assert_eq!(dst_re.len(), rows * nc);
    let tile = crate::dft::pipeline::DEFAULT_ROW_TILE;
    let mut re_rest: &mut [f64] = dst_re;
    let mut im_rest: &mut [f64] = dst_im;
    let mut r = 0usize;
    while r < rows {
        let len = tile.min(rows - r);
        let (re_t, re_next) = re_rest.split_at_mut(len * nc);
        let (im_t, im_next) = im_rest.split_at_mut(len * nc);
        re_rest = re_next;
        im_rest = im_next;
        r2c_tile_engine(engine, &src[r * n..(r + len) * n], re_t, im_t, len, n, v, threads)?;
        r += len;
    }
    Ok(())
}

/// A read-only raw plane pointer shared across pipeline tasks. SAFETY
/// contract: the pointee is only ever *read* through this pointer, and
/// the borrow it was created from outlives the scheduler run.
#[derive(Clone, Copy)]
struct SendConstPtr(*const f64);
// SAFETY: see the contract above — read-only access to a live borrow.
unsafe impl Send for SendConstPtr {}

/// Execute a planned real forward transform over a batch of matrices:
/// `srcs[i]` is the i-th `n×n` real signal (row-major), `dsts[i]` the
/// caller-allocated `n×(n/2+1)` packed output. Returns the per-phase
/// timings the serving executor feeds into the online model.
pub fn execute_real_batch_with_mode(
    engine: &dyn RowFftEngine,
    plan: &PlannedTransform,
    srcs: &[&[f64]],
    dsts: &mut [&mut SignalMatrix],
    threads_per_group: usize,
    mode: PipelineMode,
) -> Result<PhaseTimings, EngineError> {
    let n = plan.n;
    let nc = half_cols(n);
    assert_eq!(
        plan.kind.plan_kind(),
        TransformKind::R2c,
        "c2c plans execute via the c2c batch executor"
    );
    assert_eq!(srcs.len(), dsts.len(), "src/dst batch arity mismatch");
    assert_eq!(plan.d.iter().sum::<usize>(), n, "plan distribution must cover all rows");
    for s in srcs {
        assert_eq!(s.len(), n * n, "real input must be n*n row-major");
    }
    for d in dsts.iter() {
        assert_eq!((d.rows, d.cols), (n, nc), "packed output must be n x (n/2+1)");
    }
    if srcs.is_empty() || n == 0 {
        return Ok(PhaseTimings::default());
    }
    let workers = plan.groups().max(1) * threads_per_group.max(1);
    match mode {
        PipelineMode::Fused => fused_real_batch(engine, plan, srcs, dsts, workers),
        PipelineMode::Barrier => barrier_real_batch(engine, plan, srcs, dsts, threads_per_group),
    }
}

/// One packed column tile: transpose-gather columns `[start, start+len)`
/// of the `n × nc` packed planes into scratch rows of length `fft_len`
/// (zero tail = stride-choice padding), one engine call, scatter the
/// first `n` spectrum points back.
fn col_tile_ffts_packed(
    engine: &dyn RowFftEngine,
    re: SendPtr,
    im: SendPtr,
    rows: usize,
    stride: usize,
    tile: TileSpec,
) -> Result<(), EngineError> {
    let (c0, w, v) = (tile.start, tile.len, tile.fft_len);
    with_scratch(|scratch| {
        let (wre, wim) = scratch.pair(w * v);
        // SAFETY: the DAG schedules this task strictly after every row
        // tile of its matrix, column tiles own pairwise-disjoint column
        // sets, and the caller holds the plane borrows until the DAG
        // run returns.
        unsafe { gather_col_tile(re, im, rows, stride, c0, c0 + w, v, wre, wim) };
        engine.fft_rows(wre, wim, w, v, Direction::Forward, 1)?;
        unsafe { scatter_col_tile(re, im, rows, stride, c0, c0 + w, v, wre, wim) };
        Ok(())
    })
}

fn fused_real_batch(
    engine: &dyn RowFftEngine,
    plan: &PlannedTransform,
    srcs: &[&[f64]],
    dsts: &mut [&mut SignalMatrix],
    workers: usize,
) -> Result<PhaseTimings, EngineError> {
    let n = plan.n;
    let nc = half_cols(n);
    // compile the c2c tile schedule, then clip the column tiles to the
    // packed width: only the stored columns exist
    let pipe = plan.pipeline();
    let col_tiles: Vec<TileSpec> = pipe
        .col_tiles
        .iter()
        .filter(|t| t.start < nc)
        .map(|t| TileSpec { start: t.start, len: t.len.min(nc - t.start), fft_len: t.fft_len })
        .collect();

    let errors: Mutex<Vec<EngineError>> = Mutex::new(Vec::new());
    let row_ns = AtomicU64::new(0);
    let col_ns = AtomicU64::new(0);

    let mats: Vec<(SendConstPtr, SendPtr, SendPtr)> = srcs
        .iter()
        .zip(dsts.iter_mut())
        .map(|(s, d)| {
            let dd: &mut SignalMatrix = &mut **d;
            (SendConstPtr(s.as_ptr()), SendPtr(dd.re.as_mut_ptr()), SendPtr(dd.im.as_mut_ptr()))
        })
        .collect();

    let mut dag = StageDag::new();
    for &(sp, dre, dim) in &mats {
        let mut row_ids = Vec::with_capacity(pipe.row_tiles.len());
        for &tile in &pipe.row_tiles {
            let errors = &errors;
            let row_ns = &row_ns;
            row_ids.push(dag.add(move || {
                // rebind the wrappers whole (2021 precise capture)
                let (sp, dre, dim) = (sp, dre, dim);
                // SAFETY: row tiles materialize `&mut` over their OWN
                // disjoint packed row ranges only (tiles partition the
                // rows; distinct matrices use distinct buffers); the
                // source plane is only read; column tasks are ordered
                // strictly after every row tile by DAG edges; run()
                // returns only after all tasks end, so the borrows the
                // pointers came from outlive every access.
                let (src_t, re_t, im_t) = unsafe {
                    (
                        std::slice::from_raw_parts(sp.0.add(tile.start * n), tile.len * n),
                        std::slice::from_raw_parts_mut(dre.0.add(tile.start * nc), tile.len * nc),
                        std::slice::from_raw_parts_mut(dim.0.add(tile.start * nc), tile.len * nc),
                    )
                };
                let t0 = Instant::now();
                let r = r2c_tile_engine(engine, src_t, re_t, im_t, tile.len, n, tile.fft_len, 1);
                row_ns.fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
                if let Err(e) = r {
                    errors.lock().unwrap().push(e);
                }
            }));
        }
        // a no-op join keeps the edge count O(R + C), not R·C
        let join = dag.add(|| {});
        for id in row_ids {
            dag.add_edge(id, join);
        }
        for &tile in &col_tiles {
            let errors = &errors;
            let col_ns = &col_ns;
            let cid = dag.add(move || {
                let (dre, dim) = (dre, dim);
                let t0 = Instant::now();
                let r = col_tile_ffts_packed(engine, dre, dim, n, nc, tile);
                col_ns.fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
                if let Err(e) = r {
                    errors.lock().unwrap().push(e);
                }
            });
            dag.add_edge(join, cid);
        }
    }
    dag.run(ExecCtx::global(), workers);

    match errors.into_inner().unwrap().into_iter().next() {
        Some(e) => Err(e),
        None => Ok(PhaseTimings {
            row_s: row_ns.load(Ordering::Relaxed) as f64 * 1e-9,
            col_s: col_ns.load(Ordering::Relaxed) as f64 * 1e-9,
        }),
    }
}

fn barrier_real_batch(
    engine: &dyn RowFftEngine,
    plan: &PlannedTransform,
    srcs: &[&[f64]],
    dsts: &mut [&mut SignalMatrix],
    threads_per_group: usize,
) -> Result<PhaseTimings, EngineError> {
    let n = plan.n;
    let nc = half_cols(n);
    let d = &plan.d;
    let pad_lens = plan.pad_lens();
    let offsets = row_offsets(d);
    let mut row_s = 0.0;
    let mut col_s = 0.0;

    for (src, dst) in srcs.iter().zip(dsts.iter_mut()) {
        let t0 = Instant::now();
        // row phase: per-group jobs over disjoint packed row slices —
        // the same 32-row tiling (hence the same pairing) as the fused
        // path, so the two modes stay bit-identical
        {
            let dd: &mut SignalMatrix = &mut **dst;
            let mut re_rest: &mut [f64] = &mut dd.re;
            let mut im_rest: &mut [f64] = &mut dd.im;
            let mut slices: Vec<(&mut [f64], &mut [f64])> = Vec::with_capacity(d.len());
            for i in 0..d.len() {
                let len = (offsets[i + 1] - offsets[i]) * nc;
                let (re_here, re_next) = re_rest.split_at_mut(len);
                let (im_here, im_next) = im_rest.split_at_mut(len);
                re_rest = re_next;
                im_rest = im_next;
                slices.push((re_here, im_here));
            }
            let errors: Mutex<Vec<EngineError>> = Mutex::new(Vec::new());
            let mut jobs: Vec<Job> = Vec::with_capacity(d.len());
            for (i, (re, im)) in slices.into_iter().enumerate() {
                let rows = d[i];
                if rows == 0 {
                    continue;
                }
                let v = pad_lens[i];
                let off = offsets[i];
                let errors = &errors;
                let src: &[f64] = src;
                jobs.push(Box::new(move || {
                    let r = r2c_rows_engine(
                        engine,
                        &src[off * n..(off + rows) * n],
                        re,
                        im,
                        rows,
                        n,
                        v,
                        threads_per_group,
                    );
                    if let Err(e) = r {
                        errors.lock().unwrap().push(e);
                    }
                }));
            }
            ExecCtx::global().run_jobs(jobs);
            if let Some(e) = errors.into_inner().unwrap().into_iter().next() {
                return Err(e);
            }
        }
        row_s += t0.elapsed().as_secs_f64();

        let t0 = Instant::now();
        // column phase: transpose the packed rectangle out of place,
        // per-group (clipped to the packed width) padded row FFTs over
        // the transposed rows — the stored columns — transpose back
        let mut t = transposed(&**dst);
        {
            let errors: Mutex<Vec<EngineError>> = Mutex::new(Vec::new());
            let mut re_rest: &mut [f64] = &mut t.re;
            let mut im_rest: &mut [f64] = &mut t.im;
            let mut carved = 0usize;
            let mut jobs: Vec<Job> = Vec::new();
            for i in 0..d.len() {
                let start_c = offsets[i].min(nc);
                let end_c = (offsets[i] + d[i]).min(nc);
                if end_c <= start_c {
                    continue;
                }
                debug_assert_eq!(carved, start_c, "clipped group ranges must tile [0, nc)");
                let rows_c = end_c - start_c;
                let (re_here, re_next) = re_rest.split_at_mut(rows_c * n);
                let (im_here, im_next) = im_rest.split_at_mut(rows_c * n);
                re_rest = re_next;
                im_rest = im_next;
                carved = end_c;
                let v = pad_lens[i];
                let errors = &errors;
                jobs.push(Box::new(move || {
                    let r = if v == n {
                        engine.fft_rows(
                            re_here,
                            im_here,
                            rows_c,
                            n,
                            Direction::Forward,
                            threads_per_group,
                        )
                    } else {
                        fft_rows_padded(engine, re_here, im_here, rows_c, n, v, threads_per_group)
                    };
                    if let Err(e) = r {
                        errors.lock().unwrap().push(e);
                    }
                }));
            }
            ExecCtx::global().run_jobs(jobs);
            if let Some(e) = errors.into_inner().unwrap().into_iter().next() {
                return Err(e);
            }
        }
        **dst = transposed(&t);
        col_s += t0.elapsed().as_secs_f64();
    }
    Ok(PhaseTimings { row_s, col_s })
}

/// Execute a planned real transform over one matrix, allocating the
/// packed output.
pub fn rfft_planned_with_mode(
    engine: &dyn RowFftEngine,
    plan: &PlannedTransform,
    src: &RealMatrix,
    threads_per_group: usize,
    mode: PipelineMode,
) -> Result<SignalMatrix, EngineError> {
    assert_eq!((src.rows, src.cols), (plan.n, plan.n), "square real input required");
    let mut out = SignalMatrix::zeros(plan.n, half_cols(plan.n));
    {
        let srcs: Vec<&[f64]> = vec![&src.data[..]];
        let mut dst_refs: Vec<&mut SignalMatrix> = vec![&mut out];
        execute_real_batch_with_mode(engine, plan, &srcs, &mut dst_refs, threads_per_group, mode)?;
    }
    Ok(out)
}

/// PFFT-FPM over a real signal: FPM-optimal distribution `d`, exact row
/// length, Hermitian-packed output. The real variant of
/// [`crate::coordinator::pfft::pfft_fpm`].
pub fn pfft_fpm_real_with_mode(
    engine: &dyn RowFftEngine,
    src: &RealMatrix,
    d: &[usize],
    threads_per_group: usize,
    mode: PipelineMode,
) -> Result<SignalMatrix, EngineError> {
    let n = src.rows;
    let plan = PlannedTransform {
        n,
        d: d.to_vec(),
        pads: trivial_pads(d.len(), n),
        // label only — the caller supplied d, whatever produced it
        algorithm: Algorithm::Balanced,
        makespan: f64::NAN,
        kind: TransformKind::R2c,
    };
    rfft_planned_with_mode(engine, &plan, src, threads_per_group, mode)
}

/// [`pfft_fpm_real_with_mode`] under the process-wide default mode.
pub fn pfft_fpm_real(
    engine: &dyn RowFftEngine,
    src: &RealMatrix,
    d: &[usize],
    threads_per_group: usize,
) -> Result<SignalMatrix, EngineError> {
    pfft_fpm_real_with_mode(engine, src, d, threads_per_group, default_mode())
}

/// PFFT-FPM-PAD over a real signal: per-group padded pair FFTs (the
/// forward-only spectral-interpolation semantics of the c2c driver,
/// halved). The real variant of
/// [`crate::coordinator::pfft::pfft_fpm_pad`].
pub fn pfft_fpm_pad_real_with_mode(
    engine: &dyn RowFftEngine,
    src: &RealMatrix,
    d: &[usize],
    pads: &[PadDecision],
    threads_per_group: usize,
    mode: PipelineMode,
) -> Result<SignalMatrix, EngineError> {
    let n = src.rows;
    let plan = PlannedTransform {
        n,
        d: d.to_vec(),
        pads: pads.to_vec(),
        algorithm: Algorithm::Balanced,
        makespan: f64::NAN,
        kind: TransformKind::R2c,
    };
    rfft_planned_with_mode(engine, &plan, src, threads_per_group, mode)
}

/// [`pfft_fpm_pad_real_with_mode`] under the process-wide default mode.
pub fn pfft_fpm_pad_real(
    engine: &dyn RowFftEngine,
    src: &RealMatrix,
    d: &[usize],
    pads: &[PadDecision],
    threads_per_group: usize,
) -> Result<SignalMatrix, EngineError> {
    pfft_fpm_pad_real_with_mode(engine, src, d, pads, threads_per_group, default_mode())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::NativeEngine;
    use crate::dft::dft2d::dft2d_with_mode;
    use crate::dft::real::{crop_to_packed, embed_real};

    fn oracle_packed(m: &RealMatrix) -> SignalMatrix {
        let mut full = embed_real(m);
        dft2d_with_mode(&mut full, Direction::Forward, 1, PipelineMode::Barrier);
        crop_to_packed(&full)
    }

    #[test]
    fn planned_real_matches_oracle_and_modes_bitwise() {
        let n = 48;
        let m = RealMatrix::random(n, n, 3);
        let d = vec![20usize, 17, 11]; // imbalanced FPM-style distribution
        let fused = pfft_fpm_real_with_mode(&NativeEngine, &m, &d, 2, PipelineMode::Fused).unwrap();
        let barrier =
            pfft_fpm_real_with_mode(&NativeEngine, &m, &d, 2, PipelineMode::Barrier).unwrap();
        assert_eq!(fused.max_abs_diff(&barrier), 0.0, "fused must be bit-exact vs barrier");
        let want = oracle_packed(&m);
        let err = fused.max_abs_diff(&want) / want.norm().max(1.0);
        assert!(err < 1e-9, "rel err {err}");
    }

    #[test]
    fn padded_real_matches_padded_c2c_oracle() {
        let n = 48;
        let m = RealMatrix::random(n, n, 5);
        let d = vec![28usize, 20];
        let pads = vec![
            PadDecision { n_padded: n, t_unpadded: 1.0, t_padded: 1.0 },
            PadDecision { n_padded: 60, t_unpadded: 1.0, t_padded: 0.5 },
        ];
        let fused =
            pfft_fpm_pad_real_with_mode(&NativeEngine, &m, &d, &pads, 1, PipelineMode::Fused)
                .unwrap();
        let barrier =
            pfft_fpm_pad_real_with_mode(&NativeEngine, &m, &d, &pads, 1, PipelineMode::Barrier)
                .unwrap();
        assert_eq!(fused.max_abs_diff(&barrier), 0.0, "padded fused must be bit-exact");
        // c2c oracle: the padded complex driver on the embedded input,
        // cropped to the stored columns
        let mut full = embed_real(&m);
        crate::coordinator::pfft::pfft_fpm_pad_with_mode(
            &NativeEngine,
            &mut full,
            &d,
            &pads,
            1,
            64,
            PipelineMode::Barrier,
        )
        .unwrap();
        let want = crop_to_packed(&full);
        let err = fused.max_abs_diff(&want) / want.norm().max(1.0);
        assert!(err < 1e-9, "rel err {err}");
    }

    #[test]
    fn batch_matches_singles_bitwise() {
        let n = 32;
        let d = vec![18usize, 14];
        let plan = PlannedTransform {
            n,
            d: d.clone(),
            pads: trivial_pads(2, n),
            algorithm: Algorithm::Balanced,
            makespan: f64::NAN,
            kind: TransformKind::R2c,
        };
        let ms: Vec<RealMatrix> = (0..3).map(|s| RealMatrix::random(n, n, 60 + s)).collect();
        let singles: Vec<SignalMatrix> = ms
            .iter()
            .map(|m| {
                rfft_planned_with_mode(&NativeEngine, &plan, m, 1, PipelineMode::Fused).unwrap()
            })
            .collect();
        let mut outs: Vec<SignalMatrix> =
            (0..3).map(|_| SignalMatrix::zeros(n, half_cols(n))).collect();
        {
            let srcs: Vec<&[f64]> = ms.iter().map(|m| &m.data[..]).collect();
            let mut dst_refs: Vec<&mut SignalMatrix> = outs.iter_mut().collect();
            let t = execute_real_batch_with_mode(
                &NativeEngine,
                &plan,
                &srcs,
                &mut dst_refs,
                2,
                PipelineMode::Fused,
            )
            .unwrap();
            assert!(t.row_s >= 0.0 && t.col_s >= 0.0);
        }
        for (b, s) in outs.iter().zip(&singles) {
            assert_eq!(b.max_abs_diff(s), 0.0);
        }
    }

    #[test]
    fn zero_row_groups_allowed() {
        let n = 16;
        let m = RealMatrix::random(n, n, 4);
        let got =
            pfft_fpm_real_with_mode(&NativeEngine, &m, &[0, 16, 0], 1, PipelineMode::Fused)
                .unwrap();
        let want = oracle_packed(&m);
        let err = got.max_abs_diff(&want) / want.norm().max(1.0);
        assert!(err < 1e-9, "rel err {err}");
    }

    #[test]
    #[should_panic(expected = "c2c plans execute")]
    fn c2c_plan_rejected() {
        let n = 8;
        let m = RealMatrix::random(n, n, 1);
        let plan = PlannedTransform::balanced_fallback(2, n);
        let _ = rfft_planned_with_mode(&NativeEngine, &plan, &m, 1, PipelineMode::Fused);
    }
}
