//! `hclfft` CLI — the L3 coordinator entrypoint.
//!
//! Subcommands (see `hclfft help`):
//! * `plan`     — FPM-based row partitioning (POPTA/HPOPTA) + pad lengths
//! * `run`      — execute a 2D-DFT with PFFT-LB / PFFT-FPM / PFFT-FPM-PAD
//! * `profile`  — build speed functions for a real engine (FPM dump)
//! * `figures`  — regenerate the paper's figures/tables
//! * `simulate` — virtual-testbed campaign summary
//! * `bench`    — `run` measured with the MeanUsingTtest methodology
//! * `serve-bench` — load generator against the in-process 2D-DFT
//!   service. `--mode closed` (default): each client waits for its
//!   response; cold + warm passes, model calibration, the
//!   `BENCH_serve.json` trajectory, optional `--drift-factor` speed
//!   shift. `--mode open`: open-loop fixed/Poisson arrivals against the
//!   sharded front end (`serve` module) — latency from arrival,
//!   bounded admission sheds under overload, model routing vs
//!   round-robin (deterministic in virtual time for sim-* engines)
//! * `serve-net` — TCP serving front end (`--listen`) and its blocking
//!   client (`--connect`): length-prefixed binary frames over
//!   `std::net`, typed error codes, drain-on-shutdown
//! * `wisdom`   — inspect / prewarm the persistent planning wisdom
//! * `model`    — inspect the online performance model (sections,
//!   sample counts, drift events)

use std::path::{Path, PathBuf};

use hclfft::cli;
use hclfft::config::Config;
use hclfft::coordinator::engine::{
    BuiltEngine, EngineId, EngineRegistry, NativeEngine, RowFftEngine,
};
use hclfft::coordinator::group::GroupConfig;
use hclfft::coordinator::pad::PadCost;
use hclfft::coordinator::pfft::{
    pfft_fpm, pfft_fpm_pad, pfft_fpm_pad_real, pfft_fpm_real, pfft_lb,
};
use hclfft::coordinator::PlannedTransform;
use hclfft::dft::real::{crop_to_packed, embed_real, RealMatrix, TransformKind};
use hclfft::dft::SignalMatrix;
use hclfft::figures::{generate, generate_all, Ctx};
use hclfft::model::PerfModel;
use hclfft::profiler::{build_fpms, ProfileSpec};
use hclfft::simulator::vexec::{Campaign, CampaignSummary};
use hclfft::simulator::Package;
use hclfft::stats::{mean_using_ttest, TtestPolicy};

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let code = match run(&argv) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            eprintln!("run `hclfft help` for usage");
            1
        }
    };
    std::process::exit(code);
}

fn run(argv: &[String]) -> Result<(), String> {
    let args = match cli::parse(argv) {
        Ok(a) => a,
        Err(e) => {
            if argv.is_empty() {
                println!("{}", cli::help());
                return Ok(());
            }
            return Err(e);
        }
    };
    let cfg = Config::load(args.opt("config").map(Path::new))?;
    match args.subcommand.as_str() {
        "help" => {
            println!("{}", cli::help());
            Ok(())
        }
        "plan" => cmd_plan(&args, &cfg),
        "run" => cmd_run(&args, &cfg, false),
        "bench" => cmd_run(&args, &cfg, true),
        "profile" => cmd_profile(&args, &cfg),
        "figures" => cmd_figures(&args, &cfg),
        "simulate" => cmd_simulate(&args),
        "serve-bench" => cmd_serve_bench(&args, &cfg),
        "serve-net" => cmd_serve_net(&args, &cfg),
        "wisdom" => cmd_wisdom(&args, &cfg),
        "model" => cmd_model(&args),
        other => Err(format!("unknown subcommand `{other}`")),
    }
}

fn cmd_plan(args: &cli::Args, cfg: &Config) -> Result<(), String> {
    args.validate(&["n", "p", "eps", "package", "pad", "source", "config"])?;
    let n = args.opt_usize("n")?.ok_or("--n required")?;
    let pkg = Package::parse(&args.opt_or("package", "mkl")).ok_or("bad --package")?;
    let p = args.opt_usize("p")?.unwrap_or(pkg.best_groups().p);
    let eps = args.opt_f64("eps")?.unwrap_or(cfg.eps);

    // the planning consumers read sections through the PerfModel trait
    let model = hclfft::model::SimModel::new(pkg, GroupConfig::new(p, 36 / p.max(1)));
    let curves: Vec<_> = (0..p).map(|g| model.plane_section(g, n)).collect();
    let identical = hclfft::coordinator::partition::curves_identical(&curves, eps);
    let part = if identical {
        let avg = hclfft::coordinator::partition::average_curve(&curves);
        hclfft::coordinator::partition::popta(&avg, p, n - n % 128)
    } else {
        hclfft::coordinator::partition::hpopta(&curves, n - n % 128)
    }
    .map_err(|e| e.to_string())?;

    println!("package: {} | N = {n} | p = {p} | eps = {eps}", pkg.name());
    println!(
        "identity test: curves {} => {}",
        if identical { "identical" } else { "heterogeneous" },
        if identical { "POPTA (averaged)" } else { "HPOPTA" }
    );
    println!("distribution d = {:?} (makespan {:.4})", part.d, part.makespan);
    if args.flag("pad") {
        for (i, &di) in part.d.iter().enumerate() {
            if di == 0 {
                continue;
            }
            let col = model.column_section(i, di, n, hclfft::simulator::vexec::PAD_WINDOW);
            let dec = hclfft::coordinator::pad::determine_pad_length(
                &col,
                di,
                n,
                PadCost::PaperRatio,
            );
            println!(
                "group{}: N_padded = {} (predicted gain {:.1}%)",
                i + 1,
                dec.n_padded,
                100.0 * dec.n_padded_gain()
            );
        }
    }
    Ok(())
}

/// Build a real (executing) engine through the [`EngineRegistry`]
/// seam; sim-* and `portfolio` ids are serving-side concepts and are
/// rejected here with a pointer at the subcommands that drive them.
fn make_engine(
    id: EngineId,
    artifacts: &Path,
) -> Result<std::sync::Arc<dyn RowFftEngine + Send + Sync>, String> {
    match EngineRegistry::with_artifacts(artifacts).build(id)? {
        BuiltEngine::Real(e) => Ok(e),
        BuiltEngine::Virtual(_) => Err(format!(
            "engine `{id}` is a virtual-time backend; drive it with `serve-bench`/`simulate` \
             (run/bench/profile execute real FFTs)"
        )),
    }
}

/// Shared `--pipeline fused|barrier` parsing: sets the process-wide
/// default mode every implicit entry point (drivers, dft2d) consults.
fn pipeline_from_args(args: &cli::Args) -> Result<hclfft::dft::pipeline::PipelineMode, String> {
    let mode = match args.opt("pipeline") {
        Some(v) => hclfft::dft::pipeline::PipelineMode::parse(v)
            .ok_or_else(|| format!("--pipeline must be `fused` or `barrier`, got `{v}`"))?,
        None => hclfft::dft::pipeline::default_mode(),
    };
    hclfft::dft::pipeline::set_default_mode(mode);
    Ok(mode)
}

/// Shared `--kind c2c|real` parsing (`real` = r2c: real signal in,
/// Hermitian-packed half spectrum out).
fn kind_from_args(args: &cli::Args) -> Result<TransformKind, String> {
    match args.opt("kind") {
        Some(v) => {
            let k = TransformKind::parse(v)
                .ok_or_else(|| format!("--kind must be `c2c` or `real`, got `{v}`"))?;
            if k == TransformKind::C2r {
                return Err(
                    "--kind c2r is the service inverse path; use `real` for forward r2c".into(),
                );
            }
            Ok(k)
        }
        None => Ok(TransformKind::C2c),
    }
}

fn cmd_run(args: &cli::Args, cfg: &Config, bench: bool) -> Result<(), String> {
    args.validate(&[
        "n", "engine", "algo", "p", "t", "artifacts", "verify", "config", "seed", "pipeline",
        "kind",
    ])?;
    let n = args.opt_usize("n")?.ok_or("--n required")?;
    let mode = pipeline_from_args(args)?;
    let kind = kind_from_args(args)?;
    let algo = args.opt_or("algo", "fpm");
    let p = args.opt_usize("p")?.unwrap_or(cfg.groups);
    let t = args.opt_usize("t")?.unwrap_or(cfg.threads_per_group);
    let artifacts = args
        .opt("artifacts")
        .map(PathBuf::from)
        .unwrap_or_else(|| cfg.artifacts_dir.clone());
    let engine_id: EngineId = args.opt_or("engine", "native").parse()?;
    let engine = make_engine(engine_id, &artifacts)?;
    let seed = args.opt_usize("seed")?.unwrap_or(42) as u64;
    let grp = GroupConfig::new(p, t);

    // plan from measured plane (real FPM construction, scaled-down
    // reps), once, through the shared PlannedTransform seam — the same
    // value the service's wisdom store memoizes. Real-kind planes are
    // measured with the r2c pair kernel (their own ~2x-faster surfaces).
    let xs: Vec<usize> = (1..=8).map(|k| (k * n / 8).max(1)).collect();
    let fpms = hclfft::profiler::build_plane_kind(
        engine.as_ref(),
        grp,
        xs,
        n,
        cfg.rep_scale.max(100),
        kind,
    );
    let plan = PlannedTransform::from_fpms(&fpms, n, cfg.eps, Some(PadCost::PaperRatio))
        .map_err(|e| e.to_string())?
        .with_kind(kind);

    let mut exec = |label: &str| -> Result<f64, String> {
        if kind == TransformKind::R2c {
            let rm = RealMatrix::random(n, n, seed);
            let t0 = std::time::Instant::now();
            match label {
                // one group with the whole thread budget
                "basic" => {
                    pfft_fpm_real(engine.as_ref(), &rm, &[n], p * t).map_err(|e| e.to_string())?;
                }
                "lb" => {
                    let d = hclfft::coordinator::partition::balanced(p, n).d;
                    pfft_fpm_real(engine.as_ref(), &rm, &d, t).map_err(|e| e.to_string())?;
                }
                "fpm" => {
                    pfft_fpm_real(engine.as_ref(), &rm, &plan.d, t).map_err(|e| e.to_string())?;
                }
                "fpm-pad" => {
                    pfft_fpm_pad_real(engine.as_ref(), &rm, &plan.d, &plan.pads, t)
                        .map_err(|e| e.to_string())?;
                }
                other => return Err(format!("unknown algo `{other}`")),
            }
            return Ok(t0.elapsed().as_secs_f64());
        }
        let mut m = SignalMatrix::random(n, n, seed);
        let t0 = std::time::Instant::now();
        match label {
            "basic" => {
                // one group with the whole thread budget
                pfft_lb(engine.as_ref(), &mut m, GroupConfig::new(1, p * t), cfg.transpose_block)
                    .map_err(|e| e.to_string())?;
            }
            "lb" => {
                pfft_lb(engine.as_ref(), &mut m, grp, cfg.transpose_block)
                    .map_err(|e| e.to_string())?;
            }
            "fpm" => {
                pfft_fpm(engine.as_ref(), &mut m, &plan.d, t, cfg.transpose_block)
                    .map_err(|e| e.to_string())?;
            }
            "fpm-pad" => {
                pfft_fpm_pad(engine.as_ref(), &mut m, &plan.d, &plan.pads, t, cfg.transpose_block)
                    .map_err(|e| e.to_string())?;
            }
            other => return Err(format!("unknown algo `{other}`")),
        }
        Ok(t0.elapsed().as_secs_f64())
    };

    // which row kernel actually executes: the native engine dispatches
    // by length (mixed-radix for 5-smooth, Bluestein else) — with
    // padding, the row phases run at the *pad* lengths; other engines
    // bring their own kernels (PJRT executes pow2 AOT artifacts)
    let kernel = if engine_id == EngineId::Native {
        let lens = if algo == "fpm-pad" { plan.pad_lens() } else { vec![n] };
        kernel_label(&lens)
    } else {
        "engine-defined kernel".to_string()
    };
    let work_flops = hclfft::stats::harness::fft2d_flops(n) * kind.flops_factor();
    if bench {
        let policy = TtestPolicy { min_reps: 5, max_reps: 50, max_time_s: 30.0, cl: 0.95, eps: 0.025 };
        let m = mean_using_ttest(&policy, || exec(&algo).expect("bench run failed"));
        let mflops = work_flops / m.mean / 1e6;
        println!(
            "{} {} {} N={n} (p={p}, t={t}, {kernel}, {} pipeline): mean {:.6}s ± {:.6}s over {} reps ({:.1} MFLOPs)",
            engine.name(),
            kind.name(),
            algo,
            mode.name(),
            m.mean,
            m.ci_half_width,
            m.reps,
            mflops
        );
    } else {
        let secs = exec(&algo)?;
        let mflops = work_flops / secs / 1e6;
        println!(
            "{} {} {} N={n} (p={p}, t={t}, {kernel}, {} pipeline): {:.6}s ({:.1} MFLOPs), d = {:?}",
            engine.name(),
            kind.name(),
            algo,
            mode.name(),
            secs,
            mflops,
            plan.d
        );
    }

    if args.flag("verify") {
        if kind == TransformKind::R2c {
            // real path vs the c2c oracle: 2D-DFT of the real embedding,
            // cropped to the stored half-spectrum columns
            let rm = RealMatrix::random(n, n, seed);
            let got = pfft_fpm_real(engine.as_ref(), &rm, &plan.d, t).map_err(|e| e.to_string())?;
            let mut reference = embed_real(&rm);
            hclfft::dft::dft2d::dft2d(&mut reference, hclfft::dft::fft::Direction::Forward, 1);
            let want = crop_to_packed(&reference);
            let err = got.max_abs_diff(&want) / want.norm().max(1.0);
            println!("verify r2c vs c2c oracle (real-embedded input): rel err {err:.3e}");
            if err > 1e-3 {
                return Err(format!("verification failed: rel err {err}"));
            }
        } else {
            let mut m = SignalMatrix::random(n, n, seed);
            pfft_fpm(engine.as_ref(), &mut m, &plan.d, t, cfg.transpose_block)
                .map_err(|e| e.to_string())?;
            let mut reference = SignalMatrix::random(n, n, seed);
            hclfft::dft::dft2d::dft2d(&mut reference, hclfft::dft::fft::Direction::Forward, 1);
            let err = m.max_abs_diff(&reference) / reference.norm().max(1.0);
            println!("verify vs native serial 2D-DFT: rel err {err:.3e}");
            if err > 1e-3 {
                return Err(format!("verification failed: rel err {err}"));
            }
        }
    }
    Ok(())
}

fn cmd_profile(args: &cli::Args, cfg: &Config) -> Result<(), String> {
    args.validate(&["engine", "n-list", "x-list", "p", "t", "out", "scale", "artifacts", "config", "budget"])?;
    let ys = parse_csv_usize(&args.opt_or("n-list", "128,256,512"))?;
    let max_y = *ys.iter().max().unwrap_or(&512);
    let xs = match args.opt("x-list") {
        Some(s) => parse_csv_usize(s)?,
        None => (1..=4).map(|k| k * max_y / 4).collect(),
    };
    let p = args.opt_usize("p")?.unwrap_or(cfg.groups);
    let t = args.opt_usize("t")?.unwrap_or(cfg.threads_per_group);
    let artifacts = args
        .opt("artifacts")
        .map(PathBuf::from)
        .unwrap_or_else(|| cfg.artifacts_dir.clone());
    let engine = make_engine(args.opt_or("engine", "native").parse::<EngineId>()?, &artifacts)?;
    let mut spec = ProfileSpec::new(xs, ys, GroupConfig::new(p, t));
    spec.rep_scale = args.opt_usize("scale")?.unwrap_or(cfg.rep_scale);
    if let Some(b) = args.opt_f64("budget")? {
        spec.budget_s = b;
    }

    let fpms = build_fpms(engine.as_ref(), &spec);
    let out_base = args.opt_or("out", "results/fpm");
    for (g, fpm) in fpms.iter().enumerate() {
        let path = PathBuf::from(format!("{out_base}_group{}.tsv", g + 1));
        fpm.write_tsv(&path).map_err(|e| e.to_string())?;
        println!(
            "group{}: {} points -> {}",
            g + 1,
            fpm.measured_points(),
            path.display()
        );
    }
    Ok(())
}

fn cmd_figures(args: &cli::Args, cfg: &Config) -> Result<(), String> {
    args.validate(&["fig", "all", "out-dir", "quick", "artifacts", "config"])?;
    let out_dir = PathBuf::from(args.opt_or("out-dir", cfg.results_dir.to_str().unwrap_or("results")));
    std::fs::create_dir_all(&out_dir).map_err(|e| e.to_string())?;
    let mut ctx = Ctx::new(&out_dir, args.flag("quick"));
    if let Some(a) = args.opt("artifacts") {
        ctx.artifacts_dir = PathBuf::from(a);
    }
    let text = if args.flag("all") {
        generate_all(&ctx)?
    } else {
        let id = args.opt("fig").ok_or("--fig <id> or --all required")?;
        generate(id, &ctx)?
    };
    println!("{text}");
    Ok(())
}

fn parse_csv_usize(s: &str) -> Result<Vec<usize>, String> {
    s.split(',')
        .map(|v| v.trim().parse().map_err(|_| format!("bad list item `{v}`")))
        .collect()
}

/// Kernel summary over the distinct row lengths a plan executes
/// (padded groups run their pad length, not N).
fn kernel_label(lens: &[usize]) -> String {
    let mut lens: Vec<usize> = lens.to_vec();
    lens.sort_unstable();
    lens.dedup();
    let parts: Vec<String> =
        lens.iter().map(|&l| hclfft::dft::radix::kernel_summary(l)).collect();
    parts.join(" | ")
}

/// Kernel description for a wisdom record: the native kernels its row
/// phases actually execute, or a non-kernel marker for virtual /
/// artifact-backed engines.
fn record_kernel(rec: &hclfft::service::wisdom::WisdomRecord) -> String {
    if rec.engine.is_sim() {
        return "virtual".to_string();
    }
    if rec.engine != EngineId::Native {
        return "engine-defined".to_string();
    }
    kernel_label(&rec.plan.pad_lens())
}

/// `sim-<pkg>` engine names resolve to a virtual-testbed package (via
/// [`EngineId::parse`], so every package alias the typed layer accepts
/// works here too); anything else returns Ok(None). Bad `sim-`
/// suffixes are errors.
fn sim_package(engine: &str) -> Result<Option<Package>, String> {
    if !engine.starts_with("sim-") {
        return Ok(None);
    }
    EngineId::parse(engine)
        .and_then(|id| id.package())
        .map(Some)
        .ok_or_else(|| format!("unknown simulator package `{engine}`"))
}

/// The shared `--p/--t/--pad/--budget` → PlanningConfig plumbing of
/// `serve-bench` and `wisdom`.
fn planning_from_args(
    args: &cli::Args,
    cfg: &Config,
) -> Result<hclfft::service::wisdom::PlanningConfig, String> {
    Ok(hclfft::service::wisdom::PlanningConfig {
        groups: args.opt_usize("p")?.unwrap_or(cfg.groups),
        threads_per_group: args.opt_usize("t")?.unwrap_or(cfg.threads_per_group),
        eps: cfg.eps,
        pad_cost: args.flag("pad").then_some(PadCost::PaperRatio),
        profile_budget_s: args.opt_f64("budget")?.unwrap_or(1.5),
        ..hclfft::service::wisdom::PlanningConfig::default()
    })
}

/// The calibrated sim-* members `--engine portfolio` registers. Their
/// crossover structure (MKL wins small sizes, FFTW3 large ones) is what
/// makes per-`(n, kind)` engine selection non-trivial.
const PORTFOLIO_MEMBERS: [EngineId; 3] = [
    EngineId::Sim(Package::Fftw2),
    EngineId::Sim(Package::Fftw3),
    EngineId::Sim(Package::Mkl),
];

/// Register the backend(s) for one `--engine` id through the
/// [`EngineRegistry`] seam: real/sim ids map to a single backend;
/// `portfolio` registers every sim-* member and enables portfolio
/// planning, so admission resolves each request to the fastest member
/// per `(n, kind)`.
fn service_builder_for_engine(
    builder: hclfft::service::ServiceBuilder,
    registry: &EngineRegistry,
    id: EngineId,
) -> Result<hclfft::service::ServiceBuilder, String> {
    if id == EngineId::Portfolio {
        let mut b = builder;
        for m in PORTFOLIO_MEMBERS {
            b = b.engine_id(registry, m)?;
        }
        return Ok(b.portfolio(PORTFOLIO_MEMBERS.to_vec()));
    }
    builder.engine_id(registry, id)
}

fn cmd_serve_bench(args: &cli::Args, cfg: &Config) -> Result<(), String> {
    use hclfft::service::{Dft2dRequest, ServiceBuilder, ServiceConfig};

    args.validate(&[
        "n", "requests", "clients", "engine", "p", "t", "workers", "batch", "wisdom",
        "no-wisdom", "pad", "starve", "budget", "seed", "config", "drift-factor", "json",
        "no-json", "pipeline", "kind", "mode", "rate", "arrivals", "shards", "capacity",
        "route", "slowdowns", "reps",
    ])?;
    match args.opt_or("mode", "closed").as_str() {
        "closed" => {}
        "open" => return cmd_serve_bench_open(args, cfg),
        other => return Err(format!("unknown --mode `{other}` (closed|open)")),
    }
    let pipeline = pipeline_from_args(args)?;
    let kind = kind_from_args(args)?;
    let ns = parse_csv_usize(&args.opt_or("n", "1024"))?;
    if ns.is_empty() {
        return Err("--n requires at least one size".into());
    }
    let requests = args.opt_usize("requests")?.unwrap_or(64).max(1);
    let clients = args.opt_usize("clients")?.unwrap_or(8).max(1);
    let reps = args.opt_usize("reps")?.unwrap_or(1).max(1);
    let engine: EngineId = args.opt_or("engine", "native").parse()?;
    let seed = args.opt_usize("seed")?.unwrap_or(42) as u64;
    let portfolio = engine == EngineId::Portfolio;
    // the portfolio's members are sim-* backends: priced in virtual time
    let virtual_engine = engine.is_sim() || portfolio;
    if kind.is_real() && virtual_engine {
        return Err("--kind real requires a real engine (sim-* backends price c2c only)".into());
    }
    if virtual_engine && (args.opt("p").is_some() || args.opt("t").is_some()) {
        eprintln!(
            "note: sim-* engines pin their package's paper-best (p, t); --p/--t are ignored"
        );
    }
    let drift_factor = args.opt_f64("drift-factor")?;
    if let Some(f) = drift_factor {
        if !virtual_engine {
            return Err("--drift-factor requires a sim-* or portfolio engine (virtual time)".into());
        }
        if !(f.is_finite() && f > 0.0) {
            return Err("--drift-factor must be a positive number".into());
        }
    }

    let planning = planning_from_args(args, cfg)?;
    let scfg = ServiceConfig {
        workers: args.opt_usize("workers")?.unwrap_or(2).max(1),
        max_batch: args.opt_usize("batch")?.unwrap_or(8).max(1),
        starvation_bound_s: args.opt_f64("starve")?.unwrap_or(5.0),
        transpose_block: cfg.transpose_block,
        pipeline,
        planning,
        ..ServiceConfig::default()
    };

    let wisdom_path = if args.flag("no-wisdom") {
        None
    } else {
        Some(PathBuf::from(args.opt_or("wisdom", "results/wisdom.json")))
    };

    let workers = scfg.workers;
    let max_batch = scfg.max_batch;
    let registry = EngineRegistry::new();
    let mut builder = service_builder_for_engine(ServiceBuilder::new(scfg), &registry, engine)?;
    if let Some(path) = wisdom_path.as_ref().filter(|p| p.exists()) {
        builder = builder.load_wisdom(path)?;
    }
    let svc = builder.build();
    if let Some(path) = &wisdom_path {
        println!(
            "wisdom: {} record(s) available from {}",
            svc.wisdom_snapshot().len(),
            path.display()
        );
    }

    println!(
        "serve-bench: engine {engine} | kind {} | sizes {ns:?} | {requests} requests/pass x \
         (1 cold + {reps} warm) passes | {clients} clients | {workers} workers | max batch \
         {max_batch} | {} pipeline | exec pool {} thread(s)",
        kind.name(),
        pipeline.name(),
        hclfft::dft::exec::ExecCtx::global().workers()
    );

    // one closed-loop pass: each client owns its share of the request
    // budget and waits for every response before the next send
    let engine_str: &str = engine.as_str();
    let run_pass = |pass: u64| -> Vec<String> {
        let failures: std::sync::Mutex<Vec<String>> = std::sync::Mutex::new(Vec::new());
        std::thread::scope(|scope| {
            for c in 0..clients {
                let svc = &svc;
                let ns = &ns;
                let failures = &failures;
                scope.spawn(move || {
                    let mine = requests / clients + usize::from(c < requests % clients);
                    for i in 0..mine {
                        let n = ns[(c + i) % ns.len()];
                        let req = if virtual_engine {
                            Dft2dRequest::probe(engine_str, n)
                        } else {
                            // hash (seed, pass, client, i): collision-free
                            // regardless of request division
                            let mseed = hclfft::util::prng::hash_key(&[
                                seed, pass, c as u64, i as u64,
                            ]);
                            if kind == TransformKind::R2c {
                                Dft2dRequest::real_forward(
                                    engine_str,
                                    hclfft::dft::SignalMatrix::random_real(n, n, mseed),
                                )
                            } else {
                                Dft2dRequest::forward(
                                    engine_str,
                                    hclfft::dft::SignalMatrix::random(n, n, mseed),
                                )
                            }
                        };
                        let outcome = svc.submit(req).and_then(|h| h.wait());
                        if let Err(e) = outcome {
                            failures.lock().unwrap().push(e.to_string());
                        }
                    }
                });
            }
        });
        failures.into_inner().unwrap()
    };

    // cold pass (plans, first observations), then `--reps` warm passes
    // (memoized wisdom; the --drift-factor speed shift is injected in
    // between so the warm passes exercise drift detection +
    // re-planning). Each warm repetition gets its own stats window —
    // cross-repetition scatter is the run-to-run variance the t-CI
    // summarizes below.
    svc.stats_mark();
    let mut failures = run_pass(0);
    let cold = svc.stats_since_mark();
    println!("{}", cold.render_table(&format!("serve-bench {engine} — cold pass")));
    if let Some(f) = drift_factor {
        if portfolio {
            // slow the incumbent member(s) the cold pass settled on: their
            // drift detectors fire in the warm pass and the portfolio must
            // re-pick toward an unslowed member
            let mut incumbents: Vec<EngineId> =
                svc.portfolio_picks().into_iter().map(|(_, _, m)| m).collect();
            incumbents.sort_unstable();
            incumbents.dedup();
            if incumbents.len() == PORTFOLIO_MEMBERS.len() {
                // keep at least one member unslowed so a strictly faster
                // alternative exists to re-pick onto
                incumbents.pop();
            }
            for m in incumbents {
                println!(
                    "injecting virtual machine slowdown x{f} on incumbent {m} before the warm pass"
                );
                svc.set_virtual_slowdown(m.as_str(), f);
            }
        } else {
            println!("injecting virtual machine slowdown x{f} before the warm pass");
            svc.set_virtual_slowdown(engine.as_str(), f);
        }
    }
    let mut warm_reps: Vec<hclfft::service::stats::ServiceStats> = Vec::with_capacity(reps);
    for r in 0..reps {
        svc.stats_mark();
        failures.extend(run_pass(1 + r as u64));
        warm_reps.push(svc.stats_since_mark());
    }
    let warm = warm_reps.last().expect("reps >= 1").clone();
    println!("{}", warm.render_table(&format!("serve-bench {engine} — warm pass")));
    // cross-repetition variance: per-rep p50/p95 as mean ± 95% two-sided
    // Student-t half-width over the `--reps` independent warm windows
    let warm_ci = (reps >= 2).then(|| {
        let p50s: Vec<f64> = warm_reps.iter().map(|s| s.latency_p50_s * 1e3).collect();
        let p95s: Vec<f64> = warm_reps.iter().map(|s| s.latency_p95_s * 1e3).collect();
        (mean_t_ci(&p50s), mean_t_ci(&p95s))
    });
    if let Some(((p50m, p50h), (p95m, p95h))) = warm_ci {
        println!(
            "warm variance over {reps} repetitions: latency p50 {p50m:.3} ± {p50h:.3} ms, \
             p95 {p95m:.3} ± {p95h:.3} ms (95% Student-t CI)"
        );
    }

    if portfolio {
        for (n, k, m) in svc.portfolio_picks() {
            println!("portfolio: n {n} {} -> {m}", k.name());
        }
        for ev in svc.portfolio_repicks() {
            println!("portfolio re-pick after drift: {ev}");
        }
    }

    let total = svc.stats();
    let model = svc.model_snapshot(&hclfft::service::model_key(engine.as_str(), kind));
    let (obs, points) = model.as_ref().map_or((0, 0), |m| (m.observations(), m.len()));
    println!(
        "planning: {} cold event(s), {} warm wisdom hit(s)",
        total.planning_events, total.wisdom_hits
    );
    println!(
        "model: {obs} observation(s) over {points} point(s), {} drift event(s), \
         calibration err mean {} (cold) -> {} (warm)",
        total.drift_events,
        fmt_pct(cold.calibration_mean_err, cold.calibration_batches),
        fmt_pct(warm.calibration_mean_err, warm.calibration_batches),
    );
    for f in &failures {
        eprintln!("request failed: {f}");
    }

    if !args.flag("no-json") {
        let json_path = PathBuf::from(args.opt_or("json", "BENCH_serve.json"));
        let mut doc = hclfft::util::json::Json::obj()
            .set("bench", "serve")
            .set("engine", engine.as_str())
            .set("kind", kind.name())
            .set("sizes", ns.clone())
            .set("requests_per_pass", requests)
            .set("clients", clients)
            .set("reps", reps)
            .set("workers", workers)
            .set("max_batch", max_batch)
            .set("pipeline", pipeline.name())
            .set(
                "drift_factor",
                drift_factor.map(hclfft::util::json::Json::Num).unwrap_or(
                    hclfft::util::json::Json::Null,
                ),
            )
            .set("cold", phase_json(&cold))
            .set("warm", phase_json(&warm))
            .set(
                "warm_reps",
                hclfft::util::json::Json::Arr(warm_reps.iter().map(phase_json).collect()),
            )
            .set("drift_events", total.drift_events as i64)
            .set("model_observations", obs as i64)
            .set("model_points", points as i64);
        if let Some(((p50m, p50h), (p95m, p95h))) = warm_ci {
            doc = doc.set(
                "warm_ci",
                hclfft::util::json::Json::obj()
                    .set("p50_ms_mean", p50m)
                    .set("p50_ms_hw", p50h)
                    .set("p95_ms_mean", p95m)
                    .set("p95_ms_hw", p95h),
            );
        }
        if let Some(dir) = json_path.parent().filter(|d| !d.as_os_str().is_empty()) {
            std::fs::create_dir_all(dir).map_err(|e| e.to_string())?;
        }
        std::fs::write(&json_path, doc.to_pretty())
            .map_err(|e| format!("cannot write {}: {e}", json_path.display()))?;
        println!("bench trajectory written to {}", json_path.display());
    }

    if let Some(path) = &wisdom_path {
        svc.save_wisdom(path)?;
        println!(
            "wisdom: saved {} record(s) + model deltas to {} (rerun to serve fully warm)",
            svc.wisdom_snapshot().len(),
            path.display()
        );
    }
    svc.shutdown();
    if !failures.is_empty() {
        return Err(format!(
            "{} of {} request(s) failed",
            failures.len(),
            (1 + reps) * requests
        ));
    }
    Ok(())
}

fn parse_csv_f64(s: &str) -> Result<Vec<f64>, String> {
    s.split(',')
        .map(|v| v.trim().parse().map_err(|_| format!("bad list item `{v}`")))
        .collect()
}

/// `serve-bench --mode open`: open-loop arrivals (fixed/Poisson)
/// against a sharded front end, latency measured from arrival. sim-*
/// engines run the deterministic virtual-time harness (real router
/// placement over modeled shards, `--slowdowns` heterogeneity, exact
/// reproducibility); `native` drives a live [`hclfft::serve`] front on
/// the wall clock and then needs an explicit `--rate`.
fn cmd_serve_bench_open(args: &cli::Args, cfg: &Config) -> Result<(), String> {
    use hclfft::serve::{
        run_open_loop, run_virtual_open_loop, Arrivals, FrontBuilder, FrontConfig,
        OpenLoopReport, OpenLoopSpec, RoutePolicy, VirtualShard, VirtualSpec,
    };
    use hclfft::service::{Dft2dRequest, ServiceBuilder, ServiceConfig};

    let kind = kind_from_args(args)?;
    let ns = parse_csv_usize(&args.opt_or("n", "1024"))?;
    if ns.is_empty() {
        return Err("--n requires at least one size".into());
    }
    let requests = args.opt_usize("requests")?.unwrap_or(200).max(1);
    let engine: EngineId = args.opt_or("engine", "sim-mkl").parse()?;
    if engine == EngineId::Portfolio {
        return Err(
            "--mode open drives one engine per run; portfolio planning is the closed-loop \
             serve-bench (omit --mode)"
                .into(),
        );
    }
    let seed = args.opt_usize("seed")?.unwrap_or(42) as u64;
    let shard_count = args.opt_usize("shards")?.unwrap_or(2).max(1);
    let capacity = args.opt_usize("capacity")?.unwrap_or(8).max(1);
    let route = args.opt_or("route", "both");
    let policies: Vec<RoutePolicy> = if route == "both" {
        vec![RoutePolicy::ModelFinishTime, RoutePolicy::RoundRobin]
    } else {
        vec![RoutePolicy::parse(&route)
            .ok_or_else(|| format!("unknown --route `{route}` (model|round-robin|both)"))?]
    };
    let slowdowns: Vec<f64> = match args.opt("slowdowns") {
        Some(s) => parse_csv_f64(s)?,
        // heterogeneous by default: routing only matters when shards differ
        None => (0..shard_count).map(|i| 1.0 + 1.5 * i as f64).collect(),
    };
    if slowdowns.len() != shard_count {
        return Err(format!("--slowdowns needs exactly {shard_count} value(s)"));
    }
    if kind.is_real() && engine.is_sim() {
        return Err("--kind real requires a real engine (sim-* backends price c2c only)".into());
    }
    let rate_arg = args.opt_f64("rate")?;
    let arrivals_name = args.opt_or("arrivals", "poisson");

    let mut reports: Vec<OpenLoopReport> = Vec::new();
    if let Some(pkg) = engine.package() {
        let base: Vec<f64> = ns
            .iter()
            .map(|&n| hclfft::simulator::vexec::predict_point(pkg, n).t_fpm)
            .collect();
        let mean_cost = base.iter().sum::<f64>() / base.len() as f64;
        // aggregate service rate of the modeled shards; the default
        // offered rate doubles it — guaranteed overload, nonzero sheds
        let capacity_rps: f64 = slowdowns.iter().map(|s| 1.0 / (mean_cost * s)).sum();
        let rate = match rate_arg {
            Some(r) if r > 0.0 => r,
            _ => 2.0 * capacity_rps,
        };
        let arrivals =
            Arrivals::parse(&arrivals_name, rate, seed).ok_or("bad --arrivals (fixed|poisson)")?;
        let shards: Vec<VirtualShard> = (0..shard_count)
            .map(|j| {
                let true_s: Vec<f64> = base.iter().map(|b| b * slowdowns[j]).collect();
                // the router only sees beliefs; give them a deterministic
                // few-percent error so prediction is imperfect but useful
                let believed_s = true_s
                    .iter()
                    .enumerate()
                    .map(|(k, t)| {
                        let h = hclfft::util::prng::hash_key(&[seed, j as u64, k as u64]);
                        t * (1.0 + ((h % 1000) as f64 / 1000.0 - 0.5) * 0.06)
                    })
                    .collect();
                VirtualShard { name: format!("s{j}"), true_s, believed_s }
            })
            .collect();
        println!(
            "serve-bench open: engine {engine} | sizes {ns:?} | {requests} arrivals \
             ({} @ {rate:.1} rps vs ~{capacity_rps:.1} rps capacity) | {shard_count} shard(s) \
             slowdowns {slowdowns:?} | window {capacity} | virtual time",
            arrivals.name()
        );
        for &policy in &policies {
            let spec = VirtualSpec {
                requests,
                arrivals,
                capacity,
                policy,
                classes: (0..ns.len()).collect(),
            };
            reports.push(run_virtual_open_loop(&shards, &spec));
        }
    } else {
        let rate = rate_arg
            .filter(|r| *r > 0.0)
            .ok_or("--mode open with a real engine needs --rate (arrivals per second)")?;
        let arrivals =
            Arrivals::parse(&arrivals_name, rate, seed).ok_or("bad --arrivals (fixed|poisson)")?;
        let planning = planning_from_args(args, cfg)?;
        let scfg = ServiceConfig {
            workers: args.opt_usize("workers")?.unwrap_or(2).max(1),
            max_batch: args.opt_usize("batch")?.unwrap_or(8).max(1),
            starvation_bound_s: args.opt_f64("starve")?.unwrap_or(5.0),
            transpose_block: cfg.transpose_block,
            pipeline: pipeline_from_args(args)?,
            planning,
            ..ServiceConfig::default()
        };
        println!(
            "serve-bench open: engine {engine} | kind {} | sizes {ns:?} | {requests} arrivals \
             ({arrivals_name} @ {rate:.1} rps) | {shard_count} shard(s) | window {capacity} | \
             live",
            kind.name()
        );
        let registry = EngineRegistry::new();
        for (pass, &policy) in policies.iter().enumerate() {
            let mut fb = FrontBuilder::new(FrontConfig { capacity, policy });
            for j in 0..shard_count {
                fb = fb.shard(
                    &format!("s{j}"),
                    service_builder_for_engine(
                        ServiceBuilder::new(scfg.clone()),
                        &registry,
                        engine,
                    )?,
                );
            }
            let front = fb.build();
            let engine_str: &str = engine.as_str();
            let spec = OpenLoopSpec { requests, arrivals };
            let rep = run_open_loop(
                &front,
                |i| {
                    let n = ns[i % ns.len()];
                    let mseed =
                        hclfft::util::prng::hash_key(&[seed, pass as u64, i as u64]);
                    if kind == TransformKind::R2c {
                        Dft2dRequest::real_forward(
                            engine_str,
                            SignalMatrix::random_real(n, n, mseed),
                        )
                    } else {
                        Dft2dRequest::forward(engine_str, SignalMatrix::random(n, n, mseed))
                    }
                },
                &spec,
            );
            front.shutdown();
            reports.push(rep);
        }
    }

    for rep in &reports {
        println!("{}", rep.render(&format!("serve-bench open [{}]", rep.policy)));
        println!(
            "open-loop[{}]: offered {} accepted {} shed {} p95 {:.3} ms p99 {:.3} ms",
            rep.policy,
            rep.offered,
            rep.accepted,
            rep.shed,
            rep.latency_p95_s * 1e3,
            rep.latency_p99_s * 1e3
        );
    }
    if route == "both" && reports.len() == 2 {
        let (m, r) = (&reports[0], &reports[1]);
        let gain = if r.latency_p95_s > 0.0 {
            (1.0 - m.latency_p95_s / r.latency_p95_s) * 100.0
        } else {
            0.0
        };
        println!(
            "routing: model p95 {:.3} ms vs round-robin p95 {:.3} ms ({gain:+.1}% improvement)",
            m.latency_p95_s * 1e3,
            r.latency_p95_s * 1e3
        );
    }

    if !args.flag("no-json") {
        let json_path = PathBuf::from(args.opt_or("json", "BENCH_serve.json"));
        let runs: Vec<hclfft::util::json::Json> =
            reports.iter().map(|r| r.to_json()).collect();
        let doc = hclfft::util::json::Json::obj()
            .set("bench", "serve-open")
            .set("engine", engine.as_str())
            .set("kind", kind.name())
            .set("sizes", ns.clone())
            .set("requests", requests)
            .set("shards", shard_count)
            .set(
                "slowdowns",
                hclfft::util::json::Json::Arr(
                    slowdowns.iter().map(|&s| hclfft::util::json::Json::Num(s)).collect(),
                ),
            )
            .set("capacity", capacity)
            .set("runs", hclfft::util::json::Json::Arr(runs));
        if let Some(dir) = json_path.parent().filter(|d| !d.as_os_str().is_empty()) {
            std::fs::create_dir_all(dir).map_err(|e| e.to_string())?;
        }
        std::fs::write(&json_path, doc.to_pretty())
            .map_err(|e| format!("cannot write {}: {e}", json_path.display()))?;
        println!("open-loop results written to {}", json_path.display());
    }
    Ok(())
}

/// `serve-net`: the TCP front end. `--listen <addr>` starts a sharded
/// serving process speaking the length-prefixed wire protocol;
/// `--connect <addr>` runs the blocking client against one.
fn cmd_serve_net(args: &cli::Args, cfg: &Config) -> Result<(), String> {
    args.validate(&[
        "listen", "connect", "engine", "shards", "capacity", "route", "workers", "batch",
        "starve", "p", "t", "pad", "budget", "wisdom", "no-wisdom", "pipeline", "config",
        "allow-shutdown", "max-payload-mb", "n", "kind", "requests", "seed", "verify",
        "shutdown", "deadline-ms",
    ])?;
    if let Some(addr) = args.opt("listen") {
        serve_net_server(args, cfg, addr)
    } else if let Some(addr) = args.opt("connect") {
        serve_net_client(args, addr)
    } else {
        Err("serve-net needs --listen <addr> or --connect <addr>".into())
    }
}

fn serve_net_server(args: &cli::Args, cfg: &Config, addr: &str) -> Result<(), String> {
    use hclfft::serve::{FrontBuilder, FrontConfig, NetConfig, NetServer, RoutePolicy};
    use hclfft::service::{ServiceBuilder, ServiceConfig};

    let engine: EngineId = args.opt_or("engine", "native").parse()?;
    let shard_count = args.opt_usize("shards")?.unwrap_or(2).max(1);
    let capacity = args.opt_usize("capacity")?.unwrap_or(64).max(1);
    let policy = RoutePolicy::parse(&args.opt_or("route", "model"))
        .ok_or("bad --route (model|round-robin)")?;
    let planning = planning_from_args(args, cfg)?;
    let scfg = ServiceConfig {
        workers: args.opt_usize("workers")?.unwrap_or(2).max(1),
        max_batch: args.opt_usize("batch")?.unwrap_or(8).max(1),
        starvation_bound_s: args.opt_f64("starve")?.unwrap_or(5.0),
        transpose_block: cfg.transpose_block,
        pipeline: pipeline_from_args(args)?,
        planning,
        max_payload_bytes: args.opt_usize("max-payload-mb")?.map(|mb| mb << 20),
        ..ServiceConfig::default()
    };
    let wisdom_path = if args.flag("no-wisdom") {
        None
    } else {
        Some(PathBuf::from(args.opt_or("wisdom", "results/wisdom.json")))
    };
    let registry = EngineRegistry::new();
    let mut fb = FrontBuilder::new(FrontConfig { capacity, policy });
    for j in 0..shard_count {
        let mut b =
            service_builder_for_engine(ServiceBuilder::new(scfg.clone()), &registry, engine)?;
        if let Some(path) = wisdom_path.as_ref().filter(|p| p.exists()) {
            b = b.load_wisdom(path)?;
        }
        fb = fb.shard(&format!("s{j}"), b);
    }
    let ncfg = NetConfig {
        allow_remote_shutdown: args.flag("allow-shutdown"),
        ..NetConfig::default()
    };
    let mut server = NetServer::bind(fb.build(), addr, ncfg)
        .map_err(|e| format!("cannot bind {addr}: {e}"))?;
    println!(
        "serve-net: listening on {} | engine {engine} | {shard_count} shard(s) | route {} | \
         capacity {capacity}",
        server.local_addr(),
        policy.name()
    );
    server.wait_until_stopped();
    server.shutdown();
    println!("{}", server.front().stats().render());
    println!("serve-net: shutdown complete");
    Ok(())
}

fn serve_net_client(args: &cli::Args, addr: &str) -> Result<(), String> {
    use hclfft::serve::wire::WireRequest;
    use hclfft::serve::NetClient;

    let n = args.opt_usize("n")?.unwrap_or(64);
    let kind = kind_from_args(args)?;
    let requests = args.opt_usize("requests")?.unwrap_or(4).max(1);
    let seed = args.opt_usize("seed")?.unwrap_or(42) as u64;
    let engine = args.opt_or("engine", "native");
    let deadline_us = args
        .opt_f64("deadline-ms")?
        .map(|ms| (ms * 1e3).max(0.0) as u64)
        .unwrap_or(0);
    let mut client =
        NetClient::connect(addr).map_err(|e| format!("cannot connect to {addr}: {e}"))?;
    let mut failures = 0usize;
    for i in 0..requests {
        let mseed = hclfft::util::prng::hash_key(&[seed, i as u64]);
        let input = if kind == TransformKind::R2c {
            SignalMatrix::random_real(n, n, mseed)
        } else {
            SignalMatrix::random(n, n, mseed)
        };
        let req = WireRequest {
            req_id: 0,
            deadline_us,
            n: n as u64,
            kind,
            direction: hclfft::dft::fft::Direction::Forward,
            engine: engine.clone(),
            re: input.re.clone(),
            // real signals ship with an empty (implicit all-zero) im plane
            im: if kind == TransformKind::R2c { Vec::new() } else { input.im.clone() },
        };
        match client.roundtrip(req).map_err(|e| format!("io error: {e}"))? {
            Ok(resp) => {
                let mut line = format!(
                    "serve-net: req {i} ok | n {n} kind {} | shard {} | {}x{} spectrum | \
                     server latency {:.3} ms",
                    kind.name(),
                    resp.shard,
                    resp.rows,
                    resp.cols,
                    resp.server_latency_s * 1e3
                );
                if args.flag("verify") {
                    let max_err = verify_against_local(&input, kind, &resp.re, &resp.im)?;
                    line.push_str(&format!(" | verify max err {max_err:.2e}"));
                    if max_err > 1e-6 {
                        line.push_str(" MISMATCH");
                        failures += 1;
                    }
                }
                println!("{line}");
            }
            Err((code, msg)) => {
                eprintln!("serve-net: req {i} rejected (code {code}): {msg}");
                failures += 1;
            }
        }
    }
    if args.flag("shutdown") {
        let acked = client.shutdown_server().map_err(|e| format!("io error: {e}"))?;
        println!(
            "serve-net: server shutdown {}",
            if acked { "acknowledged" } else { "refused (not enabled on server)" }
        );
    }
    if failures > 0 {
        return Err(format!("{failures} of {requests} request(s) failed"));
    }
    Ok(())
}

/// Max abs deviation of a served spectrum from the local single-thread
/// oracle (`dft2d` for c2c, `rfft2d` for real input).
fn verify_against_local(
    input: &SignalMatrix,
    kind: TransformKind,
    got_re: &[f64],
    got_im: &[f64],
) -> Result<f64, String> {
    let oracle = match kind {
        TransformKind::C2c => {
            let mut m = input.clone();
            hclfft::dft::dft2d::dft2d(&mut m, hclfft::dft::fft::Direction::Forward, 1);
            m
        }
        TransformKind::R2c => {
            let rm = RealMatrix {
                rows: input.rows,
                cols: input.cols,
                data: input.re.clone(),
            };
            hclfft::dft::real::rfft2d(&rm, 1)
        }
        TransformKind::C2r => return Err("--verify supports c2c and r2c requests".into()),
    };
    if got_re.len() != oracle.re.len() || got_im.len() != oracle.im.len() {
        return Err(format!(
            "verify: geometry mismatch (got {}+{} values, oracle {}+{})",
            got_re.len(),
            got_im.len(),
            oracle.re.len(),
            oracle.im.len()
        ));
    }
    let mut max_err = 0.0f64;
    for (a, b) in got_re.iter().zip(&oracle.re).chain(got_im.iter().zip(&oracle.im)) {
        max_err = max_err.max((a - b).abs());
    }
    Ok(max_err)
}

/// Sample mean ± 95% two-sided Student-t half-width
/// (`t_inv_cdf(0.975, n-1) * sd / sqrt(n)`); half-width 0 for n < 2.
fn mean_t_ci(xs: &[f64]) -> (f64, f64) {
    let n = xs.len() as f64;
    let mean = xs.iter().sum::<f64>() / n.max(1.0);
    if xs.len() < 2 {
        return (mean, 0.0);
    }
    let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / (n - 1.0);
    let hw = hclfft::stats::ttest::t_inv_cdf(0.975, n - 1.0) * (var / n).sqrt();
    (mean, hw)
}

/// "12.3%" or "n/a" when no calibration samples exist.
fn fmt_pct(err: f64, batches: u64) -> String {
    if batches == 0 || !err.is_finite() {
        "n/a".to_string()
    } else {
        format!("{:.1}%", err * 100.0)
    }
}

/// One serve-bench phase as a BENCH_serve.json object.
fn phase_json(s: &hclfft::service::stats::ServiceStats) -> hclfft::util::json::Json {
    hclfft::util::json::Json::obj()
        .set("completed", s.completed as i64)
        .set("failed", s.failed as i64)
        .set("wall_s", s.wall_s)
        .set("throughput_rps", s.throughput_rps)
        .set("latency_p50_ms", s.latency_p50_s * 1e3)
        .set("latency_p95_ms", s.latency_p95_s * 1e3)
        .set("latency_p99_ms", s.latency_p99_s * 1e3)
        .set("planning_events", s.planning_events as i64)
        .set("wisdom_hits", s.wisdom_hits as i64)
        .set("drift_events", s.drift_events as i64)
        .set(
            "calibration_mean_err",
            if s.calibration_batches == 0 {
                hclfft::util::json::Json::Null
            } else {
                hclfft::util::json::Json::Num(s.calibration_mean_err)
            },
        )
}

fn cmd_wisdom(args: &cli::Args, cfg: &Config) -> Result<(), String> {
    use hclfft::service::wisdom::{WisdomRecord, WisdomStore};

    args.validate(&["file", "prewarm", "engine", "p", "t", "pad", "budget", "config", "kind"])?;
    let path = PathBuf::from(args.opt_or("file", "results/wisdom.json"));
    let mut store = if path.exists() {
        WisdomStore::load(&path)?
    } else {
        WisdomStore::new()
    };

    if let Some(list) = args.opt("prewarm") {
        let sizes = parse_csv_usize(list)?;
        let engine: EngineId = args.opt_or("engine", "native").parse()?;
        let kind = kind_from_args(args)?;
        let planning = planning_from_args(args, cfg)?;
        if engine.is_sim() && (args.opt("p").is_some() || args.opt("t").is_some()) {
            eprintln!(
                "note: sim-* engines pin their package's paper-best (p, t); --p/--t are ignored"
            );
        }
        for &n in &sizes {
            let rec = match engine {
                EngineId::Sim(pkg) => {
                    if kind.is_real() {
                        return Err("--kind real requires a real engine for prewarm".into());
                    }
                    WisdomRecord::from_simulator(pkg, n, planning.pad_cost.is_some())
                }
                EngineId::Native => {
                    WisdomRecord::from_measurement_kind(engine, &NativeEngine, n, &planning, kind)
                }
                other => {
                    return Err(format!("engine `{other}` is not prewarmable (native|sim-*)"))
                }
            };
            println!(
                "prewarmed {engine} {} N={n}: d = {:?}, algo {}, kernel {}, predicted {:.6}s",
                rec.kind().name(),
                rec.plan.d,
                rec.plan.algorithm.name(),
                record_kernel(&rec),
                rec.predicted_cost_s
            );
            store.insert(rec);
        }
        store.save(&path)?;
        println!("wisdom: saved {} record(s) to {}", store.len(), path.display());
    }

    let mut table = hclfft::util::table::Table::new(
        &format!("wisdom store {}", path.display()),
        &["engine", "n", "p", "t", "kind", "algo", "padded", "kernel", "predicted_s"],
    );
    for rec in store.iter() {
        table.row(vec![
            rec.engine.to_string(),
            rec.n.to_string(),
            rec.p.to_string(),
            rec.t.to_string(),
            rec.kind().name().to_string(),
            rec.plan.algorithm.name().to_string(),
            if rec.plan.is_padded() { "yes".into() } else { "no".into() },
            record_kernel(rec),
            format!("{:.6}", rec.predicted_cost_s),
        ]);
    }
    println!("{}", table.render());
    if store.is_empty() {
        println!("(empty — run `hclfft serve-bench` or `hclfft wisdom --prewarm <sizes>`)");
    }
    Ok(())
}

/// `hclfft model` — inspect the persisted performance-model state:
/// per-engine sample counts, refined points, drift events, and (with
/// `--engine --n`) the plane sections planning runs against.
fn cmd_model(args: &cli::Args) -> Result<(), String> {
    use hclfft::model::{SimModel, StaticModel};
    use hclfft::service::wisdom::WisdomStore;
    use std::sync::Arc;

    args.validate(&["file", "engine", "n", "config"])?;
    let path = PathBuf::from(args.opt_or("file", "results/wisdom.json"));
    let store = if path.exists() {
        WisdomStore::load(&path)?
    } else {
        WisdomStore::new()
    };
    let engine_filter = args.opt("engine");
    let keep = |e: &str| engine_filter.map_or(true, |f| f == e);

    let mut table = hclfft::util::table::Table::new(
        &format!("online models {}", path.display()),
        &["engine", "points", "observations", "dropped", "drift events", "speed scale"],
    );
    let mut shown = 0usize;
    for (e, m) in store.models() {
        if !keep(e) {
            continue;
        }
        shown += 1;
        // reattach the virtual base so the observed speed scale is
        // computable for sim engines (real engines report 1.000 until
        // a service session attaches their measured surfaces). An
        // unparseable sim-* name in a hand-edited file is skipped, not
        // fatal — the inspection tool must work on the files it debugs.
        let mut m = m.clone();
        if let Ok(Some(pkg)) = sim_package(e) {
            m.set_base(Arc::new(SimModel::paper_best(pkg)));
        }
        table.row(vec![
            e.clone(),
            m.len().to_string(),
            m.observations().to_string(),
            m.dropped().to_string(),
            m.drift_events().len().to_string(),
            format!("{:.3}", m.speed_scale()),
        ]);
    }
    println!("{}", table.render());
    if shown == 0 {
        println!("(no model state — serve traffic with `hclfft serve-bench` first)");
    }

    // refined points: sample counts and running estimates
    for (e, m) in store.models() {
        if !keep(e) {
            continue;
        }
        for (&(x, y), p) in m.points() {
            let ci = p.reported_ci_rel();
            println!(
                "  {e} point (x={x}, y={y}): {} sample(s), mean {:.6}s, ci {}, {} drift(s)",
                p.samples(),
                p.mean(),
                if ci.is_finite() { format!("+/-{:.2}%", ci * 100.0) } else { "n/a".into() },
                p.drift_count
            );
        }
        for ev in m.drift_events().iter().rev().take(10) {
            println!(
                "  {e} drift at obs #{}: (x={}, y={}) expected {:.6}s observed {:.6}s \
                 (variation {:.0}%, {} drift)",
                ev.at_observation,
                ev.x,
                ev.y,
                ev.expected_s,
                ev.observed_s,
                ev.variation_pct,
                ev.class.name()
            );
        }
    }

    // section inspection: the curves planning consumes for (engine, n)
    if let (Some(engine), Some(n)) = (engine_filter, args.opt_usize("n")?) {
        let model: Option<Box<dyn PerfModel>> = if let Some(pkg) = sim_package(engine)? {
            Some(Box::new(SimModel::paper_best(pkg)))
        } else {
            store
                .iter()
                .find(|r| r.engine.as_str() == engine && r.n == n && !r.fpms.is_empty())
                .map(|r| Box::new(StaticModel::new(r.fpms.clone())) as Box<dyn PerfModel>)
        };
        match model {
            Some(model) => {
                println!("plane sections y = {n} ({engine}):");
                for g in 0..model.groups() {
                    let c = model.plane_section(g, n);
                    if c.is_empty() {
                        println!("  group{}: (no measured points)", g + 1);
                        continue;
                    }
                    let (lo, hi) = c
                        .speeds
                        .iter()
                        .fold((f64::INFINITY, 0.0f64), |(lo, hi), &s| (lo.min(s), hi.max(s)));
                    println!(
                        "  group{}: {} point(s), x in [{}, {}], speed {:.0}..{:.0} MFLOPs",
                        g + 1,
                        c.len(),
                        c.xs[0],
                        c.xs[c.len() - 1],
                        lo,
                        hi
                    );
                }
            }
            None => println!(
                "no sections available for {engine} N={n} (no persisted surfaces; run \
                 serve-bench or wisdom --prewarm)"
            ),
        }
    }
    Ok(())
}

fn cmd_simulate(args: &cli::Args) -> Result<(), String> {
    args.validate(&["package", "sizes", "config", "quick"])?;
    let pkg = Package::parse(&args.opt_or("package", "mkl")).ok_or("bad --package")?;
    let sizes: Vec<usize> = match args.opt("sizes") {
        Some(s) => parse_csv_usize(s)?,
        None => {
            let all = hclfft::simulator::campaign_sizes();
            if args.flag("quick") {
                all.into_iter().step_by(16).collect()
            } else {
                all
            }
        }
    };
    let c = Campaign::run(pkg, &sizes);
    let s = c.summary();
    let mid = CampaignSummary::for_range(&c.points, 10_000, 33_000);
    println!("virtual campaign: {} over {} sizes (p={}, t={})", pkg.name(), s.count, c.cfg.p, c.cfg.t);
    println!("  PFFT-FPM:     avg {:.2}x  max {:.2}x", s.avg_speedup_fpm, s.max_speedup_fpm);
    println!("  PFFT-FPM-PAD: avg {:.2}x  max {:.2}x", s.avg_speedup_pad, s.max_speedup_pad);
    println!("  mid-range (10000,33000]: FPM {:.2}x  PAD {:.2}x", mid.avg_speedup_fpm, mid.avg_speedup_pad);
    println!(
        "  avg MFLOPs: basic {:.0} | FPM {:.0} | PAD {:.0}",
        s.avg_mflops_basic, s.avg_mflops_fpm, s.avg_mflops_pad
    );
    Ok(())
}
