//! `hclfft` CLI — the L3 coordinator entrypoint.
//!
//! Subcommands (see `hclfft help`):
//! * `plan`     — FPM-based row partitioning (POPTA/HPOPTA) + pad lengths
//! * `run`      — execute a 2D-DFT with PFFT-LB / PFFT-FPM / PFFT-FPM-PAD
//! * `profile`  — build speed functions for a real engine (FPM dump)
//! * `figures`  — regenerate the paper's figures/tables
//! * `simulate` — virtual-testbed campaign summary
//! * `bench`    — `run` measured with the MeanUsingTtest methodology

use std::path::{Path, PathBuf};

use hclfft::cli;
use hclfft::config::Config;
use hclfft::coordinator::engine::{NativeEngine, RowFftEngine};
use hclfft::coordinator::group::GroupConfig;
use hclfft::coordinator::pad::{pads_for_distribution, PadCost};
use hclfft::coordinator::pfft::{pfft_fpm, pfft_fpm_pad, pfft_lb, plan_partition};
use hclfft::dft::SignalMatrix;
use hclfft::figures::{generate, generate_all, Ctx};
use hclfft::profiler::{build_fpms, ProfileSpec};
use hclfft::runtime::PjrtRowFftEngine;
use hclfft::simulator::fpm::SimTestbed;
use hclfft::simulator::vexec::{Campaign, CampaignSummary};
use hclfft::simulator::Package;
use hclfft::stats::{mean_using_ttest, TtestPolicy};

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let code = match run(&argv) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            eprintln!("run `hclfft help` for usage");
            1
        }
    };
    std::process::exit(code);
}

fn run(argv: &[String]) -> Result<(), String> {
    let args = match cli::parse(argv) {
        Ok(a) => a,
        Err(e) => {
            if argv.is_empty() {
                println!("{}", cli::help());
                return Ok(());
            }
            return Err(e);
        }
    };
    let cfg = Config::load(args.opt("config").map(Path::new))?;
    match args.subcommand.as_str() {
        "help" => {
            println!("{}", cli::help());
            Ok(())
        }
        "plan" => cmd_plan(&args, &cfg),
        "run" => cmd_run(&args, &cfg, false),
        "bench" => cmd_run(&args, &cfg, true),
        "profile" => cmd_profile(&args, &cfg),
        "figures" => cmd_figures(&args, &cfg),
        "simulate" => cmd_simulate(&args),
        other => Err(format!("unknown subcommand `{other}`")),
    }
}

fn cmd_plan(args: &cli::Args, cfg: &Config) -> Result<(), String> {
    args.validate(&["n", "p", "eps", "package", "pad", "source", "config"])?;
    let n = args.opt_usize("n")?.ok_or("--n required")?;
    let pkg = Package::parse(&args.opt_or("package", "mkl")).ok_or("bad --package")?;
    let p = args.opt_usize("p")?.unwrap_or(pkg.best_groups().p);
    let eps = args.opt_f64("eps")?.unwrap_or(cfg.eps);

    let tb = SimTestbed::new(pkg, GroupConfig::new(p, 36 / p.max(1)));
    let curves = tb.plane_sections(n);
    let identical = hclfft::coordinator::partition::curves_identical(&curves, eps);
    let part = if identical {
        let avg = hclfft::coordinator::partition::average_curve(&curves);
        hclfft::coordinator::partition::popta(&avg, p, n - n % 128)
    } else {
        hclfft::coordinator::partition::hpopta(&curves, n - n % 128)
    }
    .map_err(|e| e.to_string())?;

    println!("package: {} | N = {n} | p = {p} | eps = {eps}", pkg.name());
    println!(
        "identity test: curves {} => {}",
        if identical { "identical" } else { "heterogeneous" },
        if identical { "POPTA (averaged)" } else { "HPOPTA" }
    );
    println!("distribution d = {:?} (makespan {:.4})", part.d, part.makespan);
    if args.flag("pad") {
        for (i, &di) in part.d.iter().enumerate() {
            if di == 0 {
                continue;
            }
            let col = tb.column_section(i + 1, di, n, hclfft::simulator::vexec::PAD_WINDOW);
            let dec = hclfft::coordinator::pad::determine_pad_length(
                &col,
                di,
                n,
                PadCost::PaperRatio,
            );
            println!(
                "group{}: N_padded = {} (predicted gain {:.1}%)",
                i + 1,
                dec.n_padded,
                100.0 * dec.n_padded_gain()
            );
        }
    }
    Ok(())
}

fn make_engine(name: &str, artifacts: &Path) -> Result<Box<dyn RowFftEngine>, String> {
    match name {
        "native" => Ok(Box::new(NativeEngine)),
        "pjrt" => Ok(Box::new(PjrtRowFftEngine::load(artifacts).map_err(|e| e.to_string())?)),
        other => Err(format!("unknown engine `{other}` (native|pjrt)")),
    }
}

fn cmd_run(args: &cli::Args, cfg: &Config, bench: bool) -> Result<(), String> {
    args.validate(&["n", "engine", "algo", "p", "t", "artifacts", "verify", "config", "seed"])?;
    let n = args.opt_usize("n")?.ok_or("--n required")?;
    let algo = args.opt_or("algo", "fpm");
    let p = args.opt_usize("p")?.unwrap_or(cfg.groups);
    let t = args.opt_usize("t")?.unwrap_or(cfg.threads_per_group);
    let artifacts = args
        .opt("artifacts")
        .map(PathBuf::from)
        .unwrap_or_else(|| cfg.artifacts_dir.clone());
    let engine = make_engine(&args.opt_or("engine", "native"), &artifacts)?;
    let seed = args.opt_usize("seed")?.unwrap_or(42) as u64;
    let grp = GroupConfig::new(p, t);

    // plan from measured plane (real FPM construction, scaled-down reps)
    let xs: Vec<usize> = (1..=8).map(|k| (k * n / 8).max(1)).collect();
    let fpms = hclfft::profiler::build_plane(engine.as_ref(), grp, xs, n, cfg.rep_scale.max(100));
    let part = plan_partition(&fpms, n, cfg.eps).map_err(|e| e.to_string())?;

    let mut exec = |label: &str| -> Result<f64, String> {
        let mut m = SignalMatrix::random(n, n, seed);
        let t0 = std::time::Instant::now();
        match label {
            "basic" => {
                // one group with the whole thread budget
                pfft_lb(engine.as_ref(), &mut m, GroupConfig::new(1, p * t), cfg.transpose_block)
                    .map_err(|e| e.to_string())?;
            }
            "lb" => {
                pfft_lb(engine.as_ref(), &mut m, grp, cfg.transpose_block)
                    .map_err(|e| e.to_string())?;
            }
            "fpm" => {
                pfft_fpm(engine.as_ref(), &mut m, &part.d, t, cfg.transpose_block)
                    .map_err(|e| e.to_string())?;
            }
            "fpm-pad" => {
                let pads = pads_for_distribution(&fpms, &part.d, n, PadCost::PaperRatio);
                pfft_fpm_pad(engine.as_ref(), &mut m, &part.d, &pads, t, cfg.transpose_block)
                    .map_err(|e| e.to_string())?;
            }
            other => return Err(format!("unknown algo `{other}`")),
        }
        Ok(t0.elapsed().as_secs_f64())
    };

    if bench {
        let policy = TtestPolicy { min_reps: 5, max_reps: 50, max_time_s: 30.0, cl: 0.95, eps: 0.025 };
        let m = mean_using_ttest(&policy, || exec(&algo).expect("bench run failed"));
        let mflops = hclfft::stats::harness::fft2d_flops(n) / m.mean / 1e6;
        println!(
            "{} {} N={n} (p={p}, t={t}): mean {:.6}s ± {:.6}s over {} reps ({:.1} MFLOPs)",
            engine.name(),
            algo,
            m.mean,
            m.ci_half_width,
            m.reps,
            mflops
        );
    } else {
        let secs = exec(&algo)?;
        let mflops = hclfft::stats::harness::fft2d_flops(n) / secs / 1e6;
        println!(
            "{} {} N={n} (p={p}, t={t}): {:.6}s ({:.1} MFLOPs), d = {:?}",
            engine.name(),
            algo,
            secs,
            mflops,
            part.d
        );
    }

    if args.flag("verify") {
        let mut m = SignalMatrix::random(n, n, seed);
        pfft_fpm(engine.as_ref(), &mut m, &part.d, t, cfg.transpose_block)
            .map_err(|e| e.to_string())?;
        let mut reference = SignalMatrix::random(n, n, seed);
        hclfft::dft::dft2d::dft2d(&mut reference, hclfft::dft::fft::Direction::Forward, 1);
        let err = m.max_abs_diff(&reference) / reference.norm().max(1.0);
        println!("verify vs native serial 2D-DFT: rel err {err:.3e}");
        if err > 1e-3 {
            return Err(format!("verification failed: rel err {err}"));
        }
    }
    Ok(())
}

fn cmd_profile(args: &cli::Args, cfg: &Config) -> Result<(), String> {
    args.validate(&["engine", "n-list", "x-list", "p", "t", "out", "scale", "artifacts", "config", "budget"])?;
    let parse_list = |s: &str| -> Result<Vec<usize>, String> {
        s.split(',')
            .map(|v| v.trim().parse().map_err(|_| format!("bad list item `{v}`")))
            .collect()
    };
    let ys = parse_list(&args.opt_or("n-list", "128,256,512"))?;
    let max_y = *ys.iter().max().unwrap_or(&512);
    let xs = match args.opt("x-list") {
        Some(s) => parse_list(s)?,
        None => (1..=4).map(|k| k * max_y / 4).collect(),
    };
    let p = args.opt_usize("p")?.unwrap_or(cfg.groups);
    let t = args.opt_usize("t")?.unwrap_or(cfg.threads_per_group);
    let artifacts = args
        .opt("artifacts")
        .map(PathBuf::from)
        .unwrap_or_else(|| cfg.artifacts_dir.clone());
    let engine = make_engine(&args.opt_or("engine", "native"), &artifacts)?;
    let mut spec = ProfileSpec::new(xs, ys, GroupConfig::new(p, t));
    spec.rep_scale = args.opt_usize("scale")?.unwrap_or(cfg.rep_scale);
    if let Some(b) = args.opt_f64("budget")? {
        spec.budget_s = b;
    }

    let fpms = build_fpms(engine.as_ref(), &spec);
    let out_base = args.opt_or("out", "results/fpm");
    for (g, fpm) in fpms.iter().enumerate() {
        let path = PathBuf::from(format!("{out_base}_group{}.tsv", g + 1));
        fpm.write_tsv(&path).map_err(|e| e.to_string())?;
        println!(
            "group{}: {} points -> {}",
            g + 1,
            fpm.measured_points(),
            path.display()
        );
    }
    Ok(())
}

fn cmd_figures(args: &cli::Args, cfg: &Config) -> Result<(), String> {
    args.validate(&["fig", "all", "out-dir", "quick", "artifacts", "config"])?;
    let out_dir = PathBuf::from(args.opt_or("out-dir", cfg.results_dir.to_str().unwrap_or("results")));
    std::fs::create_dir_all(&out_dir).map_err(|e| e.to_string())?;
    let mut ctx = Ctx::new(&out_dir, args.flag("quick"));
    if let Some(a) = args.opt("artifacts") {
        ctx.artifacts_dir = PathBuf::from(a);
    }
    let text = if args.flag("all") {
        generate_all(&ctx)?
    } else {
        let id = args.opt("fig").ok_or("--fig <id> or --all required")?;
        generate(id, &ctx)?
    };
    println!("{text}");
    Ok(())
}

fn cmd_simulate(args: &cli::Args) -> Result<(), String> {
    args.validate(&["package", "sizes", "config", "quick"])?;
    let pkg = Package::parse(&args.opt_or("package", "mkl")).ok_or("bad --package")?;
    let sizes: Vec<usize> = match args.opt("sizes") {
        Some(s) => s
            .split(',')
            .map(|v| v.trim().parse().map_err(|_| format!("bad size `{v}`")))
            .collect::<Result<_, _>>()?,
        None => {
            let all = hclfft::simulator::campaign_sizes();
            if args.flag("quick") {
                all.into_iter().step_by(16).collect()
            } else {
                all
            }
        }
    };
    let c = Campaign::run(pkg, &sizes);
    let s = c.summary();
    let mid = CampaignSummary::for_range(&c.points, 10_000, 33_000);
    println!("virtual campaign: {} over {} sizes (p={}, t={})", pkg.name(), s.count, c.cfg.p, c.cfg.t);
    println!("  PFFT-FPM:     avg {:.2}x  max {:.2}x", s.avg_speedup_fpm, s.max_speedup_fpm);
    println!("  PFFT-FPM-PAD: avg {:.2}x  max {:.2}x", s.avg_speedup_pad, s.max_speedup_pad);
    println!("  mid-range (10000,33000]: FPM {:.2}x  PAD {:.2}x", mid.avg_speedup_fpm, mid.avg_speedup_pad);
    println!(
        "  avg MFLOPs: basic {:.0} | FPM {:.0} | PAD {:.0}",
        s.avg_mflops_basic, s.avg_mflops_fpm, s.avg_mflops_pad
    );
    Ok(())
}
