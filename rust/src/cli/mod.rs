//! Minimal command-line parsing (no `clap` in the offline vendor set).
//!
//! Grammar: `hclfft <subcommand> [--key value]... [--flag]...`
//! Unknown options are errors; every subcommand documents its options in
//! [`crate::cli::help`].

use std::collections::BTreeMap;

/// Parsed command line: subcommand + options.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub subcommand: String,
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
}

/// Parse errors are plain strings (rendered with usage by main).
pub fn parse(argv: &[String]) -> Result<Args, String> {
    let mut args = Args::default();
    let mut it = argv.iter().peekable();
    match it.next() {
        Some(sub) if !sub.starts_with('-') => args.subcommand = sub.clone(),
        Some(other) => return Err(format!("expected subcommand, got `{other}`")),
        None => return Err("missing subcommand".into()),
    }
    while let Some(tok) = it.next() {
        let Some(key) = tok.strip_prefix("--") else {
            return Err(format!("unexpected positional argument `{tok}`"));
        };
        if key.is_empty() {
            return Err("bare `--` not supported".into());
        }
        // `--key=value` form (equivalent to `--key value`; the value may
        // itself contain `=`)
        if let Some((k, v)) = key.split_once('=') {
            if k.is_empty() {
                return Err(format!("empty option name in `{tok}`"));
            }
            args.opts.insert(k.to_string(), v.to_string());
            continue;
        }
        // `--key value` form if next token isn't an option; else flag
        match it.peek() {
            Some(next) if !next.starts_with("--") => {
                args.opts.insert(key.to_string(), it.next().unwrap().clone());
            }
            _ => args.flags.push(key.to_string()),
        }
    }
    Ok(args)
}

impl Args {
    /// Is a boolean flag set? Bare `--flag` form, plus the `=`-forms
    /// `--flag=true|1|yes` (and `--flag=false|0|no` for an explicit
    /// off) so the "all options accept `--key=value`" promise holds for
    /// flags too.
    pub fn flag(&self, name: &str) -> bool {
        if self.flags.iter().any(|f| f == name) {
            return true;
        }
        matches!(self.opt(name), Some("true") | Some("1") | Some("yes"))
    }

    pub fn opt(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    pub fn opt_or(&self, name: &str, default: &str) -> String {
        self.opt(name).unwrap_or(default).to_string()
    }

    pub fn opt_usize(&self, name: &str) -> Result<Option<usize>, String> {
        match self.opt(name) {
            None => Ok(None),
            Some(v) => v
                .parse()
                .map(Some)
                .map_err(|_| format!("--{name}: expected integer, got `{v}`")),
        }
    }

    pub fn opt_f64(&self, name: &str) -> Result<Option<f64>, String> {
        match self.opt(name) {
            None => Ok(None),
            Some(v) => v
                .parse()
                .map(Some)
                .map_err(|_| format!("--{name}: expected number, got `{v}`")),
        }
    }

    /// All parsed option keys + flags (for unknown-option validation).
    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.opts.keys().map(|s| s.as_str()).chain(self.flags.iter().map(|s| s.as_str()))
    }

    /// Error if any provided option is not in `allowed`.
    pub fn validate(&self, allowed: &[&str]) -> Result<(), String> {
        for k in self.keys() {
            if !allowed.contains(&k) {
                return Err(format!(
                    "unknown option `--{k}` for `{}` (allowed: {})",
                    self.subcommand,
                    allowed.join(", ")
                ));
            }
        }
        Ok(())
    }
}

/// Top-level usage text.
pub fn help() -> &'static str {
    "hclfft — model-based 2D-DFT performance optimization (PFFT-FPM / PFFT-FPM-PAD)

USAGE: hclfft <subcommand> [options]

SUBCOMMANDS:
  plan      Partition N rows across p abstract processors using FPMs
            --n <rows> --p <groups> [--eps <tol>] [--package mkl|fftw3|fftw2]
            [--pad] [--source sim|native]
  run       Execute a 2D-DFT via an engine and report time/MFLOPs and
            the row kernel used (mixed-radix for 5-smooth N, Bluestein
            fallback otherwise)
            --n <size> [--engine native|pjrt] [--algo lb|fpm|fpm-pad|basic]
            [--p <groups>] [--t <threads>] [--artifacts <dir>] [--verify]
            [--kind c2c|real]   (real = r2c: a real signal transforms via
            the pair kernel into an N x (N/2+1) Hermitian-packed half
            spectrum — roughly half the flops of c2c)
            [--pipeline fused|barrier]   (fused: tile stage-DAG, strided
            column FFTs, no transpose barriers — the default; barrier:
            the four-step fallback. Also via env HCLFFT_PIPELINE)
  profile   Build speed functions for an engine (FPM construction)
            --engine native|pjrt --n-list <csv> [--x-list <csv>] [--p <groups>]
            [--out <file.tsv>] [--scale <rep-divisor>] [--artifacts <dir>]
  figures   Regenerate the paper's figures/tables
            --fig <id>|--all [--out-dir <dir>] [--quick]
  simulate  Run the virtual-testbed experiment campaign
            --package mkl|fftw3 [--algo fpm|fpm-pad] [--sizes <csv>]
  bench     Alias of `run` with MeanUsingTtest measurement
  serve-bench
            Closed-loop load generator against the in-process 2D-DFT
            service (batching + wisdom + FPM scheduling); runs a cold
            pass and --reps warm passes, prints latency/throughput
            tables + model calibration (p50/p95 mean ± 95% Student-t CI
            across warm repetitions when --reps >= 2), writes the
            BENCH_serve.json trajectory and persists planning wisdom +
            model deltas
            --n <size[,size...]> [--requests <count-per-pass>]
            [--clients <threads>] [--reps <warm-passes>]
            [--engine native|sim-mkl|sim-fftw3|sim-fftw2|portfolio]
            [--p <groups>] [--t <threads>] [--workers <count>] [--batch <max>]
            [--wisdom <file.json>] [--no-wisdom] [--pad] [--starve <s>]
            [--budget <s>] [--seed <u64>] [--json <file.json>] [--no-json]
            [--pipeline fused|barrier]
            [--kind c2c|real]   (real: r2c requests — batching, wisdom and
            the online model are all keyed per kind; real engines only)
            [--drift-factor <x>]   (sim-*/portfolio only: slow the virtual
            machine -- under portfolio, the incumbent member(s) -- by x
            before the warm pass to exercise drift -> re-planning and
            portfolio re-picking)
            (--engine portfolio registers every sim-* member and resolves
            each request to the model-fastest engine per (n, kind) at
            admission; prints `portfolio:` pick lines and `portfolio
            re-pick after drift:` lines, and persists the learned
            per-engine surfaces in the wisdom file)
            [--mode closed|open]   (open: open-loop arrivals against a
            sharded front end — latency measured from arrival, overload
            sheds instead of queueing without bound)
            open-mode options: [--rate <rps>] [--arrivals fixed|poisson]
            [--shards <k>] [--capacity <inflight>]
            [--route model|round-robin|both] [--slowdowns <csv>]
            (sim-* engines replay the schedule deterministically in
            virtual time through the real router; native runs live and
            requires --rate)
  serve-net TCP front end speaking the length-prefixed binary wire
            protocol (see README §Serving architecture)
            server: --listen <host:port>   (port 0 = ephemeral; prints
            the bound address) [--engine native|sim-*] [--shards <k>]
            [--capacity <inflight>] [--route model|round-robin]
            [--workers <count>] [--batch <max>] [--p] [--t] [--pad]
            [--wisdom <file.json>] [--no-wisdom] [--max-payload-mb <mb>]
            [--allow-shutdown]   (honor client shutdown frames)
            client: --connect <host:port> [--n <size>] [--kind c2c|real]
            [--requests <count>] [--seed <u64>] [--deadline-ms <ms>]
            [--verify]   (check spectra against the local oracle)
            [--shutdown]   (ask the server to drain and exit)
  wisdom    Inspect or prewarm the planning wisdom store (records are
            kind-keyed; JSON v5 adds the engine-portfolio surfaces, v4
            measured row-tile widths -- older files all load forward:
            v3 with no tiles, v2 as c2c)
            [--file <file.json>] [--prewarm <size[,size...]>]
            [--engine native|sim-mkl|...] [--p <groups>] [--t <threads>]
            [--pad] [--budget <s>] [--kind c2c|real]
  model     Inspect the online performance model persisted alongside the
            wisdom: per-engine observation/drift summaries, refined
            points, and (with --engine and --n) the plane sections
            planning consumes
            [--file <file.json>] [--engine <name>] [--n <size>]
  help      Show this text

All options accept both `--key value` and `--key=value`.
"
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(parts: &[&str]) -> Vec<String> {
        parts.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_and_options() {
        let a = parse(&sv(&["plan", "--n", "1024", "--p", "4", "--pad"])).unwrap();
        assert_eq!(a.subcommand, "plan");
        assert_eq!(a.opt("n"), Some("1024"));
        assert_eq!(a.opt_usize("p").unwrap(), Some(4));
        assert!(a.flag("pad"));
        assert!(!a.flag("quick"));
    }

    #[test]
    fn parses_equals_form() {
        let a = parse(&sv(&["run", "--n=256", "--engine=native"])).unwrap();
        assert_eq!(a.opt("n"), Some("256"));
        assert_eq!(a.opt("engine"), Some("native"));
    }

    #[test]
    fn equals_and_space_forms_are_equivalent() {
        let a = parse(&sv(&["serve-bench", "--n=1024", "--clients", "8"])).unwrap();
        let b = parse(&sv(&["serve-bench", "--n", "1024", "--clients=8"])).unwrap();
        assert_eq!(a.opt("n"), b.opt("n"));
        assert_eq!(a.opt("clients"), b.opt("clients"));
        assert_eq!(a.opt_usize("n").unwrap(), Some(1024));
    }

    #[test]
    fn equals_value_may_contain_equals() {
        let a = parse(&sv(&["run", "--filter=key=value"])).unwrap();
        assert_eq!(a.opt("filter"), Some("key=value"));
    }

    #[test]
    fn equals_empty_value_is_kept() {
        let a = parse(&sv(&["run", "--out="])).unwrap();
        assert_eq!(a.opt("out"), Some(""));
    }

    #[test]
    fn equals_empty_key_rejected() {
        assert!(parse(&sv(&["run", "--=x"])).is_err());
    }

    #[test]
    fn flags_accept_equals_form() {
        let a = parse(&sv(&["run", "--verify=true", "--quick=1", "--pad=false"])).unwrap();
        assert!(a.flag("verify"));
        assert!(a.flag("quick"));
        assert!(!a.flag("pad"));
        // bare form unaffected
        let b = parse(&sv(&["run", "--verify"])).unwrap();
        assert!(b.flag("verify"));
        assert!(!b.flag("pad"));
    }

    #[test]
    fn missing_subcommand_errors() {
        assert!(parse(&[]).is_err());
        assert!(parse(&sv(&["--n", "4"])).is_err());
    }

    #[test]
    fn positional_rejected() {
        assert!(parse(&sv(&["plan", "oops"])).is_err());
    }

    #[test]
    fn bad_number_reported() {
        let a = parse(&sv(&["plan", "--n", "abc"])).unwrap();
        let err = a.opt_usize("n").unwrap_err();
        assert!(err.contains("expected integer"));
    }

    #[test]
    fn validate_unknown_option() {
        let a = parse(&sv(&["plan", "--bogus", "1"])).unwrap();
        assert!(a.validate(&["n", "p"]).is_err());
        let b = parse(&sv(&["plan", "--n", "1"])).unwrap();
        assert!(b.validate(&["n", "p"]).is_ok());
    }

    #[test]
    fn flag_followed_by_option() {
        let a = parse(&sv(&["run", "--verify", "--n", "64"])).unwrap();
        assert!(a.flag("verify"));
        assert_eq!(a.opt("n"), Some("64"));
    }
}
