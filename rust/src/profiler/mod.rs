//! FPM construction by measurement (paper §V-A/§V-B).
//!
//! Builds the discrete speed functions `S_i = {((x, y), s)}` for `p`
//! abstract processors by *actually executing* row-FFT batches on a real
//! engine (native or PJRT) and applying the paper's `MeanUsingTtest`
//! methodology per data point. All `p` groups execute the same point
//! concurrently ("all of them execute the same problem size in parallel
//! to determine the speed", §V-B).
//!
//! Also implements *partial* FPM construction (the paper's answer to the
//! 96-hour full-surface build): points in the neighbourhood of the
//! homogeneous distribution first, until a time budget is spent.
//!
//! All measured times flow through the model layer's sanitized ingestion
//! point ([`crate::model::speed_from_time_sanitized`]) and can be teed
//! into an online model via [`build_fpms_with`] — profiling emits
//! samples into the same store the serving executor appends to.

use std::time::Instant;

use crate::coordinator::engine::RowFftEngine;
use crate::coordinator::group::GroupConfig;
use crate::dft::fft::Direction;
use crate::dft::real::{half_cols, RealMatrix, TransformKind};
use crate::dft::SignalMatrix;
use crate::model::{speed_from_time_sanitized, SpeedFunction};
use crate::stats::{mean_using_ttest, TtestPolicy};

/// Grid + policy settings for a profiling run.
#[derive(Clone, Debug)]
pub struct ProfileSpec {
    /// row-count grid (x axis)
    pub xs: Vec<usize>,
    /// row-length grid (y axis)
    pub ys: Vec<usize>,
    pub cfg: GroupConfig,
    /// divide the paper's repetition counts by this (CI speed knob)
    pub rep_scale: usize,
    /// wall-clock budget for the whole build (partial-FPM cutoff)
    pub budget_s: f64,
    /// which row kernel to measure: c2c complex rows (default) or the
    /// r2c pair kernel — real planes run ~2x faster, so they get their
    /// own surfaces (and hence their own POPTA/HPOPTA partitions)
    pub kind: TransformKind,
}

impl ProfileSpec {
    pub fn new(xs: Vec<usize>, ys: Vec<usize>, cfg: GroupConfig) -> Self {
        ProfileSpec {
            xs,
            ys,
            cfg,
            rep_scale: 1000,
            budget_s: f64::INFINITY,
            kind: TransformKind::C2c,
        }
    }

    /// Builder-style kind override ([`TransformKind::C2r`] measures the
    /// shared r2c plane).
    pub fn with_kind(mut self, kind: TransformKind) -> Self {
        self.kind = kind.plan_kind();
        self
    }
}

/// Measure the speed functions of all `p` groups of an engine.
///
/// Returns one [`SpeedFunction`] per group. Groups run concurrently per
/// data point, mirroring the paper's methodology; each group's time is
/// measured with `MeanUsingTtest`.
pub fn build_fpms(engine: &dyn RowFftEngine, spec: &ProfileSpec) -> Vec<SpeedFunction> {
    build_fpms_with(engine, spec, |_, _, _| {})
}

/// [`build_fpms`] with a raw-sample sink: `on_sample(x, y, t_seconds)`
/// is called once per `(group, point)` mean time, so profiling runs can
/// feed the same online model store the serving executor appends to
/// (times are sanitized downstream at the model ingestion point).
pub fn build_fpms_with(
    engine: &dyn RowFftEngine,
    spec: &ProfileSpec,
    mut on_sample: impl FnMut(usize, usize, f64),
) -> Vec<SpeedFunction> {
    let p = spec.cfg.p;
    let started = Instant::now();
    let kind_tag = if spec.kind.is_real() {
        format!("-{}", spec.kind.plan_kind().name())
    } else {
        String::new()
    };
    let mut fpms: Vec<SpeedFunction> = (0..p)
        .map(|g| {
            SpeedFunction::new(
                &format!("{}-group{}-p{}t{}{}", engine.name(), g + 1, p, spec.cfg.t, kind_tag),
                spec.xs.clone(),
                spec.ys.clone(),
            )
        })
        .collect();

    // visit points nearest the homogeneous distribution first so a
    // budget cutoff yields the paper's *partial* FPM
    let mut points: Vec<(usize, usize)> = Vec::new();
    for &y in &spec.ys {
        for &x in &spec.xs {
            points.push((x, y));
        }
    }
    points.sort_by_key(|&(x, y)| {
        let homog = y / p.max(1);
        (y, x.abs_diff(homog))
    });

    for (x, y) in points {
        if started.elapsed().as_secs_f64() > spec.budget_s {
            break; // partial FPM
        }
        let times = measure_point(engine, spec, x, y);
        for (g, t_mean) in times.into_iter().enumerate() {
            let Some(t_mean) = t_mean else { continue };
            on_sample(x, y, t_mean);
            // the model layer's sanitized ingestion: a ~0 ns reading is
            // clamped to timer resolution, NaN/degenerate means dropped
            if let Some(s) = speed_from_time_sanitized(x, y, t_mean) {
                fpms[g].set(x, y, s);
            }
        }
    }
    fpms
}

/// Measure one (x, y) data point: all p groups execute x row-FFTs of
/// length y concurrently; per-group mean time via MeanUsingTtest.
/// Returns the raw mean seconds per group (`None` on engine failure).
fn measure_point(
    engine: &dyn RowFftEngine,
    spec: &ProfileSpec,
    x: usize,
    y: usize,
) -> Vec<Option<f64>> {
    let p = spec.cfg.p;
    let t = spec.cfg.t;
    let policy = {
        let mut pol = TtestPolicy::for_problem_size(y, spec.rep_scale);
        pol.max_time_s = pol.max_time_s.min(10.0);
        pol
    };
    let kind = spec.kind.plan_kind();
    let results: std::sync::Mutex<Vec<Option<f64>>> = std::sync::Mutex::new(vec![None; p]);
    std::thread::scope(|scope| {
        for g in 0..p {
            let results = &results;
            let policy = policy;
            scope.spawn(move || {
                let mut failed = false;
                let tt = if kind == TransformKind::R2c {
                    // real plane: time the r2c pair kernel — x real rows
                    // of length y into packed x × (y/2+1) half spectra
                    let src = RealMatrix::random(x, y, (g as u64 + 1) * 7919);
                    let nc = half_cols(y);
                    let mut dre = vec![0.0; x * nc];
                    let mut dim = vec![0.0; x * nc];
                    mean_using_ttest(&policy, || {
                        let t0 = Instant::now();
                        if crate::coordinator::real::r2c_rows_engine(
                            engine, &src.data, &mut dre, &mut dim, x, y, y, t,
                        )
                        .is_err()
                        {
                            failed = true;
                        }
                        t0.elapsed().as_secs_f64()
                    })
                } else {
                    // per-group private buffers (groups share nothing)
                    let mut m = SignalMatrix::random(x, y, (g as u64 + 1) * 7919);
                    mean_using_ttest(&policy, || {
                        let t0 = Instant::now();
                        if engine
                            .fft_rows(&mut m.re, &mut m.im, x, y, Direction::Forward, t)
                            .is_err()
                        {
                            failed = true;
                        }
                        t0.elapsed().as_secs_f64()
                    })
                };
                if !failed {
                    results.lock().unwrap()[g] = Some(tt.mean);
                }
            });
        }
    });
    results.into_inner().unwrap()
}

/// Convenience: profile the plane y = n only (what PFFT-FPM Step 1
/// actually consumes when a full surface is unaffordable).
pub fn build_plane(
    engine: &dyn RowFftEngine,
    cfg: GroupConfig,
    xs: Vec<usize>,
    n: usize,
    rep_scale: usize,
) -> Vec<SpeedFunction> {
    build_plane_kind(engine, cfg, xs, n, rep_scale, TransformKind::C2c)
}

/// [`build_plane`] for an explicit transform kind (real planes measure
/// the r2c pair kernel).
pub fn build_plane_kind(
    engine: &dyn RowFftEngine,
    cfg: GroupConfig,
    xs: Vec<usize>,
    n: usize,
    rep_scale: usize,
    kind: TransformKind,
) -> Vec<SpeedFunction> {
    let mut spec = ProfileSpec::new(xs, vec![n], cfg).with_kind(kind);
    spec.rep_scale = rep_scale;
    build_fpms(engine, &spec)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::NativeEngine;

    fn quick_spec(xs: Vec<usize>, ys: Vec<usize>) -> ProfileSpec {
        let mut s = ProfileSpec::new(xs, ys, GroupConfig::new(2, 1));
        s.rep_scale = 10_000; // min reps ~3
        s
    }

    #[test]
    fn builds_full_grid() {
        let spec = quick_spec(vec![4, 8], vec![32, 64]);
        let fpms = build_fpms(&NativeEngine, &spec);
        assert_eq!(fpms.len(), 2);
        for f in &fpms {
            assert_eq!(f.measured_points(), 4);
            for &x in &[4usize, 8] {
                for &y in &[32usize, 64] {
                    let s = f.get(x, y).expect("measured");
                    assert!(s > 0.0, "speed {s}");
                }
            }
        }
    }

    #[test]
    fn bigger_batches_not_slower_per_flop() {
        // speed(8 rows) should be >= ~0.3x speed(1 row): smoke check that
        // the speed formula normalizes batch size
        let spec = quick_spec(vec![1, 8], vec![128]);
        let fpms = build_fpms(&NativeEngine, &spec);
        let s1 = fpms[0].get(1, 128).unwrap();
        let s8 = fpms[0].get(8, 128).unwrap();
        assert!(s8 > 0.3 * s1, "s1 {s1} s8 {s8}");
    }

    #[test]
    fn sample_sink_receives_every_measured_point() {
        let spec = quick_spec(vec![4, 8], vec![32]);
        let mut samples: Vec<(usize, usize, f64)> = Vec::new();
        let fpms = build_fpms_with(&NativeEngine, &spec, |x, y, t| samples.push((x, y, t)));
        assert_eq!(samples.len(), 4, "2 points x 2 groups");
        assert!(samples.iter().all(|&(_, _, t)| t > 0.0 && t.is_finite()));
        assert_eq!(fpms[0].measured_points(), 2);
    }

    #[test]
    fn budget_yields_partial_fpm() {
        let mut spec = quick_spec(vec![4, 8, 16, 32], vec![64, 128]);
        spec.budget_s = 0.0; // cut off immediately
        let fpms = build_fpms(&NativeEngine, &spec);
        assert!(fpms[0].measured_points() < 8);
    }

    #[test]
    fn plane_helper_single_y() {
        let fpms = build_plane(&NativeEngine, GroupConfig::new(2, 1), vec![4, 8], 64, 10_000);
        assert_eq!(fpms.len(), 2);
        let c = fpms[0].plane_section(64);
        assert_eq!(c.xs, vec![4, 8]);
    }

    #[test]
    fn real_plane_measures_r2c_kernel() {
        // the real plane must build (positive speeds) and carry the
        // kind tag in the surface name; c2r maps to the shared r2c plane
        let fpms = build_plane_kind(
            &NativeEngine,
            GroupConfig::new(2, 1),
            vec![8, 16],
            64,
            10_000,
            TransformKind::C2r,
        );
        assert_eq!(fpms.len(), 2);
        for f in &fpms {
            assert!(f.name.contains("r2c"), "surface name `{}` must carry the kind", f.name);
            for &x in &[8usize, 16] {
                let s = f.get(x, 64).expect("measured");
                assert!(s > 0.0);
            }
        }
    }
}
