//! Deterministic pseudo-random generators.
//!
//! Two uses in this crate, both needing *reproducibility*, not
//! cryptographic quality:
//!
//! * the [`crate::simulator`] synthesises package speed-function noise
//!   keyed by `(package, problem size)` — [`splitmix64`] acts as the hash
//!   so every figure regenerates bit-identically;
//! * test-input generation in the mini property-test harness.

/// One splitmix64 step: a high-quality 64-bit mixer (Steele et al.).
#[inline]
pub fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = x;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Hash an arbitrary byte-key list into one u64 (for noise keyed by
/// `(pkg, n)` tuples).
pub fn hash_key(parts: &[u64]) -> u64 {
    let mut h = 0x51_7C_C1_B7_27_22_0A_95u64;
    for &p in parts {
        h = splitmix64(h ^ p);
    }
    h
}

/// Map a u64 to a uniform f64 in [0, 1).
#[inline]
pub fn unit_f64(x: u64) -> f64 {
    (x >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
}

/// xoshiro256** — fast, high-quality sequential PRNG.
#[derive(Clone, Debug)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    /// Seed via splitmix64 expansion (the reference seeding procedure).
    pub fn seeded(seed: u64) -> Self {
        let mut x = seed;
        let mut s = [0u64; 4];
        for slot in &mut s {
            x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
            *slot = splitmix64(x);
        }
        Self { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        unit_f64(self.next_u64())
    }

    /// Uniform usize in [lo, hi] inclusive.
    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo <= hi);
        let span = (hi - lo) as u64 + 1;
        lo + (self.next_u64() % span) as usize
    }

    /// Standard normal via Box-Muller.
    pub fn next_gaussian(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-300);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_is_deterministic_and_mixing() {
        assert_eq!(splitmix64(0), splitmix64(0));
        assert_ne!(splitmix64(0), splitmix64(1));
        // avalanche sanity: single-bit flip changes many output bits
        let d = (splitmix64(42) ^ splitmix64(43)).count_ones();
        assert!(d > 16, "weak mixing: {d} bits");
    }

    #[test]
    fn unit_f64_in_range() {
        for i in 0..1000u64 {
            let v = unit_f64(splitmix64(i));
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn xoshiro_reproducible() {
        let mut a = Xoshiro256::seeded(7);
        let mut b = Xoshiro256::seeded(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn xoshiro_uniform_mean() {
        let mut r = Xoshiro256::seeded(1);
        let mean: f64 = (0..10_000).map(|_| r.next_f64()).sum::<f64>() / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Xoshiro256::seeded(2);
        let xs: Vec<f64> = (0..20_000).map(|_| r.next_gaussian()).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / xs.len() as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.08, "var {var}");
    }

    #[test]
    fn range_usize_bounds() {
        let mut r = Xoshiro256::seeded(3);
        for _ in 0..1000 {
            let v = r.range_usize(5, 9);
            assert!((5..=9).contains(&v));
        }
    }
}
