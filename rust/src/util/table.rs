//! Aligned console tables + CSV writing for the figures harness.

use std::fs;
use std::io;
use std::path::Path;

/// A simple column-aligned table that renders like the paper's tables.
#[derive(Clone, Debug, Default)]
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        debug_assert_eq!(cells.len(), self.header.len(), "ragged table row");
        self.rows.push(cells);
    }

    /// Render with padded columns and a rule under the header.
    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths = vec![0usize; ncol];
        for (i, h) in self.header.iter().enumerate() {
            widths[i] = widths[i].max(h.len());
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("== {} ==\n", self.title));
        }
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            cells
                .iter()
                .zip(widths)
                .map(|(c, w)| format!("{c:>w$}"))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.header, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (ncol - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    /// Write the rows as CSV (quotes cells containing commas).
    pub fn write_csv(&self, path: &Path) -> io::Result<()> {
        if let Some(dir) = path.parent() {
            fs::create_dir_all(dir)?;
        }
        let esc = |c: &str| {
            if c.contains(',') || c.contains('"') {
                format!("\"{}\"", c.replace('"', "\"\""))
            } else {
                c.to_string()
            }
        };
        let mut s = String::new();
        s.push_str(&self.header.iter().map(|h| esc(h)).collect::<Vec<_>>().join(","));
        s.push('\n');
        for row in &self.rows {
            s.push_str(&row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
            s.push('\n');
        }
        fs::write(path, s)
    }
}

/// Format a float with fixed decimals, trimming noise.
pub fn fnum(x: f64, decimals: usize) -> String {
    format!("{x:.decimals$}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo", &["n", "speed"]);
        t.row(vec!["128".into(), "17841.0".into()]);
        t.row(vec!["64000".into(), "7.5".into()]);
        let r = t.render();
        assert!(r.contains("== demo =="));
        assert!(r.lines().count() >= 4);
        // alignment: both data lines have the same length
        let lines: Vec<&str> = r.lines().skip(2).collect();
        assert_eq!(lines[0].len(), lines[1].len());
    }

    #[test]
    fn csv_escapes() {
        let mut t = Table::new("", &["a", "b"]);
        t.row(vec!["x,y".into(), "pl\"ain".into()]);
        let dir = std::env::temp_dir().join("hclfft_table_test");
        let path = dir.join("t.csv");
        t.write_csv(&path).unwrap();
        let s = std::fs::read_to_string(&path).unwrap();
        assert_eq!(s, "a,b\n\"x,y\",\"pl\"\"ain\"\n");
    }

    #[test]
    fn fnum_formats() {
        assert_eq!(fnum(1.23456, 2), "1.23");
        assert_eq!(fnum(2.0, 1), "2.0");
    }
}
