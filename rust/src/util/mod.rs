//! Small self-contained utilities.
//!
//! The offline vendor set ships only the `xla` crate's dependency closure,
//! so the usual ecosystem crates (`rand`, `serde_json`, `proptest`,
//! `prettytable`) are replaced by minimal in-repo equivalents:
//!
//! * [`prng`] — deterministic splitmix64 / xoshiro256** generators,
//! * [`json`] — a tiny JSON *emitter* (results files only; inputs use TSV),
//! * [`table`] — aligned console tables for the figures harness,
//! * [`proptest`] — a miniature property-testing harness with input
//!   shrinking used by `rust/tests/proptests.rs`.

pub mod json;
pub mod prng;
pub mod proptest;
pub mod table;

/// Round `x` up to the next multiple of `m` (m > 0).
pub fn round_up(x: usize, m: usize) -> usize {
    debug_assert!(m > 0);
    x.div_ceil(m) * m
}

/// Integer log2 for exact powers of two.
pub fn log2_exact(n: usize) -> Option<u32> {
    (n.is_power_of_two()).then(|| n.trailing_zeros())
}

/// `true` if `n` is a power of two (and nonzero).
pub fn is_pow2(n: usize) -> bool {
    n != 0 && n & (n - 1) == 0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_up_basics() {
        assert_eq!(round_up(0, 8), 0);
        assert_eq!(round_up(1, 8), 8);
        assert_eq!(round_up(8, 8), 8);
        assert_eq!(round_up(9, 8), 16);
        assert_eq!(round_up(127, 64), 128);
    }

    #[test]
    fn log2_exact_basics() {
        assert_eq!(log2_exact(1), Some(0));
        assert_eq!(log2_exact(1024), Some(10));
        assert_eq!(log2_exact(3), None);
        assert_eq!(log2_exact(0), None);
    }

    #[test]
    fn is_pow2_basics() {
        assert!(is_pow2(1));
        assert!(is_pow2(65536));
        assert!(!is_pow2(0));
        assert!(!is_pow2(24704));
    }
}
