//! Miniature property-testing harness.
//!
//! `proptest`/`quickcheck` are not in the offline vendor set, so this
//! module provides the 10% of them this repo needs: deterministic random
//! case generation from a seeded [`Xoshiro256`], a configurable number of
//! cases, and greedy *shrinking* of failing inputs via a user-supplied
//! shrink function. Used by `rust/tests/proptests.rs` on the coordinator
//! invariants (partition sums, makespan bounds, FFT roundtrips, ...).

use crate::util::prng::Xoshiro256;

/// Configuration for a property run.
#[derive(Clone, Debug)]
pub struct Config {
    pub cases: usize,
    pub seed: u64,
    pub max_shrink_steps: usize,
}

impl Default for Config {
    fn default() -> Self {
        // Seed overridable for reproduction of CI failures.
        let seed = std::env::var("HCLFFT_PROPTEST_SEED")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(0xC0FFEE);
        Config { cases: 64, seed, max_shrink_steps: 200 }
    }
}

/// Outcome of a single case check.
pub type CheckResult = Result<(), String>;

/// Run `check` on `cfg.cases` inputs drawn from `gen`. On failure, shrink
/// with `shrink` (returns candidate smaller inputs) and panic with the
/// minimal reproducer.
pub fn run<T, G, S, C>(name: &str, cfg: &Config, mut gen: G, shrink: S, check: C)
where
    T: Clone + std::fmt::Debug,
    G: FnMut(&mut Xoshiro256) -> T,
    S: Fn(&T) -> Vec<T>,
    C: Fn(&T) -> CheckResult,
{
    let mut rng = Xoshiro256::seeded(cfg.seed ^ hash_name(name));
    for case in 0..cfg.cases {
        let input = gen(&mut rng);
        if let Err(msg) = check(&input) {
            let (min_input, min_msg) = shrink_loop(input, msg, &shrink, &check, cfg);
            panic!(
                "property `{name}` failed (case {case}/{}, seed {:#x}):\n  input: {min_input:?}\n  error: {min_msg}",
                cfg.cases, cfg.seed
            );
        }
    }
}

fn shrink_loop<T, S, C>(
    mut input: T,
    mut msg: String,
    shrink: &S,
    check: &C,
    cfg: &Config,
) -> (T, String)
where
    T: Clone + std::fmt::Debug,
    S: Fn(&T) -> Vec<T>,
    C: Fn(&T) -> CheckResult,
{
    let mut steps = 0;
    'outer: while steps < cfg.max_shrink_steps {
        for cand in shrink(&input) {
            steps += 1;
            if let Err(m) = check(&cand) {
                input = cand;
                msg = m;
                continue 'outer; // keep shrinking from the smaller failure
            }
            if steps >= cfg.max_shrink_steps {
                break;
            }
        }
        break; // no shrink candidate fails — minimal
    }
    (input, msg)
}

fn hash_name(name: &str) -> u64 {
    name.bytes().fold(0xcbf29ce484222325u64, |h, b| {
        (h ^ b as u64).wrapping_mul(0x100000001b3)
    })
}

/// Common shrinker: halve a usize toward a lower bound.
pub fn shrink_usize(x: usize, lo: usize) -> Vec<usize> {
    let mut out = Vec::new();
    if x > lo {
        out.push(lo);
        let mid = lo + (x - lo) / 2;
        if mid != lo && mid != x {
            out.push(mid);
        }
        out.push(x - 1);
    }
    out.dedup();
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0usize;
        run(
            "always-true",
            &Config { cases: 10, seed: 1, max_shrink_steps: 10 },
            |r| r.range_usize(0, 100),
            |_| vec![],
            |_| {
                // count via a Cell-free hack: can't capture &mut in Fn, so
                // assert trivially; case counting tested via panic below.
                Ok(())
            },
        );
        count += 1;
        assert_eq!(count, 1);
    }

    #[test]
    #[should_panic(expected = "property `fails-above-10`")]
    fn failing_property_panics() {
        run(
            "fails-above-10",
            &Config { cases: 50, seed: 2, max_shrink_steps: 50 },
            |r| r.range_usize(0, 1000),
            |x| shrink_usize(*x, 0),
            |x| if *x <= 10 { Ok(()) } else { Err(format!("{x} > 10")) },
        );
    }

    #[test]
    fn shrinking_finds_minimal_reproducer() {
        // run the shrink loop directly: minimal failing usize > 10 is 11
        let cfg = Config { cases: 1, seed: 3, max_shrink_steps: 500 };
        let check = |x: &usize| if *x <= 10 { Ok(()) } else { Err("big".to_string()) };
        let shrink = |x: &usize| shrink_usize(*x, 0);
        let (min, _) = shrink_loop(987usize, "big".into(), &shrink, &check, &cfg);
        assert_eq!(min, 11);
    }

    #[test]
    fn shrink_usize_candidates() {
        assert_eq!(shrink_usize(10, 0), vec![0, 5, 9]);
        assert!(shrink_usize(0, 0).is_empty());
        assert_eq!(shrink_usize(1, 0), vec![0]);
    }
}
