//! Minimal JSON emitter for results files.
//!
//! Only *output* is needed (figure series, bench reports, experiment
//! records); all machine-readable *inputs* in this repo are TSV
//! (`artifacts/manifest.tsv`, speed-function dumps), so no parser lives
//! here.

use std::fmt::Write as _;

/// A JSON value tree.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Int(i64),
    Str(String),
    Arr(Vec<Json>),
    /// Insertion-ordered object (Vec keeps output stable for diffs).
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(Vec::new())
    }

    /// Add/overwrite a field on an object (panics on non-objects —
    /// builder misuse is a programming error).
    pub fn set(mut self, key: &str, val: impl Into<Json>) -> Json {
        match &mut self {
            Json::Obj(fields) => {
                let val = val.into();
                if let Some(f) = fields.iter_mut().find(|(k, _)| k == key) {
                    f.1 = val;
                } else {
                    fields.push((key.to_string(), val));
                }
                self
            }
            _ => panic!("Json::set on non-object"),
        }
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Serialize with 2-space indentation.
    pub fn to_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        let (nl, pad, pad_in) = match indent {
            Some(w) => ("\n", " ".repeat(w * depth), " ".repeat(w * (depth + 1))),
            None => ("", String::new(), String::new()),
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Int(i) => {
                let _ = write!(out, "{i}");
            }
            Json::Num(x) => {
                if x.is_finite() {
                    let _ = write!(out, "{x}");
                } else {
                    out.push_str("null"); // JSON has no NaN/Inf
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push_str(nl);
                    out.push_str(&pad_in);
                    item.write(out, indent, depth + 1);
                }
                if !items.is_empty() {
                    out.push_str(nl);
                    out.push_str(&pad);
                }
                out.push(']');
            }
            Json::Obj(fields) => {
                out.push('{');
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push_str(nl);
                    out.push_str(&pad_in);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !fields.is_empty() {
                    out.push_str(nl);
                    out.push_str(&pad);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}
impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Json {
        Json::Int(x)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Int(x as i64)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars() {
        assert_eq!(Json::Null.to_string(), "null");
        assert_eq!(Json::from(true).to_string(), "true");
        assert_eq!(Json::from(3i64).to_string(), "3");
        assert_eq!(Json::from(1.5).to_string(), "1.5");
        assert_eq!(Json::from("a\"b\n").to_string(), r#""a\"b\n""#);
    }

    #[test]
    fn nan_becomes_null() {
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
        assert_eq!(Json::Num(f64::INFINITY).to_string(), "null");
    }

    #[test]
    fn object_builder_ordered_and_overwrites() {
        let j = Json::obj().set("b", 1i64).set("a", 2i64).set("b", 3i64);
        assert_eq!(j.to_string(), r#"{"b":3,"a":2}"#);
    }

    #[test]
    fn arrays_nest() {
        let j = Json::from(vec![1i64, 2, 3]);
        assert_eq!(j.to_string(), "[1,2,3]");
        let o = Json::obj().set("xs", j);
        assert_eq!(o.to_string(), r#"{"xs":[1,2,3]}"#);
    }

    #[test]
    fn pretty_roundtrips_structure() {
        let j = Json::obj()
            .set("name", "fig15")
            .set("series", Json::from(vec![1.0, 2.0]));
        let p = j.to_pretty();
        assert!(p.contains("\n  \"name\": \"fig15\""), "{p}");
    }

    #[test]
    fn control_chars_escaped() {
        assert_eq!(Json::from("\u{1}").to_string(), "\"\\u0001\"");
    }
}
